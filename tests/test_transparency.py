"""Adversarial transparency-subsystem tests, mirroring tests/test_wire.py:
the canonical manifest codec treats every byte as hostile (truncation,
tag flips, version skew, non-canonical orderings, byte-flip fuzz), the
bundle <-> manifest digest binding fails closed, and transparency-log
inclusion/consistency proofs reject forgery and equivocation."""
import struct

import numpy as np
import pytest

from repro.core import transparency as tl
from repro.core import wire
from repro.core.commit import (CommitmentManifest, MANIFEST_VERSION,
                               MissingCommitmentError, TableGeometry)
from repro.core.session import ProofBundle, WireFormatError, ZKGraphSession

HEADER = len(wire.MAGIC) + 2 + 1     # magic + u16 version + u8 payload kind


@pytest.fixture(scope="module")
def manifest(owner):
    return owner.commitments


@pytest.fixture(scope="module")
def raw(manifest):
    return manifest.to_bytes()


@pytest.fixture(scope="module")
def log(raw):
    """A small log whose FIRST leaf is the owner's real manifest, padded
    with distinct revision leaves (so proofs have real paths)."""
    log = tl.TransparencyLog("test-log")
    log.append(raw)
    for i in range(5):
        log.append(raw + bytes([i]))
    return log


# ---------------------------------------------------------------------------
# canonical manifest round trip + digest
# ---------------------------------------------------------------------------
def test_manifest_roundtrip_byte_identical(raw):
    rt = CommitmentManifest.from_bytes(raw)
    assert rt.to_bytes() == raw


def test_manifest_roundtrip_preserves_every_field(manifest, raw):
    rt = CommitmentManifest.from_bytes(raw)
    assert rt.version == manifest.version
    assert rt.n_nodes == manifest.n_nodes
    assert rt.edge_counts == dict(manifest.edge_counts)
    assert set(rt.tables) == set(manifest.tables)
    for desc, geo in manifest.tables.items():
        got = rt.tables[desc]
        assert (got.n_cols, got.n_table_rows) == (geo.n_cols,
                                                  geo.n_table_rows)
        assert tuple(got.sizes) == tuple(geo.sizes)
        assert tuple(got.columns) == tuple(geo.columns)
    assert set(rt.roots) == set(manifest.roots)
    for key in manifest.roots:
        assert np.array_equal(rt.roots[key], manifest.roots[key])
        assert rt.roots[key].dtype == np.uint32


def test_manifest_digest_is_leaf_hash_of_canonical_bytes(manifest, raw):
    assert np.array_equal(manifest.digest(), tl.manifest_digest(raw))
    rt = CommitmentManifest.from_bytes(raw)
    assert np.array_equal(rt.digest(), manifest.digest())


def test_drop_keeps_published_digest(manifest, bundle, tiny_cfg):
    """A partial deployment trusts the same PUBLISHED manifest (same
    digest); a step over the missing table is a deployment error
    (MissingCommitmentError), not an authenticity failure (False)."""
    partial = manifest.drop("hasCreator")
    assert np.array_equal(partial.digest(), manifest.digest())
    with pytest.raises(MissingCommitmentError):
        ZKGraphSession.verifier(partial, tiny_cfg).verify(bundle)


# ---------------------------------------------------------------------------
# malformed manifest bytes fail closed
# ---------------------------------------------------------------------------
def test_manifest_truncation_rejected(raw):
    for cut in (0, 1, HEADER - 1, HEADER, HEADER + 2, len(raw) // 2,
                len(raw) - 1):
        with pytest.raises(WireFormatError):
            CommitmentManifest.from_bytes(raw[:cut])


def test_manifest_trailing_bytes_rejected(raw):
    with pytest.raises(WireFormatError):
        CommitmentManifest.from_bytes(raw + b"\x00")


def test_manifest_bad_magic_and_wire_version_skew(raw):
    with pytest.raises(WireFormatError):
        CommitmentManifest.from_bytes(b"NOPE" + raw[4:])
    future = raw[:4] + struct.pack("<H", wire.WIRE_VERSION + 1) + raw[6:]
    with pytest.raises(WireFormatError):
        CommitmentManifest.from_bytes(future)


def test_manifest_payload_kind_confusion(bundle, raw):
    with pytest.raises(WireFormatError):
        CommitmentManifest.from_bytes(bundle.to_bytes())
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(raw)


def test_manifest_version_skew_rejected(raw):
    # manifest schema version sits right after its field tag at HEADER
    skewed = raw[: HEADER + 1] + struct.pack(
        "<I", MANIFEST_VERSION + 1) + raw[HEADER + 5:]
    with pytest.raises(WireFormatError, match="manifest version"):
        CommitmentManifest.from_bytes(skewed)


def test_manifest_flipped_field_tag_rejected(raw):
    flipped = bytearray(raw)
    flipped[HEADER] ^= 0xFF
    with pytest.raises(WireFormatError):
        CommitmentManifest.from_bytes(bytes(flipped))


def test_manifest_byte_flips_fail_closed_or_stay_canonical(raw):
    """Any single byte flip either raises WireFormatError or lands in root
    data and still decodes to a manifest whose re-encoding is byte-identical
    — there is no byte whose corruption silently de-canonicalizes."""
    rng = np.random.default_rng(13)
    survived = 0
    for pos in rng.integers(0, len(raw), size=48):
        flipped = bytearray(raw)
        flipped[pos] ^= 0x20
        try:
            m = CommitmentManifest.from_bytes(bytes(flipped))
        except WireFormatError:
            continue
        survived += 1
        assert m.to_bytes() == bytes(flipped)
    assert survived > 0          # root payload bytes do survive, canonically


def _mini_manifest_bytes(edge_names=("a", "b"), root_key=("t", 8),
                         sizes=(8, 16)):
    """Hand-encode a minimal manifest so non-canonical orderings (which the
    real encoder refuses to produce) can be fed to the decoder."""
    e = wire._Enc()
    e.buf += wire.MAGIC
    e.u16(wire.WIRE_VERSION)
    e.u8(wire.KIND_MANIFEST)
    e.u8(wire._F_M_VERSION)
    e.u32(MANIFEST_VERSION)
    e.u8(wire._F_M_NNODES)
    e.i64(4)
    e.u8(wire._F_M_EDGES)
    e.u32(len(edge_names))
    for name in edge_names:
        e.string(name)
        e.i64(3)
    e.u8(wire._F_M_TABLES)
    e.u32(1)
    e.string("t")
    e.u32(2)                     # n_cols
    e.u32(5)                     # n_table_rows
    e.u32(len(sizes))
    for s in sizes:
        e.u32(s)
    e.u32(0)                     # no named columns
    e.u8(wire._F_M_ROOTS)
    e.u32(1)
    e.string(root_key[0])
    e.u32(root_key[1])
    e.array(np.arange(8, dtype=np.uint32), dtype=np.uint32, ndim=1)
    return bytes(e.buf)


def test_mini_manifest_is_valid_and_canonical():
    raw = _mini_manifest_bytes()
    m = CommitmentManifest.from_bytes(raw)
    assert m.to_bytes() == raw
    assert m.edge_counts == {"a": 3, "b": 3}
    assert m.geometry("t").sizes == (8, 16)


def test_non_canonical_edge_order_rejected():
    with pytest.raises(WireFormatError, match="edge-count order"):
        CommitmentManifest.from_bytes(_mini_manifest_bytes(
            edge_names=("b", "a")))
    with pytest.raises(WireFormatError, match="duplicate|order"):
        CommitmentManifest.from_bytes(_mini_manifest_bytes(
            edge_names=("a", "a")))


def test_non_increasing_sizes_rejected():
    with pytest.raises(WireFormatError, match="strictly increasing"):
        CommitmentManifest.from_bytes(_mini_manifest_bytes(sizes=(16, 8)))


def test_root_without_published_geometry_rejected():
    # unknown descriptor, and a size the geometry never published
    with pytest.raises(WireFormatError, match="geometry"):
        CommitmentManifest.from_bytes(_mini_manifest_bytes(
            root_key=("ghost", 8)))
    with pytest.raises(WireFormatError, match="geometry"):
        CommitmentManifest.from_bytes(_mini_manifest_bytes(
            root_key=("t", 32)))


def test_encoder_rejects_what_decoder_rejects(manifest):
    """encode and decode accept the same language: un-publishable objects
    (roots without geometry, wrong manifest version) fail at encode too."""
    bad = CommitmentManifest(
        manifest.version, manifest.n_nodes, dict(manifest.edge_counts),
        dict(manifest.tables), dict(manifest.roots))
    bad.roots[("ghost", 64)] = np.arange(8, dtype=np.uint32)
    with pytest.raises(WireFormatError, match="geometry"):
        bad.to_bytes()
    skewed = CommitmentManifest(
        MANIFEST_VERSION + 1, 4, {}, {"t": TableGeometry("t", 1, 1, (8,))})
    with pytest.raises(WireFormatError, match="version"):
        skewed.to_bytes()


# ---------------------------------------------------------------------------
# bundle <-> manifest digest binding
# ---------------------------------------------------------------------------
def test_bundle_carries_manifest_digest(bundle, manifest):
    assert np.array_equal(bundle.manifest_digest, manifest.digest())
    rt = ProofBundle.from_bytes(bundle.to_bytes())
    assert np.array_equal(rt.manifest_digest, manifest.digest())


def test_digestless_bundle_not_encodable_and_not_verifiable(bundle,
                                                            verifier):
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.manifest_digest = None
    with pytest.raises(WireFormatError, match="manifest_digest"):
        clone.to_bytes()
    assert verifier.verify(clone) is False


def test_tampered_digest_fails_closed_through_the_wire(bundle, verifier):
    """A re-encoded bundle claiming a different manifest digest survives the
    codec (the digest is just 8 lanes) but MUST die at the digest pin."""
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.manifest_digest = clone.manifest_digest.copy()
    clone.manifest_digest[3] ^= 1
    rewired = clone.to_bytes()
    assert ProofBundle.from_bytes(rewired).to_bytes() == rewired
    assert verifier.verify_bytes(rewired) is False
    assert verifier.verify_bytes(bundle.to_bytes()) is True


def test_verify_against_different_manifest_digest_is_false(bundle, manifest,
                                                           tiny_cfg):
    """A verifier bootstrapped from a DIFFERENT published manifest (revised
    geometry => different canonical bytes => different digest) rejects the
    bundle up front — equivocation between prove and verify fails closed."""
    other = CommitmentManifest(
        manifest.version, manifest.n_nodes, dict(manifest.edge_counts),
        dict(manifest.tables), dict(manifest.roots))
    k = sorted(other.edge_counts)[0]
    other.edge_counts[k] += 1                     # a one-count revision
    assert not np.array_equal(other.digest(), bundle.manifest_digest)
    assert ZKGraphSession.verifier(other, tiny_cfg).verify(bundle) is False


# ---------------------------------------------------------------------------
# transparency log: inclusion, consistency, forgery, equivocation
# ---------------------------------------------------------------------------
def test_inclusion_every_leaf_every_size(log):
    for idx in range(log.size):
        for size in range(idx + 1, log.size + 1):
            pf = log.inclusion_proof(idx, size)
            leaf = tl.manifest_digest(log.entry(idx))
            assert tl.verify_inclusion(log.checkpoint(size), pf, leaf)


def test_inclusion_wrong_leaf_or_index_fails(log):
    cp = log.checkpoint()
    pf = log.inclusion_proof(2)
    assert not tl.verify_inclusion(cp, pf, tl.manifest_digest(log.entry(3)))
    pf_wrong = tl.InclusionProof(3, pf.tree_size, pf.path)
    assert not tl.verify_inclusion(cp, pf_wrong,
                                   tl.manifest_digest(log.entry(2)))


def test_inclusion_forged_path_fails(log):
    cp = log.checkpoint()
    pf = log.inclusion_proof(2)
    leaf = tl.manifest_digest(log.entry(2))
    for row in range(pf.path.shape[0]):
        forged = pf.path.copy()
        forged[row, 0] ^= 1
        assert not tl.verify_inclusion(
            cp, tl.InclusionProof(pf.leaf_index, pf.tree_size, forged), leaf)
    # truncated and extended paths fail too (never crash)
    short = tl.InclusionProof(pf.leaf_index, pf.tree_size, pf.path[:-1])
    assert not tl.verify_inclusion(cp, short, leaf)
    extended = tl.InclusionProof(pf.leaf_index, pf.tree_size,
                                 np.vstack([pf.path, pf.path[:1]]))
    assert not tl.verify_inclusion(cp, extended, leaf)


def test_consistency_every_pair(log):
    for old in range(1, log.size + 1):
        for new in range(old, log.size + 1):
            pr = log.consistency_proof(old, new)
            assert tl.verify_consistency(log.checkpoint(old),
                                         log.checkpoint(new), pr), (old, new)


def test_consistency_forgery_fails(log):
    old, new = log.checkpoint(3), log.checkpoint(log.size)
    pr = log.consistency_proof(3)
    for row in range(pr.path.shape[0]):
        forged = pr.path.copy()
        forged[row, 0] ^= 1
        assert not tl.verify_consistency(
            old, new, tl.ConsistencyProof(pr.old_size, pr.new_size, forged))
    # size-mismatched proofs are rejected before any hashing
    assert not tl.verify_consistency(
        old, new, tl.ConsistencyProof(2, pr.new_size, pr.path))


def test_equivocation_detected(log, raw):
    """An owner that rewrites history (different first leaf) cannot produce
    a consistency proof linking the honest checkpoint to the forked log."""
    fork = tl.TransparencyLog(log.origin)
    fork.append(raw + b"\xff")           # different manifest at leaf 0
    for i in range(5):
        fork.append(raw + bytes([i]))
    honest_cp = log.checkpoint(1)
    forked_cp = fork.checkpoint()
    assert not tl.verify_consistency(honest_cp, forked_cp,
                                     fork.consistency_proof(1))
    # a same-origin prefix-honest log, by contrast, passes
    assert tl.verify_consistency(log.checkpoint(2), log.checkpoint(),
                                 log.consistency_proof(2))


def test_cross_origin_checkpoints_rejected(log):
    other = tl.TransparencyLog("other-log")
    other.append(log.entry(0))
    pr = log.consistency_proof(1)
    assert not tl.verify_consistency(other.checkpoint(), log.checkpoint(),
                                     pr)


def test_log_bounds_fail_closed(log):
    with pytest.raises(tl.TransparencyError):
        log.inclusion_proof(log.size)              # no such leaf
    with pytest.raises(tl.TransparencyError):
        log.inclusion_proof(0, log.size + 1)       # no such checkpoint
    with pytest.raises(tl.TransparencyError):
        log.consistency_proof(0)                   # RFC: old size >= 1
    with pytest.raises(tl.TransparencyError):
        log.root(log.size + 1)


# ---------------------------------------------------------------------------
# checkpoint / proof wire codecs
# ---------------------------------------------------------------------------
def test_transparency_structures_roundtrip(log):
    cp = log.checkpoint()
    cp2 = tl.Checkpoint.from_bytes(cp.to_bytes())
    assert (cp2.origin, cp2.tree_size) == (cp.origin, cp.tree_size)
    assert np.array_equal(cp2.root, cp.root)
    assert cp2.to_bytes() == cp.to_bytes()
    pf = log.inclusion_proof(1)
    pf2 = tl.InclusionProof.from_bytes(pf.to_bytes())
    assert pf2.to_bytes() == pf.to_bytes()
    assert tl.verify_inclusion(cp, pf2, tl.manifest_digest(log.entry(1)))
    pr = log.consistency_proof(2)
    pr2 = tl.ConsistencyProof.from_bytes(pr.to_bytes())
    assert pr2.to_bytes() == pr.to_bytes()
    assert tl.verify_consistency(log.checkpoint(2), cp, pr2)


def test_transparency_structures_malformed_rejected(log):
    cp_raw = log.checkpoint().to_bytes()
    pf_raw = log.inclusion_proof(1).to_bytes()
    pr_raw = log.consistency_proof(2).to_bytes()
    decoders = ((cp_raw, tl.Checkpoint.from_bytes),
                (pf_raw, tl.InclusionProof.from_bytes),
                (pr_raw, tl.ConsistencyProof.from_bytes))
    for raw_msg, decode in decoders:
        for cut in (0, HEADER - 1, HEADER, len(raw_msg) - 1):
            with pytest.raises(WireFormatError):
                decode(raw_msg[:cut])
        with pytest.raises(WireFormatError):
            decode(raw_msg + b"\x00")
    with pytest.raises(WireFormatError):
        tl.InclusionProof.from_bytes(cp_raw)       # kind confusion
    with pytest.raises(WireFormatError):
        tl.ConsistencyProof.from_bytes(pf_raw)
    # out-of-range index is rejected at decode, not verification
    bad = tl.InclusionProof(0, 1, np.zeros((0, 8), np.uint32)).to_bytes()
    hacked = bad.replace(struct.pack("<q", 1), struct.pack("<q", 0), 1)
    with pytest.raises(WireFormatError):
        tl.InclusionProof.from_bytes(hacked)


# ---------------------------------------------------------------------------
# verifier bootstrap from a checkpoint (the full trust chain)
# ---------------------------------------------------------------------------
def test_verifier_bootstraps_from_checkpoint(log, raw, bundle, tiny_cfg):
    cp = log.checkpoint()
    pf = log.inclusion_proof(0)                    # the real manifest leaf
    v = ZKGraphSession.verifier(cfg=tiny_cfg, checkpoint=cp, inclusion=pf,
                                manifest_bytes=raw)
    assert v.verify(bundle) is True
    assert v.verify_bytes(bundle.to_bytes()) is True


def test_bootstrap_rejects_unlogged_or_tampered_manifest(log, raw, tiny_cfg):
    cp = log.checkpoint()
    pf = log.inclusion_proof(0)
    with pytest.raises(tl.TransparencyError):
        ZKGraphSession.verifier(cfg=tiny_cfg, checkpoint=cp, inclusion=pf,
                                manifest_bytes=raw + b"\x00")
    wrong_leaf = log.inclusion_proof(1)
    with pytest.raises(tl.TransparencyError):
        ZKGraphSession.verifier(cfg=tiny_cfg, checkpoint=cp,
                                inclusion=wrong_leaf, manifest_bytes=raw)
    with pytest.raises(tl.TransparencyError):
        ZKGraphSession.verifier(cfg=tiny_cfg, checkpoint=cp, inclusion=pf,
                                manifest_bytes=None)
    with pytest.raises(TypeError):
        ZKGraphSession.verifier()


def test_bootstrap_included_junk_fails_at_decode(tiny_cfg):
    """A log leaf that is not a valid manifest passes inclusion but fails
    closed at decode — the verifier never holds an unparsed trust root."""
    junk = b"not a manifest"
    log = tl.TransparencyLog("junk-log")
    cp = log.append(junk)
    pf = log.inclusion_proof(0)
    with pytest.raises(WireFormatError):
        ZKGraphSession.verifier(cfg=tiny_cfg, checkpoint=cp, inclusion=pf,
                                manifest_bytes=junk)
