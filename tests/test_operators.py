"""Graph operator tests (paper §IV): witness-level constraint satisfaction
(fast, exact) for completeness/soundness, plus full prove+verify round trips
on representative operators."""
import numpy as np
import pytest

from repro.core import field as F
from repro.core import prover as pv
from repro.core.operators import (all_shortest, birc, expansion, orderby,
                                  reachability, set_expansion, sssp)
from repro.core.operators.common import check_constraints
from repro.graphdb import engine, ldbc
from repro.graphdb.storage import pad_pow2

FAST = pv.ProverConfig(blowup=4, n_queries=8, fri_final_size=16)


@pytest.fixture(scope="module")
def db():
    return ldbc.generate(n_knows=100, n_persons=24, seed=3)


# ---------------------------------------------------------------------------
# single-source expansion, edge-list
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_expand_edge_list_complete_and_prove(db):
    t = db.tables["person_knows_person"]
    src_id = int(t.src[0])
    op = expansion.build_edge_list(pad_pow2(len(t)), len(t))
    advice, inst, data = expansion.witness_edge_list(op, t.src, t.dst, src_id)
    assert check_constraints(op, advice, inst, data) == []
    # oracle agreement
    want, _ = engine.expand(t, src_id)
    got = inst[op.handles["C_t"].index][inst[op.handles["out_sel"].index] == 1]
    assert sorted(got.tolist()) == sorted(want.tolist())
    # full round trip incl. dataset-root binding
    op.keygen(FAST)
    proof = op.prove(advice, inst, data)
    assert op.verify(inst, proof, expected_data_root=proof.data_root)
    assert not op.verify(inst, proof, expected_data_root=np.zeros(8, np.uint32))


def test_expand_edge_list_soundness(db):
    t = db.tables["person_knows_person"]
    src_id = int(t.src[0])
    op = expansion.build_edge_list(pad_pow2(len(t)), len(t))
    advice, inst, data = expansion.witness_edge_list(op, t.src, t.dst, src_id)
    # (a) forged neighbour in the output
    bad_inst = inst.copy()
    col = op.handles["C_t"].index
    bad_inst[col, 0] = (int(bad_inst[col, 0]) + 1) % F.P
    assert any(b.startswith("bus:out_perm") for b in
               check_constraints(op, advice, bad_inst, data))
    # (b) omitted edge: flip a flag off
    bad_adv = advice.copy()
    fl = op.handles["fl"].index
    row = int(np.nonzero(advice[fl])[0][0])
    bad_adv[fl, row] = 0
    assert check_constraints(op, bad_adv, inst, data) != []
    # (c) full-proof rejection for (a)
    op.keygen(FAST)
    proof = op.prove(advice, bad_inst, data)
    assert not op.verify(bad_inst, proof)


# ---------------------------------------------------------------------------
# single-source expansion, CSR (Table I comparison partner)
# ---------------------------------------------------------------------------
def test_expand_csr_complete(db):
    t = db.tables["person_knows_person"]
    col, row_ptr, lut = t.to_csr(db.node_ids)
    src_id = int(t.src[5])
    n_rows = pad_pow2(max(len(col), len(lut) + 1))
    op = expansion.build_csr(n_rows, len(col), len(lut),
                             id_bits=max(db.id_bits, n_rows.bit_length()))
    advice, inst, data = expansion.witness_csr(op, col, row_ptr, lut, src_id)
    assert check_constraints(op, advice, inst, data) == []
    want, _ = engine.expand(t, src_id)
    got = inst[op.handles["C_t"].index][inst[op.handles["out_sel"].index] == 1]
    assert sorted(got.tolist()) == sorted(want.tolist())


def test_expand_csr_soundness(db):
    t = db.tables["person_knows_person"]
    col, row_ptr, lut = t.to_csr(db.node_ids)
    src_id = int(t.src[5])
    n_rows = pad_pow2(max(len(col), len(lut) + 1))
    op = expansion.build_csr(n_rows, len(col), len(lut),
                             id_bits=max(db.id_bits, n_rows.bit_length()))
    advice, inst, data = expansion.witness_csr(op, col, row_ptr, lut, src_id)
    # widen the claimed range by one: extra spurious neighbour
    bad = advice.copy()
    r_s = op.handles["r_s"].index
    bad[r_s] = (bad[r_s].astype(np.int64) + 1) % F.P
    assert check_constraints(op, bad, inst, data) != []


# ---------------------------------------------------------------------------
# set-based expansion
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bidir", [False, True])
def test_set_expansion_complete(db, bidir):
    t = db.tables["person_knows_person"]
    ids = np.unique(t.src[:6])
    op = set_expansion.build(pad_pow2(len(t)), len(t), len(ids),
                             bidirectional=bidir)
    advice, inst, data = set_expansion.witness(op, t.src, t.dst, ids)
    assert check_constraints(op, advice, inst, data) == []
    out_sel = inst[op.handles["out_sel"].index] == 1
    got = set(zip(inst[op.handles["C_s"].index][out_sel].tolist(),
                  inst[op.handles["C_t"].index][out_sel].tolist()))
    if not bidir:
        s, d, _ = engine.expand_set(t, ids)
        assert got == set(zip(s.tolist(), d.tolist()))
    else:
        s, d, _ = engine.expand_set(t, ids)
        s2 = t.dst[np.isin(t.dst, ids)]
        d2 = t.src[np.isin(t.dst, ids)]
        assert got == set(zip(s.tolist(), d.tolist())) | \
            set(zip(s2.tolist(), d2.tolist()))


def test_set_expansion_soundness(db):
    t = db.tables["person_knows_person"]
    ids = np.unique(t.src[:6])
    op = set_expansion.build(pad_pow2(len(t)), len(t), len(ids))
    advice, inst, data = set_expansion.witness(op, t.src, t.dst, ids)
    # drop one output edge
    bad = inst.copy()
    sel = op.handles["out_sel"].index
    row = int(np.nonzero(inst[sel])[0][-1])
    bad[sel, row] = 0
    assert check_constraints(op, advice, bad, data) != []
    # tamper the sorted copy (breaks permutation to committed data)
    bad_adv = advice.copy()
    ap = op.handles["Ap"].index
    bad_adv[ap, 0] = (int(bad_adv[ap, 0]) + 1) % F.P
    assert check_constraints(op, bad_adv, inst, data) != []


def test_set_expansion_prove_verify(db):
    t = db.tables["person_knows_person"]
    ids = np.unique(t.src[:4])
    op = set_expansion.build(pad_pow2(len(t)), len(t), len(ids)).keygen(FAST)
    advice, inst, data = set_expansion.witness(op, t.src, t.dst, ids)
    proof = op.prove(advice, inst, data)
    assert op.verify(inst, proof)


# ---------------------------------------------------------------------------
# SSSP
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("undirected", [True, False])
def test_sssp_complete(db, undirected):
    t = db.tables["person_knows_person"]
    src_id = int(db.node_ids[0])
    dist, pred, pd = engine.bfs_sssp(t, db.node_ids, src_id, undirected)
    n_rows = pad_pow2(max(len(t), db.n_nodes))
    op = sssp.build(n_rows, len(t), db.n_nodes, undirected=undirected)
    advice, inst, data = sssp.witness(op, t.src, t.dst, db.node_ids, src_id,
                                      dist, pred, pd)
    assert check_constraints(op, advice, inst, data) == []


def test_sssp_soundness_short_and_long(db):
    t = db.tables["person_knows_person"]
    src_id = int(db.node_ids[0])
    dist, pred, pd = engine.bfs_sssp(t, db.node_ids, src_id, True)
    n_rows = pad_pow2(max(len(t), db.n_nodes))
    op = sssp.build(n_rows, len(t), db.n_nodes, undirected=True)
    reachable = np.nonzero((dist > 0) & (dist < db.n_nodes + 1))[0]
    v = int(reachable[0])
    # claim shorter than truth -> path-validity constraints break
    d_short = dist.copy()
    d_short[v] -= 1
    advice, inst, data = sssp.witness(op, t.src, t.dst, db.node_ids, src_id,
                                      d_short, pred, pd)
    assert check_constraints(op, advice, inst, data) != []
    # claim longer than truth -> relaxation breaks
    d_long = dist.copy()
    d_long[v] += 1
    advice, inst, data = sssp.witness(op, t.src, t.dst, db.node_ids, src_id,
                                      d_long, pred, pd)
    assert check_constraints(op, advice, inst, data) != []
    # falsely claim unreachable -> relaxation breaks
    d_unr = dist.copy()
    d_unr[v] = db.n_nodes + 1
    advice, inst, data = sssp.witness(op, t.src, t.dst, db.node_ids, src_id,
                                      d_unr, pred, pd)
    assert check_constraints(op, advice, inst, data) != []


def test_sssp_prove_verify(db):
    t = db.tables["person_knows_person"]
    src_id = int(db.node_ids[0])
    dist, pred, pd = engine.bfs_sssp(t, db.node_ids, src_id, True)
    n_rows = pad_pow2(max(len(t), db.n_nodes))
    op = sssp.build(n_rows, len(t), db.n_nodes, undirected=True).keygen(FAST)
    advice, inst, data = sssp.witness(op, t.src, t.dst, db.node_ids, src_id,
                                      dist, pred, pd)
    proof = op.prove(advice, inst, data)
    assert op.verify(inst, proof)


# ---------------------------------------------------------------------------
# BiRC
# ---------------------------------------------------------------------------
def test_birc_complete_and_sound(db):
    t = db.tables["person_knows_person"]
    op = birc.build(pad_pow2(len(t)), len(t))
    advice, inst, data = birc.witness(op, t.src, t.dst)
    assert check_constraints(op, advice, inst, data) == []
    lo = inst[op.handles["L"].index][: len(t)]
    hi = inst[op.handles["H"].index][: len(t)]
    assert (lo <= hi).all()
    assert ((lo == t.src) | (lo == t.dst)).all()
    # non-canonical (swapped) output must fail the order range check
    row = int(np.nonzero(t.src != t.dst)[0][0])
    bad = inst.copy()
    bad[op.handles["L"].index, row], bad[op.handles["H"].index, row] = \
        bad[op.handles["H"].index, row], bad[op.handles["L"].index, row]
    assert check_constraints(op, advice, bad, data) != []
    # sum ok but product wrong
    bad2 = inst.copy()
    L, H = op.handles["L"].index, op.handles["H"].index
    bad2[L, row] = (int(bad2[L, row]) + 1) % F.P
    bad2[H, row] = (int(bad2[H, row]) - 1) % F.P
    assert any("prod" in b or "order" in b
               for b in check_constraints(op, advice, bad2, data))


# ---------------------------------------------------------------------------
# order-by / limit-k
# ---------------------------------------------------------------------------
def test_orderby_complete_and_sound(db):
    t = db.tables["comment_hasCreator_person"]
    vals = t.props["creationDate"][:50]
    pay = t.src[:50]
    k = 10
    op = orderby.build(pad_pow2(50), 50, k)
    advice, inst, data = orderby.witness(op, vals, pay)
    assert check_constraints(op, advice, inst, data) == []
    sel, pivot = engine.top_k(vals, k)
    got = inst[op.handles["O_val"].index][
        inst[op.handles["out_sel"].index] == 1]
    assert sorted(got.tolist()) == sorted(vals[sel].tolist())
    # swap a top-k entry for a non-top-k one
    bad = advice.copy()
    isk = op.handles["isk"].index
    on = int(np.nonzero(advice[isk])[0][0])
    off = int(np.nonzero((advice[isk] == 0) & (np.arange(len(advice[isk])) < 50))[0][0])
    bad[isk, on], bad[isk, off] = 0, 1
    assert check_constraints(op, bad, inst, data) != []


# ---------------------------------------------------------------------------
# reachability
# ---------------------------------------------------------------------------
def test_reachability_complete_and_sound(db):
    t = db.tables["person_knows_person"]
    dist, _, _ = engine.bfs_sssp(t, db.node_ids, int(db.node_ids[0]), True)
    far = np.nonzero((dist >= 2) & (dist < db.n_nodes + 1))[0]
    s, tt = int(db.node_ids[0]), int(db.node_ids[int(far[0])])
    path = engine.find_path(t, db.node_ids, s, tt)
    assert path is not None
    op = reachability.build(pad_pow2(len(t)), len(t), len(path))
    advice, inst, data = reachability.witness(op, t.src, t.dst, path, s, tt)
    assert check_constraints(op, advice, inst, data) == []
    # corrupt an interior path node -> a step stops being an edge
    bad = advice.copy()
    pcol = op.handles["path"].index
    bad[pcol, 1] = (int(bad[pcol, 1]) + 1) % F.P
    assert check_constraints(op, bad, inst, data) != []
    # claim reachability of a node not on the path
    bad_inst = inst.copy()
    bad_inst[op.handles["id_t"].index] = 999999
    assert check_constraints(op, advice, bad_inst, data) != []


# ---------------------------------------------------------------------------
# all-shortest-paths frontier
# ---------------------------------------------------------------------------
def test_all_shortest_complete_and_sound(db):
    t = db.tables["person_knows_person"]
    s = int(db.node_ids[0])
    dist, _, _ = engine.bfs_sssp(t, db.node_ids, s, True)
    cand = np.nonzero((dist >= 2) & (dist < db.n_nodes + 1))[0]
    tt = int(db.node_ids[int(cand[0])])
    d = int(dist[int(cand[0])])
    n_rows = pad_pow2(max(len(t), db.n_nodes))
    op = all_shortest.build(n_rows, len(t), db.n_nodes, undirected=True)
    advice, inst, data = all_shortest.witness(op, t.src, t.dst, db.node_ids,
                                              dist, tt, d)
    assert check_constraints(op, advice, inst, data) == []
    # oracle: frontier = {p : dist[p]=d-1, (p,tt) canonical edge either way}
    idx_of = {int(v): i for i, v in enumerate(db.node_ids.tolist())}
    want = []
    for a, b in zip(t.src.tolist(), t.dst.tolist()):
        if b == tt and dist[idx_of[a]] == d - 1:
            want.append(a)
        if a == tt and dist[idx_of[b]] == d - 1:
            want.append(b)
    out_sel = inst[op.handles["out_sel"].index] == 1
    got = inst[op.handles["C_out"].index][out_sel].tolist()
    assert sorted(got) == sorted(want)
    assert len(got) > 0
    # omitting one frontier member must break the multiset argument
    bad = inst.copy()
    row = int(np.nonzero(inst[op.handles["out_sel"].index])[0][0])
    bad[op.handles["out_sel"].index, row] = 0
    assert check_constraints(op, advice, bad, data) != []
