"""NTT vs naive DFT, LDE consistency, extension-point evaluation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field as F
from repro.core import poly


@pytest.mark.parametrize("n", [1, 2, 8, 64, 256])
def test_ntt_matches_naive_dft(n):
    rng = np.random.default_rng(n)
    a = rng.integers(0, F.P, size=n).astype(np.uint32)
    got = np.asarray(poly.ntt(jnp.asarray(a)))
    want = poly.naive_dft(a)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [2, 32, 128])
def test_intt_roundtrip(n):
    rng = np.random.default_rng(n + 1)
    a = jnp.asarray(rng.integers(0, F.P, size=(3, n)).astype(np.uint32))
    back = poly.intt(poly.ntt(a))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(a))


@pytest.mark.parametrize("blowup", [2, 4])
def test_coset_lde_agrees_pointwise(blowup):
    """LDE evaluations must equal Horner evaluation of the coefficients at
    every coset point."""
    n = 16
    rng = np.random.default_rng(7)
    evals = jnp.asarray(rng.integers(0, F.P, size=n).astype(np.uint32))
    lde = np.asarray(poly.coset_lde(evals, blowup))
    coeffs = np.asarray(poly.intt(evals))
    pts = np.asarray(poly.domain_points(n * blowup, poly.COSET_SHIFT))
    for i in range(0, n * blowup, 5):
        x = int(pts[i])
        want = 0
        for j in range(n - 1, -1, -1):
            want = (want * x + int(coeffs[j])) % F.P
        assert int(lde[i]) == want


def test_lde_restricts_to_original_on_subgroup():
    """f on H_n must reappear inside the LDE when the shift is 1 and indices
    are strided by blowup."""
    n, blowup = 32, 4
    rng = np.random.default_rng(9)
    evals = jnp.asarray(rng.integers(0, F.P, size=n).astype(np.uint32))
    lde = np.asarray(poly.coset_lde(evals, blowup, shift=1))
    np.testing.assert_array_equal(lde[::blowup], np.asarray(evals))


def test_eval_at_ext_matches_base_eval():
    n = 32
    rng = np.random.default_rng(11)
    coeffs = jnp.asarray(rng.integers(0, F.P, size=n).astype(np.uint32))
    # pick a base-field point embedded in Fp4 — must agree with Horner in Fp
    x = 12345
    z = jnp.asarray(np.array([x, 0, 0, 0], np.uint32))
    got = np.asarray(poly.eval_at_ext(coeffs, z))
    want = 0
    cs = np.asarray(coeffs)
    for j in range(n - 1, -1, -1):
        want = (want * x + int(cs[j])) % F.P
    assert got[0] == want and np.all(got[1:] == 0)


def test_batched_ntt_shapes():
    a = jnp.zeros((5, 3, 16), jnp.uint32)
    out = poly.ntt(a)
    assert out.shape == (5, 3, 16)
