"""Golden wire-format vectors: the byte-level spec in docs/protocol.md is
checked against bytes committed under tests/vectors/, so neither the codec
nor the doc can silently drift.  Each vector is rebuilt programmatically and
must equal the committed hex byte-for-byte; the committed hex must decode
and re-encode to itself; digests and log proofs must verify.

Regenerate after an INTENTIONAL format change (and update docs/protocol.md):

    PYTHONPATH=src python tests/test_vectors.py --write
"""
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import gossip as gp
from repro.core import transparency as tl
from repro.core import wire
from repro.core.commit import (CommitmentManifest, MANIFEST_VERSION,
                               TableGeometry)
from repro.core.ed25519 import SigningKey

VECTOR_DIR = Path(__file__).resolve().parent / "vectors"


# ---------------------------------------------------------------------------
# deterministic builders (no database, no randomness, no timestamps)
# ---------------------------------------------------------------------------
def build_manifest() -> CommitmentManifest:
    """A tiny two-table manifest with fixed roots — the spec's worked
    example (docs/protocol.md §7)."""
    roots = {
        ("knows", 8): np.arange(8, dtype=np.uint32),
        ("knows", 16): np.arange(8, 16, dtype=np.uint32),
        ("person_name", 8): np.full(8, 7, dtype=np.uint32),
    }
    tables = {
        "knows": TableGeometry("knows", 2, 5, (8, 16), ("src", "dst")),
        "person_name": TableGeometry("person_name", 2, 4, (8,),
                                     ("id", "name")),
    }
    return CommitmentManifest(MANIFEST_VERSION, 6,
                              {"person_knows_person": 5}, tables, roots)


def build_log() -> tl.TransparencyLog:
    """A 5-leaf log: leaf 0 is the manifest vector, later leaves are
    distinct revisions of it."""
    log = tl.TransparencyLog("zkgraph-vector-log")
    raw = build_manifest().to_bytes()
    log.append(raw)
    for i in range(4):
        log.append(raw + bytes([i]))
    return log


def build_value() -> bytes:
    """A kitchen-sink `value` exercising every tag of the value grammar
    (docs/protocol.md §2)."""
    e = wire._Enc()
    e.value({
        "arr": np.array([[1, 2], [3, 4]], np.uint32),
        "bool": True,
        "float": 2.5,
        "int": -7,
        "list": [1, "two"],
        "str": "zkgraph",
        "tuple": (np.array([5], np.int64), False),
    })
    return bytes(e.buf)


def _u32s_to_bytes(digest: np.ndarray) -> bytes:
    return np.asarray(digest, np.uint32).astype("<u4").tobytes()


VECTOR_GOSSIP_KEY = SigningKey.from_secret(b"zkgraph-vector-gossip-key")


def build_gossip() -> gp.GossipMessage:
    """The vector log's size-5 head as an Ed25519-signed gossip message
    carrying the 3 -> 5 consistency proof (docs/protocol.md §10)."""
    return gp.emit(build_log(), VECTOR_GOSSIP_KEY, since=3)


def vectors() -> dict:
    manifest_raw = build_manifest().to_bytes()
    log = build_log()
    return {
        "manifest.hex": manifest_raw,
        "manifest_digest.hex": _u32s_to_bytes(tl.manifest_digest(
            manifest_raw)),
        "checkpoint_size5.hex": log.checkpoint().to_bytes(),
        "checkpoint_size3.hex": log.checkpoint(3).to_bytes(),
        "inclusion_leaf0_size5.hex": log.inclusion_proof(0).to_bytes(),
        "consistency_3_to_5.hex": log.consistency_proof(3).to_bytes(),
        "value_kitchen_sink.hex": build_value(),
        "gossip_head_3_to_5.hex": build_gossip().to_bytes(),
        "logstore_5_leaves.hex": build_store_bytes(),
    }


def build_store_bytes() -> bytes:
    """The exact on-disk bytes of a durable store holding the vector log
    (docs/protocol.md §9): magic, origin record, and per append an entry
    record followed by its checkpoint record — all CRC-framed and
    position-bound (each record's CRC covers its file offset)."""
    from repro.core import logstore as ls
    log = build_log()
    out = bytearray(ls.STORE_MAGIC)
    out += ls.frame_record(ls.REC_ORIGIN, log.origin.encode("utf-8"),
                           len(out))
    for i in range(log.size):
        out += ls.frame_record(ls.REC_ENTRY, log.entry(i), len(out))
        out += ls.frame_record(ls.REC_CHECKPOINT,
                               log.checkpoint(i + 1).to_bytes(), len(out))
    return bytes(out)


def _read(name: str) -> bytes:
    path = VECTOR_DIR / name
    assert path.exists(), \
        f"missing golden vector {name}; regenerate with " \
        f"`PYTHONPATH=src python tests/test_vectors.py --write`"
    return bytes.fromhex(path.read_text().strip())


# ---------------------------------------------------------------------------
# the vectors hold
# ---------------------------------------------------------------------------
def test_builders_reproduce_committed_bytes():
    for name, built in vectors().items():
        assert built == _read(name), f"vector {name} drifted from the codec"


def test_manifest_vector_decodes_and_reencodes():
    raw = _read("manifest.hex")
    m = CommitmentManifest.from_bytes(raw)
    assert m.to_bytes() == raw
    assert m.n_nodes == 6
    assert m.geometry("knows").columns == ("src", "dst")
    assert np.array_equal(m.root("knows", 16),
                          np.arange(8, 16, dtype=np.uint32))


def test_manifest_digest_vector():
    digest = np.frombuffer(_read("manifest_digest.hex"), "<u4")
    assert np.array_equal(tl.manifest_digest(_read("manifest.hex")), digest)


def test_checkpoint_and_proof_vectors_verify():
    cp5 = tl.Checkpoint.from_bytes(_read("checkpoint_size5.hex"))
    cp3 = tl.Checkpoint.from_bytes(_read("checkpoint_size3.hex"))
    incl = tl.InclusionProof.from_bytes(_read("inclusion_leaf0_size5.hex"))
    cons = tl.ConsistencyProof.from_bytes(_read("consistency_3_to_5.hex"))
    assert cp5.to_bytes() == _read("checkpoint_size5.hex")
    assert (cp5.origin, cp5.tree_size) == ("zkgraph-vector-log", 5)
    digest = np.frombuffer(_read("manifest_digest.hex"), "<u4")
    assert tl.verify_inclusion(cp5, incl, digest)
    assert tl.verify_consistency(cp3, cp5, cons)
    # and the binding is real: the digest of different bytes is NOT included
    other = tl.manifest_digest(_read("manifest.hex") + b"\x00")
    assert not tl.verify_inclusion(cp5, incl, other)


def test_value_vector_decodes_to_expected_object():
    raw = _read("value_kitchen_sink.hex")
    got = wire._Dec(raw).value()
    assert got["int"] == -7 and got["bool"] is True and got["float"] == 2.5
    assert got["str"] == "zkgraph" and got["list"] == [1, "two"]
    assert np.array_equal(got["arr"], [[1, 2], [3, 4]])
    assert np.array_equal(got["tuple"][0], [5]) and got["tuple"][1] is False
    # canonical: re-encoding the decoded object reproduces the bytes
    e = wire._Enc()
    e.value(got)
    assert bytes(e.buf) == raw


def test_gossip_vector_verifies_end_to_end():
    raw = _read("gossip_head_3_to_5.hex")
    msg = gp.GossipMessage.from_bytes(raw)
    assert msg.to_bytes() == raw
    assert msg.signer == VECTOR_GOSSIP_KEY.pub
    assert gp.verify_signature(msg.signer, msg.checkpoint, msg.signature)
    cp3 = tl.Checkpoint.from_bytes(_read("checkpoint_size3.hex"))
    assert tl.verify_consistency(cp3, msg.checkpoint, msg.consistency)
    # a peer pinned at the size-3 vector checkpoint advances on exactly it
    peer = gp.GossipPeer("zkgraph-vector-log", VECTOR_GOSSIP_KEY.pub)
    peer.offer(gp.GossipMessage(
        cp3, None, VECTOR_GOSSIP_KEY.pub,
        gp.sign_checkpoint(VECTOR_GOSSIP_KEY, cp3)))
    assert peer.offer(msg) is True
    assert peer.pinned.tree_size == 5


def test_logstore_vector_replays_to_the_vector_log():
    from repro.core import logstore as ls
    raw = _read("logstore_5_leaves.hex")
    origin, entries, checkpoints, intact = ls.replay(raw)
    assert intact == len(raw)
    assert origin == "zkgraph-vector-log"
    log = build_log()
    assert entries == [log.entry(i) for i in range(log.size)]
    assert [cp.tree_size for _, cp in checkpoints] == [1, 2, 3, 4, 5]
    assert np.array_equal(checkpoints[-1][1].root, log.root())
    # and a torn tail inside the final (checkpoint) record truncates back
    # to exactly the end of the last intact record
    last_cp = log.checkpoint(5).to_bytes()
    last_start = len(raw) - (5 + len(last_cp) + 4)   # hdr + payload + crc
    assert ls.frame_record(ls.REC_CHECKPOINT, last_cp, last_start) \
        == raw[last_start:]
    _, entries, _, intact2 = ls.replay(raw[:-5])
    assert len(entries) == 5 and intact2 == last_start


def test_wire_constants_pinned():
    """The spec constants in docs/protocol.md §1 are written against these
    values; bump the doc and regenerate vectors when changing them."""
    assert wire.MAGIC == b"ZKGB"
    assert wire.WIRE_VERSION == 3
    assert (wire.KIND_BUNDLE, wire.KIND_PROOF, wire.KIND_FRI,
            wire.KIND_MANIFEST, wire.KIND_CHECKPOINT, wire.KIND_INCLUSION,
            wire.KIND_CONSISTENCY, wire.KIND_GOSSIP) == (1, 2, 3, 4, 5, 6,
                                                         7, 9)
    assert wire._KIND_GOSSIP_MAC_RETIRED == 8   # never reused
    assert (wire.SIGNER_LEN, wire.SIG_LEN) == (32, 64)


if __name__ == "__main__":
    if "--write" not in sys.argv:
        sys.exit("usage: PYTHONPATH=src python tests/test_vectors.py --write")
    VECTOR_DIR.mkdir(exist_ok=True)
    for name, built in vectors().items():
        (VECTOR_DIR / name).write_text(built.hex() + "\n")
        print(f"wrote {name}: {len(built)} bytes")
