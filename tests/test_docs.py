"""Docs stay true: the protocol spec's pinned constants and worked-example
digest are checked against the live codec, and the README quickstart block
must exist and reference the real API (CI additionally *executes* it via
docs/run_quickstart.py)."""
import re
from pathlib import Path

from repro.core import wire

ROOT = Path(__file__).resolve().parent.parent


def _section(text, start, end=None):
    """The slice of a doc between two headings (to its end if end=None)."""
    i = text.index(start)
    return text[i:text.index(end)] if end else text[i:]


_PIN_ROW = r"\|\s*`([A-Z_]+)`\s*\|\s*`([0-9a-fx]+)`\s*\|"


def test_protocol_constants_match_wire_module():
    text = (ROOT / "docs" / "protocol.md").read_text()
    rows = re.findall(_PIN_ROW, _section(text, "## 8.", "## 9."))
    pinned = dict(rows)
    assert "MAGIC" in pinned and "WIRE_VERSION" in pinned, \
        "protocol.md §8 constants table is missing or unparseable"
    assert bytes.fromhex(pinned.pop("MAGIC")) == wire.MAGIC
    for name, value in pinned.items():
        assert int(value, 0) == getattr(wire, name), \
            f"docs/protocol.md pins {name}={value} but wire.{name} is " \
            f"{getattr(wire, name)}"
    # every cap and kind the module exports is pinned in the doc
    exported = {n for n in dir(wire)
                if n.startswith(("KIND_", "MAX_", "SIG"))
                or n == "WIRE_VERSION"}
    missing = exported - set(pinned) - {"MAGIC"}
    assert not missing, f"protocol.md §8 is missing constants: {missing}"


def test_protocol_net_constants_match_framing_module():
    """§10's transport constants AND the frame-kind table are pinned
    against repro.net.framing — the wire format of the socket fabric is a
    spec, not an implementation detail."""
    from repro.net import framing
    text = (ROOT / "docs" / "protocol.md").read_text()
    sec = _section(text, "## 10.")
    pinned = dict(re.findall(_PIN_ROW, sec))
    assert "NET_MAGIC" in pinned and "MAX_FRAME" in pinned, \
        "protocol.md §10 constants tables are missing or unparseable"
    assert bytes.fromhex(pinned.pop("NET_MAGIC")) == framing.NET_MAGIC
    for name, value in pinned.items():
        assert int(value, 0) == getattr(framing, name), \
            f"docs/protocol.md pins {name}={value} but framing.{name} is " \
            f"{getattr(framing, name)}"
    # every frame kind and transport cap the module exports is pinned
    exported = {n for n in dir(framing)
                if n.startswith(("REQ_", "RESP_"))
                or n in ("NET_VERSION", "MAX_FRAME")}
    missing = exported - set(pinned) - {"NET_MAGIC"}
    assert not missing, f"protocol.md §10 is missing constants: {missing}"
    # the retirement story stays told: kind 8 and tag 0x82 are documented
    # as retired, never reused
    assert "retired" in _section(text, "## 1.", "## 2.").lower()
    assert "0x82" in sec and "never reused" in sec


def test_protocol_worked_example_digest_matches_vector():
    text = (ROOT / "docs" / "protocol.md").read_text()
    vector = (ROOT / "tests" / "vectors" / "manifest_digest.hex") \
        .read_text().strip()
    assert vector in text, \
        "protocol.md §7's worked-example digest drifted from " \
        "tests/vectors/manifest_digest.hex"


def test_readme_quickstart_block_present_and_current():
    readme = (ROOT / "README.md").read_text()
    m = re.search(r"```python\n(.*?)```", readme, re.S)
    assert m, "README.md lost its quickstart code block"
    code = m.group(1)
    # the snippet must exercise the documented trust path end to end:
    # durable log, gossip-pinned head, byte-level verification
    for needle in ("ZKGraphSession", "TransparencyLog.open", "publish_to",
                   "verify_bytes", "GossipPeer", "gossip="):
        assert needle in code, f"README quickstart no longer uses {needle}"
    compile(code, "README.md#quickstart", "exec")    # at least parses


def test_readme_networked_snippet_present_and_current():
    """The README's networked-quickstart block must exercise the real
    socket fabric: a NetServer serving the signed head, a PeerClient
    fetching it, and the gossip peer verifying the Ed25519 envelope."""
    readme = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", readme, re.S)
    net = [b for b in blocks if "NetServer" in b]
    assert net, "README.md lost its networked-quickstart code block"
    code = net[0]
    for needle in ("from repro.net import", "PeerClient", "REQ_HEAD",
                   "GossipMessage.from_bytes", "peer.offer"):
        assert needle in code, \
            f"README networked snippet no longer uses {needle}"
    compile(code, "README.md#networked", "exec")     # at least parses
    # and the full multi-process demo is pointed at
    assert "examples/serve_queries.py" in readme


def test_readme_serving_snippet_present_and_current():
    readme = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```python\n(.*?)```", readme, re.S)
    serving = [b for b in blocks if "ProofService" in b]
    assert serving, "README.md lost its serving code block"
    code = serving[0]
    for needle in ("from repro.serve import ProofService", "svc.submit",
                   "f.result()", "svc.stats()"):
        assert needle in code, f"README serving snippet no longer uses {needle}"
    compile(code, "README.md#serving", "exec")       # at least parses


def test_serving_doc_matches_live_surfaces():
    """docs/serving.md must keep naming the real API and the real metrics
    schema (the schema itself is asserted against the live service in
    tests/test_serve.py::test_service_metrics_schema)."""
    text = (ROOT / "docs" / "serving.md").read_text()
    for needle in ("ProofService", "step_shape_key", "prove_batch",
                   "BatchedTranscript", "commit_lanes", "fri_prove_lanes",
                   "max_batch", "flush_interval", "max_pending",
                   "wire-byte-identical", "BENCH_serving.json"):
        assert needle in text, f"docs/serving.md no longer mentions {needle}"
    # every documented metrics key exists in the live schema constant
    from repro.serve.metrics import PHASES
    for phase in PHASES:
        assert f"`{phase}`" in text, \
            f"docs/serving.md metrics table is missing phase {phase}"
    for key in ("counters", "phase_us", "queue_wait_us", "prove_us",
                "batch_occupancy", "keygen_cache", "depths"):
        assert f"`{key}`" in text, \
            f"docs/serving.md metrics table is missing key {key}"
    # architecture.md links the serving section
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "repro.serve" in arch and "serving.md" in arch


def test_query_language_doc_matches_live_surfaces():
    """docs/query_language.md pins the real grammar surface: every catalog
    label/edge/property, every comparison and aggregation, and the parser's
    hard caps must match the live modules."""
    from repro.query import ast, catalog, parser
    text = (ROOT / "docs" / "query_language.md").read_text()
    for label in catalog.LABELS:
        assert f":{label}" in text, \
            f"docs/query_language.md is missing label {label}"
    for etype in catalog.EDGES:
        assert f":{etype}" in text, \
            f"docs/query_language.md is missing edge type {etype}"
    for fn in ast.AGG_FNS:
        assert f"`{fn}`" in text or f"{fn} \"(\"" in text, \
            f"docs/query_language.md is missing aggregation {fn}"
    for cmp in ast.CMP_TOKENS:
        assert f'"{cmp}"' in text, \
            f"docs/query_language.md is missing comparison {cmp}"
    # the documented caps are the enforced caps
    for name in ("MAX_TEXT", "MAX_ITEMS", "MAX_HOPS"):
        cap = getattr(parser, name)
        assert f"`{name}` {cap}" in text, \
            f"docs/query_language.md pins a stale value for {name}"
    from repro.core.operators import filter as filter_op
    assert f"`VAL_BITS` = {filter_op.VAL_BITS}" in text
    for needle in ("compile_query", "prove_plan", "QuerySyntaxError",
                   "QueryCompileError", "tests/test_query_conformance.py",
                   "wire-byte-identical", "tests/test_query_vectors.py",
                   "shortestPath", "repro.query.ldbc_texts"):
        assert needle in text, \
            f"docs/query_language.md no longer mentions {needle}"
    # architecture.md links the section; README points at the doc
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "repro.query" in arch and "query_language.md" in arch
    assert "query_language.md" in (ROOT / "README.md").read_text()


def test_analysis_doc_matches_live_catalogue():
    """docs/analysis.md documents every check id the analyzer can emit,
    the adapter vetting contract, and the baseline workflow."""
    from repro.analysis.findings import ALL_CHECKS
    text = (ROOT / "docs" / "analysis.md").read_text()
    for check in sorted(ALL_CHECKS):
        assert f"`{check}`" in text, \
            f"docs/analysis.md is missing check id {check}"
    for needle in ("analysis_cases", "analysis_baseline.json",
                   "python -m repro.analysis", "--fail-on-findings",
                   "--selftest", "auto_multiplicities", "rotation diameter"):
        assert needle in text, f"docs/analysis.md no longer mentions {needle}"
    # architecture.md links the analysis section; README points at the doc
    arch = (ROOT / "docs" / "architecture.md").read_text()
    assert "repro.analysis" in arch and "analysis.md" in arch
    assert "analysis.md" in (ROOT / "README.md").read_text()
