"""Deterministic fault-injection suite (repro/net/faults.py).

Every injected fault — dropped, duplicated, reordered, truncated,
corrupted frames, frozen-peer stalls, connections killed mid-exchange —
must end in a typed error or a clean fallback: never a hang (the per-test
timeout enforces this), never acceptance of a damaged or unsigned head.

The scripts are consumed frame-by-frame in arrival order (request then
response for this strict RPC protocol), so each test states exactly which
frame misbehaves and replays identically every run.
"""
import time

import pytest

from repro.core import ed25519 as ed
from repro.core import gossip as gp
from repro.core.transparency import TransparencyLog
from repro.core.wire import WireFormatError
from repro.net import framing
from repro.net.faults import FaultProxy
from repro.net.peer import PeerClient, PeerUnavailable, RemoteError
from repro.net.server import NetServer

KEY = ed.SigningKey.from_secret(b"fault-test-origin-key")
ORIGIN = "fault-test-log"


def make_log(n=4):
    log = TransparencyLog(ORIGIN)
    for i in range(n):
        log.append(b"manifest-rev-%d" % i)
    return log


@pytest.fixture()
def head_server():
    """An owner serving its signed head; yields (server, log)."""
    log = make_log()
    srv = NetServer(conn_timeout=5.0)
    srv.register(framing.REQ_HEAD,
                 lambda p: (framing.RESP_HEAD, gp.emit(log, KEY).to_bytes()))
    srv.register(framing.REQ_PING, lambda p: (framing.RESP_PONG, p))
    with srv.serving():
        yield srv, log


def proxied_client(srv, script, timeout=0.4, retries=3, **kw):
    proxy = FaultProxy(("127.0.0.1", srv.port), script=script,
                       stall_seconds=kw.pop("stall_seconds", 1.2))
    addr = proxy.start()
    client = PeerClient(addr, timeout=timeout, retries=retries,
                        backoff=0.01, **kw)
    return proxy, client


def fetch_and_pin(client):
    kind, payload = client.request(framing.REQ_HEAD, b"")
    assert kind == framing.RESP_HEAD
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    assert peer.offer(gp.GossipMessage.from_bytes(payload)) is True
    return peer


# ---------------------------------------------------------------------------
# one fault per frame, each must resolve typed-or-clean
# ---------------------------------------------------------------------------
def test_dropped_request_is_retried_to_success(head_server):
    srv, _ = head_server
    proxy, client = proxied_client(srv, ["drop"])
    try:
        peer = fetch_and_pin(client)        # attempt 1 drops, attempt 2 lands
        assert peer.pinned.tree_size == 4
    finally:
        client.close()
        proxy.stop()


def test_dropped_response_is_retried_to_success(head_server):
    srv, _ = head_server
    proxy, client = proxied_client(srv, ["pass", "drop"])
    try:
        peer = fetch_and_pin(client)
        assert peer.pinned.tree_size == 4
    finally:
        client.close()
        proxy.stop()


def test_truncated_response_is_typed_then_recovered(head_server):
    """Half a frame then connection death: FrameError inside the client,
    one reconnect, clean success — the poisoned stream is never re-read."""
    srv, _ = head_server
    proxy, client = proxied_client(srv, ["pass", "truncate"])
    try:
        peer = fetch_and_pin(client)
        assert peer.pinned.tree_size == 4
    finally:
        client.close()
        proxy.stop()


def test_corrupted_head_is_never_accepted(head_server):
    """A flipped payload byte survives the transport (framing is intact) —
    so the *payload* codec or the signature must refuse it.  Either way the
    peer pins nothing."""
    srv, _ = head_server
    proxy, client = proxied_client(srv, ["pass", "corrupt"], retries=1)
    try:
        peer = gp.GossipPeer(ORIGIN, KEY.pub)
        kind, payload = client.request(framing.REQ_HEAD, b"")
        assert kind == framing.RESP_HEAD
        with pytest.raises((WireFormatError, gp.GossipError)):
            peer.offer(gp.GossipMessage.from_bytes(payload))
        assert peer.head is None
    finally:
        client.close()
        proxy.stop()


def test_every_corruption_position_fails_closed(head_server):
    """Sweep the corrupt action across many deterministic seeds: whatever
    byte flips, the outcome is a typed rejection, never a pinned forgery."""
    srv, _ = head_server
    outcomes = set()
    for seed in range(12):
        proxy = FaultProxy(("127.0.0.1", srv.port),
                           script=["pass", "corrupt"], seed=seed)
        addr = proxy.start()
        client = PeerClient(addr, timeout=0.4, retries=1, backoff=0.01)
        try:
            peer = gp.GossipPeer(ORIGIN, KEY.pub)
            _, payload = client.request(framing.REQ_HEAD, b"")
            try:
                peer.offer(gp.GossipMessage.from_bytes(payload))
                outcomes.add("accepted")
            except WireFormatError:
                outcomes.add("codec-rejected")
            except gp.GossipError:
                outcomes.add("signature-rejected")
            assert peer.head is None
        finally:
            client.close()
            proxy.stop()
    assert "accepted" not in outcomes
    assert outcomes                         # the sweep actually ran


def test_duplicated_response_leaves_protocol_recoverable(head_server):
    """A duplicated response frame desyncs the persistent connection: the
    next request reads the stale duplicate.  The duplicate is still an
    honestly-signed head — offer() treats it as the no-op replay it is —
    and the client recovers on its own connection lifecycle."""
    srv, _ = head_server
    proxy, client = proxied_client(srv, ["pass", "dup"])
    try:
        peer = fetch_and_pin(client)
        # next request consumes the stale duplicate first
        kind, payload = client.request(framing.REQ_HEAD, b"")
        assert kind == framing.RESP_HEAD
        assert peer.offer(gp.GossipMessage.from_bytes(payload)) is False
        assert peer.pinned.tree_size == 4   # replay was a no-op
    finally:
        client.close()
        proxy.stop()


def test_reordered_responses_are_detected_by_kind(head_server):
    """Reordering across two pipelined exchanges delivers a PONG where a
    HEAD was expected: the caller's kind check catches it — a typed
    protocol violation, not a mis-pinned head."""
    srv, _ = head_server
    # frames: req1 pass, req2 pass, then the two responses swap
    proxy, client = proxied_client(srv, ["pass", "reorder"])
    try:
        # issue REQ_PING then REQ_HEAD on one connection; the ping response
        # is held and released after the head response
        kind1, _ = client.request(framing.REQ_PING, b"marker")
        kind2, payload2 = client.request(framing.REQ_HEAD, b"")
        kinds = {kind1, kind2}
        assert kinds == {framing.RESP_PONG, framing.RESP_HEAD}
        got_head = payload2 if kind2 == framing.RESP_HEAD else None
        if kind2 != framing.RESP_HEAD:
            # caller-side contract: wrong kind => protocol violation, the
            # response is NOT fed to the gossip layer
            return
        peer = gp.GossipPeer(ORIGIN, KEY.pub)
        assert peer.offer(gp.GossipMessage.from_bytes(got_head)) is True
    finally:
        client.close()
        proxy.stop()


def test_frozen_peer_stall_falls_back_to_pinned_head(head_server):
    """The frozen-peer scenario end to end: a verifier with a pinned head
    asks for a newer one, the peer stalls past every timeout — the fetch
    dies typed, the verifier keeps serving from its pin."""
    srv, log = head_server
    proxy, client = proxied_client(srv, [], timeout=0.3, retries=2)
    try:
        peer = fetch_and_pin(client)        # healthy bootstrap
        log.append(b"manifest-rev-new")     # a newer head exists
        proxy.extend_script(["stall", "stall", "stall", "stall"])
        t0 = time.monotonic()
        with pytest.raises(PeerUnavailable):
            client.request(framing.REQ_HEAD, b"")
        assert time.monotonic() - t0 < 4.0  # bounded by budget, not wedged
        # the fallback: last pinned head still serves
        assert peer.pinned.tree_size == 4
    finally:
        client.close()
        proxy.stop()


def test_connection_killed_mid_exchange_is_typed(head_server):
    srv, _ = head_server
    proxy, client = proxied_client(srv, ["close", "close", "close"],
                                   retries=3)
    try:
        with pytest.raises(PeerUnavailable):
            client.request(framing.REQ_HEAD, b"")
    finally:
        client.close()
        proxy.stop()


def test_fault_storm_never_wedges_and_never_forges(head_server):
    """A deterministic storm of every fault class in sequence: each request
    either completes with an honestly-signed head or dies typed; the peer's
    pin only ever moves forward through verification."""
    srv, _ = head_server
    storm = ["drop", "pass", "corrupt", "truncate", "dup", "stall",
             "close", "pass", "reorder", "drop"]
    proxy, client = proxied_client(srv, storm, timeout=0.3, retries=2)
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    t0 = time.monotonic()
    try:
        for _ in range(8):
            try:
                kind, payload = client.request(framing.REQ_HEAD, b"")
            except (PeerUnavailable, RemoteError):
                continue                    # typed transport failure: fine
            if kind != framing.RESP_HEAD:
                continue                    # reordered junk: ignored
            try:
                peer.offer(gp.GossipMessage.from_bytes(payload))
            except (WireFormatError, gp.GossipError):
                continue                    # damaged payload: fine
        assert time.monotonic() - t0 < 20.0
        assert peer.head is None or peer.pinned.tree_size == 4
    finally:
        client.close()
        proxy.stop()


def test_unknown_script_action_rejected_up_front():
    with pytest.raises(ValueError, match="unknown fault actions"):
        FaultProxy(("127.0.0.1", 1), script=["explode"])
    proxy = FaultProxy(("127.0.0.1", 1))
    with pytest.raises(ValueError, match="unknown fault actions"):
        proxy.extend_script(["sever"])
