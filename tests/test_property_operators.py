"""Hypothesis property tests: operator circuits are complete (accept honest
witnesses) on random graphs, and the engine oracles agree with brute force.
Uses check_constraints (exact, no proof) so many cases stay fast."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.operators import expansion, set_expansion, sssp
from repro.core.operators.common import check_constraints
from repro.graphdb import engine
from repro.graphdb.storage import EdgeTable, pad_pow2


@st.composite
def small_graph(draw):
    n_nodes = draw(st.integers(4, 12))
    m = draw(st.integers(3, 24))
    src = draw(st.lists(st.integers(1, n_nodes), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(1, n_nodes), min_size=m, max_size=m))
    return (np.asarray(src, np.int64), np.asarray(dst, np.int64), n_nodes)


@given(small_graph(), st.integers(1, 12))
@settings(max_examples=8, deadline=None)
def test_expansion_complete_on_random_graphs(g, src_id):
    src, dst, n_nodes = g
    src_id = (src_id % n_nodes) + 1
    op = expansion.build_edge_list(pad_pow2(len(src)), len(src))
    advice, inst, data = expansion.witness_edge_list(op, src, dst, src_id)
    assert check_constraints(op, advice, inst, data) == []
    out_sel = inst[op.handles["out_sel"].index] == 1
    got = sorted(inst[op.handles["C_t"].index][out_sel].tolist())
    want = sorted(dst[src == src_id].tolist())
    assert got == want


@given(small_graph(), st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_set_expansion_complete_on_random_graphs(g, k):
    src, dst, n_nodes = g
    ids = np.unique(src)[:k]
    out_count = int(np.isin(src, ids).sum())
    n_rows = pad_pow2(max(len(src), len(ids) + 2, out_count))
    op = set_expansion.build(n_rows, len(src), len(ids))
    advice, inst, data = set_expansion.witness(op, src, dst, ids)
    assert check_constraints(op, advice, inst, data) == []
    out_sel = inst[op.handles["out_sel"].index] == 1
    got = sorted(zip(inst[op.handles["C_s"].index][out_sel].tolist(),
                     inst[op.handles["C_t"].index][out_sel].tolist()))
    mask = np.isin(src, ids)
    want = sorted(zip(src[mask].tolist(), dst[mask].tolist()))
    assert got == want


@given(small_graph())
@settings(max_examples=6, deadline=None)
def test_sssp_complete_on_random_graphs(g):
    src, dst, n_nodes = g
    node_ids = np.arange(1, n_nodes + 1, dtype=np.int64)
    t = EdgeTable(src, dst)
    s = int(node_ids[0])
    dist, pred, pd = engine.bfs_sssp(t, node_ids, s, undirected=True)
    # oracle: Floyd-Warshall-ish brute force on the undirected graph
    INF = n_nodes + 1
    d = np.full((n_nodes + 1,), INF)
    d[s] = 0
    for _ in range(n_nodes):
        for a, b in zip(src, dst):
            if d[a] + 1 < d[b]:
                d[b] = d[a] + 1
            if d[b] + 1 < d[a]:
                d[a] = d[b] + 1
    np.testing.assert_array_equal(dist, d[1:])
    n_rows = pad_pow2(max(len(src), n_nodes))
    op = sssp.build(n_rows, len(src), n_nodes, undirected=True)
    advice, inst, data = sssp.witness(op, src, dst, node_ids, s, dist, pred,
                                      pd)
    assert check_constraints(op, advice, inst, data) == []
