"""Golden plan vectors: the compiled plan for each LDBC query text is
rendered canonically (repro.query.render_plan) and compared byte-for-byte
against the committed text under tests/vectors/ — planner drift becomes a
visible diff, mirroring the wire-format vectors in test_vectors.py.

Regenerate after an INTENTIONAL planner/decomposition change:

    PYTHONPATH=src python tests/test_query_vectors.py --write
"""
import sys
from pathlib import Path

import pytest

from repro.query import QUERY_TEXTS, compile_query, render_plan

VECTOR_DIR = Path(__file__).resolve().parent / "vectors"


def _vector_name(qname: str) -> str:
    return f"plan_{qname.lower()}.txt"


def _render(qname: str) -> str:
    return render_plan(compile_query(QUERY_TEXTS[qname], name=qname))


@pytest.mark.parametrize("qname", list(QUERY_TEXTS))
def test_compiled_plan_matches_golden_vector(qname):
    path = VECTOR_DIR / _vector_name(qname)
    assert path.exists(), \
        f"missing golden plan vector {path.name}; regenerate with " \
        f"`PYTHONPATH=src python tests/test_query_vectors.py --write`"
    assert _render(qname) == path.read_text(), \
        f"compiled plan for {qname} drifted from its committed vector"


def test_render_is_deterministic():
    for qname in QUERY_TEXTS:
        assert _render(qname) == _render(qname)


def test_render_covers_every_node_and_result_key():
    for qname in QUERY_TEXTS:
        plan = compile_query(QUERY_TEXTS[qname], name=qname)
        text = _render(qname)
        assert text.startswith(f"plan {qname}\n")
        for i in range(len(plan.nodes)):
            assert f"\n  {i}: " in text
        for key in plan.result:
            assert f"\n  {key}: " in text


if __name__ == "__main__":
    if "--write" not in sys.argv:
        sys.exit("usage: PYTHONPATH=src python "
                 "tests/test_query_vectors.py --write")
    VECTOR_DIR.mkdir(exist_ok=True)
    for qname in QUERY_TEXTS:
        out = VECTOR_DIR / _vector_name(qname)
        out.write_text(_render(qname))
        print(f"wrote {out.name}: {len(out.read_text())} bytes")
