"""Hash permutation sanity, Merkle commit/open/verify, FRI accept/reject."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field as F
from repro.core import fri, hashing, merkle, poly
from repro.core.transcript import Transcript


def test_permute_deterministic_and_bijective_shape():
    x = jnp.arange(32, dtype=jnp.uint32).reshape(2, 16) % F.P
    y1 = hashing.permute(x)
    y2 = hashing.permute(x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert y1.shape == (2, 16)
    # different inputs -> different outputs
    assert not np.array_equal(np.asarray(y1[0]), np.asarray(y1[1]))


def test_hash_rows_collision_resistance_smoke():
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.integers(0, F.P, size=(64, 5)).astype(np.uint32))
    d = np.asarray(hashing.hash_rows(rows))
    assert d.shape == (64, 8)
    assert len({tuple(r) for r in d}) == 64  # no collisions among 64 rows
    # flipping one cell changes the digest
    rows2 = rows.at[3, 2].set((rows[3, 2] + 1) % F.P)
    d2 = np.asarray(hashing.hash_rows(rows2))
    assert not np.array_equal(d[3], d2[3])
    np.testing.assert_array_equal(d[4], d2[4])


@pytest.mark.parametrize("n,width", [(8, 3), (64, 8), (128, 1)])
def test_merkle_roundtrip(n, width):
    rng = np.random.default_rng(n)
    rows = jnp.asarray(rng.integers(0, F.P, size=(n, width)).astype(np.uint32))
    tree = merkle.commit(rows)
    idx = jnp.asarray(rng.integers(0, n, size=5))
    opened, path = merkle.open_at(tree, idx)
    assert bool(merkle.verify_open(tree.root, idx, opened, path))
    # tampered row must fail
    bad = opened.at[0, 0].set((opened[0, 0] + 1) % F.P)
    assert not bool(merkle.verify_open(tree.root, idx, bad, path))
    # wrong index must fail
    bad_idx = idx.at[0].set((idx[0] + 1) % n)
    assert not bool(merkle.verify_open(tree.root, bad_idx, opened, path))


def _random_low_degree_codeword(n, blowup, rng):
    """Fp4 codeword of a degree < n/blowup polynomial on shift*H_n."""
    deg = n // blowup
    coeffs = rng.integers(0, F.P, size=(4, deg)).astype(np.uint32)
    ext_evals = []
    for c in coeffs:  # evaluate each Fp4 coefficient-component separately
        padded = jnp.asarray(np.pad(c, (0, n - deg)))
        ext_evals.append(poly.ntt(F.fmul(padded, jnp.asarray(
            np.array([pow(poly.COSET_SHIFT, i, F.P) for i in range(n)], np.uint32)))))
    return jnp.stack(ext_evals, axis=-1)  # (n, 4)


def test_fri_accepts_low_degree():
    n = 256
    cfg = fri.FriConfig(blowup=4, n_queries=16, final_size=16)
    rng = np.random.default_rng(42)
    cw = _random_low_degree_codeword(n, cfg.blowup, rng)
    proof = fri.fri_prove(cw, Transcript("t"), cfg)
    ok, q, layer0, _ = fri.fri_verify(proof, Transcript("t"), cfg, n)
    assert ok
    # layer-0 openings are the codeword itself at the query points
    lo, hi, idx = layer0
    np.testing.assert_array_equal(lo, np.asarray(cw)[idx])
    np.testing.assert_array_equal(hi, np.asarray(cw)[idx + n // 2])


def test_fri_rejects_high_degree():
    n = 256
    cfg = fri.FriConfig(blowup=4, n_queries=16, final_size=16)
    rng = np.random.default_rng(43)
    cw = jnp.asarray(rng.integers(0, F.P, size=(n, 4)).astype(np.uint32))  # random => high degree
    proof = fri.fri_prove(cw, Transcript("t"), cfg)
    ok, *_ = fri.fri_verify(proof, Transcript("t"), cfg, n)
    assert not ok


def test_fri_rejects_tampered_final_codeword():
    n = 256
    cfg = fri.FriConfig(blowup=4, n_queries=16, final_size=16)
    rng = np.random.default_rng(44)
    cw = _random_low_degree_codeword(n, cfg.blowup, rng)
    proof = fri.fri_prove(cw, Transcript("t"), cfg)
    proof.final_codeword = proof.final_codeword.copy()
    proof.final_codeword[0, 0] = (proof.final_codeword[0, 0] + 1) % F.P
    ok, *_ = fri.fri_verify(proof, Transcript("t"), cfg, n)
    assert not ok


def test_transcript_determinism_and_divergence():
    t1, t2 = Transcript("a"), Transcript("a")
    t1.absorb([1, 2, 3]); t2.absorb([1, 2, 3])
    assert np.array_equal(t1.challenge_ext(), t2.challenge_ext())
    t3 = Transcript("a"); t3.absorb([1, 2, 4])
    assert not np.array_equal(t1.challenge_ext(), t3.challenge_ext())
