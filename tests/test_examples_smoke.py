"""Examples smoke tests: run each example's main() at tiny circuit sizes so
API breakage in examples is caught by tier-1 (the examples are the documented
entry points to the session API)."""
import importlib.util
import os
import sys
from pathlib import Path

import pytest

from repro.core import prover as pv

EXAMPLES = Path(__file__).resolve().parent.parent / "examples"
TINY = pv.ProverConfig(blowup=4, n_queries=4, fri_final_size=16)


def load_example(name):
    spec = importlib.util.spec_from_file_location(name, EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.slow
def test_quickstart_smoke():
    load_example("quickstart").main(n_knows=48, n_persons=16, cfg=TINY)


@pytest.mark.slow
def test_ldbc_ic1_smoke():
    load_example("ldbc_ic1").main(n_knows=48, n_persons=16, cfg=TINY)


@pytest.mark.slow
def test_serve_queries_smoke(tmp_path):
    mod = load_example("serve_queries")
    mod.STATE = str(tmp_path / "serve_state.json")
    # IC13 queue entries draw person2 from [9, 24), so keep >= 24 persons
    mod.main(["--queries", "3"], n_knows=48, n_persons=24, cfg=TINY)
    assert not os.path.exists(mod.STATE)    # completed queue cleans up


@pytest.mark.slow
def test_serve_queries_resume(tmp_path):
    mod = load_example("serve_queries")
    mod.STATE = str(tmp_path / "serve_state.json")
    mod.main(["--queries", "3", "--restart-demo"],
             n_knows=48, n_persons=24, cfg=TINY)
    assert os.path.exists(mod.STATE)        # crashed mid-queue: checkpoint
    mod.main(["--queries", "3"], n_knows=48, n_persons=24, cfg=TINY)
    assert not os.path.exists(mod.STATE)
