"""Examples smoke tests: run each example's main() at tiny circuit sizes so
API breakage in examples is caught by tier-1 (the examples are the documented
entry points to the session API)."""
import importlib.util
import os
import sys
from pathlib import Path

import pytest

from repro.core import prover as pv

EXAMPLES = Path(__file__).resolve().parent.parent / "examples"
TINY = pv.ProverConfig(blowup=4, n_queries=4, fri_final_size=16)


def load_example(name):
    spec = importlib.util.spec_from_file_location(name, EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.slow
def test_quickstart_smoke():
    load_example("quickstart").main(n_knows=48, n_persons=16, cfg=TINY)


@pytest.mark.slow
def test_ldbc_ic1_smoke():
    load_example("ldbc_ic1").main(n_knows=48, n_persons=16, cfg=TINY)


@pytest.mark.slow
def test_query_text_smoke():
    load_example("query_text").main(n_knows=48, n_persons=16, cfg=TINY)


@pytest.mark.slow
def test_serve_queries_demo(tmp_path):
    """The full networked deployment demo: owner and two verifiers as
    separate socket processes (repro.net frames carry every trust byte),
    deterministic frame faults on the verifiers' owner links, owner
    SIGKILL + torn-tail recovery mid-stream, revision advance by
    consistency proof, verifier-to-verifier gossip over TCP, and a forged
    (correctly signed!) fork head alarmed by both peers.  The driver
    asserts all of it internally; here we re-assert the summaries.
    IC13 queue entries draw person2 from [9, 24), so keep >= 24 persons."""
    mod = load_example("serve_queries")
    out = mod.main(["--queries", "3", "--dir", str(tmp_path / "demo")],
                   n_knows=48, n_persons=24, cfg=TINY)
    assert out["owner"]["tree_size"] == 2          # manifest + revision
    for name in ("v1", "v2"):
        assert all(out[name]["results"].values())
        assert out[name]["advanced"] is True       # by consistency proof
        assert out[name]["cross_advance"] is False  # peers already agreed
        assert out[name]["head"] == 2
        assert out[name]["equivocation_detected"] is True
    assert os.path.exists(tmp_path / "demo" / "transparency.log")
