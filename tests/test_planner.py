"""Query-plan tests: every LDBC plan's steps satisfy their circuits, results
match the plain engine, and one full chain round-trips prove+verify."""
import numpy as np
import pytest

from repro.core import prover as pv
from repro.core import planner
from repro.core.operators.common import check_constraints
from repro.graphdb import engine, ldbc

FAST = pv.ProverConfig(blowup=4, n_queries=8, fri_final_size=16)


@pytest.fixture(scope="module")
def db():
    return ldbc.generate(n_knows=96, n_persons=24, n_comments=64, seed=11)


@pytest.mark.parametrize("qname,params", [
    ("IS3", dict(person=3)),
    ("IS4", dict(message=(1 << 20) + 5)),
    ("IS5", dict(message=(1 << 20) + 7)),
    ("IC1", dict(person=2, firstName=None)),   # name filled in test
    ("IC2", dict(person=4, k=10)),
    ("IC8", dict(person=5, k=10)),
    ("IC9", dict(person=6, k=10)),
    ("IC13", dict(person1=1, person2=9)),
])
def test_plan_constraints_hold(db, qname, params):
    if qname == "IC1":
        params = dict(params)
        params["firstName"] = int(db.node_props["person"]["firstName"][0])
    run = planner.plan_query(db, qname, params)
    assert len(run.steps) >= 1
    for st in run.steps:
        bad = check_constraints(st.op, st.advice, st.instance, st.data)
        assert bad == [], f"{qname}/{st.op.name}: {bad}"


def test_is3_result_matches_engine(db):
    run = planner.plan_query(db, "IS3", dict(person=3))
    t = db.tables["person_knows_person"]
    want, *_ = engine.expand_undirected(t, 3)
    assert sorted(run.result["friends"].tolist()) == sorted(want.tolist())
    d = run.result["dates"]
    assert (np.diff(d) <= 0).all()  # descending


def test_ic13_distance_matches_engine(db):
    t = db.tables["person_knows_person"]
    dist, _, _ = engine.bfs_sssp(t, db.node_ids, 1, True)
    idx = int(np.nonzero(db.node_ids == 9)[0][0])
    want = int(dist[idx]) if dist[idx] <= db.n_nodes else -1
    run = planner.plan_query(db, "IC13", dict(person1=1, person2=9))
    assert run.result["distance"] == want


@pytest.mark.slow
def test_full_chain_prove_verify(db):
    run = planner.plan_query(db, "IS5", dict(message=(1 << 20) + 7))
    proofs = planner.prove_query(run, FAST)
    commitments = planner.publish_commitments(db, FAST)
    assert planner.verify_query(run, proofs, commitments, FAST)
    # a proof against a different dataset must be rejected
    db2 = ldbc.generate(n_knows=96, n_persons=24, n_comments=64, seed=99)
    bad_commitments = planner.publish_commitments(db2, FAST)
    assert not planner.verify_query(run, proofs, bad_commitments, FAST)
