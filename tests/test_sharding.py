"""Distribution integration tests: sharding specs are structurally valid and
a reduced config lowers+compiles under an 8-device SPMD mesh (subprocess, so
the 8-device XLA flag never leaks into other tests)."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.launch import sharding as shd
from repro.models import lm


@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_specs_cover_tree(arch):
    cfg = get_config(arch)
    p_struct = jax.eval_shape(lambda: lm.init_params(cfg))
    specs = shd.param_specs(cfg, p_struct)
    flat_p = jax.tree.leaves(p_struct)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, f"{arch}: spec {spec} rank > {leaf.shape}"


@pytest.mark.parametrize("arch", list(ARCHS))
def test_cache_specs_cover_tree(arch):
    cfg = get_config(arch)
    import jax as _jax
    from repro.launch import mesh as mesh_lib
    c_struct = lm.init_cache_shapes(cfg, 128, 256)
    # fake mesh object with .shape mapping (no devices needed for specs)
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    specs = shd.cache_specs(cfg, c_struct, 128, FakeMesh())
    assert len(jax.tree.leaves(c_struct)) == len(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_config
    from repro.launch import sharding as shd
    from repro.models import lm
    from repro.train import optimizer as opt, train_step as ts, compression

    cfg = get_config("internlm2-1.8b").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, params)
    shards = shd.shardings_of(specs, mesh, params)
    params = jax.tree.map(jax.device_put, params,
                          shards, is_leaf=lambda x: hasattr(x, "shape"))
    state = opt.init_state(params)
    err = compression.init_error(params)
    step = ts.make_train_step(cfg, opt.AdamWConfig(lr=1e-3))
    batch = {"tokens": jnp.ones((8, 32), jnp.int32)}
    with mesh:
        jitted = jax.jit(step)
        p2, s2, e2, m = jitted(params, state, err, batch)
        print("LOSS", float(m["loss"]))
    # decode on the same mesh
    serve = jax.jit(ts.make_serve_step(cfg))
    cache = lm.init_cache(cfg, 8, 64)
    with mesh:
        tok, cache = serve(p2, cache, jnp.ones((8, 1), jnp.int32),
                           jax.random.PRNGKey(0))
    print("TOK", tok.shape)
    print("OK")
""")


@pytest.mark.slow
def test_train_and_decode_on_8_device_mesh():
    out = subprocess.run([sys.executable, "-c", _SUBPROC], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert "OK" in out.stdout, out.stdout + out.stderr
    assert "LOSS" in out.stdout
