"""Field axioms + inversion for BabyBear Fp and Fp4 (hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import field as F

fp_elem = st.integers(min_value=0, max_value=F.P - 1)


@given(fp_elem, fp_elem, fp_elem)
@settings(max_examples=50, deadline=None)
def test_fp_ring_axioms(a, b, c):
    A, B, C = (jnp.uint32(x) for x in (a, b, c))
    assert int(F.fadd(A, B)) == (a + b) % F.P
    assert int(F.fsub(A, B)) == (a - b) % F.P
    assert int(F.fmul(A, B)) == (a * b) % F.P
    # distributivity
    lhs = F.fmul(A, F.fadd(B, C))
    rhs = F.fadd(F.fmul(A, B), F.fmul(A, C))
    assert int(lhs) == int(rhs)


@given(fp_elem)
@settings(max_examples=30, deadline=None)
def test_fp_inverse(a):
    if a == 0:
        return
    inv = F.finv(jnp.uint32(a))
    assert int(F.fmul(jnp.uint32(a), inv)) == 1


def test_batch_inverse():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, F.P, size=257).astype(np.uint32))
    a = a.at[13].set(0)
    inv = F.fbatch_inv(a)
    prod = F.fmul(a, inv)
    expect = np.ones(257, np.uint32)
    expect[13] = 0
    np.testing.assert_array_equal(np.asarray(prod), expect)


@given(st.integers(0, 2**32), st.integers(0, 2**32))
@settings(max_examples=30, deadline=None)
def test_ext_mul_matches_poly_mul(seed_a, seed_b):
    rng = np.random.default_rng(seed_a * 2**33 + seed_b)
    a = rng.integers(0, F.P, size=4)
    b = rng.integers(0, F.P, size=4)
    got = np.asarray(F.emul(jnp.asarray(a, jnp.uint32), jnp.asarray(b, jnp.uint32)))
    # schoolbook in python ints, reduce x^4 = W
    full = [0] * 7
    for i in range(4):
        for j in range(4):
            full[i + j] = (full[i + j] + int(a[i]) * int(b[j])) % F.P
    for k in range(6, 3, -1):
        full[k - 4] = (full[k - 4] + full[k] * F.W_EXT) % F.P
    np.testing.assert_array_equal(got, np.asarray(full[:4], np.uint32))


@given(st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_ext_inverse(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, F.P, size=4), jnp.uint32)
    if int(jnp.sum(a)) == 0:
        return
    inv = F.einv(a)
    one = F.emul(a, inv)
    np.testing.assert_array_equal(np.asarray(one), F.EXT_ONE)


def test_ext_batch_inverse():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, F.P, size=(33, 4)), jnp.uint32)
    a = a.at[7].set(0)
    inv = F.ebatch_inv(a)
    prod = F.emul(a, inv)
    expect = np.tile(F.EXT_ONE, (33, 1))
    expect[7] = 0
    np.testing.assert_array_equal(np.asarray(prod), expect)


def test_roots_of_unity():
    for k in [1, 2, 8, 16]:
        w = F.root_of_unity(k)
        assert pow(w, k, F.P) == 1
        if k > 1:
            assert pow(w, k // 2, F.P) != 1


def test_epow_matches_repeated_mul():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, F.P, size=4), jnp.uint32)
    acc = jnp.asarray(F.EXT_ONE)
    for e in range(8):
        np.testing.assert_array_equal(np.asarray(F.epow(a, e)), np.asarray(acc))
        acc = F.emul(acc, a)
