"""Shared tier-1 fixtures.

One session-scoped LDBC instance + one tiny ProverConfig + one proven bundle
are shared across test modules, so the default (fast) tier-1 run pays for db
generation, commitment publication, and an end-to-end IS5 prove exactly once.
Long end-to-end chains are marked ``slow``; the default run excludes them
(``pytest.ini`` addopts) and ``pytest -m ""`` runs everything.
"""
import pytest

from repro.core import prover as pv
from repro.core.session import ZKGraphSession
from repro.graphdb import ldbc


@pytest.fixture(scope="session")
def tiny_cfg():
    """Smallest fast ProverConfig the circuits accept: keygen/FRI in ms."""
    return pv.ProverConfig(blowup=4, n_queries=4, fri_final_size=16)


@pytest.fixture(scope="session")
def db():
    return ldbc.generate(n_knows=96, n_persons=24, n_comments=64, seed=11)


@pytest.fixture(scope="session")
def owner(db, tiny_cfg):
    """Owner-side session; publishing the manifest happens once per run."""
    return ZKGraphSession(db, tiny_cfg)


@pytest.fixture(scope="session")
def bundle(owner):
    """One proven IS5 bundle, shared by serialization/verification tests."""
    return owner.prove("IS5", dict(message=(1 << 20) + 7))


@pytest.fixture(scope="session")
def verifier(owner, tiny_cfg):
    return ZKGraphSession.verifier(owner.commitments, tiny_cfg)
