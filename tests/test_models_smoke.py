"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, shape + finiteness assertions, and decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import lm
from repro.models.config import param_count, active_param_count


def _inputs(cfg, batch=2, seq=16):
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    fe = None
    if cfg.frontend != "none":
        fe = jax.random.normal(key, (batch, cfg.frontend_len, cfg.d_model),
                               jnp.float32) * 0.02
    return tokens, fe


@pytest.mark.parametrize("arch", list(ARCHS))
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens, fe = _inputs(cfg)
    logits = lm.forward(params, cfg, tokens, fe)
    exp_seq = tokens.shape[1] + (cfg.frontend_len if cfg.frontend == "vlm"
                                 else 0)
    assert logits.shape == (2, exp_seq, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", list(ARCHS))
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens, fe = _inputs(cfg)

    def loss_fn(p):
        logits = lm.forward(p, cfg, tokens, fe)
        tgt_len = tokens.shape[1]
        lg = logits[:, -tgt_len:, :]
        onehot = jax.nn.one_hot(tokens, cfg.vocab)
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        return -(onehot * logp).sum(-1).mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch
    # at least some gradient signal everywhere important
    assert float(sum(jnp.abs(g).sum() for g in leaves)) > 0


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "zamba2-1.2b",
                                  "falcon-mamba-7b", "whisper-base",
                                  "mixtral-8x22b", "starcoder2-3b"])
def test_decode_consistency(arch):
    """Greedy decode over cached steps must agree with full forward."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch, seq = 2, 8
    tokens, fe = _inputs(cfg, batch, seq)
    full = lm.forward(params, cfg, tokens, fe)

    cache = lm.init_cache(cfg, batch, 16)
    if cfg.enc_dec:
        cache["memory"] = lm._encoder_forward(params, cfg, fe)
    outs = []
    for t in range(seq):
        logits, cache = lm.decode_step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(logits)
    stepped = jnp.concatenate(outs, axis=1)
    want = full[:, -seq:, :]
    if cfg.frontend == "vlm":
        # decode path skips the patch prefix; compare later positions only,
        # where the sliding window has forgotten the prefix — for the reduced
        # config the windows differ, so just check shape/finite here.
        assert stepped.shape == want.shape
        return
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(want),
                               rtol=4e-2, atol=4e-3)


def test_banded_swa_matches_masked_full():
    """sdpa_banded must equal masked full attention exactly (same math)."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(3)
    B, S, H, hd, W = 2, 32, 4, 16, 8
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, S, H, hd),
                                 jnp.float32) for i in range(3))
    banded = L.sdpa_banded(q, k, v, W)
    full = L.sdpa(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_param_counts_full_configs():
    """Full configs should land near their nominal sizes."""
    approx = {
        "internlm2-1.8b": (1.3e9, 2.6e9),
        "starcoder2-3b": (2.4e9, 4.0e9),
        "starcoder2-15b": (12e9, 18e9),
        "qwen1.5-32b": (26e9, 40e9),
        "mixtral-8x22b": (110e9, 160e9),
        "dbrx-132b": (100e9, 160e9),
        "falcon-mamba-7b": (5e9, 9e9),
        "whisper-base": (6e7, 2.2e8),
    }
    for arch, (lo, hi) in approx.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    # MoE active < total
    for arch in ("mixtral-8x22b", "dbrx-132b"):
        cfg = get_config(arch)
        assert active_param_count(cfg) < param_count(cfg)
