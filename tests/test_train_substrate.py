"""Training substrate: optimizer descends, grad-accum equivalence, int8
compression w/ error feedback, checkpoint save/restore/elastic, deterministic
data resume, straggler/failure policy."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import lm
from repro.train import checkpoint, compression, data, fault
from repro.train import optimizer as opt
from repro.train import train_step as ts


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("internlm2-1.8b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch(cfg, dcfg=None, step=0):
    dc = data.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    stream = data.TokenStream(dc)
    stream.step = step
    return next(stream)


def test_loss_decreases(tiny):
    cfg, params = tiny
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=60)
    step_fn = jax.jit(ts.make_train_step(cfg, ocfg))
    state = opt.init_state(params)
    err = compression.init_error(params)
    dc = data.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)
    stream = data.TokenStream(dc)
    losses = []
    for _ in range(30):
        params, state, err, m = step_fn(params, state, err, next(stream))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_grad_accum_equivalence(tiny):
    cfg, params = tiny
    ocfg = opt.AdamWConfig(lr=1e-3)
    batch = _batch(cfg)
    s1 = jax.jit(ts.make_train_step(cfg, ocfg, grad_accum=1))
    s4 = jax.jit(ts.make_train_step(cfg, ocfg, grad_accum=4))
    st = opt.init_state(params)
    err = compression.init_error(params)
    p1, *_ , m1 = s1(params, st, err, batch)
    p4, *_ , m4 = s4(params, st, err, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    l1, l4 = jax.tree.leaves(p1), jax.tree.leaves(p4)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)


def test_compression_error_feedback():
    """EF compensates quantization: the running sum of compressed grads
    tracks the true sum much better than memoryless quantization."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(64,)) * 0.01, jnp.float32)
              for _ in range(50)]
    err = jnp.zeros((64,), jnp.float32)
    acc_ef = jnp.zeros((64,))
    acc_nq = jnp.zeros((64,))
    for g in g_true:
        (dq,), (err,) = jax.tree.flatten(
            compression.compress((g,), (err,)))[0][0:1], \
            (compression.compress((g,), (err,))[1][0],)
        acc_ef = acc_ef + dq
        acc_nq = acc_nq + g
    true_sum = sum(g_true)
    # EF accumulates to within one quantization step of the truth
    assert float(jnp.max(jnp.abs(acc_ef - true_sum))) < 2e-3


def test_compressed_psum_matches_mean():
    """shard_map int8 psum-with-EF approximates the plain pmean."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs[:1]), ("data",))
    g = jnp.linspace(-1, 1, 32).astype(jnp.float32)
    err = jnp.zeros_like(g)

    def f(g, e):
        out, ne = compression.compressed_psum((g,), (e,), "data")
        return out[0], ne[0]

    fm = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    out, ne = fm(g, err)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=2e-2)


def test_checkpoint_roundtrip_and_elastic(tiny, tmp_path):
    cfg, params = tiny
    state = opt.init_state(params)
    path = str(tmp_path / "ckpt")
    os.makedirs(path, exist_ok=True)
    checkpoint.save(path, 7, params, state, extra={"data_step": 7})
    checkpoint.save(path, 9, params, state, extra={"data_step": 9})
    assert checkpoint.latest_step(path) == 9
    p2, s2, step, extra = checkpoint.restore(path, 9, params, state)
    assert step == 9 and extra["data_step"] == 9
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # elastic: restore with explicit shardings onto the current (1-dev) mesh
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    p3, *_ = checkpoint.restore(path, 9, params, state, shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last(tiny, tmp_path):
    cfg, params = tiny
    state = opt.init_state(params)
    path = str(tmp_path / "ckpt")
    os.makedirs(path, exist_ok=True)
    for s in range(6):
        checkpoint.save(path, s, params, state, keep_last=2)
    kept = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    assert kept == ["step_4", "step_5"]


def test_data_deterministic_resume():
    dc = data.DataConfig(vocab=100, seq_len=16, global_batch=4, seed=5)
    s1 = data.TokenStream(dc)
    batches = [next(s1) for _ in range(5)]
    s2 = data.TokenStream(dc)
    s2.load_state_dict({"step": 3})
    np.testing.assert_array_equal(np.asarray(next(s2)["tokens"]),
                                  np.asarray(batches[3]["tokens"]))


def test_fault_controller_detects_dead_and_stragglers():
    t = [0.0]
    clock = lambda: t[0]
    fc = fault.FaultController(
        ["n0", "n1", "n2", "n3"],
        fault.FaultConfig(heartbeat_interval_s=1.0, dead_after=3,
                          straggle_factor=1.5, straggle_strikes=2),
        clock=clock)
    # normal beats
    for step in range(3):
        t[0] += 1.0
        for n in ["n0", "n1", "n2"]:
            fc.heartbeat(n, 1.0)
        fc.heartbeat("n3", 1.0 if step == 0 else 2.5)  # n3 straggles
        out = fc.sweep()
    assert "n3" in out["evict"] or any("n3" in e["evict"] for e in fc.events)
    # n1 stops beating entirely
    for _ in range(4):
        t[0] += 1.0
        for n in ["n0", "n2"]:
            fc.heartbeat(n, 1.0)
        out = fc.sweep()
    assert "n1" not in fc.surviving()
    assert fc.surviving() == ["n0", "n2"]
    assert fault.elastic_mesh_shape(len(fc.surviving()) * 8, 8) == (2, 8)
