"""Plan-IR tests: every LDBC query builds a well-formed plan, the generic
executor's results match the untrusted engine, and tampering with a chained
intermediate table is rejected end-to-end."""
import numpy as np
import pytest

from repro.core import ir
from repro.core.operators import registry
from repro.core.operators.common import check_constraints
from repro.core.session import ProofBundle, ZKGraphSession
from repro.graphdb import engine, ldbc
from repro.graphdb.tables import COMMENT_ID_BASE


def qparams(db, qname):
    return {
        "IS3": dict(person=3), "IS4": dict(message=(1 << 20) + 5),
        "IS5": dict(message=(1 << 20) + 7),
        "IC1": dict(person=2, firstName=int(
            db.node_props["person"]["firstName"][0])),
        "IC2": dict(person=4, k=10), "IC8": dict(person=5, k=10),
        "IC9": dict(person=6, k=10), "IC13": dict(person1=1, person2=9),
    }[qname]


# ---------------------------------------------------------------------------
# plan structure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qname", ir.QUERIES)
def test_every_query_builds_a_plan(qname):
    plan = ir.build_plan(qname)
    assert plan.name == qname
    assert len(plan.nodes) >= 1
    assert plan.result
    for node in plan.nodes:
        registry.adapter_for(node)      # every node kind has an adapter
    # result bindings only reference nodes that exist
    for b in plan.result.values():
        for out in _outs_of(b):
            assert 0 <= out.step < len(plan.nodes)


def _outs_of(b):
    if isinstance(b, ir.Out):
        yield b
    elif isinstance(b, ir.App):
        for a in b.args:
            yield from _outs_of(a)


def test_unknown_query_rejected():
    with pytest.raises(KeyError):
        ir.build_plan("IC999")


def test_plans_are_pure():
    a, b = ir.build_plan("IC1"), ir.build_plan("IC1")
    assert a == b


# ---------------------------------------------------------------------------
# executor vs the untrusted engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qname", ir.QUERIES)
def test_executor_witnesses_satisfy_circuits(db, qname):
    run = ir.execute(db, ir.build_plan(qname), qparams(db, qname))
    assert len(run.steps) == len(ir.build_plan(qname).nodes)
    for st in run.steps:
        bad = check_constraints(st.op, st.advice, st.instance, st.data)
        assert bad == [], f"{qname}/{st.op.name}: {bad}"


def run_query(db, qname):
    return ir.execute(db, ir.build_plan(qname), qparams(db, qname))


def test_is3_matches_engine(db):
    run = run_query(db, "IS3")
    t = db.tables["person_knows_person"]
    want, *_ = engine.expand_undirected(t, 3)
    assert sorted(run.result["friends"].tolist()) == sorted(want.tolist())
    assert (np.diff(run.result["dates"]) <= 0).all()


def test_is4_matches_node_props(db):
    run = run_query(db, "IS4")
    mid = qparams(db, "IS4")["message"] - COMMENT_ID_BASE
    cp = db.node_props["comment"]
    assert run.result["content"].tolist() == [int(cp["content"][mid])]
    assert run.result["date"].tolist() == [int(cp["creationDate"][mid])]


def test_is5_matches_engine(db):
    run = run_query(db, "IS5")
    want, _ = engine.expand(db.tables["comment_hasCreator_person"],
                            qparams(db, "IS5")["message"])
    assert sorted(run.result["creator"].tolist()) == sorted(want.tolist())


def test_ic13_matches_engine(db):
    t = db.tables["person_knows_person"]
    dist, _, _ = engine.bfs_sssp(t, db.node_ids, 1, True)
    idx = int(np.nonzero(db.node_ids == 9)[0][0])
    want = int(dist[idx]) if dist[idx] <= db.n_nodes else -1
    assert run_query(db, "IC13").result["distance"] == want


def test_ic1_semantics(db):
    p = 2
    name = int(db.node_props["person"]["firstName"][0])
    run = run_query(db, "IC1")
    persons = set(run.result["persons"].tolist())
    first = db.node_props["person"]["firstName"]
    idx_of = {int(v): i for i, v in enumerate(db.node_ids.tolist())}
    dist, _, _ = engine.bfs_sssp(db.tables["person_knows_person"],
                                 db.node_ids, p, True)
    for x in persons:
        assert int(first[idx_of[x]]) == name
        assert dist[idx_of[x]] <= 3
    # completeness: every correctly-named person within 1..3 hops is returned
    for x in db.node_ids.tolist():
        if int(first[idx_of[x]]) == name and 1 <= dist[idx_of[x]] <= 3:
            assert x in persons


def test_ic2_semantics(db):
    p = 4
    run = run_query(db, "IC2")
    t = db.tables["person_knows_person"]
    friends = set(np.asarray(engine.expand_undirected(t, p)[0]).tolist())
    hc = db.tables["comment_hasCreator_person"]
    creator_of = {int(s): int(d) for s, d in zip(hc.src, hc.dst)}
    assert (np.diff(run.result["dates"]) <= 0).all()
    for m in run.result["messages"].tolist():
        assert creator_of[m] in friends


def test_ic8_semantics(db):
    p = 5
    run = run_query(db, "IC8")
    hc = db.tables["comment_hasCreator_person"]
    mine = set(hc.src[hc.dst == p].tolist())
    ro = db.tables["comment_replyOf_comment"]
    parent_of = {int(s): int(d) for s, d in zip(ro.src, ro.dst)}
    assert (np.diff(run.result["dates"]) <= 0).all()
    for r in run.result["replies"].tolist():
        assert parent_of[r] in mine


def test_ic9_semantics(db):
    p = 6
    run = run_query(db, "IC9")
    t = db.tables["person_knows_person"]
    f1 = np.unique(engine.expand_undirected(t, p)[0])
    fof = np.concatenate([t.dst[np.isin(t.src, f1)],
                          t.src[np.isin(t.dst, f1)]])   # undirected 2nd hop
    hc = db.tables["comment_hasCreator_person"]
    creator_of = {int(s): int(d) for s, d in zip(hc.src, hc.dst)}
    allowed = set(np.concatenate([f1, fof]).tolist()) - {p}
    for m in run.result["messages"].tolist():
        assert creator_of[m] in allowed


def test_ic1_isolated_person_returns_no_real_person():
    """An isolated person has an empty 3-hop candidate set; the empty-set
    sentinel must expand to nothing (the seed's fallback to node_ids[0]
    could leak a real, unrelated person into the result), and the witness
    must still satisfy the circuits."""
    db2 = ldbc.generate(n_knows=8, n_persons=20, n_comments=8, seed=3)
    t = db2.tables["person_knows_person"]
    linked = set(t.src.tolist()) | set(t.dst.tolist())
    isolated = [int(x) for x in db2.node_ids.tolist() if x not in linked]
    assert isolated, "expected an isolated person in this tiny graph"
    name = int(db2.node_props["person"]["firstName"][0])
    run = ir.execute(db2, ir.build_plan("IC1"),
                     dict(person=isolated[0], firstName=name))
    for st in run.steps:
        bad = check_constraints(st.op, st.advice, st.instance, st.data)
        assert bad == [], f"{st.op.name}: {bad}"
    # only the order-by padding row (id 0) may appear, never a real person
    assert set(run.result["persons"].tolist()) <= {0}


# ---------------------------------------------------------------------------
# chained intermediates are bound end-to-end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def proven_is3(owner, tiny_cfg):
    bundle = owner.prove("IS3", dict(person=3))
    verifier = ZKGraphSession.verifier(owner.commitments, tiny_cfg)
    assert verifier.verify(bundle)
    return bundle, verifier


def _tamper(bundle, step, col, value):
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    rec = clone.steps[step]
    op = registry.build_operator(rec.kind, rec.shape)
    sel = np.nonzero(rec.instance[op.handles["out_sel"].index] == 1)[0]
    row = int(sel[0]) if len(sel) else 0
    rec.instance[op.handles[col].index, row] = value
    return clone


def test_tampered_chained_table_rejected(proven_is3):
    """IS3's order-by step is chained: its table is the expand outputs. A
    prover who alters the upstream public output must be rejected, because
    the verifier re-derives the chained data root itself."""
    bundle, verifier = proven_is3
    assert not verifier.verify(_tamper(bundle, 0, "C_t", 999))


def test_tampered_final_output_rejected(proven_is3):
    bundle, verifier = proven_is3
    assert not verifier.verify(_tamper(bundle, 2, "O_pay", 999))


def test_tampered_claimed_result_rejected(proven_is3):
    bundle, verifier = proven_is3
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.result["friends"] = np.asarray(
        clone.result["friends"], np.int64).copy()
    if len(clone.result["friends"]):
        clone.result["friends"][0] = 999
    else:
        clone.result["friends"] = np.asarray([999], np.int64)
    assert not verifier.verify(clone)
