"""Adversarial wire-format tests: the canonical ProofBundle codec must treat
every byte as hostile — truncations, flipped tags, oversized length prefixes,
wrong dtypes, legacy pickle, version skew — and the verifier must reject a
re-encoded bundle whose base-table geometry disagrees with the published
manifest (the soundness gap this codec + manifest close)."""
import pickle
import struct

import numpy as np
import pytest

from repro.core import wire
from repro.core.session import ProofBundle, WireFormatError

HEADER = len(wire.MAGIC) + 2 + 1     # magic + u16 version + u8 payload kind


@pytest.fixture(scope="module")
def raw(bundle):
    return bundle.to_bytes()


# ---------------------------------------------------------------------------
# canonical round trip
# ---------------------------------------------------------------------------
def test_roundtrip_byte_identical(raw):
    """One canonical encoding per bundle: decode+re-encode is the identity."""
    rt = ProofBundle.from_bytes(raw)
    assert rt.to_bytes() == raw


def test_roundtrip_preserves_every_field(bundle, raw):
    rt = ProofBundle.from_bytes(raw)
    assert rt.query == bundle.query
    assert rt.params == bundle.params
    assert rt.cfg == bundle.cfg
    assert len(rt.steps) == len(bundle.steps)
    for a, b in zip(rt.steps, bundle.steps):
        assert a.kind == b.kind and a.shape == b.shape
        assert a.data_desc == b.data_desc
        assert np.array_equal(a.instance, b.instance)
        assert a.instance.dtype == np.uint32
        assert sorted(a.proof.openings) == sorted(b.proof.openings)
        assert a.proof.size_fields() == b.proof.size_fields()
    assert set(rt.result) == set(bundle.result)


def test_proof_and_fri_standalone_roundtrip(bundle):
    proof = bundle.steps[0].proof
    from repro.core.prover import Proof
    from repro.core.fri import FriProof
    p2 = Proof.from_bytes(proof.to_bytes())
    assert p2.to_bytes() == proof.to_bytes()
    assert np.array_equal(p2.data_root, proof.data_root)
    f2 = FriProof.from_bytes(proof.fri_proof.to_bytes())
    assert f2.to_bytes() == proof.fri_proof.to_bytes()
    assert np.array_equal(f2.query_indices, proof.fri_proof.query_indices)


def test_decoded_arrays_are_writable(raw):
    rt = ProofBundle.from_bytes(raw)
    rt.steps[0].instance[0, 0] = 7      # tamper tests rely on this


# ---------------------------------------------------------------------------
# malformed input: every deviation is a typed error, never a crash/exec
# ---------------------------------------------------------------------------
def test_truncation_rejected(raw):
    for cut in (0, 1, HEADER - 1, HEADER, HEADER + 3, len(raw) // 2,
                len(raw) - 1):
        with pytest.raises(WireFormatError):
            ProofBundle.from_bytes(raw[:cut])


def test_trailing_bytes_rejected(raw):
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(raw + b"\x00")


def test_legacy_pickle_rejected(bundle):
    blob = pickle.dumps(bundle, protocol=pickle.HIGHEST_PROTOCOL)
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(blob)


def test_bad_magic_rejected(raw):
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(b"NOPE" + raw[4:])


def test_version_mismatch_rejected_and_verify_bytes_false(raw, verifier):
    future = raw[:4] + struct.pack("<H", wire.WIRE_VERSION + 1) + raw[6:]
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(future)
    # the serving path fails closed, it does not crash
    assert verifier.verify_bytes(future) is False
    assert verifier.verify_bytes(b"junk") is False
    assert verifier.verify_bytes(raw) is True


def test_payload_kind_confusion_rejected(bundle, raw):
    proof_bytes = bundle.steps[0].proof.to_bytes()
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(proof_bytes)       # a Proof is not a bundle
    from repro.core.prover import Proof
    with pytest.raises(WireFormatError):
        Proof.from_bytes(raw)                     # and vice versa


def test_flipped_field_tag_rejected(raw):
    flipped = bytearray(raw)
    flipped[HEADER] ^= 0xFF                       # first field tag (query)
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(bytes(flipped))


def test_oversized_length_prefix_rejected(raw):
    # the query-string length prefix sits right after its field tag
    huge = raw[: HEADER + 1] + struct.pack("<I", 0xFFFFFFFF) + \
        raw[HEADER + 5:]
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(huge)
    # a plausible-but-too-long length must hit the bound, not allocate
    biggish = raw[: HEADER + 1] + struct.pack("<I", wire.MAX_STR + 1) + \
        raw[HEADER + 5:]
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(biggish)


def test_wrong_dtype_array_rejected(bundle):
    fri_bytes = bytearray(bundle.steps[0].proof.fri_proof.to_bytes())
    # layout: header, tag(_F_FRI_ROOTS), u32 count, then dtype code byte
    dtype_off = HEADER + 1 + 4
    fri_bytes[dtype_off] = 1                      # int64 where u32 expected
    from repro.core.fri import FriProof
    with pytest.raises(WireFormatError):
        FriProof.from_bytes(bytes(fri_bytes))
    fri_bytes[dtype_off] = 99                     # unknown dtype code
    with pytest.raises(WireFormatError):
        FriProof.from_bytes(bytes(fri_bytes))


def test_unknown_step_kind_rejected(bundle):
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.steps[0].kind = "evil_operator"
    with pytest.raises(WireFormatError):
        clone.to_bytes()                          # encode validates too
    raw = bundle.to_bytes()
    patched = raw.replace(b"expand", b"expanq")
    with pytest.raises(WireFormatError):
        ProofBundle.from_bytes(patched)


def test_shape_schema_checked(bundle):
    with pytest.raises(WireFormatError):
        wire.check_shape_schema("expand", dict(n_rows=64))     # missing keys
    with pytest.raises(WireFormatError):
        wire.check_shape_schema("expand", dict(
            n_rows=64, m_edges=48, with_prop=False, reverse=False, evil=1))
    with pytest.raises(WireFormatError):
        wire.check_shape_schema("expand", dict(                # bool != int
            n_rows=True, m_edges=48, with_prop=False, reverse=False))
    with pytest.raises(WireFormatError):
        wire.check_shape_schema("expand", dict(                # int != bool
            n_rows=64, m_edges=48, with_prop=0, reverse=False))
    with pytest.raises(WireFormatError):
        wire.check_shape_schema("no_such_kind", dict(n_rows=64))


def test_unknown_query_name_fails_closed(raw, verifier):
    b = ProofBundle.from_bytes(raw)
    b.query = "IC999"
    assert verifier.verify(b) is False


def test_deep_nesting_rejected_not_recursion_error(bundle, verifier):
    """A ~2.5KB payload of nested single-element lists must hit the depth
    cap as WireFormatError — a RecursionError would crash verify_bytes
    instead of failing closed."""
    deep = bytearray()
    for _ in range(500):
        deep.append(wire._T_LIST)
        deep += struct.pack("<I", 1)
    deep.append(wire._T_INT)
    deep += struct.pack("<q", 0)
    with pytest.raises(WireFormatError, match="nesting"):
        wire._Dec(bytes(deep)).value()
    # the encoder refuses to produce such bytes in the first place
    nested = 0
    for _ in range(500):
        nested = [nested]
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.params = dict(evil=nested)
    with pytest.raises(WireFormatError, match="nesting"):
        clone.to_bytes()


def test_non_canonical_dict_rejected():
    e = wire._Enc()
    e.u8(wire._T_DICT)
    e.u32(2)
    for key in ("b", "a"):                        # out of sorted order
        e.u8(wire._T_STR)
        e.string(key)
        e.u8(wire._T_INT)
        e.i64(1)
    with pytest.raises(WireFormatError):
        wire._Dec(bytes(e.buf)).value()


def test_byte_flips_never_crash(raw, verifier):
    """Flipping any byte either raises WireFormatError or yields a bundle
    the verifier handles without crashing — malformed bundles are *invalid
    proofs*, not exceptions. A few surviving decodes are pushed through
    verify to prove the no-crash property end to end."""
    rng = np.random.default_rng(7)
    checked = 0
    for pos in rng.integers(0, len(raw), size=24):
        flipped = bytearray(raw)
        flipped[pos] ^= 0x40
        try:
            b = ProofBundle.from_bytes(bytes(flipped))
        except WireFormatError:
            continue
        if checked < 3:
            # a flip that survives decode landed in payload data (arrays,
            # floats): verify must return a clean bool, never raise
            assert verifier.verify(b) in (True, False)
            checked += 1


# ---------------------------------------------------------------------------
# the closed geometry gap, end to end through the wire
# ---------------------------------------------------------------------------
def test_reencoded_tampered_n_rows_fails_via_manifest(bundle, owner,
                                                      verifier):
    """Acceptance: a bundle re-encoded with a tampered base-table n_rows —
    at a size the owner even published a root for — must now fail via the
    manifest geometry pin (the shape is schema-valid, so only the published
    geometry can catch it)."""
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    rec = clone.steps[0]
    assert rec.data_desc == "hasCreator"
    bigger = rec.shape["n_rows"] * 2
    assert ("hasCreator", bigger) in owner.commitments
    rec.shape = dict(rec.shape, n_rows=bigger)
    rewired = ProofBundle.from_bytes(clone.to_bytes())   # survives the codec
    assert rewired.steps[0].shape["n_rows"] == bigger
    assert verifier.verify(rewired) is False             # dies at the pin


def test_reencoded_tampered_m_edges_fails_via_manifest(bundle, verifier):
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    rec = clone.steps[0]
    rec.shape = dict(rec.shape, m_edges=rec.shape["m_edges"] - 1)
    rewired = ProofBundle.from_bytes(clone.to_bytes())
    assert verifier.verify(rewired) is False


def test_no_pickle_in_session_module():
    """The trust boundary ships no pickle: neither the session module nor
    the codec imports it."""
    import repro.core.session as session_mod
    import repro.core.wire as wire_mod
    import inspect
    for mod in (session_mod, wire_mod):
        assert not hasattr(mod, "pickle")
        assert "import pickle" not in inspect.getsource(mod)
