"""Pallas kernels vs pure-jnp oracles (interpret mode): shape sweeps, edge
values, and the uint32 16-bit-limb mulmod path vs the uint64 oracle.

hypothesis is optional: only the property-based test skips without it —
the rest of the kernel suite must run everywhere (CI runs this module
under ``ZKGRAPH_BACKEND=pallas-interpret`` to catch kernel drift)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import field as F
from repro.core import hashing, poly
from repro.kernels.fieldops import ops as fops
from repro.kernels.fieldops import ref as fref
from repro.kernels.fieldops.fieldops import mulmod_limb
from repro.kernels.ntt import ops as ntt_ops
from repro.kernels.ntt import ref as ntt_ref
from repro.kernels.poseidon import ops as pos_ops
from repro.kernels.poseidon import ref as pos_ref


# ---------------------------------------------------------------------------
# fieldops: limb mulmod
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [8, 256, 4096])
def test_mulmod_kernel_matches_oracle(n):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.integers(0, F.P, size=n).astype(np.uint32))
    b = jnp.asarray(rng.integers(0, F.P, size=n).astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(fops.mulmod(a, b)),
                                  np.asarray(fref.mulmod_ref(a, b)))


def test_mulmod_edge_values():
    edge = np.asarray([0, 1, 2, 3, F.P - 1, F.P - 2, (1 << 16) - 1, 1 << 16,
                       (1 << 16) + 1, (1 << 27), (1 << 27) - 1, F.P // 2,
                       (1 << 30), 1234567, F.P - (1 << 16)], np.uint64)
    a, b = np.meshgrid(edge, edge)
    a, b = a.ravel(), b.ravel()
    # pad to kernel block multiple
    pad = (-len(a)) % 8
    a = np.concatenate([a, np.zeros(pad, np.uint64)])
    b = np.concatenate([b, np.zeros(pad, np.uint64)])
    got = np.asarray(fops.mulmod(jnp.asarray(a.astype(np.uint32)),
                                 jnp.asarray(b.astype(np.uint32))))
    want = ((a * b) % F.P).astype(np.uint32)
    np.testing.assert_array_equal(got, want)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, F.P - 1), st.integers(0, F.P - 1))
    @settings(max_examples=50, deadline=None)
    def test_mulmod_limb_property(a, b):
        got = int(mulmod_limb(jnp.full((8,), a, jnp.uint32),
                              jnp.full((8,), b, jnp.uint32))[0])
        assert got == (a * b) % F.P


@pytest.mark.parametrize("shape", [(64,), (8, 32), (4, 4, 16)])
def test_fused_mul_add(shape):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, F.P, size=shape).astype(np.uint32))
    b = jnp.asarray(rng.integers(0, F.P, size=shape).astype(np.uint32))
    c = jnp.asarray(rng.integers(0, F.P, size=shape).astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(fops.fused_mul_add(a, b, c)),
                                  np.asarray(fref.fused_mul_add_ref(a, b, c)))


# ---------------------------------------------------------------------------
# NTT kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 16, 64, 512])
@pytest.mark.parametrize("batch", [1, 4])
@pytest.mark.parametrize("inverse", [False, True])
def test_ntt_kernel_matches_oracle(n, batch, inverse):
    rng = np.random.default_rng(n + batch)
    x = jnp.asarray(rng.integers(0, F.P, size=(batch, n)).astype(np.uint32))
    got = np.asarray(ntt_ops.ntt(x, inverse=inverse))
    want = np.asarray(ntt_ref.ntt_ref(x, inverse=inverse))
    np.testing.assert_array_equal(got, want)


def test_ntt_kernel_roundtrip():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, F.P, size=(2, 128)).astype(np.uint32))
    back = ntt_ops.ntt(ntt_ops.ntt(x), inverse=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# ---------------------------------------------------------------------------
# Poseidon kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 8, 64, 128])
def test_poseidon_kernel_matches_oracle(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.integers(0, F.P, size=(n, 16)).astype(np.uint32))
    got = np.asarray(pos_ops.permute(x))
    want = np.asarray(pos_ref.permute_ref(x))
    np.testing.assert_array_equal(got, want)


def test_grand_product_kernel_matches_oracle():
    from repro.kernels.grand_product import ops as gp_ops
    from repro.kernels.grand_product import ref as gp_ref
    rng = np.random.default_rng(0)
    for n in (8, 256, 1024):
        x = jnp.asarray(rng.integers(1, F.P, size=n).astype(np.uint32))
        got = np.asarray(gp_ops.grand_product(x))
        want = np.asarray(gp_ref.grand_product_ref(x))
        np.testing.assert_array_equal(got, want)
    # paper Eq. (2): a true permutation ratio telescopes back to 1
    vals = rng.integers(1, F.P, size=255).astype(np.uint64)
    one = np.ones(1, np.uint64)
    num = np.concatenate([vals, one])
    den = np.concatenate([one, vals])
    inv_den = np.asarray([pow(int(d), F.P - 2, F.P) for d in den], np.uint64)
    ratios = (num * inv_den % F.P).astype(np.uint32)
    z = np.asarray(gp_ops.grand_product(jnp.asarray(ratios)))
    total = int(z[-1]) * int(ratios[-1]) % F.P
    assert total == 1


def test_grand_product_ext_kernel_matches_oracle():
    from repro.kernels.grand_product import ops as gp_ops
    from repro.kernels.grand_product import ref as gp_ref
    rng = np.random.default_rng(7)
    for n in (8, 256, 512):
        x = jnp.asarray(rng.integers(0, F.P, size=(n, 4)).astype(np.uint32))
        got = np.asarray(gp_ops.grand_product_ext(x))
        want = np.asarray(gp_ref.grand_product_ext_ref(x))
        np.testing.assert_array_equal(got, want)
    # telescoping sanity: ratios of a cyclic shift multiply back to one
    vals = jnp.asarray(rng.integers(1, F.P, size=(64, 4)).astype(np.uint32))
    num = jnp.concatenate([vals[1:], vals[:1]], axis=0)
    inv = F.ebatch_inv(vals)
    ratios = F.emul(num, inv)
    z = np.asarray(gp_ops.grand_product_ext(ratios))
    total = F.emul(jnp.asarray(z[-1]), ratios[-1])
    assert np.asarray(total).tolist() == [1, 0, 0, 0]


def test_poseidon_kernel_zero_state():
    x = jnp.zeros((8, 16), jnp.uint32)
    got = np.asarray(pos_ops.permute(x))
    want = np.asarray(pos_ref.permute_ref(x))
    np.testing.assert_array_equal(got, want)
    assert not np.array_equal(got[0], np.zeros(16))  # permutation moves zero
