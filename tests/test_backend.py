"""Compute-backend subsystem (repro.core.backend): registry/selection
semantics, padding-edge parity for every dispatched primitive (row counts
1, tile-1, tile+1 — the adapters in each kernel's ops.py), keygen-cache
isolation, and the Fiat–Shamir-critical guarantee: a full
ZKGraphSession.prove round trip emits bit-identical proof bytes on every
backend (timings — a wall-clock diagnostic the wire format carries — are
normalized before comparison; all semantic fields must match exactly).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import backend
from repro.core import commit, field as F, hashing, merkle, poly
from repro.core import prover as pv
from repro.core.operators import registry
from repro.core.session import KeygenCache, ZKGraphSession

PARITY = ("ref", "pallas-interpret")


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, F.P, size=shape).astype(np.uint32))


# ---------------------------------------------------------------------------
# registry + selection
# ---------------------------------------------------------------------------
def test_registry_has_all_backends():
    assert set(PARITY) | {"pallas"} <= set(backend.names())
    for name in backend.names():
        be = backend.get(name)
        assert be.name == name and callable(be.permute)


def test_unknown_backend_fails_loudly():
    with pytest.raises(backend.UnknownBackendError, match="available"):
        backend.get("cuda")
    with pytest.raises(backend.UnknownBackendError):
        with backend.use("not-a-backend"):
            pass


def test_env_var_selection(monkeypatch):
    monkeypatch.setenv(backend.ENV_VAR, "pallas-interpret")
    assert backend.active_name() == "pallas-interpret"
    monkeypatch.setenv(backend.ENV_VAR, "bogus")
    with pytest.raises(backend.UnknownBackendError):
        backend.active_name()
    monkeypatch.delenv(backend.ENV_VAR)
    assert backend.active_name() == backend.DEFAULT


def test_use_nests_and_restores(monkeypatch):
    monkeypatch.delenv(backend.ENV_VAR, raising=False)
    with backend.use("pallas-interpret") as outer:
        assert outer.name == backend.active_name() == "pallas-interpret"
        with backend.use("ref"):
            assert backend.active_name() == "ref"
            # use(None) pins whatever is active at entry
            with backend.use(None):
                assert backend.active_name() == "ref"
        assert backend.active_name() == "pallas-interpret"
    assert backend.active_name() == backend.DEFAULT


def test_probe_reports_cleanly():
    ok, reason = backend.probe("pallas-interpret")
    assert ok, reason
    # the compiled backend needs an accelerator; on CPU hosts the probe
    # must answer False with a reason, never raise
    import jax
    ok, reason = backend.probe("pallas")
    if jax.default_backend() == "cpu":
        assert not ok and reason


# ---------------------------------------------------------------------------
# per-primitive parity at padding edges (tile-1 / tile / tile+1 / 1)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 63, 64, 65, 130])
def test_permute_parity(n):
    x = _rand((n, 16), seed=n)
    want = np.asarray(hashing.permute_ref(x))
    with backend.use("pallas-interpret"):
        got = np.asarray(hashing.permute(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", [(1, 64), (7, 32), (9, 128), (2, 3, 16)])
@pytest.mark.parametrize("inverse", [False, True])
def test_ntt_parity(shape, inverse):
    x = _rand(shape, seed=sum(shape))
    want = np.asarray(poly.ntt_ref(x, inverse=inverse))
    with backend.use("pallas-interpret"):
        got = np.asarray(poly.ntt(x, inverse=inverse))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [1, 255, 256, 257])
def test_grand_product_ext_parity(n):
    from repro.kernels.grand_product.ref import grand_product_ext_ref
    x = _rand((n, 4), seed=n)
    want = np.asarray(grand_product_ext_ref(x))
    with backend.use("pallas-interpret"):
        got = np.asarray(backend.active().grand_product_ext(x))
    np.testing.assert_array_equal(got, want)
    assert got[0].tolist() == [1, 0, 0, 0]          # exclusive: Z[0] = 1


def test_hash_bytes_and_merkle_parity():
    data = b"zkgraph backend parity \x00\x01\x02"
    rows = _rand((32, 5), seed=3)
    want_digest = hashing.hash_bytes(data)
    want_root = np.asarray(merkle.commit(rows).root)
    with backend.use("pallas-interpret"):
        got_digest = hashing.hash_bytes(data)
        got_root = np.asarray(merkle.commit(rows).root)
    np.testing.assert_array_equal(got_digest, want_digest)
    np.testing.assert_array_equal(got_root, want_root)


def test_data_root_parity(tiny_cfg):
    import dataclasses
    cols = np.asarray(np.arange(3 * 20).reshape(3, 20), np.uint32)
    cfg_r = dataclasses.replace(tiny_cfg, backend="ref")
    cfg_k = dataclasses.replace(tiny_cfg, backend="pallas-interpret")
    want = commit.data_root(cols, 32, cfg_r, desc="parity")
    got = commit.data_root(cols, 32, cfg_k, desc="parity")
    np.testing.assert_array_equal(got, want)
    # cfg equality ignores the backend field: it is execution policy, not a
    # proof parameter (the verifier would otherwise reject the bundle)
    assert cfg_k == cfg_r == tiny_cfg


# ---------------------------------------------------------------------------
# keygen cache isolation + cfg routing
# ---------------------------------------------------------------------------
def _tiny_op():
    return registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))


def test_keygen_cache_never_crosses_backends(tiny_cfg):
    # explicit backends on both sides: the test must hold under ANY ambient
    # selection (CI runs the whole suite with ZKGRAPH_BACKEND set)
    import dataclasses
    cfg_ref = dataclasses.replace(tiny_cfg, backend="ref")
    cfg_pal = dataclasses.replace(tiny_cfg, backend="pallas-interpret")
    cache = KeygenCache()
    cache.ensure(_tiny_op(), cfg_ref)
    cache.ensure(_tiny_op(), cfg_pal)
    assert cache.stats() == dict(hits=0, misses=2, waits=0, entries=2)
    # same backend again: a hit, not a third keygen
    cache.ensure(_tiny_op(), cfg_ref)
    assert cache.stats()["hits"] == 1


def test_keygen_records_resolved_backend(tiny_cfg):
    import dataclasses
    keys = pv.keygen(_tiny_op().circuit, tiny_cfg)
    assert keys.backend == backend.active_name()    # None = ambient
    cfg_k = dataclasses.replace(tiny_cfg, backend="pallas-interpret")
    keys = pv.keygen(_tiny_op().circuit, cfg_k)
    assert keys.backend == "pallas-interpret"
    cfg_r = dataclasses.replace(tiny_cfg, backend="ref")
    np.testing.assert_array_equal(
        np.asarray(keys.fixed_lde),
        np.asarray(pv.keygen(_tiny_op().circuit, cfg_r).fixed_lde))


# ---------------------------------------------------------------------------
# the parity guarantee: full prove/verify round trip, byte-identical
# ---------------------------------------------------------------------------
def _canonical_bytes(bundle):
    """Wire bytes with the wall-clock timings diagnostic normalized out —
    every *semantic* field (roots, openings, FRI layers, tree openings,
    result, manifest digest) must already be bit-identical."""
    for step in bundle.steps:
        step.proof.timings = {}
    return bundle.to_bytes()


def test_proof_bytes_identical_across_backends(db, owner, tiny_cfg):
    raws = {}
    for name in PARITY:
        with backend.use(name):
            session = ZKGraphSession(db, tiny_cfg,
                                     commitments=owner.commitments)
            bundle = session.prove("IS5", dict(message=(1 << 20) + 7))
        raws[name] = _canonical_bytes(bundle)
    assert raws["ref"] == raws["pallas-interpret"], \
        "backends diverged: Fiat–Shamir transcripts are not bit-identical"
    # cross-verification: a bundle proven on one backend verifies on the
    # other (the verifier re-derives chained roots with ITS backend)
    verifier = ZKGraphSession.verifier(owner.commitments, tiny_cfg)
    for prover_name, raw in raws.items():
        other = [n for n in PARITY if n != prover_name][0]
        with backend.use(other):
            assert verifier.verify_bytes(raw), \
                f"bundle proven under {prover_name} rejected under {other}"
