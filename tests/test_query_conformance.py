"""Differential conformance: compiled plans prove to the SAME wire bytes.

For every LDBC query text, the bundle produced by proving the compiled plan
must be byte-identical (after zeroing the nondeterministic timing metadata)
to the bundle produced by the hand-written plan function — same circuits,
same shapes, same instances, same transcript, same proof bytes — and must
verify.  The suite runs under whatever ``ZKGRAPH_BACKEND`` selects; CI's
``query`` job runs it under both ``ref`` and ``pallas-interpret``.

The four cheap queries run in tier-1; the four long chains are ``slow``
(nightly / CI query job, which runs with ``-m ""``).
"""
import numpy as np
import pytest

from repro.core import ir
from repro.query import QUERY_TEXTS, QueryError, compile_query

CONFORMANCE = [
    ("IS3", dict(person=2), True),
    ("IS4", dict(message=(1 << 20) + 7), False),
    ("IS5", dict(message=(1 << 20) + 7), False),
    ("IC1", dict(person=2, firstName=None), True),     # name filled per-db
    ("IC2", dict(person=2, k=20), True),
    ("IC8", dict(person=1, k=20), False),
    ("IC9", dict(person=2, k=20), True),
    ("IC13", dict(person1=1, person2=9), False),
]

PARAMS = [pytest.param(q, p, marks=pytest.mark.slow if slow else ())
          for q, p, slow in CONFORMANCE]


def _canon(bundle) -> bytes:
    """Canonical bundle bytes: proof timings are wall-clock metadata, the
    only legitimately nondeterministic field."""
    for st in bundle.steps:
        st.proof.timings = {}
    return bundle.to_bytes()


@pytest.mark.parametrize("qname,params", PARAMS)
def test_compiled_bundle_is_wire_byte_identical(db, owner, verifier,
                                                qname, params):
    params = dict(params)
    if params.get("firstName", 0) is None:
        params["firstName"] = int(db.node_props["person"]["firstName"][0])
    hand = owner.prove(qname, dict(params))
    compiled = owner.prove_plan(
        compile_query(QUERY_TEXTS[qname], name=qname), dict(params))
    raw_hand, raw_compiled = _canon(hand), _canon(compiled)
    assert raw_hand == raw_compiled, \
        f"{qname}: compiled plan proves to different wire bytes"
    assert verifier.verify_bytes(raw_compiled), \
        f"{qname}: compiled bundle does not verify"


def test_text_named_bundle_round_trips(owner, verifier):
    """A bundle whose query field is the raw text verifies end-to-end: the
    verifier re-compiles the text itself via the registered plan resolver."""
    text = QUERY_TEXTS["IS5"]
    bundle = owner.prove_plan(compile_query(text), dict(message=(1 << 20) + 7))
    assert bundle.query == text
    raw = bundle.to_bytes()
    from repro.core.session import ProofBundle
    decoded = ProofBundle.from_bytes(raw)
    assert decoded.query == text
    assert verifier.verify_bytes(raw)


def test_renamed_bundle_fails_closed(owner, verifier, bundle):
    """Rewriting the query name to garbage text, a different query, or an
    unparseable string must invalidate the bundle, never crash."""
    import copy
    for bad in ("MATCH garbage (((", "IC99",
                QUERY_TEXTS["IS4"],        # parseable but a DIFFERENT query
                ""):
        b = copy.copy(bundle)
        b.query = bad
        assert not verifier.verify(b), f"accepted query name {bad!r}"


def test_compiled_result_matches_hand_result(db, owner):
    """Cheap no-prove sweep over all 8: identical query results."""
    for qname, params, _ in CONFORMANCE:
        params = dict(params)
        if params.get("firstName", 0) is None:
            params["firstName"] = int(
                db.node_props["person"]["firstName"][0])
        rh = owner.run_query(qname, dict(params))
        rc = owner.run_plan(
            compile_query(QUERY_TEXTS[qname], name=qname), dict(params))
        assert set(rh.result) == set(rc.result)
        for k in rh.result:
            assert np.array_equal(np.asarray(rh.result[k]),
                                  np.asarray(rc.result[k])), (qname, k)


def test_all_ldbc_texts_compile():
    for qname, text in QUERY_TEXTS.items():
        plan = compile_query(text, name=qname)
        assert plan.name == qname
        assert len(plan.nodes) == len(ir.build_plan(qname).nodes)


def test_query_text_resolver_fails_closed():
    for bad in ("MATCH (p:Person RETURN", "MATCH (p:Robot {id: 1})"
                "-[:KNOWS]-(f) RETURN f.id AS x"):
        with pytest.raises((QueryError, KeyError)):
            ir.build_plan(bad)
