"""RFC 8032 conformance for the pure-Python Ed25519 under the gossip
signatures (repro/core/ed25519.py): the RFC §7.1 test vectors byte-for-byte,
plus the strictness matrix — malleable scalars, off-curve and non-canonical
points, wrong lengths — all of which must verify ``False``, never raise."""
import hashlib

import pytest

from repro.core import ed25519 as ed

# RFC 8032 §7.1 TEST 1-3: (seed, public key, message, signature), hex
RFC_VECTORS = [
    ("9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60",
     "d75a980182b10ab7d54bfed3c964073a0ee172f3daa62325af021a68f707511a",
     "",
     "e5564300c360ac729086e2cc806e828a84877f1eb8e5d974d873e06522490155"
     "5fb8821590a33bacc61e39701cf9b46bd25bf5f0595bbe24655141438e7a100b"),
    ("4ccd089b28ff96da9db6c346ec114e0f5b8a319f35aba624da8cf6ed4fb8a6fb",
     "3d4017c3e843895a92b70aa74d1b7ebc9c982ccf2ec4968cc0cd55f12af4660c",
     "72",
     "92a009a9f0d4cab8720e820b5f642540a2b27b5416503f8fb3762223ebdb69da"
     "085ac1e43e15996e458f3613d0f11d8c387b2eaeb4302aeeb00d291612bb0c00"),
    ("c5aa8df43f9f837bedb7442f31dcb7b166d38535076f094b85ce3a2e0b4458f7",
     "fc51cd8e6218a1a38da47ed00230f0580816ed13ba3303ac5deb911548908025",
     "af82",
     "6291d657deec24024827e69c3abe01a30ce548a284743a445e3680d7db5ac3ac"
     "18ff9b538d16f290ae67f760984dc6594a7c15e9716ed28dc027beceea1ec40a"),
]


@pytest.mark.parametrize("seed,pub,msg,sig", RFC_VECTORS)
def test_rfc8032_vectors(seed, pub, msg, sig):
    seed, pub = bytes.fromhex(seed), bytes.fromhex(pub)
    msg, sig = bytes.fromhex(msg), bytes.fromhex(sig)
    assert ed.public_key(seed) == pub
    assert ed.sign(seed, msg) == sig
    assert ed.verify(pub, msg, sig) is True


def test_sign_verify_roundtrip_many_messages():
    key = ed.SigningKey.from_secret(b"roundtrip-secret")
    for i in range(8):
        msg = b"checkpoint-%d" % i * (i + 1)
        sig = key.sign(msg)
        assert ed.verify(key.pub, msg, sig) is True
        assert ed.verify(key.pub, msg + b"x", sig) is False
        assert ed.verify(key.pub, msg[:-1], sig) is False


def test_wrong_key_and_tampered_signature_fail():
    k1 = ed.SigningKey.from_secret(b"owner")
    k2 = ed.SigningKey.from_secret(b"not-the-owner")
    msg = b"the signed head"
    sig = k1.sign(msg)
    assert ed.verify(k2.pub, msg, sig) is False
    for pos in range(0, ed.SIGNATURE_LEN, 7):
        bad = bytearray(sig)
        bad[pos] ^= 1
        assert ed.verify(k1.pub, msg, bytes(bad)) is False


def test_malleability_s_plus_l_rejected():
    """S' = S + L satisfies the unreduced curve equation — RFC 8032
    demands rejecting it so signatures are non-malleable."""
    key = ed.SigningKey.from_secret(b"malleability")
    msg = b"m"
    sig = key.sign(msg)
    s = int.from_bytes(sig[32:], "little")
    s_malleated = s + ed._L
    if s_malleated < (1 << 256):
        forged = sig[:32] + int.to_bytes(s_malleated, 32, "little")
        assert ed.verify(key.pub, msg, forged) is False
    assert ed.verify(
        key.pub, msg, sig[:32] + b"\xff" * 32) is False    # S >> L


def test_noncanonical_and_off_curve_points_rejected():
    key = ed.SigningKey.from_secret(b"points")
    msg = b"m"
    sig = key.sign(msg)
    # a y coordinate >= p is a non-canonical encoding
    bad_pub = int.to_bytes(ed._P + 1, 32, "little")
    assert ed.verify(bad_pub, msg, sig) is False
    # R replaced by an off-curve encoding (y=2 is not on the curve)
    off = int.to_bytes(2, 32, "little")
    assert ed.verify(key.pub, msg, off + sig[32:]) is False
    # -0: x sign bit set with x = 0 is non-canonical
    minus_zero = int.to_bytes(1 | (1 << 255), 32, "little")
    assert ed.verify(minus_zero, msg, sig) is False


def test_wrong_lengths_and_types_return_false_never_raise():
    key = ed.SigningKey.from_secret(b"lengths")
    sig = key.sign(b"m")
    for pub in (key.pub[:-1], key.pub + b"\x00", b"", None, "not-bytes", 7):
        assert ed.verify(pub, b"m", sig) is False
    for bad_sig in (sig[:-1], sig + b"\x00", b"", None, "not-bytes", 7):
        assert ed.verify(key.pub, b"m", bad_sig) is False


def test_signing_side_fails_loud_on_bad_material():
    with pytest.raises(ed.Ed25519Error):
        ed.sign(b"short", b"m")
    with pytest.raises(ed.Ed25519Error):
        ed.public_key(b"\x00" * 31)
    with pytest.raises(ed.Ed25519Error):
        ed.SigningKey(b"\x00" * 33)
    with pytest.raises(ed.Ed25519Error):
        ed.SigningKey.from_secret(b"")


def test_from_secret_is_the_documented_derivation():
    secret = b"zkgraph-demo-origin-key"
    key = ed.SigningKey.from_secret(secret)
    assert key.seed == hashlib.sha512(secret).digest()[:32]
    assert key.pub == ed.public_key(key.seed)
