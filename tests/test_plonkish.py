"""End-to-end PLONKish proof system tests: completeness + soundness.

The Fibonacci circuit mirrors the paper's Fig. 1 example; the bus and
grand-product circuits exercise the argument machinery the graph operators
(paper §IV) are built from.
"""
import numpy as np
import pytest

from repro.core import field as F
from repro.core import plonkish as pk
from repro.core import prover as pv
from repro.core import verifier as vf

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)


def _fib_circuit(n_rows=32):
    """Paper Fig. 1: S[i] * (A[i] + B[i] - C[i]) = 0 with wiring via rotation
    gates A[i+1]=B[i], B[i+1]=C[i]; claimed f(8)=21 lives in an instance col."""
    c = pk.Circuit(n_rows, name="fib")
    steps = 8
    sel = c.add_fixed("s_add", np.array([1] * steps + [0] * (n_rows - steps)))
    sel_w = c.add_fixed("s_wire", np.array([1] * (steps - 1) + [0] * (n_rows - steps + 1)))
    a = c.add_advice("A")
    b = c.add_advice("B")
    cc = c.add_advice("C")
    out = c.add_instance("claimed")
    one_hot_last = np.zeros(n_rows)
    one_hot_last[steps - 1] = 1
    sel_out = c.add_fixed("s_out", one_hot_last)
    c.add_gate("add", sel * (a + b - cc))
    c.add_gate("wireA", sel_w * (a.rotate(1) - b))
    c.add_gate("wireB", sel_w * (b.rotate(1) - cc))
    c.add_gate("output", sel_out * (cc - out))
    return c, steps


def _fib_witness(c, steps, tamper=False):
    n = c.n_rows
    advice = np.zeros((c.n_advice, n), np.uint32)
    fa, fb = 1, 1
    for i in range(steps):
        advice[0, i], advice[1, i] = fa, fb
        advice[2, i] = fa + fb
        fa, fb = fb, fa + fb
    claimed = advice[2, steps - 1]
    if tamper:
        claimed = claimed + 1
    instance = np.full((1, n), claimed, np.uint32)
    return advice, instance


@pytest.mark.slow
def test_fibonacci_completeness():
    c, steps = _fib_circuit()
    keys = pv.keygen(c, CFG)
    advice, instance = _fib_witness(c, steps)
    proof = pv.prove(keys, advice, instance)
    assert vf.verify(keys, instance, proof)


def test_fibonacci_soundness_wrong_claim():
    c, steps = _fib_circuit()
    keys = pv.keygen(c, CFG)
    advice, instance = _fib_witness(c, steps, tamper=True)
    proof = pv.prove(keys, advice, instance)
    assert not vf.verify(keys, instance, proof)


def test_fibonacci_soundness_tampered_witness():
    c, steps = _fib_circuit()
    keys = pv.keygen(c, CFG)
    advice, instance = _fib_witness(c, steps)
    advice[2, 3] = (int(advice[2, 3]) + 5) % F.P
    proof = pv.prove(keys, advice, instance)
    assert not vf.verify(keys, instance, proof)


def test_fibonacci_rejects_instance_swap():
    """Proof generated for one claim must not verify against another."""
    c, steps = _fib_circuit()
    keys = pv.keygen(c, CFG)
    advice, instance = _fib_witness(c, steps)
    proof = pv.prove(keys, advice, instance)
    other = instance.copy()
    other[0, :] = 99
    assert not vf.verify(keys, other, proof)


def _lookup_circuit(n_rows=64, bad=False):
    """f-column values must all appear in a fixed table (logUp bus)."""
    c = pk.Circuit(n_rows, name="lookup")
    table = c.add_fixed("table", np.arange(0, 2 * n_rows, 2))  # even numbers
    f = c.add_advice("f")
    sel = c.add_fixed("sel", np.ones(n_rows))
    c.add_bus("f_in_table", [f], [table], m_f=sel)
    advice = np.zeros((c.n_advice, n_rows), np.uint32)
    rng = np.random.default_rng(5)
    advice[0] = rng.integers(0, n_rows, size=n_rows) * 2
    if bad:
        advice[0, 17] = 3  # odd: not in table
    return c, advice


def test_lookup_bus_completeness():
    c, advice = _lookup_circuit()
    keys = pv.keygen(c, CFG)
    proof = pv.prove(keys, advice, np.zeros((0, c.n_rows), np.uint32))
    assert vf.verify(keys, np.zeros((0, c.n_rows), np.uint32), proof)


def test_lookup_bus_soundness():
    c, advice = _lookup_circuit(bad=True)
    keys = pv.keygen(c, CFG)
    proof = pv.prove(keys, advice, np.zeros((0, c.n_rows), np.uint32))
    assert not vf.verify(keys, np.zeros((0, c.n_rows), np.uint32), proof)


def _permutation_circuit(n_rows=64, mode="gp", bad=False):
    """Paper Eq. (1)+(2): two column pairs must be multiset-equal."""
    c = pk.Circuit(n_rows, name="perm")
    a1 = c.add_advice("a1")
    a2 = c.add_advice("a2")
    b1 = c.add_advice("b1")
    b2 = c.add_advice("b2")
    if mode == "gp":
        c.add_grand_product("perm", [a1, a2], [b1, b2])
    else:
        one = c.add_fixed("one", np.ones(n_rows))
        c.add_multiset_equal("perm", [a1, a2], one, [b1, b2], one)
    rng = np.random.default_rng(7)
    advice = np.zeros((c.n_advice, n_rows), np.uint32)
    pairs = rng.integers(0, F.P, size=(n_rows, 2)).astype(np.uint32)
    perm = rng.permutation(n_rows)
    advice[0], advice[1] = pairs[:, 0], pairs[:, 1]
    advice[2], advice[3] = pairs[perm, 0], pairs[perm, 1]
    if bad:
        advice[2, 5] = (int(advice[2, 5]) + 1) % F.P
    return c, advice


@pytest.mark.parametrize("mode", ["gp", "bus"])
def test_permutation_argument_completeness(mode):
    c, advice = _permutation_circuit(mode=mode)
    keys = pv.keygen(c, CFG)
    inst = np.zeros((0, c.n_rows), np.uint32)
    proof = pv.prove(keys, advice, inst)
    assert vf.verify(keys, inst, proof)


@pytest.mark.parametrize("mode", ["gp", "bus"])
def test_permutation_argument_soundness(mode):
    c, advice = _permutation_circuit(mode=mode, bad=True)
    keys = pv.keygen(c, CFG)
    inst = np.zeros((0, c.n_rows), np.uint32)
    proof = pv.prove(keys, advice, inst)
    assert not vf.verify(keys, inst, proof)


def test_range_check():
    n_rows = 256
    c = pk.Circuit(n_rows, name="range")
    v = c.add_advice("v")
    limbs, lb = c.add_range_check("v_range", v, bits=16)
    keys = pv.keygen(c, CFG)
    advice = np.zeros((c.n_advice, n_rows), np.uint32)
    rng = np.random.default_rng(11)
    vals = rng.integers(0, 2 ** 16, size=n_rows)
    advice[0] = vals
    pk.fill_range_limbs(advice, limbs, lb, vals)
    inst = np.zeros((0, n_rows), np.uint32)
    proof = pv.prove(keys, advice, inst)
    assert vf.verify(keys, inst, proof)
    # out-of-range value with forged limbs must fail
    advice2 = advice.copy()
    advice2[0, 3] = F.P - 5  # "negative" value, not representable in 16 bits
    proof2 = pv.prove(keys, advice2, inst)
    assert not vf.verify(keys, inst, proof2)
