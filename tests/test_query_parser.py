"""Parser robustness: the front door fails closed.

Hostile input — truncation, unbalanced patterns, depth bombs, garbage — must
raise a *positioned* QuerySyntaxError (never a raw exception, never a wrong
AST), and out-of-schema queries must raise QueryCompileError.  The Hypothesis
round-trip property (``parse(pretty_print(ast)) == ast``, marked ``fuzz``)
pins the printer and parser to each other; it skips cleanly where hypothesis
is not installed (CI installs it).
"""
import pytest

from repro.query import (QUERY_TEXTS, QueryCompileError, QueryError,
                         QuerySyntaxError, compile_query, parse, pretty_print)
from repro.query import ast as A
from repro.query.parser import MAX_HOPS, MAX_INT, MAX_ITEMS, MAX_TEXT

VALID = "MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) RETURN f.id AS x"


# ---------------------------------------------------------------------------
# positioned syntax errors
# ---------------------------------------------------------------------------
HOSTILE = [
    "",                                       # empty
    "   \n\t ",                               # whitespace only
    "SELECT * FROM t",                        # wrong language
    "MATCH",                                  # truncated after keyword
    "MATCH (",                                # unbalanced node
    "MATCH (p",                               # unclosed node
    "MATCH (p:Person {id: $x}",               # unclosed prop map
    "MATCH (p)-[",                            # unclosed edge
    "MATCH (p)-[:KNOWS]-",                    # edge without right node
    "MATCH (p)-[:KNOWS]>(q) RETURN p.id AS x",    # malformed arrow
    "MATCH (p) RETURN",                       # missing return item
    "MATCH (p) RETURN p.id",                  # missing AS alias
    "MATCH (p) RETURN p.id AS",               # missing alias name
    "MATCH (p) RETURN p.id AS x ORDER",       # ORDER without BY
    "MATCH (p) RETURN p.id AS x ORDER BY p.id",   # missing ASC/DESC
    "MATCH (p) RETURN p.id AS x LIMIT",       # missing limit value
    "MATCH (p) RETURN p.id AS x LIMIT -3",    # negative literal
    "MATCH (p) WHERE p.a ~ 3 RETURN p.id AS x",   # unknown operator
    "MATCH (p) WHERE p.a = 'x' RETURN p.id AS x",  # string literal
    "MATCH (p)-[:KNOWS*0..3]-(f) RETURN f.id AS x",   # hop lower bound 0
    "MATCH (p)-[:KNOWS*3..2]-(f) RETURN f.id AS x",   # inverted bounds
    f"MATCH (p)-[:KNOWS*1..{MAX_HOPS + 1}]-(f) RETURN f.id AS x",
    "MATCH (p) RETURN p.id AS x trailing",    # trailing garbage
    "MATCH (p) RETURN p.id AS x \0",          # control character
    f"MATCH (p) WHERE p.a = {MAX_INT} RETURN p.id AS x",  # oversized int
]


@pytest.mark.parametrize("text", HOSTILE, ids=lambda t: repr(t[:28]))
def test_hostile_inputs_fail_closed_with_position(text):
    with pytest.raises(QuerySyntaxError) as err:
        parse(text)
    assert err.value.line >= 1 and err.value.col >= 1
    assert f"line {err.value.line}, col {err.value.col}" in str(err.value)


def test_depth_bombs_hit_hard_caps():
    with pytest.raises(QuerySyntaxError):
        parse("MATCH " + "(a)-[:KNOWS]-" * (MAX_ITEMS + 2)
              + "(z) RETURN z.id AS x")
    with pytest.raises(QuerySyntaxError):
        parse("MATCH " + ", ".join(["(a)"] * (MAX_ITEMS + 2))
              + " RETURN a.id AS x")
    with pytest.raises(QuerySyntaxError):
        parse("MATCH (a) WHERE "
              + " AND ".join(["a.p = 1"] * (MAX_ITEMS + 2))
              + " RETURN a.id AS x")
    with pytest.raises(QuerySyntaxError) as err:
        parse(VALID + " " * (MAX_TEXT + 1))
    assert "exceeds" in str(err.value)
    # non-string input is a syntax error, not an AttributeError
    with pytest.raises(QuerySyntaxError):
        parse(None)


def test_every_prefix_truncation_fails_closed():
    """No prefix of a valid query may raise anything but QueryError."""
    for text in QUERY_TEXTS.values():
        for i in range(len(text)):
            try:
                q = parse(text[:i])
            except QueryError:
                continue
            assert isinstance(q, A.Query)   # a shorter valid query is fine


# ---------------------------------------------------------------------------
# compile errors (well-formed text outside the subset / schema)
# ---------------------------------------------------------------------------
BAD_COMPILES = [
    # unknown names
    "MATCH (p:Robot {id: $x})-[:KNOWS]-(f) RETURN f.id AS y",
    "MATCH (p:Person {id: $x})-[:LIKES]->(f) RETURN f.id AS y",
    "MATCH (p:Person {id: $x})-[:KNOWS]-(f:Person) "
    "WHERE f.shoeSize = 4 RETURN f.id AS y",
    # unanchored / misanchored patterns
    "MATCH (p:Person)-[:KNOWS]-(f) RETURN f.id AS y",
    "MATCH (p:Person {name: $x})-[:KNOWS]-(f) RETURN f.id AS y",
    "MATCH (p:Person {id: $x})-[:KNOWS]-(f:Person {id: 3}) "
    "RETURN f.id AS y",
    # direction misuse
    "MATCH (p:Person {id: $x})-[:KNOWS]->(f) RETURN f.id AS y",
    "MATCH (m:Message {id: $x})-[:HAS_CREATOR]-(c) RETURN c.id AS y",
    # variable-length misuse
    "MATCH (p:Person {id: $x})-[:KNOWS*]-(f) RETURN f.id AS y",
    "MATCH (p:Person {id: $x})<-[:HAS_CREATOR*1..2]-(m) RETURN m.id AS y",
    "MATCH (p:Person {id: $x})-[:KNOWS*2..3]-(f) RETURN f.id AS y",
    # clause misuse
    "MATCH (p:Person {id: $x})-[:KNOWS]-(f) RETURN f.id AS y LIMIT 5",
    "MATCH (p:Person {id: $x})-[:KNOWS]-(f) "
    "RETURN count(f) AS n ORDER BY f.id DESC",
    "MATCH (p:Person {id: $x})-[:KNOWS]-(f) RETURN length(f) AS y",
    "MATCH (p:Person {id: $x})-[k:KNOWS]-(f) "
    "WHERE k.creationDate > 3 RETURN f.id AS y",
    # edge without a type
    "MATCH (p:Person {id: $x})-[]-(f) RETURN f.id AS y",
    # duplicate variable
    "MATCH (f:Person {id: $x})-[f:KNOWS]-(g) RETURN g.id AS y",
    # multiple patterns
    "MATCH (p:Person {id: $x}), (q:Person {id: $y}) RETURN p.id AS a",
]


@pytest.mark.parametrize("text", BAD_COMPILES, ids=lambda t: t[:44])
def test_out_of_subset_queries_raise_compile_errors(text):
    with pytest.raises(QueryCompileError):
        compile_query(text)


def test_ldbc_texts_parse_and_round_trip():
    for name, text in QUERY_TEXTS.items():
        ast = parse(text)
        assert parse(pretty_print(ast)) == ast, name


# ---------------------------------------------------------------------------
# Hypothesis round trip: parse(pretty_print(ast)) == ast
# ---------------------------------------------------------------------------
_RESERVED = {"match", "where", "and", "return", "order", "by", "limit",
             "as", "asc", "desc", "count", "sum", "min", "length",
             "shortestpath"}


def _strategies():
    st = pytest.importorskip(
        "hypothesis.strategies",
        reason="hypothesis is a CI-only dependency (requirements-ci.txt)")

    ident = st.from_regex(r"[a-z][a-zA-Z0-9_]{0,6}", fullmatch=True) \
        .filter(lambda s: s.lower() not in _RESERVED)
    value = st.one_of(
        st.integers(0, 10**9).map(A.IntLit),
        ident.map(A.ParamRef))
    node = st.builds(
        A.NodePat,
        var=st.none() | ident,
        label=st.none() | ident,
        prop_key=st.none() | ident,
        prop_value=value,
    ).map(lambda n: A.NodePat(n.var, n.label, n.prop_key,
                              n.prop_value if n.prop_key else None))
    hops = st.one_of(
        st.just((None, None)), st.just((1, None)),
        st.tuples(st.integers(1, MAX_HOPS), st.integers(1, MAX_HOPS))
        .map(lambda t: (min(t), max(t))))
    edge = st.builds(
        lambda var, etype, d, h: A.EdgePat(var, etype, d, h[0], h[1]),
        st.none() | ident, st.none() | ident,
        st.sampled_from(["out", "in", "any"]), hops)
    path = st.builds(
        lambda nodes, edges, pv, sp: A.PathPat(
            tuple(nodes[:len(edges) + 1]), tuple(edges), pv, sp),
        st.lists(node, min_size=MAX_ITEMS + 1, max_size=MAX_ITEMS + 1),
        st.lists(edge, min_size=0, max_size=3),
        st.none() | ident, st.booleans())
    prop_ref = st.builds(A.PropRef, ident, ident)
    expr = st.one_of(
        prop_ref,
        st.builds(A.AggCall, st.sampled_from(list(A.AGG_FNS)),
                  st.one_of(ident, prop_ref)),
        st.builds(A.LengthCall, ident))
    query = st.builds(
        A.Query,
        patterns=st.lists(path, min_size=1, max_size=2).map(tuple),
        where=st.lists(
            st.builds(A.Predicate, prop_ref,
                      st.sampled_from(list(A.CMP_TOKENS)), value),
            max_size=2).map(tuple),
        returns=st.lists(st.builds(A.ReturnItem, expr, ident),
                         min_size=1, max_size=2).map(tuple),
        order=st.lists(st.builds(A.OrderItem, prop_ref, st.booleans()),
                       max_size=2).map(tuple),
        limit=st.none() | value)
    return query


@pytest.mark.fuzz
def test_parse_pretty_print_round_trip():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="hypothesis is a CI-only dependency (requirements-ci.txt)")
    query = _strategies()

    @hypothesis.settings(max_examples=300, deadline=None)
    @hypothesis.given(query)
    def prop(q):
        text = pretty_print(q)
        assert parse(text) == q, text

    prop()


@pytest.mark.fuzz
def test_fuzz_compile_never_raises_raw_exceptions():
    """Compiling any printable AST either yields a plan or a QueryError."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="hypothesis is a CI-only dependency (requirements-ci.txt)")
    query = _strategies()

    @hypothesis.settings(max_examples=200, deadline=None)
    @hypothesis.given(query)
    def prop(q):
        try:
            compile_query(pretty_print(q))
        except QueryError:
            pass

    prop()
