"""Proof-path purity lint: every rule on a synthetic snippet, the real
tree staying clean (modulo the committed baseline), and the LM-training
quarantine regression guard."""
from pathlib import Path

from repro.analysis.findings import ERROR, WARNING, load_baseline
from repro.analysis.purity import (is_proof_path, lint_source,
                                   run_purity_lint)

ROOT = Path(__file__).resolve().parent.parent


def _ids(findings):
    return {(f.check, f.key) for f in findings}


# ---------------------------------------------------------------------------
# rule-by-rule on synthetic snippets
# ---------------------------------------------------------------------------
def test_pickle_banned_everywhere():
    for rel in ("core/session.py", "serve/service.py", "core/prover.py"):
        fs = lint_source(rel, "import pickle\n")
        assert ("banned-import", "import pickle") in _ids(fs)
    fs = lint_source("serve/service.py", "from dill import loads\n")
    assert any(f.check == "banned-import" for f in fs)


def test_time_random_banned_only_on_proof_path():
    src = "import time\nimport random\n"
    assert len(lint_source("core/prover.py", src)) == 2
    assert lint_source("core/session.py", src) == []     # infra may time
    assert is_proof_path("core/operators/expansion.py")
    assert not is_proof_path("core/session.py")


def test_quarantine_breach_absolute_and_relative():
    fs = lint_source("core/session.py", "from repro.train import loop\n")
    assert any(f.check == "quarantine-breach" for f in fs)
    # relative import resolution: core/x.py's ``..train`` is repro.train
    fs = lint_source("core/x.py", "from ..train import loop\n")
    assert any(f.check == "quarantine-breach" for f in fs)
    fs = lint_source("serve/x.py", "from repro.models import lm\n")
    assert any(f.check == "quarantine-breach" for f in fs)
    # core importing core is fine
    assert lint_source("core/x.py", "from ..core import field\n") == []


def test_float_rules_fire_on_proof_path_only():
    cases = ["x = 1.5\n", "y = a / b\n", "d = np.float32\n",
             "z = float(x)\n"]
    for src in cases:
        fs = lint_source("core/fri.py", src)
        assert any(f.check == "float-in-field-code" and f.severity == ERROR
                   for f in fs), src
        assert lint_source("core/backend.py", src) == [], src
    # integer division and int literals are fine on the proof path
    assert lint_source("core/fri.py", "x = a // b\ny = 7\n") == []


def test_unseeded_rng_detected():
    fs = lint_source("core/session.py", "r = np.random.default_rng()\n")
    assert any(f.check == "unseeded-rng" for f in fs)
    fs = lint_source("serve/x.py", "np.random.shuffle(xs)\n")
    assert any(f.check == "unseeded-rng" for f in fs)
    assert lint_source("core/session.py",
                       "r = np.random.default_rng(11)\n") == []


def test_nondet_set_iteration_warned():
    fs = lint_source("core/x.py", "for v in {1, 2, 3}:\n    pass\n")
    assert any(f.check == "nondet-iteration" and f.severity == WARNING
               for f in fs)
    fs = lint_source("core/x.py", "ys = [v for v in set(xs)]\n")
    assert any(f.check == "nondet-iteration" for f in fs)
    assert lint_source("core/x.py",
                       "for v in sorted(set(xs)):\n    pass\n") == []


def test_eval_exec_banned():
    assert any(f.check == "eval-exec"
               for f in lint_source("serve/x.py", "eval('1+1')\n"))


SERVE_CLASS = """\
import threading

class Svc:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def unsafe(self):
        self.n += 1

    def safe(self):
        with self._lock:
            self.n += 1
"""


def test_unlocked_serve_state_warned():
    fs = lint_source("serve/svc.py", SERVE_CLASS)
    hits = [f for f in fs if f.check == "unlocked-serve-state"]
    assert len(hits) == 1 and "self.n += 1" == hits[0].key
    # same code outside repro.serve is not the lint's business
    assert lint_source("core/svc.py", SERVE_CLASS) == []


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------
def test_real_tree_clean_modulo_baseline():
    findings, n_files = run_purity_lint()
    assert n_files >= 30, "lint should scan all of repro.core + repro.serve"
    baseline = load_baseline(ROOT / "analysis_baseline.json")
    unsuppressed = [f for f in findings if f.ident() not in baseline]
    assert unsuppressed == [], \
        f"purity findings outside the baseline: " \
        f"{[(f.check, f.where, f.line, f.key) for f in unsuppressed]}"
    # and the baseline itself has no stale entries
    idents = {f.ident() for f in findings}
    assert baseline <= idents, f"stale baseline entries: {baseline - idents}"


def test_quarantine_holds_on_real_tree():
    """Regression guard for the LM-training quarantine: no core/serve file
    imports repro.train, repro.models, or repro.configs.lm."""
    findings, _ = run_purity_lint()
    breaches = [f for f in findings if f.check == "quarantine-breach"]
    assert breaches == [], \
        f"quarantine breached: {[(f.where, f.key) for f in breaches]}"
