"""Durable log-store crash and adversarial coverage.

The durability claims of repro/core/logstore.py are gated here, on every PR
(none of these are slow-marked; CI additionally runs this file in a
dedicated ``logstore-recovery`` step):

* acknowledged appends survive ``kill -9`` — asserted with a real
  SIGKILLed writer subprocess, at a random moment, repeatedly;
* a torn tail record (simulated crash mid-write, at EVERY byte offset of
  the final record) is detected and truncated back to the last intact
  record, and the recovered log's roots are byte-identical to a fresh
  in-memory log over the recovered entries;
* non-crash corruption — bad magic, mid-file damage with intact records
  after it, checkpoint records whose roots don't match the re-derived
  tree — fails closed with ``LogStoreError``, never a silent repair.
"""
import os
import signal
import struct
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import logstore as ls
from repro.core.transparency import Checkpoint, TransparencyLog

ENTRIES = [b"manifest-rev-%d" % i + bytes(range(i % 7)) for i in range(9)]


def fresh_store(path, entries=ENTRIES, checkpoint_every=1):
    log = ls.DurableTransparencyLog.open(path, "t-log",
                                         checkpoint_every=checkpoint_every)
    for e in entries:
        log.append(e)
    log.close()
    return path


def expected_root(entries):
    mem = TransparencyLog("t-log")
    for e in entries:
        mem.append(e)
    return mem.root()


def record_spans(raw):
    """[(offset, kind, payload, end)] for every intact record, in order."""
    pos, spans = len(ls.STORE_MAGIC), []
    while pos < len(raw):
        rec = ls._parse_record(raw, pos)
        if rec is None:
            break
        kind, payload, end = rec
        spans.append((pos, kind, payload, end))
        pos = end
    return spans


def append_record(path, kind, payload):
    """Append one framed record at the file's current tail offset."""
    offset = os.path.getsize(path)
    with open(path, "ab") as fh:
        fh.write(ls.frame_record(kind, payload, offset))


# ---------------------------------------------------------------------------
# round trip + durability basics
# ---------------------------------------------------------------------------
def test_reopen_preserves_entries_and_roots(tmp_path):
    path = fresh_store(tmp_path / "log.bin")
    log = TransparencyLog.open(path)            # the front door
    assert isinstance(log, ls.DurableTransparencyLog)
    assert log.origin == "t-log"                # adopted from the store
    assert log.recovered_bytes == 0
    assert log.size == len(ENTRIES)
    assert [log.entry(i) for i in range(log.size)] == ENTRIES
    for size in range(1, log.size + 1):
        assert np.array_equal(log.root(size), expected_root(ENTRIES[:size]))
    log.sync()
    log.close()


def test_append_is_on_disk_before_checkpoint_returns(tmp_path):
    """No close(), no extra flush: the bytes an append acknowledged must
    already be replayable by an independent reader (fsync'd write-through)."""
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    cp = log.append(b"only-entry")
    origin, entries, checkpoints, intact = ls.replay(path.read_bytes())
    assert origin == "t-log" and entries == [b"only-entry"]
    assert checkpoints and checkpoints[-1][1].tree_size == 1
    assert np.array_equal(checkpoints[-1][1].root, cp.root)
    log.close()


def test_checkpoint_every_n_appends(tmp_path):
    path = fresh_store(tmp_path / "log.bin", checkpoint_every=4)
    _, entries, checkpoints, _ = ls.replay(path.read_bytes())
    assert len(entries) == 9
    assert [cp.tree_size for _, cp in checkpoints] == [4, 8]
    log = TransparencyLog.open(path)
    assert log.last_stored_checkpoint.tree_size == 8
    log.append(b"ninth-to-twelfth" * 1)
    log.close()


def test_open_adopts_or_rejects_origin(tmp_path):
    path = fresh_store(tmp_path / "log.bin")
    assert TransparencyLog.open(path, "t-log").origin == "t-log"
    with pytest.raises(ls.LogStoreError, match="belongs to"):
        TransparencyLog.open(path, "other-log")


def test_closed_store_refuses_appends(tmp_path):
    log = ls.DurableTransparencyLog.open(tmp_path / "log.bin", "t-log")
    log.close()
    with pytest.raises(ls.LogStoreError, match="closed"):
        log.append(b"x")


def test_failed_write_poisons_store_and_rolls_back_memory(tmp_path):
    """A write that dies mid-record (disk full, I/O error) may leave junk
    at an unknowable offset: the store must poison itself (no further
    appends framed against a stale offset, which replay would silently
    truncate as a torn tail) and the in-memory tree must roll back so it
    never runs ahead of disk.  Reopening recovers the intact prefix."""
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    log.append(b"survives")
    root_before = log.root()

    class _DyingFh:
        def write(self, data):
            # the classic partial failure: a few bytes land (inside the
            # entry record's frame, so the tail is genuinely torn), then
            # the device reports ENOSPC
            with open(path, "ab") as fh:
                fh.write(data[:10])
            raise OSError(28, "No space left on device")

        def close(self):
            pass

    log._fh.close()
    log._fh = _DyingFh()
    with pytest.raises(OSError):
        log.append(b"never-acknowledged")
    assert log.size == 1                      # memory rolled back
    assert np.array_equal(log.root(), root_before)
    with pytest.raises(ls.LogStoreError, match="poisoned|closed"):
        log.append(b"refused")                # poisoned until reopened
    reopened = TransparencyLog.open(path)     # junk truncated as torn tail
    assert reopened.size == 1
    assert reopened.entry(0) == b"survives"
    assert reopened.recovered_bytes > 0
    assert np.array_equal(reopened.root(), root_before)
    reopened.append(b"post-recovery")         # fully writable again
    reopened.sync()
    reopened.close()


# ---------------------------------------------------------------------------
# torn-tail recovery (the acceptance criterion)
# ---------------------------------------------------------------------------
def test_torn_tail_truncated_at_every_cut_of_the_last_record(tmp_path):
    """Simulated crash mid-append: cut the file at EVERY byte inside the
    final record.  Reopening must recover to the intact prefix with
    byte-identical roots, and the store must keep working."""
    path = fresh_store(tmp_path / "log.bin")
    raw = path.read_bytes()
    # the last record is the checkpoint for entry 9; the one before it the
    # entry itself — find the final ENTRY record's start to cut inside both
    _, _, _, intact = ls.replay(raw)
    assert intact == len(raw)
    entry_spans = [s for s in record_spans(raw) if s[1] == ls.REC_ENTRY]
    entry_start, _, payload, entry_end = entry_spans[-1]
    assert payload == ENTRIES[-1]
    for cut in range(entry_start + 1, len(raw)):
        path.write_bytes(raw[:cut])
        log = TransparencyLog.open(path)
        # entry record torn -> lose the last entry; entry intact but its
        # checkpoint record torn -> all entries survive
        kept = ENTRIES if cut >= entry_end else ENTRIES[:-1]
        assert log.size == len(kept), f"cut at {cut}"
        torn_from = entry_end if kept == ENTRIES else entry_start
        assert log.recovered_bytes == max(0, cut - torn_from), f"cut {cut}"
        assert np.array_equal(log.root(), expected_root(kept)), \
            f"root diverged after recovery at cut {cut}"
        log.append(b"post-recovery")         # the store stays writable
        assert np.array_equal(log.root(),
                              expected_root(kept + [b"post-recovery"]))
        log.sync()
        log.close()


def test_recovery_lands_on_last_intact_checkpoint(tmp_path):
    """With checkpoint_every=1 a torn ENTRY record recovers to exactly the
    state of the last intact checkpoint record — byte-identical root."""
    path = fresh_store(tmp_path / "log.bin")
    raw = path.read_bytes()
    start, _, _, end = [s for s in record_spans(raw)
                        if s[1] == ls.REC_ENTRY][-1]
    path.write_bytes(raw[: start + (end - start) // 2])
    log = TransparencyLog.open(path)
    stored = log.last_stored_checkpoint
    assert stored is not None and stored.tree_size == log.size == 8
    assert np.array_equal(stored.root, expected_root(ENTRIES[:-1]))
    log.close()


def test_torn_store_header_reinitializes(tmp_path):
    path = tmp_path / "log.bin"
    path.write_bytes(ls.STORE_MAGIC[:5])     # crash during store creation
    log = ls.DurableTransparencyLog.open(path, "t-log")
    assert log.size == 0 and log.recovered_bytes == 5
    log.append(b"first")
    log.close()
    assert TransparencyLog.open(path).size == 1


def test_torn_origin_record_reinitializes(tmp_path):
    path = tmp_path / "log.bin"
    full = ls.STORE_MAGIC + ls.frame_record(ls.REC_ORIGIN, b"t-log",
                                           len(ls.STORE_MAGIC))
    path.write_bytes(full[:-3])              # crash writing the origin
    log = ls.DurableTransparencyLog.open(path, "t-log")
    assert log.size == 0
    log.append(b"first")
    log.sync()
    log.close()


# ---------------------------------------------------------------------------
# kill-during-append: a real SIGKILLed writer process
# ---------------------------------------------------------------------------
_WRITER = """
import sys, time
sys.path.insert(0, {src!r})
from repro.core import logstore as ls
log = ls.DurableTransparencyLog.open({path!r}, "kill-log")
print("ready", flush=True)
i = log.size
while True:
    log.append(b"entry-%06d" % i)
    i += 1
"""


@pytest.mark.parametrize("grace", [0.05, 0.25])
def test_kill_during_append_recovers_to_intact_prefix(tmp_path, grace):
    """SIGKILL a live writer at an arbitrary moment; the reopened store
    must hold an intact prefix of what the writer wrote, in order, with
    byte-identical re-derived roots — twice, to cover a recovered store
    being killed again."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    path = str(tmp_path / "log.bin")
    sizes = []
    for round_ in range(2):
        proc = subprocess.Popen(
            [sys.executable, "-c", _WRITER.format(src=src, path=path)],
            stdout=subprocess.PIPE)
        assert proc.stdout.readline().strip() == b"ready"
        deadline = time.time() + 30
        while not os.path.exists(path) and time.time() < deadline:
            time.sleep(0.01)
        time.sleep(grace)                      # let it race mid-append
        proc.send_signal(signal.SIGKILL)
        proc.wait()
        log = TransparencyLog.open(path)
        sizes.append(log.size)
        entries = [log.entry(i) for i in range(log.size)]
        assert entries == [b"entry-%06d" % i for i in range(log.size)], \
            "recovered entries are not the writer's prefix"
        if log.size:
            assert np.array_equal(log.root(), expected_root(entries))
        log.sync()
        log.close()
    assert sizes[1] >= sizes[0], "recovery lost acknowledged appends"


# ---------------------------------------------------------------------------
# non-crash corruption fails closed
# ---------------------------------------------------------------------------
def test_foreign_file_rejected(tmp_path):
    path = tmp_path / "notalog.bin"
    path.write_bytes(b"GIF89a, definitely not a log store" * 4)
    with pytest.raises(ls.LogStoreError, match="magic"):
        TransparencyLog.open(path)


def test_midfile_corruption_with_intact_tail_rejected(tmp_path):
    """Damage an EARLY record while later records stay intact: that state
    is unreachable by a crash (append-only writes tear only the tail), so
    recovery must refuse to 'repair' it."""
    path = fresh_store(tmp_path / "log.bin")
    raw = bytearray(path.read_bytes())
    first = ls.STORE_MAGIC + ls.frame_record(ls.REC_ORIGIN, b"t-log",
                                            len(ls.STORE_MAGIC))
    raw[len(first) + 7] ^= 0xFF            # inside the first entry payload
    path.write_bytes(bytes(raw))
    with pytest.raises(ls.LogStoreError, match="torn tail"):
        TransparencyLog.open(path)


def test_tampered_checkpoint_root_rejected(tmp_path):
    """A stored checkpoint record that passes CRC but whose root does not
    match the tree re-derived from the entries is tampering, not a crash:
    open() must raise, not truncate."""
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    log.append(b"honest-entry")
    log.close()
    evil = Checkpoint("t-log", 1, np.arange(8, dtype=np.uint32))
    append_record(path, ls.REC_CHECKPOINT, evil.to_bytes())
    with pytest.raises(ls.LogStoreError, match="re-derived"):
        TransparencyLog.open(path)


def test_checkpoint_beyond_entries_rejected(tmp_path):
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    cp = log.append(b"the-entry")
    log.close()
    ahead = Checkpoint("t-log", 2, cp.root)
    append_record(path, ls.REC_CHECKPOINT, ahead.to_bytes())
    with pytest.raises(ls.LogStoreError, match="entries precede"):
        TransparencyLog.open(path)


def test_cross_origin_checkpoint_record_rejected(tmp_path):
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    cp = log.append(b"the-entry")
    log.close()
    alien = Checkpoint("other-log", 1, cp.root)
    append_record(path, ls.REC_CHECKPOINT, alien.to_bytes())
    with pytest.raises(ls.LogStoreError, match="origin"):
        TransparencyLog.open(path)


def test_malformed_stored_checkpoint_payload_rejected(tmp_path):
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    log.append(b"the-entry")
    log.close()
    append_record(path, ls.REC_CHECKPOINT, b"not a checkpoint")
    with pytest.raises(ls.LogStoreError, match="malformed"):
        TransparencyLog.open(path)


def test_duplicate_or_late_origin_record_rejected(tmp_path):
    path = fresh_store(tmp_path / "log.bin")
    append_record(path, ls.REC_ORIGIN, b"t-log")
    with pytest.raises(ls.LogStoreError, match="origin record"):
        TransparencyLog.open(path)


def test_oversized_record_never_allocates(tmp_path):
    """A torn length prefix claiming 4 GiB must be treated as torn tail
    framing, not an allocation."""
    path = fresh_store(tmp_path / "log.bin")
    with open(path, "ab") as fh:
        fh.write(struct.pack("<BI", ls.REC_ENTRY, 0xFFFFFFFF) + b"junk")
    log = TransparencyLog.open(path)
    assert log.size == len(ENTRIES)
    log.close()


def test_sync_detects_external_divergence(tmp_path):
    """sync() audits disk against memory: an externally rewritten file (a
    second writer, a hostile edit) raises even when the file itself is a
    well-formed store."""
    path = tmp_path / "log.bin"
    log = ls.DurableTransparencyLog.open(path, "t-log")
    log.append(b"mine")
    other = tmp_path / "other.bin"
    rewrite = ls.DurableTransparencyLog.open(other, "t-log")
    rewrite.append(b"theirs")
    rewrite.close()
    path.write_bytes(other.read_bytes())
    with pytest.raises(ls.LogStoreError, match="diverge"):
        log.sync()
    log.close()


def test_replay_record_helpers_roundtrip():
    framed = ls.frame_record(ls.REC_ENTRY, b"payload", 1)
    kind, payload, end = ls._parse_record(b"\x00" + framed, 1)
    assert (kind, payload, end) == (ls.REC_ENTRY, b"payload",
                                    1 + len(framed))
    # CRC covers offset+kind+length+payload: flipping any header/payload
    # byte breaks it, and so does shifting the record to another offset
    for pos in (0, 3, 7, len(framed) - 1):
        bad = bytearray(framed)
        bad[pos] ^= 1
        assert ls._parse_record(b"\x00" + bytes(bad), 1) is None
    assert ls._parse_record(framed, 0) is None       # position-bound
    assert ls._parse_record(b"\x00\x00" + framed, 2) is None


def test_embedded_store_bytes_cannot_brick_recovery(tmp_path):
    """A torn entry whose payload IS a complete store (embedded framed
    records) must still classify as a torn tail: position-bound CRCs stop
    the embedded frames from masquerading as real records, so recovery
    truncates instead of refusing forever."""
    inner = tmp_path / "inner.bin"
    ilog = ls.DurableTransparencyLog.open(inner, "t-log")
    ilog.append(b"inner-entry")
    ilog.close()
    inner_bytes = inner.read_bytes()

    path = tmp_path / "outer.bin"
    olog = ls.DurableTransparencyLog.open(path, "t-log")
    olog.append(b"first-entry")
    olog.append(inner_bytes)          # a store's bytes as a leaf: legal
    olog.close()
    raw = path.read_bytes()
    start, _, payload, end = [s for s in record_spans(raw)
                              if s[1] == ls.REC_ENTRY][-1]
    assert payload == inner_bytes
    # tear the outer entry mid-payload, INSIDE the embedded store, leaving
    # whole embedded frames between the tear and EOF
    cut = start + 5 + len(inner_bytes) - 3
    path.write_bytes(raw[:cut])
    log = TransparencyLog.open(path)             # must not raise
    assert log.size == 1 and log.entry(0) == b"first-entry"
    assert log.recovered_bytes == cut - start
    log.append(inner_bytes)                      # the append can be redone
    assert log.entry(1) == inner_bytes
    log.sync()
    log.close()


# ---------------------------------------------------------------------------
# the durable log is a drop-in TransparencyLog for the session API
# ---------------------------------------------------------------------------
def test_publish_to_durable_log_bootstraps_verifier(tmp_path, owner, bundle,
                                                    tiny_cfg):
    from repro.core.session import ZKGraphSession
    log = TransparencyLog.open(tmp_path / "log.bin", "session-log")
    checkpoint, inclusion, raw = owner.publish_to(log)
    log.close()
    reopened = TransparencyLog.open(tmp_path / "log.bin")
    assert np.array_equal(reopened.checkpoint().root, checkpoint.root)
    v = ZKGraphSession.verifier(cfg=tiny_cfg, checkpoint=checkpoint,
                                inclusion=inclusion, manifest_bytes=raw)
    assert v.verify(bundle) is True
    reopened.close()
