"""Checkpoint-gossip adversarial coverage (repro/core/gossip.py).

The satellite paths the issue names are all here, running on every PR:
stale-checkpoint replay, consistency-proof forgery across a manifest
revision, and split-view equivocation between two peers — plus Ed25519
origin signatures, signature/version skew against the retired MAC era,
the wire envelope treating every byte as hostile, and the session
bootstrap from a gossip-pinned head.
"""
import numpy as np
import pytest

from repro.core import ed25519 as ed
from repro.core import gossip as gp
from repro.core import wire
from repro.core.session import WireFormatError, ZKGraphSession
from repro.core.transparency import (Checkpoint, ConsistencyProof,
                                     TransparencyLog)

KEY = ed.SigningKey.from_secret(b"test-origin-key")
ORIGIN = "gossip-log"


@pytest.fixture()
def log():
    log = TransparencyLog(ORIGIN)
    for i in range(6):
        log.append(b"manifest-rev-%d" % i)
    return log


@pytest.fixture()
def fork(log):
    """Same origin, same length, different history from leaf 1 on."""
    fork = TransparencyLog(ORIGIN)
    fork.append(log.entry(0))
    for i in range(1, log.size):
        fork.append(b"FORKED-rev-%d" % i)
    return fork


def pinned_peer(log, size=3):
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    cp = log.checkpoint(size)
    assert peer.offer(gp.GossipMessage(cp, None, KEY.pub,
                                       gp.sign_checkpoint(KEY, cp)))
    return peer


def msg_at(log, size, since=None):
    cp = log.checkpoint(size)
    proof = log.consistency_proof(since, size) if since else None
    return gp.GossipMessage(cp, proof, KEY.pub, gp.sign_checkpoint(KEY, cp))


# ---------------------------------------------------------------------------
# head pinning and advancement
# ---------------------------------------------------------------------------
def test_bootstrap_then_advance_with_proof(log):
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    with pytest.raises(gp.GossipError, match="no pinned head"):
        peer.pinned
    assert peer.offer(msg_at(log, 2)) is True
    assert peer.pinned.tree_size == 2
    assert peer.offer(msg_at(log, 5, since=2)) is True
    assert peer.pinned.tree_size == 5
    assert np.array_equal(peer.pinned.root, log.root(5))


def test_advance_without_proof_is_demanded_not_accepted(log):
    peer = pinned_peer(log, 3)
    with pytest.raises(gp.ConsistencyRequired):
        peer.offer(msg_at(log, 6))
    assert peer.pinned.tree_size == 3          # head unchanged
    # a proof for the WRONG span is demanded again, not misused
    with pytest.raises(gp.ConsistencyRequired, match="links 2 -> 6"):
        peer.offer(msg_at(log, 6, since=2))
    assert peer.offer(msg_at(log, 6, since=3)) is True


def test_duplicate_head_is_a_noop(log):
    peer = pinned_peer(log, 4)
    assert peer.offer(msg_at(log, 4)) is False
    assert peer.pinned.tree_size == 4


def test_empty_checkpoint_rejected(log):
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    cp = Checkpoint(ORIGIN, 0, log.root(0))
    with pytest.raises(gp.GossipError, match="size-0"):
        peer.offer(gp.GossipMessage(cp, None, KEY.pub,
                                    gp.sign_checkpoint(KEY, cp)))


def test_cross_origin_head_rejected(log):
    peer = gp.GossipPeer("other-log", KEY.pub)
    with pytest.raises(gp.GossipError, match="pinned on"):
        peer.offer(msg_at(log, 2))


# ---------------------------------------------------------------------------
# stale-checkpoint replay
# ---------------------------------------------------------------------------
def test_stale_replay_never_regresses_the_head(log):
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    peer.offer(msg_at(log, 2))
    peer.offer(msg_at(log, 5, since=2))
    # replaying both an already-seen and a never-seen older checkpoint
    assert peer.offer(msg_at(log, 2)) is False
    assert peer.offer(msg_at(log, 4)) is False
    assert peer.pinned.tree_size == 5


def test_stale_replay_that_contradicts_history_is_equivocation(log, fork):
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    peer.offer(msg_at(log, 3))
    peer.offer(msg_at(log, 6, since=3))
    with pytest.raises(gp.EquivocationError) as exc:
        peer.offer(msg_at(fork, 3))            # same size 3, forked root
    assert exc.value.pinned.tree_size == exc.value.offered.tree_size == 3
    assert np.array_equal(exc.value.pinned.root, log.root(3))
    assert np.array_equal(exc.value.offered.root, fork.root(3))


# ---------------------------------------------------------------------------
# consistency-proof forgery across a manifest revision
# ---------------------------------------------------------------------------
def test_forged_consistency_proof_raises_equivocation(log):
    peer = pinned_peer(log, 3)
    honest = log.consistency_proof(3, 6)
    for row in range(honest.path.shape[0]):
        forged_path = honest.path.copy()
        forged_path[row, 0] ^= 1
        cp6 = log.checkpoint(6)
        forged = gp.GossipMessage(cp6, ConsistencyProof(3, 6, forged_path),
                                  KEY.pub, gp.sign_checkpoint(KEY, cp6))
        with pytest.raises(gp.EquivocationError, match="does not extend"):
            peer.offer(forged)
        assert peer.pinned.tree_size == 3      # alarm, no state change


def test_forked_head_with_its_own_valid_proof_is_equivocation(log, fork):
    """The fork CAN prove its own 3 -> 6 consistency — but not against the
    peer's honestly-pinned head, whose root differs at size 3... and when
    sizes collide exactly, the split view fires first."""
    peer = pinned_peer(log, 3)
    forked = gp.GossipMessage(fork.checkpoint(6),
                              fork.consistency_proof(3, 6), KEY.pub,
                              gp.sign_checkpoint(KEY, fork.checkpoint(6)))
    with pytest.raises(gp.EquivocationError):
        peer.offer(forked)
    evidence = None
    try:
        peer.offer(forked)
    except gp.EquivocationError as e:
        evidence = e
    assert evidence.pinned.tree_size == 3      # both heads attached
    assert evidence.offered.tree_size == 6


# ---------------------------------------------------------------------------
# split-view equivocation between two peers (the acceptance criterion)
# ---------------------------------------------------------------------------
def test_split_view_between_two_peers_raises_with_both_checkpoints(log,
                                                                   fork):
    """Two GossipPeers fed conflicting heads for the same tree size: the
    moment they gossip with each other, EquivocationError fires carrying
    both conflicting checkpoints as evidence."""
    v1 = pinned_peer(log, 6)                   # honest view
    v2 = pinned_peer(fork, 6)                  # the owner's forked view
    with pytest.raises(gp.EquivocationError) as exc:
        v1.gossip_with(v2)
    assert exc.value.pinned.tree_size == exc.value.offered.tree_size == 6
    roots = {exc.value.pinned.root.tobytes(),
             exc.value.offered.root.tobytes()}
    assert roots == {np.asarray(log.root(6), np.uint32).tobytes(),
                     np.asarray(fork.root(6), np.uint32).tobytes()}
    # and the direction is symmetric
    with pytest.raises(gp.EquivocationError):
        v2.gossip_with(v1)


def test_agreeing_peers_gossip_without_advance(log):
    v1 = pinned_peer(log, 6)
    v2 = pinned_peer(log, 6)
    assert v1.gossip_with(v2) is False


def test_behind_peer_keeps_pin_until_proof_arrives(log):
    """gossip_with between peers at different sizes must not regress or
    blind-advance: the behind peer demands a proof (swallowed as
    non-conflicting), then advances when the owner supplies one."""
    ahead = pinned_peer(log, 6)
    behind = pinned_peer(log, 3)
    assert ahead.gossip_with(behind) is False
    assert behind.pinned.tree_size == 3
    assert behind.offer(msg_at(log, 6, since=3)) is True
    assert behind.gossip_with(ahead) is False  # now in agreement


# ---------------------------------------------------------------------------
# origin signatures (Ed25519 over canonical checkpoint bytes)
# ---------------------------------------------------------------------------
def test_bad_or_missing_signature_rejected(log):
    peer = gp.GossipPeer(ORIGIN, KEY.pub)
    cp = log.checkpoint(2)
    other = ed.SigningKey.from_secret(b"not-the-key")
    # a relay re-signing under its own (honestly-named) key: wrong signer
    with pytest.raises(gp.GossipError, match="unexpected key"):
        peer.offer(gp.GossipMessage(cp, None, other.pub,
                                    gp.sign_checkpoint(other, cp)))
    # naming the origin's key but signing with another: bad signature
    with pytest.raises(gp.GossipError, match="signature"):
        peer.offer(gp.GossipMessage(cp, None, KEY.pub,
                                    gp.sign_checkpoint(other, cp)))
    tampered = bytearray(gp.sign_checkpoint(KEY, cp))
    tampered[0] ^= 1
    with pytest.raises(gp.GossipError, match="signature"):
        peer.offer(gp.GossipMessage(cp, None, KEY.pub, bytes(tampered)))
    with pytest.raises(gp.GossipError, match="signature"):
        peer.offer(gp.GossipMessage(cp, None, KEY.pub, b"\x00" * 64))


def test_signature_binds_the_exact_checkpoint(log):
    cp2, cp3 = log.checkpoint(2), log.checkpoint(3)
    sig2 = gp.sign_checkpoint(KEY, cp2)
    assert gp.verify_signature(KEY.pub, cp2, sig2)
    assert not gp.verify_signature(KEY.pub, cp3, sig2)       # size swap
    assert not gp.verify_signature(KEY.pub, Checkpoint(
        "other-log", cp2.tree_size, cp2.root), sig2)         # origin swap
    assert not gp.verify_signature(KEY.pub, cp2, None)
    assert not gp.verify_signature(
        ed.SigningKey.from_secret(b"other").pub, cp2, sig2)


def test_signature_domain_separated_from_leaf_hash_and_mac(log):
    """The signed bytes are 0x03 || checkpoint — a signature over the bare
    checkpoint bytes (or any other domain) must not verify."""
    cp = log.checkpoint(2)
    for prefix in (b"", b"\x00", b"\x02"):
        wrong_domain = KEY.sign(prefix + cp.to_bytes())
        assert not gp.verify_signature(KEY.pub, cp, wrong_domain)
    assert gp.verify_signature(KEY.pub, cp, KEY.sign(b"\x03" + cp.to_bytes()))


def test_keyless_peer_skips_signature_but_still_detects_equivocation(
        log, fork):
    """signer=None models a pre-authenticated transport: signature checks
    are skipped, the split-view alarm is not."""
    peer = gp.GossipPeer(ORIGIN, signer=None)
    junk = b"\x00" * ed.SIGNATURE_LEN
    assert peer.offer(gp.GossipMessage(log.checkpoint(3), None,
                                       b"\x00" * 32, junk))
    with pytest.raises(gp.EquivocationError):
        peer.offer(gp.GossipMessage(fork.checkpoint(3), None,
                                    b"\x00" * 32, junk))


def test_signing_requires_a_signing_key(log):
    with pytest.raises(gp.GossipError, match="SigningKey"):
        gp.sign_checkpoint(b"raw-secret-bytes", log.checkpoint(2))
    with pytest.raises(gp.GossipError, match="32 bytes"):
        gp.GossipPeer(ORIGIN, b"short-key")


# ---------------------------------------------------------------------------
# signature/version skew: the MAC era fails closed by name
# ---------------------------------------------------------------------------
def _mac_era_bytes(log):
    """Bytes shaped like the retired v2 kind-8 envelope: v2 header, kind 8,
    embedded checkpoint, no-consistency flag, (8,) uint32 MAC field."""
    e = wire._Enc()
    e.buf += wire.MAGIC
    e.u16(2)                                   # WIRE_VERSION of the MAC era
    e.u8(8)                                    # retired KIND_GOSSIP
    e.u8(wire._F_G_CHECKPOINT)
    cp_raw = log.checkpoint(3).to_bytes()
    e.u32(len(cp_raw))
    e.buf += cp_raw
    e.u8(wire._F_G_CONSIST)
    e.u8(0)
    e.u8(0x82)                                 # the retired MAC field tag
    e.array(np.arange(8, dtype=np.uint32))
    return bytes(e.buf)


def test_mac_era_message_to_signed_era_peer_fails_closed(log):
    """A v2 MAC-era gossip message offered to a signed-era peer dies in the
    codec with a typed error — version first, so not a byte is interpreted."""
    with pytest.raises(WireFormatError, match="unsupported wire version"):
        gp.GossipMessage.from_bytes(_mac_era_bytes(log))


def test_retired_gossip_kind_rejected_by_name(log):
    """Kind 8 under the CURRENT version (an upgraded relay replaying an old
    envelope shape) is named as the retired MAC era, not a generic kind
    mismatch — and no decoder resurrects it."""
    raw = bytearray(_mac_era_bytes(log))
    raw[4:6] = wire.WIRE_VERSION.to_bytes(2, "little")
    with pytest.raises(WireFormatError, match="retired MAC-era"):
        gp.GossipMessage.from_bytes(bytes(raw))
    with pytest.raises(WireFormatError, match="retired MAC-era"):
        wire.decode_checkpoint(bytes(raw))


def test_signed_era_message_to_mac_era_peer_fails_closed(log):
    """The reverse skew: today's kind-9 bytes presented to a decoder
    expecting the old kind (simulated by re-tagging the header) mismatch
    on the kind byte — a v2 peer would already have failed on version."""
    raw = gp.emit(log, KEY).to_bytes()
    kind_at = len(wire.MAGIC) + 2
    assert raw[kind_at] == wire.KIND_GOSSIP
    with pytest.raises(WireFormatError, match="payload kind"):
        wire.decode_checkpoint(raw)            # kind 9 where 5 expected
    v2 = bytearray(raw)
    v2[4:6] = (2).to_bytes(2, "little")        # what a v2 peer would see
    with pytest.raises(WireFormatError, match="unsupported wire version"):
        gp.GossipMessage.from_bytes(bytes(v2))


# ---------------------------------------------------------------------------
# the wire envelope (kind 9) treats every byte as hostile
# ---------------------------------------------------------------------------
def test_gossip_message_roundtrip_canonical(log):
    for msg in (gp.emit(log, KEY), gp.emit(log, KEY, since=2)):
        raw = msg.to_bytes()
        rt = gp.GossipMessage.from_bytes(raw)
        assert rt.to_bytes() == raw
        assert rt.checkpoint.to_bytes() == msg.checkpoint.to_bytes()
        assert (rt.consistency is None) == (msg.consistency is None)
        if rt.consistency is not None:
            assert rt.consistency.to_bytes() == msg.consistency.to_bytes()
        assert rt.signer == KEY.pub
        assert rt.signature == msg.signature
        assert gp.verify_signature(rt.signer, rt.checkpoint, rt.signature)


def test_gossip_wire_truncation_and_trailing_rejected(log):
    raw = gp.emit(log, KEY, since=2).to_bytes()
    header = len(wire.MAGIC) + 3
    for cut in (0, 3, header - 1, header, header + 4, len(raw) // 2,
                len(raw) - 1):
        with pytest.raises(WireFormatError):
            gp.GossipMessage.from_bytes(raw[:cut])
    with pytest.raises(WireFormatError):
        gp.GossipMessage.from_bytes(raw + b"\x00")


def test_gossip_wire_kind_confusion_rejected(log):
    with pytest.raises(WireFormatError):
        gp.GossipMessage.from_bytes(log.checkpoint().to_bytes())
    with pytest.raises(WireFormatError):
        from repro.core.transparency import Checkpoint as CP
        CP.from_bytes(gp.emit(log, KEY).to_bytes())


def test_gossip_wire_non_canonical_flag_rejected(log):
    raw = bytearray(gp.emit(log, KEY).to_bytes())
    # the consistency flag byte follows the embedded checkpoint message
    cp_len = len(log.checkpoint().to_bytes())
    flag_at = len(wire.MAGIC) + 3 + 1 + 4 + cp_len + 1
    assert raw[flag_at] == 0
    raw[flag_at] = 2
    with pytest.raises(WireFormatError, match="flag"):
        gp.GossipMessage.from_bytes(bytes(raw))


def test_gossip_wire_embedded_message_validated(log):
    """The embedded checkpoint passes through decode_checkpoint wholesale:
    corrupting its inner bytes fails the inner decoder."""
    msg = gp.emit(log, KEY)
    raw = bytearray(msg.to_bytes())
    raw[len(wire.MAGIC) + 3 + 1 + 4] ^= 0xFF    # embedded MAGIC byte
    with pytest.raises(WireFormatError):
        gp.GossipMessage.from_bytes(bytes(raw))


def test_gossip_wire_byte_flip_fuzz_never_crashes(log):
    raw = gp.emit(log, KEY, since=3).to_bytes()
    rng = np.random.default_rng(7)
    peer = pinned_peer(log, 3)
    for pos in rng.integers(0, len(raw), size=64):
        flipped = bytearray(raw)
        flipped[pos] ^= 0x10
        try:
            msg = gp.GossipMessage.from_bytes(bytes(flipped))
        except WireFormatError:
            continue
        # survived the codec: the peer must still fail closed (bad
        # signature, bad proof, or equivocation) or accept a byte-identical
        # message
        try:
            peer.offer(msg)
        except gp.GossipError:
            pass
        assert peer.pinned.tree_size in (3, 6)


def test_signed_envelope_flip_fuzz_over_signature_fields(log):
    """Hostile-bytes flip fuzz targeted at the signer + signature tail of
    the new envelope: every flip either dies in the codec or fails
    signature verification — no flipped head is ever accepted."""
    raw = gp.emit(log, KEY).to_bytes()
    tail = len(raw) - (1 + ed.PUBLIC_KEY_LEN + 1 + ed.SIGNATURE_LEN)
    for pos in range(tail, len(raw)):
        for bit in (0x01, 0x80):
            flipped = bytearray(raw)
            flipped[pos] ^= bit
            peer = gp.GossipPeer(ORIGIN, KEY.pub)
            try:
                msg = gp.GossipMessage.from_bytes(bytes(flipped))
            except WireFormatError:
                continue               # flipped a field tag: codec rejects
            with pytest.raises(gp.GossipError):
                peer.offer(msg)
            assert peer.head is None


def test_oversized_embed_rejected():
    e = wire._Enc()
    e.buf += wire.MAGIC
    e.u16(wire.WIRE_VERSION)
    e.u8(wire.KIND_GOSSIP)
    e.u8(wire._F_G_CHECKPOINT)
    e.u32(wire.MAX_EMBED + 1)
    e.buf += b"\x00" * 64
    with pytest.raises(WireFormatError, match="embedded"):
        wire.decode_gossip_message(bytes(e.buf))


# ---------------------------------------------------------------------------
# session bootstrap from a gossip-pinned head
# ---------------------------------------------------------------------------
def test_verifier_bootstraps_from_gossip_pinned_head(owner, bundle,
                                                     tiny_cfg):
    log = TransparencyLog("session-gossip-log")
    checkpoint, inclusion, raw = owner.publish_to(log)
    peer = gp.GossipPeer("session-gossip-log", KEY.pub)
    peer.offer(gp.GossipMessage(checkpoint, None, KEY.pub,
                                gp.sign_checkpoint(KEY, checkpoint)))
    v = ZKGraphSession.verifier(cfg=tiny_cfg, gossip=peer,
                                inclusion=inclusion, manifest_bytes=raw)
    assert v.verify(bundle) is True


def test_verifier_gossip_bootstrap_fails_closed(owner, tiny_cfg):
    log = TransparencyLog("session-gossip-log")
    checkpoint, inclusion, raw = owner.publish_to(log)
    empty = gp.GossipPeer("session-gossip-log", KEY.pub)
    with pytest.raises(gp.GossipError, match="no pinned head"):
        ZKGraphSession.verifier(cfg=tiny_cfg, gossip=empty,
                                inclusion=inclusion, manifest_bytes=raw)
    pinned = gp.GossipPeer("session-gossip-log", KEY.pub)
    pinned.offer(gp.GossipMessage(checkpoint, None, KEY.pub,
                                  gp.sign_checkpoint(KEY, checkpoint)))
    with pytest.raises(TypeError, match="not both"):
        ZKGraphSession.verifier(cfg=tiny_cfg, gossip=pinned,
                                checkpoint=checkpoint, inclusion=inclusion,
                                manifest_bytes=raw)
