"""Serving-layer tests: the lane-batched prover's bit-identity contract,
ProofService end-to-end equivalence with the sequential session, the
thread-safe single-flight keygen cache, and the pipeline mechanics.

The load-bearing property: a proof produced inside a batch is WIRE-BYTE-
IDENTICAL to the same witness proved solo (timings excluded — they are
host telemetry).  Everything the service does — shape routing, lane
padding, deadline flushing — must be invisible in the artifact.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import backend as be
from repro.core import prover as pv
from repro.core.session import KeygenCache, ZKGraphSession
from repro.core.transcript import BatchedTranscript, Transcript
from repro.serve import (Histogram, ProofService, ServiceClosed, ShapeBatcher,
                         Stage, StepSlot)

PARITY = ["ref", "pallas-interpret"]


def _canonical_proof(proof) -> bytes:
    proof.timings = {}
    return proof.to_bytes()


def _canonical_bundle(bundle) -> bytes:
    for sp in bundle.steps:
        sp.proof.timings = {}
    return bundle.to_bytes()


# ---------------------------------------------------------------------------
# batched transcript: lockstep lanes == solo transcripts
# ---------------------------------------------------------------------------
def test_batched_transcript_matches_solo_lanes():
    rng = np.random.default_rng(5)
    lane_vals = [rng.integers(0, 2**31, size=13) for _ in range(3)]
    shared = rng.integers(0, 2**31, size=9)

    solos = []
    for vals in lane_vals:
        tx = Transcript("lanes-test")
        tx.absorb(shared)
        tx.absorb(vals)
        solos.append(tx)
    btx = BatchedTranscript("lanes-test", lanes=3)
    btx.absorb_shared(shared)
    btx.absorb(np.stack(lane_vals))

    ch = btx.challenge_ext()
    for l, tx in enumerate(solos):
        np.testing.assert_array_equal(ch[l], tx.challenge_ext())
    idx = btx.challenge_indices(7, 64)
    for l, tx in enumerate(solos):
        np.testing.assert_array_equal(idx[l], tx.challenge_indices(7, 64))


# ---------------------------------------------------------------------------
# lane-batched prover: bit-identity with the solo prover
# ---------------------------------------------------------------------------
def test_prove_batch_bytes_match_solo(owner):
    """Two IS5 queries: batch their (same-shaped) steps in one prove_batch
    pass and require byte equality with solo proves, lane by lane."""
    runs = [owner.run_query("IS5", dict(message=(1 << 20) + m))
            for m in (3, 9)]
    steps = [st for run in runs for st in run.steps]
    key0 = owner.step_shape_key(steps[0])
    assert all(owner.step_shape_key(st) == key0 for st in steps[1:])

    solo = [_canonical_proof(owner.prove_step(st).proof) for st in steps]
    batched = owner.prove_steps(steps)
    assert len(batched) == len(steps)
    for sp_solo, sp_batch in zip(solo, batched):
        assert _canonical_proof(sp_batch.proof) == sp_solo


def test_prove_steps_rejects_mixed_shapes(owner):
    st_is5 = owner.run_query("IS5", dict(message=(1 << 20) + 3)).steps[0]
    st_is4 = owner.run_query("IS4", dict(message=(1 << 20) + 3)).steps[0]
    if owner.step_shape_key(st_is4) == owner.step_shape_key(st_is5):
        pytest.skip("IS4/IS5 share a circuit shape at this size")
    with pytest.raises(AssertionError):
        owner.prove_steps([st_is5, st_is4])


def test_prove_steps_single_lane_degrades_to_solo(owner):
    st = owner.run_query("IS5", dict(message=(1 << 20) + 5)).steps[0]
    sp = owner.prove_steps([st])[0]
    assert _canonical_proof(sp.proof) == \
        _canonical_proof(owner.prove_step(st).proof)


def test_batched_proofs_verify(owner):
    """Step proofs from a batch pass the solo verifier (full-bundle
    verification through the service is covered below)."""
    runs = [owner.run_query("IS5", dict(message=(1 << 20) + m))
            for m in (11, 15)]
    steps = [st for run in runs for st in run.steps]
    sps = owner.prove_steps(steps)
    for st, sp in zip(steps, sps):
        assert st.op.verify(sp.instance, sp.proof)


# ---------------------------------------------------------------------------
# ProofService: concurrent serving == sequential session, byte for byte
# ---------------------------------------------------------------------------
def _query_mix(seed: int, n: int):
    """A deterministic 'random' mix of single-step LDBC short reads."""
    rng = np.random.default_rng(seed)
    mix = []
    for _ in range(n):
        kind = ["IS5", "IS4"][int(rng.integers(0, 2))]
        mix.append((kind, dict(message=(1 << 20) + int(rng.integers(0, 32)))))
    return mix


def _serve_and_compare(db, owner, cfg, queries, **svc_kw):
    seq = ZKGraphSession(db, cfg, commitments=owner.commitments)
    expected = [_canonical_bundle(seq.prove(q, p)) for q, p in queries]

    svc_session = ZKGraphSession(db, cfg, commitments=owner.commitments)
    with ProofService(svc_session, **svc_kw) as svc:
        futs = [svc.submit(q, p) for q, p in queries]
        got = [f.result(timeout=600) for f in futs]
        stats = svc.stats()
    for bundle, raw in zip(got, expected):
        assert _canonical_bundle(bundle) == raw
    return got, stats


def test_service_bundles_wire_identical_ref(db, owner, tiny_cfg, verifier):
    queries = _query_mix(seed=7, n=5)
    bundles, stats = _serve_and_compare(
        db, owner, tiny_cfg, queries, max_batch=4, flush_interval=0.1)
    assert stats["counters"]["completed"] == len(queries)
    assert stats["counters"]["failed"] == 0
    # batching actually happened: fewer prove batches than queries
    assert stats["counters"]["batches"] < len(queries)
    assert stats["batch_occupancy"]["max"] >= 2
    for bundle in bundles:
        assert verifier.verify(bundle)


@pytest.mark.slow
def test_service_bundles_wire_identical_both_backends(db, owner, tiny_cfg):
    """The cross-backend property: for a random query mix, served bundles
    are wire-byte-identical to sequential proves under BOTH the ref and the
    pallas-interpret backend (and therefore to each other)."""
    queries = _query_mix(seed=13, n=3)
    per_backend = {}
    for name in PARITY:
        cfg = dataclasses.replace(tiny_cfg, backend=name)
        bundles, stats = _serve_and_compare(
            db, owner, cfg, queries, max_batch=4, flush_interval=0.1)
        assert stats["counters"]["failed"] == 0
        per_backend[name] = [_canonical_bundle(b) for b in bundles]
    # cfg.backend is compare=False metadata, so the encodings must agree
    assert per_backend["ref"] == per_backend["pallas-interpret"]


def test_service_error_isolated_to_one_query(db, owner, tiny_cfg):
    session = ZKGraphSession(db, tiny_cfg, commitments=owner.commitments)
    with ProofService(session, max_batch=2, flush_interval=0.05) as svc:
        bad = svc.submit("NO_SUCH_QUERY", {})
        good = svc.submit("IS5", dict(message=(1 << 20) + 7))
        with pytest.raises(KeyError):
            bad.result(timeout=600)
        assert good.result(timeout=600).query == "IS5"
    stats = svc.stats()
    assert stats["counters"]["failed"] == 1
    assert stats["counters"]["completed"] == 1


def test_service_rejects_after_close(db, owner, tiny_cfg):
    session = ZKGraphSession(db, tiny_cfg, commitments=owner.commitments)
    svc = ProofService(session)
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit("IS5", dict(message=3))
    svc.close()     # idempotent


def test_service_metrics_schema(db, owner, tiny_cfg):
    session = ZKGraphSession(db, tiny_cfg, commitments=owner.commitments)
    with ProofService(session, max_batch=2, flush_interval=0.05) as svc:
        svc.submit("IS5", dict(message=(1 << 20) + 2)).result(timeout=600)
        stats = svc.stats()
    # the documented schema (docs/serving.md) — exact top-level keys
    assert set(stats) == {"counters", "phase_us", "queue_wait_us",
                          "prove_us", "batch_occupancy", "keygen_cache",
                          "depths"}
    assert set(stats["counters"]) == {"submitted", "completed", "failed",
                                      "batches", "lanes", "pad_lanes"}
    assert {"fri", "total"} <= set(stats["phase_us"])
    for stat in (stats["phase_us"]["total"], stats["queue_wait_us"],
                 stats["batch_occupancy"]):
        assert set(stat) == {"count", "mean", "p50", "p95", "max"}
    assert set(stats["keygen_cache"]) == {"hits", "misses", "waits",
                                          "entries"}


# ---------------------------------------------------------------------------
# shape batcher + pipeline mechanics (no proving)
# ---------------------------------------------------------------------------
def _slot(key="k"):
    return StepSlot(ticket=None, pos=0, step=key)


def test_batcher_flushes_on_size():
    b = ShapeBatcher(max_batch=3, flush_interval=999)
    assert b.add("a", _slot()) is None
    assert b.add("b", _slot()) is None      # different shape: own queue
    assert b.add("a", _slot()) is None
    ready = b.add("a", _slot())
    assert ready is not None and ready.key == "a" and len(ready.slots) == 3
    assert b.depth() == 1                   # "b" still waiting


def test_batcher_flushes_on_deadline():
    b = ShapeBatcher(max_batch=8, flush_interval=0.01)
    b.add("a", _slot())
    assert b.take_expired(now=time.monotonic()) == [] or True  # not yet due
    time.sleep(0.02)
    ready = b.take_expired()
    assert len(ready) == 1 and len(ready[0].slots) == 1
    assert b.depth() == 0


def test_batcher_drain():
    b = ShapeBatcher(max_batch=8, flush_interval=999)
    b.add("a", _slot())
    b.add("b", _slot())
    assert sorted(r.key for r in b.drain()) == ["a", "b"]
    assert b.depth() == 0


def test_stage_backpressure_and_error_isolation():
    done, errs = [], []
    gate = threading.Event()

    def handler(item):
        gate.wait(5)
        done.append(item)
        if item == "bad":
            raise ValueError(item)

    stage = Stage("t", handler, maxsize=1,
                  on_error=lambda item, e: errs.append(item))
    stage.start()
    stage.put("bad")            # worker picks it up, blocks on gate
    time.sleep(0.05)
    stage.put("ok")             # fills the 1-slot inbox
    with pytest.raises(Exception):
        stage.inbox.put("overflow", timeout=0.05)   # backpressure: full
    gate.set()
    stage.stop(wait=True)
    assert done == ["bad", "ok"] and errs == ["bad"]


def test_histogram_percentiles():
    h = Histogram(max_samples=100)
    for v in range(1, 101):
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["max"] == 100.0
    assert 45 <= snap["p50"] <= 55 and 90 <= snap["p95"] <= 100


# ---------------------------------------------------------------------------
# thread-safe keygen cache: single-flight misses, thread-local backend scopes
# ---------------------------------------------------------------------------
def _tiny_op():
    from repro.core.operators import registry
    return registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))


def test_keygen_cache_single_flight(tiny_cfg, monkeypatch):
    """N threads demand the same missing key at once: keygen runs once,
    everyone else blocks on the leader and shares its Keys."""
    calls = []
    real_keygen = pv.keygen

    def slow_keygen(circuit, cfg):
        calls.append(threading.get_ident())
        time.sleep(0.1)                     # widen the race window
        return real_keygen(circuit, cfg)

    monkeypatch.setattr(pv, "keygen", slow_keygen)
    cache = KeygenCache()
    results, failures = [], []

    def worker():
        try:
            results.append(cache.ensure(_tiny_op(), tiny_cfg).keys)
        except BaseException as exc:        # pragma: no cover
            failures.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not failures
    assert len(calls) == 1, "keygen must be single-flight per key"
    assert all(keys is results[0] for keys in results)
    stats = cache.stats()
    assert stats["misses"] == 1 and stats["entries"] == 1
    assert stats["waits"] >= 1              # someone actually blocked


def test_keygen_cache_leader_failure_reelects(tiny_cfg, monkeypatch):
    """A failing leader must not strand its waiters: they re-elect and one
    of them completes the keygen."""
    real_keygen = pv.keygen
    state = dict(first=True)
    barrier = threading.Barrier(2)

    def flaky_keygen(circuit, cfg):
        if state.pop("first", False):
            barrier.wait(5)                 # ensure a waiter is parked
            time.sleep(0.05)
            raise RuntimeError("injected keygen failure")
        return real_keygen(circuit, cfg)

    monkeypatch.setattr(pv, "keygen", flaky_keygen)
    cache = KeygenCache()
    outcomes = []

    def worker(first):
        try:
            if not first:
                barrier.wait(5)
            outcomes.append(cache.ensure(_tiny_op(), tiny_cfg).keys)
        except RuntimeError as exc:
            outcomes.append(exc)

    t1 = threading.Thread(target=worker, args=(True,))
    t2 = threading.Thread(target=worker, args=(False,))
    t1.start()
    time.sleep(0.02)
    t2.start()
    t1.join()
    t2.join()
    kinds = sorted(type(o).__name__ for o in outcomes)
    assert kinds == ["Keys", "RuntimeError"]
    assert cache.stats()["entries"] == 1


def test_backend_scopes_are_thread_local():
    """A be.use() scope on one thread must not leak into another — worker
    threads pin their own backend explicitly (ProofService does)."""
    seen = {}

    def probe():
        seen["worker"] = be.active_name()

    with be.use("pallas-interpret"):
        t = threading.Thread(target=probe)
        t.start()
        t.join()
        assert be.active_name() == "pallas-interpret"
    assert seen["worker"] != "pallas-interpret"


def test_lde_cache_concurrent_access(db, tiny_cfg):
    """Concurrent ensure() against one shared session cache (the service's
    real access pattern) keeps the fixed-LDE caches consistent: every
    thread ends up with the same Keys object per shape."""
    session = ZKGraphSession(db, tiny_cfg)
    st = session.run_query("IS5", dict(message=(1 << 20) + 3)).steps[0]
    solo_keys = pv.keygen(st.op.circuit, tiny_cfg)
    got = []

    def worker():
        got.append(session.cache.ensure(st.op, session.cfg).keys)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(k is got[0] for k in got)
    np.testing.assert_array_equal(np.asarray(got[0].fixed_lde),
                                  np.asarray(solo_keys.fixed_lde))
