"""Session-API tests: prove/verify bundles, the keygen cache, bundle
serialization, the base-table commitment soundness fix, and the
manifest-pinned circuit geometry (shared fixtures live in conftest.py)."""
import warnings

import numpy as np
import pytest

from repro.core import planner
from repro.core import prover as pv
from repro.core.commit import CommitmentManifest
from repro.core.session import (KeygenCache, MissingCommitmentError,
                                ProofBundle, ZKGraphSession,
                                circuit_shape_digest)
from repro.graphdb import ldbc


def test_prove_verify_roundtrip(bundle, verifier):
    assert verifier.verify(bundle)


@pytest.mark.slow
def test_ic1_chain_verifies(db, owner, verifier):
    """IC1 exercises every adapter kind incl. the NameFilter chained step."""
    name = int(db.node_props["person"]["firstName"][0])
    b = owner.prove("IC1", dict(person=2, firstName=name))
    assert verifier.verify(b)


def test_bundle_serialization_roundtrip(bundle, verifier):
    rt = ProofBundle.from_bytes(bundle.to_bytes())
    assert rt.query == bundle.query and rt.params == bundle.params
    assert verifier.verify(rt)


@pytest.mark.slow
def test_wrong_dataset_rejected(bundle, verifier, tiny_cfg):
    db2 = ldbc.generate(n_knows=96, n_persons=24, n_comments=64, seed=99)
    bad = ZKGraphSession(db2, tiny_cfg).commitments
    assert not verifier.verify(bundle, commitments=bad)


def test_cfg_mismatch_rejected(bundle, owner):
    stricter = ZKGraphSession.verifier(
        owner.commitments, pv.ProverConfig(blowup=4, n_queries=32,
                                           fri_final_size=16))
    assert not stricter.verify(bundle)


# ---------------------------------------------------------------------------
# keygen cache
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_keygen_cache_once_per_shape(db, tiny_cfg):
    """Proving the same query twice in one session performs keygen at most
    once per distinct circuit shape (the seed re-ran it per step per query)."""
    session = ZKGraphSession(db, tiny_cfg)
    session.prove("IS5", dict(message=(1 << 20) + 7))
    misses_after_first = session.cache.misses
    assert misses_after_first >= 1
    session.prove("IS5", dict(message=(1 << 20) + 7))
    assert session.cache.misses == misses_after_first
    assert session.cache.hits >= 1
    # distinct shapes in one plan each get exactly one keygen
    session.prove("IS3", dict(person=3))
    entries = len(session.cache.entries)
    session.prove("IS3", dict(person=3))
    assert len(session.cache.entries) == entries


def test_shape_digest_separates_circuits(tiny_cfg):
    from repro.core.operators import registry
    a = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))
    b = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=True))
    c = registry.build_operator("expand", dict(
        n_rows=32, m_edges=24, with_prop=False, reverse=False))
    d = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))
    assert circuit_shape_digest(a.circuit) == circuit_shape_digest(d.circuit)
    assert circuit_shape_digest(a.circuit) != circuit_shape_digest(c.circuit)
    cache = KeygenCache()
    cache.ensure(a, tiny_cfg)
    cache.ensure(b, tiny_cfg)   # different circuit name -> miss
    cache.ensure(c, tiny_cfg)   # different fixed columns -> miss
    cache.ensure(d, tiny_cfg)   # identical shape -> hit
    assert cache.stats() == dict(hits=1, misses=3, waits=0, entries=3)
    assert d.keys is a.keys


def test_shape_digest_memoized_and_invalidated():
    """The SHA-256 over all fixed-column bytes is paid once per circuit;
    structural mutations (e.g. keygen's __row0 column) invalidate the memo
    so the digest never goes stale."""
    from repro.core.operators import registry
    op = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))
    c = op.circuit
    first = circuit_shape_digest(c)
    assert c._shape_digest == first          # memo populated
    assert circuit_shape_digest(c) == first  # hit returns identical value
    c.add_fixed("extra", np.arange(4))
    assert c._shape_digest is None           # mutation invalidates
    assert circuit_shape_digest(c) != first  # and the digest really differs


# ---------------------------------------------------------------------------
# soundness: base tables must be bound to *published* commitments
# ---------------------------------------------------------------------------
def test_missing_base_commitment_raises(bundle, owner, verifier):
    partial = owner.commitments.drop("hasCreator")
    with pytest.raises(MissingCommitmentError):
        verifier.verify(bundle, commitments=partial)


def test_verify_requires_manifest(bundle, owner, verifier):
    """A bare {(desc, n_rows): root} dict has no published geometry, so the
    verifier refuses it loudly instead of silently skipping the shape pins."""
    with pytest.raises(TypeError):
        verifier.verify(bundle, commitments=dict(owner.commitments.items()))


@pytest.mark.slow
def test_legacy_verify_missing_commitment_fails(db, tiny_cfg):
    """The seed silently recomputed a missing base-table root from
    prover-supplied data — which accepts proofs over a *never-published*
    dataset. It must reject instead."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        run = planner.plan_query(db, "IS5", dict(message=(1 << 20) + 7))
        proofs = planner.prove_query(run, tiny_cfg)
        commitments = planner.publish_commitments(db, tiny_cfg)
        assert planner.verify_query(run, proofs, commitments, tiny_cfg)
        partial = {k: v for k, v in commitments.items()
                   if k[0] != "hasCreator"}
        assert not planner.verify_query(run, proofs, partial, tiny_cfg)
        # chained steps stay verifiable without a published entry
        run3 = planner.plan_query(db, "IS3", dict(person=3))
        proofs3 = planner.prove_query(run3, tiny_cfg)
        assert planner.verify_query(run3, proofs3, commitments, tiny_cfg)
        # a truncated (or empty) proof list must not pass by zip-truncation
        assert not planner.verify_query(run3, proofs3[:1], commitments,
                                        tiny_cfg)
        assert not planner.verify_query(run3, [], commitments, tiny_cfg)


def test_data_desc_substitution_rejected(bundle, owner, verifier):
    """A prover must not relabel a step's base table to another published
    descriptor with the same layout: the verifier binds the commitment
    lookup to the PLAN's table, not the bundle's claim."""
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.steps[0].data_desc = "knows"     # same 2-col layout as hasCreator
    assert not verifier.verify(clone, commitments=owner.commitments)


def test_shape_flag_flip_rejected(bundle, owner, verifier):
    """Semantic circuit flags on base-table steps are pinned by the plan
    node: flipping e.g. `reverse` in the declared shape must be rejected
    before any proof is checked."""
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.steps[0].shape = dict(clone.steps[0].shape, reverse=True)
    assert not verifier.verify(clone, commitments=owner.commitments)


def test_param_substitution_rejected(bundle, owner, verifier):
    """A bundle that claims different query params than were proven must be
    rejected: the verifier pins the instance's public inputs (id_s, id sets,
    targets) to the plan-resolved bindings."""
    claimed_other = ProofBundle.from_bytes(bundle.to_bytes())
    claimed_other.params = dict(message=(1 << 20) + 8)
    assert not verifier.verify(claimed_other, commitments=owner.commitments)
    no_params = ProofBundle.from_bytes(bundle.to_bytes())
    no_params.params = {}
    assert not verifier.verify(no_params, commitments=owner.commitments)


def test_step_count_mismatch_rejected(bundle, verifier):
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.steps = clone.steps + clone.steps
    assert not verifier.verify(clone)


@pytest.mark.slow
def test_chained_shape_must_match_rederivation(db, owner, tiny_cfg):
    """A prover who lies about a chained step's circuit geometry (e.g. a
    shrunken input region that drops rows) is rejected before proof check."""
    b3 = owner.prove("IS3", dict(person=3))
    verifier = ZKGraphSession.verifier(owner.commitments, tiny_cfg)
    assert verifier.verify(b3)
    clone = ProofBundle.from_bytes(b3.to_bytes())
    rec = clone.steps[2]            # the chained order-by step
    assert rec.data_desc == "chained"
    rec.shape = dict(rec.shape, m_in=max(1, rec.shape["m_in"] - 1))
    assert not verifier.verify(clone)


# ---------------------------------------------------------------------------
# soundness: base-table circuit geometry is pinned by the PUBLISHED manifest
# ---------------------------------------------------------------------------
def test_manifest_pins_base_table_n_rows(bundle, verifier, owner):
    """A base-table step that declares a different n_rows than the manifest
    implies — even one the owner also published a root at — must fail:
    geometry comes from the manifest, never from the bundle."""
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    rec = clone.steps[0]
    bigger = rec.shape["n_rows"] * 2
    assert ("hasCreator", bigger) in owner.commitments   # root IS published
    rec.shape = dict(rec.shape, n_rows=bigger)
    assert not verifier.verify(clone)


def test_manifest_pins_base_table_m_edges(bundle, verifier):
    """m_edges bounds the circuit's selector regions; shrinking or growing
    it against the published row count must fail before proof check."""
    for delta in (-1, +1):
        clone = ProofBundle.from_bytes(bundle.to_bytes())
        rec = clone.steps[0]
        rec.shape = dict(rec.shape, m_edges=rec.shape["m_edges"] + delta)
        assert not verifier.verify(clone)


def test_manifest_pins_sssp_geometry(db, owner, tiny_cfg):
    """SSSP's n_nodes (the BiRC node universe) and edge count are pinned by
    the manifest: shrinking the node universe would let a prover hide
    reachable nodes behind the padding region."""
    b13 = owner.prove("IC13", dict(person1=1, person2=9))
    verifier = ZKGraphSession.verifier(owner.commitments, tiny_cfg)
    assert verifier.verify(b13)
    for field, delta in (("n_nodes", -1), ("n_nodes", +1), ("m_edges", -1)):
        clone = ProofBundle.from_bytes(b13.to_bytes())
        rec = clone.steps[0]
        rec.shape = dict(rec.shape, **{field: rec.shape[field] + delta})
        assert not verifier.verify(clone), (field, delta)


def test_manifest_shape_schema_enforced(bundle, verifier):
    """Unknown shape keys and bool/int confusion are rejected up front."""
    extra = ProofBundle.from_bytes(bundle.to_bytes())
    extra.steps[0].shape = dict(extra.steps[0].shape, n_rows_extra=64)
    assert not verifier.verify(extra)
    retyped = ProofBundle.from_bytes(bundle.to_bytes())
    retyped.steps[0].shape = dict(retyped.steps[0].shape, with_prop=0)
    assert not verifier.verify(retyped)


def test_data_root_size_mismatch_is_diagnosable(tiny_cfg):
    """An over-wide column matrix must fail with the descriptor + sizes in
    the message (the error an honest owner hits when table_sizes and an
    operator's shape disagree), not an opaque broadcasting ValueError."""
    from repro.core import commit
    cols = np.zeros((2, 100), np.int64)
    with pytest.raises(ValueError, match=r"hasCreator.*100 rows.*n_rows=64"):
        commit.data_root(cols, 64, tiny_cfg, desc="hasCreator")
    with pytest.raises(ValueError, match=r"2-d"):
        commit.data_root(np.zeros(8, np.int64), 64, tiny_cfg)


def test_manifest_structure(owner, db):
    """The published manifest carries the full trusted geometry."""
    m = owner.commitments
    assert isinstance(m, CommitmentManifest)
    assert m.n_nodes == db.n_nodes
    geo = m.geometry("knows")
    t = db.tables["person_knows_person"]
    assert geo.n_table_rows == len(t)
    assert geo.n_cols == 2
    assert geo.columns == ("src", "dst")
    for n_rows in geo.sizes:
        assert ("knows", n_rows) in m
    assert m.edge_count("person_knows_person") == len(t)
    # legacy mapping interface stays intact for the deprecated planner path
    assert len(dict(m.items())) == len(m)
