"""Session-API tests: prove/verify bundles, the keygen cache, bundle
serialization, and the base-table commitment soundness fix."""
import warnings

import numpy as np
import pytest

from repro.core import planner
from repro.core import prover as pv
from repro.core.session import (KeygenCache, MissingCommitmentError,
                                ProofBundle, ZKGraphSession,
                                circuit_shape_digest)
from repro.graphdb import ldbc

FAST = pv.ProverConfig(blowup=4, n_queries=8, fri_final_size=16)


@pytest.fixture(scope="module")
def db():
    return ldbc.generate(n_knows=96, n_persons=24, n_comments=64, seed=11)


@pytest.fixture(scope="module")
def owner(db):
    return ZKGraphSession(db, FAST)


@pytest.fixture(scope="module")
def bundle(owner):
    return owner.prove("IS5", dict(message=(1 << 20) + 7))


@pytest.fixture(scope="module")
def verifier(owner):
    return ZKGraphSession.verifier(owner.commitments, FAST)


def test_prove_verify_roundtrip(bundle, verifier):
    assert verifier.verify(bundle)


def test_ic1_chain_verifies(db, owner, verifier):
    """IC1 exercises every adapter kind incl. the NameFilter chained step."""
    name = int(db.node_props["person"]["firstName"][0])
    b = owner.prove("IC1", dict(person=2, firstName=name))
    assert verifier.verify(b)


def test_bundle_serialization_roundtrip(bundle, verifier):
    rt = ProofBundle.from_bytes(bundle.to_bytes())
    assert rt.query == bundle.query and rt.params == bundle.params
    assert verifier.verify(rt)


def test_wrong_dataset_rejected(bundle, verifier):
    db2 = ldbc.generate(n_knows=96, n_persons=24, n_comments=64, seed=99)
    bad = ZKGraphSession(db2, FAST).commitments
    assert not verifier.verify(bundle, commitments=bad)


def test_cfg_mismatch_rejected(bundle, owner):
    stricter = ZKGraphSession.verifier(
        owner.commitments, pv.ProverConfig(blowup=4, n_queries=32,
                                           fri_final_size=16))
    assert not stricter.verify(bundle)


# ---------------------------------------------------------------------------
# keygen cache
# ---------------------------------------------------------------------------
def test_keygen_cache_once_per_shape(db):
    """Proving the same query twice in one session performs keygen at most
    once per distinct circuit shape (the seed re-ran it per step per query)."""
    session = ZKGraphSession(db, FAST)
    session.prove("IS5", dict(message=(1 << 20) + 7))
    misses_after_first = session.cache.misses
    assert misses_after_first >= 1
    session.prove("IS5", dict(message=(1 << 20) + 7))
    assert session.cache.misses == misses_after_first
    assert session.cache.hits >= 1
    # distinct shapes in one plan each get exactly one keygen
    session.prove("IS3", dict(person=3))
    entries = len(session.cache.entries)
    session.prove("IS3", dict(person=3))
    assert len(session.cache.entries) == entries


def test_shape_digest_separates_circuits(db):
    from repro.core.operators import registry
    a = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))
    b = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=True))
    c = registry.build_operator("expand", dict(
        n_rows=32, m_edges=24, with_prop=False, reverse=False))
    d = registry.build_operator("expand", dict(
        n_rows=32, m_edges=20, with_prop=False, reverse=False))
    assert circuit_shape_digest(a.circuit) == circuit_shape_digest(d.circuit)
    assert circuit_shape_digest(a.circuit) != circuit_shape_digest(c.circuit)
    cache = KeygenCache()
    cache.ensure(a, FAST)
    cache.ensure(b, FAST)       # different circuit name -> miss
    cache.ensure(c, FAST)       # different fixed columns -> miss
    cache.ensure(d, FAST)       # identical shape -> hit
    assert cache.stats() == dict(hits=1, misses=3, entries=3)
    assert d.keys is a.keys


# ---------------------------------------------------------------------------
# soundness: base tables must be bound to *published* commitments
# ---------------------------------------------------------------------------
def test_missing_base_commitment_raises(bundle, owner, verifier):
    partial = {k: v for k, v in owner.commitments.items()
               if k[0] != "hasCreator"}
    with pytest.raises(MissingCommitmentError):
        verifier.verify(bundle, commitments=partial)


def test_legacy_verify_missing_commitment_fails(db):
    """The seed silently recomputed a missing base-table root from
    prover-supplied data — which accepts proofs over a *never-published*
    dataset. It must reject instead."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        run = planner.plan_query(db, "IS5", dict(message=(1 << 20) + 7))
        proofs = planner.prove_query(run, FAST)
        commitments = planner.publish_commitments(db, FAST)
        assert planner.verify_query(run, proofs, commitments, FAST)
        partial = {k: v for k, v in commitments.items()
                   if k[0] != "hasCreator"}
        assert not planner.verify_query(run, proofs, partial, FAST)
        # chained steps stay verifiable without a published entry
        run3 = planner.plan_query(db, "IS3", dict(person=3))
        proofs3 = planner.prove_query(run3, FAST)
        assert planner.verify_query(run3, proofs3, commitments, FAST)
        # a truncated (or empty) proof list must not pass by zip-truncation
        assert not planner.verify_query(run3, proofs3[:1], commitments, FAST)
        assert not planner.verify_query(run3, [], commitments, FAST)


def test_data_desc_substitution_rejected(db, verifier):
    """A prover must not relabel a step's base table to another published
    descriptor with the same layout: the verifier binds the commitment
    lookup to the PLAN's table, not the bundle's claim."""
    owner = ZKGraphSession(db, FAST)
    b = owner.prove("IS5", dict(message=(1 << 20) + 7))
    clone = ProofBundle.from_bytes(b.to_bytes())
    clone.steps[0].data_desc = "knows"     # same 2-col layout as hasCreator
    assert not verifier.verify(clone, commitments=owner.commitments)


def test_shape_flag_flip_rejected(db, verifier):
    """Semantic circuit flags on base-table steps are pinned by the plan
    node: flipping e.g. `reverse` in the declared shape must be rejected
    before any proof is checked."""
    owner = ZKGraphSession(db, FAST)
    b = owner.prove("IS5", dict(message=(1 << 20) + 7))
    clone = ProofBundle.from_bytes(b.to_bytes())
    clone.steps[0].shape = dict(clone.steps[0].shape, reverse=True)
    assert not verifier.verify(clone, commitments=owner.commitments)


def test_param_substitution_rejected(db, verifier):
    """A bundle that claims different query params than were proven must be
    rejected: the verifier pins the instance's public inputs (id_s, id sets,
    targets) to the plan-resolved bindings."""
    owner = ZKGraphSession(db, FAST)
    b = owner.prove("IS5", dict(message=(1 << 20) + 7))
    claimed_other = ProofBundle.from_bytes(b.to_bytes())
    claimed_other.params = dict(message=(1 << 20) + 8)
    assert not verifier.verify(claimed_other, commitments=owner.commitments)
    no_params = ProofBundle.from_bytes(b.to_bytes())
    no_params.params = {}
    assert not verifier.verify(no_params, commitments=owner.commitments)


def test_step_count_mismatch_rejected(bundle, verifier):
    clone = ProofBundle.from_bytes(bundle.to_bytes())
    clone.steps = clone.steps + clone.steps
    assert not verifier.verify(clone)


def test_chained_shape_must_match_rederivation(db, owner):
    """A prover who lies about a chained step's circuit geometry (e.g. a
    shrunken input region that drops rows) is rejected before proof check."""
    b3 = owner.prove("IS3", dict(person=3))
    verifier = ZKGraphSession.verifier(owner.commitments, FAST)
    assert verifier.verify(b3)
    clone = ProofBundle.from_bytes(b3.to_bytes())
    rec = clone.steps[2]            # the chained order-by step
    assert rec.data_desc == "chained"
    rec.shape = dict(rec.shape, m_in=max(1, rec.shape["m_in"] - 1))
    assert not verifier.verify(clone)
