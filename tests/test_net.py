"""Socket-transport coverage (repro/net): frame codec hostility, the
threaded server, the retrying client, the circuit breaker, and a signed
gossip head + proof bundle crossing a real TCP connection end to end.

Everything runs on loopback with sub-second timeouts: a hang here is a bug
in the transport, and the per-test timeout (pytest.ini) makes it a failure
instead of a stuck job.
"""
import socket
import threading
import time

import pytest

from repro.core import ed25519 as ed
from repro.core import gossip as gp
from repro.core.session import ZKGraphSession
from repro.core.transparency import TransparencyLog
from repro.core.wire import WireFormatError
from repro.net import framing
from repro.net.peer import (CircuitOpen, PeerClient, PeerUnavailable,
                            RemoteError)
from repro.net.server import NetServer

KEY = ed.SigningKey.from_secret(b"net-test-origin-key")
ORIGIN = "net-test-log"


def make_log(n=5):
    log = TransparencyLog(ORIGIN)
    for i in range(n):
        log.append(b"manifest-rev-%d" % i)
    return log


@pytest.fixture()
def echo_server():
    srv = NetServer(conn_timeout=5.0)
    srv.register(framing.REQ_PING, lambda p: (framing.RESP_PONG, p))
    with srv.serving() as addr:
        yield srv, addr


def fast_client(addr, **kw):
    kw.setdefault("timeout", 1.0)
    kw.setdefault("retries", 2)
    kw.setdefault("backoff", 0.01)
    return PeerClient(addr, **kw)


# ---------------------------------------------------------------------------
# frame codec: every byte hostile
# ---------------------------------------------------------------------------
def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        framing.send_frame(a, framing.REQ_HEAD, b"payload-bytes")
        assert framing.recv_frame(b) == (framing.REQ_HEAD, b"payload-bytes")
        framing.send_frame(b, framing.RESP_HEAD, b"")
        assert framing.recv_frame(a) == (framing.RESP_HEAD, b"")
    finally:
        a.close()
        b.close()


def test_frame_encode_rejects_bad_kind_and_oversize():
    with pytest.raises(framing.FrameError, match="unknown frame kind"):
        framing.encode_frame(0x7F, b"")
    big = bytearray(framing.encode_frame(framing.REQ_PING, b""))
    big[6:10] = (framing.MAX_FRAME + 1).to_bytes(4, "little")
    a, b = socket.socketpair()
    try:
        a.sendall(bytes(big))
        with pytest.raises(framing.FrameError, match="exceeds cap"):
            framing.recv_frame(b)
    finally:
        a.close()
        b.close()


@pytest.mark.parametrize("mutate,match", [
    (lambda f: b"XXXX" + f[4:], "bad frame magic"),
    (lambda f: f[:4] + bytes([framing.NET_VERSION + 1]) + f[5:],
     "unsupported transport version"),
    (lambda f: f[:5] + b"\x7f" + f[6:], "unknown frame kind"),
])
def test_frame_header_hostility_is_typed(mutate, match):
    raw = framing.encode_frame(framing.REQ_HEAD, b"x" * 8)
    a, b = socket.socketpair()
    try:
        a.sendall(mutate(raw))
        a.close()
        with pytest.raises(framing.FrameError, match=match):
            framing.recv_frame(b)
    finally:
        b.close()


def test_frame_truncation_vs_clean_eof():
    raw = framing.encode_frame(framing.REQ_HEAD, b"x" * 32)
    for cut, exc in ((0, framing.ConnectionClosed),
                     (5, framing.FrameError),
                     (len(raw) - 1, framing.FrameError)):
        a, b = socket.socketpair()
        try:
            a.sendall(raw[:cut])
            a.close()
            with pytest.raises(exc):
                framing.recv_frame(b)
        finally:
            b.close()
    # FrameError IS a WireFormatError: the existing fail-closed paths apply
    assert issubclass(framing.FrameError, WireFormatError)


# ---------------------------------------------------------------------------
# server + client happy path
# ---------------------------------------------------------------------------
def test_ping_round_trip_and_persistent_connection(echo_server):
    _, addr = echo_server
    with fast_client(addr) as client:
        for i in range(4):
            kind, payload = client.request(framing.REQ_PING, b"n%d" % i)
            assert (kind, payload) == (framing.RESP_PONG, b"n%d" % i)


def test_concurrent_clients_each_get_their_own_answers(echo_server):
    _, addr = echo_server
    errors = []

    def worker(tag):
        try:
            with fast_client(addr) as client:
                for i in range(8):
                    msg = b"%s-%d" % (tag, i)
                    assert client.request(framing.REQ_PING, msg)[1] == msg
        except Exception as e:      # noqa: BLE001 — collected for the assert
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(b"t%d" % t,))
               for t in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert errors == []


def test_unregistered_kind_is_a_typed_remote_error(echo_server):
    _, addr = echo_server
    with fast_client(addr) as client:
        with pytest.raises(RemoteError, match="no handler"):
            client.request(framing.REQ_BUNDLE, b"\x00" * 8)
        # the connection survives a refusal
        assert client.request(framing.REQ_PING, b"ok")[1] == b"ok"


def test_handler_exception_becomes_remote_error_not_disconnect():
    srv = NetServer()

    def explode(payload):
        raise ValueError("handler went bang")

    srv.register(framing.REQ_PING, explode)
    with srv.serving() as addr, fast_client(addr) as client:
        with pytest.raises(RemoteError, match="handler went bang"):
            client.request(framing.REQ_PING, b"")
        with pytest.raises(RemoteError):        # still serving
            client.request(framing.REQ_PING, b"")


def test_hostile_bytes_get_one_error_then_disconnect(echo_server):
    _, addr = echo_server
    raw = socket.create_connection(addr, timeout=2.0)
    raw.settimeout(2.0)
    try:
        raw.sendall(b"GET / HTTP/1.1\r\n\r\n")     # not a zkgraph frame
        kind, payload = framing.recv_frame(raw)
        assert kind == framing.RESP_ERROR
        assert b"magic" in payload
        # server hung up: clean EOF or an RST (unread bytes were pending)
        with pytest.raises((framing.ConnectionClosed, ConnectionResetError)):
            framing.recv_frame(raw)
    finally:
        raw.close()


# ---------------------------------------------------------------------------
# retry, timeout, circuit breaker
# ---------------------------------------------------------------------------
def test_dead_peer_is_peer_unavailable_not_a_hang():
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    port = sock.getsockname()[1]
    sock.close()                                   # nothing listens here
    client = fast_client(("127.0.0.1", port), timeout=0.5)
    t0 = time.monotonic()
    with pytest.raises(PeerUnavailable, match="unreachable after 2"):
        client.request(framing.REQ_PING, b"")
    assert time.monotonic() - t0 < 5.0


def test_circuit_breaker_opens_then_probes_half_open():
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    addr = ("127.0.0.1", sock.getsockname()[1])
    sock.close()
    client = fast_client(addr, timeout=0.3, retries=1,
                         fail_threshold=2, cooldown=0.4)
    for _ in range(2):
        with pytest.raises(PeerUnavailable):
            client.request(framing.REQ_PING, b"")
    assert client.state == "open"
    # open breaker fails fast: no socket work, microseconds not timeouts
    t0 = time.monotonic()
    with pytest.raises(CircuitOpen, match="circuit open"):
        client.request(framing.REQ_PING, b"")
    assert time.monotonic() - t0 < 0.1
    # after cooldown the next request is the half-open probe — and a server
    # that came back up closes the breaker again
    time.sleep(0.45)
    assert client.state == "half-open"
    srv = NetServer()
    srv.register(framing.REQ_PING, lambda p: (framing.RESP_PONG, p))
    srv.host, srv.port = addr[0], addr[1]
    with srv.serving():
        assert client.request(framing.REQ_PING, b"back")[1] == b"back"
    assert client.state == "closed"
    client.close()


def test_failed_half_open_probe_reopens_the_breaker():
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    addr = ("127.0.0.1", sock.getsockname()[1])
    sock.close()
    client = fast_client(addr, timeout=0.2, retries=1,
                         fail_threshold=1, cooldown=0.2)
    with pytest.raises(PeerUnavailable):
        client.request(framing.REQ_PING, b"")
    time.sleep(0.25)
    with pytest.raises(PeerUnavailable):           # the probe itself fails
        client.request(framing.REQ_PING, b"")
    with pytest.raises(CircuitOpen):               # and re-opened instantly
        client.request(framing.REQ_PING, b"")
    client.close()


def test_frozen_handler_hits_client_timeout_budget():
    srv = NetServer(conn_timeout=10.0)
    release = threading.Event()

    def frozen(payload):
        release.wait(8.0)
        return (framing.RESP_PONG, b"late")

    srv.register(framing.REQ_PING, frozen)
    try:
        with srv.serving() as addr:
            client = fast_client(addr, timeout=0.3, retries=2, backoff=0.01)
            t0 = time.monotonic()
            with pytest.raises(PeerUnavailable):
                client.request(framing.REQ_PING, b"")
            assert time.monotonic() - t0 < 3.0     # bounded, not wedged
            client.close()
    finally:
        release.set()


# ---------------------------------------------------------------------------
# the transparency fabric over the wire
# ---------------------------------------------------------------------------
def serve_transparency(log, key):
    """An owner-side server exposing the real RPC surface."""
    srv = NetServer()
    srv.register(framing.REQ_HEAD,
                 lambda p: (framing.RESP_HEAD, gp.emit(log, key).to_bytes()))

    def consistency(payload):
        if len(payload) != 8:
            raise ValueError("REQ_CONSISTENCY wants a u64 old size")
        since = int.from_bytes(payload, "little")
        return (framing.RESP_CONSISTENCY,
                gp.emit(log, key, since=since).to_bytes())

    srv.register(framing.REQ_CONSISTENCY, consistency)
    return srv


def test_signed_head_fetch_verify_and_advance_over_tcp():
    log = make_log(3)
    srv = serve_transparency(log, KEY)
    with srv.serving() as addr, fast_client(addr) as client:
        peer = gp.GossipPeer(ORIGIN, KEY.pub)
        kind, payload = client.request(framing.REQ_HEAD, b"")
        assert kind == framing.RESP_HEAD
        assert peer.offer(gp.GossipMessage.from_bytes(payload)) is True
        assert peer.pinned.tree_size == 3
        # the log grows; the peer advances only through a consistency fetch
        log.append(b"manifest-rev-3")
        kind, payload = client.request(framing.REQ_HEAD, b"")
        with pytest.raises(gp.ConsistencyRequired):
            peer.offer(gp.GossipMessage.from_bytes(payload))
        kind, payload = client.request(
            framing.REQ_CONSISTENCY,
            int(peer.pinned.tree_size).to_bytes(8, "little"))
        assert kind == framing.RESP_CONSISTENCY
        assert peer.offer(gp.GossipMessage.from_bytes(payload)) is True
        assert peer.pinned.tree_size == 4


def test_relay_cannot_substitute_its_own_signed_head():
    """A hostile relay re-signs the head under its own key: the transport
    delivers it fine — and the gossip layer rejects it, which is the whole
    point of carrying signatures inside the envelope."""
    log = make_log(3)
    mallory = ed.SigningKey.from_secret(b"mallory")
    srv = serve_transparency(log, mallory)          # serves mallory-signed
    with srv.serving() as addr, fast_client(addr) as client:
        peer = gp.GossipPeer(ORIGIN, KEY.pub)       # pins the honest key
        _, payload = client.request(framing.REQ_HEAD, b"")
        with pytest.raises(gp.GossipError, match="unexpected key"):
            peer.offer(gp.GossipMessage.from_bytes(payload))
        assert peer.head is None


def test_verifier_bootstrap_and_bundle_delivery_over_tcp(owner, bundle,
                                                         tiny_cfg):
    """The full trust path over sockets: manifest, inclusion proof, signed
    head, and the proof bundle all travel as frames; the verifier session
    is built purely from received bytes and accepts the bundle."""
    log = TransparencyLog("session-net-log")
    checkpoint, inclusion, manifest_raw = owner.publish_to(log)
    raw_bundle = bundle.to_bytes()
    srv = NetServer()
    srv.register(framing.REQ_HEAD,
                 lambda p: (framing.RESP_HEAD,
                            gp.emit(log, KEY).to_bytes()))
    srv.register(framing.REQ_MANIFEST,
                 lambda p: (framing.RESP_MANIFEST, manifest_raw))
    srv.register(framing.REQ_INCLUSION,
                 lambda p: (framing.RESP_INCLUSION, inclusion.to_bytes()))
    srv.register(framing.REQ_BUNDLE,
                 lambda p: (framing.RESP_BUNDLE, raw_bundle))
    with srv.serving() as addr, fast_client(addr, timeout=5.0) as client:
        peer = gp.GossipPeer("session-net-log", KEY.pub)
        _, head_raw = client.request(framing.REQ_HEAD, b"")
        assert peer.offer(gp.GossipMessage.from_bytes(head_raw)) is True
        _, man_raw = client.request(framing.REQ_MANIFEST, b"")
        _, incl_raw = client.request(framing.REQ_INCLUSION, b"")
        from repro.core.transparency import InclusionProof
        verifier = ZKGraphSession.verifier(
            cfg=tiny_cfg, gossip=peer,
            inclusion=InclusionProof.from_bytes(incl_raw),
            manifest_bytes=man_raw)
        _, bundle_raw = client.request(framing.REQ_BUNDLE, b"")
        assert verifier.verify_bytes(bundle_raw) is True
        # and a tampered delivery fails closed, same as ever
        assert verifier.verify_bytes(bundle_raw[:-3]) is False
