"""Planner + new-operator semantics.

Structural/executional equivalence of every compiled LDBC text against its
hand-written plan (the cheap half of the conformance story — the wire-byte
half lives in test_query_conformance.py), executor-level coverage of the
Filter/Aggregate operators across every comparison/aggregation, and an
end-to-end prove+verify of queries only the parsed front door can express
(WHERE with an order predicate, RETURN count/sum/min).
"""
import numpy as np
import pytest

from repro.core import ir
from repro.core.operators.common import check_constraints
from repro.graphdb import ldbc
from repro.query import QUERY_TEXTS, QueryCompileError, compile_query

QUERY_PARAMS = {
    "IS3": dict(person=2),
    "IS4": dict(message=(1 << 20) + 7),
    "IS5": dict(message=(1 << 20) + 7),
    "IC1": dict(person=2, firstName=None),     # name filled per-db below
    "IC2": dict(person=2, k=20),
    "IC8": dict(person=1, k=20),
    "IC9": dict(person=2, k=20),
    "IC13": dict(person1=1, person2=9),
}


def _params(db, qname):
    params = dict(QUERY_PARAMS[qname])
    if params.get("firstName", 0) is None:
        params["firstName"] = int(db.node_props["person"]["firstName"][0])
    return params


def _run_fingerprint(run):
    """Everything the wire bytes depend on, minus the (nondeterministic)
    proof transcript: step kinds, shapes, public instances, data columns."""
    return [(st.kind, tuple(sorted(st.shape.items())), st.data_desc,
             st.instance.tobytes(), st.data.tobytes()) for st in run.steps]


@pytest.mark.parametrize("qname", list(QUERY_TEXTS))
def test_compiled_plan_matches_hand_plan_execution(db, qname):
    hand = ir.build_plan(qname)
    comp = compile_query(QUERY_TEXTS[qname], name=qname)
    assert [type(n).__name__ for n in comp.nodes] \
        == [type(n).__name__ for n in hand.nodes]
    params = _params(db, qname)
    rh = ir.execute(db, hand, dict(params))
    rc = ir.execute(db, comp, dict(params))
    assert _run_fingerprint(rh) == _run_fingerprint(rc)
    assert set(rh.result) == set(rc.result)
    for key in rh.result:
        assert np.array_equal(np.asarray(rh.result[key]),
                              np.asarray(rc.result[key])), (qname, key)


def test_build_plan_resolves_query_text(db):
    """ir.build_plan accepts a parseable text as the query name (the
    verifier-side path for text-named bundles) and fails closed otherwise."""
    text = QUERY_TEXTS["IS5"]
    plan = ir.build_plan(text)
    assert plan.name == text
    run = ir.execute(db, plan, dict(message=(1 << 20) + 7))
    assert "creator" in run.result
    with pytest.raises(KeyError):
        ir.build_plan("MATCH (p:Person RETURN")     # syntax error -> KeyError
    with pytest.raises(KeyError):
        ir.build_plan("MATCH (p:Robot {id: 1})-[:KNOWS]-(f) "
                      "RETURN f.id AS x")           # compile error -> KeyError
    with pytest.raises(KeyError):
        ir.build_plan("IC99")                       # unknown name stays one


def test_build_plan_fails_closed_when_front_door_unimportable(monkeypatch):
    """If the lazy repro.query bootstrap cannot import, build_plan must still
    raise KeyError (session.verify catches exactly that) — never leak the
    ImportError through verify_bytes' returns-False contract."""
    import sys
    monkeypatch.setattr(ir, "_PLAN_RESOLVERS", [])
    monkeypatch.setattr(ir, "_RESOLVER_BOOTSTRAPPED", [False])
    monkeypatch.setitem(sys.modules, "repro.query", None)   # import -> error
    with pytest.raises(KeyError):
        ir.build_plan("MATCH (m:Message {id: 1}) RETURN m.content AS c")


# ---------------------------------------------------------------------------
# WHERE must bind — predicates that downstream nodes would bypass fail closed
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pred", ["f.firstName >= $thr", "f.firstName = $thr"])
def test_where_on_intermediate_variable_fails_closed(pred):
    """A predicate on a variable that later hops already expanded from would
    compile to a dead Filter (downstream nodes captured the unfiltered ids);
    the compiler must refuse rather than prove a silently different query."""
    text = ("MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person)"
            "<-[:HAS_CREATOR]-(m:Message) "
            f"WHERE {pred} RETURN m.id AS ids")
    with pytest.raises(QueryCompileError, match="intermediate"):
        compile_query(text)


def test_where_on_edge_payload_variable_fails_closed():
    """Filtering a node bound to an edge-property expansion would be bypassed
    by the ORDER BY payload, which reads the unfiltered expansion outputs."""
    text = ("MATCH (p:Person {id: $person})-[k:KNOWS]-(f:Person) "
            "WHERE f.firstName >= $thr "
            "RETURN k.creationDate AS dates ORDER BY k.creationDate DESC")
    with pytest.raises(QueryCompileError, match="edge-property"):
        compile_query(text)


def test_where_on_terminal_variable_still_compiles():
    plan = compile_query(
        "MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) "
        "WHERE f.firstName >= $thr RETURN f.id AS ids")
    assert [type(n).__name__ for n in plan.nodes] \
        == ["SetExpand", "SetExpand", "Filter"]


def test_filter_on_empty_expansion_has_no_phantom_rows():
    """An anchored person with no KNOWS edges: the WHERE lookup is empty, so
    Chained pads it to one (0, 0) row; a predicate the padding satisfies
    (>= 0) must not surface phantom node id 0 in the result."""
    lonely_db = ldbc.generate(n_knows=24, n_persons=16, n_comments=8, seed=0)
    t = lonely_db.tables["person_knows_person"]
    used = set(t.src.tolist()) | set(t.dst.tolist())
    lonely = next(int(i) for i in lonely_db.node_ids if int(i) not in used)
    plan = compile_query(
        "MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) "
        "WHERE f.firstName >= 0 RETURN f.id AS ids")
    run = ir.execute(lonely_db, plan, dict(person=lonely))
    assert np.asarray(run.result["ids"]).tolist() == []


# ---------------------------------------------------------------------------
# Filter operator semantics (executor level, constraints checked)
# ---------------------------------------------------------------------------
_IDS = tuple(range(1, 9))
_VALS = (5, 30, 17, 30, 2, 99, 42, 8)


def _filter_run(db, cmp, thr):
    node = ir.Filter(ir.Chained((ir.Lit(_IDS), ir.Lit(_VALS))), cmp,
                     ir.Lit(thr))
    plan = ir.Plan("t", (node,), dict(ids=ir.Out(0, "src"),
                                      vals=ir.Out(0, "dst")))
    run = ir.execute(db, plan, {})
    st = run.steps[0]
    assert not check_constraints(st.op, st.advice, st.instance, st.data)
    return run.result


@pytest.mark.parametrize("cmp,py", [
    ("ge", lambda v, t: v >= t), ("gt", lambda v, t: v > t),
    ("le", lambda v, t: v <= t), ("lt", lambda v, t: v < t),
    ("eq", lambda v, t: v == t), ("ne", lambda v, t: v != t)])
@pytest.mark.parametrize("thr", [0, 17, 30, 1000])
def test_filter_all_comparisons(db, cmp, py, thr):
    got = _filter_run(db, cmp, thr)
    want = [(i, v) for i, v in zip(_IDS, _VALS) if py(v, thr)]
    assert got["ids"].tolist() == [i for i, _ in want]
    assert got["vals"].tolist() == [v for _, v in want]


def test_filter_empty_input_and_bounds(db):
    empty = ir.Lit(())
    node = ir.Filter(ir.Chained((empty, empty)), "ge", ir.Lit(7))
    run = ir.execute(db, ir.Plan("t", (node,), dict(ids=ir.Out(0, "src"))),
                     {})
    st = run.steps[0]
    assert not check_constraints(st.op, st.advice, st.instance, st.data)
    assert run.result["ids"].tolist() == []
    # order comparisons demand range-checkable values
    with pytest.raises(AssertionError):
        ir.execute(db, ir.Plan("t", (ir.Filter(
            ir.Chained((ir.Lit((1,)), ir.Lit((1 << 29,)))), "ge",
            ir.Lit(0)),), {}), {})


# ---------------------------------------------------------------------------
# Aggregate operator semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("agg,vals,want", [
    ("count", (7, 31, 9, 31, 12, 4), 6),
    ("count", (3, 0, 5, 0), 2),            # counts NONZERO entries
    ("count", (), 0),                      # chained empty -> phantom 0 row
    ("sum", (7, 31, 9, 31, 12, 4), 94),
    ("sum", (), 0),
    ("min", (7, 31, 9, 31, 12, 4), 4),
    ("min", (42,), 42),
])
def test_aggregate_semantics(db, agg, vals, want):
    node = ir.Aggregate(ir.Chained((ir.Lit(vals),)), agg)
    run = ir.execute(db, ir.Plan("t", (node,), dict(v=ir.Out(0, "value"))),
                     {})
    st = run.steps[0]
    assert not check_constraints(st.op, st.advice, st.instance, st.data)
    assert run.result["v"] == want


def test_aggregate_min_rejects_oversized_values(db):
    node = ir.Aggregate(ir.Chained((ir.Lit((1 << 29,)),)), "min")
    with pytest.raises(AssertionError):
        ir.execute(db, ir.Plan("t", (node,), {}), {})


@pytest.mark.parametrize("m", [1, 2, 3, 7])
def test_filter_aggregate_manifest_pins_match_shape(db, m):
    """The verifier's manifest pin and the honest prover's shape() must
    derive the SAME geometry for an m-row table — including tiny tables
    where shape() applies its max(..., 2) circuit floor."""
    from types import SimpleNamespace

    from repro.core.operators import registry
    geo = SimpleNamespace(n_table_rows=m)
    ids = ir.Lit(tuple(range(1, m + 1)))
    vals = ir.Lit(tuple(range(m)))
    fnode = ir.Filter(ir.Chained((ids, vals)), "ge", ir.Lit(0))
    fa = registry.adapter_for(fnode)
    assert fa.manifest_pins(fnode, ir.Env({}), None, geo)["n_rows"] \
        == fa.shape(db, fnode, ir.Env({}))["n_rows"]
    anode = ir.Aggregate(ir.Chained((vals,)), "count")
    aa = registry.adapter_for(anode)
    assert aa.manifest_pins(anode, ir.Env({}), None, geo)["n_rows"] \
        == aa.shape(db, anode, ir.Env({}))["n_rows"]
    # a 0-row table still pins the 2-row floor the builders require
    empty = SimpleNamespace(n_table_rows=0)
    assert fa.manifest_pins(fnode, ir.Env({}), None, empty)["n_rows"] >= 2
    assert aa.manifest_pins(anode, ir.Env({}), None, empty)["n_rows"] >= 2


# ---------------------------------------------------------------------------
# end-to-end: queries only the front door can express
# ---------------------------------------------------------------------------
def _canon(bundle) -> bytes:
    for st in bundle.steps:
        st.proof.timings = {}
    return bundle.to_bytes()


def test_prove_and_verify_order_predicate_query(db, owner, verifier):
    """WHERE with an order predicate lowers to the new Filter circuit and
    survives the full prove -> serialize -> verify loop."""
    names = db.node_props["person"]["firstName"]
    thr = int(np.median(names))
    text = ("MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) "
            "WHERE f.firstName >= $thr RETURN f.id AS ids")
    plan = compile_query(text)
    kinds = [type(n).__name__ for n in plan.nodes]
    assert kinds == ["SetExpand", "SetExpand", "Filter"]
    bundle = owner.prove_plan(plan, dict(person=2, thr=thr))
    assert bundle.query == text
    assert verifier.verify_bytes(bundle.to_bytes())
    # the result is exactly the honest filter of the friend set
    run = owner.run_plan(ir.build_plan("IC2"), dict(person=2, k=20))
    friends = np.unique(np.asarray(run.steps[0].outputs["dst"]))
    want = sorted(int(f) for f in friends
                  if int(names[int(f) - 1]) >= thr)
    assert sorted(np.asarray(bundle.result["ids"]).tolist()) == want


@pytest.mark.parametrize("fn,expr", [
    ("count", "count(f)"), ("sum", "sum(f.firstName)"),
    ("min", "min(f.firstName)")])
def test_prove_and_verify_aggregate_query(db, owner, verifier, fn, expr):
    text = (f"MATCH (p:Person {{id: $person}})-[:KNOWS]-(f:Person) "
            f"RETURN {expr} AS out")
    plan = compile_query(text)
    assert type(plan.nodes[-1]).__name__ == "Aggregate"
    bundle = owner.prove_plan(plan, dict(person=2))
    assert verifier.verify_bytes(bundle.to_bytes())
    friends = np.unique(np.asarray(
        owner.run_plan(ir.build_plan("IC2"),
                       dict(person=2, k=20)).steps[0].outputs["dst"]))
    names = db.node_props["person"]["firstName"]
    vals = [int(names[int(f) - 1]) for f in friends]
    want = {"count": len(friends), "sum": sum(vals), "min": min(vals)}[fn]
    assert int(bundle.result["out"]) == want


def test_tampered_aggregate_output_fails_verification(owner, verifier):
    text = ("MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person) "
            "RETURN count(f) AS n")
    bundle = owner.prove_plan(compile_query(text), dict(person=2))
    agg = bundle.steps[-1]
    agg.instance = agg.instance.copy()
    agg.instance[0, :] += 1            # claim one more friend
    bundle.result = dict(n=int(bundle.result["n"]) + 1)
    assert not verifier.verify(bundle)
