"""Circuit soundness analyzer (repro.analysis): structural checks on
hand-built circuits, the witness perturbation probe, the registry vetting
contract, corpus detection, and the CLI surface.

The expensive all-registry sweep and full seeded-bug corpus are marked
``slow`` (nightly full-suite); the blocking CI `analysis` job runs both on
every PR via ``python -m repro.analysis --all-adapters --purity --selftest``.
"""
import ast
import json
import re
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (analyze_case, apply_baseline, load_baseline,
                            registry_cases, write_baseline)
from repro.analysis.findings import ALL_CHECKS, ERROR, Finding, WARNING
from repro.analysis.structural import analyze_circuit
from repro.analysis.witness import witness_analysis
from repro.core.plonkish import ADVICE, Circuit, Col, Const

ROOT = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# structural checks on hand-built circuits (fast, no witness)
# ---------------------------------------------------------------------------
def _checks(findings, check):
    return [f for f in findings if f.check == check]


def test_degree_overflow_detected():
    c = Circuit(8, "t")
    a = c.add_advice("a")
    c.gates.append(("deg5", a * a * a * a * a))     # bypass add_gate's assert
    hits = _checks(analyze_circuit(c, "t", blowup=4), "gate-degree-overflow")
    assert len(hits) == 1 and hits[0].severity == ERROR
    assert "deg5" in hits[0].key


def test_rotation_out_of_range_detected():
    c = Circuit(8, "t")
    a = c.add_advice("a")
    c.gates.append(("wide", Col(ADVICE, a.index, 8) - a))
    assert _checks(analyze_circuit(c, "t"), "rotation-out-of-range")


def test_unguarded_wrap_flagged_and_guard_accepted():
    n = 8
    bad = Circuit(n, "bad")
    a = bad.add_advice("a")
    bad.add_gate("step", Col(ADVICE, a.index, 1) - a)
    assert _checks(analyze_circuit(bad, "bad"), "unguarded-wrap")

    good = Circuit(n, "good")
    a = good.add_advice("a")
    sel = good.add_fixed("sel", [1] * (n - 1) + [0])   # vanishes on wrap row
    good.add_gate("step", sel * (Col(ADVICE, a.index, 1) - a))
    assert not _checks(analyze_circuit(good, "good"), "unguarded-wrap")


def test_vacuous_gate_detected():
    c = Circuit(8, "t")
    a = c.add_advice("a")
    sel = c.add_fixed("sel", [0] * 8)                  # all-zero selector
    c.add_gate("dead", sel * a * (a - Const(1)))
    hits = _checks(analyze_circuit(c, "t"), "vacuous-gate")
    assert hits and hits[0].severity == ERROR


def test_orphan_and_unused_columns_detected():
    c = Circuit(8, "t")
    a = c.add_advice("a")
    c.add_gate("bool", a * (a - Const(1)))
    c.add_advice("ghost")                              # never referenced
    c.add_instance("pub")                              # public, unchecked!
    c.add_fixed("dead_sel", [1] * 8)                   # never referenced
    fs = analyze_circuit(c, "t")
    assert any(f.key == "ghost" for f in _checks(fs, "orphan-advice-column"))
    assert any(f.key == "pub" for f in _checks(fs, "orphan-instance-column"))
    assert any(f.key == "dead_sel" and f.severity == WARNING
               for f in _checks(fs, "unused-fixed-column"))


def test_floating_advice_component_detected():
    c = Circuit(8, "t")
    a, b = c.add_advice("a"), c.add_advice("b")
    c.add_gate("tie", a - b)          # a,b only ever constrained to each other
    assert _checks(analyze_circuit(c, "t"), "floating-advice-component")


def test_honest_minimal_circuit_is_clean():
    c = Circuit(8, "t")
    a = c.add_advice("a")
    sel = c.add_fixed("sel", [1] * 8)
    c.add_gate("bool", sel * a * (a - Const(1)))
    assert [f for f in analyze_circuit(c, "t") if f.fails_gate()] == []


# ---------------------------------------------------------------------------
# witness perturbation probe (fast, hand-built)
# ---------------------------------------------------------------------------
def _wit(c, n_adv, n_inst, n):
    return (np.zeros((n_adv, n), np.uint32),
            np.zeros((n_inst, n), np.uint32),
            np.zeros((0, n), np.uint32))


def test_probe_bound_column_has_no_free_cells():
    n = 8
    c = Circuit(n, "t")
    a = c.add_advice("a")
    c.add_gate("bool", a * (a - Const(1)))
    adv, inst, data = _wit(c, 1, 0, n)
    fs, cov = witness_analysis(c, adv, inst, data, "t")
    assert [f for f in fs if f.fails_gate()] == []
    assert cov[0]["column"] == "a" and cov[0]["free_cells"] == 0


def test_probe_flags_referenced_but_unconstrained_column():
    n = 8
    c = Circuit(n, "t")
    a = c.add_advice("a")
    b = c.add_advice("b")
    c.add_gate("bool", a * (a - Const(1)))
    zero = c.add_fixed("zsel", [0] * n)
    c.add_gate("dead", zero * b)      # b referenced, never actually bound
    adv, inst, data = _wit(c, 2, 0, n)
    fs, _ = witness_analysis(c, adv, inst, data, "t")
    assert any(f.check == "unconstrained-advice-column" and f.key == "b"
               for f in fs)


def test_probe_reports_honest_witness_violation_first():
    n = 8
    c = Circuit(n, "t")
    a = c.add_advice("a")
    c.add_gate("bool", a * (a - Const(1)))
    adv = np.full((1, n), 2, np.uint32)               # 2*(2-1) != 0
    fs, _ = witness_analysis(c, adv, *_wit(c, 0, 0, n)[1:], "t")
    hits = [f for f in fs if f.check == "witness-violation"]
    assert hits and hits[0].severity == ERROR and "bool" in hits[0].key


def test_probe_classifies_forgeable_public_output():
    n = 8
    c = Circuit(n, "t")
    a = c.add_advice("a")
    c.add_gate("bool", a * (a - Const(1)))
    c.add_instance("out")                              # public, unbound
    adv, inst, data = _wit(c, 1, 1, n)

    def extract(instance):
        return dict(out=np.asarray(instance[0], np.int64))

    fs, _ = witness_analysis(c, adv, inst, data, "t", extract=extract)
    hits = [f for f in fs if f.check == "forgeable-output"]
    assert hits and hits[0].severity == ERROR and hits[0].key == "out"


# ---------------------------------------------------------------------------
# registry vetting contract + one end-to-end case
# ---------------------------------------------------------------------------
def test_every_adapter_declares_two_representative_shapes(db):
    cases = registry_cases(db)
    per = {}
    for case in cases:
        per.setdefault(case.adapter, []).append(case.label)
    from repro.core.operators import registry
    assert set(per) == set(registry.adapters()), \
        "some registered adapter produced no analysis cases"
    for name, labels in per.items():
        assert len(labels) >= 2, \
            f"adapter {name!r} declares fewer than 2 analysis shapes"
    # labels are unique per adapter (they key findings and reports)
    for name, labels in per.items():
        assert len(set(labels)) == len(labels)


def test_orderby_case_end_to_end_clean(db):
    case = next(c for c in registry_cases(db)
                if (c.adapter, c.label) == ("orderby", "top3_desc"))
    findings, stats = analyze_case(case)
    assert [f for f in findings if f.fails_gate()] == []
    assert stats["gates"], "gate_info() should describe the circuit"
    # selector-bound columns are fully covered on the honest witness
    cov = {c["column"]: c["free_cells"] for c in stats["coverage"]}
    assert cov["IS_k"] == 0 and cov["out_sel"] == 0


@pytest.mark.slow
def test_full_registry_is_clean(db):
    from repro.analysis import analyze_all
    report = analyze_all(db)
    assert report.gating() == [], \
        f"registry circuits have findings: " \
        f"{[(f.check, f.where, f.key) for f in report.gating()]}"


@pytest.mark.slow
def test_seeded_bug_corpus_fully_detected(db):
    from repro.analysis.corpus import run_selftest
    assert run_selftest(db=db, verbose=False)


def test_corpus_variant_detected_fast(db):
    """One corpus variant in tier-1 so detection regressions surface on
    every push, not only nightly: the zeroed selector must be caught."""
    from repro.analysis.corpus import v_dropped_selector
    name, case, expected = v_dropped_selector(db)
    findings, _ = analyze_case(case)
    got = {f.check for f in findings if f.fails_gate()}
    assert expected <= got, f"{name}: expected {expected}, got {got}"


# ---------------------------------------------------------------------------
# findings / baseline mechanics
# ---------------------------------------------------------------------------
def test_check_ids_stay_in_catalogue():
    """Every kebab-case string literal in the emitting modules is a
    registered check id — no module invents ids the docs don't list."""
    kebab = re.compile(r"^[a-z]+(-[a-z]+)+$")
    for mod in ("structural", "witness", "purity"):
        src = (ROOT / "src" / "repro" / "analysis" / f"{mod}.py").read_text()
        ids = {node.value for node in ast.walk(ast.parse(src))
               if isinstance(node, ast.Constant)
               and isinstance(node.value, str) and kebab.fullmatch(node.value)}
        unknown = ids - ALL_CHECKS
        assert not unknown, f"{mod}.py emits unregistered check ids {unknown}"


def test_baseline_roundtrip_and_staleness(tmp_path):
    f1 = Finding("vacuous-gate", ERROR, "x:y/z", "g1", "d")
    f2 = Finding("banned-import", ERROR, "core/a.py", "import time", "d", 3)
    path = tmp_path / "b.json"
    assert write_baseline([f1, f2], path) == 2
    base = load_baseline(path)
    kept, suppressed, stale = apply_baseline([f1], base)
    assert kept == [] and suppressed == [f1]
    assert stale == [f2.ident()], "unmatched entries must be reported stale"


def test_committed_baseline_is_minimal_and_current():
    """The committed baseline holds exactly the two reviewed prover timing
    imports — nothing may creep in without showing up in this diff."""
    base = load_baseline(ROOT / "analysis_baseline.json")
    assert base == {
        ("banned-import", "core/prover.py", "import time"),
        ("banned-import", "core/prover_batch.py", "import time"),
    }


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------
def test_cli_purity_json_and_gate(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "report.json"
    rc = main(["--purity", "--json", str(out), "--fail-on-findings"])
    assert rc == 0, "purity lint over the real tree must pass the gate"
    doc = json.loads(out.read_text())
    assert doc["purity"]["files_scanned"] > 30
    assert doc["gating_after_baseline"] == 0
    assert doc["suppressed"] == 2 and doc["stale_baseline"] == []


def test_cli_write_baseline_then_clean(tmp_path):
    from repro.analysis.__main__ import main
    bl = tmp_path / "bl.json"
    assert main(["--purity", "--no-baseline", "--write-baseline",
                 "--baseline", str(bl)]) == 0
    assert load_baseline(bl) == load_baseline(ROOT / "analysis_baseline.json")
    assert main(["--purity", "--baseline", str(bl),
                 "--fail-on-findings"]) == 0
