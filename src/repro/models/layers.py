"""Model layers: GQA attention (sliding-window, bias options), RoPE, norms,
SwiGLU/GELU MLP, capacity-based MoE, Mamba1 selective scan, Mamba2 SSD.

All functions are pure; params are nested dicts of arrays. Static shapes
throughout (argsort/top_k are fine — XLA needs static shapes, not values).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# Data-parallel mesh axes for in-graph sharding constraints (set by the
# launcher/dry-run before tracing; None = no constraints, e.g. smoke tests).
# Needed because GSPMD loses the batch sharding through the MoE dispatch
# scatter/gather chain and replicates token buffers onto every device
# (EXPERIMENTS.md §Perf iteration 2).
DP_AXES = None
DP_SIZE = 1


def _dp_constraint(x, *rest):
    if DP_AXES is None or x.shape[0] % max(DP_SIZE, 1) != 0:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(DP_AXES, *rest))


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def norm_params(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p, cfg, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(cfg, positions):
    """positions: (...,) int -> cos/sin (..., hd/2) f32.

    All arithmetic pinned to f32: the ZK core enables jax x64 globally, and
    un-pinned numpy f64 constants would silently promote the rope (and then
    q/k) to f64 in one code path but not the other."""
    hd = cfg.hd
    inv = jnp.asarray(1.0 / (cfg.rope_theta ** (np.arange(0, hd, 2) / hd)),
                      jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attn_params(key, cfg):
    d, hd, H, K = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), pd),
        "wk": dense_init(ks[1], (d, K, hd), pd),
        "wv": dense_init(ks[2], (d, K, hd), pd),
        "wo": dense_init(ks[3], (H, hd, d), pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), pd)
        p["bk"] = jnp.zeros((K, hd), pd)
        p["bv"] = jnp.zeros((K, hd), pd)
    if cfg.proj_bias:
        p["bo"] = jnp.zeros((d,), pd)
    return p


def _qkv(p, cfg, x, positions=None, use_rope=True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if use_rope:
        if positions is None:
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def sdpa(q, k, v, causal=True, window=0, kv_offset=0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd). Mask built from iotas (never
    materialized at rest — XLA fuses it)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k) * scale
    qpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2) + kv_offset
    kpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 3)
    mask = jnp.ones_like(logits, dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def sdpa_banded(q, k, v, window):
    """Sliding-window attention computed on the band only (§Perf iter. 6).

    Queries in blocks of W attend keys of blocks (i-1, i): score tensor is
    (B, nb, H, W, 2W) instead of (B, H, S, S) — a S/(2W) reduction in score
    FLOPs/bytes (4x at S=32k, W=4k). Exactly equals masked full attention
    (tested in test_models_smoke.py::test_banded_swa_matches_masked_full)."""
    B, S, H, hd = q.shape
    W = window
    nb = S // W
    scale = 1.0 / math.sqrt(hd)
    qb = q.reshape(B, nb, W, H, hd)
    kb = k.reshape(B, nb, W, H, hd)
    vb = v.reshape(B, nb, W, H, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k_ctx = jnp.concatenate([k_prev, kb], axis=2)     # (B, nb, 2W, H, hd)
    v_ctx = jnp.concatenate([v_prev, vb], axis=2)
    logits = jnp.einsum("bnqhk,bnshk->bnhqs", qb, k_ctx) * scale
    qi = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 3)   # in-block q
    kj = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 4)   # ctx key idx
    bi = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    qpos = bi * W + qi
    kpos = (bi - 1) * W + kj                                    # ctx starts at block i-1
    mask = (kpos <= qpos) & (kpos > qpos - W) & (kpos >= 0)
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    ob = jnp.einsum("bnhqs,bnshk->bnqhk", probs, v_ctx)
    return ob.reshape(B, S, H, hd)


def self_attention(p, cfg, x, causal=True, use_rope=True):
    q, k, v = _qkv(p, cfg, x, use_rope=use_rope)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv)
    W = cfg.sliding_window
    if causal and W and x.shape[1] % W == 0 and x.shape[1] >= 2 * W:
        o = sdpa_banded(q, k, v, W)
    else:
        o = sdpa(q, k, v, causal=causal, window=W)
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(x.dtype))
    if cfg.proj_bias:
        out = out + p["bo"].astype(x.dtype)
    return out


def decode_attention(p, cfg, x, cache_k, cache_v, pos, use_rope=True):
    """One-token decode. cache_k/v: (B, S, K, hd); pos: scalar int32 —
    current write position. Returns (out, new_k, new_v).

    GQA is computed in *grouped* form — queries reshaped to (B,1,K,G,hd) and
    contracted against the (B,S,K,hd) cache directly. Materializing the
    repeated KV (the naive path) forces GSPMD to re-shard the entire cache
    (a ~GB-scale all-gather per step at 32k context); grouped form keeps the
    cache layout untouched (EXPERIMENTS.md §Perf iteration 1)."""
    B = x.shape[0]
    q, k, v = _qkv(p, cfg, x, positions=jnp.full((1,), pos, jnp.int32),
                   use_rope=use_rope)
    pos = pos.astype(jnp.int32) if hasattr(pos, "astype") else jnp.int32(pos)
    zero = jnp.zeros((), jnp.int32)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (zero, pos, zero, zero))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (zero, pos, zero, zero))
    K, G = cfg.n_kv, cfg.n_heads // cfg.n_kv
    q4 = q.reshape(B, 1, K, G, cfg.hd)
    scale = 1.0 / math.sqrt(cfg.hd)
    kk = cache_k.astype(x.dtype)
    vv = cache_v.astype(x.dtype)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q4, kk) * scale
    kpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 4)
    mask = kpos <= pos
    if cfg.sliding_window:
        mask &= kpos > pos - cfg.sliding_window
    logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, vv)
    o = o.reshape(B, 1, cfg.n_heads, cfg.hd)
    out = jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(x.dtype))
    if cfg.proj_bias:
        out = out + p["bo"].astype(x.dtype)
    return out, cache_k, cache_v


def cross_attention(p, cfg, x, memory):
    """Encoder-decoder cross attention (whisper); no RoPE, no mask."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(dt))
    o = sdpa(q, _repeat_kv(k, cfg.n_heads // cfg.n_kv),
             _repeat_kv(v, cfg.n_heads // cfg.n_kv), causal=False)
    return jnp.einsum("bqhk,hkd->bqd", o, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------
def mlp_params(key, cfg, n_experts=0):
    d, ff = cfg.d_model, cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    lead = (n_experts,) if n_experts else ()
    if cfg.mlp == "swiglu":
        p = {"wg": dense_init(ks[0], lead + (d, ff), pd),
             "wu": dense_init(ks[1], lead + (d, ff), pd),
             "wd": dense_init(ks[2], lead + (ff, d), pd)}
    else:
        p = {"wu": dense_init(ks[1], lead + (d, ff), pd),
             "wd": dense_init(ks[2], lead + (ff, d), pd)}
        if cfg.proj_bias:
            p["bu"] = jnp.zeros(lead + (ff,), pd)
            p["bd"] = jnp.zeros(lead + (d,), pd)
    if n_experts:
        p["router"] = dense_init(ks[3], (d, n_experts), pd, scale=0.02)
    return p


def apply_mlp(p, cfg, x):
    dt = x.dtype
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(x @ p["wg"].astype(dt))
        u = x @ p["wu"].astype(dt)
        return (g * u) @ p["wd"].astype(dt)
    h = x @ p["wu"].astype(dt)
    if cfg.proj_bias:
        h = h + p["bu"].astype(dt)
    h = jax.nn.gelu(h)
    out = h @ p["wd"].astype(dt)
    if cfg.proj_bias:
        out = out + p["bd"].astype(dt)
    return out


def apply_moe(p, cfg, x, group_size: int = 4096):
    """Capacity-based token-dropping MoE (GShard-style, fully static shapes).

    x: (B, S, d). Tokens are flattened, grouped, routed top-k, dispatched to
    per-expert capacity buffers by scatter, processed with a grouped einsum
    (the expert dim maps onto the MXU), and combined by gather.
    """
    B, S, d = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    dt = x.dtype
    T = B * S
    g = min(group_size, T)
    G = T // g
    xs = _dp_constraint(x.reshape(G, g, d), None, None)
    logits = xs @ p["router"].astype(dt)                     # (G, g, E)
    gate, eidx = jax.lax.top_k(logits, K)                    # (G, g, K)
    gate = jax.nn.softmax(gate.astype(jnp.float32), axis=-1).astype(dt)
    cap = int(math.ceil(g * K / E * cfg.moe_capacity_factor))
    cap = max(8, min(g, ((cap + 7) // 8) * 8))
    # position of each (token, k) within its expert: cumsum over flat (g*K)
    onehot = jax.nn.one_hot(eidx.reshape(G, g * K), E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1                     # (G, g*K, E)
    pos = jnp.take_along_axis(
        pos, eidx.reshape(G, g * K)[..., None], axis=2)[..., 0]  # (G, g*K)
    keep = pos < cap
    # scatter token indices into (G, E, cap) buffers (int32-pinned: the ZK
    # core enables x64 and arange would default to int64)
    tok_idx = jnp.broadcast_to(
        jnp.arange(g, dtype=jnp.int32)[None, :, None], (G, g, K)) \
        .reshape(G, g * K)
    flat_e = eidx.reshape(G, g * K).astype(jnp.int32)
    buf = jnp.full((G, E, cap), g, jnp.int32)                # g = OOB sentinel
    scatter_pos = jnp.where(keep, pos, cap).astype(jnp.int32)  # dropped -> OOB
    buf = jax.vmap(lambda b, e, pp, t: b.at[e, pp].set(t, mode="drop"))(
        buf, flat_e, scatter_pos, tok_idx)
    # gather expert inputs; OOB sentinel rows read zeros via padding
    xs_pad = jnp.concatenate([xs, jnp.zeros((G, 1, d), dt)], axis=1)
    exp_in = jnp.take_along_axis(
        xs_pad[:, None, :, :], buf[..., None].clip(0, g), axis=2)  # (G,E,cap,d)
    exp_in = _dp_constraint(exp_in, None, None, None)
    # expert matmuls: TP over the hidden dim; the down-projection's cross-
    # shard reduce runs in the model dtype (half the wire bytes of f32)
    if cfg.mlp == "swiglu":
        gh = jax.nn.silu(jnp.einsum("gecd,edf->gecf", exp_in,
                                    p["wg"].astype(dt),
                                    preferred_element_type=dt))
        uh = jnp.einsum("gecd,edf->gecf", exp_in, p["wu"].astype(dt),
                        preferred_element_type=dt)
        exp_out = jnp.einsum("gecf,efd->gecd", gh * uh, p["wd"].astype(dt),
                             preferred_element_type=dt)
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", exp_in,
                                   p["wu"].astype(dt),
                                   preferred_element_type=dt))
        exp_out = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(dt),
                             preferred_element_type=dt)
    exp_out = _dp_constraint(exp_out, None, None, None)
    # combine: for each (token, k), read its (e, pos) slot
    flat_out = exp_out.reshape(G, E * cap, d)
    slot = flat_e * cap + scatter_pos.clip(0, cap - 1)       # (G, g*K)
    gathered = jnp.take_along_axis(flat_out, slot[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0)
    y = (gathered.reshape(G, g, K, d) *
         gate[..., None]).sum(axis=2)                        # (G, g, d)
    return y.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba): selective scan, chunked
# ---------------------------------------------------------------------------
def mamba1_params(key, cfg):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    dt_rank = max(16, d // 16)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), pd),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di), pd, scale=0.5),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * n), pd),
        "dt_proj": dense_init(ks[3], (dt_rank, di), pd),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)).copy()).astype(pd),
        "D": jnp.ones((di,), pd),
        "out_proj": dense_init(ks[4], (di, d), pd),
    }


def _causal_conv(x, w):
    """x: (B, L, di); w: (k, di) depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out


def mamba1_block(p, cfg, x, chunk=64):
    """x: (B, L, d) -> (B, L, d); L % chunk == 0 assumed (pad upstream)."""
    B, L, d = x.shape
    chunk = min(chunk, L)
    assert L % chunk == 0, f"seq {L} not divisible by chunk {chunk}"
    dt_ = x.dtype
    n = cfg.ssm_state
    di = cfg.d_inner
    xz = x @ p["in_proj"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"].astype(dt_)))
    proj = xi @ p["x_proj"].astype(dt_)
    dt_rank = p["dt_proj"].shape[0]
    dtv, Bv, Cv = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(dtv @ p["dt_proj"].astype(dt_))   # (B, L, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (di, n)

    nc = L // chunk
    xi_c = xi.reshape(B, nc, chunk, di)
    delta_c = delta.reshape(B, nc, chunk, di).astype(jnp.float32)
    B_c = Bv.reshape(B, nc, chunk, n).astype(jnp.float32)
    C_c = Cv.reshape(B, nc, chunk, n).astype(jnp.float32)

    def chunk_step(h, inputs):
        xc, dc, bc, cc = inputs  # (B, chunk, di), ..., (B, chunk, n)
        dA = jnp.exp(dc[..., None] * A[None, None])            # (B,c,di,n)
        dBx = dc[..., None] * bc[:, :, None, :] * \
            xc.astype(jnp.float32)[..., None]                  # (B,c,di,n)
        # within-chunk associative scan (cumulative state)
        def comb(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])
        dAs, hs = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
        hs = hs + dAs * h[:, None]                             # carry in
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, n), jnp.float32)
    _, ys = jax.lax.scan(
        lambda h, inp: chunk_step(h, inp),
        h0, (xi_c.transpose(1, 0, 2, 3), delta_c.transpose(1, 0, 2, 3),
             B_c.transpose(1, 0, 2, 3), C_c.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, di).astype(dt_)
    y = y + xi * p["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_)


def mamba1_decode(p, cfg, x, h, conv_buf):
    """Single-token decode: x (B,1,d), h (B,di,n), conv_buf (B,k-1,di)."""
    dt_ = x.dtype
    n = cfg.ssm_state
    xz = x @ p["in_proj"].astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    w = p["conv_w"].astype(dt_)
    window = jnp.concatenate([conv_buf, xi], axis=1)          # (B, k, di)
    conv_out = jnp.einsum("bkd,kd->bd", window, w)[:, None, :]
    new_buf = window[:, 1:, :]
    xi = jax.nn.silu(conv_out)
    proj = xi @ p["x_proj"].astype(dt_)
    dt_rank = p["dt_proj"].shape[0]
    dtv, Bv, Cv = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(dtv @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(delta[:, 0, :, None] * A[None])              # (B, di, n)
    dBx = (delta[:, 0, :, None] * Bv.astype(jnp.float32)[:, 0, None, :] *
           xi.astype(jnp.float32)[:, 0, :, None])
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cv.astype(jnp.float32)[:, 0])[:, None, :]
    y = y.astype(dt_) + xi * p["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dt_), h, new_buf


# ---------------------------------------------------------------------------
# Mamba2 / SSD (zamba2): chunked state-space duality form
# ---------------------------------------------------------------------------
def mamba2_params(key, cfg):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    pd = jnp.dtype(cfg.param_dtype)
    H = cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + H), pd),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di + 2 * n), pd, scale=0.5),
        "A_log": jnp.zeros((H,), pd),
        "D": jnp.ones((H,), pd),
        "norm_scale": jnp.ones((di,), pd),
        "out_proj": dense_init(ks[2], (di, d), pd),
    }


def mamba2_block(p, cfg, x, chunk=64):
    """SSD (Mamba-2) with scalar-per-head decay; chunked parallel form."""
    B, L, d = x.shape
    chunk = min(chunk, L)
    assert L % chunk == 0, f"seq {L} not divisible by chunk {chunk}"
    dt_ = x.dtype
    n = cfg.ssm_state
    di = cfg.d_inner
    H = cfg.n_heads
    P = di // H
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xbc, dtv = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    # xbc: (B, L, di + 2n) -> conv -> x, B, C
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(dt_)))
    xi, Bv, Cv = jnp.split(xbc, [di, di + n], axis=-1)
    delta = jax.nn.softplus(dtv.astype(jnp.float32))           # (B, L, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    la = delta * A[None, None]                                 # log decay
    xh = xi.reshape(B, L, H, P).astype(jnp.float32)
    xh = xh * delta[..., None]
    nc = L // chunk
    xc = xh.reshape(B, nc, chunk, H, P)
    lac = la.reshape(B, nc, chunk, H)
    Bc = Bv.reshape(B, nc, chunk, n).astype(jnp.float32)
    Cc = Cv.reshape(B, nc, chunk, n).astype(jnp.float32)
    cum = jnp.cumsum(lac, axis=2)                              # (B,nc,c,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,c,c,H)
    iota_q = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 2)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 3)
    Lmat = jnp.where(iota_k <= iota_q, jnp.exp(seg), 0.0)
    scores = jnp.einsum("bgqn,bgkn->bgqk", Cc, Bc)
    intra = jnp.einsum("bgqk,bgqkh,bgkhp->bgqhp", scores, Lmat, xc)
    # inter-chunk: carried state
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,c,H)
    chunk_state = jnp.einsum("bgkn,bgkh,bgkhp->bghnp",
                             Bc, decay_to_end, xc)             # per-chunk contrib
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def carry_step(S, inp):
        cs, cd = inp                                           # (B,H,n,P),(B,H)
        out = S
        S = S * cd[..., None, None] + cs
        return S, out
    S0 = jnp.zeros((B, H, n, P), jnp.float32)
    _, S_in = jax.lax.scan(carry_step, S0,
                           (chunk_state.transpose(1, 0, 2, 3, 4),
                            chunk_decay.transpose(1, 0, 2)))
    S_in = S_in.transpose(1, 0, 2, 3, 4)                       # (B,nc,H,n,P)
    inter = jnp.einsum("bgqn,bgqh,bghnp->bgqhp", Cc, jnp.exp(cum), S_in)
    y = (intra + inter).reshape(B, L, H, P)
    y = y + xh.reshape(B, L, H, P) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, L, di).astype(dt_)
    y = y * jax.nn.silu(z)
    y = y * p["norm_scale"].astype(dt_)
    return y @ p["out_proj"].astype(dt_)


def mamba2_decode(p, cfg, x, S, conv_buf):
    """One-token SSD decode: S (B,H,n,P), conv_buf (B,k-1,di+2n)."""
    B = x.shape[0]
    dt_ = x.dtype
    n, di, H = cfg.ssm_state, cfg.d_inner, cfg.n_heads
    P = di // H
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xbc, dtv = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    w = p["conv_w"].astype(dt_)
    window = jnp.concatenate([conv_buf, xbc], axis=1)
    conv_out = jnp.einsum("bkd,kd->bd", window, w)[:, None, :]
    new_buf = window[:, 1:, :]
    xbc = jax.nn.silu(conv_out)
    xi, Bv, Cv = jnp.split(xbc, [di, di + n], axis=-1)
    delta = jax.nn.softplus(dtv.astype(jnp.float32))[:, 0]     # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(delta * A[None])                             # (B, H)
    xh = xi.reshape(B, H, P).astype(jnp.float32) * delta[..., None]
    Bf = Bv.astype(jnp.float32)[:, 0]
    Cf = Cv.astype(jnp.float32)[:, 0]
    S = S * dec[..., None, None] + jnp.einsum("bn,bhp->bhnp", Bf, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cf, S)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(dt_)
    y = y * jax.nn.silu(z) * p["norm_scale"].astype(dt_)
    return y @ p["out_proj"].astype(dt_), S, new_buf
