"""Model assembly: decoder LMs (dense/MoE/SWA), hybrid Mamba2+shared-attn
(zamba2), pure SSM (falcon-mamba), encoder-decoder (whisper), and VLM stub
(internvl2). One forward for train/prefill, one step for decode.

Params are nested dicts; abstract shapes via jax.eval_shape(init_params, ...)
feed the multi-pod dry-run without allocation.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import ModelConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_params(key, cfg: ModelConfig, enc=False):
    ks = jax.random.split(key, 6)
    p = {"norm1": L.norm_params(cfg)}
    if cfg.block_type == "attn" or enc:
        p["attn"] = L.attn_params(ks[0], cfg)
        p["norm2"] = L.norm_params(cfg)
        if cfg.moe_experts and not enc:
            p["moe"] = L.mlp_params(ks[1], cfg, n_experts=cfg.moe_experts)
        elif cfg.d_ff:
            p["mlp"] = L.mlp_params(ks[1], cfg)
        if cfg.enc_dec and not enc:
            p["cross"] = L.attn_params(ks[2], cfg)
            p["norm3"] = L.norm_params(cfg)
    elif cfg.block_type == "mamba1":
        p["mamba"] = L.mamba1_params(ks[0], cfg)
    elif cfg.block_type == "mamba2":
        p["mamba"] = L.mamba2_params(ks[0], cfg)
    return p


def can_scan(cfg: ModelConfig) -> bool:
    """Decoder stacks are scanned over a stacked param pytree (compile time
    stays O(1) in depth at 512-way SPMD). zamba2's shared attention block is
    handled inside the scan via a per-layer flag + lax.cond (+ a carried
    shared-KV stack at decode). Only enc-dec (whisper, 6 layers) unrolls."""
    return not cfg.enc_dec


def init_params(cfg: ModelConfig, key=None, scan_layers: bool = None):
    key = key if key is not None else jax.random.PRNGKey(0)
    scan_layers = can_scan(cfg) if scan_layers is None else scan_layers
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 4)
    if scan_layers and can_scan(cfg):
        layer_keys = jax.random.split(ks[2], cfg.n_layers)
        layers = jax.vmap(lambda k: _block_params(k, cfg))(layer_keys)
    else:
        layers = [_block_params(ks[2 + i], cfg) for i in range(cfg.n_layers)]
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02
                  ).astype(pd),
        "unembed": L.dense_init(ks[1], (cfg.d_model, cfg.vocab), pd),
        "norm_f": L.norm_params(cfg),
        "layers": layers,
    }
    if cfg.shared_attn_every:
        shared_key = jax.random.split(ks[-1], 2)
        params["shared_attn"] = {
            "norm1": L.norm_params(cfg),
            "attn": L.attn_params(shared_key[0], cfg),
            "norm2": L.norm_params(cfg),
            "mlp": L.mlp_params(shared_key[1], cfg),
        }
    if cfg.enc_dec:
        eks = jax.random.split(ks[-2], cfg.enc_layers + 1)
        params["encoder"] = {
            "layers": [_block_params(eks[i], cfg, enc=True)
                       for i in range(cfg.enc_layers)],
            "norm_f": L.norm_params(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _pos_embed_sinusoidal(length, d, dtype):
    pos = np.arange(length)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    pe = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(pe, dtype)


def _encoder_forward(params, cfg: ModelConfig, frames):
    """frames: (B, T, d) precomputed stub embeddings (conv frontend stubbed)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _pos_embed_sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    for blk in params["encoder"]["layers"]:
        h = L.apply_norm(blk["norm1"], cfg, x)
        x = x + L.self_attention(blk["attn"], cfg, h, causal=False,
                                 use_rope=False)
        h = L.apply_norm(blk["norm2"], cfg, x)
        x = x + L.apply_mlp(blk["mlp"], cfg, h)
    return L.apply_norm(params["encoder"]["norm_f"], cfg, x)


def _decoder_block(blk, cfg: ModelConfig, x, memory=None, shared=None,
                   layer_idx=0):
    if cfg.block_type == "attn":
        h = L.apply_norm(blk["norm1"], cfg, x)
        x = x + L.self_attention(blk["attn"], cfg, h,
                                 use_rope=not cfg.enc_dec)
        if cfg.enc_dec and memory is not None:
            h = L.apply_norm(blk["norm3"], cfg, x)
            x = x + L.cross_attention(blk["cross"], cfg, h, memory)
        h = L.apply_norm(blk["norm2"], cfg, x)
        if cfg.moe_experts:
            x = x + L.apply_moe(blk["moe"], cfg, h)
        else:
            x = x + L.apply_mlp(blk["mlp"], cfg, h)
    else:
        h = L.apply_norm(blk["norm1"], cfg, x)
        if cfg.block_type == "mamba1":
            x = x + L.mamba1_block(blk["mamba"], cfg, h)
        else:
            x = x + L.mamba2_block(blk["mamba"], cfg, h)
    if shared is not None and cfg.shared_attn_every and \
            (layer_idx + 1) % cfg.shared_attn_every == 0:
        h = L.apply_norm(shared["norm1"], cfg, x)
        x = x + L.self_attention(shared["attn"], cfg, h)
        h = L.apply_norm(shared["norm2"], cfg, x)
        x = x + L.apply_mlp(shared["mlp"], cfg, h)
    return x


def forward(params, cfg: ModelConfig, tokens, frontend_embeds=None):
    """tokens: (B, S_tok). With a frontend, ``frontend_embeds`` (B, F, d) is
    prepended (VLM patches / audio goes to the encoder instead). Returns
    logits (B, S, vocab)."""
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dt)
    memory = None
    if cfg.frontend == "vlm" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(dt), x], axis=1)
    if cfg.enc_dec:
        memory = _encoder_forward(params, cfg, frontend_embeds)
        x = x + _pos_embed_sinusoidal(x.shape[1], cfg.d_model, dt)[None]
    shared = params.get("shared_attn")

    if isinstance(params["layers"], dict):
        # stacked params: scan over the layer dimension
        flags = _shared_flags(cfg)

        def body(x, xs):
            blk, flag = xs
            y = _decoder_block(blk, cfg, x, memory, None, 0)
            if cfg.shared_attn_every:
                def with_shared(xx):
                    h = L.apply_norm(shared["norm1"], cfg, xx)
                    xx = xx + L.self_attention(shared["attn"], cfg, h)
                    h = L.apply_norm(shared["norm2"], cfg, xx)
                    return xx + L.apply_mlp(shared["mlp"], cfg, h)
                y = jax.lax.cond(flag, with_shared, lambda xx: xx, y)
            return y, None
        scan_body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(scan_body, x, (params["layers"], flags))
    else:
        for i, blk in enumerate(params["layers"]):
            body = lambda xx: _decoder_block(blk, cfg, xx, memory, shared, i)
            if cfg.remat:
                body = jax.checkpoint(body)
            x = body(x)
    x = L.apply_norm(params["norm_f"], cfg, x)
    return x @ params["unembed"].astype(dt)


# ---------------------------------------------------------------------------
# decode (one token, cache-carrying)
# ---------------------------------------------------------------------------
def _shared_flags(cfg: ModelConfig):
    if not cfg.shared_attn_every:
        return jnp.zeros(cfg.n_layers, bool)
    return (jnp.arange(cfg.n_layers) + 1) % cfg.shared_attn_every == 0


def n_shared_blocks(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every \
        else 0


def _layer_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int, i: int,
                        lead=()):
    dt = jnp.dtype(cfg.dtype)
    lc = {}
    if cfg.block_type == "attn":
        kv = lead + (batch, max_seq, cfg.n_kv, cfg.hd)
        lc["k"] = jax.ShapeDtypeStruct(kv, dt)
        lc["v"] = jax.ShapeDtypeStruct(kv, dt)
    elif cfg.block_type == "mamba1":
        lc["h"] = jax.ShapeDtypeStruct(
            lead + (batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        lc["conv"] = jax.ShapeDtypeStruct(
            lead + (batch, cfg.ssm_conv - 1, cfg.d_inner), dt)
    else:  # mamba2
        lc["S"] = jax.ShapeDtypeStruct(
            lead + (batch, cfg.n_heads, cfg.ssm_state,
                    cfg.d_inner // cfg.n_heads), jnp.float32)
        lc["conv"] = jax.ShapeDtypeStruct(
            lead + (batch, cfg.ssm_conv - 1,
                    cfg.d_inner + 2 * cfg.ssm_state), dt)
    if lead == () and cfg.shared_attn_every and \
            (i + 1) % cfg.shared_attn_every == 0:
        kv = (batch, max_seq, cfg.n_kv, cfg.hd)
        lc["shared_k"] = jax.ShapeDtypeStruct(kv, dt)
        lc["shared_v"] = jax.ShapeDtypeStruct(kv, dt)
    return lc


def init_cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                      scan_layers: bool = None):
    """ShapeDtypeStructs for the decode cache (used by the dry-run).

    Scanned stacks get one stacked cache dict (n_layers leading dim); the
    zamba2 shared-attention KV stack is a separate (n_shared, ...) entry
    carried through the scan."""
    dt = jnp.dtype(cfg.dtype)
    scan_layers = can_scan(cfg) if scan_layers is None else scan_layers
    c = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if scan_layers and can_scan(cfg):
        c["layers"] = _layer_cache_shapes(cfg, batch, max_seq, 0,
                                          lead=(cfg.n_layers,))
        if cfg.shared_attn_every:
            ns = n_shared_blocks(cfg)
            kv = (ns, batch, max_seq, cfg.n_kv, cfg.hd)
            c["shared"] = {"k": jax.ShapeDtypeStruct(kv, dt),
                           "v": jax.ShapeDtypeStruct(kv, dt)}
    else:
        c["layers"] = [_layer_cache_shapes(cfg, batch, max_seq, i)
                       for i in range(cfg.n_layers)]
    if cfg.enc_dec:
        c["memory"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_len, cfg.d_model), dt)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               scan_layers: bool = None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_shapes(cfg, batch, max_seq, scan_layers))


def _decode_layer(blk, cfg: ModelConfig, x, lc, pos, memory=None):
    """One decoder layer of single-token decode; returns (x, new layer cache)."""
    lc = dict(lc)
    if cfg.block_type == "attn":
        h = L.apply_norm(blk["norm1"], cfg, x)
        o, lc["k"], lc["v"] = L.decode_attention(
            blk["attn"], cfg, h, lc["k"], lc["v"], pos,
            use_rope=not cfg.enc_dec)
        x = x + o
        if cfg.enc_dec:
            h = L.apply_norm(blk["norm3"], cfg, x)
            x = x + L.cross_attention(blk["cross"], cfg, h, memory)
        h = L.apply_norm(blk["norm2"], cfg, x)
        if cfg.moe_experts:
            x = x + L.apply_moe(blk["moe"], cfg, h, group_size=64)
        else:
            x = x + L.apply_mlp(blk["mlp"], cfg, h)
    elif cfg.block_type == "mamba1":
        h = L.apply_norm(blk["norm1"], cfg, x)
        o, lc["h"], lc["conv"] = L.mamba1_decode(blk["mamba"], cfg, h,
                                                 lc["h"], lc["conv"])
        x = x + o
    else:
        h = L.apply_norm(blk["norm1"], cfg, x)
        o, lc["S"], lc["conv"] = L.mamba2_decode(blk["mamba"], cfg, h,
                                                 lc["S"], lc["conv"])
        x = x + o
    return x, lc


def decode_step(params, cfg: ModelConfig, cache, token):
    """token: (B, 1) int32. Returns (logits (B,1,V), new_cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"][token].astype(dt)
    pos = cache["pos"]
    if isinstance(params["layers"], dict):
        # scanned stack; shared-attn KV stack is carried with a counter
        shared = params.get("shared_attn")
        flags = _shared_flags(cfg)

        def body(carry, xs):
            xx, sk, sv, cnt = carry
            blk, lc, flag = xs
            xx, lc = _decode_layer(blk, cfg, xx, lc, pos)
            if cfg.shared_attn_every:
                def do_shared(op):
                    xx, sk, sv, cnt = op
                    k_i = jax.lax.dynamic_index_in_dim(sk, cnt, 0,
                                                       keepdims=False)
                    v_i = jax.lax.dynamic_index_in_dim(sv, cnt, 0,
                                                       keepdims=False)
                    h = L.apply_norm(shared["norm1"], cfg, xx)
                    o, k_i, v_i = L.decode_attention(shared["attn"], cfg, h,
                                                     k_i, v_i, pos)
                    xx = xx + o
                    h = L.apply_norm(shared["norm2"], cfg, xx)
                    xx = xx + L.apply_mlp(shared["mlp"], cfg, h)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, k_i, cnt, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, v_i, cnt, 0)
                    return (xx, sk, sv, cnt + 1)
                xx, sk, sv, cnt = jax.lax.cond(flag, do_shared,
                                               lambda op: op,
                                               (xx, sk, sv, cnt))
            return (xx, sk, sv, cnt), lc

        if cfg.shared_attn_every:
            sk0, sv0 = cache["shared"]["k"], cache["shared"]["v"]
        else:
            sk0 = jnp.zeros((1, 1, 1, 1, 1), dt)
            sv0 = sk0
        (x, sk, sv, _), new_layers = jax.lax.scan(
            body, (x, sk0, sv0, jnp.zeros((), jnp.int32)),
            (params["layers"], cache["layers"], flags))
        x = L.apply_norm(params["norm_f"], cfg, x)
        logits = x @ params["unembed"].astype(dt)
        out = {"pos": pos + 1, "layers": new_layers}
        if cfg.shared_attn_every:
            out["shared"] = {"k": sk, "v": sv}
        if cfg.enc_dec:
            out["memory"] = cache["memory"]
        return logits, out
    if cfg.enc_dec:
        # sinusoidal position at the dynamic decode index (f32-pinned)
        i = jnp.arange(cfg.d_model // 2, dtype=jnp.float32)
        ang = pos.astype(jnp.float32) / jnp.power(
            jnp.float32(10000.0), 2.0 * i / cfg.d_model)
        pe_dyn = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
        x = x + pe_dyn.astype(dt)
    shared = params.get("shared_attn")
    new_layers = []
    for i, blk in enumerate(params["layers"]):
        lc = dict(cache["layers"][i])
        if cfg.block_type == "attn":
            h = L.apply_norm(blk["norm1"], cfg, x)
            o, lc["k"], lc["v"] = L.decode_attention(
                blk["attn"], cfg, h, lc["k"], lc["v"], pos,
                use_rope=not cfg.enc_dec)
            x = x + o
            if cfg.enc_dec:
                h = L.apply_norm(blk["norm3"], cfg, x)
                x = x + L.cross_attention(blk["cross"], cfg, h,
                                          cache["memory"])
            h = L.apply_norm(blk["norm2"], cfg, x)
            if cfg.moe_experts:
                x = x + L.apply_moe(blk["moe"], cfg, h, group_size=64)
            else:
                x = x + L.apply_mlp(blk["mlp"], cfg, h)
        elif cfg.block_type == "mamba1":
            h = L.apply_norm(blk["norm1"], cfg, x)
            o, lc["h"], lc["conv"] = L.mamba1_decode(blk["mamba"], cfg, h,
                                                     lc["h"], lc["conv"])
            x = x + o
        else:
            h = L.apply_norm(blk["norm1"], cfg, x)
            o, lc["S"], lc["conv"] = L.mamba2_decode(blk["mamba"], cfg, h,
                                                     lc["S"], lc["conv"])
            x = x + o
        if shared is not None and cfg.shared_attn_every and \
                (i + 1) % cfg.shared_attn_every == 0:
            h = L.apply_norm(shared["norm1"], cfg, x)
            o, lc["shared_k"], lc["shared_v"] = L.decode_attention(
                shared["attn"], cfg, h, lc["shared_k"], lc["shared_v"], pos)
            x = x + o
            h = L.apply_norm(shared["norm2"], cfg, x)
            x = x + L.apply_mlp(shared["mlp"], cfg, h)
        new_layers.append(lc)
    x = L.apply_norm(params["norm_f"], cfg, x)
    logits = x @ params["unembed"].astype(dt)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + 1
    return logits, new_cache
