"""Model configuration covering the 10 assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    mlp: str = "swiglu"               # swiglu | gelu
    qkv_bias: bool = False
    proj_bias: bool = False           # out-proj / mlp biases (starcoder2)
    rope_theta: float = 1e4
    sliding_window: int = 0           # 0 = full attention
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    block_type: str = "attn"          # attn | mamba1 | mamba2
    shared_attn_every: int = 0        # zamba2: shared attn block cadence
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    enc_dec: bool = False
    enc_layers: int = 0
    frontend: str = "none"            # none | audio | vlm (stub embeddings)
    frontend_len: int = 0
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_ssm(self) -> bool:
        return self.block_type in ("mamba1", "mamba2")

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        return self.is_ssm or self.sliding_window > 0

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_heads = 4
        n_kv = max(1, min(self.n_kv * 4 // max(self.n_heads, 1), 4)) \
            if self.n_kv else 4
        return replace(
            self, n_layers=2, d_model=64, n_heads=n_heads, n_kv=n_kv or 4,
            d_ff=128 if self.d_ff else 0, vocab=128, head_dim=16,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window
            else 0,
            moe_experts=4 if self.moe_experts else 0,
            moe_top_k=2 if self.moe_top_k else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            ssm_state=8 if self.ssm_state else 0,
            enc_layers=2 if self.enc_dec else 0,
            frontend_len=8 if self.frontend != "none" else 0,
            dtype="float32", remat=False)


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (embedding + blocks + head)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    total = v * d * 2  # embed + unembed
    per_layer = 0
    if cfg.block_type == "attn" or cfg.shared_attn_every:
        qkv = d * (cfg.n_heads + 2 * cfg.n_kv) * cfg.hd
        per_layer += qkv + cfg.n_heads * cfg.hd * d
    if cfg.block_type == "mamba1":
        di = cfg.d_inner
        per_layer += d * 2 * di + di * d + di * (2 * cfg.ssm_state + 2)
    if cfg.block_type == "mamba2":
        di = cfg.d_inner
        per_layer += d * 2 * di + di * d + di * cfg.ssm_state
    if ff:
        n_mat = 3 if cfg.mlp == "swiglu" else 2
        ff_params = n_mat * d * ff
        if cfg.moe_experts:
            per_layer += cfg.moe_experts * ff_params + d * cfg.moe_experts
        else:
            per_layer += ff_params
    return total + cfg.n_layers * per_layer


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) parameters — MoE counts top_k experts only."""
    if not cfg.moe_experts:
        return param_count(cfg)
    dense = param_count(cfg)
    n_mat = 3 if cfg.mlp == "swiglu" else 2
    ff_params = n_mat * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.moe_experts - cfg.moe_top_k) * ff_params
    return dense - inactive
