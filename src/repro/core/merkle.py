"""Merkle tree over BabyBear rows (Poseidon-like compression).

Commits to a 2D matrix (n_leaves, row_width): leaf i hashes row i, internal
nodes use 2-to-1 compression. Openings return the row plus the authentication
path. All layers are materialized as jnp arrays (prover-side); verification is
pure and cheap.

Every hash here goes through ``hashing.permute``, which dispatches to the
active compute backend (:mod:`repro.core.backend`): each tree level is one
batched permutation call — ``(n/2, 16)`` states for level builds, ``(n, 16)``
per sponge block for the leaves — so the ``pallas`` backends run the whole
build through the kernel with no per-node Python overhead.  Roots are
bit-identical across backends.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import jax
import jax.numpy as jnp
import numpy as np

from . import field as F
from . import hashing as H

_U32 = jnp.uint32


@dataclass
class MerkleTree:
    leaves: jnp.ndarray          # (n, width) committed rows
    layers: list                 # [(n,8), (n/2,8), ..., (1,8)]

    @property
    def root(self) -> jnp.ndarray:
        return self.layers[-1][0]


def commit(rows: jnp.ndarray) -> MerkleTree:
    """rows: (n, width) with n a power of two."""
    n = rows.shape[0]
    assert n & (n - 1) == 0, "leaf count must be a power of two"
    layer = H.hash_rows(rows)                       # (n, 8)
    layers = [layer]
    while layer.shape[0] > 1:
        layer = H.compress(layer[0::2], layer[1::2])
        layers.append(layer)
    return MerkleTree(leaves=rows, layers=layers)


def open_at(tree: MerkleTree, indices: jnp.ndarray):
    """Open leaves at ``indices`` (k,). Returns (rows (k,width), path (k,d,8))."""
    rows = tree.leaves[indices]
    sibs = []
    idx = indices
    for layer in tree.layers[:-1]:
        sibs.append(layer[idx ^ 1])
        idx = idx // 2
    path = jnp.stack(sibs, axis=1) if sibs else jnp.zeros((len(indices), 0, 8), _U32)
    return rows, path


# ---------------------------------------------------------------------------
# lane-batched trees (repro.core.prover_batch): L same-shaped commitments in
# one pass.  ``hash_rows``/``compress`` support leading batch dims and every
# hash is row-independent, so lane l of the batched tree is bit-identical to
# ``commit(rows[l])`` — one permutation dispatch per level instead of L.
# ---------------------------------------------------------------------------
@dataclass
class BatchedMerkleTree:
    leaves: jnp.ndarray          # (L, n, width) committed rows
    layers: list                 # [(L,n,8), (L,n/2,8), ..., (L,1,8)]

    @property
    def roots(self) -> jnp.ndarray:
        return self.layers[-1][:, 0]                    # (L, 8)


def commit_lanes(rows: jnp.ndarray) -> BatchedMerkleTree:
    """rows: (L, n, width) with n a power of two — L trees in lockstep."""
    n = rows.shape[1]
    assert n & (n - 1) == 0, "leaf count must be a power of two"
    layer = H.hash_rows(rows)                           # (L, n, 8)
    layers = [layer]
    while layer.shape[1] > 1:
        layer = H.compress(layer[:, 0::2], layer[:, 1::2])
        layers.append(layer)
    return BatchedMerkleTree(leaves=rows, layers=layers)


def open_lanes(tree: BatchedMerkleTree, indices: jnp.ndarray):
    """Open per-lane leaves at ``indices`` (L, k).

    Returns (rows (L,k,width), path (L,k,d,8)) — lane l equals
    ``open_at(tree_l, indices[l])``."""
    idx = jnp.asarray(indices)
    rows = jnp.take_along_axis(tree.leaves, idx[:, :, None], axis=1)
    sibs = []
    for layer in tree.layers[:-1]:
        sibs.append(jnp.take_along_axis(layer, (idx ^ 1)[:, :, None], axis=1))
        idx = idx // 2
    path = jnp.stack(sibs, axis=2) if sibs else \
        jnp.zeros(idx.shape + (0, 8), _U32)
    return rows, path


def compress_pair(left, right) -> np.ndarray:
    """Numpy-facing 2-to-1 node hash: (8,), (8,) -> (8,) uint32.

    The internal-node hash of the transparency log (repro.core.transparency)
    — the same Poseidon compression the proof trees use, so a log verifier
    needs no second hash implementation."""
    l = jnp.asarray(left, _U32).reshape(1, 8)
    r = jnp.asarray(right, _U32).reshape(1, 8)
    return np.asarray(H.compress(l, r)[0], np.uint32)


def verify_open(root, indices, rows, path) -> jnp.ndarray:
    """Vectorized path check. Returns bool scalar (all openings valid)."""
    node = H.hash_rows(rows)                       # (k, 8)
    idx = jnp.asarray(indices)
    ok = jnp.array(True)
    depth = path.shape[1]
    for d in range(depth):
        sib = path[:, d]
        is_right = (idx & 1).astype(bool)[:, None]
        left = jnp.where(is_right, sib, node)
        right = jnp.where(is_right, node, sib)
        node = H.compress(left, right)
        idx = idx // 2
    ok = jnp.all(node == root[None, :])
    return ok
