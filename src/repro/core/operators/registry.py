"""Operator registry: plan-node types -> circuit adapters.

Each adapter knows how to lower one IR node kind to a primitive operator
circuit:

* ``shape(db, node, env)``   — serializable build kwargs (circuit geometry)
* ``build(shape)``           — construct the circuit (no data needed, so the
                               *verifier* can rebuild it from a proof bundle)
* ``witness(db, op, node, env)`` — run the untrusted engine + fill columns
* ``extract_outputs(op, instance)`` — public outputs for chaining, read from
                               the instance only (so the verifier can extract
                               them from a *verified* proof)
* ``chained_cols(node, env)`` — recompute a chained intermediate table from
                               earlier outputs (prover and verifier must
                               agree bit-for-bit; this is the chain glue)

Registering a new operator is ``register(MyAdapter())`` — the planner,
session, and verifier pick it up without modification.
"""
from __future__ import annotations

import numpy as np

from ...graphdb import engine, ldbc, tables
from ...graphdb.storage import pad_pow2
from .. import field as F
from .. import ir
from . import aggregate, expansion, orderby, set_expansion, sssp
from . import filter as filtering
from .common import Operator

_BY_KIND: dict = {}    # node type -> adapter instance
_BY_NAME: dict = {}    # adapter name -> adapter instance


def register(adapter):
    """Register an adapter for its node type. Later registrations for the
    same node type override earlier ones (so projects can swap circuits)."""
    _BY_KIND[adapter.kind] = adapter
    _BY_NAME[adapter.name] = adapter
    return adapter


def adapter_for(node):
    try:
        return _BY_KIND[type(node)]
    except KeyError:
        raise KeyError(f"no adapter registered for node type "
                       f"{type(node).__name__}") from None


def adapters() -> dict:
    """Every registered adapter by name (the soundness analyzer iterates
    this: a new adapter is vetted the moment it is registered)."""
    return dict(_BY_NAME)


def adapter_named(name: str):
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(f"no adapter named {name!r}; "
                       f"known: {sorted(_BY_NAME)}") from None


def build_operator(name: str, shape: dict) -> Operator:
    """Verifier-side circuit reconstruction from a bundle's step record."""
    return adapter_named(name).build(shape)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def _table_cols(db, table, env: ir.Env) -> np.ndarray:
    # memoized per execution: shape() and witness() share the resolution
    key = ("cols", table)
    cols = env.memo.get(key)
    if cols is None:
        if isinstance(table, ir.BaseTable):
            cols = tables.base_table_cols(db, table.desc)
        elif isinstance(table, ir.Chained):
            cols = table.resolve_cols(env)
        else:
            raise TypeError(f"unsupported table ref {table!r}")
        env.memo[key] = cols
    return cols


def _desc_of(table) -> str:
    return table.desc if isinstance(table, ir.BaseTable) else "chained"


def _selected(op: Operator, instance, col: str) -> np.ndarray:
    sel = instance[op.handles["out_sel"].index] == 1
    return instance[op.handles[col].index][sel].astype(np.int64)


class Adapter:
    kind: type = None
    name: str = ""
    #: serializable circuit-geometry schema: shape-dict key -> exact type.
    #: The wire codec and the verifier both reject a step whose declared
    #: shape deviates from this (extra/missing keys, bool-vs-int confusion).
    shape_schema: dict = {}

    def data_desc(self, node) -> str:
        return _desc_of(node.table)

    def shape_flags(self, node) -> dict:
        """The shape fields derivable from the plan node alone (no db, no
        outputs). The verifier pins these against a bundle's declared shape
        — a prover cannot flip semantic circuit flags (reverse, bidirectional,
        …) on a base-table step."""
        return {}

    def manifest_pins(self, node, env: ir.Env, manifest, geo) -> dict:
        """Shape fields pinned by the owner's PUBLISHED manifest for a
        base-table step (``geo`` is the table's :class:`TableGeometry`).
        Together with :meth:`shape_flags` and the published-size membership
        check this pins the step's full circuit geometry — the verifier
        never trusts row counts from the prover's bundle."""
        return dict(n_rows=pad_pow2(geo.n_table_rows),
                    m_edges=geo.n_table_rows)

    def check_instance(self, op: Operator, instance, node, env: ir.Env) -> bool:
        """Verifier-side: the public inputs embedded in the instance must
        equal the plan-resolved bindings — otherwise a prover could answer a
        *different* query (other source id, other id set) than the one the
        bundle claims in ``params``."""
        return True

    def chained_cols(self, node, env: ir.Env) -> np.ndarray:
        assert isinstance(node.table, ir.Chained), \
            f"{self.name} step is bound to a base table, not chained"
        return _table_cols(None, node.table, env)   # shares the env memo

    def analysis_cases(self, db) -> list:
        """Representative shapes for the soundness analyzer
        (``repro.analysis``): >= 2 tuples ``(label, mini_plan, params)``
        whose LAST node is this adapter's node type.  Mandatory for every
        registered adapter — the analysis CI job fails a registry whose
        adapters cannot be probed (docs/analysis.md, 'vetting a new
        adapter')."""
        raise NotImplementedError(
            f"adapter {self.name!r} declares no analysis_cases(); every "
            f"registered adapter must be analyzable (docs/analysis.md)")


def _col_equals(op: Operator, instance, handle: str, value: int) -> bool:
    col = np.asarray(instance[op.handles[handle].index], np.int64)
    return bool((col == int(value) % F.P).all())


# ---------------------------------------------------------------------------
# Expand (§IV-A edge-list) — also the base for NameFilter
# ---------------------------------------------------------------------------
class ExpandAdapter(Adapter):
    kind = ir.Expand
    name = "expand"
    shape_schema = dict(n_rows=int, m_edges=int, with_prop=bool, reverse=bool)

    def _source(self, node, env):
        return int(ir.resolve(node.source, env))

    def _flags(self, node):
        return node.with_prop, node.reverse

    def shape_flags(self, node) -> dict:
        with_prop, reverse = self._flags(node)
        return dict(with_prop=with_prop, reverse=reverse)

    def shape(self, db, node, env: ir.Env) -> dict:
        cols = _table_cols(db, node.table, env)
        return dict(n_rows=pad_pow2(cols.shape[1]), m_edges=int(cols.shape[1]),
                    **self.shape_flags(node))

    def build(self, shape: dict) -> Operator:
        return expansion.build_edge_list(**shape)

    def witness(self, db, op: Operator, node, env: ir.Env):
        cols = _table_cols(db, node.table, env)
        with_prop, _ = self._flags(node)
        return expansion.witness_edge_list(
            op, cols[0], cols[1], self._source(node, env),
            cols[2] if with_prop else None)

    def extract_outputs(self, op: Operator, instance) -> dict:
        out = dict(src=_selected(op, instance, "C_s"),
                   dst=_selected(op, instance, "C_t"))
        if op.handles["with_prop"]:
            out["prop"] = _selected(op, instance, "C_p")
        return out

    def check_instance(self, op, instance, node, env: ir.Env) -> bool:
        return _col_equals(op, instance, "id_s", self._source(node, env))

    def analysis_cases(self, db) -> list:
        def plan(label, node):
            return (label, ir.Plan(f"analysis/{label}", (node,), {}), {})
        return [
            plan("hasCreator", ir.Expand(ir.BaseTable("hasCreator"),
                                         ir.Lit(ldbc.COMMENT_BASE + 7))),
            plan("knows_prop", ir.Expand(ir.BaseTable("knows_date"),
                                         ir.Lit(1), with_prop=True)),
            plan("knows_prop_rev", ir.Expand(ir.BaseTable("knows_date"),
                                             ir.Lit(2), with_prop=True,
                                             reverse=True)),
        ]


class NameFilterAdapter(ExpandAdapter):
    """Attribute filter = reversed expansion over a chained (id, attr) table:
    flag rows whose attr equals the public name, emit the matching ids."""
    kind = ir.NameFilter
    name = "name_filter"

    def _source(self, node, env):
        return int(ir.resolve(node.name, env))

    def _flags(self, node):
        return False, True     # reversed expansion, no property column

    def analysis_cases(self, db) -> list:
        names = db.node_props["person"]["firstName"]

        def case(label, ids, name):
            scaffold = ir.SetExpand(ir.BaseTable("person_firstName"),
                                    ir.Lit(tuple(int(i) for i in ids)))
            filt = ir.NameFilter(ir.Chained((ir.Out(0, "src"),
                                             ir.Out(0, "dst"))),
                                 ir.Lit(int(name)))
            return (label, ir.Plan(f"analysis/{label}", (scaffold, filt), {}),
                    {})
        return [case("match_first", np.arange(1, 9), names[0]),
                case("match_none", np.arange(1, 5), 0)]


# ---------------------------------------------------------------------------
# SetExpand (§IV-B, integrated BiRC per §IV-D)
# ---------------------------------------------------------------------------
class SetExpandAdapter(Adapter):
    kind = ir.SetExpand
    name = "set_expand"
    shape_schema = dict(n_rows=int, m_edges=int, set_size=int,
                        bidirectional=bool)

    def manifest_pins(self, node, env: ir.Env, manifest, geo) -> dict:
        # n_rows also depends on the (proof-determined) output count, so it
        # is bounded by published-size membership rather than pinned exactly
        ids = self._ids(None, node, env)
        return dict(m_edges=geo.n_table_rows, set_size=int(len(ids)))

    def _ids(self, db, node, env: ir.Env) -> np.ndarray:
        key = ("ids", node)
        ids = env.memo.get(key)
        if ids is None:
            ids = np.unique(np.asarray(ir.resolve(node.ids, env), np.int64))
            if len(ids) == 0:
                # the circuit needs a non-empty set; use the reserved public
                # sentinel (never a valid id), so an empty start set expands
                # to nothing — and the verifier re-derives it without the db
                ids = np.asarray([set_expansion.EMPTY_SET_ID], np.int64)
            else:
                assert int(ids.max()) < set_expansion.EMPTY_SET_ID, \
                    "ids collide with the reserved empty-set sentinel"
            env.memo[key] = ids
        return ids

    def shape(self, db, node, env: ir.Env) -> dict:
        cols = _table_cols(db, node.table, env)
        src, dst = cols[0], cols[1]
        ids = self._ids(db, node, env)
        # output rows can exceed the edge region (bidirectional doubles
        # matches), so size the circuit to the actual output count
        out_count = int(np.isin(src, ids).sum())
        if node.bidirectional:
            out_count += int(np.isin(dst, ids).sum())
        n_rows = pad_pow2(max(len(src), len(ids) + 2, out_count))
        return dict(n_rows=n_rows, m_edges=int(len(src)),
                    set_size=int(len(ids)), **self.shape_flags(node))

    def shape_flags(self, node) -> dict:
        return dict(bidirectional=node.bidirectional)

    def build(self, shape: dict) -> Operator:
        return set_expansion.build(**shape)

    def witness(self, db, op: Operator, node, env: ir.Env):
        cols = _table_cols(db, node.table, env)
        return set_expansion.witness(op, cols[0], cols[1],
                                     self._ids(db, node, env))

    def extract_outputs(self, op: Operator, instance) -> dict:
        return dict(src=_selected(op, instance, "C_s"),
                    dst=_selected(op, instance, "C_t"))

    def check_instance(self, op, instance, node, env: ir.Env) -> bool:
        ids = self._ids(None, node, env)    # db-free (public bindings only)
        s_ext = np.concatenate([[0], np.sort(ids),
                                [set_expansion.ID_MAX]]).astype(np.int64)
        col = np.asarray(instance[op.handles["IDs"].index], np.int64)
        want = np.zeros(op.circuit.n_rows, np.int64)
        if len(s_ext) > len(want):
            return False
        want[: len(s_ext)] = s_ext
        return bool((col == want).all())

    def analysis_cases(self, db) -> list:
        def plan(label, node):
            return (label, ir.Plan(f"analysis/{label}", (node,), {}), {})
        return [
            plan("knows_bidir", ir.SetExpand(
                ir.BaseTable("knows"), ir.Lit((1, 2, 3)),
                bidirectional=True)),
            plan("firstName", ir.SetExpand(
                ir.BaseTable("person_firstName"),
                ir.Lit(tuple(range(1, 7))))),
        ]


# ---------------------------------------------------------------------------
# OrderBy (§IV-E) — always chained: its table is earlier nodes' outputs
# ---------------------------------------------------------------------------
class OrderByAdapter(Adapter):
    kind = ir.OrderBy
    name = "orderby"
    shape_schema = dict(n_rows=int, m_in=int, k=int, descending=bool)

    def _vals_pay(self, node, env: ir.Env):
        vals = np.asarray(ir.resolve(node.values, env), np.int64)
        pay = np.asarray(ir.resolve(node.payload, env), np.int64)
        if len(vals) == 0:
            vals, pay = np.asarray([0]), np.asarray([0])
        return vals, pay

    def data_desc(self, node) -> str:
        return "chained"

    def chained_cols(self, node, env: ir.Env) -> np.ndarray:
        vals, pay = self._vals_pay(node, env)
        return np.stack([vals, pay])

    def shape(self, db, node, env: ir.Env) -> dict:
        vals, _ = self._vals_pay(node, env)
        k = int(ir.resolve(node.k, env))
        # +1: the circuit needs the boundary row just after the input region
        return dict(n_rows=pad_pow2(max(len(vals) + 1, 2)),
                    m_in=int(len(vals)), k=min(k, len(vals)),
                    **self.shape_flags(node))

    def shape_flags(self, node) -> dict:
        return dict(descending=node.descending)

    def build(self, shape: dict) -> Operator:
        return orderby.build(**shape)

    def witness(self, db, op: Operator, node, env: ir.Env):
        vals, pay = self._vals_pay(node, env)
        return orderby.witness(op, vals, pay)

    def extract_outputs(self, op: Operator, instance) -> dict:
        return dict(vals=_selected(op, instance, "O_val"),
                    pay=_selected(op, instance, "O_pay"))

    def analysis_cases(self, db) -> list:
        vals = (50, 30, 90, 10, 70, 30)
        pays = (11, 12, 13, 14, 15, 16)

        def plan(label, descending, k):
            node = ir.OrderBy(ir.Lit(vals), ir.Lit(pays), k=ir.Lit(k),
                              descending=descending)
            return (label, ir.Plan(f"analysis/{label}", (node,), {}), {})
        return [plan("top3_desc", True, 3), plan("bottom2_asc", False, 2)]


# ---------------------------------------------------------------------------
# Filter (order-predicate filter over a chained (id, value) table)
# ---------------------------------------------------------------------------
class FilterAdapter(Adapter):
    kind = ir.Filter
    name = "filter"
    shape_schema = dict(n_rows=int, m_in=int, cmp=str)

    def manifest_pins(self, node, env: ir.Env, manifest, geo) -> dict:
        # same max(..., 2) floor as shape(): a 1-row base table still builds
        # a 2-row circuit, and the honest prover must pass the pin
        return dict(n_rows=pad_pow2(max(geo.n_table_rows, 2)),
                    m_in=geo.n_table_rows)

    def shape_flags(self, node) -> dict:
        return dict(cmp=str(node.cmp))

    def shape(self, db, node, env: ir.Env) -> dict:
        cols = _table_cols(db, node.table, env)
        m_in = int(cols.shape[1])
        return dict(n_rows=pad_pow2(max(m_in, 2)), m_in=m_in,
                    **self.shape_flags(node))

    def build(self, shape: dict) -> Operator:
        return filtering.build(**shape)

    def witness(self, db, op: Operator, node, env: ir.Env):
        cols = _table_cols(db, node.table, env)
        return filtering.witness(op, cols[0], cols[1],
                                 int(ir.resolve(node.threshold, env)))

    def extract_outputs(self, op: Operator, instance) -> dict:
        return dict(src=_selected(op, instance, "C_s"),
                    dst=_selected(op, instance, "C_t"))

    def check_instance(self, op, instance, node, env: ir.Env) -> bool:
        return _col_equals(op, instance, "thr",
                           int(ir.resolve(node.threshold, env)))

    def analysis_cases(self, db) -> list:
        ids = ir.Lit(tuple(range(1, 9)))
        vals = ir.Lit((5, 30, 17, 30, 2, 99, 42, 8))

        def case(label, cmp, thr):
            node = ir.Filter(ir.Chained((ids, vals)), cmp, ir.Lit(thr))
            return (label, ir.Plan(f"analysis/{label}", (node,), {}), {})
        return [case("ge_30", "ge", 30), case("ne_30", "ne", 30),
                case("lt_17", "lt", 17)]


# ---------------------------------------------------------------------------
# Aggregate (count / sum / min over a chained value column)
# ---------------------------------------------------------------------------
class AggregateAdapter(Adapter):
    kind = ir.Aggregate
    name = "aggregate"
    shape_schema = dict(n_rows=int, m_in=int, agg=str)

    def manifest_pins(self, node, env: ir.Env, manifest, geo) -> dict:
        # same max(..., 2) floor as shape() (the honest circuit never shrinks
        # below 2 rows, even over a 1-row base table)
        return dict(n_rows=pad_pow2(max(geo.n_table_rows + 1, 2)),
                    m_in=geo.n_table_rows)

    def shape_flags(self, node) -> dict:
        return dict(agg=str(node.agg))

    def shape(self, db, node, env: ir.Env) -> dict:
        cols = _table_cols(db, node.table, env)
        m_in = int(cols.shape[1])
        # +1: count/sum need the boundary row just after the input region
        return dict(n_rows=pad_pow2(max(m_in + 1, 2)), m_in=m_in,
                    **self.shape_flags(node))

    def build(self, shape: dict) -> Operator:
        return aggregate.build(**shape)

    def witness(self, db, op: Operator, node, env: ir.Env):
        cols = _table_cols(db, node.table, env)
        return aggregate.witness(op, cols[0])

    def extract_outputs(self, op: Operator, instance) -> dict:
        return dict(value=int(instance[op.handles["agg_out"].index][0]))

    def analysis_cases(self, db) -> list:
        vals = ir.Lit((7, 31, 9, 31, 12, 4))

        def case(label, agg):
            node = ir.Aggregate(ir.Chained((vals,)), agg)
            return (label, ir.Plan(f"analysis/{label}", (node,), {}), {})
        return [case("count", "count"), case("sum", "sum"),
                case("min", "min")]


# ---------------------------------------------------------------------------
# SSSP (§IV-C, integrated BiRC)
# ---------------------------------------------------------------------------
class SSSPAdapter(Adapter):
    kind = ir.SSSP
    name = "sssp"
    shape_schema = dict(n_rows=int, m_edges=int, n_nodes=int, undirected=bool,
                        with_target=bool)

    def manifest_pins(self, node, env: ir.Env, manifest, geo) -> dict:
        # m_edges counts the *edge table* the BFS ran over, not the committed
        # (src, dst, node) table — its true size is published per edge table
        return dict(n_rows=pad_pow2(geo.n_table_rows),
                    m_edges=manifest.edge_count(node.edge_table),
                    n_nodes=manifest.n_nodes)

    def shape(self, db, node, env: ir.Env) -> dict:
        cols = _table_cols(db, node.table, env)
        t = db.tables[node.edge_table]
        return dict(n_rows=pad_pow2(cols.shape[1]), m_edges=len(t),
                    n_nodes=db.n_nodes, **self.shape_flags(node))

    def shape_flags(self, node) -> dict:
        return dict(undirected=True, with_target=node.target is not None)

    def build(self, shape: dict) -> Operator:
        return sssp.build(**shape)

    def witness(self, db, op: Operator, node, env: ir.Env):
        t = db.tables[node.edge_table]
        id_s = int(ir.resolve(node.source, env))
        id_t = None if node.target is None else int(ir.resolve(node.target, env))
        dist, pred, pd = engine.bfs_sssp(t, db.node_ids, id_s, True)
        return sssp.witness(op, t.src, t.dst, db.node_ids, id_s, dist, pred,
                            pd, id_t=id_t)

    def extract_outputs(self, op: Operator, instance) -> dict:
        h = op.handles
        out = dict(distances=np.asarray(
            instance[h["D"].index][: h["n_nodes"]], np.int64))
        if h["id_t"] is not None:
            d = int(instance[h["d_t"].index][0])
            out.update(dist=d, distance=d if d <= h["n_nodes"] else -1)
        return out

    def check_instance(self, op, instance, node, env: ir.Env) -> bool:
        if not _col_equals(op, instance, "id_s",
                           int(ir.resolve(node.source, env))):
            return False
        if node.target is not None:
            return _col_equals(op, instance, "id_t",
                               int(ir.resolve(node.target, env)))
        return True

    def analysis_cases(self, db) -> list:
        def plan(label, node):
            return (label, ir.Plan(f"analysis/{label}", (node,), {}), {})
        return [
            plan("with_target", ir.SSSP(ir.BaseTable("knows_nodes"),
                                        ir.Lit(1), target=ir.Lit(9))),
            plan("all_dists", ir.SSSP(ir.BaseTable("knows_nodes"),
                                      ir.Lit(2))),
        ]


register(ExpandAdapter())
register(NameFilterAdapter())
register(SetExpandAdapter())
register(OrderByAdapter())
register(SSSPAdapter())
register(FilterAdapter())
register(AggregateAdapter())
