"""Bidirectional relationship canonicalization (paper §IV-D).

Vieta's trick: (min,max) is the unique ordered root pair of
x^2 - (U+V)x + UV, enforced by sum/product invariants + an order constraint.
"""
from __future__ import annotations

import numpy as np

from ..plonkish import Circuit, Const
from .common import Operator, pad_col, region_selector
from .set_expansion import SENTINEL_BITS, _fill_named_range


def build(n_rows: int, m_edges: int) -> Operator:
    c = Circuit(n_rows, name="birc")
    U = c.add_data("U")
    V = c.add_data("V")
    sel = region_selector(c, "sel_edge", m_edges)
    L = c.add_instance("L")      # canonical min (public output)
    H = c.add_instance("H")      # canonical max
    c.add_gate("sum_invariant", sel * (U + V - L - H))
    c.add_gate("prod_invariant", sel * (U * V - L * H))
    c.add_range_check("order", H - L, SENTINEL_BITS, sel=sel)
    op = Operator("birc", c)
    op.handles = dict(U=U, V=V, sel=sel, L=L, H=H, m_edges=m_edges)
    return op


def witness(op: Operator, src, dst):
    h = op.handles
    n = op.circuit.n_rows
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    data[h["U"].index] = pad_col(src, n)
    data[h["V"].index] = pad_col(dst, n)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    inst[h["L"].index, : len(lo)] = lo
    inst[h["H"].index, : len(hi)] = hi
    sel = np.zeros(n, np.int64)
    sel[: h["m_edges"]] = 1
    diff = np.zeros(n, np.int64)
    diff[: len(lo)] = hi - lo
    _fill_named_range(op.circuit, advice, "order", np.where(sel, diff, 0))
    return advice, inst, data
