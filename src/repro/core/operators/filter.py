"""Order-predicate filter over a chained (id, value) pair table.

Each input row carries an id and a value; a public threshold and a fixed
comparison pick the passing rows.  The pass flag is boolean, region-gated,
and *evidenced*: for the order comparisons both the marked and the unmarked
side must exhibit a range-checked witness (pass: ``V - thr ∈ [0, 2^28)``
etc.), so a prover can neither hide a passing row nor smuggle a failing one.
Equality comparisons reuse the inverse-trick flag gadget.  One multiset
argument binds the public output table to the flagged rows.

Values and thresholds must fit ``VAL_BITS`` (the same 2^28 bound the
order-by pivot checks use); the planner rejects out-of-range literals.
"""
from __future__ import annotations

import numpy as np

from .. import field as F
from ..plonkish import Circuit, Const
from .common import Operator, eq_flag_gadget, fill_eq_flag, pad_col, region_selector
from .set_expansion import _fill_named_range

VAL_BITS = 28
CMPS = ("ge", "gt", "le", "lt", "eq", "ne")


def build(n_rows: int, m_in: int, cmp: str) -> Operator:
    assert cmp in CMPS, f"unknown comparison {cmp!r}"
    assert 1 <= m_in <= n_rows
    c = Circuit(n_rows, name=f"filter_{cmp}")
    Id = c.add_data("Id")
    V = c.add_data("V")
    sel_in = region_selector(c, "sel_in", m_in)
    thr = c.add_instance("thr")
    out_sel = c.add_instance("out_sel")
    C_s = c.add_instance("C_s")
    C_t = c.add_instance("C_t")
    handles = dict(Id=Id, V=V, sel_in=sel_in, thr=thr, out_sel=out_sel,
                   C_s=C_s, C_t=C_t, m_in=m_in, cmp=cmp)
    if cmp in ("ge", "gt", "le", "lt"):
        fl = c.add_advice("pass")
        nk = c.add_advice("fail")
        c.add_gate("pass_bool", fl * (Const(1) - fl))
        c.add_gate("pass_region", (Const(1) - sel_in) * fl)
        c.add_gate("fail_def", nk - sel_in * (Const(1) - fl))
        pass_expr, fail_expr = {
            "ge": (V - thr, thr - Const(1) - V),
            "gt": (V - thr - Const(1), thr - V),
            "le": (thr - V, V - thr - Const(1)),
            "lt": (thr - Const(1) - V, V - thr),
        }[cmp]
        c.add_range_check("cmp_pass", pass_expr, VAL_BITS, sel=fl)
        c.add_range_check("cmp_fail", fail_expr, VAL_BITS, sel=nk)
        handles.update(fl=fl, nk=nk)
    else:
        fe, inv = eq_flag_gadget(c, "eq", V, thr, sel_in)
        c.add_gate("eq_region", (Const(1) - sel_in) * fe)
        if cmp == "eq":
            fl = fe
        else:
            fl = c.add_advice("pass")
            c.add_gate("pass_def", fl - sel_in * (Const(1) - fe))
        handles.update(fe=fe, inv=inv, fl=fl)
    c.add_multiset_equal("out_perm", [C_s, C_t], out_sel, [Id, V], fl)
    op = Operator(c.name, c)
    op.handles = handles
    return op


def _pass_mask(vals: np.ndarray, thr: int, cmp: str) -> np.ndarray:
    return {"ge": vals >= thr, "gt": vals > thr, "le": vals <= thr,
            "lt": vals < thr, "eq": vals == thr, "ne": vals != thr}[cmp]


def witness(op: Operator, ids, vals, thr: int):
    h = op.handles
    c = op.circuit
    n = c.n_rows
    m = h["m_in"]
    cmp = h["cmp"]
    ids = np.asarray(ids, np.int64)
    vals = np.asarray(vals, np.int64)
    assert len(ids) == m and len(vals) == m
    thr = int(thr)
    if cmp not in ("eq", "ne"):
        assert 0 <= thr < (1 << VAL_BITS), "threshold exceeds VAL_BITS bound"
        assert vals.min() >= 0 and vals.max() < (1 << VAL_BITS), \
            "filter values exceed VAL_BITS bound"
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    data[h["Id"].index] = pad_col(ids, n)
    data[h["V"].index] = pad_col(vals, n)
    inst[h["thr"].index] = thr % F.P
    sel = np.zeros(n, np.int64)
    sel[:m] = 1
    v = np.zeros(n, np.int64)
    v[:m] = vals
    mask = np.zeros(n, bool)
    mask[:m] = _pass_mask(vals, thr, cmp)
    if cmp in ("ge", "gt", "le", "lt"):
        advice[h["fl"].index] = mask.astype(np.int64)
        advice[h["nk"].index] = sel * (1 - mask)
        pass_diff, fail_diff = {
            "ge": (v - thr, thr - 1 - v),
            "gt": (v - thr - 1, thr - v),
            "le": (thr - v, v - thr - 1),
            "lt": (thr - 1 - v, v - thr),
        }[cmp]
        _fill_named_range(c, advice, "cmp_pass", np.where(mask, pass_diff, 0))
        _fill_named_range(c, advice, "cmp_fail",
                          np.where(sel * (1 - mask), fail_diff, 0))
    else:
        fill_eq_flag(advice, h["fe"], h["inv"], v, np.full(n, thr), sel)
        if cmp == "ne":
            advice[h["fl"].index] = sel * (1 - advice[h["fe"].index])
    flv = advice[h["fl"].index].astype(bool)
    k = int(flv.sum())
    inst[h["out_sel"].index, :k] = 1
    inst[h["C_s"].index, :k] = data[h["Id"].index][flv]
    inst[h["C_t"].index, :k] = data[h["V"].index][flv]
    return advice, inst, data
