"""Single-source shortest path verification (paper §IV-C).

The prover runs BFS natively (engine.bfs_sssp); the circuit checks
  node level: source init, distance propagation D = PD + 1 | d_max,
              predecessor validity via lookups into (N, D) and the edge table;
  edge level: UD/VD consistency lookups + the Bellman-Ford relaxation
              VD <= UD + 1 on every edge.

``undirected=True`` is the *integrated BiRC* mode (paper §IV-D extension):
relaxation is enforced in both orientations and the predecessor edge may be
matched in either direction — no duplicated edge rows (Table IV).
"""
from __future__ import annotations

import numpy as np

from .. import field as F
from ..plonkish import Circuit, Const, fill_range_limbs
from .common import Operator, eq_flag_gadget, fill_eq_flag, pad_col, region_selector
from .set_expansion import _fill_named_range


def build(n_rows: int, m_edges: int, n_nodes: int, d_max: int = None,
          undirected: bool = True, with_target: bool = False) -> Operator:
    c = Circuit(n_rows, name="sssp" + ("_birc" if undirected else ""))
    d_max = d_max if d_max is not None else n_nodes + 1
    dist_bits = max(2, int(d_max + 2).bit_length())
    U = c.add_data("U")
    V = c.add_data("V")
    N = c.add_data("N")                      # node-id column (node region)
    sel_e = region_selector(c, "sel_edge", m_edges)
    sel_n = region_selector(c, "sel_node", n_nodes)
    id_s = c.add_instance("id_s")
    D = c.add_instance("D")                  # the public result: distances
    S, inv_s = eq_flag_gadget(c, "src", N, id_s, sel_n)
    reach = c.add_advice("reach")
    P = c.add_advice("P")
    PD = c.add_advice("PD")
    UD = c.add_advice("UD")
    VD = c.add_advice("VD")
    g = c.add_advice("g")                    # gates predecessor lookups
    # node-level gates
    c.add_gate("src_dist", sel_n * S * D)
    c.add_gate("dist_prop",
               sel_n * (Const(1) - S) * (D - PD - Const(1)) * (D - Const(d_max)))
    c.add_gate("reach_bool", reach * (Const(1) - reach))
    c.add_gate("unreach_dmax", sel_n * (Const(1) - reach) * (D - Const(d_max)))
    c.add_gate("g_def", g - sel_n * (Const(1) - S) * reach)
    c.add_range_check("d_range", D, dist_bits, sel=sel_n)
    # predecessor validity
    c.add_bus("pred_dist", [P, PD], [N, D], m_f=g, t_sel=sel_n)
    if not undirected:
        c.add_bus("pred_edge", [P, N], [U, V], m_f=g, t_sel=sel_e)
        gf = gb = None
    else:
        gf = c.add_advice("g_fwd")
        gb = c.add_advice("g_bwd")
        c.add_gate("g_split", g - gf - gb)
        c.add_gate("gf_bool", gf * (Const(1) - gf))
        c.add_gate("gb_bool", gb * (Const(1) - gb))
        c.add_bus("pred_edge_f", [P, N], [U, V], m_f=gf, t_sel=sel_e)
        c.add_bus("pred_edge_b", [P, N], [V, U], m_f=gb, t_sel=sel_e)
    # edge-level consistency + relaxation
    c.add_bus("ud", [U, UD], [N, D], m_f=sel_e, t_sel=sel_n)
    c.add_bus("vd", [V, VD], [N, D], m_f=sel_e, t_sel=sel_n)
    c.add_range_check("relax_fwd", UD + Const(1) - VD, dist_bits, sel=sel_e)
    if undirected:
        c.add_range_check("relax_bwd", VD + Const(1) - UD, dist_bits, sel=sel_e)
    id_t = d_t = None
    if with_target:
        # IC13-style answer extraction: (id_t, d_t) must be a (N, D) entry
        row0 = np.zeros(n_rows, np.uint32)
        row0[0] = 1
        onehot0 = c.add_fixed("onehot0_t", row0)
        id_t = c.add_instance("id_t")
        d_t = c.add_instance("d_t")
        c.add_bus("target", [id_t, d_t], [N, D], m_f=onehot0, t_sel=sel_n)
    op = Operator(c.name, c)
    op.handles = dict(U=U, V=V, N=N, sel_e=sel_e, sel_n=sel_n, id_s=id_s, D=D,
                      S=S, inv_s=inv_s, reach=reach, P=P, PD=PD, UD=UD, VD=VD,
                      g=g, gf=gf, gb=gb, m_edges=m_edges, n_nodes=n_nodes,
                      d_max=d_max, undirected=undirected, id_t=id_t, d_t=d_t)
    return op


def witness(op: Operator, src, dst, node_ids, id_s: int, dist, pred,
            pred_dist, id_t: int = None):
    """dist/pred/pred_dist from engine.bfs_sssp aligned with node_ids."""
    h = op.handles
    c = op.circuit
    n = c.n_rows
    m, nn, d_max = h["m_edges"], h["n_nodes"], h["d_max"]
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    node_ids = np.asarray(node_ids, np.int64)
    dist = np.asarray(dist, np.int64)
    data[h["U"].index] = pad_col(src, n)
    data[h["V"].index] = pad_col(dst, n)
    data[h["N"].index] = pad_col(node_ids, n)
    inst[h["id_s"].index] = id_s
    inst[h["D"].index, :nn] = dist
    sel_n = np.zeros(n, np.int64)
    sel_n[:nn] = 1
    sel_e = np.zeros(n, np.int64)
    sel_e[:m] = 1
    fill_eq_flag(advice, h["S"], h["inv_s"], data[h["N"].index],
                 np.full(n, id_s), sel_n)
    reach_v = np.zeros(n, np.int64)
    reach_v[:nn] = dist < d_max
    advice[h["reach"].index] = reach_v
    s_flag = advice[h["S"].index].astype(np.int64)
    g_v = sel_n * (1 - s_flag) * reach_v
    advice[h["g"].index] = g_v
    advice[h["P"].index] = pad_col(np.where(g_v[:nn] == 1, pred, 0), n)
    advice[h["PD"].index] = pad_col(np.where(g_v[:nn] == 1, pred_dist, 0), n)
    idx_of = {int(v): i for i, v in enumerate(node_ids.tolist())}
    ud = np.asarray([dist[idx_of[int(u)]] for u in src], np.int64)
    vd = np.asarray([dist[idx_of[int(v)]] for v in dst], np.int64)
    advice[h["UD"].index] = pad_col(ud, n)
    advice[h["VD"].index] = pad_col(vd, n)
    if h["undirected"]:
        # predecessor edge orientation: (P, N) in (U,V) or (V,U)
        pair_fwd = {(int(a), int(b)) for a, b in zip(src, dst)}
        gf = np.zeros(n, np.int64)
        gb = np.zeros(n, np.int64)
        for i in range(nn):
            if g_v[i]:
                p, x = int(advice[h["P"].index][i]), int(node_ids[i])
                if (p, x) in pair_fwd:
                    gf[i] = 1
                else:
                    gb[i] = 1
        advice[h["gf"].index] = gf
        advice[h["gb"].index] = gb
    if h["id_t"] is not None:
        assert id_t is not None
        inst[h["id_t"].index] = id_t
        t_pos = int(np.nonzero(node_ids == id_t)[0][0])
        inst[h["d_t"].index] = int(dist[t_pos])
    dist_col = inst[h["D"].index].astype(np.int64)
    ud_p, vd_p = np.zeros(n, np.int64), np.zeros(n, np.int64)
    ud_p[:m], vd_p[:m] = ud, vd
    _fill_named_range(c, advice, "d_range", np.where(sel_n, dist_col, 0))
    _fill_named_range(c, advice, "relax_fwd",
                      np.where(sel_e, ud_p + 1 - vd_p, 0))
    if h["undirected"]:
        _fill_named_range(c, advice, "relax_bwd",
                          np.where(sel_e, vd_p + 1 - ud_p, 0))
    return advice, inst, data
