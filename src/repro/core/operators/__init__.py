from . import (all_shortest, birc, expansion, orderby, reachability,
               set_expansion, sssp)  # noqa: F401
