"""Reachability (paper §IV-E): path-witness verification.

The prover supplies a node sequence; lookups check that both endpoints appear
in the sequence and that every consecutive pair is an edge. Bidirectional
tables are handled with the dual-orientation trick (integrated BiRC).
"""
from __future__ import annotations

import numpy as np

from ..plonkish import Circuit, Const
from .common import Operator, pad_col, region_selector


def build(n_rows: int, m_edges: int, path_len: int,
          undirected: bool = True) -> Operator:
    c = Circuit(n_rows, name="reach")
    U = c.add_data("U")
    V = c.add_data("V")
    sel_e = region_selector(c, "sel_edge", m_edges)
    sel_path = region_selector(c, "sel_path", path_len)
    sel_step = region_selector(c, "sel_step", max(path_len - 1, 0))
    row0 = np.zeros(n_rows, np.uint32)
    row0[0] = 1
    onehot0 = c.add_fixed("onehot0", row0)
    id_s = c.add_instance("id_s")
    id_t = c.add_instance("id_t")
    path = c.add_advice("path")
    # endpoint presence (lookup into the path witness)
    c.add_bus("s_present", [id_s], [path], m_f=onehot0, t_sel=sel_path)
    c.add_bus("t_present", [id_t], [path], m_f=onehot0, t_sel=sel_path)
    handles = dict(U=U, V=V, sel_e=sel_e, sel_path=sel_path,
                   sel_step=sel_step, id_s=id_s, id_t=id_t, path=path,
                   m_edges=m_edges, path_len=path_len, undirected=undirected)
    if not undirected:
        c.add_bus("steps", [path, path.rotate(1)], [U, V], m_f=sel_step,
                  t_sel=sel_e)
    else:
        df = c.add_advice("dir_f")
        db = c.add_advice("dir_b")
        c.add_gate("dir_split", sel_step * (df + db - Const(1)))
        c.add_gate("df_bool", df * (Const(1) - df))
        c.add_gate("db_bool", db * (Const(1) - db))
        c.add_gate("dir_region", (Const(1) - sel_step) * (df + db))
        c.add_bus("steps_f", [path, path.rotate(1)], [U, V], m_f=df, t_sel=sel_e)
        c.add_bus("steps_b", [path, path.rotate(1)], [V, U], m_f=db, t_sel=sel_e)
        handles.update(df=df, db=db)
    op = Operator("reach", c)
    op.handles = handles
    return op


def witness(op: Operator, src, dst, path_nodes, id_s: int, id_t: int):
    h = op.handles
    n = op.circuit.n_rows
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    data[h["U"].index] = pad_col(src, n)
    data[h["V"].index] = pad_col(dst, n)
    path = np.asarray(path_nodes, np.int64)
    assert len(path) == h["path_len"]
    advice[h["path"].index] = pad_col(path, n)
    inst[h["id_s"].index] = id_s
    inst[h["id_t"].index] = id_t
    if h["undirected"]:
        pair_fwd = {(int(a), int(b)) for a, b in zip(src, dst)}
        df = np.zeros(n, np.int64)
        db = np.zeros(n, np.int64)
        for i in range(len(path) - 1):
            if (int(path[i]), int(path[i + 1])) in pair_fwd:
                df[i] = 1
            else:
                db[i] = 1
        advice[h["df"].index] = df
        advice[h["db"].index] = db
    return advice, inst, data
