"""order-by + limit-k (paper §IV-E).

Pivot strategy: val_k = value of the k-th entry after sorting. IS_k marks the
selected top-k rows; constraints force every marked value to be on the correct
side of the pivot, the pivot itself to be one of the marked entries, the mark
count to be exactly k, and the public output to be the multiset of marked
(value, payload) pairs.
"""
from __future__ import annotations

import numpy as np

from ..plonkish import Circuit, Const
from .common import Operator, pad_col, region_selector
from .set_expansion import _fill_named_range

VAL_BITS = 28


def instance_rot(col):
    return col.rotate(1)


def build(n_rows: int, m_in: int, k: int, descending: bool = True) -> Operator:
    assert m_in < n_rows, "need the boundary row just after the input region"
    c = Circuit(n_rows, name="orderby")
    Val = c.add_data("Val")          # input values (from the previous operator)
    Pay = c.add_data("Payload")      # carried payload (e.g. node id)
    sel_in = region_selector(c, "sel_in", m_in)
    boundary = np.zeros(n_rows, np.uint32)
    boundary[m_in] = 1               # row just after the input region
    b_end = c.add_fixed("b_end", boundary)
    row0 = np.zeros(n_rows, np.uint32)
    row0[0] = 1
    onehot0 = c.add_fixed("onehot0", row0)
    val_k = c.add_instance("val_k")  # the pivot (public)
    out_sel = c.add_instance("out_sel")
    O_val = c.add_instance("O_val")
    O_pay = c.add_instance("O_pay")
    isk = c.add_advice("IS_k")
    nk = c.add_advice("IS_nk")       # sel_in * (1 - IS_k), materialized
    R = c.add_advice("count")        # running count of marks
    c.add_gate("isk_bool", isk * (Const(1) - isk))
    c.add_gate("isk_region", (Const(1) - sel_in) * isk)
    c.add_gate("nk_def", nk - sel_in * (Const(1) - isk))
    # running count: R[0] = 0; R[i+1] = R[i] + IS_k[i]; R[m_in] = k
    c.add_gate("count0", onehot0 * R)
    c.add_gate("count_step", sel_in * (R.rotate(1) - R - isk))
    c.add_gate("count_final", b_end * (R - Const(k)))
    # pivot originates from a marked entry
    c.add_bus("pivot_origin", [val_k], [Val], m_f=onehot0, t_sel=isk)
    # marked entries beat the pivot; unmarked are beaten by it
    if descending:
        c.add_range_check("ge_pivot", Val - val_k, VAL_BITS, sel=isk)
        c.add_range_check("le_pivot", val_k - Val, VAL_BITS, sel=nk)
    else:
        c.add_range_check("ge_pivot", val_k - Val, VAL_BITS, sel=isk)
        c.add_range_check("le_pivot", Val - val_k, VAL_BITS, sel=nk)
    # public output = multiset of marked rows
    c.add_multiset_equal("out_perm", [O_val, O_pay], out_sel, [Val, Pay], isk)
    # the public listing itself is sorted: adjacent-pair order checks
    adj = c.add_advice("adj")
    c.add_gate("adj_def", adj - out_sel * instance_rot(out_sel))
    if descending:
        c.add_range_check("out_sorted", O_val - instance_rot(O_val), VAL_BITS,
                          sel=adj)
    else:
        c.add_range_check("out_sorted", instance_rot(O_val) - O_val, VAL_BITS,
                          sel=adj)
    op = Operator("orderby", c)
    op.handles = dict(Val=Val, Pay=Pay, sel_in=sel_in, val_k=val_k,
                      out_sel=out_sel, O_val=O_val, O_pay=O_pay, isk=isk,
                      nk=nk, R=R, adj=adj, m_in=m_in, k=k,
                      descending=descending)
    return op


def witness(op: Operator, values, payload):
    from ...graphdb.engine import top_k
    h = op.handles
    n = op.circuit.n_rows
    m, k = h["m_in"], h["k"]
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    values = np.asarray(values, np.int64)
    payload = np.asarray(payload, np.int64)
    data[h["Val"].index] = pad_col(values, n)
    data[h["Pay"].index] = pad_col(payload, n)
    sel_mask, pivot = top_k(values, k, h["descending"])
    isk = np.zeros(n, np.int64)
    isk[:m] = sel_mask
    advice[h["isk"].index] = isk
    sel_in = np.zeros(n, np.int64)
    sel_in[:m] = 1
    advice[h["nk"].index] = sel_in * (1 - isk)
    advice[h["R"].index] = np.concatenate([[0], np.cumsum(isk)[:-1]])
    inst[h["val_k"].index] = pivot
    kk = int(isk.sum())
    sel_vals = values[sel_mask]
    sel_pay = payload[sel_mask]
    order = np.argsort(sel_vals, kind="stable")
    if h["descending"]:
        order = order[::-1]
    inst[h["out_sel"].index, :kk] = 1
    inst[h["O_val"].index, :kk] = sel_vals[order]
    inst[h["O_pay"].index, :kk] = sel_pay[order]
    # adjacent-order witness
    out_sel_col = inst[h["out_sel"].index].astype(np.int64)
    adj = out_sel_col * np.roll(out_sel_col, -1)
    advice[h["adj"].index] = adj
    oval = inst[h["O_val"].index].astype(np.int64)
    if h["descending"]:
        diff = np.where(adj == 1, oval - np.roll(oval, -1), 0)
    else:
        diff = np.where(adj == 1, np.roll(oval, -1) - oval, 0)
    _fill_named_range(op.circuit, advice, "out_sorted", diff)
    if h["descending"]:
        ge = np.where(isk == 1, values_pad(values, n) - pivot, 0)
        le = np.where(advice[h["nk"].index] == 1, pivot - values_pad(values, n), 0)
    else:
        ge = np.where(isk == 1, pivot - values_pad(values, n), 0)
        le = np.where(advice[h["nk"].index] == 1, values_pad(values, n) - pivot, 0)
    _fill_named_range(op.circuit, advice, "ge_pivot", ge)
    _fill_named_range(op.circuit, advice, "le_pivot", le)
    return advice, inst, data


def values_pad(values, n):
    out = np.zeros(n, np.int64)
    out[: len(values)] = values
    return out
