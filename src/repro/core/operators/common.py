"""Shared circuit gadgets + witness helpers for the graph operators."""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from .. import field as F
from .. import prover as pv
from .. import verifier as vf
from ..plonkish import Circuit, Col, Const, Expr


def host_inv(x: np.ndarray) -> np.ndarray:
    """Vectorized modular inverse on the host (witness side only)."""
    import jax.numpy as jnp
    arr = jnp.asarray(np.asarray(x, np.int64) % F.P).astype(jnp.uint32)
    return np.asarray(F.fbatch_inv(arr)).astype(np.int64)


def eq_flag_gadget(c: Circuit, name: str, lhs: Expr, rhs: Expr, sel: Expr):
    """fl = 1 iff lhs == rhs on selected rows (standard inverse trick).

    Gates: fl boolean; sel*fl*(lhs-rhs)=0; sel*(1-fl)*((lhs-rhs)*inv - 1)=0.
    Returns (fl, inv) advice columns. Witness: use fill_eq_flag.
    """
    fl = c.add_advice(f"{name}/fl")
    inv = c.add_advice(f"{name}/inv")
    diff = lhs - rhs
    c.add_gate(f"{name}/bool", fl * (Const(1) - fl))
    c.add_gate(f"{name}/zero", sel * fl * diff)
    c.add_gate(f"{name}/nonzero", sel * (Const(1) - fl) * (diff * inv - Const(1)))
    return fl, inv


def fill_eq_flag(advice, fl: Col, inv: Col, lhs_vals, rhs_vals, sel_vals):
    lhs = np.asarray(lhs_vals, np.int64) % F.P
    rhs = np.asarray(rhs_vals, np.int64) % F.P
    sel = np.asarray(sel_vals, np.int64)
    eq = (lhs == rhs) & (sel != 0)
    advice[fl.index] = eq.astype(np.uint32)
    diff = (lhs - rhs) % F.P
    invv = host_inv(diff)
    advice[inv.index] = np.where((sel != 0) & ~eq, invv, 0).astype(np.uint32)


def region_selector(c: Circuit, name: str, length: int) -> Col:
    vals = np.zeros(c.n_rows, np.uint32)
    vals[:length] = 1
    return c.add_fixed(name, vals)


def pad_col(vals, n: int) -> np.ndarray:
    out = np.zeros(n, np.int64)
    v = np.asarray(vals, np.int64)
    out[: len(v)] = v
    return out % F.P


@dataclass
class Operator:
    """A compiled operator: circuit + keys + the filled column layout."""
    name: str
    circuit: Circuit
    keys: pv.Keys = None
    handles: dict = dc_field(default_factory=dict)

    def keygen(self, cfg: pv.ProverConfig = None):
        self.keys = pv.keygen(self.circuit, cfg or pv.ProverConfig())
        return self

    def new_advice(self):
        return np.zeros((self.circuit.n_advice, self.circuit.n_rows), np.uint32)

    def new_instance(self):
        return np.zeros((self.circuit.n_instance, self.circuit.n_rows), np.uint32)

    def new_data(self):
        return np.zeros((self.circuit.n_data, self.circuit.n_rows), np.uint32)

    def prove(self, advice, instance, data=None):
        assert self.keys is not None, "call keygen() first"
        return pv.prove(self.keys, advice, instance, data, label=self.name)

    def verify(self, instance, proof, expected_data_root=None) -> bool:
        return vf.verify(self.keys, instance, proof, expected_data_root,
                         label=self.name)


def check_constraints(op: Operator, advice, instance, data=None,
                      seed: int = 0) -> list:
    """Fast witness validation on H (no proof): returns list of violated
    constraint names. Gates are checked exactly; buses/grand-products with a
    random challenge (sound whp)."""
    import jax.numpy as jnp
    from .. import prover as pv_mod
    from ..plonkish import ADVICE, DATA, FIXED, INSTANCE, BaseOps, eval_expr

    c = op.circuit
    c.assign_ext_cols()
    n = c.n_rows
    if data is None:
        data = np.zeros((0, n), np.uint32)
    adv = advice.copy()
    pv_mod.auto_multiplicities(c, data, adv, instance)
    fixed_n = jnp.asarray(np.stack(c.fixed_cols)
                          if c.fixed_cols else np.zeros((0, n), np.uint32))
    srcs = {FIXED: fixed_n, ADVICE: jnp.asarray(adv.astype(np.uint32)),
            INSTANCE: jnp.asarray(instance.astype(np.uint32)),
            DATA: jnp.asarray(np.asarray(data).astype(np.uint32))}

    def getter(kind, idx, rot):
        return jnp.roll(srcs[kind][idx], -rot)

    like = jnp.zeros(n, jnp.uint32)
    bad = []
    for name, gate in c.gates:
        v = eval_expr(gate, getter, BaseOps, like)
        if int(jnp.max(v)) != 0:
            bad.append(f"gate:{name}@rows{np.nonzero(np.asarray(v))[0][:5].tolist()}")
    rng = np.random.default_rng(seed)
    alpha = jnp.asarray(rng.integers(1, F.P, size=4).astype(np.uint32))
    beta = jnp.asarray(rng.integers(1, F.P, size=4).astype(np.uint32))
    ext_cols = pv_mod.build_ext_columns(c, getter, like, alpha, beta)
    # a bus/gp is satisfied iff its helper column telescopes around the cycle:
    # check the wrap increment (constraint at row n-1 -> row 0)
    from ..plonkish import compress_tuple
    i = 0
    for bus in c.buses:
        h = ext_cols[i]
        f_vals = [eval_expr(e, getter, BaseOps, like) for e in bus.f_tuple]
        t_vals = [eval_expr(e, getter, BaseOps, like) for e in bus.t_tuple]
        m_f = eval_expr(bus.m_f, getter, BaseOps, like)
        m_t = eval_expr(bus.m_t * bus.t_sel, getter, BaseOps, like)
        d_f = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(f_vals, alpha))
        d_t = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(t_vals, alpha))
        h1 = jnp.roll(h, -1, axis=0)
        lhs = F.emul(F.esub(h1, h), F.emul(d_f, d_t))
        rhs = F.esub(F.fmul(d_t, m_f[:, None]), F.fmul(d_f, m_t[:, None]))
        if not np.array_equal(np.asarray(lhs), np.asarray(rhs)):
            bad.append(f"bus:{bus.name}")
        i += 1
    for gp in c.gps:
        zc = ext_cols[i]
        total_ok = np.array_equal(np.asarray(zc[0]), F.EXT_ONE)
        # wrap: Z[0] must equal Z[n-1] * ratio[n-1]; build_ext computed the
        # full cyclic product into Z via prefix, so check product == 1
        c1 = [eval_expr(e, getter, BaseOps, like) for e in gp.c1_tuple]
        c2 = [eval_expr(e, getter, BaseOps, like) for e in gp.c2_tuple]
        s1 = eval_expr(gp.sel1, getter, BaseOps, like)
        s2 = eval_expr(gp.sel2, getter, BaseOps, like)
        one = jnp.zeros((n, 4), jnp.uint32).at[:, 0].set(1)
        d1 = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(c1, alpha))
        d2 = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(c2, alpha))
        f1 = F.eadd(F.fmul(d1, s1[:, None]),
                    F.fmul(one, F.fsub(jnp.full_like(s1, 1), s1)[:, None]))
        f2 = F.eadd(F.fmul(d2, s2[:, None]),
                    F.fmul(one, F.fsub(jnp.full_like(s2, 1), s2)[:, None]))
        prod1 = f1[0]
        prod2 = f2[0]
        for r in range(1, n):
            prod1 = F.emul(prod1, f1[r])
            prod2 = F.emul(prod2, f2[r])
        if not (total_ok and np.array_equal(np.asarray(prod1), np.asarray(prod2))):
            bad.append(f"gp:{gp.name}")
        i += 1
    return bad
