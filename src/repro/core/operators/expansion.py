"""Single-source expansion (paper §IV-A) — edge-list AND CSR circuit designs.

Edge-list: flag column + inverse-trick completeness gates + one multiset
permutation argument binding the public output table to the flagged edges.

CSR: the paper's comparison design — node-LUT / row-pointer lookups for
(idx_s, l_s, r_s), a 3-way partition (selected / below / above) with gated
range checks, and the output multiset argument. Strictly more buses + range
checks than edge-list: this is what Table I measures.
"""
from __future__ import annotations

import numpy as np

from .. import field as F
from ..plonkish import Circuit, Const
from . import common
from .common import Operator, eq_flag_gadget, fill_eq_flag, pad_col, region_selector


# ---------------------------------------------------------------------------
# edge-list format
# ---------------------------------------------------------------------------
def build_edge_list(n_rows: int, m_edges: int, with_prop: bool = False,
                    reverse: bool = False) -> Operator:
    """``reverse=True`` expands along incoming edges (flag on B, output
    (B, A)) over the *same* committed table — used for undirected relations
    and inverted traversals without re-committing data."""
    c = Circuit(n_rows, name="expand_el" + ("_rev" if reverse else ""))
    A = c.add_data("A")
    B = c.add_data("B")
    P = c.add_data("Val") if with_prop else None
    sel_e = region_selector(c, "sel_edge", m_edges)
    id_s = c.add_instance("id_s")
    out_sel = c.add_instance("out_sel")
    C_s = c.add_instance("C_s")
    C_t = c.add_instance("C_t")
    C_p = c.add_instance("C_p") if with_prop else None
    key, other = (B, A) if reverse else (A, B)
    fl, inv = eq_flag_gadget(c, "flag", key, id_s, sel_e)
    out_tuple = [C_s, C_t] + ([C_p] if with_prop else [])
    edge_tuple = [key, other] + ([P] if with_prop else [])
    c.add_multiset_equal("out_perm", out_tuple, out_sel, edge_tuple, fl)
    op = Operator(c.name, c)
    op.handles = dict(A=A, B=B, P=P, sel_e=sel_e, id_s=id_s, out_sel=out_sel,
                      C_s=C_s, C_t=C_t, C_p=C_p, fl=fl, inv=inv,
                      m_edges=m_edges, with_prop=with_prop, reverse=reverse)
    return op


def witness_edge_list(op: Operator, src, dst, id_s: int, prop=None):
    h = op.handles
    n = op.circuit.n_rows
    m = h["m_edges"]
    assert len(src) == m
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    data[h["A"].index] = pad_col(src, n)
    data[h["B"].index] = pad_col(dst, n)
    if h["with_prop"]:
        data[h["P"].index] = pad_col(prop, n)
    key_col = data[h["B"].index] if h["reverse"] else data[h["A"].index]
    other_col = data[h["A"].index] if h["reverse"] else data[h["B"].index]
    sel = np.zeros(n, np.int64)
    sel[:m] = 1
    fill_eq_flag(advice, h["fl"], h["inv"], key_col, np.full(n, id_s), sel)
    flv = advice[h["fl"].index].astype(bool)
    k = int(flv.sum())
    inst[h["id_s"].index] = id_s
    inst[h["out_sel"].index, :k] = 1
    inst[h["C_s"].index, :k] = id_s
    inst[h["C_t"].index, :k] = other_col[flv]
    if h["with_prop"]:
        inst[h["C_p"].index, :k] = data[h["P"].index][flv]
    return advice, inst, data


# ---------------------------------------------------------------------------
# CSR format
# ---------------------------------------------------------------------------
def build_csr(n_rows: int, len_col: int, n_nodes: int, id_bits: int) -> Operator:
    c = Circuit(n_rows, name="expand_csr")
    Colm = c.add_data("Col")         # concatenated targets
    RowP = c.add_data("Row")         # row pointers (n_nodes + 1 entries)
    LUT = c.add_data("NodeLUT")      # node id at each row index
    cidx = c.add_fixed("C_idx", np.arange(n_rows))
    sel_c = region_selector(c, "sel_col", len_col)
    sel_n = region_selector(c, "sel_node", n_nodes)
    sel_p = region_selector(c, "sel_ptr", n_nodes + 1)
    id_s = c.add_instance("id_s")
    out_sel = c.add_instance("out_sel")
    C_s = c.add_instance("C_s")
    C_t = c.add_instance("C_t")
    idx_s = c.add_advice("idx_s")
    l_s = c.add_advice("l_s")
    r_s = c.add_advice("r_s")
    sel = c.add_advice("sel")        # k in [l_s, r_s)
    b1 = c.add_advice("b1")          # k < l_s
    b2 = c.add_advice("b2")          # k >= r_s
    # lookups for idx_s / l_s / r_s correctness (paper: node LUT + Row)
    c.add_bus("lut", [idx_s, id_s], [cidx, LUT], m_f=sel_c, t_sel=sel_n)
    c.add_bus("lo", [idx_s, l_s], [cidx, RowP], m_f=sel_c, t_sel=sel_p)
    c.add_bus("hi", [idx_s + Const(1), r_s], [cidx, RowP], m_f=sel_c, t_sel=sel_p)
    # 3-way partition with gated range checks
    for b in (sel, b1, b2):
        c.add_gate(f"bool_{b.index}", b * (Const(1) - b))
    c.add_gate("partition", sel_c * (sel + b1 + b2 - Const(1)))
    c.add_gate("off_region", (Const(1) - sel_c) * (sel + b1 + b2))
    bits = id_bits
    rc_in_lo = c.add_range_check("in_lo", cidx - l_s, bits, sel=sel)
    rc_in_hi = c.add_range_check("in_hi", r_s - Const(1) - cidx, bits, sel=sel)
    rc_b1 = c.add_range_check("below", l_s - Const(1) - cidx, bits, sel=b1)
    rc_b2 = c.add_range_check("above", cidx - r_s, bits, sel=b2)
    # output multiset == selected Col entries
    c.add_multiset_equal("out_perm", [C_s, C_t], out_sel, [id_s, Colm], sel)
    op = Operator("expand_csr", c)
    op.handles = dict(Col=Colm, Row=RowP, LUT=LUT, sel_c=sel_c, sel_n=sel_n,
                      sel_p=sel_p, id_s=id_s, out_sel=out_sel, C_s=C_s,
                      C_t=C_t, idx_s=idx_s, l_s=l_s, r_s=r_s, sel=sel, b1=b1,
                      b2=b2, rcs=(rc_in_lo, rc_in_hi, rc_b1, rc_b2),
                      len_col=len_col, n_nodes=n_nodes)
    return op


def witness_csr(op: Operator, col, row_ptr, node_lut, id_s: int):
    from ..plonkish import fill_range_limbs
    h = op.handles
    n = op.circuit.n_rows
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    data[h["Col"].index] = pad_col(col, n)
    data[h["Row"].index] = pad_col(row_ptr, n)
    data[h["LUT"].index] = pad_col(node_lut, n)
    i_s = int(np.nonzero(np.asarray(node_lut) == id_s)[0][0])
    ls, rs = int(row_ptr[i_s]), int(row_ptr[i_s + 1])
    advice[h["idx_s"].index] = i_s
    advice[h["l_s"].index] = ls
    advice[h["r_s"].index] = rs
    k_idx = np.arange(n)
    region = k_idx < h["len_col"]
    in_rng = region & (k_idx >= ls) & (k_idx < rs)
    below = region & (k_idx < ls)
    above = region & (k_idx >= rs)
    advice[h["sel"].index] = in_rng
    advice[h["b1"].index] = below
    advice[h["b2"].index] = above
    rc_in_lo, rc_in_hi, rc_b1, rc_b2 = h["rcs"]
    z = np.zeros(n, np.int64)
    fill_range_limbs(advice, *rc_in_lo, np.where(in_rng, k_idx - ls, z))
    fill_range_limbs(advice, *rc_in_hi, np.where(in_rng, rs - 1 - k_idx, z))
    fill_range_limbs(advice, *rc_b1, np.where(below, ls - 1 - k_idx, z))
    fill_range_limbs(advice, *rc_b2, np.where(above, k_idx - rs, z))
    k = rs - ls
    inst[h["id_s"].index] = id_s
    inst[h["out_sel"].index, :k] = 1
    inst[h["C_s"].index, :k] = id_s
    inst[h["C_t"].index, :k] = np.asarray(col[ls:rs]) % F.P
    return advice, inst, data
