"""All-shortest-path enumeration (paper §IV-E).

Reduction (as in the paper): with dist(s,t) = d proven by the SSSP operator,
the set of final hops of all distinct shortest paths is
    { p : dist(s,p) = d-1  and  (p,t) in E }.
This circuit consumes the same public distance column D as the SSSP proof
(the planner checks the instance columns match across the chained proofs) and
emits that frontier as its public output.
"""
from __future__ import annotations

import numpy as np

from .. import field as F
from ..plonkish import Circuit, Const
from .common import Operator, eq_flag_gadget, fill_eq_flag, pad_col, region_selector


def build(n_rows: int, m_edges: int, n_nodes: int,
          undirected: bool = True) -> Operator:
    c = Circuit(n_rows, name="all_shortest")
    U = c.add_data("U")
    V = c.add_data("V")
    N = c.add_data("N")
    sel_e = region_selector(c, "sel_edge", m_edges)
    sel_n = region_selector(c, "sel_node", n_nodes)
    id_t = c.add_instance("id_t")
    d = c.add_instance("d")              # claimed shortest distance s->t
    D = c.add_instance("D")              # distances (shared with SSSP proof)
    out_sel = c.add_instance("out_sel")
    C_out = c.add_instance("C_out")
    UD = c.add_advice("UD")
    c.add_bus("ud", [U, UD], [N, D], m_f=sel_e, t_sel=sel_n)
    ft, inv_t = eq_flag_gadget(c, "tgt", V, id_t, sel_e)
    fe, inv_e = eq_flag_gadget(c, "dm1", UD, d - Const(1), sel_e)
    se = c.add_advice("se")
    c.add_gate("se_def", se - ft * fe)
    handles = dict(U=U, V=V, N=N, sel_e=sel_e, sel_n=sel_n, id_t=id_t, d=d,
                   D=D, out_sel=out_sel, C_out=C_out, UD=UD, ft=ft,
                   inv_t=inv_t, fe=fe, inv_e=inv_e, se=se, m_edges=m_edges,
                   n_nodes=n_nodes, undirected=undirected)
    if not undirected:
        c.add_multiset_equal("out_perm", [C_out], out_sel, [U], se)
    else:
        VD = c.add_advice("VD")
        c.add_bus("vd", [V, VD], [N, D], m_f=sel_e, t_sel=sel_n)
        gt, inv_t2 = eq_flag_gadget(c, "tgt_b", U, id_t, sel_e)
        ge, inv_e2 = eq_flag_gadget(c, "dm1_b", VD, d - Const(1), sel_e)
        se2 = c.add_advice("se2")
        c.add_gate("se2_def", se2 - gt * ge)
        out_dir = c.add_instance("out_dir")
        m_fwd = c.add_advice("m_out_fwd")
        m_bwd = c.add_advice("m_out_bwd")
        c.add_gate("m_fwd_def", m_fwd - out_sel * out_dir)
        c.add_gate("m_bwd_def", m_bwd - out_sel * (Const(1) - out_dir))
        c.add_multiset_equal("out_fwd", [C_out], m_fwd, [U], se)
        c.add_multiset_equal("out_bwd", [C_out], m_bwd, [V], se2)
        handles.update(VD=VD, gt=gt, inv_t2=inv_t2, ge=ge, inv_e2=inv_e2,
                       se2=se2, out_dir=out_dir, m_fwd=m_fwd, m_bwd=m_bwd)
    op = Operator("all_shortest", c)
    op.handles = handles
    return op


def witness(op: Operator, src, dst, node_ids, dist, id_t: int, d: int):
    h = op.handles
    n = op.circuit.n_rows
    m, nn = h["m_edges"], h["n_nodes"]
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    node_ids = np.asarray(node_ids, np.int64)
    dist = np.asarray(dist, np.int64)
    data[h["U"].index] = pad_col(src, n)
    data[h["V"].index] = pad_col(dst, n)
    data[h["N"].index] = pad_col(node_ids, n)
    inst[h["id_t"].index] = id_t
    inst[h["d"].index] = d
    inst[h["D"].index, :nn] = dist
    sel_e = np.zeros(n, np.int64)
    sel_e[:m] = 1
    idx_of = {int(v): i for i, v in enumerate(node_ids.tolist())}
    ud = np.asarray([dist[idx_of[int(u)]] for u in src], np.int64)
    advice[h["UD"].index] = pad_col(ud, n)
    fill_eq_flag(advice, h["ft"], h["inv_t"], data[h["V"].index],
                 np.full(n, id_t), sel_e)
    fill_eq_flag(advice, h["fe"], h["inv_e"], advice[h["UD"].index],
                 np.full(n, d - 1), sel_e)
    se = advice[h["ft"].index].astype(np.int64) * advice[h["fe"].index]
    advice[h["se"].index] = se
    if not h["undirected"]:
        k = int(se.sum())
        inst[h["out_sel"].index, :k] = 1
        inst[h["C_out"].index, :k] = data[h["U"].index][se.astype(bool)]
    else:
        vd = np.asarray([dist[idx_of[int(v)]] for v in dst], np.int64)
        advice[h["VD"].index] = pad_col(vd, n)
        fill_eq_flag(advice, h["gt"], h["inv_t2"], data[h["U"].index],
                     np.full(n, id_t), sel_e)
        fill_eq_flag(advice, h["ge"], h["inv_e2"], advice[h["VD"].index],
                     np.full(n, d - 1), sel_e)
        se2 = advice[h["gt"].index].astype(np.int64) * advice[h["ge"].index]
        advice[h["se2"].index] = se2
        kf, kb = int(se.sum()), int(se2.sum())
        k = kf + kb
        inst[h["out_sel"].index, :k] = 1
        inst[h["out_dir"].index, :kf] = 1
        inst[h["C_out"].index, :kf] = data[h["U"].index][se.astype(bool)]
        inst[h["C_out"].index, kf:k] = data[h["V"].index][se2.astype(bool)]
        advice[h["m_fwd"].index] = inst[h["out_sel"].index] * \
            inst[h["out_dir"].index]
        advice[h["m_bwd"].index] = inst[h["out_sel"].index] * \
            (1 - inst[h["out_dir"].index])
    return advice, inst, data
