"""Set-based expansion (paper §IV-B): greatest-lower-bound matching.

The prover provides a sorted copy (A', B') of the committed edge table plus
bracketing columns C_aux <= A' < C'_aux whose validity is enforced by a lookup
into the consecutive-pair table (T1, T2) = (IDs, IDs.rot(1)) of the extended
sorted start set. Selected edges (A' == C_aux) flow to the public output via
one multiset argument — O(|E|) circuit cost independent of |S| (Fig. 6b).

The *integrated BiRC* variant (paper §IV-D extension, Table IV) adds a second
bracketing on B' so canonical undirected edges match by either endpoint.
"""
from __future__ import annotations

import numpy as np

from .. import field as F
from ..plonkish import Circuit, Const, fill_range_limbs
from .common import Operator, eq_flag_gadget, fill_eq_flag, pad_col, region_selector

SENTINEL_BITS = 24  # ids live in [1, 2^24-3]; 0 / 2^24-1 are the paper's
ID_MAX = (1 << SENTINEL_BITS) - 1   # dummies, and 2^24-2 is reserved as the
EMPTY_SET_ID = ID_MAX - 1           # empty-start-set sentinel (matches no id)


def build(n_rows: int, m_edges: int, set_size: int,
          bidirectional: bool = False) -> Operator:
    c = Circuit(n_rows, name="expand_set" + ("_birc" if bidirectional else ""))
    A = c.add_data("A")
    B = c.add_data("B")
    sel_e = region_selector(c, "sel_edge", m_edges)
    sel_pairs = region_selector(c, "sel_pairs", set_size + 1)  # S' has s+2 rows
    IDs = c.add_instance("IDs")          # extended sorted start set S'
    out_sel = c.add_instance("out_sel")
    C_s = c.add_instance("C_s")
    C_t = c.add_instance("C_t")
    Ap = c.add_advice("A_sorted")
    Bp = c.add_advice("B_sorted")
    aux = c.add_advice("C_aux")
    aux2 = c.add_advice("C_aux_next")
    # sorted table is a permutation of the committed table
    c.add_multiset_equal("sort_perm", [Ap, Bp], sel_e, [A, B], sel_e)
    # S' strictly increasing (public, but enforced in-circuit per the paper)
    c.add_range_check("ids_sorted", IDs.rotate(1) - IDs - Const(1),
                      SENTINEL_BITS, sel=sel_pairs)
    # bracketing: (C_aux, C'_aux) must be consecutive in S' ...
    c.add_bus("glb_pair", [aux, aux2], [IDs, IDs.rotate(1)], m_f=sel_e,
              t_sel=sel_pairs)
    # ... and C_aux <= A' < C'_aux
    c.add_range_check("glb_lo", Ap - aux, SENTINEL_BITS, sel=sel_e)
    c.add_range_check("glb_hi", aux2 - Const(1) - Ap, SENTINEL_BITS, sel=sel_e)
    # selection flag: A' == C_aux
    fl, inv = eq_flag_gadget(c, "flag", Ap, aux, sel_e)
    handles = dict(A=A, B=B, sel_e=sel_e, sel_pairs=sel_pairs, IDs=IDs,
                   out_sel=out_sel, C_s=C_s, C_t=C_t, Ap=Ap, Bp=Bp, aux=aux,
                   aux2=aux2, fl=fl, inv=inv, m_edges=m_edges,
                   set_size=set_size, bidirectional=bidirectional)
    if not bidirectional:
        c.add_multiset_equal("out_perm", [C_s, C_t], out_sel, [Ap, Bp], fl)
    else:
        # second bracket on the other endpoint (canonical undirected storage)
        aux_b = c.add_advice("C_aux_b")
        aux2_b = c.add_advice("C_aux_next_b")
        c.add_bus("glb_pair_b", [aux_b, aux2_b], [IDs, IDs.rotate(1)],
                  m_f=sel_e, t_sel=sel_pairs)
        c.add_range_check("glb_lo_b", Bp - aux_b, SENTINEL_BITS, sel=sel_e)
        c.add_range_check("glb_hi_b", aux2_b - Const(1) - Bp, SENTINEL_BITS,
                          sel=sel_e)
        fl_b, inv_b = eq_flag_gadget(c, "flag_b", Bp, aux_b, sel_e)
        # output direction marker partitions the public output between the
        # two orientations
        out_dir = c.add_instance("out_dir")
        m_fwd = c.add_advice("m_out_fwd")
        m_bwd = c.add_advice("m_out_bwd")
        c.add_gate("m_fwd_def", m_fwd - out_sel * out_dir)
        c.add_gate("m_bwd_def", m_bwd - out_sel * (Const(1) - out_dir))
        c.add_multiset_equal("out_fwd", [C_s, C_t], m_fwd, [Ap, Bp], fl)
        c.add_multiset_equal("out_bwd", [C_s, C_t], m_bwd, [Bp, Ap], fl_b)
        handles.update(aux_b=aux_b, aux2_b=aux2_b, fl_b=fl_b, inv_b=inv_b,
                       out_dir=out_dir, m_fwd=m_fwd, m_bwd=m_bwd)
    op = Operator(c.name, c)
    op.handles = handles
    return op


def _extended_sorted(ids, set_size):
    s = np.sort(np.asarray(ids, np.int64))
    assert len(s) == set_size
    return np.concatenate([[0], s, [ID_MAX]])


def _glb(sorted_ext: np.ndarray, vals: np.ndarray):
    """greatest-lower-bound + successor for each value."""
    pos = np.searchsorted(sorted_ext, vals, side="right") - 1
    pos = np.clip(pos, 0, len(sorted_ext) - 2)
    return sorted_ext[pos], sorted_ext[pos + 1]


def witness(op: Operator, src, dst, ids):
    """ids: the start set (unextended). Returns (advice, instance, data)."""
    h = op.handles
    c = op.circuit
    n = c.n_rows
    m = h["m_edges"]
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    data[h["A"].index] = pad_col(src, n)
    data[h["B"].index] = pad_col(dst, n)
    order = np.argsort(src, kind="stable")
    Ap = pad_col(src[order], n)
    Bp = pad_col(dst[order], n)
    advice[h["Ap"].index] = Ap
    advice[h["Bp"].index] = Bp
    s_ext = _extended_sorted(ids, h["set_size"])
    inst[h["IDs"].index, : len(s_ext)] = s_ext
    sel = np.zeros(n, np.int64)
    sel[:m] = 1
    # sortedness limbs for IDs (instance rotation): diff of consecutive
    ids_col = inst[h["IDs"].index].astype(np.int64)
    diff = np.where(np.arange(n) < h["set_size"] + 1,
                    np.roll(ids_col, -1) - ids_col - 1, 0)
    _fill_named_range(c, advice, "ids_sorted", diff)
    # bracketing on A'
    glb, suc = _glb(s_ext, Ap[:m])
    aux = pad_col(glb, n)
    aux2 = pad_col(suc, n)
    # padding rows: keep aux pair valid-shaped but unselected (sel gates)
    advice[h["aux"].index] = aux
    advice[h["aux2"].index] = aux2
    _fill_named_range(c, advice, "glb_lo", np.where(sel, (Ap - aux) % F.P, 0))
    _fill_named_range(c, advice, "glb_hi",
                      np.where(sel, (aux2 - 1 - Ap) % F.P, 0))
    fill_eq_flag(advice, h["fl"], h["inv"], Ap, aux, sel)
    flv = advice[h["fl"].index].astype(bool)
    if not h["bidirectional"]:
        k = int(flv.sum())
        inst[h["out_sel"].index, :k] = 1
        inst[h["C_s"].index, :k] = Ap[flv]
        inst[h["C_t"].index, :k] = Bp[flv]
    else:
        glb_b, suc_b = _glb(s_ext, Bp[:m])
        aux_b = pad_col(glb_b, n)
        aux2_b = pad_col(suc_b, n)
        advice[h["aux_b"].index] = aux_b
        advice[h["aux2_b"].index] = aux2_b
        _fill_named_range(c, advice, "glb_lo_b",
                          np.where(sel, (Bp - aux_b) % F.P, 0))
        _fill_named_range(c, advice, "glb_hi_b",
                          np.where(sel, (aux2_b - 1 - Bp) % F.P, 0))
        fill_eq_flag(advice, h["fl_b"], h["inv_b"], Bp, aux_b, sel)
        flb = advice[h["fl_b"].index].astype(bool)
        kf, kb = int(flv.sum()), int(flb.sum())
        k = kf + kb
        assert k <= n, f"output ({k}) exceeds circuit rows ({n}): " \
                       f"size n_rows to the expansion output"
        inst[h["out_sel"].index, :k] = 1
        inst[h["out_dir"].index, :kf] = 1
        inst[h["C_s"].index, :kf] = Ap[flv]
        inst[h["C_t"].index, :kf] = Bp[flv]
        inst[h["C_s"].index, kf:k] = Bp[flb]
        inst[h["C_t"].index, kf:k] = Ap[flb]
        advice[h["m_fwd"].index] = inst[h["out_sel"].index] * inst[h["out_dir"].index]
        advice[h["m_bwd"].index] = inst[h["out_sel"].index] * \
            (1 - inst[h["out_dir"].index])
    return advice, inst, data


def _fill_named_range(c: Circuit, advice, prefix: str, values):
    """Fill limb columns created by add_range_check under ``prefix``.

    Values that do not fit the declared range are clamped — the recompose
    gate / limb lookups will then (correctly) reject the witness, which is
    exactly what a cheating prover faces.
    """
    limb_bits = min(16, max(1, int(np.log2(c.n_rows))))
    v = np.asarray(values, np.int64).copy()
    v = np.where(v < 0, 0, v)   # unfillable: leave limbs inconsistent
    j = 0
    while True:
        name = f"{prefix}/limb{j}"
        if name not in c.advice_names:
            break
        advice[c.advice_names.index(name)] = v & ((1 << limb_bits) - 1)
        v >>= limb_bits
        j += 1
    assert j > 0, f"no limbs found for {prefix}"
