"""Scalar aggregation (count / sum / min) over a chained value column.

``count`` and ``sum`` thread a running accumulator through the input region
(the order-by running-count pattern): R[0] = 0, R[i+1] = R[i] + term[i], and
a logUp bus binds the public ``agg_out`` cell at row 0 to the accumulator at
the boundary row just past the region.  ``count`` counts *nonzero* entries
(ids are >= 1; the chained-table padding row is 0), with the per-row term
evidenced by the inverse-trick zero flag so the value column is constrained,
not merely present.  ``sum`` is mod-P by construction (documented limit).

``min`` avoids the accumulator entirely: a range check forces
``V - agg_out ∈ [0, 2^28)`` on every input row (agg_out is a lower bound)
and an explicit-multiplicity bus forces ``agg_out`` to originate from an
``is_min``-marked input row, so the lower bound is attained.  The bus
multiplicity is the marker column itself — an auto-multiplicity column here
would leave the marker free.
"""
from __future__ import annotations

import numpy as np

from .. import field as F
from ..plonkish import Circuit, Const
from .common import Operator, eq_flag_gadget, fill_eq_flag, pad_col, region_selector
from .set_expansion import _fill_named_range

VAL_BITS = 28
AGGS = ("count", "sum", "min")


def build(n_rows: int, m_in: int, agg: str) -> Operator:
    assert agg in AGGS, f"unknown aggregation {agg!r}"
    assert 1 <= m_in < n_rows, "need the boundary row just after the region"
    c = Circuit(n_rows, name=f"agg_{agg}")
    V = c.add_data("V")
    sel_in = region_selector(c, "sel_in", m_in)
    row0 = np.zeros(n_rows, np.uint32)
    row0[0] = 1
    onehot0 = c.add_fixed("onehot0", row0)
    agg_out = c.add_instance("agg_out")
    handles = dict(V=V, sel_in=sel_in, onehot0=onehot0, agg_out=agg_out,
                   m_in=m_in, agg=agg)
    if agg in ("count", "sum"):
        boundary = np.zeros(n_rows, np.uint32)
        boundary[m_in] = 1
        b_end = c.add_fixed("b_end", boundary)
        R = c.add_advice("acc")
        if agg == "count":
            fe, inv = eq_flag_gadget(c, "zero", V, Const(0), sel_in)
            cnt = c.add_advice("cnt")
            c.add_gate("cnt_def", cnt - sel_in * (Const(1) - fe))
            term = cnt
            handles.update(fe=fe, inv=inv, cnt=cnt)
        else:
            term = V
        c.add_gate("acc0", onehot0 * R)
        c.add_gate("acc_step", sel_in * (R.rotate(1) - R - term))
        # bind the public output (read at row 0) to the final accumulator
        c.add_bus("agg_bind", [agg_out], [R], m_f=onehot0, t_sel=b_end)
        handles.update(R=R, b_end=b_end)
    else:
        is_min = c.add_advice("is_min")
        c.add_gate("ismin_bool", is_min * (Const(1) - is_min))
        c.add_gate("ismin_region", (Const(1) - sel_in) * is_min)
        c.add_bus("min_origin", [agg_out], [V], m_f=onehot0, m_t=is_min)
        c.add_range_check("min_le", V - agg_out, VAL_BITS, sel=sel_in)
        handles.update(is_min=is_min)
    op = Operator(c.name, c)
    op.handles = handles
    return op


def witness(op: Operator, vals):
    h = op.handles
    c = op.circuit
    n = c.n_rows
    m = h["m_in"]
    agg = h["agg"]
    vals = np.asarray(vals, np.int64)
    assert len(vals) == m
    data = op.new_data()
    advice = op.new_advice()
    inst = op.new_instance()
    data[h["V"].index] = pad_col(vals, n)
    v = np.zeros(n, np.int64)
    v[:m] = vals
    sel = np.zeros(n, np.int64)
    sel[:m] = 1
    if agg == "count":
        fill_eq_flag(advice, h["fe"], h["inv"], v, np.zeros(n), sel)
        cnt = sel * (1 - advice[h["fe"].index].astype(np.int64))
        advice[h["cnt"].index] = cnt
        term = cnt
        result = int(cnt.sum())
    elif agg == "sum":
        term = v % F.P
        result = int(v.sum() % F.P)
    else:
        assert vals.min() >= 0 and vals.max() < (1 << VAL_BITS), \
            "min aggregation values exceed VAL_BITS bound"
        result = int(vals.min())
        is_min = np.zeros(n, np.int64)
        is_min[int(np.argmin(vals))] = 1
        advice[h["is_min"].index] = is_min
        _fill_named_range(c, advice, "min_le", np.where(sel, v - result, 0))
    if agg in ("count", "sum"):
        advice[h["R"].index] = (np.concatenate([[0], np.cumsum(term)[:-1]])
                                % F.P)
    inst[h["agg_out"].index] = result % F.P
    return advice, inst, data
