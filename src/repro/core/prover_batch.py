"""Lane-batched DEEP-ALI + FRI prover: L same-shaped witnesses, one pass.

The serving observation (ROADMAP "millions of users" axis): the paper's
expansion-centric decomposition makes every query a chain of SMALL
shape-regular circuits, and at those sizes the prover's wall-clock is
dominated by per-dispatch overhead, not arithmetic.  Same-shaped steps from
*different* queries follow the identical Fiat–Shamir schedule — only the
absorbed values differ — so stacking their witnesses behind a leading lane
axis ``L`` lets every phase (NTT/LDE, Merkle levels, sponge blocks,
constraint evaluation, FRI folds) run as ONE batched dispatch that amortizes
across queries.  ``repro.serve`` routes concurrent queries into these lanes.

Bit-identity contract (enforced by ``tests/test_serve.py`` across compute
backends): lane ``l`` of :func:`prove_batch` produces a :class:`Proof` whose
wire bytes equal the solo ``prove(keys, *witnesses[l])`` bytes.  It holds
because every primitive here is the solo primitive with a leading batch dim
— all field ops are elementwise integers mod P (no reassociation), hashing
and the NTT are row-independent under every backend, and per-lane challenge
streams never mix (:class:`~repro.core.transcript.BatchedTranscript`).
Nothing is approximated: this is the same proof, computed L at a time.

Layout conventions (solo shape -> lane shape):
  witness columns   (c, n)     -> (L, c, n)
  LDE matrices      (c, nl)    -> (L, c, nl)
  ext/Fp4 values    (n, 4)     -> (L, n, 4)
  challenges        (4,)       -> (L, 4)
  Merkle digests    (8,)       -> (L, 8)
Challenge broadcasts use ``[:, None, :]`` where the solo code used
``jnp.broadcast_to(ch, val.shape)`` — same elementwise products.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import backend as be
from . import field as F
from . import fri as fri_mod
from . import merkle
from . import poly
from . import prover as pv
from .plonkish import ADVICE, DATA, FIXED, INSTANCE, BaseOps, eval_expr
from .transcript import BatchedTranscript

_U32 = jnp.uint32

__all__ = ["prove_batch"]


# ---------------------------------------------------------------------------
# lane-shaped helpers (solo siblings live in repro.core.prover)
# ---------------------------------------------------------------------------
def _lde_lanes(cols: jnp.ndarray, blowup: int, shift: int) -> jnp.ndarray:
    """(L, c, n) evaluations -> (L, c, n*blowup) coset LDE (c may be 0)."""
    if cols.shape[1] == 0:
        return jnp.zeros((cols.shape[0], 0, cols.shape[2] * blowup), _U32)
    return poly.coset_lde(cols, blowup, shift)


def _compress_tuple_lanes(vals, alpha):
    """Paper Eq. (1) with (L, 4) lane challenges over (L, n) columns."""
    acc = F.ext(vals[0])
    apow = alpha
    for v in vals[1:]:
        acc = F.eadd(acc, F.emul(apow[:, None, :], F.ext(v)))
        apow = F.emul(apow, alpha)
    return acc


def _build_ext_columns_lanes(circuit, getter_n, like_n, alpha, beta):
    """(L, n_ext, n, 4) phase-2 columns; mirrors pv.build_ext_columns."""
    lanes, n = like_n.shape
    cols = []
    for bus in circuit.buses:
        f_vals = [eval_expr(e, getter_n, BaseOps, like_n) for e in bus.f_tuple]
        t_vals = [eval_expr(e, getter_n, BaseOps, like_n) for e in bus.t_tuple]
        m_f = eval_expr(bus.m_f, getter_n, BaseOps, like_n)
        m_t = eval_expr(bus.m_t * bus.t_sel, getter_n, BaseOps, like_n)
        d_f = F.eadd(beta[:, None, :], _compress_tuple_lanes(f_vals, alpha))
        d_t = F.eadd(beta[:, None, :], _compress_tuple_lanes(t_vals, alpha))
        num = F.esub(F.fmul(d_t, m_f[:, :, None]), F.fmul(d_f, m_t[:, :, None]))
        inc = F.emul(num, F.ebatch_inv(F.emul(d_f, d_t)))
        h = pv._cumsum_mod(inc, axis=1)
        h = jnp.concatenate([jnp.zeros((lanes, 1, 4), _U32), h[:, :-1]], axis=1)
        cols.append(h)
    for gp in circuit.gps:
        c1 = [eval_expr(e, getter_n, BaseOps, like_n) for e in gp.c1_tuple]
        c2 = [eval_expr(e, getter_n, BaseOps, like_n) for e in gp.c2_tuple]
        s1 = eval_expr(gp.sel1, getter_n, BaseOps, like_n)
        s2 = eval_expr(gp.sel2, getter_n, BaseOps, like_n)
        one = jnp.zeros((lanes, n, 4), _U32).at[..., 0].set(1)
        d1 = F.eadd(beta[:, None, :], _compress_tuple_lanes(c1, alpha))
        d2 = F.eadd(beta[:, None, :], _compress_tuple_lanes(c2, alpha))
        not_s1 = F.fsub(jnp.full_like(s1, 1), s1)
        not_s2 = F.fsub(jnp.full_like(s2, 1), s2)
        f1 = F.eadd(F.fmul(d1, s1[:, :, None]), F.fmul(one, not_s1[:, :, None]))
        f2 = F.eadd(F.fmul(d2, s2[:, :, None]), F.fmul(one, not_s2[:, :, None]))
        ratio = F.emul(f1, F.ebatch_inv(f2))
        # the dispatched accumulator is (n, 4)-shaped; lanes run it in turn
        # (bit-identical to solo by construction — same call per lane)
        z = jnp.stack([be.active().grand_product_ext(ratio[l])
                       for l in range(lanes)])
        cols.append(z)
    if not cols:
        return jnp.zeros((lanes, 0, n, 4), _U32)
    return jnp.stack(cols, axis=1)


def _combine_constraints_lanes(circuit, base_getter, alpha, beta, alpha_c,
                               like_base, ext_getter, row0_val):
    """sum_i alpha_c^i * constraint_i on the LDE domain, lane-batched.

    Base values are (L, nl); the accumulator is (L, nl, 4); challenges are
    (L, 4).  Mirrors pv.combine_constraints with BaseOps (the prover path).
    """
    acc = None
    a_pow = None

    def ext_of_base(v):
        z = jnp.zeros(v.shape + (4,), _U32)
        return z.at[..., 0].set(v)

    def add_term(val_ext):
        nonlocal acc, a_pow
        if acc is None:
            acc = val_ext
            a_pow = alpha_c
        else:
            acc = F.eadd(acc, F.emul(a_pow[:, None, :], val_ext))
            a_pow = F.emul(a_pow, alpha_c)

    for _, gate in circuit.gates:
        v = eval_expr(gate, base_getter, BaseOps, like_base)
        add_term(ext_of_base(v))

    def compress(exprs):
        vals = [eval_expr(e, base_getter, BaseOps, like_base) for e in exprs]
        out = ext_of_base(vals[0])
        apow = alpha
        for v in vals[1:]:
            out = F.eadd(out, F.emul(apow[:, None, :], ext_of_base(v)))
            apow = F.emul(apow, alpha)
        return out

    def mul_base(val_ext, base_v):
        return F.emul(val_ext, ext_of_base(base_v))

    for bus in circuit.buses:
        d_f = F.eadd(beta[:, None, :], compress(bus.f_tuple))
        d_t = F.eadd(beta[:, None, :], compress(bus.t_tuple))
        h = ext_getter(bus.ext_col, 0)
        h1 = ext_getter(bus.ext_col, 1)
        m_f = eval_expr(bus.m_f, base_getter, BaseOps, like_base)
        m_t = eval_expr(bus.m_t * bus.t_sel, base_getter, BaseOps, like_base)
        term = F.emul(F.esub(h1, h), F.emul(d_f, d_t))
        term = F.esub(term, mul_base(d_t, m_f))
        term = F.eadd(term, mul_base(d_f, m_t))
        add_term(term)
    for gp in circuit.gps:
        d1 = F.eadd(beta[:, None, :], compress(gp.c1_tuple))
        d2 = F.eadd(beta[:, None, :], compress(gp.c2_tuple))
        s1 = eval_expr(gp.sel1, base_getter, BaseOps, like_base)
        s2 = eval_expr(gp.sel2, base_getter, BaseOps, like_base)
        one_b = BaseOps.const(1, like_base)
        f1 = F.eadd(mul_base(d1, s1), ext_of_base(BaseOps.sub(one_b, s1)))
        f2 = F.eadd(mul_base(d2, s2), ext_of_base(BaseOps.sub(one_b, s2)))
        z = ext_getter(gp.ext_col, 0)
        z1 = ext_getter(gp.ext_col, 1)
        add_term(F.esub(F.emul(z1, f2), F.emul(z, f1)))
        one_e = jnp.zeros(z.shape, _U32).at[..., 0].set(1)
        add_term(F.emul(ext_of_base(row0_val), F.esub(z, one_e)))
    if acc is None:
        acc = jnp.zeros(like_base.shape + (4,), _U32)
    return acc


@jax.jit
def _eval_at_ext_lanes(coeffs: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Horner-evaluate (L, m, n) Fp coefficients at per-lane Fp4 ``z``
    (L, 4) -> (L, m, 4); mirrors poly.eval_at_ext per lane (jit like it —
    the inner scan must not re-trace on each of the rot x kind calls)."""
    n = coeffs.shape[-1]

    def step(carry, _):
        return F.emul(carry, z), carry

    one = jnp.broadcast_to(jnp.asarray(F.EXT_ONE), z.shape).astype(_U32)
    _, zpows = jax.lax.scan(step, one, None, length=n)     # (n, L, 4)
    zpows = jnp.moveaxis(zpows, 0, 1)                      # (L, n, 4)
    prod = F.fmul(coeffs[..., None].astype(_U32), zpows[:, None, :, :])
    s = jnp.sum(prod.astype(jnp.uint64), axis=-2) % jnp.uint64(F.P)
    return s.astype(_U32)


# ---------------------------------------------------------------------------
# the batched prove
# ---------------------------------------------------------------------------
def prove_batch(keys: pv.Keys, witnesses: list, label: str = "zkgraph",
                placement=None) -> list:
    """Prove L same-shaped witnesses as one lane-batched pass.

    ``witnesses``: list of ``(advice_np, instance_np, data_np)`` triples,
    all for ``keys.circuit``.  Returns one :class:`~repro.core.prover.Proof`
    per lane, wire-byte-identical (timings aside) to the solo
    ``prove(keys, ...)`` of that lane.  ``placement`` (optional,
    :class:`repro.serve.placement.Placement`) shards the lane axis across a
    device mesh; ``None`` keeps everything on the default device.

    Runs under ``keys.backend`` like solo prove — lanes never mix backends.
    """
    with be.use(keys.backend):
        return _prove_batch_impl(keys, witnesses, label, placement)


def _prove_batch_impl(keys: pv.Keys, witnesses: list, label: str,
                      placement=None) -> list:
    circuit, cfg = keys.circuit, keys.cfg
    n, B = circuit.n_rows, cfg.blowup
    nl = n * B
    lanes = len(witnesses)
    assert lanes >= 1, "prove_batch needs at least one lane"
    t0 = time.perf_counter()
    timings = {}

    adv_list, inst_list, data_list = [], [], []
    for advice_np, instance_np, data_np in witnesses:
        if data_np is None:
            data_np = np.zeros((0, n), np.uint32)
        pv.auto_multiplicities(circuit, data_np, advice_np, instance_np)
        adv_list.append(advice_np.astype(np.uint32))
        inst_list.append(instance_np.astype(np.uint32))
        data_list.append(data_np.astype(np.uint32))
    advice = jnp.asarray(np.stack(adv_list))               # (L, n_adv, n)
    data = jnp.asarray(np.stack(data_list)) if circuit.n_data \
        else jnp.zeros((lanes, 0, n), _U32)
    inst = jnp.asarray(np.stack(inst_list)) if circuit.n_instance \
        else jnp.zeros((lanes, 0, n), _U32)
    if placement is not None:
        advice, data, inst = placement.shard_lanes(advice, data, inst)

    btx = BatchedTranscript(label, lanes)
    btx.absorb_shared(circuit.digest_seed())
    if circuit.n_instance:
        inst_tree = merkle.commit_lanes(inst.transpose(0, 2, 1))
        btx.absorb_digest(np.asarray(inst_tree.roots))

    # --- phase 0: commit the dataset (the declared-DB binding) --------------
    data_coeffs = poly.intt(data) if circuit.n_data else data
    data_lde = _lde_lanes(data, B, cfg.shift)
    data_tree = merkle.commit_lanes(data_lde.transpose(0, 2, 1)) \
        if circuit.n_data else None
    data_roots = np.asarray(data_tree.roots) if data_tree \
        else np.zeros((lanes, 8), np.uint32)
    btx.absorb_digest(data_roots)

    # --- phase 1: commit advice -------------------------------------------
    adv_coeffs = poly.intt(advice) if circuit.n_advice else advice
    adv_lde = _lde_lanes(advice, B, cfg.shift)
    adv_tree = merkle.commit_lanes(adv_lde.transpose(0, 2, 1)) \
        if circuit.n_advice else None
    adv_roots = np.asarray(adv_tree.roots) if adv_tree \
        else np.zeros((lanes, 8), np.uint32)
    btx.absorb_digest(adv_roots)
    timings["commit_advice"] = time.perf_counter() - t0

    alpha = jnp.asarray(btx.challenge_ext())               # (L, 4)
    beta = jnp.asarray(btx.challenge_ext())

    # --- phase 2: ext columns ----------------------------------------------
    t1 = time.perf_counter()
    fixed_n = jnp.asarray(np.stack(circuit.fixed_cols)
                          if circuit.fixed_cols
                          else np.zeros((0, n), np.uint32))
    fixed_n_lanes = jnp.broadcast_to(fixed_n, (lanes,) + fixed_n.shape)

    def getter_n(kind, idx, rot):
        src = {FIXED: fixed_n_lanes, ADVICE: advice, INSTANCE: inst,
               DATA: data}[kind]
        return jnp.roll(src[:, idx], -rot, axis=-1)

    like_n = jnp.zeros((lanes, n), _U32)
    ext_cols = _build_ext_columns_lanes(circuit, getter_n, like_n, alpha, beta)
    n_ext = circuit.n_ext
    ext_base = ext_cols.transpose(0, 1, 3, 2).reshape(lanes, n_ext * 4, n) \
        if n_ext else jnp.zeros((lanes, 0, n), _U32)
    ext_coeffs = poly.intt(ext_base) if n_ext else ext_base
    ext_lde = _lde_lanes(ext_base, B, cfg.shift)
    ext_tree = merkle.commit_lanes(ext_lde.transpose(0, 2, 1)) \
        if n_ext else None
    ext_roots = np.asarray(ext_tree.roots) if ext_tree \
        else np.zeros((lanes, 8), np.uint32)
    btx.absorb_digest(ext_roots)
    timings["phase2_ext"] = time.perf_counter() - t1

    alpha_c = jnp.asarray(btx.challenge_ext())

    # --- quotient -----------------------------------------------------------
    t2 = time.perf_counter()
    fixed_lde = jnp.broadcast_to(keys.fixed_lde,
                                 (lanes,) + keys.fixed_lde.shape)
    inst_lde = _lde_lanes(inst, B, cfg.shift)

    def getter_lde(kind, idx, rot):
        src = {FIXED: fixed_lde, ADVICE: adv_lde, INSTANCE: inst_lde,
               DATA: data_lde}[kind]
        return jnp.roll(src[:, idx], -B * rot, axis=-1)

    def ext_getter_lde(col, rot):
        comps = [jnp.roll(ext_lde[:, col * 4 + c], -B * rot, axis=-1)
                 for c in range(4)]
        return jnp.stack(comps, axis=-1)

    like_lde = jnp.zeros((lanes, nl), _U32)
    row0_lde = (getter_lde(FIXED, circuit.fixed_names.index("__row0"), 0)
                if circuit.gps else like_lde)
    c_lde = _combine_constraints_lanes(circuit, getter_lde, alpha, beta,
                                       alpha_c, like_lde, ext_getter_lde,
                                       row0_lde)
    # Z_H(x_i): same period-B host sequence as solo (lane-independent)
    wn = F.root_of_unity(nl)
    ratio = pow(wn, n, F.P)
    vals = np.empty(B, np.uint64)
    acc = pow(cfg.shift, n, F.P)
    for i in range(B):
        vals[i] = (acc - 1) % F.P
        acc = acc * ratio % F.P
    zh = np.asarray([vals[i % B] for i in range(nl)], np.uint32)
    zh_inv = F.fbatch_inv(jnp.asarray(zh))
    q_evals = F.fmul(c_lde, zh_inv[None, :, None])
    q_coeffs = poly.coset_coeffs(q_evals.transpose(0, 2, 1), cfg.shift)
    q_segments = q_coeffs.reshape(lanes, 4, B, n) \
        .transpose(0, 2, 1, 3).reshape(lanes, B * 4, n)
    q_lde = pv._lde_from_coeffs(q_segments, B, cfg.shift)
    q_tree = merkle.commit_lanes(q_lde.transpose(0, 2, 1))
    q_roots = np.asarray(q_tree.roots)
    btx.absorb_digest(q_roots)
    timings["quotient"] = time.perf_counter() - t2

    # --- OOD openings --------------------------------------------------------
    t3 = time.perf_counter()
    z = jnp.asarray(btx.challenge_ext())                   # (L, 4)
    sched = pv.opening_schedule(circuit, B)
    fixed_coeffs = jnp.broadcast_to(keys.fixed_coeffs,
                                    (lanes,) + keys.fixed_coeffs.shape)
    coeff_src = {FIXED: fixed_coeffs,
                 INSTANCE: poly.intt(inst) if circuit.n_instance else inst,
                 DATA: data_coeffs, ADVICE: adv_coeffs, "ext": ext_coeffs,
                 "quotient": q_segments}
    w_n = F.root_of_unity(n)
    openings = {}              # (kind, i, rot) -> (L, 4) np
    rots = sorted({r for (_, _, r) in sched})
    for rot in rots:
        zr = F.emul_fp(z, _U32(pow(w_n, rot, F.P)))
        for kind in (FIXED, INSTANCE, DATA, ADVICE, "ext", "quotient"):
            idxs = [i for (k, i, rr) in sched if k == kind and rr == rot]
            if not idxs:
                continue
            coeffs = coeff_src[kind][:, jnp.asarray(idxs)]
            vals = np.asarray(_eval_at_ext_lanes(coeffs, zr))  # (L, m, 4)
            for j, i in enumerate(idxs):
                openings[(kind, i, rot)] = vals[:, j]
    for key in sched:
        btx.absorb(openings[key])
    timings["ood_openings"] = time.perf_counter() - t3

    # --- DEEP composition -----------------------------------------------------
    t4 = time.perf_counter()
    gamma = jnp.asarray(btx.challenge_ext())
    pts_ext = F.ext(F.fmul(poly.domain_points(nl), _U32(cfg.shift)))  # (nl,4)
    committed = [(k, i, r) for (k, i, r) in sched
                 if k in (DATA, ADVICE, "ext", "quotient")]
    lde_src = {DATA: data_lde, ADVICE: adv_lde, "ext": ext_lde,
               "quotient": q_lde}
    deep = jnp.zeros((lanes, nl, 4), _U32)
    g_pow = gamma
    groups = {}
    for (k, i, r) in committed:
        groups.setdefault(r, []).append((k, i))
    for r in sorted(groups):
        zr = F.emul_fp(z, _U32(pow(w_n, r, F.P)))
        denom = F.esub(pts_ext[None], zr[:, None, :])
        inv_d = F.ebatch_inv(denom)
        num = jnp.zeros((lanes, nl, 4), _U32)
        for (k, i) in groups[r]:
            p_lde = lde_src[k][:, i]                       # (L, nl)
            diff = F.esub(F.ext(p_lde),
                          jnp.asarray(openings[(k, i, r)])[:, None, :])
            num = F.eadd(num, F.emul(g_pow[:, None, :], diff))
            g_pow = F.emul(g_pow, gamma)
        deep = F.eadd(deep, F.emul(num, inv_d))
    timings["deep"] = time.perf_counter() - t4

    # --- FRI -------------------------------------------------------------------
    t5 = time.perf_counter()
    fproofs = fri_mod.fri_prove_lanes(deep, btx, cfg.fri())
    timings["fri"] = time.perf_counter() - t5

    # --- query openings ---------------------------------------------------------
    q_idx = jnp.asarray(np.stack([fp.query_indices for fp in fproofs]))
    idx_all = jnp.concatenate([q_idx, q_idx + nl // 2], axis=1)
    tree_rows = {}             # name -> (rows (L,k,w), paths (L,k,d,8)) np
    n_open = idx_all.shape[1]
    for name, tree in (("data", data_tree), ("advice", adv_tree),
                       ("ext", ext_tree), ("quotient", q_tree)):
        if tree is None:
            tree_rows[name] = (
                np.zeros((lanes, n_open, 0), np.uint32),
                np.zeros((lanes, n_open, 0, 8), np.uint32))
        else:
            rows, paths = merkle.open_lanes(tree, idx_all)
            tree_rows[name] = (np.asarray(rows), np.asarray(paths))
    timings["total"] = time.perf_counter() - t0

    # --- per-lane Proof assembly (same key orders as solo) ---------------------
    proofs = []
    for l in range(lanes):
        sent = {k: v[l] for k, v in openings.items()
                if k[0] in (DATA, ADVICE, "ext", "quotient")}
        tree_openings = {name: (rows[l], paths[l])
                         for name, (rows, paths) in tree_rows.items()}
        proofs.append(pv.Proof(data_roots[l], adv_roots[l], ext_roots[l],
                               q_roots[l], sent, fproofs[l], tree_openings,
                               dict(timings)))
    return proofs
