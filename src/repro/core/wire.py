"""Canonical proof-bundle wire format: versioned, deterministic, bounded.

This replaces the seed's pickle serialization of :class:`ProofBundle` — the
one place where attacker-controlled bytes crossed the verifier's trust
boundary (paper §III-C assumes the verifier trusts only the owner's published
commitments).  Design rules:

* **No code execution on decode.**  The format is a fixed grammar of tagged
  fields over five primitive kinds (ints, floats, strings, numpy arrays,
  containers); decoding allocates nothing before validating dtype, shape and
  remaining-byte bounds.
* **Versioned.**  Every message starts with ``MAGIC + version + payload
  kind``; a version or kind mismatch raises :class:`WireFormatError` (so a
  verifier fed a legacy / future bundle fails closed instead of
  mis-interpreting bytes).
* **Deterministic.**  Dict entries are sorted by their encoded key bytes and
  the decoder *rejects* out-of-order entries, so every bundle has exactly one
  canonical encoding and ``encode(decode(b)) == b`` byte-for-byte.
* **Bounded.**  Strings, containers, array dims and element counts all have
  hard caps; a length prefix larger than the remaining buffer is an error,
  never an allocation.
* **Schema-checked.**  A step's ``kind`` must name a registered operator
  adapter and its ``shape`` dict must match that adapter's declared
  ``shape_schema`` exactly (key set *and* types, ``bool`` distinct from
  ``int``) — malformed circuit geometry is rejected before the verifier
  does any work.

Grammar (all integers little-endian; the full byte-level spec with golden
test vectors is ``docs/protocol.md``)::

    message   := MAGIC(4) version:u16 kind:u8 body
    bundle    := Q query:str P params:value C cfg(4 x u32) G digest:arr(8,)
                 S nsteps:u32 step* R result:value
    step      := K kind:str H shape:value D desc:str I instance:arr F proof
    proof     := 4 roots:arr(8,) OPEN openings TREE tree_openings
                 FRI friproof T timings:value
    friproof  := roots:[arr(8,)] final:arr(n,4) qidx:arr(i64)
                 openings:[(rows:arr, paths:arr)]
    manifest  := V mver:u32 N n_nodes:i64 E edge_counts T tables R roots
    checkpt   := O origin:str S tree_size:i64 R root:arr(8,)
    incl      := I leaf_index:i64 S tree_size:i64 P path:arr(d,8)
    consist   := O old_size:i64 N new_size:i64 P path:arr(d,8)
    value     := tagged int | bool | float | str | arr | tuple | list | dict
    arr       := dtype:u8 ndim:u8 dims:u32* raw-bytes

Any deviation — truncation, a flipped tag, an oversized length, a wrong
dtype, trailing bytes — raises :class:`WireFormatError`.
"""
from __future__ import annotations

import struct

import numpy as np

MAGIC = b"ZKGB"
WIRE_VERSION = 3     # v3: gossip envelopes carry Ed25519 detached
                     # signatures (kind 9); the v2 MAC-era envelope
                     # (kind 8) is retired and rejected by name

# payload kinds (a message's top-level type)
KIND_BUNDLE = 1
KIND_PROOF = 2
KIND_FRI = 3
KIND_MANIFEST = 4
KIND_CHECKPOINT = 5
KIND_INCLUSION = 6
KIND_CONSISTENCY = 7
_KIND_GOSSIP_MAC_RETIRED = 8    # v2 MAC-era envelope; never decoded again
KIND_GOSSIP = 9      # v3 signed envelope (Ed25519 over checkpoint bytes)

# hard caps: a malformed length prefix can never trigger a large allocation
MAX_STR = 4096
MAX_ITEMS = 1 << 16          # container entries (dict / list / tuple)
MAX_STEPS = 64
MAX_ARR_DIMS = 4
MAX_ARR_ELEMS = 1 << 24      # per-array element cap (64 MiB of int64)
MAX_FRI_LAYERS = 64
MAX_DEPTH = 16               # value-nesting cap (no RecursionError from bytes)
MAX_TABLES = 256             # manifest: registered base-table descriptors
MAX_SIZES = 64               # manifest: published circuit sizes per table
MAX_COLUMNS = 64             # manifest: named columns per table
MAX_LOG_DEPTH = 64           # transparency log: audit/consistency path nodes
MAX_EMBED = 1 << 20          # gossip: embedded checkpoint/proof message bytes

# value tags
_T_INT, _T_BOOL, _T_FLOAT, _T_STR, _T_ARR, _T_TUPLE, _T_LIST, _T_DICT = \
    range(1, 9)

# struct field tags (explicit, one per field, checked in order)
_F_QUERY, _F_PARAMS, _F_CFG, _F_STEPS, _F_RESULT, _F_DIGEST = \
    0x01, 0x02, 0x03, 0x04, 0x05, 0x06
_F_KIND, _F_SHAPE, _F_DESC, _F_INSTANCE, _F_PROOF = \
    0x10, 0x11, 0x12, 0x13, 0x14
_F_ROOTS, _F_OPENINGS, _F_TREES, _F_FRI, _F_TIMINGS = \
    0x20, 0x21, 0x22, 0x23, 0x24
_F_FRI_ROOTS, _F_FRI_FINAL, _F_FRI_QIDX, _F_FRI_OPENS = \
    0x30, 0x31, 0x32, 0x33
_F_M_VERSION, _F_M_NNODES, _F_M_EDGES, _F_M_TABLES, _F_M_ROOTS = \
    0x40, 0x41, 0x42, 0x43, 0x44
_F_C_ORIGIN, _F_C_SIZE, _F_C_ROOT = 0x50, 0x51, 0x52
_F_I_INDEX, _F_I_SIZE, _F_I_PATH = 0x60, 0x61, 0x62
_F_Y_OLD, _F_Y_NEW, _F_Y_PATH = 0x70, 0x71, 0x72
_F_G_CHECKPOINT, _F_G_CONSIST = 0x80, 0x81
# 0x82 was the v2 MAC authenticator; retired with kind 8, never reused
_F_G_SIGNER, _F_G_SIG = 0x83, 0x84

# Ed25519 material carried by the signed gossip envelope (raw, fixed-width)
SIGNER_LEN = 32      # compressed Edwards verify key (repro.core.ed25519)
SIG_LEN = 64         # detached signature R || S

_DTYPES = {0: np.dtype("<u4"), 1: np.dtype("<i8")}
_DTYPE_CODE = {np.dtype(np.uint32): 0, np.dtype(np.int64): 1}

_I64_MIN, _I64_MAX = -(1 << 63), (1 << 63) - 1


class WireFormatError(ValueError):
    """Malformed wire bytes: truncated, mistagged, oversized, mistyped, or
    schema-violating input.  Decoding raises this instead of executing or
    trusting anything; ``ZKGraphSession.verify_bytes`` maps it to ``False``."""


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
class _Enc:
    def __init__(self):
        self.buf = bytearray()

    def u8(self, v: int):
        self.buf += struct.pack("<B", v)

    def u16(self, v: int):
        self.buf += struct.pack("<H", v)

    def u32(self, v: int):
        if not 0 <= int(v) < (1 << 32):
            raise WireFormatError(f"u32 out of range: {v}")
        self.buf += struct.pack("<I", int(v))

    def i64(self, v: int):
        v = int(v)
        if not _I64_MIN <= v <= _I64_MAX:
            raise WireFormatError(f"integer does not fit in i64: {v}")
        self.buf += struct.pack("<q", v)

    def f64(self, v: float):
        self.buf += struct.pack("<d", float(v))

    def string(self, s: str):
        if not isinstance(s, str):
            raise WireFormatError(f"expected str, got {type(s).__name__}")
        raw = s.encode("utf-8")
        if len(raw) > MAX_STR:
            raise WireFormatError(f"string too long: {len(raw)} > {MAX_STR}")
        self.u32(len(raw))
        self.buf += raw

    def array(self, a, dtype=None, ndim=None):
        a = np.ascontiguousarray(a)
        if dtype is not None:
            a = np.ascontiguousarray(a, np.dtype(dtype))
        code = _DTYPE_CODE.get(a.dtype.newbyteorder("<"))
        if code is None:
            code = _DTYPE_CODE.get(a.dtype)
        if code is None:
            raise WireFormatError(f"unsupported array dtype {a.dtype}")
        if ndim is not None and a.ndim != ndim:
            raise WireFormatError(f"expected {ndim}-d array, got {a.ndim}-d")
        if a.ndim > MAX_ARR_DIMS or a.size > MAX_ARR_ELEMS:
            raise WireFormatError(f"array too large: shape {a.shape}")
        self.u8(code)
        self.u8(a.ndim)
        for d in a.shape:
            self.u32(d)
        self.buf += a.astype(_DTYPES[code], copy=False).tobytes()

    def value(self, v, depth: int = 0):
        if depth > MAX_DEPTH:
            raise WireFormatError(f"value nesting deeper than {MAX_DEPTH}")
        if isinstance(v, bool) or isinstance(v, np.bool_):
            self.u8(_T_BOOL)
            self.u8(1 if v else 0)
        elif isinstance(v, (int, np.integer)):
            self.u8(_T_INT)
            self.i64(v)
        elif isinstance(v, (float, np.floating)):
            self.u8(_T_FLOAT)
            self.f64(v)
        elif isinstance(v, str):
            self.u8(_T_STR)
            self.string(v)
        elif isinstance(v, np.ndarray):
            self.u8(_T_ARR)
            self.array(v)
        elif isinstance(v, tuple):
            self.u8(_T_TUPLE)
            self._seq(v, depth)
        elif isinstance(v, list):
            self.u8(_T_LIST)
            self._seq(v, depth)
        elif isinstance(v, dict):
            self.u8(_T_DICT)
            self._dict(v, depth)
        else:
            raise WireFormatError(
                f"value of type {type(v).__name__} is not wire-encodable")

    def _seq(self, items, depth: int):
        if len(items) > MAX_ITEMS:
            raise WireFormatError(f"container too large: {len(items)}")
        self.u32(len(items))
        for it in items:
            self.value(it, depth + 1)

    def _dict(self, d: dict, depth: int):
        if len(d) > MAX_ITEMS:
            raise WireFormatError(f"dict too large: {len(d)}")
        encoded = []
        for k, v in d.items():
            ek = _Enc()
            ek.value(k, depth + 1)
            encoded.append((bytes(ek.buf), v))
        encoded.sort(key=lambda kv: kv[0])
        for i in range(1, len(encoded)):
            if encoded[i][0] == encoded[i - 1][0]:
                raise WireFormatError("duplicate dict key")
        self.u32(len(encoded))
        for kb, v in encoded:
            self.buf += kb
            self.value(v, depth + 1)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------
class _Dec:
    def __init__(self, raw: bytes):
        if not isinstance(raw, (bytes, bytearray, memoryview)):
            raise WireFormatError(
                f"expected bytes, got {type(raw).__name__}")
        self.raw = bytes(raw)
        self.pos = 0

    def take(self, n: int) -> bytes:
        if n < 0 or self.pos + n > len(self.raw):
            raise WireFormatError(
                f"truncated input: need {n} bytes at offset {self.pos}, "
                f"have {len(self.raw) - self.pos}")
        out = self.raw[self.pos: self.pos + n]
        self.pos += n
        return out

    def done(self):
        if self.pos != len(self.raw):
            raise WireFormatError(
                f"{len(self.raw) - self.pos} trailing bytes after message")

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self.take(4))[0]

    def i64(self) -> int:
        return struct.unpack("<q", self.take(8))[0]

    def f64(self) -> float:
        return struct.unpack("<d", self.take(8))[0]

    def tag(self, expected: int, what: str):
        got = self.u8()
        if got != expected:
            raise WireFormatError(
                f"bad field tag for {what}: expected {expected:#x}, "
                f"got {got:#x}")

    def string(self) -> str:
        n = self.u32()
        if n > MAX_STR:
            raise WireFormatError(f"string length {n} > {MAX_STR}")
        try:
            return self.take(n).decode("utf-8")
        except UnicodeDecodeError as e:
            raise WireFormatError(f"invalid utf-8 string: {e}") from None

    def array(self, dtype=None, ndim=None, shape=None) -> np.ndarray:
        code = self.u8()
        dt = _DTYPES.get(code)
        if dt is None:
            raise WireFormatError(f"unknown array dtype code {code}")
        if dtype is not None and dt != np.dtype(dtype):
            raise WireFormatError(
                f"expected {np.dtype(dtype)} array, got {dt}")
        nd = self.u8()
        if nd > MAX_ARR_DIMS:
            raise WireFormatError(f"array rank {nd} > {MAX_ARR_DIMS}")
        if ndim is not None and nd != ndim:
            raise WireFormatError(f"expected {ndim}-d array, got {nd}-d")
        dims = []
        elems = 1
        for _ in range(nd):
            d = self.u32()
            dims.append(d)
            elems *= max(d, 1)
            if elems > MAX_ARR_ELEMS:
                raise WireFormatError(f"array too large: dims {dims}")
        if shape is not None and tuple(dims) != tuple(shape):
            raise WireFormatError(
                f"expected array shape {tuple(shape)}, got {tuple(dims)}")
        nbytes = int(np.prod(dims, dtype=np.int64)) * dt.itemsize
        raw = self.take(nbytes)
        # .copy(): callers mutate instances/results; frombuffer is read-only
        return np.frombuffer(raw, dtype=dt).reshape(dims).copy()

    def value(self, depth: int = 0):
        if depth > MAX_DEPTH:
            raise WireFormatError(f"value nesting deeper than {MAX_DEPTH}")
        t = self.u8()
        if t == _T_BOOL:
            b = self.u8()
            if b not in (0, 1):
                raise WireFormatError(f"non-canonical bool byte {b}")
            return bool(b)
        if t == _T_INT:
            return self.i64()
        if t == _T_FLOAT:
            return self.f64()
        if t == _T_STR:
            return self.string()
        if t == _T_ARR:
            return self.array()
        if t in (_T_TUPLE, _T_LIST):
            n = self.u32()
            if n > MAX_ITEMS:
                raise WireFormatError(f"container length {n} > {MAX_ITEMS}")
            items = [self.value(depth + 1) for _ in range(n)]
            return tuple(items) if t == _T_TUPLE else items
        if t == _T_DICT:
            n = self.u32()
            if n > MAX_ITEMS:
                raise WireFormatError(f"dict length {n} > {MAX_ITEMS}")
            out = {}
            prev = None
            for _ in range(n):
                start = self.pos
                k = self.value(depth + 1)
                kb = self.raw[start: self.pos]
                if prev is not None and kb <= prev:
                    raise WireFormatError(
                        "non-canonical dict: keys not strictly sorted")
                prev = kb
                try:
                    out[k] = None
                except TypeError:
                    raise WireFormatError(
                        f"unhashable dict key {k!r}") from None
                out[k] = self.value(depth + 1)
            return out
        raise WireFormatError(f"unknown value tag {t:#x}")


# ---------------------------------------------------------------------------
# schema validation for step shapes
# ---------------------------------------------------------------------------
def check_shape_schema(kind: str, shape) -> dict:
    """Validate a step's declared circuit geometry against the registered
    adapter's ``shape_schema``: exact key set, exact value types (``bool`` is
    *not* accepted where ``int`` is declared, and vice versa)."""
    from .operators import registry
    if not isinstance(shape, dict):
        raise WireFormatError(
            f"step shape must be a dict, got {type(shape).__name__}")
    try:
        schema = registry.adapter_named(kind).shape_schema
    except KeyError:
        raise WireFormatError(f"unknown step kind {kind!r}") from None
    if set(shape) != set(schema):
        raise WireFormatError(
            f"step {kind!r} shape keys {sorted(shape)} do not match "
            f"schema {sorted(schema)}")
    for key, typ in schema.items():
        if type(shape[key]) is not typ:
            raise WireFormatError(
                f"step {kind!r} shape field {key!r} must be "
                f"{typ.__name__}, got {type(shape[key]).__name__}")
    return shape


# ---------------------------------------------------------------------------
# FriProof
# ---------------------------------------------------------------------------
def _fri_to_wire(e: _Enc, fp):
    if len(fp.layer_roots) > MAX_FRI_LAYERS:
        raise WireFormatError(f"too many FRI layers: {len(fp.layer_roots)}")
    if len(fp.layer_openings) != len(fp.layer_roots):
        raise WireFormatError("FRI layer roots/openings count mismatch")
    e.u8(_F_FRI_ROOTS)
    e.u32(len(fp.layer_roots))
    for r in fp.layer_roots:
        e.array(r, dtype=np.uint32, ndim=1)
    e.u8(_F_FRI_FINAL)
    e.array(fp.final_codeword, dtype=np.uint32, ndim=2)
    e.u8(_F_FRI_QIDX)
    e.array(fp.query_indices, dtype=np.int64, ndim=1)
    e.u8(_F_FRI_OPENS)
    e.u32(len(fp.layer_openings))
    for rows, paths in fp.layer_openings:
        e.array(rows, dtype=np.uint32, ndim=2)
        e.array(paths, dtype=np.uint32, ndim=3)


def _fri_from_wire(d: _Dec):
    from .fri import FriProof
    d.tag(_F_FRI_ROOTS, "fri.layer_roots")
    n_layers = d.u32()
    if n_layers > MAX_FRI_LAYERS:
        raise WireFormatError(f"FRI layer count {n_layers} > {MAX_FRI_LAYERS}")
    roots = [d.array(dtype=np.uint32, ndim=1, shape=(8,))
             for _ in range(n_layers)]
    d.tag(_F_FRI_FINAL, "fri.final_codeword")
    final = d.array(dtype=np.uint32, ndim=2)
    if final.shape[1] != 4:
        raise WireFormatError(
            f"final codeword must be (n, 4), got {final.shape}")
    d.tag(_F_FRI_QIDX, "fri.query_indices")
    qidx = d.array(dtype=np.int64, ndim=1)
    d.tag(_F_FRI_OPENS, "fri.layer_openings")
    n_open = d.u32()
    if n_open != n_layers:
        raise WireFormatError(
            f"FRI openings count {n_open} != layer count {n_layers}")
    openings = []
    for _ in range(n_open):
        rows = d.array(dtype=np.uint32, ndim=2)
        paths = d.array(dtype=np.uint32, ndim=3)
        if paths.shape[0] != rows.shape[0]:
            raise WireFormatError("FRI opening rows/paths leaf-count mismatch")
        openings.append((rows, paths))
    return FriProof(roots, final, qidx, openings)


# ---------------------------------------------------------------------------
# Proof
# ---------------------------------------------------------------------------
def _proof_to_wire(e: _Enc, p):
    e.u8(_F_ROOTS)
    for root in (p.data_root, p.advice_root, p.ext_root, p.quotient_root):
        e.array(root, dtype=np.uint32, ndim=1)
    e.u8(_F_OPENINGS)
    keys = sorted(p.openings)
    if len(keys) > MAX_ITEMS:
        raise WireFormatError(f"too many openings: {len(keys)}")
    e.u32(len(keys))
    for (kind, idx, rot) in keys:
        e.string(kind)
        e.u32(idx)
        e.u32(rot)
        e.array(p.openings[(kind, idx, rot)], dtype=np.uint32, ndim=1)
    e.u8(_F_TREES)
    names = sorted(p.tree_openings)
    e.u32(len(names))
    for name in names:
        rows, paths = p.tree_openings[name]
        e.string(name)
        e.array(rows, dtype=np.uint32, ndim=2)
        e.array(paths, dtype=np.uint32, ndim=3)
    e.u8(_F_FRI)
    _fri_to_wire(e, p.fri_proof)
    e.u8(_F_TIMINGS)
    e.value({str(k): float(v) for k, v in p.timings.items()})


def _proof_from_wire(d: _Dec):
    from .prover import Proof
    d.tag(_F_ROOTS, "proof.roots")
    roots = [d.array(dtype=np.uint32, ndim=1, shape=(8,)) for _ in range(4)]
    d.tag(_F_OPENINGS, "proof.openings")
    n = d.u32()
    if n > MAX_ITEMS:
        raise WireFormatError(f"openings count {n} > {MAX_ITEMS}")
    openings = {}
    prev = None
    for _ in range(n):
        kind = d.string()
        idx = d.u32()
        rot = d.u32()
        key = (kind, idx, rot)
        if prev is not None and key <= prev:
            raise WireFormatError("non-canonical openings order")
        prev = key
        openings[key] = d.array(dtype=np.uint32, ndim=1, shape=(4,))
    d.tag(_F_TREES, "proof.tree_openings")
    n = d.u32()
    if n > MAX_ITEMS:
        raise WireFormatError(f"tree openings count {n} > {MAX_ITEMS}")
    trees = {}
    prev = None
    for _ in range(n):
        name = d.string()
        if prev is not None and name <= prev:
            raise WireFormatError("non-canonical tree-openings order")
        prev = name
        rows = d.array(dtype=np.uint32, ndim=2)
        paths = d.array(dtype=np.uint32, ndim=3)
        if paths.shape[0] != rows.shape[0]:
            raise WireFormatError("tree opening rows/paths count mismatch")
        trees[name] = (rows, paths)
    d.tag(_F_FRI, "proof.fri_proof")
    fri_proof = _fri_from_wire(d)
    d.tag(_F_TIMINGS, "proof.timings")
    timings = d.value()
    if not isinstance(timings, dict) or not all(
            isinstance(k, str) and isinstance(v, float)
            for k, v in timings.items()):
        raise WireFormatError("proof timings must be a {str: float} dict")
    return Proof(roots[0], roots[1], roots[2], roots[3], openings, fri_proof,
                 trees, timings)


# ---------------------------------------------------------------------------
# StepProof / ProofBundle
# ---------------------------------------------------------------------------
def _step_to_wire(e: _Enc, step):
    check_shape_schema(step.kind, step.shape)
    e.u8(_F_KIND)
    e.string(step.kind)
    e.u8(_F_SHAPE)
    e.value(step.shape)
    e.u8(_F_DESC)
    e.string(step.data_desc)
    e.u8(_F_INSTANCE)
    e.array(step.instance, dtype=np.uint32, ndim=2)
    e.u8(_F_PROOF)
    _proof_to_wire(e, step.proof)


def _step_from_wire(d: _Dec):
    from .session import StepProof
    d.tag(_F_KIND, "step.kind")
    kind = d.string()
    d.tag(_F_SHAPE, "step.shape")
    shape = check_shape_schema(kind, d.value())
    d.tag(_F_DESC, "step.data_desc")
    desc = d.string()
    d.tag(_F_INSTANCE, "step.instance")
    instance = d.array(dtype=np.uint32, ndim=2)
    d.tag(_F_PROOF, "step.proof")
    proof = _proof_from_wire(d)
    return StepProof(kind, shape, desc, instance, proof)


def _header(e: _Enc, kind: int):
    e.buf += MAGIC
    e.u16(WIRE_VERSION)
    e.u8(kind)


def _check_header(d: _Dec, kind: int):
    magic = d.take(4)
    if magic != MAGIC:
        raise WireFormatError(
            f"bad magic {magic!r}: not a canonical proof message "
            f"(legacy pickle bundles are not accepted)")
    version = d.u16()
    if version != WIRE_VERSION:
        raise WireFormatError(
            f"unsupported wire version {version} (this verifier speaks "
            f"{WIRE_VERSION})")
    got = d.u8()
    if got == _KIND_GOSSIP_MAC_RETIRED:
        raise WireFormatError(
            "payload kind 8 is the retired MAC-era gossip envelope; "
            "checkpoints are Ed25519-signed since wire v3 (kind 9)")
    if got != kind:
        raise WireFormatError(f"payload kind {got} != expected {kind}")


def encode_bundle(bundle) -> bytes:
    """Canonical bytes for a :class:`repro.core.session.ProofBundle`."""
    e = _Enc()
    _header(e, KIND_BUNDLE)
    e.u8(_F_QUERY)
    e.string(bundle.query)
    e.u8(_F_PARAMS)
    e.value(dict(bundle.params))
    e.u8(_F_CFG)
    for v in (bundle.cfg.blowup, bundle.cfg.n_queries,
              bundle.cfg.fri_final_size, bundle.cfg.shift):
        e.u32(v)
    e.u8(_F_DIGEST)
    digest = bundle.manifest_digest
    if digest is None:
        raise WireFormatError(
            "bundle has no manifest_digest: prove against a published "
            "CommitmentManifest (ZKGraphSession.prove sets it)")
    digest = np.asarray(digest)
    if digest.shape != (8,):
        raise WireFormatError(
            f"manifest digest must have shape (8,), got {digest.shape}")
    e.array(digest, dtype=np.uint32, ndim=1)
    if len(bundle.steps) > MAX_STEPS:
        raise WireFormatError(f"too many steps: {len(bundle.steps)}")
    e.u8(_F_STEPS)
    e.u32(len(bundle.steps))
    for step in bundle.steps:
        _step_to_wire(e, step)
    e.u8(_F_RESULT)
    e.value(dict(bundle.result))
    return bytes(e.buf)


def decode_bundle(raw: bytes):
    """Decode + validate canonical bundle bytes; raises
    :class:`WireFormatError` on any malformed input."""
    from .prover import ProverConfig
    from .session import ProofBundle
    d = _Dec(raw)
    _check_header(d, KIND_BUNDLE)
    d.tag(_F_QUERY, "bundle.query")
    query = d.string()
    d.tag(_F_PARAMS, "bundle.params")
    params = d.value()
    if not isinstance(params, dict) or not all(
            isinstance(k, str) for k in params):
        raise WireFormatError("bundle params must be a str-keyed dict")
    d.tag(_F_CFG, "bundle.cfg")
    cfg = ProverConfig(blowup=d.u32(), n_queries=d.u32(),
                       fri_final_size=d.u32(), shift=d.u32())
    d.tag(_F_DIGEST, "bundle.manifest_digest")
    digest = d.array(dtype=np.uint32, ndim=1, shape=(8,))
    d.tag(_F_STEPS, "bundle.steps")
    n_steps = d.u32()
    if n_steps > MAX_STEPS:
        raise WireFormatError(f"step count {n_steps} > {MAX_STEPS}")
    steps = [_step_from_wire(d) for _ in range(n_steps)]
    d.tag(_F_RESULT, "bundle.result")
    result = d.value()
    if not isinstance(result, dict) or not all(
            isinstance(k, str) for k in result):
        raise WireFormatError("bundle result must be a str-keyed dict")
    d.done()
    return ProofBundle(query, params, steps, result, cfg, digest)


def encode_proof(proof) -> bytes:
    """Standalone canonical bytes for one step's :class:`Proof`."""
    e = _Enc()
    _header(e, KIND_PROOF)
    _proof_to_wire(e, proof)
    return bytes(e.buf)


def decode_proof(raw: bytes):
    d = _Dec(raw)
    _check_header(d, KIND_PROOF)
    p = _proof_from_wire(d)
    d.done()
    return p


def encode_fri_proof(fp) -> bytes:
    """Standalone canonical bytes for a :class:`FriProof`."""
    e = _Enc()
    _header(e, KIND_FRI)
    _fri_to_wire(e, fp)
    return bytes(e.buf)


def decode_fri_proof(raw: bytes):
    d = _Dec(raw)
    _check_header(d, KIND_FRI)
    fp = _fri_from_wire(d)
    d.done()
    return fp


# ---------------------------------------------------------------------------
# CommitmentManifest: the owner's published trust root, canonically encoded
# ---------------------------------------------------------------------------
def _nonneg(v: int, what: str) -> int:
    v = int(v)
    if v < 0:
        raise WireFormatError(f"{what} must be non-negative, got {v}")
    return v


def _root8(root, what: str) -> np.ndarray:
    root = np.asarray(root)
    if root.shape != (8,):
        raise WireFormatError(
            f"{what} must be an (8,) digest, got shape {root.shape}")
    return root


def encode_manifest(manifest) -> bytes:
    """Canonical bytes for a :class:`repro.core.commit.CommitmentManifest`.

    Deterministic (``encode(decode(b)) == b``): edge counts sort by table
    name, geometries by descriptor, roots by ``(descriptor, size)``; the
    decoder rejects out-of-order entries.  Every root entry must name a
    descriptor with published geometry and a size that geometry lists — the
    encoder enforces the same invariants, so the encodable set and the
    decodable set are the same language.  ``transparency.manifest_digest``
    over these bytes is the digest bundles and log leaves bind to.
    """
    from .commit import MANIFEST_VERSION
    e = _Enc()
    _header(e, KIND_MANIFEST)
    e.u8(_F_M_VERSION)
    if manifest.version != MANIFEST_VERSION:
        raise WireFormatError(
            f"manifest version {manifest.version} != {MANIFEST_VERSION}")
    e.u32(manifest.version)
    e.u8(_F_M_NNODES)
    e.i64(_nonneg(manifest.n_nodes, "manifest n_nodes"))
    e.u8(_F_M_EDGES)
    if len(manifest.edge_counts) > MAX_TABLES:
        raise WireFormatError(
            f"too many edge tables: {len(manifest.edge_counts)}")
    e.u32(len(manifest.edge_counts))
    for name in sorted(manifest.edge_counts):
        e.string(name)
        e.i64(_nonneg(manifest.edge_counts[name], f"edge count {name!r}"))
    e.u8(_F_M_TABLES)
    if len(manifest.tables) > MAX_TABLES:
        raise WireFormatError(f"too many tables: {len(manifest.tables)}")
    e.u32(len(manifest.tables))
    for desc in sorted(manifest.tables):
        geo = manifest.tables[desc]
        if geo.desc != desc:
            raise WireFormatError(
                f"geometry desc {geo.desc!r} != manifest key {desc!r}")
        e.string(desc)
        e.u32(_nonneg(geo.n_cols, f"{desc!r} n_cols"))
        e.u32(_nonneg(geo.n_table_rows, f"{desc!r} n_table_rows"))
        if len(geo.sizes) > MAX_SIZES:
            raise WireFormatError(
                f"table {desc!r} has too many sizes: {len(geo.sizes)}")
        e.u32(len(geo.sizes))
        prev = -1
        for n in geo.sizes:
            if int(n) <= prev:
                raise WireFormatError(
                    f"table {desc!r} sizes must be strictly increasing")
            prev = int(n)
            e.u32(n)
        if len(geo.columns) > MAX_COLUMNS:
            raise WireFormatError(
                f"table {desc!r} has too many columns: {len(geo.columns)}")
        e.u32(len(geo.columns))
        for col in geo.columns:
            e.string(col)
    e.u8(_F_M_ROOTS)
    if len(manifest.roots) > MAX_TABLES * MAX_SIZES:
        raise WireFormatError(f"too many roots: {len(manifest.roots)}")
    e.u32(len(manifest.roots))
    for desc, size in sorted(manifest.roots):
        geo = manifest.tables.get(desc)
        if geo is None or int(size) not in {int(s) for s in geo.sizes}:
            raise WireFormatError(
                f"root for {(desc, size)} has no matching published geometry")
        e.string(desc)
        e.u32(size)
        e.array(_root8(manifest.roots[(desc, size)], f"root {(desc, size)}"),
                dtype=np.uint32, ndim=1)
    return bytes(e.buf)


def decode_manifest(raw: bytes):
    """Decode + validate canonical manifest bytes (fails closed on any
    malformed, non-canonical, or version-skewed input)."""
    from .commit import MANIFEST_VERSION, CommitmentManifest, TableGeometry
    d = _Dec(raw)
    _check_header(d, KIND_MANIFEST)
    d.tag(_F_M_VERSION, "manifest.version")
    mver = d.u32()
    if mver != MANIFEST_VERSION:
        raise WireFormatError(
            f"unsupported manifest version {mver} (this verifier speaks "
            f"{MANIFEST_VERSION})")
    d.tag(_F_M_NNODES, "manifest.n_nodes")
    n_nodes = d.i64()
    if n_nodes < 0:
        raise WireFormatError(f"negative n_nodes {n_nodes}")
    d.tag(_F_M_EDGES, "manifest.edge_counts")
    n = d.u32()
    if n > MAX_TABLES:
        raise WireFormatError(f"edge table count {n} > {MAX_TABLES}")
    edge_counts = {}
    prev = None
    for _ in range(n):
        name = d.string()
        if prev is not None and name <= prev:
            raise WireFormatError("non-canonical edge-count order")
        prev = name
        count = d.i64()
        if count < 0:
            raise WireFormatError(f"negative edge count for {name!r}")
        edge_counts[name] = count
    d.tag(_F_M_TABLES, "manifest.tables")
    n = d.u32()
    if n > MAX_TABLES:
        raise WireFormatError(f"table count {n} > {MAX_TABLES}")
    tables = {}
    prev = None
    for _ in range(n):
        desc = d.string()
        if prev is not None and desc <= prev:
            raise WireFormatError("non-canonical table-geometry order")
        prev = desc
        n_cols = d.u32()
        n_table_rows = d.u32()
        n_sizes = d.u32()
        if n_sizes > MAX_SIZES:
            raise WireFormatError(f"size count {n_sizes} > {MAX_SIZES}")
        sizes = []
        last = -1
        for _ in range(n_sizes):
            s = d.u32()
            if s <= last:
                raise WireFormatError(
                    f"table {desc!r} sizes not strictly increasing")
            last = s
            sizes.append(s)
        n_columns = d.u32()
        if n_columns > MAX_COLUMNS:
            raise WireFormatError(f"column count {n_columns} > {MAX_COLUMNS}")
        columns = tuple(d.string() for _ in range(n_columns))
        tables[desc] = TableGeometry(desc, n_cols, n_table_rows,
                                     tuple(sizes), columns)
    d.tag(_F_M_ROOTS, "manifest.roots")
    n = d.u32()
    if n > MAX_TABLES * MAX_SIZES:
        raise WireFormatError(f"root count {n} > {MAX_TABLES * MAX_SIZES}")
    roots = {}
    prev = None
    for _ in range(n):
        desc = d.string()
        size = d.u32()
        if prev is not None and (desc, size) <= prev:
            raise WireFormatError("non-canonical root order")
        prev = (desc, size)
        geo = tables.get(desc)
        if geo is None or size not in geo.sizes:
            raise WireFormatError(
                f"root for {(desc, size)} has no matching published geometry")
        roots[(desc, size)] = d.array(dtype=np.uint32, ndim=1, shape=(8,))
    d.done()
    return CommitmentManifest(mver, n_nodes, edge_counts, tables, roots)


# ---------------------------------------------------------------------------
# transparency-log structures (Checkpoint / InclusionProof / ConsistencyProof)
# ---------------------------------------------------------------------------
def _log_path(d: _Dec, what: str) -> np.ndarray:
    path = d.array(dtype=np.uint32, ndim=2)
    if path.shape[0] > MAX_LOG_DEPTH or path.shape[1] != 8:
        raise WireFormatError(
            f"{what} path must be (d<={MAX_LOG_DEPTH}, 8), got {path.shape}")
    return path


def encode_checkpoint(cp) -> bytes:
    """Canonical bytes for a :class:`repro.core.transparency.Checkpoint`."""
    e = _Enc()
    _header(e, KIND_CHECKPOINT)
    e.u8(_F_C_ORIGIN)
    e.string(cp.origin)
    e.u8(_F_C_SIZE)
    e.i64(_nonneg(cp.tree_size, "checkpoint tree_size"))
    e.u8(_F_C_ROOT)
    e.array(_root8(cp.root, "checkpoint root"), dtype=np.uint32, ndim=1)
    return bytes(e.buf)


def decode_checkpoint(raw: bytes):
    from .transparency import Checkpoint
    d = _Dec(raw)
    _check_header(d, KIND_CHECKPOINT)
    d.tag(_F_C_ORIGIN, "checkpoint.origin")
    origin = d.string()
    d.tag(_F_C_SIZE, "checkpoint.tree_size")
    tree_size = d.i64()
    if tree_size < 0:
        raise WireFormatError(f"negative tree size {tree_size}")
    d.tag(_F_C_ROOT, "checkpoint.root")
    root = d.array(dtype=np.uint32, ndim=1, shape=(8,))
    d.done()
    return Checkpoint(origin, tree_size, root)


def encode_inclusion_proof(pf) -> bytes:
    e = _Enc()
    _header(e, KIND_INCLUSION)
    e.u8(_F_I_INDEX)
    e.i64(_nonneg(pf.leaf_index, "inclusion leaf_index"))
    e.u8(_F_I_SIZE)
    e.i64(_nonneg(pf.tree_size, "inclusion tree_size"))
    if pf.leaf_index >= pf.tree_size:
        raise WireFormatError(
            f"leaf index {pf.leaf_index} outside tree of {pf.tree_size}")
    e.u8(_F_I_PATH)
    path = np.asarray(pf.path, np.uint32).reshape(-1, 8)
    if path.shape[0] > MAX_LOG_DEPTH:
        raise WireFormatError(f"inclusion path too deep: {path.shape[0]}")
    e.array(path, dtype=np.uint32, ndim=2)
    return bytes(e.buf)


def decode_inclusion_proof(raw: bytes):
    from .transparency import InclusionProof
    d = _Dec(raw)
    _check_header(d, KIND_INCLUSION)
    d.tag(_F_I_INDEX, "inclusion.leaf_index")
    leaf_index = d.i64()
    d.tag(_F_I_SIZE, "inclusion.tree_size")
    tree_size = d.i64()
    if not 0 <= leaf_index < tree_size:
        raise WireFormatError(
            f"leaf index {leaf_index} outside tree of {tree_size}")
    d.tag(_F_I_PATH, "inclusion.path")
    path = _log_path(d, "inclusion")
    d.done()
    return InclusionProof(leaf_index, tree_size, path)


def encode_consistency_proof(pf) -> bytes:
    e = _Enc()
    _header(e, KIND_CONSISTENCY)
    e.u8(_F_Y_OLD)
    e.i64(_nonneg(pf.old_size, "consistency old_size"))
    e.u8(_F_Y_NEW)
    e.i64(_nonneg(pf.new_size, "consistency new_size"))
    if not 1 <= pf.old_size <= pf.new_size:
        raise WireFormatError(
            f"consistency sizes must satisfy 1 <= old <= new, got "
            f"{pf.old_size}, {pf.new_size}")
    e.u8(_F_Y_PATH)
    path = np.asarray(pf.path, np.uint32).reshape(-1, 8)
    if path.shape[0] > MAX_LOG_DEPTH:
        raise WireFormatError(f"consistency path too deep: {path.shape[0]}")
    e.array(path, dtype=np.uint32, ndim=2)
    return bytes(e.buf)


def decode_consistency_proof(raw: bytes):
    from .transparency import ConsistencyProof
    d = _Dec(raw)
    _check_header(d, KIND_CONSISTENCY)
    d.tag(_F_Y_OLD, "consistency.old_size")
    old_size = d.i64()
    d.tag(_F_Y_NEW, "consistency.new_size")
    new_size = d.i64()
    if not 1 <= old_size <= new_size:
        raise WireFormatError(
            f"consistency sizes must satisfy 1 <= old <= new, got "
            f"{old_size}, {new_size}")
    d.tag(_F_Y_PATH, "consistency.path")
    path = _log_path(d, "consistency")
    d.done()
    return ConsistencyProof(old_size, new_size, path)


# ---------------------------------------------------------------------------
# gossip envelope (kind 9): Ed25519-signed checkpoint + optional consistency
# ---------------------------------------------------------------------------
def _embed(e: _Enc, raw: bytes, what: str):
    """A complete inner wire message, length-prefixed.  Nesting whole
    messages (their own header included) keeps one canonical encoding per
    payload and reuses each inner codec's validation wholesale."""
    if len(raw) > MAX_EMBED:
        raise WireFormatError(
            f"embedded {what} message too large: {len(raw)} > {MAX_EMBED}")
    e.u32(len(raw))
    e.buf += raw


def _unembed(d: _Dec, what: str) -> bytes:
    n = d.u32()
    if n > MAX_EMBED:
        raise WireFormatError(
            f"embedded {what} length {n} > {MAX_EMBED}")
    return d.take(n)


def encode_gossip_message(msg) -> bytes:
    """Canonical bytes for a :class:`repro.core.gossip.GossipMessage`."""
    e = _Enc()
    _header(e, KIND_GOSSIP)
    e.u8(_F_G_CHECKPOINT)
    _embed(e, encode_checkpoint(msg.checkpoint), "checkpoint")
    e.u8(_F_G_CONSIST)
    if msg.consistency is None:
        e.u8(0)
    else:
        e.u8(1)
        _embed(e, encode_consistency_proof(msg.consistency), "consistency")
    e.u8(_F_G_SIGNER)
    signer = bytes(msg.signer)
    if len(signer) != SIGNER_LEN:
        raise WireFormatError(
            f"gossip signer must be {SIGNER_LEN} bytes, got {len(signer)}")
    e.buf += signer
    e.u8(_F_G_SIG)
    signature = bytes(msg.signature)
    if len(signature) != SIG_LEN:
        raise WireFormatError(
            f"gossip signature must be {SIG_LEN} bytes, got {len(signature)}")
    e.buf += signature
    return bytes(e.buf)


def decode_gossip_message(raw: bytes):
    """Decode + validate canonical gossip bytes; the embedded checkpoint
    and consistency proof pass through their own full decoders, so every
    inner invariant (kinds, bounds, size relations) holds before a
    :class:`~repro.core.gossip.GossipPeer` sees the message."""
    from .gossip import GossipMessage
    d = _Dec(raw)
    _check_header(d, KIND_GOSSIP)
    d.tag(_F_G_CHECKPOINT, "gossip.checkpoint")
    checkpoint = decode_checkpoint(_unembed(d, "checkpoint"))
    d.tag(_F_G_CONSIST, "gossip.consistency")
    flag = d.u8()
    if flag not in (0, 1):
        raise WireFormatError(f"non-canonical consistency flag {flag}")
    consistency = None
    if flag:
        consistency = decode_consistency_proof(_unembed(d, "consistency"))
    d.tag(_F_G_SIGNER, "gossip.signer")
    signer = d.take(SIGNER_LEN)
    d.tag(_F_G_SIG, "gossip.signature")
    signature = d.take(SIG_LEN)
    d.done()
    return GossipMessage(checkpoint, consistency, signer, signature)
