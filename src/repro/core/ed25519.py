"""Ed25519 (RFC 8032) detached signatures, pure Python.

PR 5's gossip layer authenticated checkpoints with a keyed sponge MAC — a
stand-in that forced every verifier to hold the *signing* secret, so a
verifier could forge heads and the "origin signature" modelled nothing a
relay couldn't mint.  Real transparency fabrics (certificate transparency,
the VeGraS-style verifiable-search logs this repo reproduces toward) sign
checkpoints with an asymmetric key: the owner publishes a *verify* key as
part of its identity, and no verifier ever holds the signing half.

This module is a from-the-RFC implementation over ``hashlib.sha512``:

* the container bakes no crypto dependency (no ``cryptography``, no
  ``pynacl``), and the repo's hard rule is to stub or gate missing deps —
  signing one ~60-byte checkpoint per gossip round is far below the
  performance floor where a C backend matters (see
  ``BENCH_transparency.json``'s ``ed25519_*_us`` rows);
* the arithmetic is the standard twisted-Edwards group over
  GF(2^255 - 19) in extended homogeneous coordinates, with the RFC's
  cofactored verification equation ``[8][S]B = [8]R + [8][k]A``
  relaxed to the (strictly stronger) unbatched ``[S]B = R + [k]A`` form
  used by every major deployment.

Strictness (what :func:`verify` rejects, beyond a wrong signature):

* a scalar ``S >= L`` — the RFC 8032 malleability check, so a third party
  cannot mint a second valid encoding of an honest signature;
* non-canonical or off-curve point encodings for either ``R`` or the
  public key — decoding fails closed;
* any input of the wrong length or type — ``False``, never an exception.

Like every primitive in this repo, this is a *reproduction instance*:
faithful to the RFC and pinned by its test vectors
(``tests/test_ed25519.py``), but not a constant-time or side-channel-
hardened implementation.
"""
from __future__ import annotations

import hashlib

__all__ = ["PUBLIC_KEY_LEN", "SEED_LEN", "SIGNATURE_LEN", "Ed25519Error",
           "SigningKey", "public_key", "sign", "verify"]

SEED_LEN = 32           # RFC 8032: private keys are 32-byte seeds
PUBLIC_KEY_LEN = 32     # compressed Edwards-y point
SIGNATURE_LEN = 64      # R (32 bytes) || S (32 bytes)

# field and group parameters (RFC 8032 §5.1)
_P = 2 ** 255 - 19
_L = 2 ** 252 + 27742317777372353535851937790883648493
_D = (-121665 * pow(121666, _P - 2, _P)) % _P           # -121665/121666
_I = pow(2, (_P - 1) // 4, _P)                          # sqrt(-1)

# the base point B, affine (RFC 8032 §5.1: y = 4/5, x recovered even)
_BY = (4 * pow(5, _P - 2, _P)) % _P


class Ed25519Error(ValueError):
    """Malformed key material handed to the signing side (wrong seed or
    key length).  The verifying side never raises — it returns ``False``."""


def _sha512(data: bytes) -> bytes:
    return hashlib.sha512(data).digest()


def _recover_x(y: int, sign_bit: int) -> int | None:
    """x from the curve equation -x^2 + y^2 = 1 + d x^2 y^2; ``None`` if
    ``y`` is not on the curve or the sign bit is unsatisfiable."""
    if y >= _P:
        return None
    x2 = (y * y - 1) * pow(_D * y * y + 1, _P - 2, _P) % _P
    x = pow(x2, (_P + 3) // 8, _P)
    if (x * x - x2) % _P != 0:
        x = x * _I % _P
    if (x * x - x2) % _P != 0:
        return None
    if x == 0 and sign_bit == 1:
        return None                 # -0 is not a canonical encoding
    if x & 1 != sign_bit:
        x = _P - x
    return x


_BX = _recover_x(_BY, 0)
assert _BX is not None
# extended homogeneous coordinates (X, Y, Z, T) with x=X/Z, y=Y/Z, T=XY/Z
_B = (_BX, _BY, 1, _BX * _BY % _P)
_IDENT = (0, 1, 1, 0)


def _pt_add(p: tuple, q: tuple) -> tuple:
    # add-2008-hwcd-3: complete addition on a=-1 twisted Edwards curves
    x1, y1, z1, t1 = p
    x2, y2, z2, t2 = q
    a = (y1 - x1) * (y2 - x2) % _P
    b = (y1 + x1) * (y2 + x2) % _P
    c = 2 * t1 * _D % _P * t2 % _P
    d = 2 * z1 * z2 % _P
    e, f, g, h = b - a, d - c, d + c, b + a
    return (e * f % _P, g * h % _P, f * g % _P, e * h % _P)


def _pt_mul(s: int, p: tuple) -> tuple:
    q = _IDENT
    while s > 0:
        if s & 1:
            q = _pt_add(q, p)
        p = _pt_add(p, p)
        s >>= 1
    return q


def _pt_equal(p: tuple, q: tuple) -> bool:
    # cross-multiply out the projective denominators
    x1, y1, z1, _ = p
    x2, y2, z2, _ = q
    return (x1 * z2 - x2 * z1) % _P == 0 and (y1 * z2 - y2 * z1) % _P == 0


def _pt_compress(p: tuple) -> bytes:
    x, y, z, _ = p
    zinv = pow(z, _P - 2, _P)
    x, y = x * zinv % _P, y * zinv % _P
    return int.to_bytes(y | ((x & 1) << 255), 32, "little")


def _pt_decompress(raw: bytes) -> tuple | None:
    if len(raw) != 32:
        return None
    enc = int.from_bytes(raw, "little")
    y = enc & ((1 << 255) - 1)
    x = _recover_x(y, enc >> 255)
    if x is None:
        return None
    return (x, y, 1, x * y % _P)


def _clamp(h: bytes) -> int:
    a = int.from_bytes(h[:32], "little")
    return (a & ((1 << 254) - 8)) | (1 << 254)


def public_key(seed: bytes) -> bytes:
    """The 32-byte verify key for a 32-byte seed (RFC 8032 §5.1.5)."""
    if not isinstance(seed, (bytes, bytearray)) or len(seed) != SEED_LEN:
        raise Ed25519Error(
            f"Ed25519 seed must be {SEED_LEN} bytes, got "
            f"{len(seed) if isinstance(seed, (bytes, bytearray)) else type(seed).__name__}")
    return _pt_compress(_pt_mul(_clamp(_sha512(bytes(seed))), _B))


def sign(seed: bytes, message: bytes) -> bytes:
    """RFC 8032 §5.1.6 detached signature (64 bytes) over ``message``.

    Deterministic — no ambient randomness enters the proof-adjacent path
    (the nonce is the RFC's hash of the seed prefix and the message)."""
    if not isinstance(seed, (bytes, bytearray)) or len(seed) != SEED_LEN:
        raise Ed25519Error(f"Ed25519 seed must be {SEED_LEN} bytes")
    message = bytes(message)
    h = _sha512(bytes(seed))
    a = _clamp(h)
    pk = _pt_compress(_pt_mul(a, _B))
    r = int.from_bytes(_sha512(h[32:] + message), "little") % _L
    r_enc = _pt_compress(_pt_mul(r, _B))
    k = int.from_bytes(_sha512(r_enc + pk + message), "little") % _L
    s = (r + k * a) % _L
    return r_enc + int.to_bytes(s, 32, "little")


def verify(pub: bytes, message: bytes, signature: bytes) -> bool:
    """RFC 8032 §5.1.7 verification: ``False`` on *any* defect — wrong
    length, non-canonical ``S`` (malleability), off-curve points, or a
    signature that simply does not check.  Never raises."""
    try:
        if not isinstance(pub, (bytes, bytearray)) \
                or not isinstance(signature, (bytes, bytearray)):
            return False
        pub, signature = bytes(pub), bytes(signature)
        if len(pub) != PUBLIC_KEY_LEN or len(signature) != SIGNATURE_LEN:
            return False
        a_pt = _pt_decompress(pub)
        r_pt = _pt_decompress(signature[:32])
        if a_pt is None or r_pt is None:
            return False
        s = int.from_bytes(signature[32:], "little")
        if s >= _L:
            return False            # RFC 8032 malleability rejection
        k = int.from_bytes(
            _sha512(signature[:32] + pub + bytes(message)), "little") % _L
        return _pt_equal(_pt_mul(s, _B), _pt_add(r_pt, _pt_mul(k, a_pt)))
    except (TypeError, ValueError):
        return False


class SigningKey:
    """A seed plus its derived verify key, for call sites that sign more
    than once (the public-key derivation is the expensive half).

    ``SigningKey.from_secret(b"...")`` derives a seed from arbitrary secret
    bytes via SHA-512 — the deterministic path demos and tests use so key
    material never depends on ambient randomness."""

    __slots__ = ("seed", "pub")

    def __init__(self, seed: bytes):
        if not isinstance(seed, (bytes, bytearray)) \
                or len(seed) != SEED_LEN:
            raise Ed25519Error(f"Ed25519 seed must be {SEED_LEN} bytes")
        self.seed = bytes(seed)
        self.pub = public_key(self.seed)

    @classmethod
    def from_secret(cls, secret: bytes) -> "SigningKey":
        if not isinstance(secret, (bytes, bytearray)) or not secret:
            raise Ed25519Error("secret must be non-empty bytes")
        return cls(_sha512(bytes(secret))[:SEED_LEN])

    def sign(self, message: bytes) -> bytes:
        return sign(self.seed, message)
