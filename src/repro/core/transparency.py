"""Append-only Merkle transparency log for published commitment manifests.

PR 2 made the verifier pin every circuit shape against the owner's
:class:`~repro.core.commit.CommitmentManifest`, but the manifest itself was an
in-process Python object — a verifier had to take it on faith.  This module
closes that last gap the way transparency-centric systems do (cf. certificate
transparency, and the verifiable graph-search log of arXiv:2503.10171): the
owner publishes the *canonical bytes* of every manifest revision as a leaf of
an append-only Merkle log, and hands out

* a :class:`Checkpoint` — ``(origin, tree_size, root)``, the log's signed-head
  equivalent;
* an :class:`InclusionProof` — the RFC 6962-style audit path showing a
  specific manifest digest is a leaf of that checkpoint; and
* a :class:`ConsistencyProof` — the RFC 6962-style proof that a newer
  checkpoint extends an older one append-only, so a client comparing two
  checkpoints detects *equivocation* (an owner showing different manifest
  histories to different verifiers).

The tree hashing reuses the proof system's own primitives
(:func:`repro.core.merkle.compress_pair` for internal nodes,
:func:`repro.core.hashing.hash_bytes` for leaves with an RFC 6962 ``0x00``
leaf-domain prefix), so a log verifier needs no second hash implementation.
``manifest_digest(bytes)`` *is* the leaf hash — the same (8,)-lane digest a
:class:`~repro.core.session.ProofBundle` carries in its ``manifest_digest``
field, which is what lets ``ZKGraphSession.verifier`` bootstrap its whole
trust root from ``(checkpoint, inclusion proof, manifest bytes)`` and fail
closed on any mismatch.

Byte formats for all three structures live in :mod:`repro.core.wire`
(payload kinds 5-7) and are specified in ``docs/protocol.md`` §4-5 with
golden vectors under ``tests/vectors/``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import hashing as H
from . import merkle, wire

_LEAF_PREFIX = b"\x00"       # RFC 6962 leaf-domain separation


class TransparencyError(ValueError):
    """A transparency-log check failed closed: a manifest not included in the
    presented checkpoint, malformed bootstrap inputs, or mismatched sizes.
    Verifier bootstrap raises this instead of trusting anything."""


def manifest_digest(raw: bytes) -> np.ndarray:
    """The (8,) uint32 digest of a canonically-encoded manifest.

    Defined as the transparency-log *leaf hash* of the bytes —
    ``hash_bytes(0x00 || raw)`` — so the digest a bundle binds to is exactly
    the leaf an inclusion proof authenticates (docs/protocol.md §6)."""
    return H.hash_bytes(_LEAF_PREFIX + bytes(raw))


leaf_hash = manifest_digest


@dataclass(frozen=True)
class Checkpoint:
    """A log head: everything a client pins from one gossip round."""
    origin: str             # log identity (namespaces roots across logs)
    tree_size: int          # number of leaves this root covers
    root: np.ndarray        # (8,) uint32 RFC 6962-style Merkle tree hash

    def to_bytes(self) -> bytes:
        return wire.encode_checkpoint(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "Checkpoint":
        return wire.decode_checkpoint(raw)


@dataclass(frozen=True)
class InclusionProof:
    """Audit path for ``leaf_index`` in a tree of ``tree_size`` leaves."""
    leaf_index: int
    tree_size: int
    path: np.ndarray        # (d, 8) uint32, leaf-to-root sibling digests

    def to_bytes(self) -> bytes:
        return wire.encode_inclusion_proof(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "InclusionProof":
        return wire.decode_inclusion_proof(raw)


@dataclass(frozen=True)
class ConsistencyProof:
    """Proof that the tree of ``new_size`` leaves extends ``old_size``."""
    old_size: int
    new_size: int
    path: np.ndarray        # (d, 8) uint32

    def to_bytes(self) -> bytes:
        return wire.encode_consistency_proof(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "ConsistencyProof":
        return wire.decode_consistency_proof(raw)


def _k_split(n: int) -> int:
    """Largest power of two strictly less than n (RFC 6962 split point)."""
    return 1 << ((n - 1).bit_length() - 1)


class TransparencyLog:
    """Owner-side append-only log of manifest revisions.

    Leaves are manifest digests; subtree roots are memoized, so ``append``
    and proof generation cost O(log n) compressions on a log of n entries
    (append-only means a computed ``[lo, hi)`` subtree never changes).
    """

    def __init__(self, origin: str = "zkgraph-log"):
        self.origin = origin
        self._leaves: list = []      # leaf digests, (8,) uint32 each
        self._entries: list = []     # raw manifest bytes, re-servable
        self._memo: dict = {}        # (lo, hi) -> subtree root

    @staticmethod
    def open(path, origin: str = None, checkpoint_every: int = 1):
        """Open (or create) a *durable* log backed by the append-only file
        store at ``path`` (:mod:`repro.core.logstore`): fsync'd appends,
        periodic checkpoint records, torn-tail truncate-on-recovery, and a
        replay that re-derives and cross-checks every stored checkpoint's
        Merkle root.  Returns a
        :class:`~repro.core.logstore.DurableTransparencyLog` (a drop-in
        :class:`TransparencyLog` with ``.sync()`` / ``.close()``)."""
        from .logstore import DurableTransparencyLog
        return DurableTransparencyLog.open(path, origin, checkpoint_every)

    @property
    def size(self) -> int:
        return len(self._leaves)

    def entry(self, index: int) -> bytes:
        """The raw manifest bytes at a leaf (what the log re-serves)."""
        return self._entries[index]

    def append(self, manifest) -> Checkpoint:
        """Append a manifest (object or canonical bytes); returns the new
        checkpoint covering it as the last leaf."""
        raw = manifest if isinstance(manifest, (bytes, bytearray)) \
            else manifest.to_bytes()
        raw = bytes(raw)
        self._entries.append(raw)
        self._leaves.append(manifest_digest(raw))
        return self.checkpoint()

    # -- tree hashing (RFC 6962 MTH) ----------------------------------------
    def _mth(self, lo: int, hi: int) -> np.ndarray:
        if hi - lo == 1:
            return self._leaves[lo]
        cached = self._memo.get((lo, hi))
        if cached is None:
            k = _k_split(hi - lo)
            cached = merkle.compress_pair(self._mth(lo, lo + k),
                                          self._mth(lo + k, hi))
            self._memo[(lo, hi)] = cached
        return cached

    def root(self, tree_size: int = None) -> np.ndarray:
        size = self.size if tree_size is None else int(tree_size)
        if not 0 <= size <= self.size:
            raise TransparencyError(
                f"no checkpoint at size {size} (log has {self.size} leaves)")
        if size == 0:
            return H.hash_bytes(b"")         # MTH({}) — the empty-tree root
        return self._mth(0, size)

    def checkpoint(self, tree_size: int = None) -> Checkpoint:
        size = self.size if tree_size is None else int(tree_size)
        return Checkpoint(self.origin, size, self.root(size))

    # -- proofs (RFC 6962 PATH / PROOF) -------------------------------------
    def inclusion_proof(self, leaf_index: int,
                        tree_size: int = None) -> InclusionProof:
        size = self.size if tree_size is None else int(tree_size)
        if not 0 <= leaf_index < size <= self.size:
            raise TransparencyError(
                f"no leaf {leaf_index} in a tree of {size} "
                f"(log has {self.size} leaves)")
        path = self._path(leaf_index, 0, size)
        return InclusionProof(leaf_index, size, _stack_path(path))

    def _path(self, m: int, lo: int, hi: int) -> list:
        if hi - lo == 1:
            return []
        k = _k_split(hi - lo)
        if m < k:
            return self._path(m, lo, lo + k) + [self._mth(lo + k, hi)]
        return self._path(m - k, lo + k, hi) + [self._mth(lo, lo + k)]

    def consistency_proof(self, old_size: int,
                          new_size: int = None) -> ConsistencyProof:
        new = self.size if new_size is None else int(new_size)
        old = int(old_size)
        if not 1 <= old <= new <= self.size:
            raise TransparencyError(
                f"no consistency proof {old} -> {new} "
                f"(log has {self.size} leaves)")
        path = self._subproof(old, 0, new, True)
        return ConsistencyProof(old, new, _stack_path(path))

    def _subproof(self, m: int, lo: int, hi: int, whole: bool) -> list:
        if m == hi - lo:
            return [] if whole else [self._mth(lo, hi)]
        k = _k_split(hi - lo)
        if m <= k:
            return self._subproof(m, lo, lo + k, whole) + \
                [self._mth(lo + k, hi)]
        return self._subproof(m - k, lo + k, hi, False) + \
            [self._mth(lo, lo + k)]


def _stack_path(path: list) -> np.ndarray:
    if not path:
        return np.zeros((0, 8), np.uint32)
    return np.stack(path).astype(np.uint32)


# ---------------------------------------------------------------------------
# client-side verification (no log access: checkpoint + proof only)
# ---------------------------------------------------------------------------
def verify_inclusion(checkpoint: Checkpoint, proof: InclusionProof,
                     leaf: np.ndarray) -> bool:
    """RFC 6962 audit-path check: is ``leaf`` (a manifest digest) the
    ``proof.leaf_index``-th leaf of ``checkpoint``?  Pure and closed —
    any inconsistency is ``False``, never an exception."""
    try:
        if proof.tree_size != checkpoint.tree_size:
            return False
        fn, sn = int(proof.leaf_index), int(proof.tree_size) - 1
        if not 0 <= fn <= sn:
            return False
        node = np.asarray(leaf, np.uint32)
        if node.shape != (8,):
            return False
        for sib in np.asarray(proof.path, np.uint32).reshape(-1, 8):
            if sn == 0:
                return False
            if fn & 1 or fn == sn:
                node = merkle.compress_pair(sib, node)
                while fn & 1 == 0 and fn != 0:
                    fn >>= 1
                    sn >>= 1
            else:
                node = merkle.compress_pair(node, sib)
            fn >>= 1
            sn >>= 1
        return sn == 0 and np.array_equal(node, checkpoint.root)
    except (ValueError, TypeError, AttributeError):
        return False


def verify_consistency(old: Checkpoint, new: Checkpoint,
                       proof: ConsistencyProof) -> bool:
    """RFC 6962 consistency check: does ``new`` extend ``old`` append-only?
    ``False`` on any mismatch (including cross-log origins) — the check a
    client runs between gossip rounds to detect owner equivocation."""
    try:
        if old.origin != new.origin:
            return False
        if (proof.old_size, proof.new_size) != (old.tree_size, new.tree_size):
            return False
        first, second = int(old.tree_size), int(new.tree_size)
        if not 1 <= first <= second:
            return False
        path = [p for p in np.asarray(proof.path, np.uint32).reshape(-1, 8)]
        if first == second:
            return len(path) == 0 and np.array_equal(old.root, new.root)
        if not path:
            return False
        fn, sn = first - 1, second - 1
        while fn & 1:
            fn >>= 1
            sn >>= 1
        if fn:
            fr = sr = path[0]
            path = path[1:]
        else:
            fr = sr = np.asarray(old.root, np.uint32)
        for c in path:
            if sn == 0:
                return False
            if fn & 1 or fn == sn:
                fr = merkle.compress_pair(c, fr)
                sr = merkle.compress_pair(c, sr)
                while fn & 1 == 0 and fn != 0:
                    fn >>= 1
                    sn >>= 1
            else:
                sr = merkle.compress_pair(sr, c)
            fn >>= 1
            sn >>= 1
        return sn == 0 and np.array_equal(fr, old.root) \
            and np.array_equal(sr, new.root)
    except (ValueError, TypeError, AttributeError):
        return False


def bootstrap_manifest(checkpoint: Checkpoint, inclusion: InclusionProof,
                       manifest_bytes: bytes):
    """Verifier-side trust bootstrap: authenticate manifest bytes against a
    log checkpoint, then decode them.

    Returns the decoded :class:`~repro.core.commit.CommitmentManifest` with
    its digest pinned to the *included* leaf, so every subsequently verified
    bundle is transitively bound to the transparency log.  Raises
    :class:`TransparencyError` (bad inclusion) or
    :class:`~repro.core.wire.WireFormatError` (malformed bytes) — never
    returns an unauthenticated manifest."""
    if checkpoint is None or inclusion is None or manifest_bytes is None:
        raise TransparencyError(
            "bootstrap needs a checkpoint, an inclusion proof, and the "
            "manifest bytes; none may be omitted")
    digest = manifest_digest(manifest_bytes)
    if not verify_inclusion(checkpoint, inclusion, digest):
        raise TransparencyError(
            f"manifest digest is not leaf {inclusion.leaf_index} of "
            f"checkpoint {checkpoint.origin!r}@{checkpoint.tree_size}; "
            f"refusing to bootstrap trust from an unlogged manifest")
    from .commit import CommitmentManifest
    manifest = CommitmentManifest.from_bytes(manifest_bytes)
    manifest._digest = digest
    return manifest
