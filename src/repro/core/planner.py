"""DEPRECATED compatibility shims over the plan-IR / session layers.

The monolithic planner was replaced by three layers (see
``docs/architecture.md``):

* :mod:`repro.core.ir` — declarative plan IR + the generic executor
* :mod:`repro.core.operators.registry` — node-type -> circuit adapters
* :mod:`repro.core.session` — ``ZKGraphSession`` with published commitments,
  a keygen cache, and serializable proof bundles

New code should use::

    from repro.core.session import ZKGraphSession
    session = ZKGraphSession(db)
    bundle = session.prove("IC1", dict(person=2, firstName=name))
    assert ZKGraphSession.verifier(session.commitments).verify(bundle)

The functions below keep the seed API alive for existing callers; they run
through the same IR executor and share one module-level keygen cache.
"""
from __future__ import annotations

import warnings

from . import commit, ir
from . import prover as pv
from .session import KeygenCache
from ..graphdb import tables

# legacy names, now canonical elsewhere
QUERIES = ir.QUERIES
Step = ir.Step
QueryRun = ir.QueryRun
data_root = commit.data_root
publish_commitments = commit.publish_commitments
base_table_cols = tables.base_table_cols

_CACHE = KeygenCache()     # shared by all legacy prove/verify calls


def _deprecated(name: str):
    warnings.warn(f"repro.core.planner.{name} is deprecated; use "
                  f"repro.core.session.ZKGraphSession", DeprecationWarning,
                  stacklevel=3)


def plan_query(db, qname: str, params: dict) -> QueryRun:
    """Execute + build all step circuits/witnesses for a query.

    .. deprecated:: use ``ZKGraphSession.run_query``.
    """
    _deprecated("plan_query")
    return ir.execute(db, ir.build_plan(qname), params)


def prove_query(run: QueryRun, cfg: pv.ProverConfig = None) -> list:
    """Prove every step of an executed query run.

    .. deprecated:: use ``ZKGraphSession.prove`` (per-session keygen cache).
    """
    _deprecated("prove_query")
    cfg = cfg or pv.ProverConfig()
    proofs = []
    for st in run.steps:
        _CACHE.ensure(st.op, cfg)
        proofs.append(st.op.prove(st.advice, st.instance, st.data))
    return proofs


def verify_query(run: QueryRun, proofs: list, commitments: dict,
                 cfg: pv.ProverConfig = None) -> bool:
    """Verifier side: every step proof + dataset-root binding.

    Base tables are checked against the published commitments — a missing
    base-table commitment FAILS verification (it is never recomputed from
    prover-supplied data); only chained intermediates, which are public,
    have their roots recomputed directly.

    .. deprecated:: use ``ZKGraphSession.verify`` — it also re-derives the
       chained tables and the claimed result instead of trusting ``run``.
    """
    _deprecated("verify_query")
    cfg = cfg or pv.ProverConfig()
    if len(proofs) != len(run.steps):
        return False    # every step needs a proof; zip must not truncate
    for st, proof in zip(run.steps, proofs):
        if st.op.keys is None:
            _CACHE.ensure(st.op, cfg)
        n_rows = st.op.circuit.n_rows
        if st.data_desc == "chained":
            expected = data_root(st.data, n_rows, cfg)
        else:
            key = (st.data_desc, n_rows)
            if key not in commitments:
                return False     # unpublished base table: reject, no fallback
            expected = commitments[key]
        if not st.op.verify(st.instance, proof, expected_data_root=expected):
            return False
    return True
