"""Expansion-centric query planner (paper §III-D).

A query is decomposed into a chain of attribute-laden expansion steps; each
step gets its own circuit + proof, and the chain is glued by *public*
intermediate tables: step k's public output becomes step k+1's committed data
table, so the verifier recomputes the expected data root itself. Base tables
are bound to the owner's published dataset commitments.

Implemented LDBC SNB interactive plans (paper §V): IS3, IS4, IS5, IC1, IC2,
IC8, IC9, IC13.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import jax.numpy as jnp
import numpy as np

from . import field as F
from . import merkle, prover as pv
from .operators import (all_shortest, birc, expansion, orderby, reachability,
                        set_expansion, sssp)
from .operators.common import Operator
from ..graphdb import engine
from ..graphdb.storage import GraphDB, pad_pow2

QUERIES = ["IS3", "IS4", "IS5", "IC1", "IC2", "IC8", "IC9", "IC13"]


# ---------------------------------------------------------------------------
# dataset commitments (the owner's one-time publication)
# ---------------------------------------------------------------------------
def data_root(data_np: np.ndarray, n_rows: int,
              cfg: pv.ProverConfig) -> np.ndarray:
    """Commitment to a data-column matrix at a given circuit size: must match
    exactly what prover.prove computes for the data tree."""
    raw = np.asarray(data_np, np.int64) % F.P
    padded = np.zeros((raw.shape[0], n_rows), np.int64)
    padded[:, : raw.shape[1]] = raw
    data = jnp.asarray(padded).astype(jnp.uint32)
    lde = pv._lde(data, cfg.blowup, cfg.shift)
    return np.asarray(merkle.commit(lde.T).root)


def base_table_cols(db: GraphDB, desc: str) -> np.ndarray:
    """Canonical data-column layouts for base tables, keyed by descriptor."""
    if desc == "knows":
        t = db.tables["person_knows_person"]
        return np.stack([t.src, t.dst])
    if desc == "knows_date":
        t = db.tables["person_knows_person"]
        return np.stack([t.src, t.dst, t.props["creationDate"]])
    if desc == "hasCreator":
        t = db.tables["comment_hasCreator_person"]
        return np.stack([t.src, t.dst])
    if desc == "hasCreator_date":
        t = db.tables["comment_hasCreator_person"]
        return np.stack([t.src, t.dst, t.props["creationDate"]])
    if desc == "replyOf":
        t = db.tables["comment_replyOf_comment"]
        return np.stack([t.src, t.dst])
    if desc == "hasCreator_rev":
        t = db.tables["comment_hasCreator_person"]
        return np.stack([t.dst, t.src])
    if desc == "replyOf_rev":
        t = db.tables["comment_replyOf_comment"]
        return np.stack([t.dst, t.src])
    if desc == "comment_date":
        ids = np.arange(len(db.node_props["comment"]["creationDate"])) + \
            (1 << 20)
        return np.stack([ids, db.node_props["comment"]["creationDate"]])
    if desc == "comment_content_date":
        cp = db.node_props["comment"]
        ids = np.arange(len(cp["creationDate"])) + (1 << 20)
        return np.stack([ids, cp["content"], cp["creationDate"]])
    if desc == "person_firstName":
        return np.stack([db.node_ids, db.node_props["person"]["firstName"]])
    if desc == "knows_nodes":
        t = db.tables["person_knows_person"]
        cols = np.zeros((3, max(len(t), db.n_nodes)), np.int64)
        cols[0, : len(t)] = t.src
        cols[1, : len(t)] = t.dst
        cols[2, : db.n_nodes] = db.node_ids
        return cols
    raise KeyError(desc)


def publish_commitments(db: GraphDB, cfg: pv.ProverConfig = None) -> dict:
    """Owner-side: dataset roots per (table descriptor, circuit size)."""
    cfg = cfg or pv.ProverConfig()
    roots = {}
    for desc in ("knows", "knows_date", "hasCreator", "hasCreator_date",
                 "replyOf", "hasCreator_rev", "replyOf_rev", "comment_date",
                 "comment_content_date", "person_firstName", "knows_nodes"):
        cols = base_table_cols(db, desc)
        n_rows = pad_pow2(cols.shape[1])
        roots[(desc, n_rows)] = data_root(cols, n_rows, cfg)
    return roots


# ---------------------------------------------------------------------------
# steps + chains
# ---------------------------------------------------------------------------
@dataclass
class Step:
    op: Operator
    advice: np.ndarray
    instance: np.ndarray
    data: np.ndarray
    data_desc: str          # base-table descriptor or "chained"
    outputs: dict = dc_field(default_factory=dict)  # public outputs for chaining


@dataclass
class QueryRun:
    name: str
    steps: list
    result: dict


def _mk(op_builder, witness_fn, data_desc, out_extract):
    return dict(build=op_builder, witness=witness_fn, desc=data_desc,
                extract=out_extract)


def _pairs_out(op, inst):
    h = op.handles
    sel = inst[h["out_sel"].index] == 1
    return (inst[h["C_s"].index][sel].astype(np.int64),
            inst[h["C_t"].index][sel].astype(np.int64))


def _step_set_expand(db, table_desc, src_arr, dst_arr, ids, bidir):
    ids = np.unique(np.asarray(ids, np.int64))
    if len(ids) == 0:
        ids = np.asarray([db.node_ids[0]])
    # output rows can exceed the edge region (bidirectional doubles matches)
    out_count = int(np.isin(src_arr, ids).sum())
    if bidir:
        out_count += int(np.isin(dst_arr, ids).sum())
    n_rows = pad_pow2(max(len(src_arr), len(ids) + 2, out_count))
    op = set_expansion.build(n_rows, len(src_arr), len(ids),
                             bidirectional=bidir)
    advice, inst, data = set_expansion.witness(op, src_arr, dst_arr, ids)
    s, t = _pairs_out(op, inst)
    return Step(op, advice, inst, data, table_desc,
                outputs=dict(src=s, dst=t))


def _step_expand(db, table_desc, cols, id_s, with_prop=False, reverse=False):
    n_rows = pad_pow2(cols.shape[1])
    op = expansion.build_edge_list(n_rows, cols.shape[1], with_prop=with_prop,
                                   reverse=reverse)
    advice, inst, data = expansion.witness_edge_list(
        op, cols[0], cols[1], id_s, cols[2] if with_prop else None)
    h = op.handles
    sel = inst[h["out_sel"].index] == 1
    out = dict(src=inst[h["C_s"].index][sel].astype(np.int64),
               dst=inst[h["C_t"].index][sel].astype(np.int64))
    if with_prop:
        out["prop"] = inst[h["C_p"].index][sel].astype(np.int64)
    return Step(op, advice, inst, data, table_desc, outputs=out)


def _step_orderby(vals, pay, k):
    m = max(len(vals), 1)
    vals = np.asarray(vals, np.int64)
    pay = np.asarray(pay, np.int64)
    if len(vals) == 0:
        vals, pay = np.asarray([0]), np.asarray([0])
    op = orderby.build(pad_pow2(max(m, 2)), len(vals), min(k, len(vals)))
    advice, inst, data = orderby.witness(op, vals, pay)
    h = op.handles
    sel = inst[h["out_sel"].index] == 1
    return Step(op, advice, inst, data, "chained",
                outputs=dict(vals=inst[h["O_val"].index][sel].astype(np.int64),
                             pay=inst[h["O_pay"].index][sel].astype(np.int64)))


def plan_query(db: GraphDB, qname: str, params: dict) -> QueryRun:
    """Execute + build all step circuits/witnesses for a query."""
    steps = []
    knows = db.tables["person_knows_person"]
    if qname == "IS3":
        # friends of p with friendship dates, newest first
        p = params["person"]
        cols = base_table_cols(db, "knows_date")
        st1 = _step_expand(db, "knows_date", cols, p, with_prop=True)
        st2 = _step_expand(db, "knows_date", cols, p, with_prop=True,
                           reverse=True)
        friends = np.concatenate([st1.outputs["dst"], st2.outputs["dst"]])
        dates = np.concatenate([st1.outputs["prop"], st2.outputs["prop"]])
        st3 = _step_orderby(dates, friends, k=max(len(friends), 1))
        steps = [st1, st2, st3]
        result = dict(friends=st3.outputs["pay"], dates=st3.outputs["vals"])
    elif qname == "IS4":
        mid = params["message"]
        cols = base_table_cols(db, "comment_content_date")
        st = _step_expand(db, "comment_content_date", cols, mid,
                          with_prop=True)
        steps = [st]
        result = dict(content=st.outputs["dst"], date=st.outputs["prop"])
    elif qname == "IS5":
        mid = params["message"]
        cols = base_table_cols(db, "hasCreator")
        st = _step_expand(db, "hasCreator", cols, mid)
        steps = [st]
        result = dict(creator=st.outputs["dst"])
    elif qname == "IC1":
        p, name = params["person"], params["firstName"]
        frontier = np.asarray([p], np.int64)
        seen = {p}
        hops = []
        for _ in range(3):
            st = _step_set_expand(db, "knows", knows.src, knows.dst,
                                  frontier, bidir=True)
            hops.append(st)
            nxt = [x for x in st.outputs["dst"].tolist() if x not in seen]
            seen |= set(nxt)
            frontier = np.unique(np.asarray(nxt, np.int64)) if nxt else \
                np.asarray([p])
        cand = np.unique(np.concatenate([h.outputs["dst"] for h in hops]))
        # filter candidates by firstName: set-expand the name table, then
        # select pairs whose name == target via a reversed expansion
        names = base_table_cols(db, "person_firstName")
        st4 = _step_set_expand(db, "person_firstName", names[0], names[1],
                               cand, bidir=False)
        pairs = np.stack([st4.outputs["src"], st4.outputs["dst"]]) \
            if len(st4.outputs["src"]) else np.zeros((2, 1), np.int64)
        st5 = _step_expand(db, "chained", pairs, name, reverse=True)
        matches = st5.outputs["dst"]
        st6 = _step_orderby(matches, matches, k=min(20, max(len(matches), 1)))
        steps = hops + [st4, st5, st6]
        result = dict(persons=st6.outputs["pay"])
    elif qname in ("IC2", "IC9"):
        p, k = params["person"], params.get("k", 20)
        st1 = _step_set_expand(db, "knows", knows.src, knows.dst,
                               np.asarray([p]), bidir=True)
        friends = np.unique(st1.outputs["dst"])
        steps = [st1]
        if qname == "IC9":  # friends-of-friends too
            st1b = _step_set_expand(db, "knows", knows.src, knows.dst,
                                    friends, bidir=True)
            friends = np.unique(np.concatenate([friends, st1b.outputs["dst"]]))
            friends = friends[friends != p]
            steps.append(st1b)
        hc = db.tables["comment_hasCreator_person"]
        # messages whose creator is in the friend set: reversed table layout
        st2 = _step_set_expand(db, "hasCreator_rev", hc.dst, hc.src, friends,
                               bidir=False)
        msgs = st2.outputs["dst"]
        cd = base_table_cols(db, "comment_date")
        st3 = _step_set_expand(db, "comment_date", cd[0], cd[1], msgs,
                               bidir=False)
        st4 = _step_orderby(st3.outputs["dst"], st3.outputs["src"], k=k)
        steps += [st2, st3, st4]
        result = dict(messages=st4.outputs["pay"], dates=st4.outputs["vals"])
    elif qname == "IC8":
        p, k = params["person"], params.get("k", 20)
        hc = db.tables["comment_hasCreator_person"]
        st1 = _step_expand(db, "hasCreator", np.stack([hc.src, hc.dst]), p,
                           reverse=True)
        my_msgs = st1.outputs["dst"]
        ro = db.tables["comment_replyOf_comment"]
        st2 = _step_set_expand(db, "replyOf_rev", ro.dst, ro.src, my_msgs,
                               bidir=False)
        replies = st2.outputs["dst"]
        cd = base_table_cols(db, "comment_date")
        st3 = _step_set_expand(db, "comment_date", cd[0], cd[1], replies,
                               bidir=False)
        st4 = _step_orderby(st3.outputs["dst"], st3.outputs["src"], k=k)
        steps = [st1, st2, st3, st4]
        result = dict(replies=st4.outputs["pay"], dates=st4.outputs["vals"])
    elif qname == "IC13":
        p1, p2 = params["person1"], params["person2"]
        dist, pred, pd = engine.bfs_sssp(knows, db.node_ids, p1, True)
        cols = base_table_cols(db, "knows_nodes")
        n_rows = pad_pow2(cols.shape[1])
        op = sssp.build(n_rows, len(knows), db.n_nodes, undirected=True,
                        with_target=True)
        advice, inst, data = sssp.witness(op, knows.src, knows.dst,
                                          db.node_ids, p1, dist, pred, pd,
                                          id_t=p2)
        st = Step(op, advice, inst, data, "knows_nodes",
                  outputs=dict(dist=int(inst[op.handles["d_t"].index][0])))
        steps = [st]
        d = st.outputs["dist"]
        result = dict(distance=d if d <= db.n_nodes else -1)
    else:
        raise KeyError(qname)
    return QueryRun(qname, steps, result)


# ---------------------------------------------------------------------------
# prove / verify a whole chain
# ---------------------------------------------------------------------------
def prove_query(run: QueryRun, cfg: pv.ProverConfig = None) -> list:
    cfg = cfg or pv.ProverConfig()
    proofs = []
    for st in run.steps:
        st.op.keygen(cfg)
        proofs.append(st.op.prove(st.advice, st.instance, st.data))
    return proofs


def verify_query(run: QueryRun, proofs: list, commitments: dict,
                 cfg: pv.ProverConfig = None) -> bool:
    """Verifier side: every step proof + dataset-root binding.

    Base tables are checked against the published commitments; chained
    intermediates are public, so their roots are recomputed directly.
    """
    cfg = cfg or pv.ProverConfig()
    for st, proof in zip(run.steps, proofs):
        n_rows = st.op.circuit.n_rows
        key = (st.data_desc, n_rows)
        if st.data_desc == "chained" or key not in commitments:
            expected = data_root(st.data, n_rows, cfg)
        else:
            expected = commitments[key]
        if not st.op.verify(st.instance, proof, expected_data_root=expected):
            return False
    return True
