"""Verifier for the DEEP-ALI + FRI PLONKish proofs.

Replays the Fiat-Shamir transcript, checks the constraint identity at the OOD
point, recomputes the DEEP composition at each FRI query from the Merkle
openings, and checks FRI folds + degree bound.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import field as F
from . import fri as fri_mod
from . import merkle
from . import poly
from .plonkish import (ADVICE, DATA, FIXED, INSTANCE, Circuit, ExtOps,
                       eval_expr)
from .prover import Keys, Proof, combine_constraints, opening_schedule
from .transcript import Transcript

_U32 = jnp.uint32

BASIS = [np.eye(4, dtype=np.uint32)[c] for c in range(4)]


def verify(keys: Keys, instance_np: np.ndarray, proof: Proof,
           expected_data_root: np.ndarray = None,
           label: str = "zkgraph") -> bool:
    circuit, cfg = keys.circuit, keys.cfg
    n, B = circuit.n_rows, cfg.blowup
    nl = n * B

    # the paper's "declared dataset" check: the proof must be rooted in the
    # published dataset commitment
    if expected_data_root is not None and \
            not np.array_equal(proof.data_root, np.asarray(expected_data_root)):
        return False

    inst = jnp.asarray(instance_np.astype(np.uint32)) if circuit.n_instance \
        else jnp.zeros((0, n), _U32)
    tx = Transcript(label)
    tx.absorb(circuit.digest_seed())
    if circuit.n_instance:
        tx.absorb_digest(np.asarray(merkle.commit(inst.T).root))
    tx.absorb_digest(proof.data_root)
    tx.absorb_digest(proof.advice_root)
    alpha = jnp.asarray(tx.challenge_ext())
    beta = jnp.asarray(tx.challenge_ext())
    tx.absorb_digest(proof.ext_root)
    alpha_c = jnp.asarray(tx.challenge_ext())
    tx.absorb_digest(proof.quotient_root)
    z = jnp.asarray(tx.challenge_ext())

    # -- recompute public-poly openings, assemble the full opening table -----
    sched = opening_schedule(circuit, B)
    inst_coeffs = poly.intt(inst) if circuit.n_instance else inst
    w_n = F.root_of_unity(n)
    openings = dict(proof.openings)
    rots = sorted({r for (k, _, r) in sched if k in (FIXED, INSTANCE)})
    for rot in rots:
        zr = F.emul_fp(z, _U32(pow(w_n, rot, F.P)))
        for kind, coeffs in ((FIXED, keys.fixed_coeffs), (INSTANCE, inst_coeffs)):
            idxs = [i for (k, i, rr) in sched if k == kind and rr == rot]
            if not idxs:
                continue
            vals = poly.eval_at_ext(coeffs[jnp.asarray(idxs)], zr)
            for i, v in zip(idxs, np.asarray(vals)):
                openings[(kind, i, rot)] = v
    # transcript absorbs ALL openings in schedule order (must match prover)
    for key in sched:
        if key not in openings:
            return False
        tx.absorb(openings[key])

    # -- constraint identity at z ---------------------------------------------
    def base_getter(kind, idx, rot):
        return jnp.asarray(openings[(kind, idx, rot)])

    def ext_getter(col, rot):
        acc = jnp.zeros(4, _U32)
        for c in range(4):
            v = jnp.asarray(openings[("ext", col * 4 + c, rot)])
            acc = F.eadd(acc, F.emul(jnp.asarray(BASIS[c]), v))
        return acc

    like = jnp.zeros(4, _U32)  # scalar ext template

    class ScalarExtOps:
        """base columns evaluated at z are Fp4 scalars: use ext arithmetic."""
        add = staticmethod(F.eadd)
        sub = staticmethod(F.esub)
        mul = staticmethod(F.emul)

        @staticmethod
        def const(v, like_):
            out = jnp.zeros(4, _U32)
            return out.at[0].set(v % F.P)

    row0_val = (base_getter(FIXED, circuit.fixed_names.index("__row0"), 0)
                if circuit.gps else jnp.zeros(4, _U32))
    c_at_z = combine_constraints(
        circuit, base_getter, ext_getter, alpha, beta, alpha_c,
        like, ScalarExtOps, lambda v: v, row0_val)

    q_at_z = jnp.zeros(4, _U32)
    z_pow_n = F.epow(z, n)
    zk = jnp.asarray(F.EXT_ONE)
    for k in range(B):
        seg = jnp.zeros(4, _U32)
        for c in range(4):
            seg = F.eadd(seg, F.emul(jnp.asarray(BASIS[c]),
                                     jnp.asarray(openings[("quotient", k * 4 + c, 0)])))
        q_at_z = F.eadd(q_at_z, F.emul(zk, seg))
        zk = F.emul(zk, z_pow_n)
    zh_at_z = F.esub(z_pow_n, jnp.asarray(F.EXT_ONE))
    if not np.array_equal(np.asarray(c_at_z),
                          np.asarray(F.emul(q_at_z, zh_at_z))):
        return False

    # -- DEEP + FRI -------------------------------------------------------------
    gamma = jnp.asarray(tx.challenge_ext())
    ok, q_idx, layer0, _ = fri_mod.fri_verify(proof.fri_proof, tx, cfg.fri(), nl)
    if not ok:
        return False
    lo, hi, pair_idx = layer0
    idx_all = np.concatenate([pair_idx, pair_idx + nl // 2])

    # Merkle openings of committed trees at the queried rows
    col_counts = {"data": circuit.n_data, "advice": circuit.n_advice,
                  "ext": circuit.n_ext * 4, "quotient": B * 4}
    roots = {"data": proof.data_root, "advice": proof.advice_root,
             "ext": proof.ext_root, "quotient": proof.quotient_root}
    rowvals = {}
    for name in ("data", "advice", "ext", "quotient"):
        rows, paths = proof.tree_openings[name]
        if col_counts[name] == 0:
            continue
        if rows.shape[0] != len(idx_all) or rows.shape[1] != col_counts[name]:
            return False
        if not bool(merkle.verify_open(jnp.asarray(roots[name]),
                                       jnp.asarray(idx_all),
                                       jnp.asarray(rows), jnp.asarray(paths))):
            return False
        rowvals[name] = rows

    # recompute DEEP composition at each queried point
    committed = [(k, i, r) for (k, i, r) in sched
                 if k in (DATA, ADVICE, "ext", "quotient")]
    groups = {}
    for (k, i, r) in committed:
        groups.setdefault(r, []).append((k, i))
    pts = np.asarray(F.fmul(poly.domain_points(nl), _U32(cfg.shift)))[idx_all]
    pts = jnp.asarray(pts)
    nq = len(idx_all)
    deep = jnp.zeros((nq, 4), _U32)
    g_pow = gamma
    name_of = {DATA: "data", ADVICE: "advice", "ext": "ext",
               "quotient": "quotient"}
    for r in sorted(groups):
        zr = F.emul_fp(z, _U32(pow(w_n, r, F.P)))
        denom = F.esub(F.ext(pts), jnp.broadcast_to(zr, (nq, 4)))
        inv_d = F.ebatch_inv(denom)
        num = jnp.zeros((nq, 4), _U32)
        for (k, i) in groups[r]:
            vals = jnp.asarray(rowvals[name_of[k]][:, i].astype(np.uint32))
            diff = F.esub(F.ext(vals), jnp.broadcast_to(
                jnp.asarray(openings[(k, i, r)]), (nq, 4)))
            num = F.eadd(num, F.emul(jnp.broadcast_to(g_pow, (nq, 4)), diff))
            g_pow = F.emul(g_pow, gamma)
        deep = F.eadd(deep, F.emul(num, inv_d))
    expect = np.concatenate([lo, hi], axis=0)
    return bool(np.array_equal(np.asarray(deep), expect))
