"""Checkpoint gossip between verifiers: pin the freshest consistent head.

A transparency log only constrains an owner if its clients *compare notes*:
a lone verifier that accepts whatever checkpoint the owner serves can be
shown a private fork forever.  This module is the comparing-notes layer (the
gossip protocol certificate-transparency deployments and transparency-backed
verifiable-search systems assume):

* a :class:`GossipMessage` — a wire-codable (payload kind 8,
  ``docs/protocol.md`` §9) envelope carrying a signed-origin
  :class:`~repro.core.transparency.Checkpoint`, an optional
  :class:`~repro.core.transparency.ConsistencyProof` linking it to an older
  head, and the origin's authenticator over the checkpoint bytes;
* a :class:`GossipPeer` — the verifier-side state machine.  It pins the
  freshest checkpoint it has *verified consistent* with everything it has
  ever seen, **demands a consistency proof** before advancing across a
  manifest revision (:class:`ConsistencyRequired`), ignores stale replays,
  and raises :class:`EquivocationError` carrying **both** conflicting
  checkpoints as evidence when two heads for the same tree size disagree or
  an offered extension fails its consistency proof.

The authenticator is a keyed sponge MAC over the canonical checkpoint bytes
(``hash_bytes(0x02 || key || checkpoint_bytes)`` — domain-separated from the
log's ``0x00`` leaf hash; §9).  It stands in for the log operator's
signature: this repo's hash is a reproduction instance, not an audited
signature scheme, but the *protocol shape* — origin-bound heads a relay
cannot forge without the origin key — is the real one.

Owner side: :func:`emit` builds the signed message straight from a
:class:`TransparencyLog` (durable or in-process).  Verifier side:
``GossipPeer.offer`` consumes messages from any source — the owner, another
verifier relaying (:meth:`GossipPeer.head_message`), or hostile bytes via
:func:`repro.core.wire.decode_gossip_message`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from . import hashing as H
from . import wire
from .transparency import Checkpoint, ConsistencyProof, verify_consistency

_AUTH_PREFIX = b"\x02"          # domain-separates the MAC from leaf hashes

__all__ = ["ConsistencyRequired", "EquivocationError", "GossipError",
           "GossipMessage", "GossipPeer", "emit", "sign_checkpoint",
           "verify_signature"]


class GossipError(ValueError):
    """A gossip offer was rejected before touching the peer's head: wrong
    origin, missing/bad authenticator, or an empty (size-0) head."""


class ConsistencyRequired(GossipError):
    """The offered head is newer than the pinned one but carried no
    consistency proof.  The peer refuses to advance blind — re-offer with
    ``emit(log, key, since=peer.head.tree_size)``."""


class EquivocationError(GossipError):
    """Two checkpoints for the same log cannot both be honest.

    Raised with the evidence attached: ``pinned`` (what this peer had
    verified) and ``offered`` (the conflicting head).  Either two roots
    disagree at one tree size (split view), or an offered extension failed
    its consistency proof (history rewrite / forged proof).  This is the
    alarm the whole transparency design exists to ring — callers should
    publish both checkpoints, not swallow the exception."""

    def __init__(self, pinned: Checkpoint, offered: Checkpoint, reason: str):
        self.pinned = pinned
        self.offered = offered
        super().__init__(
            f"equivocation detected ({reason}): pinned "
            f"{pinned.origin!r}@{pinned.tree_size} root "
            f"{_hex8(pinned.root)} vs offered @{offered.tree_size} root "
            f"{_hex8(offered.root)}")


def _hex8(root) -> str:
    return np.asarray(root, np.uint32).astype("<u4").tobytes().hex()[:16] \
        + "…"


# ---------------------------------------------------------------------------
# origin authentication (keyed sponge MAC over canonical checkpoint bytes)
# ---------------------------------------------------------------------------
def sign_checkpoint(key: bytes, cp: Checkpoint) -> np.ndarray:
    """(8,) uint32 authenticator binding ``cp`` to the origin key."""
    if not isinstance(key, (bytes, bytearray)) or not key:
        raise GossipError("origin key must be non-empty bytes")
    return H.hash_bytes(_AUTH_PREFIX + bytes(key) + cp.to_bytes())


def verify_signature(key: bytes, cp: Checkpoint, auth) -> bool:
    """Constant-shape check; ``False`` on any mismatch, never an
    exception (hostile ``auth`` shapes included)."""
    try:
        got = np.asarray(auth, np.uint32)
        if got.shape != (8,):
            return False
        return bool(np.array_equal(got, sign_checkpoint(key, cp)))
    except (GossipError, ValueError, TypeError):
        return False


# ---------------------------------------------------------------------------
# the wire envelope
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GossipMessage:
    """One gossip round's payload: a signed head, optionally linked to an
    older head by a consistency proof (required to advance a peer whose
    pinned head is older)."""
    checkpoint: Checkpoint
    consistency: Optional[ConsistencyProof]     # None: bootstrap offer
    auth: np.ndarray                            # (8,) uint32 origin MAC

    def to_bytes(self) -> bytes:
        return wire.encode_gossip_message(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "GossipMessage":
        return wire.decode_gossip_message(raw)


def emit(log, key: bytes, since: int = None) -> GossipMessage:
    """Owner side: the signed gossip message for ``log``'s current head.

    ``since`` attaches the consistency proof from that older tree size, so
    a peer pinned there can advance; ``since=None`` is a bootstrap offer
    (only a peer with no head yet will accept it past size agreement)."""
    cp = log.checkpoint()
    proof = None
    if since is not None:
        proof = log.consistency_proof(int(since), cp.tree_size)
    return GossipMessage(cp, proof, sign_checkpoint(key, cp))


# ---------------------------------------------------------------------------
# the peer state machine
# ---------------------------------------------------------------------------
class GossipPeer:
    """Verifier-side gossip state: origin-pinned, equivocation-alarmed.

    The peer remembers every ``tree_size -> root`` it has verified
    (``seen``), so a *stale* replay that contradicts history is caught just
    like a conflicting fresh head.  ``offer`` returns ``True`` when the
    pinned head advanced, ``False`` for duplicates and ignorable stale
    offers, and raises on everything that must not be silent."""

    def __init__(self, origin: str, auth_key: bytes = None):
        self.origin = origin
        self.auth_key = auth_key        # None: transport is pre-authenticated
        self.head: Optional[Checkpoint] = None
        self.seen: dict = {}            # tree_size -> (8,) root, verified
        self._head_msg: Optional[GossipMessage] = None

    @property
    def pinned(self) -> Checkpoint:
        """The freshest consistent head; raises until one was accepted."""
        if self.head is None:
            raise GossipError(
                f"gossip peer for {self.origin!r} has no pinned head yet")
        return self.head

    def head_message(self) -> GossipMessage:
        """The accepted message for this peer's head, for relaying to other
        peers verbatim — the origin's authenticator travels with it, so a
        relay cannot substitute its own head."""
        if self._head_msg is None:
            raise GossipError(
                f"gossip peer for {self.origin!r} has nothing to relay")
        return self._head_msg

    def offer(self, msg: GossipMessage) -> bool:
        cp = msg.checkpoint
        if cp.origin != self.origin:
            raise GossipError(
                f"checkpoint for log {cp.origin!r} offered to a peer "
                f"pinned on {self.origin!r}")
        if cp.tree_size < 1:
            raise GossipError("an empty (size-0) checkpoint pins nothing")
        if self.auth_key is not None and not verify_signature(
                self.auth_key, cp, msg.auth):
            raise GossipError(
                f"checkpoint @{cp.tree_size} failed origin authentication")
        known = self.seen.get(int(cp.tree_size))
        if known is not None and not np.array_equal(known, cp.root):
            # split view: two roots for one tree size — stale or fresh,
            # this is the equivocation alarm, with both heads as evidence
            raise EquivocationError(
                Checkpoint(self.origin, int(cp.tree_size), known), cp,
                f"two roots for tree size {cp.tree_size}")
        if self.head is None:
            self._pin(msg)
            return True
        if cp.tree_size == self.head.tree_size:
            return False                    # duplicate of the pinned head
        if cp.tree_size < self.head.tree_size:
            # stale replay: never regress.  If `known` matched above it is
            # harmless history; if unseen, it is unverifiable backwards —
            # either way the pinned head stands.
            return False
        if msg.consistency is None:
            raise ConsistencyRequired(
                f"offered head @{cp.tree_size} is ahead of the pinned "
                f"@{self.head.tree_size} but carries no consistency proof")
        if (msg.consistency.old_size, msg.consistency.new_size) != \
                (self.head.tree_size, cp.tree_size):
            raise ConsistencyRequired(
                f"consistency proof links {msg.consistency.old_size} -> "
                f"{msg.consistency.new_size}, not the pinned "
                f"{self.head.tree_size} -> offered {cp.tree_size}")
        if not verify_consistency(self.head, cp, msg.consistency):
            # a correctly-shaped proof that fails: the offered head does
            # not extend the pinned history — forged proof or forked log
            raise EquivocationError(
                self.head, cp,
                f"offered head @{cp.tree_size} does not extend the pinned "
                f"head @{self.head.tree_size} (consistency proof invalid)")
        self._pin(msg)
        return True

    def _pin(self, msg: GossipMessage) -> None:
        self.head = msg.checkpoint
        self.seen[int(msg.checkpoint.tree_size)] = \
            np.asarray(msg.checkpoint.root, np.uint32).copy()
        self._head_msg = msg

    def gossip_with(self, other: "GossipPeer") -> bool:
        """One symmetric exchange: each peer offers the other its head
        message.  Returns ``True`` if either head advanced; raises
        :class:`EquivocationError` if their views conflict (the split-view
        check two verifiers run against each other).  A peer whose head is
        behind and receives a proofless newer head keeps its pin — advance
        happens when a message with the right consistency proof arrives."""
        advanced = False
        for src, dst in ((self, other), (other, self)):
            if src._head_msg is None:
                continue
            try:
                advanced = dst.offer(src.head_message()) or advanced
            except ConsistencyRequired:
                pass        # behind, but not conflicting: keep the pin
        return advanced
