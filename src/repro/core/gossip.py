"""Checkpoint gossip between verifiers: pin the freshest consistent head.

A transparency log only constrains an owner if its clients *compare notes*:
a lone verifier that accepts whatever checkpoint the owner serves can be
shown a private fork forever.  This module is the comparing-notes layer (the
gossip protocol certificate-transparency deployments and transparency-backed
verifiable-search systems assume):

* a :class:`GossipMessage` — a wire-codable (payload kind 9,
  ``docs/protocol.md`` §10) envelope carrying an Ed25519-signed
  :class:`~repro.core.transparency.Checkpoint`, an optional
  :class:`~repro.core.transparency.ConsistencyProof` linking it to an older
  head, the signer's 32-byte verify key, and the 64-byte detached signature
  over the canonical checkpoint bytes;
* a :class:`GossipPeer` — the verifier-side state machine.  It pins the
  freshest checkpoint it has *verified consistent* with everything it has
  ever seen, **demands a consistency proof** before advancing across a
  manifest revision (:class:`ConsistencyRequired`), ignores stale replays,
  and raises :class:`EquivocationError` carrying **both** conflicting
  checkpoints as evidence when two heads for the same tree size disagree or
  an offered extension fails its consistency proof.

The signature is an Ed25519 (RFC 8032, :mod:`repro.core.ed25519`) detached
signature over ``0x03 || checkpoint_bytes`` — domain-separated from the
log's ``0x00`` leaf hash and the retired v2 MAC's ``0x02`` prefix (§10).
The owner holds the 32-byte seed; verifiers pin only the *verify* key
published alongside the manifest/log origin, so no verifier can mint a head
and a relay cannot substitute its own.  The MAC-era kind-8 envelope is
retired: :func:`repro.core.wire.decode_gossip_message` rejects it by name.

Owner side: :func:`emit` builds the signed message straight from a
:class:`TransparencyLog` (durable or in-process) and a
:class:`~repro.core.ed25519.SigningKey`.  Verifier side:
``GossipPeer.offer`` consumes messages from any source — the owner, another
verifier relaying (:meth:`GossipPeer.head_message`), or hostile bytes via
:func:`repro.core.wire.decode_gossip_message`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import ed25519, wire
from .transparency import Checkpoint, ConsistencyProof, verify_consistency

_SIG_PREFIX = b"\x03"   # domain-separates signatures from leaf hashes (0x00)
                        # and the retired v2 MAC (0x02)

__all__ = ["ConsistencyRequired", "EquivocationError", "GossipError",
           "GossipMessage", "GossipPeer", "emit", "sign_checkpoint",
           "verify_signature"]


class GossipError(ValueError):
    """A gossip offer was rejected before touching the peer's head: wrong
    origin, wrong signer, bad signature, or an empty (size-0) head."""


class ConsistencyRequired(GossipError):
    """The offered head is newer than the pinned one but carried no
    consistency proof.  The peer refuses to advance blind — re-offer with
    ``emit(log, key, since=peer.head.tree_size)``."""


class EquivocationError(GossipError):
    """Two checkpoints for the same log cannot both be honest.

    Raised with the evidence attached: ``pinned`` (what this peer had
    verified) and ``offered`` (the conflicting head).  Either two roots
    disagree at one tree size (split view), or an offered extension failed
    its consistency proof (history rewrite / forged proof).  This is the
    alarm the whole transparency design exists to ring — callers should
    publish both checkpoints, not swallow the exception."""

    def __init__(self, pinned: Checkpoint, offered: Checkpoint, reason: str):
        self.pinned = pinned
        self.offered = offered
        super().__init__(
            f"equivocation detected ({reason}): pinned "
            f"{pinned.origin!r}@{pinned.tree_size} root "
            f"{_hex8(pinned.root)} vs offered @{offered.tree_size} root "
            f"{_hex8(offered.root)}")


def _hex8(root) -> str:
    return np.asarray(root, np.uint32).astype("<u4").tobytes().hex()[:16] \
        + "…"


# ---------------------------------------------------------------------------
# origin authentication (Ed25519 over canonical checkpoint bytes)
# ---------------------------------------------------------------------------
def sign_checkpoint(key: ed25519.SigningKey, cp: Checkpoint) -> bytes:
    """64-byte detached signature binding ``cp`` to the origin identity."""
    if not isinstance(key, ed25519.SigningKey):
        raise GossipError(
            f"checkpoint signing needs an ed25519.SigningKey, got "
            f"{type(key).__name__}")
    return key.sign(_SIG_PREFIX + cp.to_bytes())


def verify_signature(signer: bytes, cp: Checkpoint, signature: bytes) -> bool:
    """``False`` on any defect — wrong signer, wrong lengths, tampered
    checkpoint or signature — never an exception (hostile inputs included)."""
    try:
        return ed25519.verify(signer, _SIG_PREFIX + cp.to_bytes(), signature)
    except (ValueError, TypeError):
        return False


# ---------------------------------------------------------------------------
# the wire envelope
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GossipMessage:
    """One gossip round's payload: a signed head, optionally linked to an
    older head by a consistency proof (required to advance a peer whose
    pinned head is older)."""
    checkpoint: Checkpoint
    consistency: ConsistencyProof | None    # None: bootstrap offer
    signer: bytes                           # 32-byte Ed25519 verify key
    signature: bytes                        # 64-byte detached signature

    def to_bytes(self) -> bytes:
        return wire.encode_gossip_message(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "GossipMessage":
        return wire.decode_gossip_message(raw)


def emit(log, key: ed25519.SigningKey, since: int | None = None) \
        -> GossipMessage:
    """Owner side: the signed gossip message for ``log``'s current head.

    ``since`` attaches the consistency proof from that older tree size, so
    a peer pinned there can advance; ``since=None`` is a bootstrap offer
    (only a peer with no head yet will accept it past size agreement)."""
    cp = log.checkpoint()
    proof = None
    if since is not None:
        proof = log.consistency_proof(int(since), cp.tree_size)
    return GossipMessage(cp, proof, key.pub, sign_checkpoint(key, cp))


# ---------------------------------------------------------------------------
# the peer state machine
# ---------------------------------------------------------------------------
class GossipPeer:
    """Verifier-side gossip state: origin-pinned, equivocation-alarmed.

    ``signer`` is the origin's published Ed25519 verify key — every offer
    must both *name* that key in its envelope and carry a signature that
    checks against it, so a relay can neither substitute its own head nor
    re-sign someone else's.  ``signer=None`` trusts the transport (tests
    and pre-authenticated channels only).

    The peer remembers every ``tree_size -> root`` it has verified
    (``seen``), so a *stale* replay that contradicts history is caught just
    like a conflicting fresh head.  ``offer`` returns ``True`` when the
    pinned head advanced, ``False`` for duplicates and ignorable stale
    offers, and raises on everything that must not be silent."""

    def __init__(self, origin: str, signer: bytes | None = None):
        if signer is not None:
            signer = bytes(signer)
            if len(signer) != ed25519.PUBLIC_KEY_LEN:
                raise GossipError(
                    f"gossip signer key must be {ed25519.PUBLIC_KEY_LEN} "
                    f"bytes, got {len(signer)}")
        self.origin = origin
        self.signer = signer            # None: transport is pre-authenticated
        self.head: Checkpoint | None = None
        self.seen: dict = {}            # tree_size -> (8,) root, verified
        self._head_msg: GossipMessage | None = None

    @property
    def pinned(self) -> Checkpoint:
        """The freshest consistent head; raises until one was accepted."""
        if self.head is None:
            raise GossipError(
                f"gossip peer for {self.origin!r} has no pinned head yet")
        return self.head

    def head_message(self) -> GossipMessage:
        """The accepted message for this peer's head, for relaying to other
        peers verbatim — the origin's signature travels with it, so a
        relay cannot substitute its own head."""
        if self._head_msg is None:
            raise GossipError(
                f"gossip peer for {self.origin!r} has nothing to relay")
        return self._head_msg

    def offer(self, msg: GossipMessage) -> bool:
        cp = msg.checkpoint
        if cp.origin != self.origin:
            raise GossipError(
                f"checkpoint for log {cp.origin!r} offered to a peer "
                f"pinned on {self.origin!r}")
        if cp.tree_size < 1:
            raise GossipError("an empty (size-0) checkpoint pins nothing")
        if self.signer is not None:
            if bytes(msg.signer) != self.signer:
                raise GossipError(
                    f"checkpoint @{cp.tree_size} signed by an unexpected "
                    f"key (not the pinned origin identity)")
            if not verify_signature(self.signer, cp, msg.signature):
                raise GossipError(
                    f"checkpoint @{cp.tree_size} failed origin signature "
                    f"verification")
        known = self.seen.get(int(cp.tree_size))
        if known is not None and not np.array_equal(known, cp.root):
            # split view: two roots for one tree size — stale or fresh,
            # this is the equivocation alarm, with both heads as evidence
            raise EquivocationError(
                Checkpoint(self.origin, int(cp.tree_size), known), cp,
                f"two roots for tree size {cp.tree_size}")
        if self.head is None:
            self._pin(msg)
            return True
        if cp.tree_size == self.head.tree_size:
            return False                    # duplicate of the pinned head
        if cp.tree_size < self.head.tree_size:
            # stale replay: never regress.  If `known` matched above it is
            # harmless history; if unseen, it is unverifiable backwards —
            # either way the pinned head stands.
            return False
        if msg.consistency is None:
            raise ConsistencyRequired(
                f"offered head @{cp.tree_size} is ahead of the pinned "
                f"@{self.head.tree_size} but carries no consistency proof")
        if (msg.consistency.old_size, msg.consistency.new_size) != \
                (self.head.tree_size, cp.tree_size):
            raise ConsistencyRequired(
                f"consistency proof links {msg.consistency.old_size} -> "
                f"{msg.consistency.new_size}, not the pinned "
                f"{self.head.tree_size} -> offered {cp.tree_size}")
        if not verify_consistency(self.head, cp, msg.consistency):
            # a correctly-shaped proof that fails: the offered head does
            # not extend the pinned history — forged proof or forked log
            raise EquivocationError(
                self.head, cp,
                f"offered head @{cp.tree_size} does not extend the pinned "
                f"head @{self.head.tree_size} (consistency proof invalid)")
        self._pin(msg)
        return True

    def _pin(self, msg: GossipMessage) -> None:
        self.head = msg.checkpoint
        self.seen[int(msg.checkpoint.tree_size)] = \
            np.asarray(msg.checkpoint.root, np.uint32).copy()
        self._head_msg = msg

    def gossip_with(self, other: "GossipPeer") -> bool:
        """One symmetric exchange: each peer offers the other its head
        message.  Returns ``True`` if either head advanced; raises
        :class:`EquivocationError` if their views conflict (the split-view
        check two verifiers run against each other).  A peer whose head is
        behind and receives a proofless newer head keeps its pin — advance
        happens when a message with the right consistency proof arrives."""
        advanced = False
        for src, dst in ((self, other), (other, self)):
            if src._head_msg is None:
                continue
            try:
                advanced = dst.offer(src.head_message()) or advanced
            except ConsistencyRequired:
                pass        # behind, but not conflicting: keep the pin
        return advanced
