"""DEEP-ALI + FRI prover for PLONKish circuits (replaces Halo2/KZG backend).

Pipeline (paper §III-B, adapted per DESIGN.md §2):
  witness finalize -> commit phase-1 advice -> draw α,β (Eq. (1) tuple
  compression + bus denominators) -> build phase-2 ext columns (logUp running
  sums / Eq. (2) running products) -> commit -> combine constraints -> quotient
  -> OOD openings at z -> DEEP composition -> FRI -> query openings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field as dc_field

import jax
import jax.numpy as jnp
import numpy as np

from . import backend as be
from . import field as F
from . import fri as fri_mod
from . import merkle
from . import poly
from .plonkish import (ADVICE, DATA, FIXED, INSTANCE, BaseOps, Circuit, Const,
                       ExtOps, eval_expr)
from .transcript import Transcript

_U32 = jnp.uint32
_U64 = jnp.uint64


@dataclass(frozen=True)
class ProverConfig:
    blowup: int = 4
    n_queries: int = 32
    fri_final_size: int = 32
    shift: int = poly.COSET_SHIFT
    # compute backend for keygen/prove (repro.core.backend); None = ambient
    # selection (ZKGRAPH_BACKEND env var, default "ref").  compare=False:
    # backends are bit-identical, so which one ran is an execution detail —
    # never serialized, never part of cfg equality or proof acceptance.
    backend: str = dc_field(default=None, compare=False)

    def fri(self) -> fri_mod.FriConfig:
        return fri_mod.FriConfig(self.blowup, self.n_queries,
                                 self.fri_final_size, self.shift)


@dataclass
class Keys:
    """PK/VK: fixed-column coefficient/LDE caches (paper Table III keygen)."""
    circuit: Circuit
    cfg: ProverConfig
    fixed_coeffs: jnp.ndarray     # (n_fixed, N)
    fixed_lde: jnp.ndarray        # (n_fixed, N*blowup)
    backend: str = "ref"          # resolved compute backend keygen ran under


@dataclass
class Proof:
    data_root: np.ndarray
    advice_root: np.ndarray
    ext_root: np.ndarray
    quotient_root: np.ndarray
    openings: dict                 # (kind, idx, rot) -> np (4,) for committed kinds
    fri_proof: fri_mod.FriProof
    tree_openings: dict            # tree name -> (rows, paths) at [q, q+half]
    timings: dict = dc_field(default_factory=dict)

    def size_fields(self) -> int:
        total = 24 + self.fri_proof.size_fields()
        total += 4 * len(self.openings)
        for rows, paths in self.tree_openings.values():
            total += int(np.prod(rows.shape)) + int(np.prod(paths.shape))
        return total

    # -- canonical serialization (repro.core.wire; never pickle) -------------
    def to_bytes(self) -> bytes:
        from . import wire
        return wire.encode_proof(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "Proof":
        """Decode canonical proof bytes; raises ``wire.WireFormatError`` on
        any malformed input."""
        from . import wire
        return wire.decode_proof(raw)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _ext_scale(base_vec: jnp.ndarray, e: jnp.ndarray) -> jnp.ndarray:
    """(N,) Fp x (4,) Fp4 -> (N, 4)."""
    return F.fmul(e[None, :], base_vec[:, None])


def _lde(cols: jnp.ndarray, blowup: int, shift: int) -> jnp.ndarray:
    if cols.shape[0] == 0:
        return jnp.zeros((0, cols.shape[1] * blowup), _U32)
    return poly.coset_lde(cols, blowup, shift)


def _lde_from_coeffs(coeffs: jnp.ndarray, blowup: int, shift: int) -> jnp.ndarray:
    n = coeffs.shape[-1]
    powers = np.ones(n, np.uint64)
    for i in range(1, n):
        powers[i] = powers[i - 1] * shift % F.P
    scaled = F.fmul(coeffs, jnp.asarray(powers.astype(np.uint32)))
    pad = [(0, 0)] * (coeffs.ndim - 1) + [(0, n * (blowup - 1))]
    return poly.ntt(jnp.pad(scaled, pad))


def _cumsum_mod(x: jnp.ndarray, axis=0) -> jnp.ndarray:
    return (jnp.cumsum(x.astype(_U64), axis=axis) % _U64(F.P)).astype(_U32)


def opening_schedule(circuit: Circuit, blowup: int):
    """Deterministic list of (kind, index, rot) openings at z*w^rot.

    kinds: fixed/instance (verifier-computed), advice, ext (components),
    quotient (components). Every committed polynomial appears at least at
    rot 0 so the DEEP argument binds it.
    """
    rotset = circuit.rotation_set()
    sched = []
    for kind, count in ((FIXED, circuit.n_fixed), (INSTANCE, circuit.n_instance),
                        (DATA, circuit.n_data), (ADVICE, circuit.n_advice)):
        for i in range(count):
            rots = {r for (k, j, r) in rotset if k == kind and j == i} | {0}
            for r in sorted(rots):
                sched.append((kind, i, r))
    for c in range(circuit.n_ext * 4):
        for r in (0, 1):
            sched.append(("ext", c, r))
    for c in range(blowup * 4):
        sched.append(("quotient", c, 0))
    return sched


def auto_multiplicities(circuit: Circuit, data_np: np.ndarray,
                        advice_np: np.ndarray, instance_np: np.ndarray):
    """Fill auto-multiplicity advice columns for lookup buses (host-side).

    t-side counts land only on rows where the bus t_sel is active, and on the
    first selected occurrence of each distinct tuple.
    """
    n = circuit.n_rows

    def getter(kind, idx, rot):
        src = {FIXED: None, ADVICE: advice_np, INSTANCE: instance_np,
               DATA: data_np}[kind]
        col = circuit.fixed_cols[idx] if kind == FIXED else src[idx]
        return jnp.asarray(np.roll(col, -rot).astype(np.uint32))

    like = jnp.zeros(n, _U32)
    for bus in circuit.buses:
        if bus.auto_mult_col < 0:
            continue
        f_vals = np.stack([np.asarray(eval_expr(e, getter, BaseOps, like))
                           for e in bus.f_tuple], axis=1)
        t_vals = np.stack([np.asarray(eval_expr(e, getter, BaseOps, like))
                           for e in bus.t_tuple], axis=1)
        m_f = np.asarray(eval_expr(bus.m_f, getter, BaseOps, like), np.int64)
        t_sel = np.asarray(eval_expr(bus.t_sel, getter, BaseOps, like), np.int64)
        both = np.concatenate([t_vals, f_vals], axis=0)
        _, inv = np.unique(both, axis=0, return_inverse=True)
        code_t, code_f = inv[:n], inv[n:]
        # exact int64 accumulation: float-weighted bincount would round
        # above 2^53 and is banned from field code by the purity lint
        counts = np.zeros(int(inv.max()) + 1, np.int64)
        np.add.at(counts, code_f, m_f)
        sel_rows = np.nonzero(t_sel != 0)[0]
        u_t, first_sel = np.unique(code_t[sel_rows], return_index=True)
        m_t = np.zeros(n, np.int64)
        m_t[sel_rows[first_sel]] = counts[u_t]
        advice_np[bus.auto_mult_col] = (m_t % F.P).astype(np.uint32)


# ---------------------------------------------------------------------------
# keygen
# ---------------------------------------------------------------------------
def keygen(circuit: Circuit, cfg: ProverConfig = ProverConfig()) -> Keys:
    with be.use(cfg.backend) as backend:
        circuit.assign_ext_cols()
        if circuit.gps and not any(n == "__row0" for n in circuit.fixed_names):
            onehot = np.zeros(circuit.n_rows, np.uint32)
            onehot[0] = 1
            circuit.add_fixed("__row0", onehot)
        fixed = jnp.asarray(np.stack(circuit.fixed_cols)
                            if circuit.fixed_cols else np.zeros((0, circuit.n_rows), np.uint32))
        coeffs = poly.intt(fixed) if circuit.n_fixed else fixed
        lde = _lde(fixed, cfg.blowup, cfg.shift)
        return Keys(circuit, cfg, coeffs, lde, backend.name)


def _row0_col(circuit: Circuit):
    from .plonkish import Col
    return Col(FIXED, circuit.fixed_names.index("__row0"))


# ---------------------------------------------------------------------------
# phase-2 ext column construction
# ---------------------------------------------------------------------------
def build_ext_columns(circuit: Circuit, getter_n, like_n, alpha, beta):
    """Returns (n_ext, N, 4) ext columns: bus running sums then GP products."""
    from .plonkish import compress_tuple
    n = circuit.n_rows
    cols = []
    for bus in circuit.buses:
        f_vals = [eval_expr(e, getter_n, BaseOps, like_n) for e in bus.f_tuple]
        t_vals = [eval_expr(e, getter_n, BaseOps, like_n) for e in bus.t_tuple]
        m_f = eval_expr(bus.m_f, getter_n, BaseOps, like_n)
        m_t = eval_expr(bus.m_t * bus.t_sel, getter_n, BaseOps, like_n)
        d_f = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(f_vals, alpha))
        d_t = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(t_vals, alpha))
        # m_f/d_f - m_t/d_t = (m_f*d_t - m_t*d_f) / (d_f*d_t): one batched
        # inversion instead of two (EXPERIMENTS.md §Perf iteration 4)
        num = F.esub(F.fmul(d_t, m_f[:, None]), F.fmul(d_f, m_t[:, None]))
        inc = F.emul(num, F.ebatch_inv(F.emul(d_f, d_t)))
        h = _cumsum_mod(inc, axis=0)
        h = jnp.concatenate([jnp.zeros((1, 4), _U32), h[:-1]], axis=0)
        cols.append(h)
    for gp in circuit.gps:
        c1 = [eval_expr(e, getter_n, BaseOps, like_n) for e in gp.c1_tuple]
        c2 = [eval_expr(e, getter_n, BaseOps, like_n) for e in gp.c2_tuple]
        s1 = eval_expr(gp.sel1, getter_n, BaseOps, like_n)
        s2 = eval_expr(gp.sel2, getter_n, BaseOps, like_n)
        one = jnp.zeros((n, 4), _U32).at[:, 0].set(1)
        d1 = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(c1, alpha))
        d2 = F.eadd(jnp.broadcast_to(beta, (n, 4)), compress_tuple(c2, alpha))
        not_s1 = F.fsub(jnp.full_like(s1, 1), s1)
        not_s2 = F.fsub(jnp.full_like(s2, 1), s2)
        f1 = F.eadd(F.fmul(d1, s1[:, None]), F.fmul(one, not_s1[:, None]))
        f2 = F.eadd(F.fmul(d2, s2[:, None]), F.fmul(one, not_s2[:, None]))
        ratio = F.emul(f1, F.ebatch_inv(f2))
        # Eq. (2) exclusive running product: Z[0]=1, Z[i]=prod_{j<i} —
        # dispatched (ref: associative scan; pallas: blocked-scan kernel)
        z = be.active().grand_product_ext(ratio)
        cols.append(z)
    if not cols:
        return jnp.zeros((0, n, 4), _U32)
    return jnp.stack(cols)


# ---------------------------------------------------------------------------
# constraint evaluation (shared shape between LDE-domain and OOD-point)
# ---------------------------------------------------------------------------
def combine_constraints(circuit: Circuit, base_getter, ext_getter, alpha, beta,
                        alpha_c, like_base, ops, ext_of_base, row0_val):
    """Evaluate sum_i alpha_c^i * constraint_i.

    ``base_getter``: base-column access returning ops-domain values.
    ``ext_getter(col, rot)``: ext helper column value (always Fp4-shaped).
    ``ext_of_base(v)``: lift a base-domain value into the ext accumulator space.
    ``row0_val``: evaluation of the __row0 one-hot fixed column (or None).
    Returns the combined accumulator (ext space).
    """
    acc = None
    a_pow = None

    def add_term(val_ext):
        nonlocal acc, a_pow
        if acc is None:
            acc = val_ext
            a_pow = alpha_c
        else:
            acc = F.eadd(acc, F.emul(jnp.broadcast_to(a_pow, val_ext.shape), val_ext))
            a_pow = F.emul(a_pow, alpha_c)

    for _, gate in circuit.gates:
        v = eval_expr(gate, base_getter, ops, like_base)
        add_term(ext_of_base(v))

    def compress(exprs):
        vals = [eval_expr(e, base_getter, ops, like_base) for e in exprs]
        out = ext_of_base(vals[0])
        apow = alpha
        for v in vals[1:]:
            out = F.eadd(out, F.emul(jnp.broadcast_to(apow, out.shape), ext_of_base(v)))
            apow = F.emul(apow, alpha)
        return out

    def mul_base(val_ext, base_v):
        return F.emul(val_ext, ext_of_base(base_v))

    for bus in circuit.buses:
        d_f = F.eadd(jnp.broadcast_to(beta, compress(bus.f_tuple).shape),
                     compress(bus.f_tuple))
        d_t = F.eadd(jnp.broadcast_to(beta, d_f.shape), compress(bus.t_tuple))
        h = ext_getter(bus.ext_col, 0)
        h1 = ext_getter(bus.ext_col, 1)
        m_f = eval_expr(bus.m_f, base_getter, ops, like_base)
        m_t = eval_expr(bus.m_t * bus.t_sel, base_getter, ops, like_base)
        term = F.emul(F.esub(h1, h), F.emul(d_f, d_t))
        term = F.esub(term, mul_base(d_t, m_f))
        term = F.eadd(term, mul_base(d_f, m_t))
        add_term(term)
    for gp in circuit.gps:
        d1 = F.eadd(jnp.broadcast_to(beta, compress(gp.c1_tuple).shape),
                    compress(gp.c1_tuple))
        d2 = F.eadd(jnp.broadcast_to(beta, d1.shape), compress(gp.c2_tuple))
        s1 = eval_expr(gp.sel1, base_getter, ops, like_base)
        s2 = eval_expr(gp.sel2, base_getter, ops, like_base)
        one_b = ops.const(1, like_base)
        f1 = F.eadd(mul_base(d1, s1), ext_of_base(ops.sub(one_b, s1)))
        f2 = F.eadd(mul_base(d2, s2), ext_of_base(ops.sub(one_b, s2)))
        z = ext_getter(gp.ext_col, 0)
        z1 = ext_getter(gp.ext_col, 1)
        add_term(F.esub(F.emul(z1, f2), F.emul(z, f1)))
        # boundary Z[row0] = 1
        one_e = jnp.zeros(z.shape, _U32).at[..., 0].set(1)
        add_term(F.emul(ext_of_base(row0_val), F.esub(z, one_e)))
    if acc is None:
        like = ext_of_base(ops.const(0, like_base))
        acc = jnp.zeros(like.shape, _U32)
    return acc


# ---------------------------------------------------------------------------
# prove
# ---------------------------------------------------------------------------
def prove(keys: Keys, advice_np: np.ndarray, instance_np: np.ndarray,
          data_np: np.ndarray = None, label: str = "zkgraph") -> Proof:
    """Prove under the backend that produced these Keys (``keys.backend``,
    resolved at keygen time) — PK/LDE buffers and the proving run never
    cross backends.  Proof bytes are bit-identical across backends —
    Fiat–Shamir soundness depends on it, and the suite asserts it — so the
    backend choice is pure execution policy."""
    with be.use(keys.backend):
        return _prove_impl(keys, advice_np, instance_np, data_np, label)


def _prove_impl(keys: Keys, advice_np: np.ndarray, instance_np: np.ndarray,
                data_np: np.ndarray = None, label: str = "zkgraph") -> Proof:
    circuit, cfg = keys.circuit, keys.cfg
    n, B = circuit.n_rows, cfg.blowup
    nl = n * B
    t0 = time.perf_counter()
    timings = {}

    if data_np is None:
        data_np = np.zeros((0, n), np.uint32)
    auto_multiplicities(circuit, data_np, advice_np, instance_np)
    advice = jnp.asarray(advice_np.astype(np.uint32))
    data = jnp.asarray(data_np.astype(np.uint32)) if circuit.n_data \
        else jnp.zeros((0, n), _U32)
    inst = jnp.asarray(instance_np.astype(np.uint32)) if circuit.n_instance \
        else jnp.zeros((0, n), _U32)

    tx = Transcript(label)
    tx.absorb(circuit.digest_seed())
    if circuit.n_instance:
        # bind public I/O by a Merkle root (one digest, not O(N) sponge blocks)
        tx.absorb_digest(np.asarray(merkle.commit(inst.T).root))

    # --- phase 0: commit the dataset (the declared-DB binding) --------------
    data_coeffs = poly.intt(data) if circuit.n_data else data
    data_lde = _lde(data, B, cfg.shift)
    data_tree = merkle.commit(data_lde.T) if circuit.n_data else None
    data_root = np.asarray(data_tree.root) if data_tree else np.zeros(8, np.uint32)
    tx.absorb_digest(data_root)

    # --- phase 1: commit advice -------------------------------------------
    adv_coeffs = poly.intt(advice) if circuit.n_advice else advice
    adv_lde = _lde(advice, B, cfg.shift)
    adv_tree = merkle.commit(adv_lde.T) if circuit.n_advice else None
    adv_root = np.asarray(adv_tree.root) if adv_tree else np.zeros(8, np.uint32)
    tx.absorb_digest(adv_root)
    timings["commit_advice"] = time.perf_counter() - t0

    alpha = jnp.asarray(tx.challenge_ext())
    beta = jnp.asarray(tx.challenge_ext())

    # --- phase 2: ext columns ----------------------------------------------
    t1 = time.perf_counter()
    fixed_n = jnp.asarray(np.stack(circuit.fixed_cols)
                          if circuit.fixed_cols else np.zeros((0, n), np.uint32))

    def getter_n(kind, idx, rot):
        src = {FIXED: fixed_n, ADVICE: advice, INSTANCE: inst, DATA: data}[kind]
        return jnp.roll(src[idx], -rot)

    like_n = jnp.zeros(n, _U32)
    ext_cols = build_ext_columns(circuit, getter_n, like_n, alpha, beta)
    n_ext = circuit.n_ext
    ext_base = ext_cols.transpose(0, 2, 1).reshape(n_ext * 4, n) if n_ext \
        else jnp.zeros((0, n), _U32)
    ext_coeffs = poly.intt(ext_base) if n_ext else ext_base
    ext_lde = _lde(ext_base, B, cfg.shift)
    ext_tree = merkle.commit(ext_lde.T) if n_ext else None
    ext_root = np.asarray(ext_tree.root) if ext_tree else np.zeros(8, np.uint32)
    tx.absorb_digest(ext_root)
    timings["phase2_ext"] = time.perf_counter() - t1

    alpha_c = jnp.asarray(tx.challenge_ext())

    # --- quotient -----------------------------------------------------------
    t2 = time.perf_counter()
    fixed_lde, inst_lde = keys.fixed_lde, _lde(inst, B, cfg.shift)

    def getter_lde(kind, idx, rot):
        src = {FIXED: fixed_lde, ADVICE: adv_lde, INSTANCE: inst_lde,
               DATA: data_lde}[kind]
        return jnp.roll(src[idx], -B * rot)

    def ext_getter_lde(col, rot):
        comps = [jnp.roll(ext_lde[col * 4 + c], -B * rot) for c in range(4)]
        return jnp.stack(comps, axis=-1)

    like_lde = jnp.zeros(nl, _U32)
    row0_lde = (getter_lde(FIXED, circuit.fixed_names.index("__row0"), 0)
                if circuit.gps else like_lde)

    def ext_of_base_lde(v):
        z = jnp.zeros(v.shape + (4,), _U32)
        return z.at[..., 0].set(v)

    c_lde = combine_constraints(circuit, getter_lde, ext_getter_lde, alpha, beta,
                                alpha_c, like_lde, BaseOps, ext_of_base_lde,
                                row0_lde)
    # Z_H(x_i) = x_i^N - 1 = shift^N * (w_nl^N)^i - 1: period-B sequence in i
    wn = F.root_of_unity(nl)
    ratio = pow(wn, n, F.P)
    vals = np.empty(B, np.uint64)
    acc = pow(cfg.shift, n, F.P)
    for i in range(B):
        vals[i] = (acc - 1) % F.P
        acc = acc * ratio % F.P
    zh = np.asarray([vals[i % B] for i in range(nl)], np.uint32)
    zh_inv = F.fbatch_inv(jnp.asarray(zh))
    q_evals = F.fmul(c_lde, zh_inv[:, None])
    q_coeffs = poly.coset_coeffs(q_evals.T, cfg.shift)    # (4, NL)
    q_segments = q_coeffs.reshape(4, B, n).transpose(1, 0, 2).reshape(B * 4, n)
    q_lde = _lde_from_coeffs(q_segments, B, cfg.shift)
    q_tree = merkle.commit(q_lde.T)
    q_root = np.asarray(q_tree.root)
    tx.absorb_digest(q_root)
    timings["quotient"] = time.perf_counter() - t2

    # --- OOD openings --------------------------------------------------------
    t3 = time.perf_counter()
    z = jnp.asarray(tx.challenge_ext())
    sched = opening_schedule(circuit, B)
    coeff_src = {FIXED: keys.fixed_coeffs, INSTANCE: poly.intt(inst) if
                 circuit.n_instance else inst, DATA: data_coeffs,
                 ADVICE: adv_coeffs, "ext": ext_coeffs, "quotient": q_segments}
    w_n = F.root_of_unity(n)
    openings = {}
    rots = sorted({r for (_, _, r) in sched})
    for rot in rots:
        zr = F.emul_fp(z, _U32(pow(w_n, rot, F.P)))
        for kind in (FIXED, INSTANCE, DATA, ADVICE, "ext", "quotient"):
            idxs = [i for (k, i, rr) in sched if k == kind and rr == rot]
            if not idxs:
                continue
            coeffs = coeff_src[kind][jnp.asarray(idxs)]
            vals = poly.eval_at_ext(coeffs, zr)
            for i, v in zip(idxs, np.asarray(vals)):
                openings[(kind, i, rot)] = v
    for key in sched:
        tx.absorb(openings[key])
    timings["ood_openings"] = time.perf_counter() - t3

    # --- DEEP composition -----------------------------------------------------
    t4 = time.perf_counter()
    gamma = jnp.asarray(tx.challenge_ext())
    pts = F.fmul(poly.domain_points(nl), _U32(cfg.shift))   # (NL,)
    committed = [(k, i, r) for (k, i, r) in sched
                 if k in (DATA, ADVICE, "ext", "quotient")]
    lde_src = {DATA: data_lde, ADVICE: adv_lde, "ext": ext_lde,
               "quotient": q_lde}
    deep = jnp.zeros((nl, 4), _U32)
    g_pow = gamma
    groups = {}
    for (k, i, r) in committed:
        groups.setdefault(r, []).append((k, i))
    for r in sorted(groups):
        zr = F.emul_fp(z, _U32(pow(w_n, r, F.P)))
        denom = F.esub(F.ext(pts), jnp.broadcast_to(zr, (nl, 4)))
        inv_d = F.ebatch_inv(denom)
        num = jnp.zeros((nl, 4), _U32)
        for (k, i) in groups[r]:
            p_lde = lde_src[k][i]
            diff = F.esub(F.ext(p_lde), jnp.broadcast_to(
                jnp.asarray(openings[(k, i, r)]), (nl, 4)))
            num = F.eadd(num, F.emul(jnp.broadcast_to(g_pow, (nl, 4)), diff))
            g_pow = F.emul(g_pow, gamma)
        deep = F.eadd(deep, F.emul(num, inv_d))
    timings["deep"] = time.perf_counter() - t4

    # --- FRI -------------------------------------------------------------------
    t5 = time.perf_counter()
    fproof = fri_mod.fri_prove(deep, tx, cfg.fri())
    timings["fri"] = time.perf_counter() - t5

    # --- query openings ---------------------------------------------------------
    q_idx = jnp.asarray(fproof.query_indices)
    idx_all = jnp.concatenate([q_idx, q_idx + nl // 2])
    tree_openings = {}
    for name, tree in (("data", data_tree), ("advice", adv_tree),
                       ("ext", ext_tree), ("quotient", q_tree)):
        if tree is None:
            tree_openings[name] = (np.zeros((len(idx_all), 0), np.uint32),
                                   np.zeros((len(idx_all), 0, 8), np.uint32))
        else:
            rows, paths = merkle.open_at(tree, idx_all)
            tree_openings[name] = (np.asarray(rows), np.asarray(paths))
    timings["total"] = time.perf_counter() - t0

    # strip fixed/instance openings from the transmitted proof (verifier
    # recomputes them); keep data/advice/ext/quotient
    sent = {k: v for k, v in openings.items()
            if k[0] in (DATA, ADVICE, "ext", "quotient")}
    return Proof(data_root, adv_root, ext_root, q_root, sent, fproof,
                 tree_openings, timings)
