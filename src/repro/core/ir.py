"""Declarative query-plan IR (paper §III-D, expansion-centric decomposition).

A query is a :class:`Plan`: a chain of typed plan nodes, each lowered to one
primitive operator circuit, glued by *public* intermediate tables.  Node
inputs are **bindings** — small declarative expressions resolved by the
executor against the query parameters and the public outputs of earlier
nodes:

* :class:`Param` — a query parameter (``Param("person")``)
* :class:`Lit` — a literal value
* :class:`Out` — a previous node's public output (``Out(2, "dst")``)
* :class:`App` — a pure host-side transform of resolved bindings (frontier
  computation, concatenation, …); this is untrusted glue, every value that
  matters flows through a committed table or a public instance column.

Node data tables are either a :class:`BaseTable` (bound to the owner's
published dataset commitment) or :class:`Chained` (columns drawn from earlier
nodes' public outputs; the verifier recomputes the root itself — step k's
public output *is* step k+1's committed table).

Each LDBC query is a small pure function returning a plan; the generic
:func:`execute` runs the untrusted engine, builds witnesses through the
operator registry, and wires the chained commitments.  New operators plug in
via :mod:`repro.core.operators.registry` without touching this module.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, Callable, Optional, Tuple

import numpy as np

QUERIES = ["IS3", "IS4", "IS5", "IC1", "IC2", "IC8", "IC9", "IC13"]


# ---------------------------------------------------------------------------
# bindings
# ---------------------------------------------------------------------------
_NO_DEFAULT = object()


@dataclass(frozen=True)
class Param:
    """A query parameter, with an optional default."""
    name: str
    default: Any = _NO_DEFAULT


@dataclass(frozen=True)
class Lit:
    value: Any


@dataclass(frozen=True)
class Out:
    """Public output ``key`` of plan node ``step`` (an index into the plan)."""
    step: int
    key: str


@dataclass(frozen=True)
class App:
    """Pure transform applied to resolved bindings: ``fn(*args)``."""
    fn: Callable
    args: Tuple = ()

    def __repr__(self):
        return f"App({getattr(self.fn, '__name__', self.fn)}, {self.args})"


Binding = Any   # Param | Lit | Out | App


@dataclass
class Env:
    """Resolution environment: query params + per-node public outputs.

    ``memo`` caches resolved table columns / id sets within one execution so
    an adapter's ``shape`` and ``witness`` don't redo the host-side work."""
    params: dict
    outputs: list = dc_field(default_factory=list)
    memo: dict = dc_field(default_factory=dict)


def resolve(b: Binding, env: Env):
    if isinstance(b, Param):
        if b.name in env.params:
            return env.params[b.name]
        if b.default is not _NO_DEFAULT:
            return b.default
        raise KeyError(f"missing query parameter {b.name!r}")
    if isinstance(b, Lit):
        return b.value
    if isinstance(b, Out):
        return env.outputs[b.step][b.key]
    if isinstance(b, App):
        return b.fn(*[resolve(a, env) for a in b.args])
    return b


# ---------------------------------------------------------------------------
# table references
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BaseTable:
    """A published base table, referenced by registry descriptor."""
    desc: str


@dataclass(frozen=True)
class Chained:
    """An intermediate table whose columns are earlier nodes' public outputs.

    The verifier recomputes its data root from the (already verified) public
    instances of the referenced nodes — the chain glue of §III-D.
    """
    cols: Tuple[Binding, ...]

    def resolve_cols(self, env: Env) -> np.ndarray:
        arrs = [np.asarray(resolve(c, env), np.int64) for c in self.cols]
        if len(arrs[0]) == 0:
            return np.zeros((len(arrs), 1), np.int64)
        return np.stack(arrs)


TableRef = Any   # BaseTable | Chained


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Expand:
    """Single-source expansion (§IV-A), edge-list circuit.

    Outputs: ``src``, ``dst`` (+ ``prop`` when ``with_prop``)."""
    table: TableRef
    source: Binding
    with_prop: bool = False
    reverse: bool = False


@dataclass(frozen=True)
class SetExpand:
    """Set-based expansion (§IV-B), optionally integrated-BiRC (§IV-D).

    Outputs: ``src``, ``dst``."""
    table: TableRef
    ids: Binding
    bidirectional: bool = False


@dataclass(frozen=True)
class OrderBy:
    """Order-by + limit-k over a chained (value, payload) table (§IV-E).

    Outputs: ``vals``, ``pay`` (sorted)."""
    values: Binding
    payload: Binding
    k: Binding
    descending: bool = True


@dataclass(frozen=True)
class SSSP:
    """Single-source shortest-path verification (§IV-C), integrated BiRC.

    ``edge_table`` names the GraphDB edge table the untrusted BFS runs over;
    ``table`` is the published commitment binding for the circuit's data.
    Outputs: ``distances`` (all nodes), plus ``dist``/``distance`` (-1 when
    unreachable) when a target is given."""
    table: TableRef
    source: Binding
    target: Optional[Binding] = None
    edge_table: str = "person_knows_person"


@dataclass(frozen=True)
class NameFilter:
    """Attribute filter: keep (id, attr) pairs whose attr equals ``name``.

    Lowered to a reversed expansion over a chained pair table.
    Outputs: ``src`` (the attr), ``dst`` (the matching ids)."""
    table: TableRef
    name: Binding


@dataclass(frozen=True)
class Filter:
    """Order-predicate filter over a chained (id, value) pair table: keep
    rows whose value compares against the public ``threshold`` under ``cmp``
    (one of ``ge``/``gt``/``le``/``lt``/``eq``/``ne``).

    Outputs: ``src`` (the passing ids), ``dst`` (their values)."""
    table: TableRef
    cmp: str
    threshold: Binding


@dataclass(frozen=True)
class Aggregate:
    """Scalar aggregation over a chained single-column value table:
    ``agg`` is ``count`` (of nonzero entries), ``sum`` (mod P), or ``min``.

    Outputs: ``value`` (the aggregate, a public scalar)."""
    table: TableRef
    agg: str


@dataclass(frozen=True)
class Plan:
    name: str
    nodes: Tuple
    result: dict     # result key -> Binding


# ---------------------------------------------------------------------------
# binding transforms (pure, host-side glue)
# ---------------------------------------------------------------------------
def _concat(*arrs):
    return np.concatenate([np.asarray(a, np.int64) for a in arrs])


def _uniq_concat(*arrs):
    return np.unique(_concat(*arrs))


def _singleton(x):
    return np.asarray([x], np.int64)


def _nonzero(a):
    """Strip id 0 — the padding row Chained materializes for empty inputs."""
    a = np.asarray(a, np.int64)
    return a[a != 0]


def _length_or_1(a):
    return max(len(a), 1)


def _cap20(a):
    return min(20, max(len(a), 1))


def _new_frontier(p, new_dst, *prev_dsts):
    """BFS frontier: nodes first reached this hop (IC1's hop glue)."""
    seen = {int(p)}
    for d in prev_dsts:
        seen |= set(np.asarray(d, np.int64).tolist())
    nxt = [x for x in np.asarray(new_dst, np.int64).tolist() if x not in seen]
    return np.unique(np.asarray(nxt, np.int64)) if nxt else _singleton(p)


def _friends_minus(p, *dsts):
    f = _uniq_concat(*dsts)
    return f[f != int(p)]


# ---------------------------------------------------------------------------
# the LDBC SNB interactive plans (paper §V) — each a small pure function
# ---------------------------------------------------------------------------
def plan_is3() -> Plan:
    """Friends of p with friendship dates, newest first."""
    p = Param("person")
    fwd = Expand(BaseTable("knows_date"), p, with_prop=True)
    bwd = Expand(BaseTable("knows_date"), p, with_prop=True, reverse=True)
    dates = App(_concat, (Out(0, "prop"), Out(1, "prop")))
    friends = App(_concat, (Out(0, "dst"), Out(1, "dst")))
    top = OrderBy(dates, friends, k=App(_length_or_1, (friends,)))
    return Plan("IS3", (fwd, bwd, top),
                dict(friends=Out(2, "pay"), dates=Out(2, "vals")))


def plan_is4() -> Plan:
    """Content + creation date of a message."""
    st = Expand(BaseTable("comment_content_date"), Param("message"),
                with_prop=True)
    return Plan("IS4", (st,), dict(content=Out(0, "dst"), date=Out(0, "prop")))


def plan_is5() -> Plan:
    """Creator of a message."""
    st = Expand(BaseTable("hasCreator"), Param("message"))
    return Plan("IS5", (st,), dict(creator=Out(0, "dst")))


def plan_ic1() -> Plan:
    """Persons named firstName within 3 hops of p, top-20."""
    p = Param("person")
    hop1 = SetExpand(BaseTable("knows"), App(_singleton, (p,)),
                     bidirectional=True)
    hop2 = SetExpand(BaseTable("knows"),
                     App(_new_frontier, (p, Out(0, "dst"))),
                     bidirectional=True)
    hop3 = SetExpand(BaseTable("knows"),
                     App(_new_frontier, (p, Out(1, "dst"), Out(0, "dst"))),
                     bidirectional=True)
    cand = App(_uniq_concat, (Out(0, "dst"), Out(1, "dst"), Out(2, "dst")))
    names = SetExpand(BaseTable("person_firstName"), cand)
    filt = NameFilter(Chained((Out(3, "src"), Out(3, "dst"))),
                      Param("firstName"))
    matches = Out(4, "dst")
    top = OrderBy(matches, matches, k=App(_cap20, (matches,)))
    return Plan("IC1", (hop1, hop2, hop3, names, filt, top),
                dict(persons=Out(5, "pay")))


def _plan_messages_by(friends: Binding, hops: tuple, name: str) -> Plan:
    """Shared IC2/IC9 tail: messages by the friend set, newest first."""
    i = len(hops)
    msgs = SetExpand(BaseTable("hasCreator_rev"), friends)
    dated = SetExpand(BaseTable("comment_date"), Out(i, "dst"))
    top = OrderBy(Out(i + 1, "dst"), Out(i + 1, "src"), k=Param("k", 20))
    return Plan(name, hops + (msgs, dated, top),
                dict(messages=Out(i + 2, "pay"), dates=Out(i + 2, "vals")))


def plan_ic2() -> Plan:
    """Recent messages by friends of p."""
    hop = SetExpand(BaseTable("knows"), App(_singleton, (Param("person"),)),
                    bidirectional=True)
    friends = App(_uniq_concat, (Out(0, "dst"),))
    return _plan_messages_by(friends, (hop,), "IC2")


def plan_ic9() -> Plan:
    """Recent messages by friends and friends-of-friends of p."""
    p = Param("person")
    hop1 = SetExpand(BaseTable("knows"), App(_singleton, (p,)),
                     bidirectional=True)
    hop2 = SetExpand(BaseTable("knows"), App(_uniq_concat, (Out(0, "dst"),)),
                     bidirectional=True)
    friends = App(_friends_minus, (p, Out(0, "dst"), Out(1, "dst")))
    return _plan_messages_by(friends, (hop1, hop2), "IC9")


def plan_ic8() -> Plan:
    """Recent replies to p's messages."""
    mine = Expand(BaseTable("hasCreator"), Param("person"), reverse=True)
    replies = SetExpand(BaseTable("replyOf_rev"), Out(0, "dst"))
    dated = SetExpand(BaseTable("comment_date"), Out(1, "dst"))
    top = OrderBy(Out(2, "dst"), Out(2, "src"), k=Param("k", 20))
    return Plan("IC8", (mine, replies, dated, top),
                dict(replies=Out(3, "pay"), dates=Out(3, "vals")))


def plan_ic13() -> Plan:
    """Shortest-path distance between two persons (-1 if unreachable)."""
    st = SSSP(BaseTable("knows_nodes"), Param("person1"),
              target=Param("person2"))
    return Plan("IC13", (st,), dict(distance=Out(0, "distance")))


PLAN_BUILDERS = {
    "IS3": plan_is3, "IS4": plan_is4, "IS5": plan_is5, "IC1": plan_ic1,
    "IC2": plan_ic2, "IC8": plan_ic8, "IC9": plan_ic9, "IC13": plan_ic13,
}

#: pluggable plan resolvers, tried (in registration order) when a query name
#: is not a registered builder.  A resolver maps ``qname -> Plan`` or returns
#: None when the name is not its to handle; it must raise KeyError (never a
#: domain exception) for names it claims but cannot compile, so the verifier
#: keeps failing closed on malformed bundle query fields.
_PLAN_RESOLVERS: list = []
_RESOLVER_BOOTSTRAPPED = [False]


def register_plan_resolver(fn):
    _PLAN_RESOLVERS.append(fn)
    return fn


def build_plan(qname: str) -> Plan:
    builder = PLAN_BUILDERS.get(qname)
    if builder is not None:
        return builder()
    for resolve_fn in list(_PLAN_RESOLVERS):
        plan = resolve_fn(qname)
        if plan is not None:
            return plan
    if not _RESOLVER_BOOTSTRAPPED[0]:
        # the textual query front door (repro.query) registers its resolver
        # on import; load it lazily so core stays importable on its own
        _RESOLVER_BOOTSTRAPPED[0] = True
        import importlib
        try:
            importlib.import_module("repro.query")
        except ImportError:
            pass    # front door unavailable: fall through to the KeyError
                    # below so verify_bytes keeps returning False, not raising
        else:
            for resolve_fn in list(_PLAN_RESOLVERS):
                plan = resolve_fn(qname)
                if plan is not None:
                    return plan
    raise KeyError(f"unknown query {qname!r}; known: {sorted(PLAN_BUILDERS)}"
                   f" (or a parseable repro.query text)")


# ---------------------------------------------------------------------------
# the generic IR executor
# ---------------------------------------------------------------------------
@dataclass
class Step:
    """One executed plan node: circuit + witness + chaining metadata."""
    op: Any                 # operators.common.Operator
    advice: np.ndarray
    instance: np.ndarray
    data: np.ndarray
    data_desc: str          # base-table descriptor or "chained"
    outputs: dict = dc_field(default_factory=dict)
    kind: str = ""          # registry adapter name
    shape: dict = dc_field(default_factory=dict)   # serializable build kwargs


@dataclass
class QueryRun:
    name: str
    steps: list
    result: dict


def execute(db, plan: Plan, params: dict) -> QueryRun:
    """Run the untrusted engine over every plan node, build each operator
    circuit + witness via the registry, and extract the public outputs that
    feed later nodes (the chained-commitment wiring)."""
    from .operators import registry
    env = Env(dict(params))
    steps = []
    for node in plan.nodes:
        ad = registry.adapter_for(node)
        shape = ad.shape(db, node, env)
        op = ad.build(shape)
        advice, instance, data = ad.witness(db, op, node, env)
        outputs = ad.extract_outputs(op, instance)
        env.outputs.append(outputs)
        steps.append(Step(op, advice, instance, data, ad.data_desc(node),
                          outputs, kind=ad.name, shape=shape))
    result = {k: resolve(b, env) for k, b in plan.result.items()}
    return QueryRun(plan.name, steps, result)
