"""FRI low-degree argument over Fp4 codewords (replaces the paper's KZG —
DESIGN.md §2).

Codewords live on a multiplicative coset ``shift * H_N`` in *natural* order,
so the fold pairs are (i, i + N/2):  -x_i = x_{i+N/2}.

    fold(f)[i] = (f(x) + f(-x))/2 + beta * (f(x) - f(-x)) / (2 x)

Each committed layer stores leaf i = concat(f[i], f[i + N/2]) (8 lanes), so a
single opening feeds one fold step. The final (small) codeword is sent in
full; the verifier interpolates it and checks the degree bound.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from . import field as F
from . import merkle
from . import poly
from .transcript import Transcript

_U32 = jnp.uint32


@dataclass(frozen=True)
class FriConfig:
    blowup: int = 4          # LDE rate 1/blowup
    n_queries: int = 32
    final_size: int = 32     # stop folding at this codeword length
    shift: int = poly.COSET_SHIFT


@dataclass
class FriProof:
    layer_roots: list          # np (8,) per committed layer
    final_codeword: np.ndarray  # (final_size, 4)
    query_indices: np.ndarray   # (q,) indices into [0, N/2)
    layer_openings: list       # per layer: (rows (q,8), paths (q,depth,8))

    def size_fields(self) -> int:
        """Proof size in field elements (for the paper's proof-size metric)."""
        total = len(self.layer_roots) * 8 + self.final_codeword.size
        for rows, paths in self.layer_openings:
            total += int(np.prod(rows.shape)) + int(np.prod(paths.shape))
        return total

    # -- canonical serialization (repro.core.wire; never pickle) -------------
    def to_bytes(self) -> bytes:
        from . import wire
        return wire.encode_fri_proof(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "FriProof":
        """Decode canonical FRI-proof bytes; raises ``wire.WireFormatError``
        on any malformed input."""
        from . import wire
        return wire.decode_fri_proof(raw)


def _fold(codeword: jnp.ndarray, beta: jnp.ndarray, shift: int) -> jnp.ndarray:
    """One FRI fold of an Fp4 codeword (N,4) on coset shift*H_N -> (N/2,4)."""
    n = codeword.shape[0]
    half = n // 2
    lo, hi = codeword[:half], codeword[half:]
    inv2 = pow(2, F.P - 2, F.P)
    # x_i^{-1} for i < half on the coset
    inv_pts = poly.domain_points(n, 1)
    inv_pts = F.finv(F.fmul(inv_pts[:half], _U32(shift)))
    even = F.emul_fp(F.eadd(lo, hi), jnp.full((half,), inv2, _U32))
    odd = F.emul_fp(F.esub(lo, hi), F.fmul(inv_pts, _U32(inv2)))
    return F.eadd(even, F.emul(jnp.broadcast_to(beta, odd.shape), odd))


def _layer_leaves(codeword: jnp.ndarray) -> jnp.ndarray:
    n = codeword.shape[0]
    return jnp.concatenate([codeword[: n // 2], codeword[n // 2:]], axis=-1)  # (N/2, 8)


def fri_prove(codeword: jnp.ndarray, tx: Transcript, cfg: FriConfig) -> FriProof:
    """codeword: (N, 4) Fp4 evals on cfg.shift * H_N."""
    n = codeword.shape[0]
    trees = []
    roots = []
    words = []
    shift = cfg.shift
    cur = codeword
    while cur.shape[0] > cfg.final_size:
        tree = merkle.commit(_layer_leaves(cur))
        trees.append(tree)
        words.append(cur)
        root = np.asarray(tree.root)
        roots.append(root)
        tx.absorb_digest(root)
        beta = jnp.asarray(tx.challenge_ext())
        cur = _fold(cur, beta, shift)
        shift = shift * shift % F.P
    final_codeword = np.asarray(cur)
    tx.absorb(final_codeword.reshape(-1))

    q_idx = tx.challenge_indices(cfg.n_queries, n // 2)
    openings = []
    idx = jnp.asarray(q_idx)
    for tree, word in zip(trees, words):
        half = word.shape[0] // 2
        idx = idx % half
        rows, paths = merkle.open_at(tree, idx)
        openings.append((np.asarray(rows), np.asarray(paths)))
    return FriProof(roots, final_codeword, q_idx, openings)


# ---------------------------------------------------------------------------
# lane-batched proving (repro.core.prover_batch): L same-length codewords
# fold/commit/open in lockstep with per-lane challenges.  Lane l's FriProof
# is bit-identical to ``fri_prove(codewords[l], solo_tx, cfg)`` when the
# transcripts agree — every op below is the solo op with a leading lane dim.
# ---------------------------------------------------------------------------
def _fold_lanes(codewords: jnp.ndarray, beta: jnp.ndarray,
                shift: int) -> jnp.ndarray:
    """One fold of (L, N, 4) codewords with per-lane betas (L, 4)."""
    n = codewords.shape[1]
    half = n // 2
    lo, hi = codewords[:, :half], codewords[:, half:]
    inv2 = pow(2, F.P - 2, F.P)
    inv_pts = poly.domain_points(n, 1)
    inv_pts = F.finv(F.fmul(inv_pts[:half], _U32(shift)))
    even = F.emul_fp(F.eadd(lo, hi), jnp.full((half,), inv2, _U32))
    odd = F.emul_fp(F.esub(lo, hi), F.fmul(inv_pts, _U32(inv2)))
    return F.eadd(even, F.emul(beta[:, None, :], odd))


def fri_prove_lanes(codewords: jnp.ndarray, btx, cfg: FriConfig) -> list:
    """codewords: (L, N, 4) on cfg.shift * H_N; ``btx`` a
    :class:`~repro.core.transcript.BatchedTranscript` with L lanes.
    Returns one :class:`FriProof` per lane."""
    lanes, n = codewords.shape[0], codewords.shape[1]
    trees = []
    roots = []                 # per committed layer: (L, 8) np
    words = []
    shift = cfg.shift
    cur = codewords
    while cur.shape[1] > cfg.final_size:
        half = cur.shape[1] // 2
        leaves = jnp.concatenate([cur[:, :half], cur[:, half:]], axis=-1)
        tree = merkle.commit_lanes(leaves)
        trees.append(tree)
        words.append(cur)
        layer_roots = np.asarray(tree.roots)
        roots.append(layer_roots)
        btx.absorb_digest(layer_roots)
        beta = jnp.asarray(btx.challenge_ext())         # (L, 4)
        cur = _fold_lanes(cur, beta, shift)
        shift = shift * shift % F.P
    final_codewords = np.asarray(cur)                   # (L, final, 4)
    btx.absorb(final_codewords.reshape(lanes, -1))

    q_idx = btx.challenge_indices(cfg.n_queries, n // 2)   # (L, q)
    openings = []              # per layer: (rows (L,q,8), paths (L,q,d,8))
    idx = jnp.asarray(q_idx)
    for tree, word in zip(trees, words):
        half = word.shape[1] // 2
        idx = idx % half
        rows, paths = merkle.open_lanes(tree, idx)
        openings.append((np.asarray(rows), np.asarray(paths)))
    return [
        FriProof([r[l] for r in roots], final_codewords[l], q_idx[l],
                 [(rows[l], paths[l]) for rows, paths in openings])
        for l in range(lanes)]


def fri_verify(proof: FriProof, tx: Transcript, cfg: FriConfig, n: int):
    """Replay the transcript and check folds/paths/degree.

    Returns (ok, query_indices (q,), layer0_lo (q,4), layer0_hi (q,4)) where
    layer0 values are the opened evaluations of the first codeword at global
    indices ``q_idx`` and ``q_idx + n/2`` — the caller must check them against
    the DEEP composition recomputed from the trace openings.
    """
    betas = []
    for root in proof.layer_roots:
        tx.absorb_digest(root)
        betas.append(jnp.asarray(tx.challenge_ext()))
    tx.absorb(proof.final_codeword.reshape(-1))
    q_idx = tx.challenge_indices(cfg.n_queries, n // 2)
    if not np.array_equal(q_idx, proof.query_indices):
        return False, q_idx, None, None

    ok = True
    shift = cfg.shift
    size = n
    idx = jnp.asarray(q_idx)
    prev_fold = None          # expected folded value at current layer index
    layer0 = None
    inv2 = pow(2, F.P - 2, F.P)
    for li, (root, (rows, paths)) in enumerate(zip(proof.layer_roots, proof.layer_openings)):
        half = size // 2
        idx = idx % half
        rows = jnp.asarray(rows)
        ok &= bool(merkle.verify_open(jnp.asarray(root), idx, rows, jnp.asarray(paths)))
        lo, hi = rows[:, :4], rows[:, 4:]
        if li == 0:
            layer0 = (np.asarray(lo), np.asarray(hi), np.asarray(idx))
        if prev_fold is not None:
            # the folded value from the previous layer must appear at slot
            # lo/hi depending on whether prev index < half
            pick_hi = (prev_idx >= half)[:, None]
            expect = jnp.where(pick_hi, hi, lo)
            ok &= bool(jnp.all(expect == prev_fold))
        # fold to next layer
        pts = poly.domain_points(size, 1)
        x_inv = F.finv(F.fmul(pts[idx], _U32(shift)))
        even = F.emul_fp(F.eadd(lo, hi), jnp.full((len(q_idx),), inv2, _U32))
        odd = F.emul_fp(F.esub(lo, hi), F.fmul(x_inv, _U32(inv2)))
        prev_fold = F.eadd(even, F.emul(jnp.broadcast_to(betas[li], odd.shape), odd))
        prev_idx = idx
        shift = shift * shift % F.P
        size = half
    # final layer: folded values must match the plain codeword
    final = jnp.asarray(proof.final_codeword)
    if prev_fold is not None:
        ok &= bool(jnp.all(final[prev_idx % size] == prev_fold))
    # degree check on the final codeword: interpolate on coset shift*H_size
    deg_bound = max(size // cfg.blowup, 1)
    w = F.root_of_unity(size)
    w_inv = pow(w, F.P - 2, F.P)
    s_inv = pow(shift, F.P - 2, F.P)
    n_inv = pow(size, F.P - 2, F.P)
    ij = np.outer(np.arange(size), np.arange(size))
    Wm = jnp.asarray(
        np.vectorize(lambda e: pow(w_inv, int(e), F.P))(ij).astype(np.uint32))
    # c_j = n^{-1} s^{-j} sum_i v_i w^{-ij}
    prod = F.fmul(final[:, None, :], Wm[:, :, None])     # (i, j, 4)
    sums = jnp.sum(prod.astype(jnp.uint64), axis=0) % jnp.uint64(F.P)
    sj = np.array([pow(s_inv, j, F.P) * n_inv % F.P for j in range(size)], np.uint32)
    coeffs = F.fmul(sums.astype(_U32), jnp.asarray(sj)[:, None])
    ok &= bool(jnp.all(coeffs[deg_bound:] == 0))
    return ok, np.asarray(q_idx), layer0, None
