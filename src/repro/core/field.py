"""BabyBear prime field Fp (p = 2^31 - 2^27 + 1) and its quartic extension Fp4.

TPU adaptation of the paper's BN254 scalar field (see DESIGN.md §2): all
arithmetic stays inside 32-bit lanes with 64-bit intermediates on CPU; the
Pallas kernels carry a pure-uint32 16-bit-limb multiply path for real TPUs.

Conventions
-----------
* Fp elements: ``jnp.uint32`` arrays, canonical representatives in [0, p).
* Fp4 elements: uint32 arrays whose **last axis has size 4** (coefficients of
  1, x, x^2, x^3 in Fp[x]/(x^4 - W)).
* All ops are vectorized and jit-safe.
"""
from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)  # uint64 intermediates for mulmod

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Base field constants
# ---------------------------------------------------------------------------
P = 2013265921                     # 15 * 2^27 + 1  (BabyBear)
TWO_ADICITY = 27
GENERATOR = 31                     # multiplicative generator of Fp*
W_EXT = 11                         # Fp4 = Fp[x]/(x^4 - 11)  (Plonky3 constant)

_U32 = jnp.uint32
_U64 = jnp.uint64


def _pow_py(base: int, exp: int, mod: int = P) -> int:
    return pow(base, exp, mod)


# two-adic roots of unity: ROOTS[k] has order 2^k
ROOTS: list[int] = [1] * (TWO_ADICITY + 1)
ROOTS[TWO_ADICITY] = _pow_py(GENERATOR, (P - 1) >> TWO_ADICITY)
for _k in range(TWO_ADICITY - 1, -1, -1):
    ROOTS[_k] = ROOTS[_k + 1] * ROOTS[_k + 1] % P
assert ROOTS[1] == P - 1 and ROOTS[0] == 1


# ---------------------------------------------------------------------------
# Fp ops
# ---------------------------------------------------------------------------
def fp(x) -> jnp.ndarray:
    """Coerce ints / arrays into canonical Fp uint32 form."""
    arr = jnp.asarray(x)
    if arr.dtype in (jnp.int64, jnp.uint64, jnp.int32):
        arr = jnp.remainder(arr.astype(jnp.int64), P).astype(_U32)
    else:
        arr = arr.astype(_U32)
        arr = jnp.where(arr >= P, arr - P, arr)
    return arr


def fadd(a, b):
    s = a.astype(_U32) + b.astype(_U32)          # < 2^32, no overflow (a,b < 2^31)
    return jnp.where(s >= P, s - P, s)


def fsub(a, b):
    a = a.astype(_U32)
    b = b.astype(_U32)
    return jnp.where(a >= b, a - b, a + (_U32(P) - b))


def fneg(a):
    a = a.astype(_U32)
    return jnp.where(a == 0, a, _U32(P) - a)


def fmul(a, b):
    prod = a.astype(_U64) * b.astype(_U64)
    return (prod % _U64(P)).astype(_U32)


@functools.partial(jax.jit, static_argnums=1)
def fpow(a, e: int):
    """a ** e with a *static* python-int exponent (square and multiply)."""
    result = jnp.full(jnp.shape(a), 1, _U32)
    base = jnp.asarray(a, _U32)
    while e > 0:
        if e & 1:
            result = fmul(result, base)
        base = fmul(base, base)
        e >>= 1
    return result


def finv(a):
    return fpow(a, P - 2)


@jax.jit
def fbatch_inv(a):
    """Montgomery batch inversion along the last axis: one finv total.

    Zero entries map to zero (callers guard their own semantics).
    """
    safe = jnp.where(a == 0, _U32(1), a)
    # inv(a_i) = (prefix-excl-self * suffix-excl-self) * inv(prod of all)
    pref = jax.lax.associative_scan(fmul, safe, axis=-1)
    total_inv = finv(pref[..., -1])
    shifted = jnp.concatenate(
        [jnp.ones_like(pref[..., :1]), pref[..., :-1]], axis=-1
    )  # prefix product excluding self
    # suffix products: reverse-scan
    rev = jnp.flip(safe, axis=-1)
    suf = jax.lax.associative_scan(fmul, rev, axis=-1)
    suf = jnp.flip(suf, axis=-1)
    suf_excl = jnp.concatenate([suf[..., 1:], jnp.ones_like(suf[..., :1])], axis=-1)
    inv = fmul(fmul(shifted, suf_excl), total_inv[..., None])
    return jnp.where(a == 0, _U32(0), inv)


# ---------------------------------------------------------------------------
# Fp4 ops — last axis of size 4
# ---------------------------------------------------------------------------
def ext(x) -> jnp.ndarray:
    """Embed Fp scalar/array into Fp4 (append 3 zero coefficients)."""
    x = fp(x)
    z = jnp.zeros(x.shape + (3,), _U32)
    return jnp.concatenate([x[..., None], z], axis=-1)


def ext_from_coeffs(c0, c1, c2, c3):
    return jnp.stack([fp(c0), fp(c1), fp(c2), fp(c3)], axis=-1)


EXT_ZERO = np.array([0, 0, 0, 0], np.uint32)
EXT_ONE = np.array([1, 0, 0, 0], np.uint32)


def eadd(a, b):
    return fadd(a, b)


def esub(a, b):
    return fsub(a, b)


def eneg(a):
    return fneg(a)


@jax.jit
def emul(a, b):
    """Schoolbook Fp4 multiply with reduction x^4 = W_EXT."""
    a0, a1, a2, a3 = a[..., 0], a[..., 1], a[..., 2], a[..., 3]
    b0, b1, b2, b3 = b[..., 0], b[..., 1], b[..., 2], b[..., 3]
    w = _U32(W_EXT)

    def m(x, y):
        return fmul(x, y)

    c0 = fadd(m(a0, b0), fmul(w, fadd(fadd(m(a1, b3), m(a2, b2)), m(a3, b1))))
    c1 = fadd(fadd(m(a0, b1), m(a1, b0)), fmul(w, fadd(m(a2, b3), m(a3, b2))))
    c2 = fadd(fadd(m(a0, b2), m(a1, b1)), fadd(m(a2, b0), fmul(w, m(a3, b3))))
    c3 = fadd(fadd(m(a0, b3), m(a1, b2)), fadd(m(a2, b1), m(a3, b0)))
    return jnp.stack([c0, c1, c2, c3], axis=-1)


def emul_fp(a_ext, b_fp):
    """Fp4 * Fp (scalar multiply each coefficient)."""
    return fmul(a_ext, b_fp[..., None].astype(_U32))


@functools.partial(jax.jit, static_argnums=1)
def epow(a, e: int):
    result = jnp.broadcast_to(jnp.asarray(EXT_ONE), jnp.shape(a)).astype(_U32)
    base = a
    while e > 0:
        if e & 1:
            result = emul(result, base)
        base = emul(base, base)
        e >>= 1
    return result


@jax.jit
def einv(a):
    """Inverse in Fp4 via the norm map (two Frobenius conjugates).

    For q = p, Frobenius phi(a)(x) = a(x^p). Since x^4 = W, x^p = x * W^((p-1)/4)
    with (p-1) divisible by 4. N(a) = a * phi(a) * phi^2(a) * phi^3(a) in Fp.
    inv(a) = phi(a)*phi^2(a)*phi^3(a) / N(a).
    """
    s = _pow_py(W_EXT, (P - 1) // 4)  # x^p = s * x, s^4 = W^(p-1) = 1
    # phi^k multiplies coefficient i by s^(i*k)
    def frob(v, k):
        mults = np.array([_pow_py(s, i * k) for i in range(4)], np.uint32)
        return fmul(v, jnp.asarray(mults))

    a1 = frob(a, 1)
    a2 = frob(a, 2)
    a3 = frob(a, 3)
    prod = emul(emul(a1, a2), a3)
    norm = emul(a, prod)  # lies in Fp: coefficients 1..3 are ~0
    n0 = norm[..., 0]
    inv_n = finv(n0)
    return emul_fp(prod, inv_n)


@jax.jit
def ebatch_inv(a):
    """Batch inversion of Fp4 elements along axis -2 (stack of ext elements)."""
    # fold to one inv via prefix/suffix products (like fbatch_inv but emul)
    is_zero = jnp.all(a == 0, axis=-1, keepdims=True)
    one = jnp.broadcast_to(jnp.asarray(EXT_ONE), a.shape).astype(_U32)
    safe = jnp.where(is_zero, one, a)
    pref = jax.lax.associative_scan(emul, safe, axis=-2)
    total_inv = einv(pref[..., -1, :])
    shifted = jnp.concatenate([one[..., :1, :], pref[..., :-1, :]], axis=-2)
    rev = jnp.flip(safe, axis=-2)
    suf = jnp.flip(jax.lax.associative_scan(emul, rev, axis=-2), axis=-2)
    suf_excl = jnp.concatenate([suf[..., 1:, :], one[..., :1, :]], axis=-2)
    inv = emul(emul(shifted, suf_excl), total_inv[..., None, :])
    return jnp.where(is_zero, jnp.zeros_like(inv), inv)


# ---------------------------------------------------------------------------
# misc helpers
# ---------------------------------------------------------------------------
def rand_fp(key, shape):
    """Uniform Fp sample (rejection-free: 2^31 mod p bias is ~2^-4 of range;
    use 64-bit sample mod p for negligible bias)."""
    bits = jax.random.bits(key, shape, dtype=jnp.uint32).astype(_U64)
    bits2 = jax.random.bits(jax.random.fold_in(key, 1), shape, dtype=jnp.uint32)
    wide = (bits << _U64(32)) | bits2.astype(_U64)
    return (wide % _U64(P)).astype(_U32)


def rand_ext(key, shape=()):
    return rand_fp(key, tuple(shape) + (4,))


@functools.lru_cache(maxsize=None)
def root_of_unity(order: int) -> int:
    """Primitive root of unity of the given power-of-two order (python int)."""
    k = order.bit_length() - 1
    assert order == 1 << k and k <= TWO_ADICITY, f"bad NTT order {order}"
    return ROOTS[k]
