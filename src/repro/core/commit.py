"""Dataset commitments: the owner's one-time publication (paper §III-C).

``data_root`` must match exactly what ``prover.prove`` computes for the data
tree of a circuit with ``n_rows`` rows.  ``publish_commitments`` produces a
:class:`CommitmentManifest` — the *complete* trusted input of a verifier:

* per ``(descriptor, circuit size)`` Merkle roots of every registered base
  table (the content binding), and
* the true table **geometry**: per-descriptor row/column counts and published
  circuit sizes, the node-universe size, and per-edge-table row counts — so
  the verifier pins a bundle's declared circuit shape (``m_edges`` selector
  regions, SSSP's ``n_nodes``) against *published* values instead of trusting
  the prover's bundle.

The manifest is mapping-compatible with the seed's ``{(desc, n_rows): root}``
dict (iteration, ``in``, ``[]``), so legacy callers keep working.
"""
from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field as dc_field

import jax.numpy as jnp
import numpy as np

from . import backend as be
from . import field as F
from . import merkle
from . import prover as pv
from ..graphdb import tables
from ..graphdb.storage import GraphDB, pad_pow2

MANIFEST_VERSION = 1


class MissingCommitmentError(KeyError):
    """A proof referenced a base table the owner never published a
    commitment (or its geometry) for. Verification must not fall back to
    recomputing roots or trusting shapes from prover-supplied data."""


def data_root(data_np: np.ndarray, n_rows: int, cfg: pv.ProverConfig,
              desc: str = None) -> np.ndarray:
    """Commitment to a data-column matrix at a given circuit size.

    ``desc`` (optional) names the table in error messages: a width/row-count
    mismatch is the error an honest owner hits when ``table_sizes`` and an
    operator's declared shape disagree, so it must be diagnosable."""
    raw = np.asarray(data_np, np.int64) % F.P
    if raw.ndim != 2:
        raise ValueError(
            f"data columns for table {desc or '<anonymous>'} must be a "
            f"2-d (n_cols, width) matrix, got shape {raw.shape}")
    if raw.shape[1] > n_rows:
        raise ValueError(
            f"table {desc or '<anonymous>'} has {raw.shape[1]} rows, which "
            f"do not fit a circuit of n_rows={n_rows}; publish the table at "
            f"a circuit size >= pad_pow2({raw.shape[1]}) = "
            f"{pad_pow2(raw.shape[1])} (see commit.table_sizes)")
    padded = np.zeros((raw.shape[0], n_rows), np.int64)
    padded[:, : raw.shape[1]] = raw
    data = jnp.asarray(padded).astype(jnp.uint32)
    # roots are backend-independent (bit-identical parity), but run the
    # publication under cfg's backend so owner-side throughput scales too
    with be.use(cfg.backend):
        lde = pv._lde(data, cfg.blowup, cfg.shift)
        return np.asarray(merkle.commit(lde.T).root)


def table_sizes(db: GraphDB, n_cols: int) -> list:
    """Circuit sizes a base table of width ``n_cols`` must be published at.

    Operators may size their circuit above the table width: set-based
    expansion needs pad_pow2(max(m, |S|+2, out_count)) rows, where the
    output count is at most 2m (bidirectional) and the start set is at most
    the node universe.  Publishing every power of two from pad_pow2(m) up to
    max(pad_pow2(2m), pad_pow2(n_nodes + 2)) covers every size an honest
    plan can request — the verifier never recomputes a base-table root.
    """
    lo = pad_pow2(n_cols)
    hi = max(pad_pow2(2 * n_cols), pad_pow2(db.n_nodes + 2), lo)
    sizes = []
    n = lo
    while n <= hi:
        sizes.append(n)
        n *= 2
    return sizes


@dataclass(frozen=True)
class TableGeometry:
    """Published geometry of one base table: the verifier-trusted shape."""
    desc: str
    n_cols: int          # column-matrix height (the layout width)
    n_table_rows: int    # TRUE row count — pins m_edges selector regions
    sizes: tuple         # circuit sizes a commitment was published at
    columns: tuple = ()  # registered column names, () if unnamed


@dataclass
class CommitmentManifest(Mapping):
    """The owner's published trust root: per-size Merkle roots + geometry.

    A read-only :class:`~collections.abc.Mapping` over the legacy
    ``{(desc, n_rows): root}`` roots dict so existing callers (deprecated
    planner path, benchmarks) keep working; new code uses :meth:`root` /
    :meth:`geometry`, which fail closed with
    :class:`MissingCommitmentError`.

    :meth:`to_bytes` is the canonical wire encoding (payload kind 4 of
    :mod:`repro.core.wire`, spec in ``docs/protocol.md`` §4) — the bytes the
    owner publishes on a transparency log — and :meth:`digest` is the leaf
    hash of those bytes, the value every :class:`ProofBundle` proven against
    this manifest carries and the verifier pins.
    """
    version: int
    n_nodes: int            # node-universe size (pins SSSP's n_nodes)
    edge_counts: dict       # GraphDB edge-table name -> true row count
    tables: dict            # desc -> TableGeometry
    roots: dict = dc_field(default_factory=dict)  # (desc, n_rows) -> root
    _digest: object = dc_field(default=None, repr=False, compare=False)

    # -- canonical serialization + digest -----------------------------------
    def to_bytes(self) -> bytes:
        """Canonical, deterministic wire bytes (``encode(decode(b)) == b``);
        what a transparency log stores as one leaf."""
        from . import wire
        return wire.encode_manifest(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "CommitmentManifest":
        """Decode canonical manifest bytes; any malformed / non-canonical /
        version-skewed input raises :class:`~repro.core.wire.WireFormatError`."""
        from . import wire
        return wire.decode_manifest(raw)

    def digest(self):
        """The (8,) uint32 manifest digest (transparency-log leaf hash of
        the canonical bytes).  Memoized: treat a manifest as immutable once
        published — revisions go through a fresh ``publish_commitments`` and
        a new log leaf."""
        if self._digest is None:
            from . import transparency
            self._digest = transparency.manifest_digest(self.to_bytes())
        return self._digest

    # -- trusted lookups (fail closed) --------------------------------------
    def geometry(self, desc: str) -> TableGeometry:
        try:
            return self.tables[desc]
        except KeyError:
            raise MissingCommitmentError(
                f"no published geometry for base table {desc!r}") from None

    def root(self, desc: str, n_rows: int) -> np.ndarray:
        try:
            return self.roots[(desc, n_rows)]
        except KeyError:
            raise MissingCommitmentError(
                f"no published commitment for base table {desc!r} at "
                f"{n_rows} rows") from None

    def edge_count(self, table_name: str) -> int:
        try:
            return self.edge_counts[table_name]
        except KeyError:
            raise MissingCommitmentError(
                f"no published row count for edge table {table_name!r}") \
                from None

    def drop(self, *descs: str) -> "CommitmentManifest":
        """A copy without the given descriptors (tests / partial deployments:
        verifying a step over a dropped table raises MissingCommitmentError).

        The copy keeps the *parent's* digest: a partial deployment still
        trusts the owner's published manifest — it is merely missing local
        root material — so digest-pinned bundles fail with
        MissingCommitmentError (a deployment problem), not a digest mismatch
        (an authenticity problem)."""
        gone = set(descs)
        return CommitmentManifest(
            self.version, self.n_nodes, dict(self.edge_counts),
            {d: g for d, g in self.tables.items() if d not in gone},
            {k: v for k, v in self.roots.items() if k[0] not in gone},
            _digest=self.digest())

    # -- legacy mapping interface over the roots ----------------------------
    def __getitem__(self, key):
        return self.roots[key]

    def __iter__(self):
        return iter(self.roots)

    def __len__(self):
        return len(self.roots)


def publish_commitments(db: GraphDB,
                        cfg: pv.ProverConfig = None) -> CommitmentManifest:
    """Owner-side: dataset roots per (table descriptor, circuit size) plus
    the committed geometry the verifier pins circuit shapes against."""
    cfg = cfg or pv.ProverConfig()
    manifest = CommitmentManifest(
        MANIFEST_VERSION, int(db.n_nodes),
        {name: len(t) for name, t in db.tables.items()}, {})
    for desc in tables.all_table_descs():
        cols = tables.base_table_cols(db, desc)
        sizes = table_sizes(db, cols.shape[1])
        manifest.tables[desc] = TableGeometry(
            desc, int(cols.shape[0]), int(cols.shape[1]), tuple(sizes),
            tables.table_columns(desc))
        for n_rows in sizes:
            manifest.roots[(desc, n_rows)] = data_root(cols, n_rows, cfg,
                                                       desc=desc)
    return manifest
