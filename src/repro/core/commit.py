"""Dataset commitments: the owner's one-time publication (paper §III-C).

``data_root`` must match exactly what ``prover.prove`` computes for the data
tree of a circuit with ``n_rows`` rows; ``publish_commitments`` produces the
root of every registered base table at its canonical circuit size.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import field as F
from . import merkle
from . import prover as pv
from ..graphdb import tables
from ..graphdb.storage import GraphDB, pad_pow2


def data_root(data_np: np.ndarray, n_rows: int,
              cfg: pv.ProverConfig) -> np.ndarray:
    """Commitment to a data-column matrix at a given circuit size."""
    raw = np.asarray(data_np, np.int64) % F.P
    padded = np.zeros((raw.shape[0], n_rows), np.int64)
    padded[:, : raw.shape[1]] = raw
    data = jnp.asarray(padded).astype(jnp.uint32)
    lde = pv._lde(data, cfg.blowup, cfg.shift)
    return np.asarray(merkle.commit(lde.T).root)


def table_sizes(db: GraphDB, n_cols: int) -> list:
    """Circuit sizes a base table of width ``n_cols`` must be published at.

    Operators may size their circuit above the table width: set-based
    expansion needs pad_pow2(max(m, |S|+2, out_count)) rows, where the
    output count is at most 2m (bidirectional) and the start set is at most
    the node universe.  Publishing every power of two from pad_pow2(m) up to
    max(pad_pow2(2m), pad_pow2(n_nodes + 2)) covers every size an honest
    plan can request — the verifier never recomputes a base-table root.
    """
    lo = pad_pow2(n_cols)
    hi = max(pad_pow2(2 * n_cols), pad_pow2(db.n_nodes + 2), lo)
    sizes = []
    n = lo
    while n <= hi:
        sizes.append(n)
        n *= 2
    return sizes


def publish_commitments(db: GraphDB, cfg: pv.ProverConfig = None) -> dict:
    """Owner-side: dataset roots per (table descriptor, circuit size)."""
    cfg = cfg or pv.ProverConfig()
    roots = {}
    for desc in tables.all_table_descs():
        cols = tables.base_table_cols(db, desc)
        for n_rows in table_sizes(db, cols.shape[1]):
            roots[(desc, n_rows)] = data_root(cols, n_rows, cfg)
    return roots
