"""ZKGraph session API: the query-serving entry point.

A :class:`ZKGraphSession` owns the published dataset commitments and a keygen
cache keyed by ``(circuit shape, fixed-columns digest)`` so repeated queries
— and repeated steps within one query — reuse the fixed-column LDE / coeff
caches instead of re-running keygen per step (the hot path a proving service
pays; see ``benchmarks/paper_tables.py:cachewin``).

Owner side::

    owner = ZKGraphSession(db)
    bundle = owner.prove("IC1", dict(person=2, firstName=name))

Verifier side (no database access)::

    verifier = ZKGraphSession.verifier(owner.commitments)
    assert verifier.verify(bundle)

or, bootstrapping the whole trust root from a transparency log
(:mod:`repro.core.transparency`) instead of an in-process object::

    checkpoint, inclusion, manifest_bytes = owner.publish_to(log)
    verifier = ZKGraphSession.verifier(
        checkpoint=checkpoint, inclusion=inclusion,
        manifest_bytes=manifest_bytes)

Every bundle carries the digest of the canonical manifest encoding it was
proven against; ``verify`` rejects any bundle whose digest differs from the
verifier's (checkpoint-authenticated) manifest.

The bundle is self-contained and serializable: per step it carries the
registry adapter name + circuit shape (so the verifier rebuilds the circuit
itself), the public instance, the data descriptor, and the proof.  The wire
format is the canonical codec of :mod:`repro.core.wire` — versioned,
deterministic, bounded, never pickle — so ``from_bytes`` can face hostile
input (malformed bytes raise :class:`~repro.core.wire.WireFormatError`;
:meth:`ZKGraphSession.verify_bytes` maps that to ``False``).

The verifier trusts ONLY the owner's published
:class:`~repro.core.commit.CommitmentManifest`: every base-table step is
bound to a published root (a missing commitment raises
:class:`MissingCommitmentError`, it is never recomputed from prover-supplied
data) and its declared circuit geometry — row counts, ``m_edges`` selector
regions, SSSP's ``n_nodes`` — is pinned against the manifest's published
geometry; chained intermediate roots and shapes are re-derived from the
previous steps' (already verified) public outputs.
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field as dc_field

import numpy as np

from . import backend as be
from . import commit, ir, wire
from . import prover as pv
from .commit import CommitmentManifest, MissingCommitmentError
from .operators import registry
from .plonkish import Circuit
from .wire import WireFormatError

__all__ = ["KeygenCache", "MissingCommitmentError", "ProofBundle",
           "StepProof", "WireFormatError", "ZKGraphSession",
           "circuit_shape_digest"]


# ---------------------------------------------------------------------------
# keygen cache
# ---------------------------------------------------------------------------
def circuit_shape_digest(circuit: Circuit) -> str:
    """Digest of everything the constraint system depends on: fixed-column
    values, the column layout, and the full gate/bus/gp *expressions* (two
    circuits that differ only in a constraint polynomial — e.g. ascending vs
    descending order-by — must not share keys).

    Memoized on the circuit (``Circuit._shape_digest``, invalidated by every
    structural mutation): the SHA-256 over all fixed-column bytes is paid
    once per circuit object, not on every cache lookup."""
    if circuit._shape_digest is not None:
        return circuit._shape_digest
    h = hashlib.sha256()
    h.update(repr(circuit.digest_seed()).encode())
    for name, col in zip(circuit.fixed_names, circuit.fixed_cols):
        h.update(name.encode())
        h.update(np.ascontiguousarray(col).tobytes())
    for names in (circuit.advice_names, circuit.instance_names,
                  circuit.data_names):
        h.update("\0".join(names).encode() + b"\1")
    for name, expr in circuit.gates:
        h.update(f"{name}={expr!r}".encode() + b"\1")
    for b in circuit.buses:
        h.update(repr((b.name, b.f_tuple, b.t_tuple, b.m_f, b.m_t,
                       b.t_sel)).encode() + b"\1")
    for g in circuit.gps:
        h.update(repr((g.name, g.c1_tuple, g.c2_tuple, g.sel1,
                       g.sel2)).encode() + b"\1")
    circuit._shape_digest = h.hexdigest()
    return circuit._shape_digest


@dataclass
class KeygenCache:
    """(circuit shape digest, prover config, compute backend) -> Keys.
    Shared by prover and verifier sessions; ``ensure`` attaches cached keys
    to an operator.  The resolved backend name is part of the key (cached
    ``Keys`` hold backend-produced buffers; PK/LDE caches never cross
    backends — this also covers the fixed-column LDE cache the Keys carry).
    Bounded: oldest entries are evicted past ``max_entries`` so a
    long-lived verifier fed ever-fresh shapes cannot grow it without limit.

    Thread-safe with single-flight misses: concurrent ``ensure`` calls for
    the same key (the proving-service hot path — many queries hit the same
    circuit shapes) run keygen exactly once; the other callers block on the
    leader's in-flight event and reuse its Keys (``waits`` counts them).
    Distinct keys keygen concurrently — only bookkeeping is locked, never
    the keygen compute itself."""
    entries: dict = dc_field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    waits: int = 0          # ensure() calls that blocked on another's keygen
    max_entries: int = 128
    _lock: threading.Lock = dc_field(default_factory=threading.Lock,
                                     repr=False, compare=False)
    _inflight: dict = dc_field(default_factory=dict, repr=False,
                               compare=False)   # key -> threading.Event

    @staticmethod
    def _key(op, cfg: pv.ProverConfig):
        # the resolved compute backend is part of the key: PK/LDE caches
        # must never cross backends (entries hold backend-produced device
        # buffers, and a keygen re-run is the only safe way to switch)
        return (op.name, op.circuit.n_rows,
                (cfg.blowup, cfg.n_queries, cfg.fri_final_size, cfg.shift,
                 be.resolve_name(cfg.backend)),
                circuit_shape_digest(op.circuit))

    def ensure(self, op, cfg: pv.ProverConfig):
        """Attach (possibly cached) keys to ``op``; keygen on first sight."""
        key = self._key(op, cfg)
        while True:
            wait_on = None
            with self._lock:
                keys = self.entries.get(key)
                if keys is not None:
                    self.hits += 1
                    self.entries[key] = self.entries.pop(key)  # LRU refresh
                    op.keys = keys
                    return op
                flight = self._inflight.get(key)
                if flight is None:
                    # this caller is the flight leader: keygen outside the
                    # lock (other keys must not serialize behind it)
                    flight = self._inflight[key] = threading.Event()
                    break
                self.waits += 1
                wait_on = flight
            wait_on.wait()
            # leader finished (or failed): re-check the cache / re-elect
        try:
            keys = pv.keygen(op.circuit, cfg)
        except BaseException:
            with self._lock:
                self._inflight.pop(key, None)
            flight.set()        # waiters wake, re-check, one re-leads
            raise
        with self._lock:
            self.misses += 1
            self.entries[key] = keys
            while len(self.entries) > self.max_entries:
                self.entries.pop(next(iter(self.entries)))
            self._inflight.pop(key, None)
        flight.set()
        op.keys = keys
        return op

    def stats(self) -> dict:
        with self._lock:
            return dict(hits=self.hits, misses=self.misses, waits=self.waits,
                        entries=len(self.entries))


# ---------------------------------------------------------------------------
# proof bundle
# ---------------------------------------------------------------------------
@dataclass
class StepProof:
    """One chained step: enough for a verifier to rebuild the circuit,
    re-derive the expected data root, and check the proof."""
    kind: str           # registry adapter name
    shape: dict         # serializable build kwargs
    data_desc: str      # base-table descriptor or "chained"
    instance: np.ndarray
    proof: pv.Proof


@dataclass
class ProofBundle:
    query: str
    params: dict
    steps: list         # [StepProof]
    result: dict        # claimed query result (re-derived by the verifier)
    cfg: pv.ProverConfig
    # digest of the canonical CommitmentManifest this bundle was proven
    # against (transparency-log leaf hash, (8,) uint32); the verifier fails
    # closed if it does not match the manifest it bootstrapped trust from
    manifest_digest: np.ndarray = None

    def size_fields(self) -> int:
        return sum(s.proof.size_fields() for s in self.steps)

    def prove_seconds(self) -> float:
        return sum(s.proof.timings.get("total", 0.0) for s in self.steps)

    def to_bytes(self) -> bytes:
        """Canonical wire bytes (versioned + deterministic; never pickle)."""
        return wire.encode_bundle(self)

    @staticmethod
    def from_bytes(raw: bytes) -> "ProofBundle":
        """Decode canonical wire bytes.  Any malformed input — truncation,
        bad tags, oversized lengths, wrong dtypes, legacy pickle bytes, a
        mismatched wire version — raises :class:`WireFormatError`; nothing
        attacker-controlled is ever executed."""
        return wire.decode_bundle(raw)


def _values_equal(a, b) -> bool:
    return np.array_equal(np.asarray(a), np.asarray(b))


def _results_equal(a: dict, b: dict) -> bool:
    return set(a) == set(b) and all(_values_equal(a[k], b[k]) for k in a)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------
class ZKGraphSession:
    """Owns commitments + keygen cache; proves and verifies query bundles."""

    def __init__(self, db=None, cfg: pv.ProverConfig = None,
                 commitments: CommitmentManifest = None):
        self.db = db
        self.cfg = cfg or pv.ProverConfig()
        self._commitments = commitments
        self.cache = KeygenCache()

    @classmethod
    def verifier(cls, commitments: CommitmentManifest = None,
                 cfg: pv.ProverConfig = None, *, checkpoint=None,
                 inclusion=None, manifest_bytes=None, gossip=None):
        """A verifier-side session: no database, trust root only.

        Three bootstrap modes:

        * ``verifier(manifest)`` — an in-process
          :class:`~repro.core.commit.CommitmentManifest` obtained out of
          band (tests, co-located deployments).
        * ``verifier(checkpoint=cp, inclusion=pf, manifest_bytes=raw)`` —
          the transparency-log path: the manifest bytes are authenticated
          against the log checkpoint via the inclusion proof
          (:func:`repro.core.transparency.bootstrap_manifest`) before
          anything trusts them; a failed inclusion raises
          :class:`~repro.core.transparency.TransparencyError`.
        * ``verifier(gossip=peer, inclusion=pf, manifest_bytes=raw)`` —
          the deployment path: the checkpoint is the
          :class:`~repro.core.gossip.GossipPeer`'s pinned head — the
          freshest head that peer has verified consistent with every other
          head it gossiped (``peer.pinned`` raises
          :class:`~repro.core.gossip.GossipError` if nothing is pinned
          yet), so the trust root is backed by the gossip network, not a
          single served checkpoint.

        Either way the session pins the manifest digest, and :meth:`verify`
        rejects any bundle whose ``manifest_digest`` differs.
        """
        if gossip is not None:
            if checkpoint is not None:
                raise TypeError(
                    "pass either checkpoint= or gossip= (whose pinned head "
                    "becomes the checkpoint), not both")
            checkpoint = gossip.pinned
        if checkpoint is not None or inclusion is not None \
                or manifest_bytes is not None:
            if commitments is not None:
                raise TypeError(
                    "pass either a manifest or a checkpoint bootstrap "
                    "(checkpoint + inclusion + manifest_bytes), not both")
            from . import transparency
            commitments = transparency.bootstrap_manifest(
                checkpoint, inclusion, manifest_bytes)
        if commitments is None:
            raise TypeError(
                "verifier needs a CommitmentManifest, or a transparency "
                "checkpoint + inclusion proof + manifest bytes")
        return cls(db=None, cfg=cfg, commitments=commitments)

    # -- owner side ---------------------------------------------------------
    @property
    def commitments(self) -> CommitmentManifest:
        if self._commitments is None:
            self._commitments = self.publish()
        return self._commitments

    def publish(self) -> CommitmentManifest:
        """(Re)compute the owner's commitment manifest (roots + geometry)."""
        assert self.db is not None, "publishing requires the database"
        self._commitments = commit.publish_commitments(self.db, self.cfg)
        return self._commitments

    def publish_to(self, log) -> tuple:
        """Publish the manifest on a transparency log.

        Appends the canonical manifest bytes as a new leaf and returns
        ``(checkpoint, inclusion_proof, manifest_bytes)`` — exactly the
        bootstrap inputs of :meth:`verifier`, so the owner's publication and
        the verifier's trust root are the same auditable artifact.  ``log``
        may be an in-process :class:`~repro.core.transparency.
        TransparencyLog` or a durable one (``TransparencyLog.open(path)``)
        — with a durable log the append is fsync'd before the checkpoint is
        returned, so a served checkpoint always survives an owner crash."""
        raw = self.commitments.to_bytes()
        cp = log.append(raw)
        pf = log.inclusion_proof(cp.tree_size - 1, cp.tree_size)
        return cp, pf, raw

    def run_query(self, qname: str, params: dict) -> ir.QueryRun:
        """Execute a query plan (engine + witnesses), no proving."""
        return self.run_plan(ir.build_plan(qname), params)

    def run_plan(self, plan: ir.Plan, params: dict) -> ir.QueryRun:
        """Execute an explicit :class:`~repro.core.ir.Plan` object."""
        assert self.db is not None, "query execution requires the database"
        return ir.execute(self.db, plan, params)

    def prove(self, qname: str, params: dict) -> ProofBundle:
        return self.prove_plan(ir.build_plan(qname), params, name=qname)

    def prove_plan(self, plan: ir.Plan, params: dict,
                   name: str = None) -> ProofBundle:
        """Prove an explicit plan object (e.g. a compiled query).

        The bundle's ``query`` field is ``name`` (default ``plan.name``);
        the verifier re-resolves that name through
        :func:`~repro.core.ir.build_plan` — which consults registered plan
        resolvers, so a bundle may be named by a registered query or by a
        parseable query text — and checks the proof against *its own*
        resolution, never the prover's plan object."""
        run = self.run_plan(plan, params)
        steps = [self.prove_step(st) for st in run.steps]
        return ProofBundle(name if name is not None else plan.name,
                           dict(params), steps, run.result, self.cfg,
                           self.commitments.digest())

    # -- step-level prove entry points (the batcher's call surface) ----------
    def step_shape_key(self, st: ir.Step):
        """The batching key for one executed plan step: two steps with equal
        keys share circuit structure, prover config, and compute backend, so
        their witnesses can ride one lane-batched prove
        (:func:`repro.core.prover_batch.prove_batch`).  This is exactly the
        keygen-cache key — same Keys, same transcript schedule."""
        return self.cache._key(st.op, self.cfg)

    def prove_step(self, st: ir.Step) -> StepProof:
        """Prove one executed plan step solo (keygen-cached)."""
        self.cache.ensure(st.op, self.cfg)
        proof = st.op.prove(st.advice, st.instance, st.data)
        return StepProof(st.kind, st.shape, st.data_desc, st.instance, proof)

    def prove_steps(self, steps: list) -> list:
        """Prove same-shaped steps as ONE lane-batched pass.

        Every step must carry the same :meth:`step_shape_key` (asserted) —
        the lanes share Keys and per-phase dispatch, and each lane's proof
        bytes are identical to what :meth:`prove_step` would have produced
        for it alone.  One step degrades to the solo path."""
        if len(steps) == 1:
            return [self.prove_step(steps[0])]
        from . import prover_batch as pvb
        key0 = self.step_shape_key(steps[0])
        for st in steps[1:]:
            assert self.step_shape_key(st) == key0, \
                "prove_steps lanes must share one circuit shape"
        for st in steps:
            self.cache.ensure(st.op, self.cfg)
        keys = steps[0].op.keys
        proofs = pvb.prove_batch(
            keys, [(st.advice, st.instance, st.data) for st in steps],
            label=steps[0].op.name)
        return [StepProof(st.kind, st.shape, st.data_desc, st.instance, pf)
                for st, pf in zip(steps, proofs)]

    # -- verifier side ------------------------------------------------------
    def verify_bytes(self, raw: bytes,
                     commitments: CommitmentManifest = None) -> bool:
        """Decode + verify a serialized bundle; malformed bytes (including
        legacy pickle and version-mismatched encodings) are simply invalid —
        ``False``, never a crash, never code execution."""
        try:
            bundle = ProofBundle.from_bytes(raw)
        except WireFormatError:
            return False
        return self.verify(bundle, commitments)

    def verify(self, bundle: ProofBundle,
               commitments: CommitmentManifest = None) -> bool:
        """Check every step proof, its dataset-root binding, the published
        circuit geometry, the chained intermediate tables, and the claimed
        result.

        Base tables MUST match a published commitment (missing => raise) and
        their declared circuit geometry MUST match the published manifest
        (``manifest_pins`` + published-size membership) — neither is ever
        taken from prover-supplied data.  Only ``data_desc == "chained"``
        roots are recomputed, and then from the *verifier's own*
        re-derivation of the previous steps' outputs.
        """
        comms = commitments if commitments is not None else self.commitments
        if not isinstance(comms, CommitmentManifest):
            raise TypeError(
                "verification requires the owner's CommitmentManifest "
                "(publish_commitments); a bare root dict has no published "
                "geometry to pin circuit shapes against")
        if bundle.cfg != self.cfg:
            return False    # proof parameters below the session's policy
        # the bundle must have been proven against the SAME published
        # manifest this verifier bootstrapped trust from (for a transparency
        # bootstrap that digest is the log-included leaf): a missing or
        # mismatched digest fails closed before any proof work
        if bundle.manifest_digest is None or not np.array_equal(
                np.asarray(bundle.manifest_digest), comms.digest()):
            return False
        try:
            plan = ir.build_plan(bundle.query)
        except KeyError:
            return False    # unknown query name = invalid bundle
        if len(plan.nodes) != len(bundle.steps):
            return False
        env = ir.Env(dict(bundle.params))
        try:
            for node, rec in zip(plan.nodes, bundle.steps):
                ad = registry.adapter_for(node)
                if ad.name != rec.kind:
                    return False
                # all structural checks happen BEFORE any keygen work, so a
                # malformed bundle cannot make the verifier burn keygen cycles
                desc = ad.data_desc(node)       # the PLAN's binding, never
                if rec.data_desc != desc:       # the bundle's claim
                    return False
                try:                            # one schema check, shared
                    wire.check_shape_schema(rec.kind, rec.shape)
                except WireFormatError:         # with the wire decoder:
                    return False                # exact keys, bool is not int
                for k, v in ad.shape_flags(node).items():
                    if rec.shape.get(k) != v:   # semantic circuit flags are
                        return False            # pinned by the plan node
                n_rows = rec.shape.get("n_rows")
                if not isinstance(n_rows, int) or n_rows <= 0:
                    return False
                if desc == "chained":
                    # the chain glue: step k's table is re-derived from
                    # earlier verified outputs, and the declared shape must
                    # match that re-derivation exactly
                    if ad.shape(None, node, env) != rec.shape:
                        return False
                    cols = ad.chained_cols(node, env)
                    expected = commit.data_root(cols, n_rows, self.cfg,
                                                desc="chained")
                else:
                    # base tables: full circuit geometry is pinned against
                    # the PUBLISHED manifest (missing tables raise; tampered
                    # geometry over a published table is just invalid)
                    geo = comms.geometry(desc)
                    if n_rows not in geo.sizes:
                        return False
                    pins = ad.manifest_pins(node, env, comms, geo)
                    if any(rec.shape.get(k) != v for k, v in pins.items()):
                        return False
                    expected = comms.root(desc, n_rows)
                op = self.cache.ensure(
                    registry.build_operator(rec.kind, rec.shape), self.cfg)
                # the instance's public inputs must be the CLAIMED query's
                # (params + chained outputs), not whatever was proven
                if not ad.check_instance(op, rec.instance, node, env):
                    return False
                if not op.verify(rec.instance, rec.proof,
                                 expected_data_root=expected):
                    return False
                env.outputs.append(ad.extract_outputs(op, rec.instance))
            result = {k: ir.resolve(b, env) for k, b in plan.result.items()}
            return _results_equal(result, bundle.result)
        except MissingCommitmentError:
            raise                   # an owner/deployment problem, not a proof
        except (TypeError, KeyError, ValueError, AssertionError, IndexError):
            return False            # malformed bundle = invalid proof
