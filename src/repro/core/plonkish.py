"""PLONKish constraint system (Halo2-style) over BabyBear.

Column kinds (paper §II-B):
* fixed    — circuit structure: selectors, range tables, constants (public)
* advice   — private witness (phase 1)
* instance — public I/O (query results, claimed scalars)
* ext      — phase-2 Fp4 helper columns built by the framework itself:
             logUp running sums (buses) and running products (paper Eq. (2))

Arguments:
* gates           — custom polynomial constraints with rotations, degree <= blowup
* buses (logUp)   — lookups f ⊆ t with multiplicities AND multiset equality
                    (the workhorse for the paper's permutation arguments)
* grand products  — the paper's Eq. (2) running-product argument, verbatim
                    (kept both for fidelity and for the Table/figure benchmarks)

Tuple compression uses a random challenge α exactly as the paper's Eq. (1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field as dc_field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import field as F

_U32 = jnp.uint32

FIXED, ADVICE, INSTANCE, DATA = "fixed", "advice", "instance", "data"


# ---------------------------------------------------------------------------
# Expression DSL
# ---------------------------------------------------------------------------
class Expr:
    def __add__(self, other):
        return _Bin("add", self, _wrap(other))

    __radd__ = __add__

    def __sub__(self, other):
        return _Bin("sub", self, _wrap(other))

    def __rsub__(self, other):
        return _Bin("sub", _wrap(other), self)

    def __mul__(self, other):
        return _Bin("mul", self, _wrap(other))

    __rmul__ = __mul__

    def __neg__(self):
        return _Bin("sub", Const(0), self)

    # -- analysis ---------------------------------------------------------
    def degree(self) -> int:
        raise NotImplementedError

    def rotations(self) -> set:
        raise NotImplementedError

    def atoms(self) -> frozenset:
        """All :class:`Col` leaves (kind, index, rot included)."""
        raise NotImplementedError


def _wrap(x):
    if isinstance(x, Expr):
        return x
    return Const(int(x))


@dataclass(frozen=True)
class Const(Expr):
    value: int

    def degree(self):
        return 0

    def rotations(self):
        return set()

    def atoms(self):
        return frozenset()


@dataclass(frozen=True)
class Col(Expr):
    kind: str
    index: int
    rot: int = 0

    def rotate(self, k: int) -> "Col":
        return Col(self.kind, self.index, self.rot + k)

    def degree(self):
        return 1

    def rotations(self):
        return {(self.kind, self.index, self.rot)}

    def atoms(self):
        return frozenset({self})


@dataclass(frozen=True)
class _Bin(Expr):
    op: str
    a: Expr
    b: Expr

    def degree(self):
        if self.op == "mul":
            return self.a.degree() + self.b.degree()
        return max(self.a.degree(), self.b.degree())

    def rotations(self):
        return self.a.rotations() | self.b.rotations()

    def atoms(self):
        return self.a.atoms() | self.b.atoms()


def fixed(i, rot=0):
    return Col(FIXED, i, rot)


def advice(i, rot=0):
    return Col(ADVICE, i, rot)


def instance(i, rot=0):
    return Col(INSTANCE, i, rot)


# Field-generic evaluation ---------------------------------------------------
class BaseOps:
    """Fp ops over uint32 arrays."""
    add = staticmethod(F.fadd)
    sub = staticmethod(F.fsub)
    mul = staticmethod(F.fmul)

    @staticmethod
    def const(v, like):
        return jnp.full(jnp.shape(like), v % F.P, _U32)


class ExtOps:
    """Fp4 ops over (..., 4) arrays."""
    add = staticmethod(F.eadd)
    sub = staticmethod(F.esub)
    mul = staticmethod(F.emul)

    @staticmethod
    def const(v, like):
        out = jnp.zeros(jnp.shape(like), _U32)
        return out.at[..., 0].set(v % F.P)


def mul_factors(expr: Expr) -> list:
    """Flatten the top-level multiplication tree: the factors whose product
    is ``expr``.  Additions/subtractions are opaque (returned whole), so a
    guarded gate ``sel * body`` yields ``[sel, body]`` — the shape the
    analyzer uses to find pure-fixed selector guards."""
    if isinstance(expr, _Bin) and expr.op == "mul":
        return mul_factors(expr.a) + mul_factors(expr.b)
    return [expr]


def is_fixed_only(expr: Expr) -> bool:
    """True when every column the expression touches is a FIXED column —
    i.e. its row values are circuit structure, computable without a witness."""
    return all(a.kind == FIXED for a in expr.atoms())


def eval_fixed_np(expr: Expr, fixed_cols, n_rows: int) -> np.ndarray:
    """Evaluate a pure-fixed expression over all rows with plain numpy
    (int64 mod P).  Only valid when :func:`is_fixed_only` holds."""
    if isinstance(expr, Const):
        return np.full(n_rows, expr.value % F.P, np.int64)
    if isinstance(expr, Col):
        assert expr.kind == FIXED, f"eval_fixed_np hit a {expr.kind} column"
        return np.roll(np.asarray(fixed_cols[expr.index], np.int64), -expr.rot)
    assert isinstance(expr, _Bin)
    a = eval_fixed_np(expr.a, fixed_cols, n_rows)
    b = eval_fixed_np(expr.b, fixed_cols, n_rows)
    if expr.op == "add":
        return (a + b) % F.P
    if expr.op == "sub":
        return (a - b) % F.P
    return (a * b) % F.P


def eval_expr(expr: Expr, getter: Callable, ops, like):
    """Evaluate an expression tree. ``getter(kind, index, rot)`` returns the
    column evaluations; ``like`` is a template value for Const shaping."""
    if isinstance(expr, Const):
        return ops.const(expr.value, like)
    if isinstance(expr, Col):
        return getter(expr.kind, expr.index, expr.rot)
    assert isinstance(expr, _Bin)
    a = eval_expr(expr.a, getter, ops, like)
    b = eval_expr(expr.b, getter, ops, like)
    return getattr(ops, expr.op)(a, b)


# ---------------------------------------------------------------------------
# Argument specs
# ---------------------------------------------------------------------------
@dataclass
class Bus:
    """logUp bus:  sum_rows [ m_f/(β + α·f) − m_t/(β + α·t) ] == 0.

    With ``auto_multiplicity`` the framework counts how many times each
    t-tuple is matched by the (selected) f-tuples and fills m_t itself —
    then the bus is a *lookup* (f ⊆ t). With both multiplicities given as
    expressions and equal cardinality it is a *multiset equality* (the
    paper's permutation argument, Eq. (1)+(2) reformulated additively).
    """
    name: str
    f_tuple: Sequence[Expr]
    t_tuple: Sequence[Expr]
    m_f: Expr = Const(1)
    m_t: Optional[Expr] = None            # None => auto multiplicity column
    t_sel: Expr = Const(1)                # gates the valid t-side region
    auto_mult_col: int = -1               # advice col auto-allocated
    ext_col: int = -1                     # helper column index (set by circuit)

    def exprs(self) -> tuple:
        """Every base-column expression the bus constraint touches."""
        return (*self.f_tuple, *self.t_tuple, self.m_f, self.m_t, self.t_sel)


@dataclass
class GrandProduct:
    """The paper's Eq. (2) running-product permutation argument.

    Z[0] = 1;  Z[i+1] = Z[i] * (β + α·c1[i]) / (β + α·c2[i]) on selected rows
    (unselected rows contribute factor 1);  Z wraps to 1.
    Tuple compression via α per Eq. (1).
    """
    name: str
    c1_tuple: Sequence[Expr]
    c2_tuple: Sequence[Expr]
    sel1: Expr = Const(1)
    sel2: Expr = Const(1)
    ext_col: int = -1

    def exprs(self) -> tuple:
        """Every base-column expression the argument touches."""
        return (*self.c1_tuple, *self.c2_tuple, self.sel1, self.sel2)


@dataclass
class Circuit:
    n_rows: int
    name: str = "circuit"
    fixed_cols: list = dc_field(default_factory=list)     # list[np.ndarray (N,)]
    fixed_names: list = dc_field(default_factory=list)
    advice_names: list = dc_field(default_factory=list)
    instance_names: list = dc_field(default_factory=list)
    data_names: list = dc_field(default_factory=list)     # committed dataset cols
    gates: list = dc_field(default_factory=list)          # [(name, Expr)]
    buses: list = dc_field(default_factory=list)
    gps: list = dc_field(default_factory=list)
    _range_tables: dict = dc_field(default_factory=dict)  # bits -> fixed col idx
    # memoized session shape digest (SHA-256 over fixed cols + constraints);
    # invalidated by every structural mutation below — the keygen cache pays
    # the hash once per circuit object instead of once per ensure() call
    _shape_digest: Optional[str] = dc_field(
        default=None, repr=False, compare=False)

    def _mutated(self):
        self._shape_digest = None

    # -- column allocation --------------------------------------------------
    def add_fixed(self, name: str, values) -> Col:
        self._mutated()
        vals = np.zeros(self.n_rows, np.uint32)
        arr = np.asarray(values, np.int64) % F.P
        vals[: len(arr)] = arr.astype(np.uint32)
        self.fixed_cols.append(vals)
        self.fixed_names.append(name)
        return Col(FIXED, len(self.fixed_cols) - 1)

    def add_advice(self, name: str) -> Col:
        self._mutated()
        self.advice_names.append(name)
        return Col(ADVICE, len(self.advice_names) - 1)

    def add_instance(self, name: str) -> Col:
        self._mutated()
        self.instance_names.append(name)
        return Col(INSTANCE, len(self.instance_names) - 1)

    def add_data(self, name: str) -> Col:
        """Private dataset column: committed in its own tree whose root is the
        paper's 'declared dataset' commitment (verifier compares roots)."""
        self._mutated()
        self.data_names.append(name)
        return Col(DATA, len(self.data_names) - 1)

    # -- constraints ----------------------------------------------------------
    def add_gate(self, name: str, expr: Expr, max_degree: int = 4):
        self._mutated()
        d = expr.degree()
        assert d <= max_degree, f"gate {name} degree {d} > {max_degree}"
        self.gates.append((name, expr))

    def add_bus(self, name, f_tuple, t_tuple, m_f=Const(1), m_t=None,
                t_sel=Const(1)) -> Bus:
        self._mutated()
        bus = Bus(name, tuple(f_tuple), tuple(t_tuple), m_f, m_t, t_sel)
        if m_t is None:
            col = self.add_advice(f"{name}/mult")
            bus.auto_mult_col = col.index
            bus.m_t = col
        self.buses.append(bus)
        return bus

    def add_multiset_equal(self, name, tuple_a, sel_a, tuple_b, sel_b):
        """Paper §IV-A 'Edge Correctness': multiset {a | sel_a} == {b | sel_b}."""
        return self.add_bus(name, tuple_a, tuple_b, m_f=sel_a, m_t=sel_b)

    def add_grand_product(self, name, c1, c2, sel1=Const(1), sel2=Const(1)):
        self._mutated()
        gp = GrandProduct(name, tuple(c1), tuple(c2), sel1, sel2)
        self.gps.append(gp)
        return gp

    def add_range_check(self, name: str, expr: Expr, bits: int,
                        sel: Optional[Expr] = None):
        """expr ∈ [0, 2^bits) via limb decomposition + table lookups.

        Limb width adapts to the circuit size (table must fit in n_rows).
        ``sel`` (degree ≤ 1) gates the check to a region: unselected rows may
        hold arbitrary expr values with zero limbs. Returns the advice limb
        columns the witness builder must fill — use :func:`fill_range_limbs`.
        """
        limb_bits = min(16, max(1, int(math.log2(self.n_rows))))
        n_limbs = (bits + limb_bits - 1) // limb_bits
        table_col = self._range_table(limb_bits)
        limbs = []
        acc: Expr = Const(0)
        shift = 1
        for j in range(n_limbs):
            c = self.add_advice(f"{name}/limb{j}")
            limbs.append(c)
            acc = acc + Const(shift) * c
            shift = (shift << limb_bits) % F.P
            self.add_bus(f"{name}/limb{j}/range", [c], [table_col],
                         m_f=sel if sel is not None else Const(1))
        recompose = acc - expr
        if sel is not None:
            recompose = sel * recompose
        self.add_gate(f"{name}/recompose", recompose)
        return limbs, limb_bits

    def _range_table(self, limb_bits: int) -> Col:
        if limb_bits in self._range_tables:
            return Col(FIXED, self._range_tables[limb_bits])
        size = 1 << limb_bits
        assert size <= self.n_rows, "range table exceeds circuit rows"
        col = self.add_fixed(f"range{limb_bits}", np.arange(size))
        self._range_tables[limb_bits] = col.index
        return col

    # -- metadata -------------------------------------------------------------
    @property
    def n_fixed(self):
        return len(self.fixed_cols)

    @property
    def n_advice(self):
        return len(self.advice_names)

    @property
    def n_instance(self):
        return len(self.instance_names)

    @property
    def n_data(self):
        return len(self.data_names)

    @property
    def n_ext(self):
        return len(self.buses) + len(self.gps)

    def assign_ext_cols(self):
        i = 0
        for b in self.buses:
            b.ext_col = i
            i += 1
        for g in self.gps:
            g.ext_col = i
            i += 1

    def constraint_exprs(self):
        """Iterate ``(kind, name, exprs)`` over every constraint — the one
        enumeration the analyzer, opening schedule, and rotation set share.
        ``kind`` is "gate" / "bus" / "gp"; ``exprs`` is the tuple of
        base-column expressions the constraint evaluates."""
        for name, e in self.gates:
            yield "gate", name, (e,)
        for b in self.buses:
            yield "bus", b.name, b.exprs()
        for g in self.gps:
            yield "gp", g.name, g.exprs()

    def rotation_set(self) -> set:
        """All (kind, col, rot) base-column accesses + ext rotations {0,1}."""
        rots = set()
        for _, _, exprs in self.constraint_exprs():
            for e in exprs:
                rots |= e.rotations()
        return rots

    def referenced_cols(self) -> dict:
        """kind -> set of column indices appearing in any constraint."""
        refs = {FIXED: set(), ADVICE: set(), INSTANCE: set(), DATA: set()}
        for k, i, _ in self.rotation_set():
            refs[k].add(i)
        return refs

    def gate_info(self) -> list:
        """Per-gate metadata for analysis/reporting: name, AST degree, and
        the rotation accesses it performs."""
        return [dict(name=name, degree=e.degree(),
                     rotations=sorted(e.rotations()))
                for name, e in self.gates]

    def digest_seed(self) -> list:
        """Cheap structural fingerprint absorbed into the transcript."""
        return [self.n_rows, self.n_fixed, self.n_advice, self.n_instance,
                self.n_data, len(self.gates), len(self.buses), len(self.gps),
                sum(ord(c) for c in self.name) % F.P]


# ---------------------------------------------------------------------------
# Witness-side helpers (prover only, vectorized)
# ---------------------------------------------------------------------------
def fill_range_limbs(advice: np.ndarray, limbs, limb_bits: int, values: np.ndarray):
    """Fill limb advice columns for add_range_check."""
    v = np.asarray(values, np.int64).copy()
    assert (v >= 0).all(), "range witness negative"
    for c in limbs:
        advice[c.index, : len(v)] = v & ((1 << limb_bits) - 1)
        v >>= limb_bits
    assert (v == 0).all(), "range witness overflows declared bits"


def compress_tuple(vals: Sequence[jnp.ndarray], alpha: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. (1) generalized: Σ_j α^j v_j (Fp inputs, Fp4 output)."""
    acc = F.ext(vals[0])
    apow = alpha
    for v in vals[1:]:
        acc = F.eadd(acc, F.emul(apow, F.ext(v)))
        apow = F.emul(apow, alpha)
    return acc
