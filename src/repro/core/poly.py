"""Polynomial arithmetic over BabyBear: radix-2 NTT, coset LDE, evaluation.

The NTT is the prover's compute hot-spot (together with Merkle hashing); the
Pallas kernel in ``repro.kernels.ntt`` implements the same butterfly schedule
with explicit VMEM BlockSpecs.  :func:`ntt` dispatches through the active
compute backend (:mod:`repro.core.backend`); :func:`ntt_ref` is the pure-jnp
oracle and the ``ref`` (CPU default) path.  Backends are bit-identical, so
``coset_lde``/``intt`` and everything built on them (commitments, quotient,
FRI folds) are backend-independent.

Domain conventions
------------------
* ``H_n``     : multiplicative subgroup of size n (powers of w_n, natural order)
* coset LDE   : evaluations on ``shift * H_{n*blowup}``
* evaluation order is *natural* (index i ↦ shift * w^i), not bit-reversed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import backend
from . import field as F

_U32 = jnp.uint32

# default coset shift for LDEs: the field generator (not in any small H)
COSET_SHIFT = F.GENERATOR


@functools.lru_cache(maxsize=None)
def _bitrev_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    rev = np.zeros_like(idx)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


@functools.lru_cache(maxsize=None)
def _stage_twiddles(n: int, inverse: bool) -> tuple[np.ndarray, ...]:
    """Per-stage twiddle tables for DIT butterflies, stage m = 1,2,4,...,n/2."""
    root = F.root_of_unity(n)
    if inverse:
        root = pow(root, F.P - 2, F.P)
    tables = []
    m = 1
    while m < n:
        w_m = pow(root, n // (2 * m), F.P)     # order 2m
        tw = np.ones(m, np.uint64)
        for j in range(1, m):
            tw[j] = tw[j - 1] * w_m % F.P
        tables.append(tw.astype(np.uint32))
        m *= 2
    return tuple(tables)


def ntt(a: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """Radix-2 DIT NTT along the last axis (length must be a power of two).

    Natural-order input -> natural-order output. ``inverse=True`` gives the
    inverse transform including the 1/n scaling.  Dispatches to the active
    compute backend (bit-identical across backends)."""
    return backend.active().ntt(a, inverse=inverse)


@functools.partial(jax.jit, static_argnames=("inverse",))
def ntt_ref(a: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """The pure-jnp reference NTT (the ``ref`` backend, and the oracle the
    Pallas stage kernel is validated against)."""
    n = a.shape[-1]
    if n == 1:
        return a
    a = a[..., jnp.asarray(_bitrev_perm(n))]
    tables = _stage_twiddles(n, inverse)
    batch = a.shape[:-1]
    m = 1
    for tw in tables:
        a = a.reshape(batch + (n // (2 * m), 2, m))
        even = a[..., 0, :]
        odd = F.fmul(a[..., 1, :], jnp.asarray(tw))
        a = jnp.stack([F.fadd(even, odd), F.fsub(even, odd)], axis=-2)
        m *= 2
    a = a.reshape(batch + (n,))
    if inverse:
        n_inv = pow(n, F.P - 2, F.P)
        a = F.fmul(a, _U32(n_inv))
    return a


def intt(a: jnp.ndarray) -> jnp.ndarray:
    return ntt(a, inverse=True)


def coset_lde(evals: jnp.ndarray, blowup: int, shift: int = COSET_SHIFT) -> jnp.ndarray:
    """Given evaluations on H_n (natural order), return evaluations on
    ``shift * H_{n*blowup}`` (natural order). Last-axis transform."""
    n = evals.shape[-1]
    coeffs = intt(evals)
    # scale c_i by shift^i, zero-pad to N = n * blowup
    powers = np.ones(n, np.uint64)
    for i in range(1, n):
        powers[i] = powers[i - 1] * shift % F.P
    coeffs = F.fmul(coeffs, jnp.asarray(powers.astype(np.uint32)))
    pad = [(0, 0)] * (coeffs.ndim - 1) + [(0, n * (blowup - 1))]
    coeffs = jnp.pad(coeffs, pad)
    return ntt(coeffs)


def coeffs_from_evals(evals: jnp.ndarray) -> jnp.ndarray:
    return intt(evals)


def coset_coeffs(evals: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Interpolate coefficients from evaluations on ``shift * H_n``."""
    n = evals.shape[-1]
    coeffs = intt(evals)
    s_inv = pow(shift, F.P - 2, F.P)
    powers = np.ones(n, np.uint64)
    for i in range(1, n):
        powers[i] = powers[i - 1] * s_inv % F.P
    return F.fmul(coeffs, jnp.asarray(powers.astype(np.uint32)))


@jax.jit
def eval_at_ext(coeffs: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Horner-evaluate an Fp-coefficient polynomial at an Fp4 point ``z``.

    coeffs: (..., n) Fp; z: (4,) Fp4. Returns (..., 4).
    Uses a power-table + dot to stay vectorized: sum_i c_i * z^i.
    """
    n = coeffs.shape[-1]
    # z powers: (n, 4)
    def step(carry, _):
        nxt = F.emul(carry, z)
        return nxt, carry
    one = jnp.asarray(F.EXT_ONE)
    _, zpows = jax.lax.scan(step, one, None, length=n)
    # sum_i c_i * zpows[i]: (..., n, 1) * (n, 4) -> mod-P dot
    prod = F.fmul(coeffs[..., None].astype(_U32), zpows)      # (..., n, 4)
    # modular sum along axis -2 (values < P; sum in uint64 then reduce)
    s = jnp.sum(prod.astype(jnp.uint64), axis=-2) % jnp.uint64(F.P)
    return s.astype(_U32)


def domain_points(n: int, shift: int = 1) -> jnp.ndarray:
    """Natural-order points of shift * H_n as Fp array."""
    w = F.root_of_unity(n)
    pts = np.ones(n, np.uint64)
    for i in range(1, n):
        pts[i] = pts[i - 1] * w % F.P
    pts = pts * shift % F.P
    return jnp.asarray(pts.astype(np.uint32))


def naive_dft(a: np.ndarray, inverse: bool = False) -> np.ndarray:
    """O(n^2) reference DFT (numpy, python ints) for testing."""
    n = len(a)
    root = F.root_of_unity(n)
    if inverse:
        root = pow(root, F.P - 2, F.P)
    out = np.zeros(n, np.uint32)
    for k in range(n):
        acc = 0
        wk = pow(root, k, F.P)
        x = 1
        for i in range(n):
            acc = (acc + int(a[i]) * x) % F.P
            x = x * wk % F.P
        if inverse:
            acc = acc * pow(n, F.P - 2, F.P) % F.P
        out[k] = acc
    return out
