"""Crash-safe, append-only file store for the transparency log.

PR 3's :class:`~repro.core.transparency.TransparencyLog` was in-process
only: every checkpoint, inclusion proof, and equivocation check evaporated
when the owner process exited.  This module is the durable backing store the
deployment story needs (cf. the durable gossiped log heads assumed by
transparency-backed verifiable search systems): a single append-only file of
length-prefixed, CRC-framed records that survives ``kill -9`` mid-append.

On-disk format (normative spec: ``docs/protocol.md`` §9)::

    file    := STORE_MAGIC(8) record*
    record  := kind:u8 length:u32 payload[length] crc32:u32
    crc32   := zlib.crc32(offset:u64 || kind || length || payload)

where ``offset`` is the record's absolute file offset: records are
**position-bound**, so bytes that merely *contain* a framed record (an
entry payload may be anything, including another store's bytes) can never
masquerade as a record at a different offset — which is what keeps the
torn-tail/corruption classification below sound.

Record kinds:

* ``REC_ORIGIN`` (0) — utf-8 log origin; exactly one, always first.
* ``REC_ENTRY`` (1) — one log leaf: the canonical manifest bytes, verbatim.
* ``REC_CHECKPOINT`` (2) — a wire kind-5 :class:`Checkpoint` message the
  owner persisted after appending; on replay every stored checkpoint's root
  is **re-derived from the entries and cross-checked** — a mismatch is
  evidence of tampering (or an equivocating rewrite) and raises
  :class:`LogStoreError` rather than being repaired.

Crash semantics: every append is ``write + flush + fsync`` (and the parent
directory is fsync'd at creation), so an acknowledged append survives a
crash.  A crash *during* an append leaves a torn tail record; recovery
(:func:`replay`) detects it — short header, unknown kind, oversized length,
truncated payload, or CRC mismatch — and :meth:`DurableTransparencyLog.open`
truncates the file back to the last intact record.  Because the file is
append-only, a valid-prefix/torn-suffix is the *only* state a crash can
produce; anything else (bad magic, a checkpoint whose root does not match
the re-derived tree) is corruption and fails closed.

``TransparencyLog.open(path)`` is the front door (it delegates here);
``.sync()`` re-replays the on-disk bytes and cross-checks them against the
in-memory tree, so a long-lived owner can audit its own durability at any
point.
"""
from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path

import numpy as np

from .transparency import Checkpoint, TransparencyError, TransparencyLog

STORE_MAGIC = b"ZKGLSTR1"       # 8 bytes; versioned by the trailing digit

REC_ORIGIN = 0
REC_ENTRY = 1
REC_CHECKPOINT = 2
_KNOWN_KINDS = (REC_ORIGIN, REC_ENTRY, REC_CHECKPOINT)

_HDR = struct.Struct("<BI")     # kind:u8 length:u32
_CRC = struct.Struct("<I")
MAX_RECORD = 1 << 24            # a torn length prefix never allocates > 16 MiB


class LogStoreError(TransparencyError):
    """The on-disk log is corrupt beyond crash semantics: bad magic, a
    mid-file record that fails framing, or a stored checkpoint whose root
    does not match the tree re-derived from the stored entries.  Recovery
    repairs torn *tails* only; everything else fails closed."""


def _crc(offset: int, kind: int, payload: bytes) -> int:
    return zlib.crc32(struct.pack("<Q", offset)
                      + _HDR.pack(kind, len(payload)) + payload)


def _fsync_dir(path: Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return                  # platform without directory fds
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def frame_record(kind: int, payload: bytes, offset: int) -> bytes:
    """The exact bytes one record occupies on disk, position-bound to the
    file ``offset`` where it will be written."""
    if kind not in _KNOWN_KINDS:
        raise LogStoreError(f"unknown record kind {kind}")
    payload = bytes(payload)
    if len(payload) > MAX_RECORD:
        raise LogStoreError(
            f"record payload {len(payload)} bytes > {MAX_RECORD}")
    return _HDR.pack(kind, len(payload)) + payload \
        + _CRC.pack(_crc(int(offset), kind, payload))


def replay(raw: bytes):
    """Parse store bytes -> ``(origin, entries, checkpoints, intact_size)``.

    ``entries`` are the raw leaf byte strings in append order;
    ``checkpoints`` are ``(entry_count_at_record, Checkpoint)`` pairs in the
    order stored.  ``intact_size`` is the byte offset of the first torn
    record (== ``len(raw)`` when the tail is clean) — the caller truncates
    there.  Raises :class:`LogStoreError` on non-crash corruption (bad
    magic, or a framing failure that is *followed by* further intact
    records, which a torn tail cannot produce).
    """
    if len(raw) < len(STORE_MAGIC):
        if raw and not STORE_MAGIC.startswith(bytes(raw)):
            raise LogStoreError(
                f"not a zkgraph log store (bad magic {bytes(raw[:8])!r})")
        return None, [], [], 0          # empty / torn header: fresh store
    if raw[: len(STORE_MAGIC)] != STORE_MAGIC:
        raise LogStoreError(
            f"not a zkgraph log store (bad magic {raw[:8]!r})")
    origin = None
    entries, checkpoints = [], []
    pos = len(STORE_MAGIC)
    while pos < len(raw):
        torn = _parse_record(raw, pos)
        if torn is None:
            break
        kind, payload, end = torn
        if kind == REC_ORIGIN:
            if origin is not None or entries or checkpoints:
                raise LogStoreError(
                    "origin record must appear exactly once, first")
            origin = payload.decode("utf-8")
        elif origin is None:
            raise LogStoreError(
                "first record must be the origin record")
        elif kind == REC_ENTRY:
            entries.append(payload)
        else:
            from . import wire
            try:
                cp = wire.decode_checkpoint(payload)
            except wire.WireFormatError as e:
                raise LogStoreError(
                    f"stored checkpoint record is malformed: {e}") from None
            checkpoints.append((len(entries), cp))
        pos = end
    if pos < len(raw) and _any_intact_record_after(raw, pos):
        raise LogStoreError(
            f"record at offset {pos} is corrupt but later records are "
            f"intact — this is not a torn tail; refusing to repair")
    return origin, entries, checkpoints, pos


def _parse_record(raw: bytes, pos: int):
    """One record at ``pos`` -> ``(kind, payload, end)``, or ``None`` if the
    bytes from ``pos`` do not frame an intact record *for that offset*
    (torn tail, or record-looking bytes that were never written there)."""
    if pos + _HDR.size > len(raw):
        return None
    kind, length = _HDR.unpack_from(raw, pos)
    if kind not in _KNOWN_KINDS or length > MAX_RECORD:
        return None
    end = pos + _HDR.size + length + _CRC.size
    if end > len(raw):
        return None
    payload = raw[pos + _HDR.size: pos + _HDR.size + length]
    (crc,) = _CRC.unpack_from(raw, end - _CRC.size)
    if crc != _crc(pos, kind, payload):
        return None
    return kind, bytes(payload), end


def _any_intact_record_after(raw: bytes, torn_at: int) -> bool:
    """Scan byte-by-byte past a torn record: a crash can only tear the
    *last* record, so any intact frame after the tear means corruption.
    Sound because records are position-bound (the CRC covers the offset):
    a framed record *embedded in* a torn payload was CRC'd for offset 0 of
    its own store, not for the absolute offset it happens to sit at here,
    so it cannot false-positive this scan."""
    pos = torn_at + 1
    while pos < len(raw):
        if _parse_record(raw, pos) is not None:
            return True
        pos += 1
    return False


class DurableTransparencyLog(TransparencyLog):
    """A :class:`TransparencyLog` whose every append is persisted, fsync'd,
    and periodically checkpointed to one append-only file.

    Use :meth:`open` (or the ``TransparencyLog.open`` front door) — it
    creates the store, or replays an existing one: torn tails are truncated
    back to the last intact record and every stored checkpoint's root is
    re-derived from the entries and cross-checked before anything is
    trusted.
    """

    def __init__(self, path, origin: str = "zkgraph-log",
                 checkpoint_every: int = 1):
        super().__init__(origin)
        self.path = Path(path)
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.recovered_bytes = 0     # torn-tail bytes truncated at open()
        self._fh = None
        self._offset = 0             # next record's file offset (CRC-bound)
        self._since_checkpoint = 0

    # -- opening / recovery -------------------------------------------------
    @classmethod
    def open(cls, path, origin: str = None,
             checkpoint_every: int = 1) -> "DurableTransparencyLog":
        """Open (or create) the store at ``path`` and replay it.

        ``origin=None`` adopts the stored origin (new stores default to
        ``"zkgraph-log"``); passing an origin that contradicts the stored
        one raises — a caller must never silently gossip under the wrong
        log identity.
        """
        path = Path(path)
        raw = path.read_bytes() if path.exists() else b""
        stored_origin, entries, checkpoints, intact = replay(raw)
        if origin is not None and stored_origin is not None \
                and origin != stored_origin:
            raise LogStoreError(
                f"store at {path} belongs to log {stored_origin!r}, "
                f"not {origin!r}")
        log = cls(path, origin or stored_origin or "zkgraph-log",
                  checkpoint_every)
        for entry in entries:
            TransparencyLog.append(log, entry)      # memory only: replaying
        _cross_check(log, checkpoints, path)
        log._since_checkpoint = log.size - (checkpoints[-1][0]
                                            if checkpoints else 0)
        if intact < len(raw):
            log.recovered_bytes = len(raw) - intact
            with open(path, "r+b") as fh:
                fh.truncate(intact)
                fh.flush()
                os.fsync(fh.fileno())
        log._fh = open(path, "ab")
        log._offset = intact
        if stored_origin is None:
            # brand-new store, or one whose very first (origin) record was
            # torn by a crash during creation: (re)initialize the header
            prefix = STORE_MAGIC if intact < len(STORE_MAGIC) else b""
            origin_at = len(STORE_MAGIC)
            log._write(prefix + frame_record(
                REC_ORIGIN, log.origin.encode("utf-8"), origin_at))
            _fsync_dir(path.resolve().parent)
        return log

    @property
    def last_stored_checkpoint(self) -> Checkpoint:
        """The newest checkpoint covered by a persisted checkpoint record
        (what a reader that trusts only fsync'd checkpoints would pin)."""
        covered = self.size - self._since_checkpoint
        if covered <= 0:
            return None
        return self.checkpoint(covered)

    # -- writing ------------------------------------------------------------
    def append(self, manifest) -> Checkpoint:
        """Durable append: the entry record (and, every
        ``checkpoint_every`` appends, a checkpoint record) is written and
        fsync'd *before* the new checkpoint is returned — an acknowledged
        append survives ``kill -9``.  Entry and checkpoint go down in ONE
        write + fsync (entry bytes first): same crash semantics as two —
        any partial pair is a torn tail recovery truncates — at half the
        fsync cost on the default ``checkpoint_every=1`` hot path."""
        if self._fh is None:
            raise LogStoreError(
                "log store is closed (or poisoned by a failed write); "
                "reopen it to recover")
        raw = manifest if isinstance(manifest, (bytes, bytearray)) \
            else manifest.to_bytes()
        raw = bytes(raw)
        cp = TransparencyLog.append(self, raw)
        framed = frame_record(REC_ENTRY, raw, self._offset)
        since = self._since_checkpoint + 1
        if since >= self.checkpoint_every:
            framed += frame_record(REC_CHECKPOINT, cp.to_bytes(),
                                   self._offset + len(framed))
            since = 0
        try:
            self._write(framed)
        except Exception:
            self._rollback_append()     # memory never runs ahead of disk
            raise
        self._since_checkpoint = since
        return cp

    def _write(self, framed: bytes) -> None:
        """One durable write.  On ANY failure (disk full, I/O error) the
        store is poisoned — the file may hold partially-written bytes at an
        unknowable offset, so framing further records against ``_offset``
        would produce CRCs that replay classifies as a torn tail and
        silently truncates, losing acknowledged appends.  Reopening replays
        and truncates the partial bytes, which is the only safe recovery."""
        try:
            self._fh.write(framed)
            self._fh.flush()
            os.fsync(self._fh.fileno())
        except Exception:
            try:
                self._fh.close()
            except Exception:   # the original failure is what matters
                pass
            self._fh = None
            raise
        self._offset += len(framed)

    def _rollback_append(self) -> None:
        """Undo the in-memory append after its durable write failed."""
        self._leaves.pop()
        self._entries.pop()
        n = len(self._leaves)
        self._memo = {k: v for k, v in self._memo.items() if k[1] <= n}

    # -- auditing -----------------------------------------------------------
    def sync(self) -> Checkpoint:
        """Replay the on-disk bytes and cross-check them against memory.

        Re-derives the Merkle root of every stored checkpoint from the
        stored entries, then requires the replayed tree to match this
        process's in-memory tree byte for byte (size, root, and raw
        entries).  Any divergence — external truncation, a flipped byte
        that survived CRC odds, a checkpoint forged onto the file — raises
        :class:`LogStoreError`.  Returns the current head checkpoint."""
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
        raw = self.path.read_bytes()
        origin, entries, checkpoints, intact = replay(raw)
        if intact < len(raw):
            raise LogStoreError(
                f"store at {self.path} has a torn tail while the writer is "
                f"live — another process truncated or wrote it")
        if origin != self.origin:
            raise LogStoreError(
                f"stored origin {origin!r} != in-memory {self.origin!r}")
        if len(entries) != self.size or any(
                stored != self.entry(i) for i, stored in enumerate(entries)):
            raise LogStoreError(
                f"stored entries diverge from memory "
                f"({len(entries)} on disk vs {self.size} in memory)")
        shadow = TransparencyLog(self.origin)
        for entry in entries:
            shadow.append(entry)
        _cross_check(shadow, checkpoints, self.path)
        if self.size and not np.array_equal(shadow.root(), self.root()):
            raise LogStoreError("replayed root diverges from memory")
        return self.checkpoint()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "DurableTransparencyLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _cross_check(log: TransparencyLog, checkpoints, path) -> None:
    """Every stored checkpoint's root must equal the root re-derived from
    the stored entries at that size — the replay-time audit that makes a
    checkpoint record a *cross-check*, never a trusted input."""
    for entry_count, cp in checkpoints:
        if cp.origin != log.origin:
            raise LogStoreError(
                f"store at {path}: checkpoint origin {cp.origin!r} != "
                f"log origin {log.origin!r}")
        if not 0 < cp.tree_size <= entry_count:
            raise LogStoreError(
                f"store at {path}: checkpoint covers {cp.tree_size} leaves "
                f"but only {entry_count} entries precede it")
        derived = log.root(cp.tree_size)
        if not np.array_equal(derived, cp.root):
            raise LogStoreError(
                f"store at {path}: stored checkpoint root at size "
                f"{cp.tree_size} does not match the root re-derived from "
                f"the stored entries — the store was tampered with")
