"""Fiat-Shamir transcript: a sponge over the Poseidon-like permutation.

Prover and verifier run the identical absorb/squeeze schedule; challenges are
Fp4 elements (4 squeezed lanes, ~124-bit challenge space) or query indices.
Runs eagerly on small host arrays (numpy) — it is not a jit hot path.
"""
from __future__ import annotations

import numpy as np

from . import field as F
from . import hashing as H


class Transcript:
    def __init__(self, label: str = "zkgraph"):
        self._state = np.zeros(H.WIDTH, np.uint32)
        self._absorbed: list[int] = []
        self.absorb_bytes(label.encode())

    # -- absorption ---------------------------------------------------------
    def absorb_bytes(self, data: bytes):
        vals = np.frombuffer(data.ljust((len(data) + 3) // 4 * 4, b"\0"), np.uint32)
        self.absorb(vals % np.uint32(F.P))

    def absorb(self, values):
        """values: array-like of field elements (flattened)."""
        vals = np.asarray(values, np.uint64).reshape(-1) % np.uint64(F.P)
        self._absorbed.extend(int(v) for v in vals)
        # absorb in RATE-sized blocks with permutation between blocks
        vals = vals.astype(np.uint32)
        pos = 0
        while pos < len(vals):
            blk = vals[pos:pos + H.RATE]
            st = self._state.copy()
            st[:len(blk)] = (st[:len(blk)].astype(np.uint64) + blk) % np.uint64(F.P)
            self._state = np.asarray(H.permute(st[None])[0])
            pos += H.RATE

    def absorb_digest(self, digest):
        self.absorb(np.asarray(digest))

    # -- squeezing ----------------------------------------------------------
    def _squeeze_lanes(self, k: int) -> np.ndarray:
        out = []
        while len(out) < k:
            out.extend(self._state[:H.RATE].tolist())
            self._state = np.asarray(H.permute(self._state[None])[0])
        return np.asarray(out[:k], np.uint32)

    def challenge_ext(self) -> np.ndarray:
        """One Fp4 challenge, shape (4,) uint32."""
        return self._squeeze_lanes(4)

    def challenge_fp(self) -> int:
        return int(self._squeeze_lanes(1)[0])

    def challenge_indices(self, n: int, domain_size: int) -> np.ndarray:
        """n query indices in [0, domain_size) (power of two)."""
        lanes = self._squeeze_lanes(n)
        return (lanes % np.uint32(domain_size)).astype(np.int64)
