"""Fiat-Shamir transcript: a sponge over the Poseidon-like permutation.

Prover and verifier run the identical absorb/squeeze schedule; challenges are
Fp4 elements (4 squeezed lanes, ~124-bit challenge space) or query indices.
Runs eagerly on small host arrays (numpy) — it is not a jit hot path.
"""
from __future__ import annotations

import numpy as np

from . import field as F
from . import hashing as H


class Transcript:
    def __init__(self, label: str = "zkgraph"):
        self._state = np.zeros(H.WIDTH, np.uint32)
        self._absorbed: list[int] = []
        self.absorb_bytes(label.encode())

    # -- absorption ---------------------------------------------------------
    def absorb_bytes(self, data: bytes):
        vals = np.frombuffer(data.ljust((len(data) + 3) // 4 * 4, b"\0"), np.uint32)
        self.absorb(vals % np.uint32(F.P))

    def absorb(self, values):
        """values: array-like of field elements (flattened)."""
        vals = np.asarray(values, np.uint64).reshape(-1) % np.uint64(F.P)
        self._absorbed.extend(int(v) for v in vals)
        # absorb in RATE-sized blocks with permutation between blocks
        vals = vals.astype(np.uint32)
        pos = 0
        while pos < len(vals):
            blk = vals[pos:pos + H.RATE]
            st = self._state.copy()
            st[:len(blk)] = (st[:len(blk)].astype(np.uint64) + blk) % np.uint64(F.P)
            self._state = np.asarray(H.permute(st[None])[0])
            pos += H.RATE

    def absorb_digest(self, digest):
        self.absorb(np.asarray(digest))

    # -- squeezing ----------------------------------------------------------
    def _squeeze_lanes(self, k: int) -> np.ndarray:
        out = []
        while len(out) < k:
            out.extend(self._state[:H.RATE].tolist())
            self._state = np.asarray(H.permute(self._state[None])[0])
        return np.asarray(out[:k], np.uint32)

    def challenge_ext(self) -> np.ndarray:
        """One Fp4 challenge, shape (4,) uint32."""
        return self._squeeze_lanes(4)

    def challenge_fp(self) -> int:
        return int(self._squeeze_lanes(1)[0])

    def challenge_indices(self, n: int, domain_size: int) -> np.ndarray:
        """n query indices in [0, domain_size) (power of two)."""
        lanes = self._squeeze_lanes(n)
        return (lanes % np.uint32(domain_size)).astype(np.int64)


class BatchedTranscript:
    """``lanes`` independent Fiat-Shamir transcripts advanced in lockstep.

    Same-shaped proofs follow the *identical* absorb/squeeze schedule — only
    the absorbed values differ per lane — so a batch of them can share every
    permutation dispatch: the states are an ``(L, 16)`` matrix and each
    sponge block is ONE batched :func:`hashing.permute` call instead of L.

    Bit-identity invariant (asserted by ``tests/test_serve.py``): lane ``l``
    of this object, fed lane ``l``'s values, produces exactly the state
    sequence of a solo :class:`Transcript` fed the same values — ``permute``
    is row-independent under every compute backend, and the block schedule
    below mirrors :meth:`Transcript.absorb` verbatim.
    """

    def __init__(self, label: str = "zkgraph", lanes: int = 1):
        self.lanes = lanes
        self._state = np.zeros((lanes, H.WIDTH), np.uint32)
        vals = np.frombuffer(
            label.encode().ljust((len(label.encode()) + 3) // 4 * 4, b"\0"),
            np.uint32)
        self.absorb_shared(vals % np.uint32(F.P))

    # -- absorption ---------------------------------------------------------
    def absorb(self, values):
        """values: array-like reshapable to (lanes, m) field elements."""
        vals = np.asarray(values, np.uint64).reshape(self.lanes, -1) \
            % np.uint64(F.P)
        vals = vals.astype(np.uint32)
        pos = 0
        while pos < vals.shape[1]:
            blk = vals[:, pos:pos + H.RATE]
            st = self._state.copy()
            st[:, :blk.shape[1]] = (
                st[:, :blk.shape[1]].astype(np.uint64) + blk
            ) % np.uint64(F.P)
            self._state = np.asarray(H.permute(st))
            pos += H.RATE

    def absorb_shared(self, values):
        """Absorb the same flat values into every lane (circuit digests,
        shared labels — anything lane-independent)."""
        v = np.asarray(values, np.uint64).reshape(-1)
        self.absorb(np.broadcast_to(v, (self.lanes, v.size)))

    def absorb_digest(self, digests):
        """digests: (lanes, 8) — one Merkle root per lane."""
        self.absorb(np.asarray(digests))

    # -- squeezing ----------------------------------------------------------
    def _squeeze_lanes(self, k: int) -> np.ndarray:
        out = []
        got = 0
        while got < k:
            out.append(self._state[:, :H.RATE].copy())
            self._state = np.asarray(H.permute(self._state))
            got += H.RATE
        return np.concatenate(out, axis=1)[:, :k].astype(np.uint32)

    def challenge_ext(self) -> np.ndarray:
        """One Fp4 challenge per lane, shape (lanes, 4) uint32."""
        return self._squeeze_lanes(4)

    def challenge_indices(self, n: int, domain_size: int) -> np.ndarray:
        """(lanes, n) query indices in [0, domain_size) (power of two)."""
        lanes = self._squeeze_lanes(n)
        return (lanes % np.uint32(domain_size)).astype(np.int64)
