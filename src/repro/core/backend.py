"""Pluggable compute backends for the prover hot loops.

One semantic spec, three interchangeable implementations (the structure
hardware-accelerated ZK systems use — cf. PAPERS.md on GPU PLONKish
proving): every backend computes the *same field elements bit-for-bit*, so
proof transcripts are identical across backends and Fiat–Shamir challenges
cannot diverge.  The suite asserts this parity (``tests/test_backend.py``).

Backends
--------
``ref``
    The pure-jnp reference paths that shipped with the seed
    (``hashing.permute_ref``, ``poly.ntt_ref``, a ``jax.lax``
    associative scan for the grand product).  Default; fastest on CPU.
``pallas-interpret``
    The Pallas kernels under ``repro.kernels`` executed with
    ``interpret=True`` — runs anywhere (CI, CPU containers) and exercises
    the exact kernel code paths, so kernel drift against the reference is
    caught on every PR without accelerator hardware.
``pallas``
    The same kernels compiled for a real accelerator (``interpret=False``).
    Raises at dispatch time on hosts whose jax backend cannot lower Pallas
    (plain CPU); gate on :func:`probe` before selecting it.

Selection
---------
Resolution order for the active backend (first hit wins):

1. an explicit :func:`use` scope (a context manager; nests, restores),
2. the ``ZKGRAPH_BACKEND`` environment variable,
3. the default, ``ref``.

``ProverConfig.backend`` (compare-excluded, never serialized: a backend is
an execution detail, not a proof parameter) routes a whole
``keygen``/``prove`` call through :func:`use` so sessions can pin a backend
per configuration.  The keygen cache key incorporates the resolved backend
name (:func:`resolve_name`) so PK/LDE caches never cross backends.

The dispatched primitives
-------------------------
``permute``
    Batched Poseidon-like permutation, ``(..., 16) -> (..., 16)`` — the
    Merkle/sponge workhorse (``hashing.permute`` and everything above it:
    ``hash_rows``, ``hash_bytes``, ``merkle.commit`` level builds).
``ntt``
    Radix-2 NTT along the last axis, natural order, ``inverse=`` for the
    scaled inverse transform — ``poly.ntt``/``intt``/``coset_lde``.
``grand_product_ext``
    Exclusive running product of Fp4 elements, ``(n, 4) -> (n, 4)`` with
    ``Z[0] = 1`` — the paper's Eq. (2) accumulator in the prover's phase-2
    ext-column construction.

Kernel-facing shape adapters (padding to tile multiples) live in each
kernel's ``ops.py``; this module only routes.
"""
from __future__ import annotations

import contextlib
import os
import threading
from dataclasses import dataclass
from typing import Callable, Optional

ENV_VAR = "ZKGRAPH_BACKEND"
DEFAULT = "ref"


class UnknownBackendError(ValueError):
    """Asked for a backend name that was never registered."""


@dataclass(frozen=True)
class ComputeBackend:
    """One named implementation of the prover's compute primitives."""
    name: str
    description: str
    permute: Callable          # (..., 16) uint32 -> (..., 16)
    ntt: Callable              # (..., n), inverse=False -> (..., n)
    grand_product_ext: Callable  # (n, 4) -> (n, 4) exclusive Fp4 products
    interpret: Optional[bool]  # Pallas interpret flag; None = pure jnp


_REGISTRY: dict = {}
# explicit use() stacks, innermost last — PER THREAD.  A proving service
# runs concurrent pipeline workers; a shared stack would interleave their
# push/pops and corrupt every thread's selection, so each thread gets its
# own.  Consequence: a worker thread does NOT inherit the spawning thread's
# scope — cross-thread pinning must be explicit (resolve_name() in the
# submitting thread, use(name) in the worker; ProofService does exactly
# this, and Keys.backend does it for keygen/prove).
_TLS = threading.local()


def _scopes() -> list:
    scopes = getattr(_TLS, "scopes", None)
    if scopes is None:
        scopes = _TLS.scopes = []
    return scopes


def register(backend: ComputeBackend) -> ComputeBackend:
    _REGISTRY[backend.name] = backend
    return backend


def names() -> tuple:
    return tuple(_REGISTRY)


def get(name: str) -> ComputeBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownBackendError(
            f"unknown compute backend {name!r}; available: "
            f"{', '.join(_REGISTRY)}") from None


def active_name() -> str:
    """The currently selected backend name (this thread's scope > env var >
    default)."""
    scopes = _scopes()
    if scopes:
        return scopes[-1]
    env = os.environ.get(ENV_VAR)
    if env:
        get(env)               # validate eagerly: typos fail loudly
        return env
    return DEFAULT


def active() -> ComputeBackend:
    return get(active_name())


def resolve_name(name: str = None) -> str:
    """A concrete backend name: ``name`` if given (validated), else the
    active selection.  This is the keygen-cache key component."""
    if name is not None:
        get(name)
        return name
    return active_name()


@contextlib.contextmanager
def use(name: str = None):
    """Pin the active backend within a ``with`` block (nests, restores).

    The pin is *thread-local*: concurrent pipeline workers can each pin a
    backend without perturbing one another, and a scope entered on one
    thread is invisible to every other (pass ``resolve_name()`` across the
    thread boundary to hand a selection over).

    ``name=None`` pins whatever is active at entry — used by
    ``keygen``/``prove`` to freeze ``cfg.backend`` resolution for the whole
    call even if the environment changes mid-proof."""
    scopes = _scopes()
    scopes.append(resolve_name(name))
    try:
        yield _REGISTRY[scopes[-1]]
    finally:
        scopes.pop()


def probe(name: str) -> tuple:
    """(usable, reason) — run a tiny permutation under ``name``.

    The compiled ``pallas`` backend needs an accelerator-capable jax
    backend; on plain CPU it raises at lowering time, which this converts
    into a clean availability answer for benchmarks and launch scripts."""
    import numpy as np
    try:
        be = get(name)
        with use(name):
            out = be.permute(np.zeros((2, 16), np.uint32))
        if out.shape != (2, 16):
            return False, f"probe returned shape {out.shape}"
        return True, "ok"
    except UnknownBackendError:
        raise
    except Exception as e:  # noqa: BLE001 — lowering errors vary by platform
        return False, f"{type(e).__name__}: {e}"


# ---------------------------------------------------------------------------
# the three registered backends (lazy imports: this module must stay
# import-light — hashing/poly import it at module load)
# ---------------------------------------------------------------------------
def _ref_permute(states):
    from . import hashing
    return hashing.permute_ref(states)


def _ref_ntt(x, inverse: bool = False):
    from . import poly
    return poly.ntt_ref(x, inverse=inverse)


def _ref_grand_product_ext(x):
    from ..kernels.grand_product.ref import grand_product_ext_ref
    return grand_product_ext_ref(x)


def _pallas_permute(interpret: bool):
    def permute(states):
        from ..kernels.poseidon import ops
        return ops.permute(states, interpret=interpret)
    return permute


def _pallas_ntt(interpret: bool):
    def ntt(x, inverse: bool = False):
        from ..kernels.ntt import ops
        return ops.ntt(x, inverse=inverse, interpret=interpret)
    return ntt


def _pallas_grand_product_ext(interpret: bool):
    def grand_product_ext(x):
        from ..kernels.grand_product import ops
        return ops.grand_product_ext(x, interpret=interpret)
    return grand_product_ext


register(ComputeBackend(
    name="ref",
    description="pure-jnp reference paths (uint64 oracle); CPU default",
    permute=_ref_permute,
    ntt=_ref_ntt,
    grand_product_ext=_ref_grand_product_ext,
    interpret=None,
))

register(ComputeBackend(
    name="pallas-interpret",
    description="Pallas kernels in interpret mode; runs on CPU/CI",
    permute=_pallas_permute(True),
    ntt=_pallas_ntt(True),
    grand_product_ext=_pallas_grand_product_ext(True),
    interpret=True,
))

register(ComputeBackend(
    name="pallas",
    description="compiled Pallas kernels; needs an accelerator jax backend",
    permute=_pallas_permute(False),
    ntt=_pallas_ntt(False),
    grand_product_ext=_pallas_grand_product_ext(False),
    interpret=False,
))
