"""Poseidon2-shaped permutation over BabyBear, batched as matmuls.

The TPU adaptation (DESIGN.md §2): the per-round linear layer of a width-16
permutation is a 16x16 matrix, so hashing a batch of states is one
(batch,16)x(16,16) modular matmul per round — an MXU-friendly schedule (the
Pallas kernel in ``repro.kernels.poseidon`` tiles exactly this). NOT a
security-audited parameter set (see DESIGN.md §8).

:func:`permute` dispatches through the active compute backend
(:mod:`repro.core.backend`): ``ref`` runs :func:`permute_ref` (the jnp path
below), the ``pallas*`` backends run the kernel.  All backends produce
bit-identical states, so everything above this primitive — the sponge, the
Merkle trees, the Fiat–Shamir transcript — is backend-independent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import backend
from . import field as F

WIDTH = 16          # state lanes
RATE = 8            # sponge rate (lanes absorbed/squeezed per block)
DIGEST = 8          # digest lanes
FULL_ROUNDS = 8     # 4 at start + 4 at end
PARTIAL_ROUNDS = 14
SBOX_DEG = 7        # gcd(7, p-1) = 1 -> permutation

_U32 = jnp.uint32
_U64 = jnp.uint64


@functools.lru_cache(maxsize=None)
def _params():
    """(mds (16,16), round_constants (n_rounds,16)) as numpy uint32."""
    # DFT-style matrix: M[i][j] = w^(i*j) with w a 16th root of unity.
    # Vandermonde-of-roots => invertible; dense mixing; literally an NTT step.
    w = F.root_of_unity(WIDTH)
    mds = np.zeros((WIDTH, WIDTH), np.uint32)
    for i in range(WIDTH):
        for j in range(WIDTH):
            mds[i, j] = pow(w, i * j, F.P)
    rng = np.random.default_rng(20250713)
    n_rounds = FULL_ROUNDS + PARTIAL_ROUNDS
    rc = (rng.integers(0, F.P, size=(n_rounds, WIDTH), dtype=np.int64)).astype(np.uint32)
    return mds, rc


def _sbox(x):
    x2 = F.fmul(x, x)
    x4 = F.fmul(x2, x2)
    x6 = F.fmul(x4, x2)
    return F.fmul(x6, x)


def _matmul_mod(state, mat):
    """(batch..., 16) x (16, 16) modular matmul.  Sum of 16 products of
    values < 2^31: fits in uint64 (16 * 2^62 overflows — reduce per-term)."""
    prod = state[..., :, None].astype(_U64) * mat[None, :, :].astype(_U64)
    prod = prod % _U64(F.P)                      # (batch..., 16, 16) < 2^31
    s = jnp.sum(prod, axis=-2) % _U64(F.P)       # 16 * 2^31 < 2^36: safe
    return s.astype(_U32)


def permute(state: jnp.ndarray) -> jnp.ndarray:
    """Apply the permutation to (..., 16) BabyBear states.

    Dispatches to the active compute backend; the backends are
    bit-identical, so callers never observe which one ran."""
    return backend.active().permute(state)


@jax.jit
def permute_ref(state: jnp.ndarray) -> jnp.ndarray:
    """The pure-jnp reference permutation (the ``ref`` backend, and the
    oracle the Pallas kernel is validated against)."""
    mds, rc = _params()
    mds = jnp.asarray(mds)
    rc = jnp.asarray(rc)
    half = FULL_ROUNDS // 2
    r = 0
    for _ in range(half):
        state = F.fadd(state, rc[r])
        state = _sbox(state)
        state = _matmul_mod(state, mds)
        r += 1
    for _ in range(PARTIAL_ROUNDS):
        state = F.fadd(state, rc[r])
        state = state.at[..., 0].set(_sbox(state[..., 0]))
        state = _matmul_mod(state, mds)
        r += 1
    for _ in range(half):
        state = F.fadd(state, rc[r])
        state = _sbox(state)
        state = _matmul_mod(state, mds)
        r += 1
    return state


def compress(left: jnp.ndarray, right: jnp.ndarray) -> jnp.ndarray:
    """2-to-1 compression for Merkle: (..., 8),(..., 8) -> (..., 8)."""
    state = jnp.concatenate([left, right], axis=-1)
    return permute(state)[..., :DIGEST]


def hash_bytes(data: bytes) -> np.ndarray:
    """Sponge-hash a byte string -> (8,) uint32 BabyBear digest.

    The canonical byte-to-field packing (docs/protocol.md §6): 3 bytes per
    lane little-endian (values < 2^24 < P), zero-padded to a multiple of 3,
    with two leading lanes carrying the byte length — so inputs that differ
    only in trailing zero bytes cannot collide.  This is the digest primitive
    under ``transparency.manifest_digest`` and the transparency-log leaves.
    """
    data = bytes(data)
    n = len(data)
    pad = (-n) % 3
    chunks = np.frombuffer(data + b"\x00" * pad, np.uint8)
    chunks = chunks.reshape(-1, 3).astype(np.uint32)
    lanes = chunks[:, 0] | (chunks[:, 1] << 8) | (chunks[:, 2] << 16)
    head = np.array([n & 0xFFFFFF, n >> 24], np.uint32)
    row = jnp.asarray(np.concatenate([head, lanes])[None, :])
    return np.asarray(hash_rows(row)[0])


def hash_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """Sponge-hash each row of (..., n, k) field elements -> (..., n, 8).

    k is padded to a multiple of RATE; absorb RATE lanes per permutation.
    """
    *batch, n, k = rows.shape
    pad = (-k) % RATE
    if pad:
        rows = jnp.pad(rows, [(0, 0)] * (rows.ndim - 1) + [(0, pad)])
        k += pad
    state = jnp.zeros(tuple(batch) + (n, WIDTH), _U32)
    # domain-separate by absorbed length
    state = state.at[..., WIDTH - 1].set(_U32(k % F.P))
    for blk in range(k // RATE):
        chunk = rows[..., blk * RATE:(blk + 1) * RATE]
        state = state.at[..., :RATE].set(F.fadd(state[..., :RATE], chunk))
        state = permute(state)
    return state[..., :DIGEST]
