"""Pallas kernel for the paper's Eq. (2) running-product accumulator.

Two-phase blocked scan (classic Blelloch decomposition adapted to a
multiplicative monoid over BabyBear):
  phase 1: each grid step loads a block into VMEM, computes the in-block
           exclusive prefix products and the block total;
  host    : tiny exclusive scan over the per-block totals (length n/block);
  phase 2: each block's prefixes are scaled by its block offset.
The modular multiply is the shared 16-bit-limb primitive (fieldops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fieldops.fieldops import mulmod_limb

_U32 = jnp.uint32


def _block_scan_kernel(x_ref, prefix_ref, total_ref):
    """Exclusive prefix products within one block (log-step doubling)."""
    x = x_ref[...]                       # (block,)
    n = x.shape[0]
    # inclusive scan via logarithmic shifts (Hillis-Steele in VMEM)
    acc = x
    shift = 1
    while shift < n:
        shifted = jnp.concatenate(
            [jnp.ones((shift,), _U32), acc[:-shift]])
        acc = mulmod_limb(acc, shifted)
        shift *= 2
    total_ref[...] = acc[-1:]
    # exclusive = inclusive shifted right with leading 1
    prefix_ref[...] = jnp.concatenate([jnp.ones((1,), _U32), acc[:-1]])


def _apply_offset_kernel(prefix_ref, offset_ref, o_ref):
    off = offset_ref[...]
    o_ref[...] = mulmod_limb(prefix_ref[...],
                             jnp.broadcast_to(off, prefix_ref.shape))


def grand_product(x: jnp.ndarray, block: int = 256,
                  interpret: bool = True) -> jnp.ndarray:
    """Exclusive running product of (n,) BabyBear elements, n % block == 0."""
    n = x.shape[0]
    block = min(block, n)
    assert n % block == 0
    nb = n // block
    prefixes, totals = pl.pallas_call(
        _block_scan_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), _U32),
                   jax.ShapeDtypeStruct((nb,), _U32)],
        interpret=interpret,
    )(x.astype(_U32))
    # tiny host-side exclusive scan over block totals (nb elements)
    from ...core import field as F
    incl = jax.lax.associative_scan(F.fmul, totals)
    offsets = jnp.concatenate([jnp.ones((1,), _U32), incl[:-1]])
    out = pl.pallas_call(
        _apply_offset_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), _U32),
        interpret=interpret,
    )(prefixes, offsets)
    return out
