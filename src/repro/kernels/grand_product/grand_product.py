"""Pallas kernels for the paper's Eq. (2) running-product accumulator.

Two-phase blocked scan (classic Blelloch decomposition adapted to a
multiplicative monoid over BabyBear):
  phase 1: each grid step loads a block into VMEM, computes the in-block
           exclusive prefix products and the block total;
  host    : tiny exclusive scan over the per-block totals (length n/block);
  phase 2: each block's prefixes are scaled by its block offset.
The modular multiply is the shared 16-bit-limb primitive (fieldops).

Two element types share the schedule: base-field scalars
(:func:`grand_product`) and the quartic extension Fp4
(:func:`grand_product_ext`) — the latter is what the prover's phase-2
ext-column construction actually accumulates (running products of
challenge-compressed tuples live in Fp4).  The in-kernel Fp4 multiply
(:func:`_emul_limb`) is the same schoolbook x^4 = W_EXT reduction as
``field.emul``, built from the 16-bit-limb primitives; modular arithmetic
is exact, so both produce bit-identical field elements.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.field import W_EXT
from ..fieldops.fieldops import addmod, mulmod_limb

_U32 = jnp.uint32


def _block_scan_kernel(x_ref, prefix_ref, total_ref):
    """Exclusive prefix products within one block (log-step doubling)."""
    x = x_ref[...]                       # (block,)
    n = x.shape[0]
    # inclusive scan via logarithmic shifts (Hillis-Steele in VMEM)
    acc = x
    shift = 1
    while shift < n:
        shifted = jnp.concatenate(
            [jnp.ones((shift,), _U32), acc[:-shift]])
        acc = mulmod_limb(acc, shifted)
        shift *= 2
    total_ref[...] = acc[-1:]
    # exclusive = inclusive shifted right with leading 1
    prefix_ref[...] = jnp.concatenate([jnp.ones((1,), _U32), acc[:-1]])


def _apply_offset_kernel(prefix_ref, offset_ref, o_ref):
    off = offset_ref[...]
    o_ref[...] = mulmod_limb(prefix_ref[...],
                             jnp.broadcast_to(off, prefix_ref.shape))


def grand_product(x: jnp.ndarray, block: int = 256,
                  interpret: bool = True) -> jnp.ndarray:
    """Exclusive running product of (n,) BabyBear elements, n % block == 0."""
    n = x.shape[0]
    block = min(block, n)
    assert n % block == 0
    nb = n // block
    prefixes, totals = pl.pallas_call(
        _block_scan_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), _U32),
                   jax.ShapeDtypeStruct((nb,), _U32)],
        interpret=interpret,
    )(x.astype(_U32))
    # tiny host-side exclusive scan over block totals (nb elements)
    from ...core import field as F
    incl = jax.lax.associative_scan(F.fmul, totals)
    offsets = jnp.concatenate([jnp.ones((1,), _U32), incl[:-1]])
    out = pl.pallas_call(
        _apply_offset_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), _U32),
        interpret=interpret,
    )(prefixes, offsets)
    return out


# ---------------------------------------------------------------------------
# Fp4 variant — the prover's phase-2 running products
# ---------------------------------------------------------------------------
def _emul_limb(a, b):
    """Schoolbook Fp4 multiply (reduction x^4 = W_EXT) on (..., 4) lanes,
    from the 16-bit-limb primitives — mirrors ``field.emul`` term for term,
    so the result is the same canonical representative bit for bit."""
    a0, a1, a2, a3 = a[..., 0], a[..., 1], a[..., 2], a[..., 3]
    b0, b1, b2, b3 = b[..., 0], b[..., 1], b[..., 2], b[..., 3]

    def m(x, y):
        return mulmod_limb(x, y)

    def mw(x):
        return mulmod_limb(jnp.full_like(x, W_EXT), x)

    c0 = addmod(m(a0, b0), mw(addmod(addmod(m(a1, b3), m(a2, b2)),
                                     m(a3, b1))))
    c1 = addmod(addmod(m(a0, b1), m(a1, b0)), mw(addmod(m(a2, b3),
                                                        m(a3, b2))))
    c2 = addmod(addmod(m(a0, b2), m(a1, b1)), addmod(m(a2, b0),
                                                     mw(m(a3, b3))))
    c3 = addmod(addmod(m(a0, b3), m(a1, b2)), addmod(m(a2, b1), m(a3, b0)))
    return jnp.stack([c0, c1, c2, c3], axis=-1)


def _ext_ones(k):
    """(k, 4) multiplicative identities [1, 0, 0, 0]."""
    return jnp.zeros((k, 4), _U32).at[:, 0].set(1)


def _block_scan_ext_kernel(x_ref, prefix_ref, total_ref):
    """Exclusive Fp4 prefix products within one block (log-step doubling).

    The doubling runs as a ``fori_loop`` with a dynamic-slice shift rather
    than a python-unrolled concatenate chain: the Fp4 limb-multiply graph is
    large, and unrolling it log2(block) times made XLA compilation take
    minutes per shape — the loop traces it exactly once."""
    x = x_ref[...]                       # (block, 4)
    n = x.shape[0]
    ones_n = _ext_ones(n)
    n_steps = (n - 1).bit_length()       # shifts 1, 2, ..., >= n/2

    def body(k, acc):
        shift = jnp.left_shift(jnp.int32(1), k)
        # shifted[i] = 1 for i < shift else acc[i - shift]
        full = jnp.concatenate([ones_n, acc], axis=0)
        shifted = jax.lax.dynamic_slice(full, (n - shift, jnp.int32(0)),
                                        (n, 4))
        return _emul_limb(acc, shifted)

    acc = jax.lax.fori_loop(0, n_steps, body, x)
    total_ref[...] = acc[-1:]
    prefix_ref[...] = jnp.concatenate([_ext_ones(1), acc[:-1]], axis=0)


def _apply_offset_ext_kernel(prefix_ref, offset_ref, o_ref):
    off = offset_ref[...]                # (1, 4)
    prefix = prefix_ref[...]             # (block, 4)
    o_ref[...] = _emul_limb(prefix, jnp.broadcast_to(off, prefix.shape))


def grand_product_ext(x: jnp.ndarray, block: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """Exclusive running product of (n, 4) Fp4 elements, n % block == 0."""
    n = x.shape[0]
    block = min(block, n)
    assert n % block == 0
    nb = n // block
    prefixes, totals = pl.pallas_call(
        _block_scan_ext_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, 4), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block, 4), lambda i: (i, 0)),
                   pl.BlockSpec((1, 4), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, 4), _U32),
                   jax.ShapeDtypeStruct((nb, 4), _U32)],
        interpret=interpret,
    )(x.astype(_U32))
    # tiny host-side exclusive scan over block totals (nb elements)
    from ...core import field as F
    incl = jax.lax.associative_scan(F.emul, totals, axis=0)
    offsets = jnp.concatenate([_ext_ones(1), incl[:-1]], axis=0)
    out = pl.pallas_call(
        _apply_offset_ext_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block, 4), lambda i: (i, 0)),
                  pl.BlockSpec((1, 4), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 4), _U32),
        interpret=interpret,
    )(prefixes, offsets)
    return out
