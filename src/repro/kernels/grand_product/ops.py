"""jit'd wrapper for the grand-product kernel."""
from __future__ import annotations

import functools

import jax

from . import grand_product as K


@functools.partial(jax.jit, static_argnames=("interpret",))
def grand_product(x, interpret: bool = True):
    return K.grand_product(x, interpret=interpret)
