"""jit'd wrappers + shape adapters for the grand-product kernels.

The blocked-scan kernels want the length to be a block multiple; circuit
row counts are powers of two but callers (tests, padding edge cases) may
not be, so both wrappers pad with the multiplicative identity — extra
trailing ones leave every real prefix product untouched — and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import grand_product as K

_U32 = jnp.uint32
BLOCK = 256        # kernel scan block


@functools.partial(jax.jit, static_argnames=("interpret",))
def grand_product(x, interpret: bool = True):
    """Exclusive running product of (n,) Fp scalars, any n >= 1."""
    n = x.shape[0]
    pad = (-n) % BLOCK if n > BLOCK else 0
    if pad:
        x = jnp.concatenate([x.astype(_U32), jnp.ones((pad,), _U32)])
    out = K.grand_product(x, block=BLOCK, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def grand_product_ext(x, interpret: bool = True):
    """Exclusive running product of (n, 4) Fp4 elements, any n >= 1."""
    n = x.shape[0]
    pad = (-n) % BLOCK if n > BLOCK else 0
    if pad:
        x = jnp.concatenate([x.astype(_U32), K._ext_ones(pad)], axis=0)
    out = K.grand_product_ext(x, block=BLOCK, interpret=interpret)
    return out[:n]
