"""Oracle for the grand-product kernel: exclusive running product mod P
(the paper's Eq. (2) accumulator Z: Z[0]=1, Z[i] = prod_{j<i} x[j])."""
import jax
import jax.numpy as jnp

from ...core import field as F


def grand_product_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (n,) uint32 -> exclusive prefix products (n,) uint32."""
    incl = jax.lax.associative_scan(F.fmul, x)
    one = jnp.ones((1,), jnp.uint32)
    return jnp.concatenate([one, incl[:-1]])


def grand_product_ext_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (n, 4) Fp4 -> exclusive prefix products (n, 4), Z[0] = [1,0,0,0].

    The ``ref`` backend's phase-2 accumulator (exactly the associative-scan
    schedule the seed prover inlined)."""
    incl = jax.lax.associative_scan(F.emul, x, axis=0)
    one = jnp.zeros((1, 4), jnp.uint32).at[0, 0].set(1)
    return jnp.concatenate([one, incl[:-1]], axis=0)
