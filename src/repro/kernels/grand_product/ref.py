"""Oracle for the grand-product kernel: exclusive running product mod P
(the paper's Eq. (2) accumulator Z: Z[0]=1, Z[i] = prod_{j<i} x[j])."""
import jax
import jax.numpy as jnp

from ...core import field as F


def grand_product_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: (n,) uint32 -> exclusive prefix products (n,) uint32."""
    incl = jax.lax.associative_scan(F.fmul, x)
    one = jnp.ones((1,), jnp.uint32)
    return jnp.concatenate([one, incl[:-1]])
