"""Pallas kernel for the Poseidon2-like permutation over BabyBear.

TPU mapping: a (block, 16) batch of sponge states lives in VMEM; each of the
22 rounds does (sbox ->) a 16x16 field matmul. The modular matmul is
elementwise 16-bit-limb products broadcast to (block, 16, 16) followed by a
log-tree modular reduction — on real TPU the i32 products ride the VPU while
the data layout matches the MXU tiling for a fused int8/int16 path (see
EXPERIMENTS.md §Perf for the measured schedule discussion).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import hashing as H
from ..fieldops.fieldops import addmod, mulmod_limb

_U32 = jnp.uint32


def _sbox(x):
    x2 = mulmod_limb(x, x)
    x4 = mulmod_limb(x2, x2)
    return mulmod_limb(mulmod_limb(x4, x2), x)


def _matmul_mod(state, mds):
    """state (bt, 16) x mds (16, 16) with limb products + tree addmod."""
    prod = mulmod_limb(
        jnp.broadcast_to(state[:, :, None], state.shape + (16,)),
        jnp.broadcast_to(mds[None, :, :], state.shape + (16,)))
    acc = prod  # (bt, 16, 16); reduce axis=1 in log steps
    k = 16
    while k > 1:
        k //= 2
        acc = addmod(acc[:, :k, :], acc[:, k:2 * k, :])
    return acc[:, 0, :]


def _permute_kernel(x_ref, rc_ref, mds_ref, o_ref):
    x = x_ref[...]
    rc = rc_ref[...]
    mds = mds_ref[...]
    half = H.FULL_ROUNDS // 2
    r = 0
    for _ in range(half):
        x = addmod(x, jnp.broadcast_to(rc[r][None], x.shape))
        x = _sbox(x)
        x = _matmul_mod(x, mds)
        r += 1
    for _ in range(H.PARTIAL_ROUNDS):
        x = addmod(x, jnp.broadcast_to(rc[r][None], x.shape))
        lane0 = _sbox(x[:, :1])
        x = jnp.concatenate([lane0, x[:, 1:]], axis=1)
        x = _matmul_mod(x, mds)
        r += 1
    for _ in range(half):
        x = addmod(x, jnp.broadcast_to(rc[r][None], x.shape))
        x = _sbox(x)
        x = _matmul_mod(x, mds)
        r += 1
    o_ref[...] = x


def permute(states: jnp.ndarray, block: int = 64,
            interpret: bool = True) -> jnp.ndarray:
    """states: (n, 16) -> (n, 16)."""
    n = states.shape[0]
    block = min(block, n)
    assert n % block == 0
    mds, rc = H._params()
    out = pl.pallas_call(
        _permute_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, 16), lambda i: (i, 0)),
            pl.BlockSpec(rc.shape, lambda i: (0, 0)),
            pl.BlockSpec((16, 16), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 16), _U32),
        interpret=interpret,
    )(states.astype(_U32), jnp.asarray(rc), jnp.asarray(mds))
    return out
