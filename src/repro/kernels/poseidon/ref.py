"""Oracle for the Poseidon-like permutation kernel."""
from ...core import hashing


def permute_ref(states):
    return hashing.permute(states)
