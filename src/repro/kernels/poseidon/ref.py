"""Oracle for the Poseidon-like permutation kernel.

Calls the pure-jnp path directly (``hashing.permute_ref``), NOT the
backend-dispatching ``hashing.permute`` — the oracle must stay the
reference even when the active backend is the kernel under test."""
from ...core import hashing


def permute_ref(states):
    return hashing.permute_ref(states)
