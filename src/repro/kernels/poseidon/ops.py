"""jit'd wrapper for the Poseidon-like permutation kernel."""
from __future__ import annotations

import functools

import jax

from . import poseidon as K


@functools.partial(jax.jit, static_argnames=("interpret",))
def permute(states, interpret: bool = True):
    return K.permute(states, interpret=interpret)
