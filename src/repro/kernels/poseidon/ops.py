"""jit'd wrapper + shape adapter for the Poseidon-like permutation kernel.

The raw kernel (``poseidon.permute``) wants a flat ``(n, 16)`` batch with
``n`` a multiple of its VMEM block.  Circuit-sized callers (Merkle level
builds, sponge absorbs) show up with arbitrary leading batch shapes and
non-tile-multiple row counts, so :func:`permute` here flattens, zero-pads
the batch up to the tile, runs the kernel, and slices the padding back off
— padding rows are independent states, so they cannot perturb real lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import poseidon as K

_U32 = jnp.uint32
TILE = 64          # kernel batch block (states per grid step)


@functools.partial(jax.jit, static_argnames=("interpret",))
def permute(states, interpret: bool = True):
    """Backend entry point: (..., 16) states, any batch shape/count."""
    shape = states.shape
    flat = states.reshape(-1, 16).astype(_U32)
    n = flat.shape[0]
    if n == 0:
        return states.astype(_U32)
    pad = (-n) % TILE
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, 16), _U32)], axis=0)
    out = K.permute(flat, block=TILE, interpret=interpret)
    return out[:n].reshape(shape)
