"""Pallas NTT kernel: one VMEM-tiled butterfly stage per pallas_call.

TPU mapping: the (batch, n) codeword matrix is tiled as
(batch_tile, n_groups, 2, m) blocks; each grid step loads one
(bt x 2m)-element tile into VMEM, multiplies the odd lane by the streamed
twiddle vector with the 16-bit-limb modular multiply (fieldops.mulmod_limb),
and writes the add/sub butterfly outputs in place. MXU is not used (the
butterflies are VPU work); data movement is the cost, hence the stage fusion
in ops.ntt (small-m stages grouped per tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fieldops.fieldops import addmod, mulmod_limb, submod

_U32 = jnp.uint32


def _stage_kernel(x_ref, tw_ref, o_ref):
    """x_ref: (bt, g, 2, m) tile; tw_ref: (1, 1, 1, m) twiddles."""
    x = x_ref[...]
    tw = tw_ref[...]
    even = x[:, :, 0, :]
    odd = mulmod_limb(x[:, :, 1, :], jnp.broadcast_to(tw[:, :, 0, :],
                                                      x[:, :, 1, :].shape))
    out = jnp.stack([addmod(even, odd), submod(even, odd)], axis=2)
    o_ref[...] = out


def ntt_stage(x: jnp.ndarray, twiddles: jnp.ndarray, m: int,
              batch_tile: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Apply one radix-2 DIT stage. x: (batch, n) in bit-reversed-progress
    order; twiddles: (m,) stage table."""
    b, n = x.shape
    g = n // (2 * m)
    x4 = x.reshape(b, g, 2, m)
    tw4 = twiddles.reshape(1, 1, 1, m)
    bt = min(batch_tile, b)
    out = pl.pallas_call(
        _stage_kernel,
        grid=(b // bt, g),
        in_specs=[
            pl.BlockSpec((bt, 1, 2, m), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, m), lambda i, j: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1, 2, m), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x4.shape, _U32),
        interpret=interpret,
    )(x4, tw4)
    return out.reshape(b, n)
