"""Oracle for the NTT kernel: the pure-jnp radix-2 transform."""
from ...core import poly


def ntt_ref(x, inverse: bool = False):
    return poly.ntt(x, inverse=inverse)
