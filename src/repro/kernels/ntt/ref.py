"""Oracle for the NTT kernel: the pure-jnp radix-2 transform.

Calls ``poly.ntt_ref`` directly, NOT the backend-dispatching ``poly.ntt``
— the oracle must stay the reference even when the active backend is the
kernel under test."""
from ...core import poly


def ntt_ref(x, inverse: bool = False):
    return poly.ntt_ref(x, inverse=inverse)
