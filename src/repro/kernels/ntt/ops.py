"""jit'd NTT built from the Pallas stage kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core import field as F
from ...core import poly
from . import ntt as K

_U32 = jnp.uint32


@functools.partial(jax.jit, static_argnames=("inverse", "interpret"))
def ntt(x: jnp.ndarray, inverse: bool = False, interpret: bool = True):
    """(batch, n) or (n,) NTT via per-stage Pallas kernels."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    b, n = x.shape
    x = x[:, jnp.asarray(poly._bitrev_perm(n))]
    tables = poly._stage_twiddles(n, inverse)
    m = 1
    for tw in tables:
        x = K.ntt_stage(x, jnp.asarray(tw), m, interpret=interpret)
        m *= 2
    if inverse:
        n_inv = pow(n, F.P - 2, F.P)
        x = F.fmul(x, _U32(n_inv))
    return x[0] if squeeze else x
