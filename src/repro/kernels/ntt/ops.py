"""jit'd NTT built from the Pallas stage kernel, plus the shape adapter.

The stage kernel tiles the codeword matrix as ``(batch_tile, g, 2, m)``
VMEM blocks, so the batch must be a multiple of the tile.  Prover call
sites transform whatever column count the circuit has (13 fixed columns,
one deep composition row, ...), so :func:`ntt` flattens leading dims and
zero-pads the batch up to the tile — transform rows are independent, so
padding rows cannot perturb real ones — then slices the padding back off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core import field as F
from ...core import poly
from . import ntt as K

_U32 = jnp.uint32
BATCH_TILE = 8     # stage-kernel batch block


@functools.partial(jax.jit, static_argnames=("inverse", "interpret"))
def ntt(x: jnp.ndarray, inverse: bool = False, interpret: bool = True):
    """Backend entry point: (..., n) NTT via per-stage Pallas kernels."""
    shape = x.shape
    n = shape[-1]
    x = x.reshape(-1, n).astype(_U32)
    b = x.shape[0]
    if b == 0 or n == 1:
        return x.reshape(shape)
    pad = (-b) % BATCH_TILE
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, n), _U32)], axis=0)
    x = x[:, jnp.asarray(poly._bitrev_perm(n))]
    tables = poly._stage_twiddles(n, inverse)
    m = 1
    for tw in tables:
        x = K.ntt_stage(x, jnp.asarray(tw), m, batch_tile=BATCH_TILE,
                        interpret=interpret)
        m *= 2
    if inverse:
        n_inv = pow(n, F.P - 2, F.P)
        x = F.fmul(x, _U32(n_inv))
    return x[:b].reshape(shape)
