"""Pallas TPU kernels for BabyBear modular arithmetic.

TPU adaptation core (DESIGN.md §2): TPUs have no 64-bit integer multiply, so
the 31-bit x 31-bit -> 62-bit product is assembled from 16-bit limbs on the
int32 VPU lanes, then reduced mod P with shift/add arithmetic exploiting
P = 2^31 - 2^27 + 1  =>  2^31 ≡ 2^27 - 1 (mod P).

The same ``mulmod_limb`` primitive is reused by the NTT and Poseidon kernels.
All kernels are validated in interpret mode against the uint64 oracle
(ref.py); the limb path itself uses only uint32 ops so it lowers to real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.field import P

_U32 = jnp.uint32
MASK16 = 0xFFFF


def mulmod_limb(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a * b) mod P using only 32-bit integer ops (TPU-native path).

    Product decomposition with 16-bit limbs:
        a*b = p0 + (p1 << 16) + (p2 << 32)
      with p0 = al*bl, p1 = al*bh + ah*bl (may carry), p2 = ah*bh.
    Reduction uses 2^31 ≡ 2^27 - 1 and 2^32 ≡ 2^28 - 2 (mod P), folding the
    high parts down until the value fits below 2*P, then a final conditional
    subtract.
    """
    a = a.astype(_U32)
    b = b.astype(_U32)
    al, ah = a & MASK16, a >> 16
    bl, bh = b & MASK16, b >> 16
    p0 = al * bl                       # < 2^32
    mid1 = al * bh                     # < 2^31
    mid2 = ah * bl                     # < 2^31
    p2 = ah * bh                       # < 2^30 (a,b < 2^31 so ah < 2^15)

    # full 64-bit value = p0 + (mid1 + mid2) << 16 + p2 << 32, tracked as
    # lo (bits 0..31) and hi (bits 32..63) with manual carries.
    mid = mid1 + mid2                  # < 2^32, may wrap: detect carry
    mid_carry = (mid < mid1).astype(_U32)          # 1 if wrapped
    lo = p0 + (mid << 16)
    carry0 = (lo < p0).astype(_U32)
    hi = p2 + (mid >> 16) + (mid_carry << _U32(16)) + carry0

    # reduce: x = hi * 2^32 + lo;  2^32 ≡ 2^28 - 2 (mod P)
    # hi < 2^31 so hi * (2^28 - 2) needs another limb round: do it via
    # recursive single step using the same decomposition (hi < 2^31):
    def fold32(hi_part, lo_part):
        """(hi*2^32 + lo) mod-ish -> value < 2^33ish then final reduce."""
        # hi * 2^32 mod P = hi * (2^28 - 2) mod P; hi < 2^31 =>
        # hi*2^28 = (hi << 28) needs 59 bits: split hi into 16/15 limbs.
        hl, hh = hi_part & MASK16, hi_part >> 16
        # hi*(2^28-2) = hl*2^28 + hh*2^44 - 2*hi
        # 2^44 mod P: fold 2^44 = 2^32 * 2^12 ≡ (2^28-2)*2^12 = 2^40 - 2^13
        #   2^40 ≡ 2^8 * 2^32 ≡ 2^8 (2^28 - 2) = 2^36 - 2^9
        #   2^36 ≡ 2^4 (2^28 - 2) = 2^32 - 2^5 ≡ 2^28 - 2 - 2^5
        # => 2^44 ≡ 2^28 - 2^13 - 2^9 - 2^5 - 2 (mod P)   [all < 2^31]
        c44 = (1 << 28) - (1 << 13) - (1 << 9) - (1 << 5) - 2
        t1 = mulmod_small(hl, (1 << 28) % P)
        t2 = mulmod_small(hh, c44 % P)
        # -2*hi mod P
        two_hi = addmod(hi_part, hi_part)
        acc = addmod(t1, t2)
        acc = submod(acc, modred(two_hi))
        return addmod(acc, modred(lo_part))

    return fold32(hi, lo)


def mulmod_small(a: jnp.ndarray, c: int) -> jnp.ndarray:
    """a (< 2^16) times python-int constant c (< P) mod P — product < 2^47:
    one limb round suffices."""
    cl, ch = c & MASK16, c >> 16
    lo = a * cl                        # < 2^32
    hi = a * ch                        # < 2^31 (represents << 16)
    # value = lo + hi * 2^16; hi*2^16 < 2^47: fold via 2^32 ≡ 2^28-2
    hi_lo = (hi << 16)
    hi_hi = hi >> 16                   # bits 32+
    part = mulmod_small16(hi_hi, ((1 << 28) - 2) % P)
    return addmod(addmod(modred(lo), modred(hi_lo)), part)


def mulmod_small16(a, c):
    """a < 2^16, c < 2^31, product < 2^47: split c."""
    cl, ch = c & MASK16, c >> 16
    lo = a * cl
    hi = a * ch                        # << 16, < 2^31
    return addmod(modred(lo), modred2(hi))


def modred(x: jnp.ndarray) -> jnp.ndarray:
    """Reduce x < 2^32 to [0, P): 2^31 ≡ 2^27 - 1."""
    lo = x & 0x7FFFFFFF
    hi = x >> 31                       # 0 or 1
    v = lo + hi * ((1 << 27) - 1)
    return jnp.where(v >= P, v - P, v)


def modred2(x: jnp.ndarray) -> jnp.ndarray:
    """Reduce (x << 16) where x < 2^31: x*2^16 mod P via limb split."""
    xl, xh = x & MASK16, x >> 16
    # x*2^16 = xl*2^16 + xh*2^32 ≡ xl*2^16 + xh*(2^28-2)
    t0 = modred(xl << 16)
    t1 = mulmod_small16_basic(xh, ((1 << 28) - 2) % P)
    return addmod(t0, t1)


def mulmod_small16_basic(a, c):
    """a < 2^15, c < 2^29ish: product < 2^44: two rounds of modred."""
    cl, ch = c & MASK16, c >> 16
    lo = a * cl                        # < 2^31
    hi = a * ch                        # << 16, < 2^28
    t = modred(hi << 16)
    hi2 = hi >> 16                     # ~0 for our ranges but keep exact
    t2 = modred(hi2 * (((1 << 28) - 2) % P))
    return addmod(addmod(modred(lo), t), t2)


def addmod(a, b):
    s = a + b
    return jnp.where(s >= P, s - P, s)


def submod(a, b):
    return jnp.where(a >= b, a - b, a + (P - 0) - b)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------
def _mulmod_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = mulmod_limb(a_ref[...], b_ref[...])


def _fma_kernel(a_ref, b_ref, c_ref, o_ref):
    o_ref[...] = addmod(mulmod_limb(a_ref[...], b_ref[...]), c_ref[...])


def _blocked_call(kernel, n_in, x_shape, block):
    rows = x_shape[0] // block
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((block,) + x_shape[1:], lambda i: (i,) + (0,) *
                               (len(x_shape) - 1))] * n_in,
        out_specs=pl.BlockSpec((block,) + x_shape[1:], lambda i: (i,) + (0,) *
                               (len(x_shape) - 1)),
        out_shape=jax.ShapeDtypeStruct(x_shape, _U32),
        interpret=True,  # CPU container: interpret; TPU: set False
    )
