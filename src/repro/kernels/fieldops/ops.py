"""jit'd wrappers + shape adapters for the fieldops Pallas kernels.

Inputs of any shape are flattened and zero-padded up to a block multiple
(elementwise kernels: padding lanes are dead work, never observed), so a
prime-sized circuit row count no longer degenerates to a block-1 grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fieldops as K

_U32 = jnp.uint32


def _pick_block(n: int) -> int:
    for b in (1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % b == 0:
            return b
    return 1


def _pad_flat(flat: jnp.ndarray) -> jnp.ndarray:
    """Pad a flat vector to a 256 multiple so _pick_block always finds a
    real block (one 256-lane block beats a grid of degenerate 1-blocks
    even for tiny inputs)."""
    pad = (-flat.shape[0]) % 256
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), _U32)])
    return flat


@functools.partial(jax.jit, static_argnames=("interpret",))
def mulmod(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = True):
    """Elementwise modular multiply via the 16-bit-limb Pallas kernel.

    a, b: uint32 arrays of any (same) shape."""
    shape = a.shape
    n = a.size
    flat_a = _pad_flat(a.reshape(-1).astype(_U32))
    flat_b = _pad_flat(b.reshape(-1).astype(_U32))
    block = _pick_block(flat_a.shape[0])
    out = pl.pallas_call(
        K._mulmod_kernel,
        grid=(flat_a.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 2,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat_a.shape, _U32),
        interpret=interpret,
    )(flat_a, flat_b)
    return out[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_mul_add(a, b, c, interpret: bool = True):
    """(a*b + c) mod P — one kernel, one VMEM round-trip."""
    shape = a.shape
    n = a.size
    flat_a, flat_b, flat_c = (_pad_flat(x.reshape(-1).astype(_U32))
                              for x in (a, b, c))
    block = _pick_block(flat_a.shape[0])
    out = pl.pallas_call(
        K._fma_kernel,
        grid=(flat_a.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat_a.shape, _U32),
        interpret=interpret,
    )(flat_a, flat_b, flat_c)
    return out[:n].reshape(shape)
