"""jit'd wrappers for the fieldops Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import fieldops as K

_U32 = jnp.uint32


def _pick_block(n: int) -> int:
    for b in (1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def mulmod(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = True):
    """Elementwise modular multiply via the 16-bit-limb Pallas kernel.

    a, b: 1-D or 2-D uint32 arrays (same shape)."""
    shape = a.shape
    flat = a.reshape(-1)
    block = _pick_block(flat.shape[0])
    out = pl.pallas_call(
        K._mulmod_kernel,
        grid=(flat.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 2,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, _U32),
        interpret=interpret,
    )(flat, b.reshape(-1))
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_mul_add(a, b, c, interpret: bool = True):
    """(a*b + c) mod P — one kernel, one VMEM round-trip."""
    shape = a.shape
    flat_a, flat_b, flat_c = (x.reshape(-1) for x in (a, b, c))
    block = _pick_block(flat_a.shape[0])
    out = pl.pallas_call(
        K._fma_kernel,
        grid=(flat_a.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat_a.shape, _U32),
        interpret=interpret,
    )(flat_a, flat_b, flat_c)
    return out.reshape(shape)
