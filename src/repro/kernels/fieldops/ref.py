"""Pure-jnp oracles for the BabyBear field kernels (uint64 fast path)."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import field as F

P = F.P


def mulmod_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise (a*b) mod P via uint64 — the CPU oracle."""
    return F.fmul(a, b)


def addmod_ref(a, b):
    return F.fadd(a, b)


def submod_ref(a, b):
    return F.fsub(a, b)


def fused_mul_add_ref(a, b, c):
    """(a*b + c) mod P."""
    return F.fadd(F.fmul(a, b), c)


def batch_inv_ref(a):
    return F.fbatch_inv(a)
