"""Base-table registry: canonical data-column layouts for published tables.

Every table the data owner publishes a commitment for is registered here by
descriptor; operators reference tables *only* through descriptors, so adding
a new base table (or a reversed / property-laden view of an existing one) is
one ``@register_table`` function — nothing in the planner or session changes.
"""
from __future__ import annotations

import numpy as np

from .storage import GraphDB

BASE_TABLES: dict = {}     # desc -> fn(db) -> (n_cols, n) int64 column matrix
TABLE_COLUMNS: dict = {}   # desc -> tuple of column names (the public layout)


def register_table(desc: str, columns=()):
    """Register a column-layout function under a table descriptor.

    ``columns`` names the layout's columns; it is published in the
    commitment manifest so a verifier knows the committed column order
    without trusting the prover's bundle."""
    def deco(fn):
        if desc in BASE_TABLES:
            raise KeyError(f"table descriptor {desc!r} already registered")
        BASE_TABLES[desc] = fn
        TABLE_COLUMNS[desc] = tuple(columns)
        return fn
    return deco


def base_table_cols(db: GraphDB, desc: str) -> np.ndarray:
    """Canonical data-column layout for a registered base table."""
    try:
        fn = BASE_TABLES[desc]
    except KeyError:
        raise KeyError(f"unknown base table descriptor {desc!r}; "
                       f"known: {sorted(BASE_TABLES)}") from None
    return fn(db)


def all_table_descs():
    return tuple(sorted(BASE_TABLES))


def table_columns(desc: str) -> tuple:
    """Registered column names for a descriptor ('' entries if unnamed)."""
    return TABLE_COLUMNS.get(desc, ())


# ---------------------------------------------------------------------------
# the LDBC SNB layouts the seed queries use
# ---------------------------------------------------------------------------
COMMENT_ID_BASE = 1 << 20


@register_table("knows", columns=("src", "dst"))
def _knows(db):
    t = db.tables["person_knows_person"]
    return np.stack([t.src, t.dst])


@register_table("knows_date", columns=("src", "dst", "creationDate"))
def _knows_date(db):
    t = db.tables["person_knows_person"]
    return np.stack([t.src, t.dst, t.props["creationDate"]])


@register_table("hasCreator", columns=("comment", "person"))
def _has_creator(db):
    t = db.tables["comment_hasCreator_person"]
    return np.stack([t.src, t.dst])


@register_table("hasCreator_date", columns=("comment", "person", "creationDate"))
def _has_creator_date(db):
    t = db.tables["comment_hasCreator_person"]
    return np.stack([t.src, t.dst, t.props["creationDate"]])


@register_table("replyOf", columns=("reply", "parent"))
def _reply_of(db):
    t = db.tables["comment_replyOf_comment"]
    return np.stack([t.src, t.dst])


@register_table("hasCreator_rev", columns=("person", "comment"))
def _has_creator_rev(db):
    t = db.tables["comment_hasCreator_person"]
    return np.stack([t.dst, t.src])


@register_table("replyOf_rev", columns=("parent", "reply"))
def _reply_of_rev(db):
    t = db.tables["comment_replyOf_comment"]
    return np.stack([t.dst, t.src])


@register_table("comment_date", columns=("comment", "creationDate"))
def _comment_date(db):
    ids = np.arange(len(db.node_props["comment"]["creationDate"])) + \
        COMMENT_ID_BASE
    return np.stack([ids, db.node_props["comment"]["creationDate"]])


@register_table("comment_content_date", columns=("comment", "content", "creationDate"))
def _comment_content_date(db):
    cp = db.node_props["comment"]
    ids = np.arange(len(cp["creationDate"])) + COMMENT_ID_BASE
    return np.stack([ids, cp["content"], cp["creationDate"]])


@register_table("person_firstName", columns=("person", "firstName"))
def _person_first_name(db):
    return np.stack([db.node_ids, db.node_props["person"]["firstName"]])


@register_table("knows_nodes", columns=("src", "dst", "node"))
def _knows_nodes(db):
    t = db.tables["person_knows_person"]
    cols = np.zeros((3, max(len(t), db.n_nodes)), np.int64)
    cols[0, : len(t)] = t.src
    cols[1, : len(t)] = t.dst
    cols[2, : db.n_nodes] = db.node_ids
    return cols
