"""Untrusted plain query engine: executes graph operations natively and
produces the results + auxiliary values the operators turn into witnesses.

This is the 'prover runs any exact algorithm' side of the paper (§IV-C): BFS
here, circuits verify. Everything is numpy/vectorized.
"""
from __future__ import annotations

import numpy as np

from .storage import EdgeTable


def expand(t: EdgeTable, src_id: int):
    """Single-source expansion: all (src_id, dst) edges (paper §IV-A)."""
    mask = t.src == src_id
    return t.dst[mask], mask


def expand_set(t: EdgeTable, ids: np.ndarray):
    """Set-based expansion (paper §IV-B): all edges with src in ids."""
    mask = np.isin(t.src, ids)
    return t.src[mask], t.dst[mask], mask


def expand_undirected(t: EdgeTable, src_id: int):
    """Expansion over canonical bidirectional edges."""
    fwd = t.src == src_id
    bwd = t.dst == src_id
    return np.concatenate([t.dst[fwd], t.src[bwd]]), fwd, bwd


def bfs_sssp(t: EdgeTable, node_ids: np.ndarray, src_id: int,
             undirected: bool = True, d_max: int = None):
    """BFS distances + predecessors over the node universe.

    Returns (dist, pred, pred_dist) aligned with node_ids; unreachable nodes
    get d_max, pred 0.
    """
    n = len(node_ids)
    d_max = d_max if d_max is not None else n + 1
    idx_of = {int(v): i for i, v in enumerate(node_ids.tolist())}
    dist = np.full(n, d_max, np.int64)
    pred = np.zeros(n, np.int64)
    s_idx = idx_of[int(src_id)]
    dist[s_idx] = 0
    srcs = t.src if not undirected else np.concatenate([t.src, t.dst])
    dsts = t.dst if not undirected else np.concatenate([t.dst, t.src])
    src_i = np.asarray([idx_of.get(int(v), -1) for v in srcs])
    dst_i = np.asarray([idx_of.get(int(v), -1) for v in dsts])
    ok = (src_i >= 0) & (dst_i >= 0)
    src_i, dst_i = src_i[ok], dst_i[ok]
    frontier = np.asarray([s_idx])
    d = 0
    visited = np.zeros(n, bool)
    visited[s_idx] = True
    while len(frontier):
        on_f = np.isin(src_i, frontier)
        cand_dst = dst_i[on_f]
        cand_src = src_i[on_f]
        new_mask = ~visited[cand_dst]
        if not new_mask.any():
            break
        nd, ns = cand_dst[new_mask], cand_src[new_mask]
        uniq, first = np.unique(nd, return_index=True)
        dist[uniq] = d + 1
        pred[uniq] = node_ids[ns[first]]
        visited[uniq] = True
        frontier = uniq
        d += 1
    pred_dist = np.where(dist > 0, dist - 1, 0)
    pred_dist[dist == d_max] = 0
    return dist, pred, pred_dist


def top_k(values: np.ndarray, k: int, descending: bool = True):
    """Order-by + limit-k (paper §IV-E): returns (mask of selected, pivot)."""
    order = np.argsort(values, kind="stable")
    if descending:
        order = order[::-1]
    sel = np.zeros(len(values), bool)
    k = min(k, len(values))
    sel[order[:k]] = True
    pivot = int(values[order[k - 1]]) if k else 0
    return sel, pivot


def find_path(t: EdgeTable, node_ids: np.ndarray, s: int, tt: int,
              undirected: bool = True):
    """Any path s -> t as a node sequence (reachability witness, §IV-E)."""
    dist, pred, _ = bfs_sssp(t, node_ids, s, undirected)
    idx_of = {int(v): i for i, v in enumerate(node_ids.tolist())}
    if tt not in idx_of or dist[idx_of[tt]] >= len(node_ids) + 1:
        return None
    path = [tt]
    cur = tt
    while cur != s:
        cur = int(pred[idx_of[cur]])
        path.append(cur)
    return np.asarray(path[::-1], np.int64)
