"""Graph storage: edge-list tables (the paper's chosen format, §III-E) plus
dataset commitments (the 'declared dataset' the prover is bound to).

Node identifiers are positive integers; 0 is reserved as the dummy/sentinel
value used for padding rows (the ZKSQL-style dummy tag, §III-B).
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np


@dataclass
class EdgeTable:
    """Directed edge list. Undirected relationships (person_knows_person) are
    stored canonically once; operators either canonicalize in-circuit (BiRC,
    §IV-D) or the table is pre-expanded via :func:`expand_bidirectional`."""
    src: np.ndarray
    dst: np.ndarray
    props: dict = dc_field(default_factory=dict)   # name -> np.ndarray

    def __len__(self):
        return len(self.src)

    def sorted_by_src(self) -> "EdgeTable":
        order = np.argsort(self.src, kind="stable")
        return EdgeTable(self.src[order], self.dst[order],
                         {k: v[order] for k, v in self.props.items()})

    def to_csr(self, node_ids: np.ndarray):
        """CSR arrays (paper §IV-A): col (targets), row_ptr, node_lut."""
        order = np.argsort(self.src, kind="stable")
        s, d = self.src[order], self.dst[order]
        node_lut = np.asarray(node_ids)
        row_ptr = np.zeros(len(node_lut) + 1, np.int64)
        counts = {nid: 0 for nid in node_lut.tolist()}
        idx_of = {nid: i for i, nid in enumerate(node_lut.tolist())}
        for x in s.tolist():
            counts[x] = counts.get(x, 0) + 1
        for i, nid in enumerate(node_lut.tolist()):
            row_ptr[i + 1] = row_ptr[i] + counts.get(nid, 0)
        # stable ordering of col by node_lut order
        col = np.zeros(len(s), np.int64)
        cursor = row_ptr[:-1].copy()
        for ss, dd in zip(s.tolist(), d.tolist()):
            i = idx_of[ss]
            col[cursor[i]] = dd
            cursor[i] += 1
        return col, row_ptr, node_lut


def expand_bidirectional(t: EdgeTable) -> EdgeTable:
    """Preprocessing strategy from Table IV: duplicate each edge in both
    directions (doubles the committed rows)."""
    return EdgeTable(np.concatenate([t.src, t.dst]),
                     np.concatenate([t.dst, t.src]),
                     {k: np.concatenate([v, v]) for k, v in t.props.items()})


@dataclass
class GraphDB:
    n_nodes: int                          # persons (node universe for traversal)
    node_ids: np.ndarray                  # person ids (1-based, unique)
    tables: dict                          # name -> EdgeTable
    node_props: dict = dc_field(default_factory=dict)  # prop -> array by id index

    @property
    def id_bits(self) -> int:
        mx = max(int(self.node_ids.max()),
                 *(int(t.dst.max(initial=1)) for t in self.tables.values()),
                 *(int(t.src.max(initial=1)) for t in self.tables.values()))
        return int(mx).bit_length() + 1


def pad_pow2(n: int) -> int:
    return 1 << max(4, (n - 1).bit_length())
