"""Synthetic LDBC SNB-like social network (paper §V experimental setup).

Entities: persons and comments. Fact tables:
  * person_knows_person      (undirected, canonical storage, creationDate prop)
  * comment_hasCreator_person (directed comment -> person, creationDate prop)
  * comment_replyOf_comment   (directed)
Sizes are controlled by the fact-table row counts like the paper's 60k/120k/
180k instances. Degree distribution is power-law-ish (preferential rewiring).
"""
from __future__ import annotations

import numpy as np

from .storage import EdgeTable, GraphDB

PERSON_BASE = 1            # person ids: 1..n_persons
COMMENT_BASE = 1 << 20     # comment ids start here (disjoint from persons)


def generate(n_knows: int = 2048, n_persons: int = None, seed: int = 0,
             n_comments: int = None) -> GraphDB:
    rng = np.random.default_rng(seed)
    n_persons = n_persons or max(64, n_knows // 16)
    n_comments = n_comments if n_comments is not None else n_knows
    person_ids = np.arange(PERSON_BASE, PERSON_BASE + n_persons, dtype=np.int64)

    # -- person_knows_person: preferential-attachment flavoured ------------
    # weights grow with previous degree; canonical (one direction) storage
    deg_w = np.ones(n_persons)
    srcs = np.empty(n_knows, np.int64)
    dsts = np.empty(n_knows, np.int64)
    block = max(1, n_knows // 16)
    filled = 0
    while filled < n_knows:
        k = min(block, n_knows - filled)
        p = deg_w / deg_w.sum()
        a = rng.choice(n_persons, size=k, p=p)
        b = rng.choice(n_persons, size=k, p=p)
        mask = a != b
        a, b = a[mask], b[mask]
        srcs[filled:filled + len(a)] = person_ids[a]
        dsts[filled:filled + len(a)] = person_ids[b]
        np.add.at(deg_w, a, 1.0)
        np.add.at(deg_w, b, 1.0)
        filled += len(a)
    # canonicalize away duplicates direction-insensitively, keep multiplicity
    dates = rng.integers(20200101, 20250101, size=n_knows).astype(np.int64)
    knows = EdgeTable(srcs, dsts, {"creationDate": dates})

    # -- comments ------------------------------------------------------------
    comment_ids = np.arange(COMMENT_BASE, COMMENT_BASE + n_comments,
                            dtype=np.int64)
    creators = person_ids[rng.choice(n_persons, size=n_comments,
                                     p=deg_w / deg_w.sum())]
    cdates = rng.integers(20200101, 20250101, size=n_comments).astype(np.int64)
    has_creator = EdgeTable(comment_ids.copy(), creators,
                            {"creationDate": cdates})
    # replies point to earlier comments
    reply_src, reply_dst = [], []
    for i in range(1, n_comments):
        if rng.random() < 0.6:
            reply_src.append(int(comment_ids[i]))
            reply_dst.append(int(comment_ids[rng.integers(0, i)]))
    reply_of = EdgeTable(np.asarray(reply_src, np.int64),
                         np.asarray(reply_dst, np.int64))

    node_props = {
        "firstName": rng.integers(1, 2000, size=n_persons).astype(np.int64),
        "lastName": rng.integers(1, 2000, size=n_persons).astype(np.int64),
        "birthday": rng.integers(19500101, 20051231, size=n_persons).astype(np.int64),
    }
    comment_props = {
        "content": rng.integers(1, 1 << 27, size=n_comments).astype(np.int64),
        "creationDate": cdates,
        "length": rng.integers(1, 2000, size=n_comments).astype(np.int64),
    }
    return GraphDB(
        n_nodes=n_persons,
        node_ids=person_ids,
        tables={"person_knows_person": knows,
                "comment_hasCreator_person": has_creator,
                "comment_replyOf_comment": reply_of},
        node_props={"person": node_props, "comment": comment_props},
    )
