"""Serving launcher: batched greedy decode with a KV/SSM cache.

``python -m repro.launch.serve --arch <id> --tokens 32`` runs the reduced
config on CPU; the production path shards the cache per launch/sharding.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_config
from repro.models import lm
from repro.train import train_step as ts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(ts.make_serve_step(cfg, args.temperature))
    cache = lm.init_cache(cfg, args.batch, args.max_seq)
    if cfg.enc_dec:
        fe = jnp.zeros((args.batch, cfg.frontend_len, cfg.d_model),
                       jnp.float32)
        cache["memory"] = lm._encoder_forward(params, cfg, fe)
    tok = jnp.ones((args.batch, 1), jnp.int32)
    rng = jax.random.PRNGKey(0)
    outs = []
    t0 = time.time()
    for i in range(args.tokens):
        tok, cache = serve(params, cache, tok, jax.random.fold_in(rng, i))
        outs.append(tok)
    wall = time.time() - t0
    seq = jnp.concatenate(outs, axis=1)
    print(f"decoded {args.tokens} tokens x batch {args.batch} in "
          f"{wall:.2f}s ({args.tokens*args.batch/wall:.1f} tok/s)")
    print("first row:", seq[0, :16].tolist())
    return seq


if __name__ == "__main__":
    main()
