"""Exact cost correction for scanned-layer models.

XLA's cost_analysis traverses a while-loop body ONCE — a lax.scan over
n_layers under-counts FLOPs/bytes/collectives by ~n_layers. This pass
recompiles each cell with fully-unrolled 1-layer and 2-layer variants (python
loop, scan_layers=False, inner scans unroll=True) on the same mesh/shapes and
extrapolates:

    body   = cost(2L) - cost(1L)          (one exact decoder layer)
    base   = cost(1L) - body              (embed/head/optimizer residue)
    total  = base + n_layers * body       (+ shared-block bodies for zamba2)

Writes dryrun_corrected.json; benchmarks/roofline.py consumes it.

    PYTHONPATH=src python -m repro.launch.costfix [--json dryrun_single.json]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + \
    " " + os.environ.get("XLA_FLAGS", "")

import argparse
import json
import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs.registry import SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import dryrun as dr
from repro.models import config as mcfg_mod


def _cell_costs(arch_cfg, shape_name, mesh):
    """Lower+compile one variant; return (flops, bytes, coll_bytes)/device."""
    import repro.configs.registry as reg
    # monkeypatch get_config so dryrun.input_specs sees the variant
    orig = reg.get_config
    reg.get_config = lambda a: arch_cfg
    try:
        fn, args, shards = dr.input_specs(arch_cfg.name, shape_name, mesh)
        with mesh:
            compiled = jax.jit(fn, in_shardings=shards).lower(*args).compile()
        ca = compiled.cost_analysis()
        coll = dr.parse_collective_bytes(compiled.as_text())
        return (float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0)),
                float(coll["total"]))
    finally:
        reg.get_config = orig


def _unroll_variant(cfg, n_layers, shared_every=None):
    import repro.models.lm as lm_mod
    v = replace(cfg, n_layers=n_layers,
                shared_attn_every=(shared_every if shared_every is not None
                                   else (1 if cfg.shared_attn_every and
                                         n_layers < cfg.shared_attn_every
                                         else cfg.shared_attn_every)))
    return v


def correct_record(rec, mesh, unroll_patch):
    arch, shape = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    if not __import__("repro.models.lm", fromlist=["can_scan"]).can_scan(cfg):
        rec["corrected"] = dict(
            flops=rec["per_device_flops"], bytes=rec["per_device_bytes"],
            coll=rec["collectives"]["total"], method="exact (unrolled)")
        return rec
    L = cfg.n_layers
    ns = cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0
    with unroll_patch():
        plain = replace(cfg, shared_attn_every=0)
        c1 = _cell_costs(replace(plain, n_layers=1), shape, mesh)
        c2 = _cell_costs(replace(plain, n_layers=2), shape, mesh)
        body = tuple(b - a for a, b in zip(c1, c2))
        base = tuple(a - b for a, b in zip(c1, body))
        if ns:
            s1 = _cell_costs(replace(cfg, n_layers=1, shared_attn_every=1),
                             shape, mesh)
            s2 = _cell_costs(replace(cfg, n_layers=2, shared_attn_every=1),
                             shape, mesh)
            sbody = tuple(b - a for a, b in zip(s1, s2))
        else:
            sbody = body
    tot = tuple(bs + (L - ns) * bd + ns * sb
                for bs, bd, sb in zip(base, body, sbody))
    rec["corrected"] = dict(flops=max(tot[0], 0), bytes=max(tot[1], 0),
                            coll=max(tot[2], 0),
                            method=f"1L/2L unrolled extrapolation (L={L}, "
                                   f"shared={ns})")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_single.json")
    ap.add_argument("--out", default="dryrun_corrected.json")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()

    import contextlib
    import repro.models.layers as layers_mod

    @contextlib.contextmanager
    def unroll_patch():
        """Force python-loop layers + fully-unrolled inner scans."""
        import repro.models.lm as lm_mod
        orig_can_scan = lm_mod.can_scan
        orig_scan = jax.lax.scan
        lm_mod.can_scan = lambda cfg: False

        def scan_unrolled(f, init, xs, length=None, **kw):
            kw.pop("unroll", None)
            return orig_scan(f, init, xs, length=length, unroll=True, **kw)
        jax.lax.scan = scan_unrolled
        try:
            yield
        finally:
            lm_mod.can_scan = orig_can_scan
            jax.lax.scan = orig_scan

    recs = json.load(open(args.json))
    out = []
    done = {}
    if os.path.exists(args.out):
        out = json.load(open(args.out))
        done = {(r["arch"], r["shape"], r["mesh"]): r for r in out}
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    for rec in recs:
        key = (rec["arch"], rec["shape"], rec.get("mesh"))
        if args.arch and rec["arch"] != args.arch:
            continue
        if args.shape and rec["shape"] != args.shape:
            continue
        if key in done or not rec.get("ok"):
            if key not in done:
                out.append(rec)
            continue
        t0 = time.time()
        print(f"CORRECT {rec['arch']} {rec['shape']} ...", flush=True)
        try:
            rec = correct_record(rec, mesh, unroll_patch)
            c = rec["corrected"]
            print(f"  raw flops/dev {rec['per_device_flops']:.3e} -> "
                  f"{c['flops']:.3e}  ({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"  correction failed: {e}", flush=True)
            rec["corrected"] = dict(flops=rec["per_device_flops"],
                                    bytes=rec["per_device_bytes"],
                                    coll=rec["collectives"]["total"],
                                    method=f"UNCORRECTED ({e})")
        out.append(rec)
        json.dump(out, open(args.out, "w"), indent=1)
    json.dump(out, open(args.out, "w"), indent=1)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
