"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any jax import (jax locks the device count at
first init) — these two lines stay at the very top of this file.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + \
    " " + os.environ.get("XLA_FLAGS", "")

import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, SHAPES, cells, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shd
from repro.models import lm
from repro.models.config import ModelConfig, active_param_count, param_count
from repro.train import compression, optimizer as opt, train_step as ts

# hardware model (TPU v5e-like): see ROOFLINE ANALYSIS in EXPERIMENTS.md
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_COLLECTIVE_RE = re.compile(
    r"=\s*(\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
                "s16": 2, "u16": 2}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective in the (per-device) module."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        total = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# per-cell function + abstract inputs
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(partial(lm.init_params, cfg,
                                  jax.random.PRNGKey(0)))


def input_specs(arch: str, shape_name: str, mesh):
    """(callable, arg_structs tuple, in_shardings tuple) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    dp = mesh_lib.dp_axes(mesh)
    p_struct = abstract_params(cfg)
    pspecs = shd.param_specs(cfg, p_struct)
    pshard = shd.shardings_of(pspecs, mesh, p_struct)
    dt = jnp.dtype(cfg.dtype)

    def batch_structs():
        tok_len = S - cfg.frontend_len if cfg.frontend == "vlm" else S
        b = {"tokens": jax.ShapeDtypeStruct((B, tok_len), jnp.int32)}
        bs = {"tokens": NamedSharding(mesh, P(dp, None))}
        if cfg.frontend != "none":
            b["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), jnp.float32)
            bs["frontend"] = NamedSharding(mesh, P(dp, None, None))
        return b, bs

    if shape.kind == "train":
        ocfg = opt.AdamWConfig()
        step = ts.make_train_step(cfg, ocfg)
        o_struct = jax.eval_shape(opt.init_state, p_struct)
        ospecs = {"step": P(), "mu": pspecs, "nu": pspecs}
        oshard = shd.shardings_of(ospecs, mesh, o_struct)
        e_struct = jax.eval_shape(compression.init_error, p_struct)
        eshard = shd.shardings_of(pspecs, mesh, e_struct)
        b, bs = batch_structs()
        return step, (p_struct, o_struct, e_struct, b), \
            (pshard, oshard, eshard, bs)
    if shape.kind == "prefill":
        fn = ts.make_prefill(cfg)
        b, bs = batch_structs()
        args = (p_struct, b["tokens"])
        shards = (pshard, bs["tokens"])
        if cfg.frontend != "none":
            args += (b["frontend"],)
            shards += (bs["frontend"],)
        return fn, args, shards
    # decode
    serve = ts.make_serve_step(cfg)
    c_struct = lm.init_cache_shapes(cfg, B, S)
    cspecs = shd.cache_specs(cfg, c_struct, B, mesh)
    cshard = shd.shardings_of(cspecs, mesh, c_struct)
    b_ax = dp if (B % mesh_lib.data_size(mesh) == 0 and
                  B >= mesh_lib.data_size(mesh)) else \
        ("data" if B % mesh.shape["data"] == 0 and B >= mesh.shape["data"]
         else None)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(b_ax, None))
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    rng_sh = NamedSharding(mesh, P(None))
    return serve, (p_struct, c_struct, tok, rng), \
        (pshard, cshard, tok_sh, rng_sh)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             want_hlo: bool = False):
    """Lower + compile one cell; returns the result record."""
    from repro.models import layers as layers_mod
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    layers_mod.DP_AXES = mesh_lib.dp_axes(mesh)
    layers_mod.DP_SIZE = mesh_lib.data_size(mesh)
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16")
    cfg = get_config(arch)
    try:
        fn, args, shards = input_specs(arch, shape_name, mesh)
        t0 = time.time()
        with mesh:
            lowered = jax.jit(fn, in_shardings=shards).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        ca = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        chips = int(np.prod(list(mesh.shape.values())))
        rec["ok"] = True
        rec["per_device_flops"] = float(ca.get("flops", -1))
        rec["per_device_bytes"] = float(ca.get("bytes accessed", -1))
        rec["mem"] = dict(
            argument=getattr(ma, "argument_size_in_bytes", -1),
            output=getattr(ma, "output_size_in_bytes", -1),
            temp=getattr(ma, "temp_size_in_bytes", -1),
            peak=getattr(ma, "peak_memory_in_bytes", -1) if
            hasattr(ma, "peak_memory_in_bytes") else -1,
        )
        hlo = compiled.as_text()
        rec["collectives"] = parse_collective_bytes(hlo)
        rec["n_chips"] = chips
        rec["model_params"] = param_count(cfg)
        rec["active_params"] = active_param_count(cfg)
        if want_hlo:
            rec["hlo"] = hlo
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def roofline_terms(rec: dict, shape_kind: str) -> dict:
    """The three roofline terms in seconds (single-pod records)."""
    chips = rec["n_chips"]
    flops = rec["per_device_flops"] * chips
    bytes_hbm = rec["per_device_bytes"] * chips
    coll = rec["collectives"]["total"] * chips
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = bytes_hbm / (chips * HBM_BW)
    t_coll = coll / (chips * ICI_BW)
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return dict(t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
                dominant=dominant, hlo_flops=flops)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    todo = []
    for arch, sname, skip in cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and sname != args.shape:
            continue
        todo.append((arch, sname, skip))

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch, sname, skip in todo:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, sname, mesh_name) in done:
                continue
            if skip:
                results.append(dict(arch=arch, shape=sname, mesh=mesh_name,
                                    ok=None, skipped=skip))
                print(f"SKIP {arch} {sname} {mesh_name}: {skip}", flush=True)
                continue
            print(f"RUN  {arch} {sname} {mesh_name} ...", flush=True)
            rec = run_cell(arch, sname, mp)
            if rec["ok"]:
                print(f"  ok lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"flops/dev={rec['per_device_flops']:.3e} "
                      f"coll/dev={rec['collectives']['total']:.3e}B",
                      flush=True)
            else:
                print(f"  FAIL {rec['error']}", flush=True)
            results.append(rec)
            json.dump(results, open(args.out, "w"), indent=1)
    json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if r.get("ok") is None)
    n_fail = sum(1 for r in results if r.get("ok") is False)
    print(f"\n== dry-run summary: {n_ok} ok / {n_skip} skipped / "
          f"{n_fail} FAILED ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
