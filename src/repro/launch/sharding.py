"""Parameter / activation / cache PartitionSpecs for the assigned archs.

Rules (DESIGN.md §3/§4):
 * TP over "model": attention heads when divisible (else KV replicated and
   only Q sharded), MLP hidden dim, vocab (embed/unembed), mamba inner dim.
 * Experts: EP over "model" when n_experts % model == 0 (dbrx), else TP
   inside each expert's hidden dim (mixtral).
 * DP over ("pod","data") on the batch.
 * Decode caches: batch over "data" when divisible; KV-cache sequence dim
   over "model" (flash-decoding split — softmax reductions inserted by
   GSPMD); for batch=1 long-context the sequence is sharded over BOTH axes.
 * Scanned (stacked) params carry a leading n_layers dim -> spec gets a
   leading None.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from . import mesh as mesh_lib


def _spec_for(path: str, leaf, cfg: ModelConfig, msize: int, stacked: bool):
    """PartitionSpec for one param leaf, identified by its tree path."""
    lead = (None,) if stacked and "/layers/" in path else ()
    name = path.rsplit("/", 1)[-1]
    ndim = leaf.ndim - len(lead)

    def pspec(*axes):
        return P(*(lead + tuple(axes)))

    if name in ("embed",):
        return P("model", None)            # vocab-sharded (never stacked)
    if name == "unembed":
        return P(None, "model")
    if name in ("scale", "bias", "D", "norm_scale"):
        return pspec(*([None] * ndim))
    # attention
    if name == "wq":
        shard_h = cfg.n_heads % msize == 0
        return pspec(None, "model" if shard_h else None, None)
    if name in ("wk", "wv"):
        shard_kv = cfg.n_kv % msize == 0
        return pspec(None, "model" if shard_kv else None, None)
    if name == "wo":
        shard_h = cfg.n_heads % msize == 0
        return pspec("model" if shard_h else None, None, None)
    if name in ("bq", "bk", "bv"):
        return pspec(None, None)
    if name == "bo":
        return pspec(None)
    # mlp / moe
    if name in ("wg", "wu"):
        if ndim == 3:  # (E, d, ff)
            if cfg.moe_experts % msize == 0:
                return pspec("model", None, None)
            return pspec(None, None, "model")
        return pspec(None, "model")
    if name == "wd":
        if ndim == 3:  # (E, ff, d)
            if cfg.moe_experts % msize == 0:
                return pspec("model", None, None)
            return pspec(None, "model", None)
        return pspec("model", None)
    if name in ("bu",):
        return pspec("model") if ndim == 1 else pspec(None, "model")
    if name in ("bd", "router"):
        return pspec(*([None] * ndim))
    # mamba
    if name == "in_proj":
        return pspec(None, "model")
    if name == "conv_w":
        return pspec(None, "model")
    if name == "x_proj":
        return pspec("model", None)
    if name == "dt_proj":
        return pspec(None, "model")
    if name == "A_log":
        return pspec("model", None) if ndim == 2 else pspec(None)
    if name == "out_proj":
        return pspec("model", None)
    return pspec(*([None] * ndim))


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tree_paths(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _tree_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def param_specs(cfg: ModelConfig, params_shape) -> dict:
    """Same-structure pytree of PartitionSpec for a params pytree (abstract
    or concrete)."""
    flat = dict(_tree_paths(params_shape))
    stacked = isinstance(params_shape.get("layers"), dict)
    msize = 16  # production model-parallel degree (both meshes)
    specs = {p: _spec_for(p, l, cfg, msize, stacked) for p, l in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
        return specs[prefix]

    return rebuild(params_shape)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop shardings on dims not divisible by their mesh-axis product —
    jit in_shardings requires exact divisibility on inputs."""
    out = []
    for i, axes in enumerate(spec):
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        prod = 1
        for a in ax_tuple:
            prod *= mesh.shape[a]
        out.append(axes if (i < len(shape) and shape[i] % prod == 0) else None)
    # pad to rank
    while len(out) < len(shape):
        out.append(None)
    return P(*out)


def shardings_of(specs, mesh, shapes_tree=None):
    """NamedShardings for a spec pytree; with ``shapes_tree`` (matching pytree
    of ShapeDtypeStruct/arrays) non-divisible dims are de-sharded."""
    if shapes_tree is None:
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, leaf: NamedSharding(mesh, sanitize_spec(s, leaf.shape, mesh)),
        specs, shapes_tree, is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh) -> P:
    return P(mesh_lib.dp_axes(mesh), None)


def cache_specs(cfg: ModelConfig, cache_shapes, batch: int, mesh) -> dict:
    """PartitionSpecs for the decode cache pytree."""
    dsize = mesh.shape["data"]
    b_ax = "data" if batch % dsize == 0 and batch >= dsize else None
    seq_axes = "model" if b_ax else ("data", "model")
    stacked = isinstance(cache_shapes["layers"], dict)
    lead = (None,) if stacked else ()

    def spec_leaf(path, leaf):
        name = path.rsplit("/", 1)[-1]
        if name == "pos":
            return P()
        if name == "memory":
            return P(b_ax, None, None)
        if "/shared/" in path:      # (n_shared, B, S, K, hd) carried stack
            return P(None, b_ax, seq_axes, None, None)
        if name in ("k", "v", "shared_k", "shared_v"):
            return P(*(lead + (b_ax, seq_axes, None, None)))
        if name == "h":      # (B, di, n)
            return P(*(lead + (b_ax, "model", None)))
        if name == "S":      # (B, H, n, P)
            shard_h = cfg.n_heads % mesh.shape["model"] == 0
            return P(*(lead + (b_ax, "model" if shard_h else None,
                               None, None)))
        if name == "conv":
            return P(*(lead + (b_ax, None, "model")))
        return P(*([None] * leaf.ndim))

    flat = dict(_tree_paths(cache_shapes))
    specs = {p: spec_leaf(p, l) for p, l in flat.items()}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return [rebuild(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
        return specs[prefix]

    return rebuild(cache_shapes)
