"""Training launcher: ``python -m repro.launch.train --arch <id> [--steps N]``.

On this CPU container it runs the reduced config on a 1-device mesh; on real
hardware the same driver runs the full config on the production mesh (the
mesh/shardings come from the same code paths the dry-run exercises).
Fault-tolerant loop: periodic checkpoints, auto-resume, straggler controller.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.launch import sharding as shd
from repro.models import lm
from repro.train import checkpoint, compression, data, fault
from repro.train import optimizer as opt
from repro.train import train_step as ts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (production) config instead of reduced")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                           total_steps=args.steps)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(params)
    err = compression.init_error(params)
    dcfg = data.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)
    stream = data.TokenStream(dcfg)

    ckpt_dir = os.path.join(args.ckpt_dir, cfg.name)
    os.makedirs(ckpt_dir, exist_ok=True)
    start = 0
    last = checkpoint.latest_step(ckpt_dir)
    if last is not None:
        params, state, start, extra = checkpoint.restore(
            ckpt_dir, last, params, state)
        stream.load_state_dict(extra.get("data", {"step": start}))
        print(f"resumed from step {start}")

    step_fn = jax.jit(ts.make_train_step(cfg, ocfg, args.grad_accum,
                                         args.compress_grads))
    ctrl = fault.FaultController([f"host{i}" for i in
                                  range(jax.process_count())])
    for step in range(start, args.steps):
        t0 = time.time()
        batch = next(stream)
        if cfg.frontend != "none":
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model), jnp.float32)
        params, state, err, metrics = step_fn(params, state, err, batch)
        dt = time.time() - t0
        ctrl.heartbeat(f"host{jax.process_index()}", dt)
        ctrl.sweep()
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            checkpoint.save(ckpt_dir, step + 1, params, state,
                            extra={"data": stream.state_dict()})
    print("done")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
