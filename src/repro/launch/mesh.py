"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the pod axis
is pure data parallelism across pods (DCN-ish links), model parallelism never
crosses the pod boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Data-parallel axes (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_size(mesh) -> int:
    return mesh.shape["model"]


def data_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
