"""Dry-run for the paper's own workload: the distributed ZKGraph prover.

Maps the prover hot loop (per-column coset LDE via NTT -> Merkle leaf hashing
-> tree reduction -> logUp accumulator) onto the production mesh:
  * proofs in a batch are data-parallel over ('pod','data') — the proving
    service fans independent query proofs across pods;
  * the column dimension of each circuit is model-parallel over 'model';
  * Merkle leaf hashing needs every column of a row -> all-gather over
    'model' (this is the collective the §Perf hillclimb attacks).

Run:  PYTHONPATH=src python -m repro.launch.dryrun_zk
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + \
    " " + os.environ.get("XLA_FLAGS", "")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.zkgraph import ZKGraphConfig
from repro.core import field as F
from repro.core import hashing, poly
from repro.launch import mesh as mesh_lib
from repro.launch.dryrun import parse_collective_bytes


def prover_core_step(columns: jnp.ndarray, alpha: jnp.ndarray,
                     beta: jnp.ndarray, blowup: int = 4):
    """The per-proof compute core, batched: columns (BP, C, N) uint32.

    Returns (roots (BP, 8), logup accumulators (BP, N, 4)) — the dominant
    FLOP/byte producers of prove() (LDE + Merkle + phase-2), without the
    host-side transcript logic (Fiat-Shamir runs on scalars).
    """
    bp, ncols, n = columns.shape
    lde = poly.coset_lde(columns, blowup)             # (BP, C, N*blowup)
    leaves = lde.transpose(0, 2, 1)                   # (BP, NL, C)
    digests = hashing.hash_rows(leaves)               # (BP, NL, 8)
    # Merkle reduction
    level = digests
    while level.shape[1] > 1:
        level = hashing.compress(level[:, 0::2], level[:, 1::2])
    roots = level[:, 0]
    # phase-2 logUp accumulator on the first two columns (bus f/t sides)
    d_f = F.eadd(jnp.broadcast_to(beta, (bp, n, 4)),
                 F.emul(jnp.broadcast_to(alpha, (bp, n, 4)),
                        F.ext(columns[:, 0, :])))
    d_t = F.eadd(jnp.broadcast_to(beta, (bp, n, 4)),
                 F.emul(jnp.broadcast_to(alpha, (bp, n, 4)),
                        F.ext(columns[:, 1, :])))
    inv_f = F.ebatch_inv(d_f)
    inv_t = F.ebatch_inv(d_t)
    inc = F.esub(inv_f, inv_t)
    h = (jnp.cumsum(inc.astype(jnp.uint64), axis=1) %
         jnp.uint64(F.P)).astype(jnp.uint32)
    return roots, h


def prover_core_step_staged(columns, alpha, beta, blowup: int = 4):
    """Beyond-paper schedule (§Perf iteration 3): the LDE stage wants the
    row axis local (NTT butterflies along N), the hashing stage wants rows
    sharded (each leaf needs every column). Instead of letting GSPMD reshard
    per absorb-block inside the sponge, we pay ONE explicit reshard between
    the stages; everything downstream of it (leaf hash, whole Merkle
    reduction, logUp scan) is device-local up to the final 16-subroot
    combine."""
    bp, ncols, n = columns.shape
    lde = poly.coset_lde(columns, blowup)             # cols sharded on 'model'
    leaves = lde.transpose(0, 2, 1)                   # (BP, NL, C)
    # the single stage boundary: rows now sharded over 'model'
    leaves = jax.lax.with_sharding_constraint(
        leaves, P(("pod", "data") if leaves.shape[0] >= 512 else "data",
                  "model", None))
    digests = hashing.hash_rows(leaves)               # local per row shard
    level = digests
    while level.shape[1] > 1:
        level = hashing.compress(level[:, 0::2], level[:, 1::2])
    roots = level[:, 0]
    d_f = F.eadd(jnp.broadcast_to(beta, (bp, n, 4)),
                 F.emul(jnp.broadcast_to(alpha, (bp, n, 4)),
                        F.ext(columns[:, 0, :])))
    d_t = F.eadd(jnp.broadcast_to(beta, (bp, n, 4)),
                 F.emul(jnp.broadcast_to(alpha, (bp, n, 4)),
                        F.ext(columns[:, 1, :])))
    # §Perf iteration 4: 1/df - 1/dt = (dt - df) / (df*dt): ONE batched
    # inversion (the scan passes dominate this stage's HBM traffic)
    inc = F.emul(F.esub(d_t, d_f), F.ebatch_inv(F.emul(d_f, d_t)))
    h = (jnp.cumsum(inc.astype(jnp.uint64), axis=1) %
         jnp.uint64(F.P)).astype(jnp.uint32)
    return roots, h


def run(multi_pod: bool, zcfg: ZKGraphConfig, staged: bool = False):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    dp = mesh_lib.dp_axes(mesh)
    bp = zcfg.batch_proofs * (2 if multi_pod else 1)
    cols = jax.ShapeDtypeStruct((bp, zcfg.n_columns, zcfg.n_rows), jnp.uint32)
    alpha = jax.ShapeDtypeStruct((4,), jnp.uint32)
    beta = jax.ShapeDtypeStruct((4,), jnp.uint32)
    shards = (NamedSharding(mesh, P(dp, "model", None)),
              NamedSharding(mesh, P(None)), NamedSharding(mesh, P(None)))
    fn = prover_core_step_staged if staged else prover_core_step
    rec = dict(arch="zkgraph-prover" + ("-staged" if staged else ""),
               shape=f"rows2^{zcfg.n_rows.bit_length()-1}"
               f"_bp{bp}", mesh="2x16x16" if multi_pod else "16x16")
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn,
                          in_shardings=shards,
                          static_argnums=()).lower(cols, alpha, beta)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    chips = int(np.prod(list(mesh.shape.values())))
    rec.update(ok=True, n_chips=chips,
               per_device_flops=float(ca.get("flops", -1)),
               per_device_bytes=float(ca.get("bytes accessed", -1)),
               collectives=parse_collective_bytes(compiled.as_text()),
               mem=dict(temp=getattr(ma, "temp_size_in_bytes", -1),
                        argument=getattr(ma, "argument_size_in_bytes", -1)))
    rec["model_params"] = 0
    rec["active_params"] = 0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=32)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--out", default="dryrun_zk.json")
    ap.add_argument("--staged", choices=["yes", "no", "both"], default="both")
    args = ap.parse_args()
    zcfg = ZKGraphConfig(n_rows=1 << args.rows, n_columns=args.cols,
                         batch_proofs=args.batch)
    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    for staged in (False, True) if args.staged == "both" else \
            ([args.staged == "yes"]):
        for mp in (False, True):
            name = "zkgraph-prover" + ("-staged" if staged else "")
            if any(r["arch"] == name and
                   r["mesh"] == ("2x16x16" if mp else "16x16")
                   for r in results):
                continue
            print(f"RUN {name} rows=2^{args.rows} cols={args.cols} "
                  f"{'2x16x16' if mp else '16x16'} ...", flush=True)
            rec = run(mp, zcfg, staged)
            print(f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"flops/dev={rec['per_device_flops']:.3e} "
                  f"coll/dev={rec['collectives']['total']:.3e}B", flush=True)
            results.append(rec)
            json.dump(results, open(args.out, "w"), indent=1)
    json.dump(results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
