"""The paper's own workload config: distributed ZK proving pipeline shapes."""
from dataclasses import dataclass


@dataclass(frozen=True)
class ZKGraphConfig:
    name: str = "zkgraph-prover"
    n_rows: int = 1 << 16          # circuit rows per proof
    n_columns: int = 32            # committed base columns
    blowup: int = 4
    batch_proofs: int = 256       # proofs batched across the mesh
