"""Architecture + shape registry: ``--arch <id>`` lookup and the assigned
input-shape grid (40 cells)."""
from __future__ import annotations

from dataclasses import dataclass
import importlib

ARCHS = {
    "internvl2-2b": "repro.configs.lm.internvl2_2b",
    "internlm2-1.8b": "repro.configs.lm.internlm2_1_8b",
    "starcoder2-3b": "repro.configs.lm.starcoder2_3b",
    "starcoder2-15b": "repro.configs.lm.starcoder2_15b",
    "qwen1.5-32b": "repro.configs.lm.qwen1_5_32b",
    "mixtral-8x22b": "repro.configs.lm.mixtral_8x22b",
    "dbrx-132b": "repro.configs.lm.dbrx_132b",
    "zamba2-1.2b": "repro.configs.lm.zamba2_1_2b",
    "whisper-base": "repro.configs.lm.whisper_base",
    "falcon-mamba-7b": "repro.configs.lm.falcon_mamba_7b",
}


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str):
    mod = importlib.import_module(ARCHS[arch])
    return mod.CONFIG


def cells():
    """All 40 (arch, shape) cells with skip annotations.

    long_500k needs sub-quadratic attention: run for SSM/hybrid/SWA archs,
    skip (recorded) for pure full-attention archs (DESIGN.md §4).
    """
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.subquadratic:
                skip = "full attention: 500k decode cache is not sub-quadratic"
            out.append((arch, sname, skip))
    return out
