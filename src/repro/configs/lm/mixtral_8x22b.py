"""Mixtral-8x22B: MoE 8 experts top-2, GQA kv=8, SWA [arXiv:2401.04088]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48, n_kv=8,
    d_ff=16384, vocab=32768, head_dim=128, norm="rmsnorm", mlp="swiglu",
    rope_theta=1e6, sliding_window=4096, moe_experts=8, moe_top_k=2)
