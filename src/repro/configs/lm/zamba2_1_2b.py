"""Zamba2-1.2B: Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", n_layers=38, d_model=2048, n_heads=32, n_kv=32,
    d_ff=8192, vocab=32000, head_dim=64, norm="rmsnorm", mlp="swiglu",
    block_type="mamba2", shared_attn_every=6, ssm_state=64)
