"""LM-architecture configs for the training substrate (quarantined).

These back the ``--arch`` grid of ``repro.launch`` / ``repro.models`` —
training-substrate material, not part of the ZK proving path.  They live in
their own subpackage so importing :mod:`repro.configs.registry` (or the
serving/proving stack) never has to wade through them: the registry resolves
each module lazily by dotted path on first ``get_config`` call.
"""
