"""Qwen1.5-32B: dense, QKV bias [hf:Qwen/Qwen1.5-32B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv=40,
    d_ff=27392, vocab=152064, head_dim=128, norm="rmsnorm", mlp="swiglu",
    qkv_bias=True, rope_theta=1e6)
