"""Falcon-Mamba-7B: attention-free Mamba1 [arXiv:2410.05355]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", n_layers=64, d_model=4096, n_heads=64, n_kv=0,
    d_ff=0, vocab=65024, head_dim=64, norm="rmsnorm", block_type="mamba1",
    ssm_state=16)
