"""DBRX-132B: MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv=8,
    d_ff=10752, vocab=100352, head_dim=128, norm="layernorm", mlp="swiglu",
    rope_theta=5e5, moe_experts=16, moe_top_k=4)
