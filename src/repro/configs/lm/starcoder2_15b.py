"""StarCoder2-15B: GQA kv=4, sliding window 4096 [arXiv:2402.19173]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", n_layers=40, d_model=6144, n_heads=48, n_kv=4,
    d_ff=24576, vocab=49152, head_dim=128, norm="layernorm", mlp="gelu",
    qkv_bias=True, proj_bias=True, rope_theta=1e5, sliding_window=4096)
