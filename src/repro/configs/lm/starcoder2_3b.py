"""StarCoder2-3B: GQA kv=2, sliding window 4096, LN+bias [arXiv:2402.19173]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24, n_kv=2,
    d_ff=12288, vocab=49152, head_dim=128, norm="layernorm", mlp="gelu",
    qkv_bias=True, proj_bias=True, rope_theta=1e5, sliding_window=4096)
