"""InternVL2-2B: InternLM2 backbone + ViT frontend stub [arXiv:2404.16821]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", n_layers=24, d_model=2048, n_heads=16, n_kv=8,
    d_ff=8192, vocab=92553, head_dim=128, norm="rmsnorm", mlp="swiglu",
    rope_theta=1e6, frontend="vlm", frontend_len=256)
