"""InternLM2-1.8B: dense GQA decoder [arXiv:2403.17297]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16, n_kv=8,
    d_ff=8192, vocab=92544, head_dim=128, norm="rmsnorm", mlp="swiglu",
    rope_theta=1e6)
