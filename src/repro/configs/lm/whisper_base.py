"""Whisper-base: enc-dec, conv frontend stubbed [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", n_layers=6, d_model=512, n_heads=8, n_kv=8,
    d_ff=2048, vocab=51865, head_dim=64, norm="layernorm", mlp="gelu",
    proj_bias=True, enc_dec=True, enc_layers=6, frontend="audio",
    frontend_len=1500)
