"""Fault-tolerance controller (simulated multi-host): heartbeats, straggler
detection, and elastic remesh decisions.

On a real cluster the controller runs on the coordinator; workers heartbeat
each step with their step time. Here the same logic is driven by simulated
timings so the policy is testable: a node that misses ``dead_after`` beats is
declared failed -> elastic restart on the surviving nodes from the last
checkpoint; a node slower than ``straggle_factor`` x median is flagged and
its shard re-balanced (or it is evicted after repeated flags)."""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field


@dataclass
class FaultConfig:
    heartbeat_interval_s: float = 10.0
    dead_after: int = 3                # missed beats before declared dead
    straggle_factor: float = 1.5
    straggle_strikes: int = 3          # flags before eviction


@dataclass
class NodeState:
    last_beat: float = 0.0
    missed: int = 0
    strikes: int = 0
    step_times: deque = field(default_factory=lambda: deque(maxlen=16))


class FaultController:
    def __init__(self, node_ids, cfg: FaultConfig = FaultConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.nodes = {n: NodeState(last_beat=clock()) for n in node_ids}
        self.events = []

    # -- worker-side signals -------------------------------------------------
    def heartbeat(self, node, step_time_s: float):
        st = self.nodes[node]
        st.last_beat = self.clock()
        st.missed = 0
        st.step_times.append(step_time_s)

    # -- coordinator sweep ---------------------------------------------------
    def sweep(self):
        """Returns dict of decisions: {"dead": [...], "stragglers": [...],
        "evict": [...]}; caller triggers checkpoint-restore/elastic remesh."""
        now = self.clock()
        dead, stragglers, evict = [], [], []
        alive_times = [list(s.step_times)[-1] for s in self.nodes.values()
                       if s.step_times]
        median = sorted(alive_times)[len(alive_times) // 2] if alive_times \
            else None
        for n, st in list(self.nodes.items()):
            missed = int((now - st.last_beat) // self.cfg.heartbeat_interval_s)
            if missed >= self.cfg.dead_after:
                dead.append(n)
                del self.nodes[n]
                continue
            if median and st.step_times and \
                    st.step_times[-1] > self.cfg.straggle_factor * median:
                st.strikes += 1
                stragglers.append(n)
                if st.strikes >= self.cfg.straggle_strikes:
                    evict.append(n)
                    del self.nodes[n]
            elif st.step_times:
                st.strikes = max(0, st.strikes - 1)
        out = {"dead": dead, "stragglers": stragglers, "evict": evict}
        if dead or evict:
            self.events.append(out)
        return out

    def surviving(self):
        return sorted(self.nodes)


def elastic_mesh_shape(n_devices: int, model_parallel: int):
    """Largest (data, model) grid on the surviving devices: keep the model
    axis (params must fit), shrink data parallelism."""
    data = max(1, n_devices // model_parallel)
    return (data, model_parallel)
