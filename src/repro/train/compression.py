"""int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce; see DESIGN.md §3).

``compress`` quantizes each leaf to int8 with a per-leaf scale; the residual
is carried in an error-feedback buffer so the scheme is unbiased over time
(Seide et al. / EF-SGD style). ``compressed_psum`` wires it through a
shard_map all-reduce when a mesh axis is given.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress_leaf(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g - deq
    return q, scale, deq, new_err


def compress(grads, err):
    """Returns (dequantized grads, new error buffers, bytes ratio)."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    deqs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        _, _, dq, ne = compress_leaf(g, e)
        deqs.append(dq)
        errs.append(ne)
    return jax.tree.unflatten(tree, deqs), jax.tree.unflatten(tree, errs)


def compressed_psum(grads, err, axis_name: str):
    """Quantize -> int8 all-reduce -> dequantize, with error feedback.

    Inside shard_map: the wire format is int8 (4x smaller than f32), the
    error buffer stays local. The summed scale is exchanged alongside (one
    scalar per leaf)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_err = g - q.astype(jnp.float32) * scale
        # int8 payload summed in int32 (hardware-friendly), scales averaged
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return q_sum.astype(jnp.float32) * (s_sum / n) / n, new_err

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out, errs = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = one(g, e)
        out.append(o)
        errs.append(ne)
    return jax.tree.unflatten(tree, out), jax.tree.unflatten(tree, errs)
