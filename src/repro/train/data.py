"""Synthetic deterministic data pipeline with exact-resume semantics.

Every batch is a pure function of (seed, step, host) — after a restart the
pipeline continues from the checkpointed step with bit-identical batches
(the fault-tolerance story depends on this)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenStream:
    """Markov-ish synthetic token stream (learnable structure so training
    loss decreases measurably)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        rng = np.random.default_rng(cfg.seed)
        # fixed bigram transition structure
        self._next = rng.integers(0, cfg.vocab,
                                  size=(cfg.vocab,)).astype(np.int32)

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, st):
        self.step = int(st["step"])

    def __iter__(self):
        return self

    def __next__(self):
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + self.step) * 31 + cfg.host_id)
        b = np.empty((per_host, cfg.seq_len), np.int32)
        start = rng.integers(0, cfg.vocab, size=per_host).astype(np.int32)
        b[:, 0] = start
        noise = rng.random((per_host, cfg.seq_len)) < 0.1
        for t in range(1, cfg.seq_len):
            nxt = self._next[b[:, t - 1]]
            rand = rng.integers(0, cfg.vocab, size=per_host)
            b[:, t] = np.where(noise[:, t], rand, nxt)
        self.step += 1
        return {"tokens": jnp.asarray(b)}
