"""Sharded, atomic, resumable checkpoints + elastic re-sharding.

Layout:  <dir>/step_<n>/  arrays.npz (flat leaves)  manifest.json (treedef,
step, data-pipeline state). Writes go to a temp dir + atomic rename so a
crash mid-save never corrupts the latest checkpoint. keep_last_k pruning.
Restore re-shards onto whatever mesh the restarted job has (elastic)."""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, str(treedef)


def save(path: str, step: int, params, opt_state, extra: dict = None,
         keep_last: int = 3):
    tmp = os.path.join(path, f".tmp_step_{step}")
    final = os.path.join(path, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    blob = {}
    manifest = {"step": step, "extra": extra or {}}
    for name, tree in (("params", params), ("opt", opt_state)):
        leaves, _ = jax.tree.flatten(tree)
        for i, leaf in enumerate(leaves):
            blob[f"{name}_{i}"] = np.asarray(leaf)
        manifest[f"{name}_count"] = len(leaves)
    np.savez(os.path.join(tmp, "arrays.npz"), **blob)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(path, keep_last)
    return final


def _prune(path: str, keep_last: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(path)
                   if d.startswith("step_"))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(path, f"step_{s}"), ignore_errors=True)


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, step: int, params_template, opt_template,
            shardings=None):
    """Restore into the *current* job's pytree templates. ``shardings``: an
    optional params-shaped pytree of jax.sharding.Sharding — re-dices the
    arrays for the new mesh (elastic restart onto fewer/more devices)."""
    d = os.path.join(path, f"step_{step}")
    blob = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    def rebuild(name, template, shard_tree=None):
        leaves, treedef = jax.tree.flatten(template)
        new = []
        shard_leaves = (jax.tree.leaves(shard_tree)
                        if shard_tree is not None else [None] * len(leaves))
        for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = blob[f"{name}_{i}"]
            assert arr.shape == tuple(leaf.shape), \
                f"{name}_{i}: {arr.shape} vs {leaf.shape}"
            if sh is not None:
                new.append(jax.device_put(arr, sh))
            else:
                new.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(treedef, new)

    params = rebuild("params", params_template, shardings)
    opt_state = rebuild("opt", opt_template)
    return params, opt_state, manifest["step"], manifest.get("extra", {})
