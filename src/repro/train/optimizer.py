"""AdamW + global-norm clipping, pure jnp (no external deps)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000


def init_state(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = _schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.beta1, cfg.beta2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    new_state = {"step": step, "mu": mu, "nu": nu}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
