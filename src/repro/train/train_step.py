"""Train / serve step builders: LM cross-entropy, grad accumulation via
lax.scan microbatching (compute/comm overlap comes from XLA latency hiding
over the scan), optional int8-compressed gradient exchange."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.config import ModelConfig
from . import compression, optimizer as opt


def lm_loss(params, cfg: ModelConfig, tokens, frontend=None):
    """Next-token cross entropy. tokens: (B, S) int32."""
    logits = lm.forward(params, cfg, tokens, frontend)
    tgt = tokens[:, 1:]
    lg = logits[:, -tokens.shape[1]:-1, :]
    logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig,
                    grad_accum: int = 1, compress_grads: bool = False,
                    data_axis: str = None):
    """Returns train_step(params, opt_state, err, batch) -> (...)

    ``batch``: dict with tokens (B, S) [+ frontend]. With grad_accum > 1 the
    batch leading dim is split into microbatches scanned sequentially.
    ``data_axis``: if set, gradients go through an explicit (optionally
    compressed) psum over that mesh axis — for use under shard_map; under
    plain pjit the reduction is implicit in the sharding and this stays None.
    """

    def grads_of(params, tokens, frontend):
        return jax.value_and_grad(lm_loss)(params, cfg, tokens, frontend)

    def train_step(params, opt_state, err, batch):
        tokens = batch["tokens"]
        frontend = batch.get("frontend")
        if grad_accum > 1:
            B = tokens.shape[0]
            mb = B // grad_accum
            tok_mb = tokens.reshape(grad_accum, mb, *tokens.shape[1:])
            fe_mb = (frontend.reshape(grad_accum, mb, *frontend.shape[1:])
                     if frontend is not None else None)

            def body(acc, xs):
                tok = xs[0]
                fe = xs[1] if fe_mb is not None else None
                loss, g = grads_of(params, tok, fe)
                acc = (acc[0] + loss,
                       jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    acc[1], g))
                return acc, None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            xs = (tok_mb, fe_mb) if fe_mb is not None else (tok_mb,)
            (loss_sum, gsum), _ = jax.lax.scan(body, (0.0, zero), xs)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        else:
            loss, grads = grads_of(params, tokens, frontend)

        if data_axis is not None:
            if compress_grads:
                grads, err = compression.compressed_psum(grads, err, data_axis)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, data_axis), grads)
        elif compress_grads:
            grads, err = compression.compress(grads, err)
        params, opt_state, metrics = opt.apply_updates(params, grads,
                                                       opt_state, ocfg)
        metrics["loss"] = loss
        return params, opt_state, err, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """serve_step(params, cache, token, rng) -> (next_token, cache)."""

    def serve_step(params, cache, token, rng):
        logits, cache = lm.decode_step(params, cfg, cache, token)
        lg = logits[:, -1, :].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(rng, lg / temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    return serve_step


def make_prefill(cfg: ModelConfig):
    """prefill(params, tokens[, frontend]) -> logits (compiled separately —
    its cost profile differs from both train and decode)."""

    def prefill(params, tokens, frontend=None):
        return lm.forward(params, cfg, tokens, frontend)

    return prefill
