"""Seeded-bug corpus: deliberately broken circuit variants the analyzer
MUST catch — the analyzer's own regression suite (and ``--selftest``).

Each variant starts from an honestly-built registry operator + witness and
injects one classic ZK soundness bug.  Every variant still *accepts the
honest witness* (except the widened rotation, whose point is that the
constraint now mis-fires), which is exactly why these bugs survive code
review and normal testing: proofs of correct executions keep verifying
while a malicious prover gains freedom.  The suite asserts 100% detection
here and zero false positives on the untouched registry.
"""
from __future__ import annotations

import numpy as np

from ..core import ir
from ..core.plonkish import ADVICE, Bus, Col, Const, _Bin
from .runner import analyze_case, default_db, materialize


def _expand_case(db, label: str, with_prop: bool = True):
    node = ir.Expand(ir.BaseTable("knows_date"), ir.Lit(1),
                     with_prop=with_prop)
    return materialize(db, "expand", label,
                       ir.Plan(f"corpus/{label}", (node,), {}), {})


def _orderby_case(db, label: str):
    node = ir.OrderBy(ir.Lit((50, 30, 90, 10, 70, 30)),
                      ir.Lit((11, 12, 13, 14, 15, 16)),
                      k=ir.Lit(3))
    return materialize(db, "orderby", label,
                       ir.Plan(f"corpus/{label}", (node,), {}), {})


def _widen_rot(e, frm: int, to: int):
    """Rewrite every advice-column access at rotation ``frm`` to ``to``."""
    if isinstance(e, Col):
        if e.kind == ADVICE and e.rot == frm:
            return Col(e.kind, e.index, to)
        return e
    if isinstance(e, _Bin):
        return _Bin(e.op, _widen_rot(e.a, frm, to), _widen_rot(e.b, frm, to))
    return e


# -- the six variants --------------------------------------------------------
def v_dropped_selector(db):
    """The edge-region selector is zeroed: every gate it guards silently
    constrains nothing (the completeness flag can point anywhere)."""
    case = _expand_case(db, "dropped_selector")
    c = case.op.circuit
    c.fixed_cols[c.fixed_names.index("sel_edge")][:] = 0
    c._mutated()
    return "dropped_selector", case, {"vacuous-gate"}


def v_widened_rotation(db):
    """orderby's running-count step reads R[i+2] instead of R[i+1]: the
    constraint no longer says what the witness builder satisfies."""
    case = _orderby_case(db, "widened_rotation")
    c = case.op.circuit
    c.gates = [(n, _widen_rot(e, 1, 2) if n == "count_step" else e)
               for n, e in c.gates]
    c._mutated()
    return "widened_rotation", case, {"witness-violation"}


def v_removed_copy_constraint(db):
    """The output-permutation bus is deleted: the public output table is no
    longer bound to the committed edges at all."""
    case = _expand_case(db, "removed_copy_constraint")
    c = case.op.circuit
    c.buses = [b for b in c.buses if b.name != "out_perm"]
    c._mutated()
    return "removed_copy_constraint", case, \
        {"orphan-instance-column", "forgeable-output"}


def v_degree_overflow(db):
    """A degree-6 gate sneaks past the LDE bound (blowup=4): the quotient
    cannot represent it, so the 'constraint' proves nothing."""
    case = _expand_case(db, "degree_overflow")
    c = case.op.circuit
    fl = Col(ADVICE, c.advice_names.index("flag/fl"))
    c.gates.append(("bool_sixth_power",
                    fl * fl * fl * fl * fl * (Const(1) - fl)))
    c._mutated()
    return "degree_overflow", case, {"gate-degree-overflow"}


def v_orphan_advice_column(db):
    """A committed advice column no constraint reads."""
    case = _expand_case(db, "orphan_advice_column")
    c = case.op.circuit
    c.add_advice("scratch")
    case.advice = np.vstack(
        [case.advice, np.zeros((1, c.n_rows), case.advice.dtype)])
    return "orphan_advice_column", case, {"orphan-advice-column"}


def v_free_output_cell(db):
    """The property column is dropped from BOTH sides of the output bus:
    the bus still balances (src/dst coordinates agree) but the public
    C_p output is completely prover-chosen."""
    case = _expand_case(db, "free_output_cell")
    c = case.op.circuit
    c.buses = [Bus(b.name, b.f_tuple[:2], b.t_tuple[:2], b.m_f, b.m_t,
                   b.t_sel, b.auto_mult_col, b.ext_col)
               if b.name == "out_perm" else b for b in c.buses]
    c._mutated()
    return "free_output_cell", case, \
        {"orphan-instance-column", "forgeable-output"}


def _filter_case(db, label: str, cmp: str = "ge", thr: int = 30):
    node = ir.Filter(ir.Chained((ir.Lit(tuple(range(1, 9))),
                                 ir.Lit((5, 30, 17, 30, 2, 99, 42, 8)))),
                     cmp, ir.Lit(thr))
    return materialize(db, "filter", label,
                       ir.Plan(f"corpus/{label}", (node,), {}), {})


def _aggregate_case(db, label: str, agg: str = "sum"):
    node = ir.Aggregate(ir.Chained((ir.Lit((7, 31, 9, 31, 12, 4)),)), agg)
    return materialize(db, "aggregate", label,
                       ir.Plan(f"corpus/{label}", (node,), {}), {})


def _strip_named(c, prefix: str):
    """Delete every gate and bus whose name starts with ``prefix`` (the
    footprint of one add_range_check call: limb buses + recompose gate)."""
    c.gates = [(n, e) for n, e in c.gates if not n.startswith(prefix)]
    c.buses = [b for b in c.buses if not b.name.startswith(prefix)]
    c._mutated()


def v_filter_unchecked_predicate(db):
    """The filter's pass-side range check is deleted: the pass flag is still
    boolean but no longer *evidenced* (V - thr need not be in range), and the
    committed limb columns float free."""
    case = _filter_case(db, "filter_unchecked_predicate")
    _strip_named(case.op.circuit, "cmp_pass")
    return "filter_unchecked_predicate", case, {"orphan-advice-column"}


def v_aggregate_forged_total(db):
    """The bus binding the public sum to the final accumulator is deleted:
    the accumulator still runs honestly but agg_out is prover-chosen."""
    case = _aggregate_case(db, "aggregate_forged_total")
    c = case.op.circuit
    c.buses = [b for b in c.buses if b.name != "agg_bind"]
    c._mutated()
    return "aggregate_forged_total", case, \
        {"orphan-instance-column", "forgeable-output"}


def v_min_missing_bound(db):
    """min's lower-bound range check is deleted: agg_out still originates
    from a marked input row, but nothing forces it to be <= every value —
    the marker can point at any row."""
    case = _aggregate_case(db, "min_missing_bound", agg="min")
    _strip_named(case.op.circuit, "min_le")
    return "min_missing_bound", case, {"orphan-advice-column"}


VARIANTS = (v_dropped_selector, v_widened_rotation, v_removed_copy_constraint,
            v_degree_overflow, v_orphan_advice_column, v_free_output_cell,
            v_filter_unchecked_predicate, v_aggregate_forged_total,
            v_min_missing_bound)


def seeded_variants(db=None) -> list:
    db = default_db() if db is None else db
    return [v(db) for v in VARIANTS]


def honest_bases(db=None) -> list:
    """The unmodified cases the variants start from — the false-positive
    control group."""
    db = default_db() if db is None else db
    return [_expand_case(db, "honest_expand"),
            _orderby_case(db, "honest_orderby"),
            _filter_case(db, "honest_filter"),
            _aggregate_case(db, "honest_agg_sum"),
            _aggregate_case(db, "honest_agg_min", agg="min")]


def run_selftest(seed: int = 0, db=None, verbose: bool = True) -> bool:
    """Every variant detected with the expected check ids, and zero
    error/warning findings on the honest base cases."""
    db = default_db() if db is None else db
    ok = True
    for name, case, expected in seeded_variants(db):
        findings, _ = analyze_case(case, seed=seed)
        got = {f.check for f in findings if f.fails_gate()}
        missed = expected - got
        if verbose:
            mark = "MISSED " + str(sorted(missed)) if missed else "detected"
            print(f"  corpus[{name:24s}] expected {sorted(expected)} "
                  f"-> {mark}")
        ok &= not missed
    for case in honest_bases(db):
        findings, _ = analyze_case(case, seed=seed)
        false_pos = [f for f in findings if f.fails_gate()]
        if false_pos:
            ok = False
            if verbose:
                print(f"  corpus[{case.label}] FALSE POSITIVES: "
                      f"{[(f.check, f.key) for f in false_pos]}")
        elif verbose:
            print(f"  corpus[{case.label:24s}] clean (no false positives)")
    return ok
