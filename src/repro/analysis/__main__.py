"""CLI: ``python -m repro.analysis`` — circuit analyzer + purity lint.

Exit codes: 0 clean (or informational run), 1 unsuppressed gating findings
under ``--fail-on-findings`` (or a failed ``--selftest``), 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import repro

from .findings import apply_baseline, load_baseline, write_baseline
from .purity import run_purity_lint
from .runner import analyze_all


def default_baseline_path() -> Path:
    # repro is a namespace package: src/repro -> parent=src -> repo root
    pkg = Path(next(iter(repro.__path__))).resolve()
    return pkg.parents[1] / "analysis_baseline.json"


def _print_findings(findings, stream=sys.stdout):
    for f in findings:
        loc = f"{f.where}:{f.line}" if f.line else f.where
        print(f"  [{f.severity.upper():7s}] {f.check:28s} {loc}\n"
              f"            {f.detail}", file=stream)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="circuit soundness analyzer + proof-path purity lint")
    ap.add_argument("--all-adapters", action="store_true",
                    help="analyze every registry adapter at its "
                         "representative shapes")
    ap.add_argument("--purity", action="store_true",
                    help="run the proof-path purity lint")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-bug corpus: every deliberately "
                         "broken circuit variant must be detected")
    ap.add_argument("--json", metavar="PATH",
                    help="write the structured JSON report here")
    ap.add_argument("--baseline", metavar="PATH",
                    default=str(default_baseline_path()),
                    help="suppression baseline (default: repo root "
                         "analysis_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the suppression baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current gating findings to the baseline "
                         "file (review the diff!)")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 on unsuppressed error/warning findings")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if not (args.all_adapters or args.purity or args.selftest):
        args.all_adapters = args.purity = True      # bare run = everything

    all_findings = []
    report = None
    purity_files = 0
    if args.all_adapters:
        report = analyze_all(baseline_path=None, seed=args.seed)
        all_findings += report.findings
        print(f"analyzed {len(report.circuits)} circuit case(s) across the "
              f"registry")
    if args.purity:
        pfindings, purity_files = run_purity_lint()
        all_findings += pfindings
        print(f"purity lint scanned {purity_files} file(s) in "
              f"repro.core + repro.serve")

    if args.write_baseline:
        n = write_baseline(all_findings, args.baseline)
        print(f"wrote {n} suppression(s) to {args.baseline}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    # staleness is only meaningful for entries whose pass actually ran:
    # purity suppressions point at .py files, circuit suppressions at cases
    baseline = {t for t in baseline
                if (args.purity if t[1].endswith(".py") else
                    args.all_adapters)}
    kept, suppressed, stale = apply_baseline(all_findings, baseline)

    selftest_failed = False
    if args.selftest:
        from .corpus import run_selftest
        selftest_failed = not run_selftest(seed=args.seed)

    gating = [f for f in kept if f.fails_gate()]
    infos = [f for f in kept if not f.fails_gate()]
    if gating:
        print(f"\n{len(gating)} unsuppressed finding(s):")
        _print_findings(gating)
    if infos:
        print(f"\n{len(infos)} informational note(s):")
        _print_findings(infos)
    if suppressed:
        print(f"\n{len(suppressed)} finding(s) suppressed by baseline")
    if stale:
        print(f"\nWARNING: {len(stale)} stale baseline entr(ies) match "
              f"nothing — remove them:")
        for t in stale:
            print(f"  {t}")
    if not gating:
        print("\nno unsuppressed findings: the registry is clean")

    if args.json:
        doc = report.to_json() if report is not None else dict(
            version=1, summary={}, circuits=[], findings=[])
        doc["summary"]["purity_files_scanned"] = purity_files
        doc["summary"]["suppressed"] = len(suppressed)
        doc["summary"]["stale_baseline"] = len(stale)
        doc["purity"] = dict(
            files_scanned=purity_files,
            findings=[dict(check=f.check, severity=f.severity, where=f.where,
                           line=f.line, key=f.key, detail=f.detail)
                      for f in all_findings if f.where.endswith(".py")])
        doc["gating_after_baseline"] = len(gating)
        doc["suppressed"] = len(suppressed)
        doc["stale_baseline"] = [list(t) for t in stale]
        if args.selftest:
            doc["selftest_passed"] = not selftest_failed
        Path(args.json).write_text(json.dumps(doc, indent=2, default=str)
                                   + "\n")
        print(f"JSON report written to {args.json}")

    if selftest_failed:
        print("SELFTEST FAILED: seeded-bug corpus not fully detected",
              file=sys.stderr)
        return 1
    if args.fail_on_findings and gating:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
