"""Drive the analyzer over every registered adapter at representative shapes.

Each adapter declares its own representative cases via
``Adapter.analysis_cases(db)`` (>= 2 mini-plans whose **last** node is the
adapter's node type — the vetting contract for new adapters, see
docs/analysis.md).  The runner executes each mini-plan against a small
deterministic LDBC graph, then runs the structural pass and the witness
perturbation probe on the resulting circuit + honest witness.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field

import numpy as np

from .findings import Report, apply_baseline, load_baseline
from .structural import analyze_circuit
from .witness import witness_analysis

# same scale as the test-suite graph: big enough for every operator to have
# a non-trivial region, small enough for exhaustive per-column probing
DB_PARAMS = dict(n_knows=96, n_persons=24, n_comments=64, seed=11)


def default_db():
    from ..graphdb import ldbc
    return ldbc.generate(**DB_PARAMS)


@dataclass
class AnalysisCase:
    """One (adapter, representative shape) pair, witness included."""
    adapter: str
    label: str
    op: object                   # operators.common.Operator
    advice: np.ndarray
    instance: np.ndarray
    data: np.ndarray
    extract: object = None       # callable(instance) -> outputs dict
    expected: set = dc_field(default_factory=set)   # corpus: check ids

    @property
    def where(self) -> str:
        return f"{self.adapter}:{self.label}/{self.op.circuit.name}"


def materialize(db, adapter_name: str, label: str, plan, params: dict):
    """Execute a mini-plan; its last step must belong to the adapter."""
    from ..core import ir
    run = ir.execute(db, plan, dict(params))
    step = run.steps[-1]
    assert step.kind == adapter_name, \
        f"analysis plan {label!r} ends in {step.kind!r}, not {adapter_name!r}"
    op = step.op
    from ..core.operators import registry
    ad = registry.adapter_named(adapter_name)

    def extract(instance):
        return ad.extract_outputs(op, instance)

    return AnalysisCase(adapter_name, label, op, step.advice, step.instance,
                        step.data, extract=extract)


def registry_cases(db=None) -> list:
    from ..core.operators import registry
    db = default_db() if db is None else db
    cases = []
    for name, ad in sorted(registry.adapters().items()):
        specs = ad.analysis_cases(db)
        assert len(specs) >= 2, \
            f"adapter {name!r} must declare >= 2 representative analysis " \
            f"shapes (got {len(specs)}) — see docs/analysis.md"
        for label, plan, params in specs:
            cases.append(materialize(db, name, label, plan, params))
    return cases


def analyze_case(case: AnalysisCase, blowup: int = 4, seed: int = 0):
    """Full pipeline on one case: structural checks + witness probe."""
    findings = analyze_circuit(case.op.circuit, case.where, blowup, seed)
    wfindings, coverage = witness_analysis(
        case.op.circuit, case.advice, case.instance, case.data, case.where,
        seed=seed, extract=case.extract)
    stats = dict(adapter=case.adapter, label=case.label,
                 circuit=case.op.circuit.name, n_rows=case.op.circuit.n_rows,
                 gates=case.op.circuit.gate_info(), coverage=coverage)
    return findings + wfindings, stats


def analyze_all(db=None, baseline_path=None, blowup: int = 4,
                seed: int = 0) -> Report:
    """Analyze every registry adapter; apply the suppression baseline."""
    report = Report()
    for case in registry_cases(db):
        findings, stats = analyze_case(case, blowup, seed)
        report.extend(findings)
        report.circuits.append(stats)
    if baseline_path is not None:
        kept, suppressed, stale = apply_baseline(
            report.findings, load_baseline(baseline_path))
        report.findings = kept
        report.suppressed = suppressed
        report.stale_baseline = stale
    return report
