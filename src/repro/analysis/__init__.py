"""Circuit soundness analyzer + proof-path purity lint (docs/analysis.md).

Two passes, one gate:

* ``analyze_circuit`` / ``analyze_all`` — static + witness-perturbation
  analysis of every registered operator circuit at representative shapes:
  under-constraint detection (free cells a malicious prover could choose),
  gate degree/rotation/vacuousness checks, and column-connectivity checks.
* ``run_purity_lint`` — a Python-AST lint over ``repro.core`` +
  ``repro.serve`` forbidding nondeterminism and unsoundness sources on the
  prove/verify path (wall-clock, unseeded randomness, float arithmetic in
  field code, pickle, set iteration, unlocked shared-state mutation, and
  imports of the quarantined LM-training modules).

``python -m repro.analysis`` runs both and emits a structured JSON report;
``analysis_baseline.json`` at the repo root suppresses the accepted
findings.  CI runs the analyzer over the full registry on every PR.
"""
from .findings import (Finding, Report, apply_baseline, load_baseline,
                       write_baseline)
from .purity import run_purity_lint
from .runner import analyze_all, analyze_case, registry_cases
from .structural import analyze_circuit

__all__ = [
    "Finding", "Report", "analyze_all", "analyze_case", "analyze_circuit",
    "apply_baseline", "load_baseline", "registry_cases", "run_purity_lint",
    "write_baseline",
]
