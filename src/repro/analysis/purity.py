"""Proof-path purity lint: a Python-AST pass over repro.core + repro.serve.

Soundness of a Fiat–Shamir proof system is a determinism property: the
prover and verifier must derive bit-identical transcripts, and nothing on
the prove/verify path may depend on wall-clock, ambient randomness, or
float rounding.  This lint bans those sources *mechanically*:

Rules (check id → scope → severity):

* ``banned-import``   — pickle/dill/shelve/marshal anywhere in core+serve
  (the wire codec replaced pickle in PR 2; it must never creep back);
  ``time``/``random``/``secrets`` in PROOF-PATH modules (legitimate
  timing diagnostics are suppressed via the committed baseline).  ERROR.
* ``quarantine-breach`` — ``repro.train`` / ``repro.models`` /
  ``repro.configs.lm`` imported anywhere in core+serve (regression guard
  for the PR 6 LM-training quarantine; relative imports resolved).  ERROR.
* ``float-in-field-code`` — float literals, ``float()``/``complex()``
  casts, ``np.float*``/``np.double`` attributes, or true division ``/``
  in PROOF-PATH modules: field arithmetic is exact; float rounding
  silently corrupts witnesses (cf. the float-weighted bincount this PR
  removed from ``auto_multiplicities``).  ERROR.
* ``unseeded-rng``    — ``default_rng()`` with no seed, or global-state
  ``np.random.<fn>`` calls, anywhere in core+serve.  Keyed ``jax.random``
  and seeded generators are fine.  ERROR.
* ``nondet-iteration`` — ``for``/comprehension iterating a set literal,
  set()/frozenset() call, or set comprehension directly: iteration order
  is hash-randomized across processes, so anything transcript-adjacent
  becomes irreproducible.  WARNING.
* ``eval-exec``       — bare ``eval``/``exec`` calls.  ERROR.
* ``unlocked-serve-state`` — in repro.serve: a class that owns a
  ``_lock`` writes ``self.*`` outside ``__init__`` without holding a
  ``with …_lock:`` block.  WARNING.

Finding keys are the *stripped source line*, so baseline suppressions
survive line drift but die with the code they cover.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import ERROR, WARNING, Finding

#: modules where the prover/verifier transcript is actually computed —
#: the strict scope for the wall-clock / randomness / float rules.
PROOF_PATH_MODULES = {
    "core/field.py", "core/poly.py", "core/fri.py", "core/merkle.py",
    "core/hashing.py", "core/transcript.py", "core/plonkish.py",
    "core/prover.py", "core/prover_batch.py", "core/verifier.py",
    "core/commit.py",
}
PROOF_PATH_DIRS = ("core/operators/",)

BANNED_EVERYWHERE = {"pickle", "dill", "shelve", "marshal"}
BANNED_PROOF_PATH = {"time", "random", "secrets"}
QUARANTINED = ("repro.train", "repro.models", "repro.configs.lm")
_GLOBAL_NP_RANDOM = {"rand", "randn", "randint", "random", "choice",
                     "shuffle", "permutation", "seed", "random_sample",
                     "uniform", "normal"}


def is_proof_path(relpath: str) -> bool:
    return (relpath in PROOF_PATH_MODULES
            or relpath.startswith(PROOF_PATH_DIRS))


def _line(src_lines, node) -> str:
    try:
        return src_lines[node.lineno - 1].strip()
    except IndexError:
        return ""


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str, src: str, in_serve: bool):
        self.relpath = relpath
        self.lines = src.splitlines()
        self.proof = is_proof_path(relpath)
        self.in_serve = in_serve
        self.findings: list = []
        self._with_lock_depth = 0
        self._method: str | None = None
        self._class_has_lock = False

    # -- helpers -----------------------------------------------------------
    def emit(self, check, severity, node, detail):
        self.findings.append(Finding(
            check, severity, self.relpath, _line(self.lines, node),
            detail, line=getattr(node, "lineno", 0)))

    def _check_module_name(self, name: str, node):
        root = name.split(".")[0]
        if root in BANNED_EVERYWHERE:
            self.emit("banned-import", ERROR, node,
                      f"{self.relpath} imports {name!r}: pickle-family "
                      f"serialization is banned (use repro.core.wire)")
        elif self.proof and root in BANNED_PROOF_PATH:
            self.emit("banned-import", ERROR, node,
                      f"proof-path module {self.relpath} imports {name!r}: "
                      f"wall-clock/ambient randomness cannot feed the "
                      f"transcript")
        for q in QUARANTINED:
            if name == q or name.startswith(q + "."):
                self.emit("quarantine-breach", ERROR, node,
                          f"{self.relpath} imports quarantined module "
                          f"{name!r}: LM-training code must stay off the "
                          f"zkgraph import path")

    def _resolve_relative(self, node: ast.ImportFrom) -> str:
        # repro/core/x.py with level=2, module="train" -> repro.train
        parts = ("repro/" + self.relpath).split("/")
        pkg = parts[:-1]                       # package path of this module
        up = node.level - 1
        base = pkg[: len(pkg) - up] if up else pkg
        mod = ".".join(base)
        return f"{mod}.{node.module}" if node.module else mod

    # -- imports -----------------------------------------------------------
    def visit_Import(self, node):
        for alias in node.names:
            self._check_module_name(alias.name, node)
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        if node.level == 0:
            name = node.module or ""
            self._check_module_name(name, node)
            for alias in node.names:
                self._check_module_name(f"{name}.{alias.name}", node)
        else:
            base = self._resolve_relative(node)
            self._check_module_name(base, node)
            for alias in node.names:
                self._check_module_name(f"{base}.{alias.name}", node)
        self.generic_visit(node)

    # -- floats ------------------------------------------------------------
    def visit_Constant(self, node):
        if self.proof and isinstance(node.value, (float, complex)):
            self.emit("float-in-field-code", ERROR, node,
                      f"float literal {node.value!r} in proof-path module "
                      f"{self.relpath}: field arithmetic must stay exact")
        self.generic_visit(node)

    def visit_BinOp(self, node):
        if self.proof and isinstance(node.op, ast.Div):
            self.emit("float-in-field-code", ERROR, node,
                      f"true division in proof-path module {self.relpath}: "
                      f"use modular inverse (finv) or // for integers")
        self.generic_visit(node)

    def visit_Attribute(self, node):
        if self.proof and node.attr in ("float16", "float32", "float64",
                                        "float_", "double", "half"):
            self.emit("float-in-field-code", ERROR, node,
                      f"float dtype .{node.attr} in proof-path module "
                      f"{self.relpath}")
        # np.random.<global-state fn>
        if (isinstance(node.value, ast.Attribute)
                and node.value.attr == "random"
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id in ("np", "numpy")
                and node.attr in _GLOBAL_NP_RANDOM):
            self.emit("unseeded-rng", ERROR, node,
                      f"global-state np.random.{node.attr} in "
                      f"{self.relpath}: use a seeded Generator")
        self.generic_visit(node)

    # -- calls -------------------------------------------------------------
    def visit_Call(self, node):
        fn = node.func
        if isinstance(fn, ast.Name):
            if fn.id in ("eval", "exec"):
                self.emit("eval-exec", ERROR, node,
                          f"bare {fn.id}() in {self.relpath}")
            if self.proof and fn.id in ("float", "complex"):
                self.emit("float-in-field-code", ERROR, node,
                          f"{fn.id}() cast in proof-path module "
                          f"{self.relpath}")
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if name == "default_rng" and not node.args and not node.keywords:
            self.emit("unseeded-rng", ERROR, node,
                      f"default_rng() without a seed in {self.relpath}: "
                      f"OS-entropy seeding is irreproducible")
        self.generic_visit(node)

    # -- set iteration -----------------------------------------------------
    @staticmethod
    def _is_set_expr(e) -> bool:
        if isinstance(e, (ast.Set, ast.SetComp)):
            return True
        return (isinstance(e, ast.Call) and isinstance(e.func, ast.Name)
                and e.func.id in ("set", "frozenset"))

    def _check_iter(self, it, node):
        if self._is_set_expr(it):
            self.emit("nondet-iteration", WARNING, node,
                      f"iteration over a set in {self.relpath}: set order "
                      f"is hash-randomized; sort first")

    def visit_For(self, node):
        self._check_iter(node.iter, node)
        self.generic_visit(node)

    def _visit_comp(self, node):
        for gen in node.generators:
            self._check_iter(gen.iter, node)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_GeneratorExp = _visit_comp

    def visit_DictComp(self, node):
        self._visit_comp(node)

    # -- serve lock discipline --------------------------------------------
    def visit_ClassDef(self, node):
        if not self.in_serve:
            self.generic_visit(node)
            return
        prev = self._class_has_lock
        self._class_has_lock = any(
            isinstance(t, ast.Attribute) and t.attr.endswith("_lock")
            and isinstance(t.value, ast.Name) and t.value.id == "self"
            for fn in node.body if isinstance(fn, ast.FunctionDef)
            for st in ast.walk(fn) for t in _assign_targets(st))
        self.generic_visit(node)
        self._class_has_lock = prev

    def visit_FunctionDef(self, node):
        prev = self._method
        self._method = node.name
        self.generic_visit(node)
        self._method = prev

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_With(self, node):
        holds = any(_is_lock_ctx(item.context_expr) for item in node.items)
        if holds:
            self._with_lock_depth += 1
        self.generic_visit(node)
        if holds:
            self._with_lock_depth -= 1

    def _check_self_write(self, node):
        if (self.in_serve and self._class_has_lock
                and self._method not in (None, "__init__")
                and self._with_lock_depth == 0):
            for t in _assign_targets(node):
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                        and not t.attr.endswith("_lock")):
                    self.emit(
                        "unlocked-serve-state", WARNING, node,
                        f"self.{t.attr} written outside `with …_lock:` in a "
                        f"lock-owning serve class ({self.relpath}): racy "
                        f"shared-state mutation")

    def visit_Assign(self, node):
        self._check_self_write(node)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._check_self_write(node)
        self.generic_visit(node)


def _assign_targets(node):
    if isinstance(node, ast.Assign):
        return node.targets
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    return []


def _is_lock_ctx(e) -> bool:
    """with self._lock / with self.x._lock / with lock."""
    if isinstance(e, ast.Attribute):
        return e.attr.endswith("_lock")
    if isinstance(e, ast.Name):
        return e.id.endswith("_lock") or e.id == "lock"
    return False


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def lint_source(relpath: str, src: str) -> list:
    """Lint one file's source; relpath is relative to the repro package
    (e.g. "core/prover.py")."""
    v = _Visitor(relpath, src, in_serve=relpath.startswith("serve/"))
    v.visit(ast.parse(src, filename=relpath))
    return v.findings


def run_purity_lint(pkg_root=None):
    """Lint repro/core + repro/serve; returns (findings, files_scanned)."""
    if pkg_root is None:
        pkg_root = Path(__file__).resolve().parent.parent   # src/repro
    pkg_root = Path(pkg_root)
    findings = []
    n_files = 0
    for sub in ("core", "serve"):
        for path in sorted((pkg_root / sub).rglob("*.py")):
            rel = path.relative_to(pkg_root).as_posix()
            findings += lint_source(rel, path.read_text())
            n_files += 1
    return findings, n_files
