"""Finding/report model + the committed suppression baseline.

A finding's identity is ``(check, where, key)``:

* ``check`` — the check id from the catalogue in docs/analysis.md
* ``where`` — the circuit context ("adapter:label/circuit") or, for purity
  findings, the repo-relative file path
* ``key``   — a stable detail key: column/gate name for circuit findings,
  the *stripped source line* for purity findings (immune to line drift)

The baseline file stores exactly these triples, so a suppression survives
refactors that move code around but dies with the code it covers — a stale
entry is reported so baselines only ever shrink.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field as dc_field
from pathlib import Path

ERROR, WARNING, INFO = "error", "warning", "info"
_SEV_ORDER = {ERROR: 0, WARNING: 1, INFO: 2}

#: the full check catalogue — docs/analysis.md documents every id here
#: (pinned by tests/test_docs.py) and the emitting modules never invent
#: ids outside it (pinned by tests/test_analysis.py)
ALL_CHECKS = frozenset({
    # structural (repro.analysis.structural)
    "gate-degree-overflow", "bus-degree-overflow", "gp-degree-overflow",
    "rotation-out-of-range", "unguarded-wrap",
    "vacuous-gate", "vacuous-bus", "vacuous-gp",
    "orphan-advice-column", "orphan-instance-column", "orphan-data-column",
    "unused-fixed-column", "floating-advice-component",
    # witness probe (repro.analysis.witness)
    "witness-violation", "forgeable-output", "unconstrained-advice-column",
    # purity lint (repro.analysis.purity)
    "banned-import", "quarantine-breach", "float-in-field-code",
    "unseeded-rng", "nondet-iteration", "eval-exec", "unlocked-serve-state",
})


@dataclass(frozen=True)
class Finding:
    check: str
    severity: str
    where: str
    key: str
    detail: str
    line: int = 0            # purity findings only (0 = not line-anchored)

    def ident(self) -> tuple:
        return (self.check, self.where, self.key)

    def fails_gate(self) -> bool:
        return self.severity in (ERROR, WARNING)


@dataclass
class Report:
    """One analyzer run: findings + per-circuit coverage stats."""
    findings: list = dc_field(default_factory=list)
    circuits: list = dc_field(default_factory=list)   # per-case stat dicts
    purity_files: int = 0
    suppressed: list = dc_field(default_factory=list)
    stale_baseline: list = dc_field(default_factory=list)

    def extend(self, findings):
        self.findings.extend(findings)

    def gating(self) -> list:
        return [f for f in self.findings if f.fails_gate()]

    def sorted_findings(self) -> list:
        return sorted(self.findings,
                      key=lambda f: (_SEV_ORDER[f.severity], f.ident()))

    def to_json(self) -> dict:
        return dict(
            version=1,
            summary=dict(
                errors=sum(f.severity == ERROR for f in self.findings),
                warnings=sum(f.severity == WARNING for f in self.findings),
                infos=sum(f.severity == INFO for f in self.findings),
                suppressed=len(self.suppressed),
                stale_baseline=len(self.stale_baseline),
                circuits_analyzed=len(self.circuits),
                purity_files_scanned=self.purity_files,
            ),
            findings=[asdict(f) for f in self.sorted_findings()],
            suppressed=[asdict(f) for f in sorted(
                self.suppressed, key=lambda f: f.ident())],
            stale_baseline=[list(t) for t in sorted(self.stale_baseline)],
            circuits=self.circuits,
        )


# ---------------------------------------------------------------------------
# suppression baseline
# ---------------------------------------------------------------------------
def load_baseline(path) -> set:
    """Load suppression triples; a missing file is an empty baseline."""
    p = Path(path)
    if not p.exists():
        return set()
    doc = json.loads(p.read_text())
    assert doc.get("version") == 1, f"unknown baseline version in {path}"
    return {(e["check"], e["where"], e["key"]) for e in doc["suppressions"]}


def apply_baseline(findings, baseline: set):
    """Split findings into (unsuppressed, suppressed) and report baseline
    entries that no longer match anything (stale)."""
    kept, suppressed, hit = [], [], set()
    for f in findings:
        if f.ident() in baseline:
            suppressed.append(f)
            hit.add(f.ident())
        else:
            kept.append(f)
    return kept, suppressed, sorted(baseline - hit)


def write_baseline(findings, path):
    """Write every gating finding as a suppression (review the diff!)."""
    entries = sorted({f.ident() for f in findings if f.fails_gate()})
    doc = dict(version=1, suppressions=[
        dict(check=c, where=w, key=k) for c, w, k in entries])
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")
    return len(entries)
