"""Static circuit checks: degree, rotations, vacuousness, connectivity.

Everything here reads only the circuit *structure* (plus the concrete fixed
columns, which are structure too) — no witness needed.  The witness-side
under-constraint probe lives in :mod:`repro.analysis.witness`.

Check catalogue (docs/analysis.md):

* ``gate-degree-overflow`` / ``bus-degree-overflow`` / ``gp-degree-overflow``
  — constraint degree exceeds the quotient/LDE bound (= blowup): the LDE
  domain cannot faithfully represent the constraint polynomial, so the
  prover's quotient is meaningless and completeness/soundness both break.
* ``rotation-out-of-range`` — |rot| >= n_rows wraps to a smaller rotation
  under ``jnp.roll`` (rot = n_rows is the identity!), silently constraining
  different cells than the author intended.
* ``unguarded-wrap`` — a gate/bus reads an advice/data column at rot != 0
  without a pure-fixed multiplicative guard vanishing on the wrap rows:
  the constraint couples the column's tail to its head across the cyclic
  boundary.  Instance rotations are exempt (public columns: the verifier
  sees the wrap rows; the seed circuits use them deliberately).
* ``vacuous-gate`` — fixed guard identically zero, or the gate evaluates to
  zero on random witnesses (identically-zero polynomial whp): the gate
  constrains nothing.
* ``vacuous-bus`` / ``vacuous-gp`` — a side's pure-fixed selector is
  identically zero: the argument degenerates.
* ``orphan-advice/instance/data-column`` — a column no constraint ever
  reads: a prover (or, for instance columns, anyone presenting the proof)
  can put arbitrary values there.
* ``unused-fixed-column`` — dead structure (warning; ``__row0`` exempt:
  keygen appends it for grand-product boundaries).
* ``floating-advice-component`` — a connected component of the
  column-co-occurrence graph containing only advice columns: a subcircuit
  anchored to no fixed structure, public input, or committed data.
"""
from __future__ import annotations

import numpy as np

from ..core import field as F
from ..core.plonkish import (ADVICE, DATA, FIXED, INSTANCE, Circuit,
                             eval_fixed_np, is_fixed_only, mul_factors)
from .findings import ERROR, WARNING, Finding

_KIND_CHECK = {ADVICE: "orphan-advice-column",
               INSTANCE: "orphan-instance-column",
               DATA: "orphan-data-column"}


def analyze_circuit(circuit: Circuit, where: str, blowup: int = 4,
                    seed: int = 0) -> list:
    """Run every structural check; returns a list of Findings."""
    circuit.assign_ext_cols()
    out = []
    out += check_degrees(circuit, where, blowup)
    out += check_rotations(circuit, where)
    out += check_vacuous(circuit, where, seed)
    out += check_columns(circuit, where)
    return out


# ---------------------------------------------------------------------------
# degrees
# ---------------------------------------------------------------------------
def bus_degree(bus) -> int:
    """Degree of the logUp bus constraint
    (h1-h)*d_f*d_t - m_f*d_t + m_t*t_sel*d_f  (h is a committed column)."""
    deg_f = max(e.degree() for e in bus.f_tuple)
    deg_t = max(e.degree() for e in bus.t_tuple)
    return max(1 + deg_f + deg_t,
               bus.m_f.degree() + deg_t,
               bus.m_t.degree() + bus.t_sel.degree() + deg_f)


def gp_degree(gp) -> int:
    """Degree of the grand-product constraint z1*f2 - z*f1 plus the
    row0*(z-1) boundary term, with f = d*s + (1-s)."""
    d1 = max(e.degree() for e in gp.c1_tuple) + gp.sel1.degree()
    d2 = max(e.degree() for e in gp.c2_tuple) + gp.sel2.degree()
    f1 = max(d1, gp.sel1.degree())
    f2 = max(d2, gp.sel2.degree())
    return max(1 + f1, 1 + f2, 2)


def check_degrees(circuit: Circuit, where: str, blowup: int) -> list:
    out = []
    for name, e in circuit.gates:
        d = e.degree()
        if d > blowup:
            out.append(Finding("gate-degree-overflow", ERROR, where, name,
                               f"gate {name!r} has degree {d} > LDE bound "
                               f"{blowup}: the quotient cannot represent it"))
    for bus in circuit.buses:
        d = bus_degree(bus)
        if d > blowup:
            out.append(Finding("bus-degree-overflow", ERROR, where, bus.name,
                               f"bus {bus.name!r} constraint degree {d} > "
                               f"LDE bound {blowup}"))
    for gp in circuit.gps:
        d = gp_degree(gp)
        if d > blowup:
            out.append(Finding("gp-degree-overflow", ERROR, where, gp.name,
                               f"grand product {gp.name!r} constraint degree "
                               f"{d} > LDE bound {blowup}"))
    return out


# ---------------------------------------------------------------------------
# rotations
# ---------------------------------------------------------------------------
def _wrap_rows(rot: int, n: int) -> np.ndarray:
    """Rows whose access at +rot crosses the cyclic boundary."""
    if rot > 0:
        return np.arange(n - rot, n)
    return np.arange(0, -rot)


def _fixed_guard(exprs, circuit: Circuit):
    """Product of the pure-fixed multiplicative factors shared by every
    expression's top level; None when there is no fixed factor at all."""
    guard = None
    for e in exprs:
        for fac in mul_factors(e):
            if is_fixed_only(fac) and fac.atoms():
                v = eval_fixed_np(fac, circuit.fixed_cols, circuit.n_rows)
                guard = v if guard is None else (guard * v) % F.P
    return guard


def check_rotations(circuit: Circuit, where: str) -> list:
    out = []
    n = circuit.n_rows
    seen_oor = set()
    for ckind, name, exprs in circuit.constraint_exprs():
        rots = set()
        for e in exprs:
            rots |= e.rotations()
        for (kind, idx, rot) in sorted(rots):
            if abs(rot) >= n and (name, kind, idx) not in seen_oor:
                seen_oor.add((name, kind, idx))
                out.append(Finding(
                    "rotation-out-of-range", ERROR, where, name,
                    f"{ckind} {name!r} reads {kind}[{idx}] at rotation {rot} "
                    f"with only {n} rows: jnp.roll wraps it to {rot % n}"))
        # wrap guard: only prover-chosen (advice) and committed-data columns
        wraps = sorted({r for (k, _, r) in rots
                       if r != 0 and abs(r) < n and k in (ADVICE, DATA)})
        if not wraps:
            continue
        if ckind == "gate":
            guard = _fixed_guard(exprs, circuit)
        elif ckind == "bus":
            bus = next(b for b in circuit.buses if b.name == name)
            f_rots = any(r != 0 for e in (*bus.f_tuple, bus.m_f)
                         for (k, _, r) in e.rotations() if k in (ADVICE, DATA))
            guard_exprs = (bus.m_f,) if f_rots else (bus.t_sel,)
            guard = _fixed_guard(guard_exprs, circuit)
        else:
            gp = next(g for g in circuit.gps if g.name == name)
            guard = _fixed_guard((gp.sel1, gp.sel2), circuit)
        for rot in wraps:
            rows = _wrap_rows(rot, n)
            if guard is None or np.any(guard[rows] != 0):
                out.append(Finding(
                    "unguarded-wrap", WARNING, where, f"{name}@{rot}",
                    f"{ckind} {name!r} reads an advice/data column at "
                    f"rotation {rot} without a fixed guard vanishing on the "
                    f"wrap rows {rows[:4].tolist()}…: the constraint couples "
                    f"the column tail to its head"))
    return out


# ---------------------------------------------------------------------------
# vacuousness
# ---------------------------------------------------------------------------
def _random_sources(circuit: Circuit, rng) -> dict:
    n = circuit.n_rows
    fixed = (np.stack(circuit.fixed_cols).astype(np.int64)
             if circuit.fixed_cols else np.zeros((0, n), np.int64))
    return {
        FIXED: fixed,
        ADVICE: rng.integers(0, F.P, (circuit.n_advice, n)),
        INSTANCE: rng.integers(0, F.P, (circuit.n_instance, n)),
        DATA: rng.integers(0, F.P, (circuit.n_data, n)),
    }


def _np_eval(expr, srcs, n: int) -> np.ndarray:
    from ..core.plonkish import Col, Const, _Bin
    if isinstance(expr, Const):
        return np.full(n, expr.value % F.P, np.int64)
    if isinstance(expr, Col):
        return np.roll(srcs[expr.kind][expr.index] % F.P, -expr.rot)
    assert isinstance(expr, _Bin)
    a = _np_eval(expr.a, srcs, n)
    b = _np_eval(expr.b, srcs, n)
    if expr.op == "add":
        return (a + b) % F.P
    if expr.op == "sub":
        return (a - b) % F.P
    return (a * b) % F.P


def check_vacuous(circuit: Circuit, where: str, seed: int = 0) -> list:
    out = []
    n = circuit.n_rows
    rng = np.random.default_rng(seed)
    trials = [_random_sources(circuit, rng) for _ in range(2)]
    for name, e in circuit.gates:
        guard = _fixed_guard((e,), circuit)
        if guard is not None and not np.any(guard):
            out.append(Finding(
                "vacuous-gate", ERROR, where, name,
                f"gate {name!r} has a fixed guard that is identically zero: "
                f"it constrains nothing on any row"))
            continue
        if all(not np.any(_np_eval(e, srcs, n)) for srcs in trials):
            out.append(Finding(
                "vacuous-gate", ERROR, where, name,
                f"gate {name!r} evaluates to zero on random witnesses: it is "
                f"the zero polynomial (whp) and constrains nothing"))
    for bus in circuit.buses:
        for label, sel in (("f-side multiplicity m_f", bus.m_f),
                           ("t-side selector t_sel", bus.t_sel)):
            if is_fixed_only(sel) and not np.any(
                    eval_fixed_np(sel, circuit.fixed_cols, n)):
                out.append(Finding(
                    "vacuous-bus", ERROR, where, bus.name,
                    f"bus {bus.name!r} {label} is identically zero: the "
                    f"argument degenerates"))
    for gp in circuit.gps:
        zeros = [is_fixed_only(s) and not np.any(
                     eval_fixed_np(s, circuit.fixed_cols, n))
                 for s in (gp.sel1, gp.sel2)]
        if all(zeros):
            out.append(Finding(
                "vacuous-gp", ERROR, where, gp.name,
                f"grand product {gp.name!r} has both selectors identically "
                f"zero: the argument is trivially satisfied"))
    return out


# ---------------------------------------------------------------------------
# columns + connectivity
# ---------------------------------------------------------------------------
def _names(circuit: Circuit, kind: str) -> list:
    return {FIXED: circuit.fixed_names, ADVICE: circuit.advice_names,
            INSTANCE: circuit.instance_names,
            DATA: circuit.data_names}[kind]


def check_columns(circuit: Circuit, where: str) -> list:
    out = []
    refs = circuit.referenced_cols()
    for kind, check in _KIND_CHECK.items():
        names = _names(circuit, kind)
        for i, colname in enumerate(names):
            if i not in refs[kind]:
                out.append(Finding(
                    check, ERROR, where, colname,
                    f"{kind} column {colname!r} appears in no gate, bus, or "
                    f"grand product: its values are entirely unconstrained"))
    for i, colname in enumerate(circuit.fixed_names):
        if i not in refs[FIXED] and colname != "__row0":
            out.append(Finding(
                "unused-fixed-column", WARNING, where, colname,
                f"fixed column {colname!r} is dead structure (committed but "
                f"never read by any constraint)"))
    out += _check_connectivity(circuit, where)
    return out


def _check_connectivity(circuit: Circuit, where: str) -> list:
    """Union-find over columns; constraints are hyper-edges."""
    parent = {}

    def find(x):
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        parent[find(a)] = find(b)

    for _, _, exprs in circuit.constraint_exprs():
        cols = sorted({(a.kind, a.index) for e in exprs for a in e.atoms()})
        for c in cols[1:]:
            union(cols[0], c)
    comps = {}
    for node in list(parent):
        comps.setdefault(find(node), []).append(node)
    out = []
    for members in comps.values():
        if all(k == ADVICE for k, _ in members):
            names = sorted(circuit.advice_names[i] for _, i in members)
            out.append(Finding(
                "floating-advice-component", WARNING, where,
                ",".join(names),
                f"advice columns {names} form a constraint component touching "
                f"no fixed/instance/data column: a free-floating subcircuit"))
    return out
