"""Under-constraint detection by stride-decoupled witness perturbation.

The question a soundness reviewer actually asks of a circuit is: *which
witness cells can a malicious prover change without violating anything?*
This module answers it mechanically (Picus/Circomspect-style mutation
probing, adapted to this codebase's PLONKish semantics):

1. Fill the honest witness once (auto-multiplicity columns included —
   and **never** refilled afterwards: refilling would let the framework
   absorb a perturbation the constraint system must catch itself).
2. For each advice/instance column, perturb cells with iid random deltas
   and re-evaluate every constraint that reads the column.  Perturbed
   cells are spaced by a stride larger than the column's rotation
   diameter, so any affected constraint row reads **exactly one**
   perturbed cell — per-cell attribution is exact, not heuristic:
   * gates: a nonzero residual at row j binds the unique perturbed cell
     among {(j+r) mod n}.
   * buses (logUp): soundness is the *global* sum; a cell is bound iff
     its total increment-diff over its attributed rows is nonzero.
   * grand products: a cell is bound iff an attributed row's factor
     changed (ratio cancellation across rows has probability ~|Fp4|^-1).
3. Cells no constraint reacts to are *free*.  Free advice cells are
   usually benign padding (reported as coverage stats); a *fully* free
   advice column is a warning.  Free **instance** cells are classified
   semantically: if perturbing them changes what the adapter's
   ``extract_outputs`` reads out of the (still-verifying!) instance, a
   prover can forge query results — the ``forgeable-output`` ERROR, the
   exact bug class this analyzer exists for.  Every forgery claim is
   re-verified by running the full honest check on the perturbed witness
   before it is reported, so false positives are essentially impossible.

Known limits (documented, by design): random deltas do not detect freedom
*within* a constrained subset (e.g. a boolean-gated cell that may be 0 or
1), nor coordinated multi-cell forgeries; data columns are not probed
(they are bound externally by the published dataset commitment).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import field as F
from ..core import prover as pv
from ..core.plonkish import (ADVICE, DATA, FIXED, INSTANCE, BaseOps,
                             compress_tuple, eval_expr)
from .findings import ERROR, WARNING, Finding

_PROBED_KINDS = (ADVICE, INSTANCE)


# ---------------------------------------------------------------------------
# numpy Fp4 helpers (host-side; tiny arrays, exact int64 arithmetic)
# ---------------------------------------------------------------------------
def _emul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Schoolbook Fp4 multiply, x^4 = W_EXT, on (..., 4) int64 arrays."""
    c = np.zeros(np.broadcast_shapes(a.shape, b.shape), np.int64)
    for i in range(4):
        for j in range(4):
            t = a[..., i] * b[..., j] % F.P
            k = i + j
            if k >= 4:
                c[..., k - 4] = (c[..., k - 4] + t * F.W_EXT) % F.P
            else:
                c[..., k] = (c[..., k] + t) % F.P
    return c


def _eprod_np(rows: np.ndarray) -> np.ndarray:
    """Product over axis 0 of an (n, 4) ext array (pairwise tree)."""
    one = np.array([1, 0, 0, 0], np.int64)
    a = rows % F.P
    while a.shape[0] > 1:
        if a.shape[0] % 2:
            a = np.concatenate([a, one[None, :]], axis=0)
        a = _emul_np(a[0::2], a[1::2])
    return a[0] if a.shape[0] else one


# ---------------------------------------------------------------------------
# constraint evaluation over a concrete assignment
# ---------------------------------------------------------------------------
class _Evaluator:
    """Evaluates gates/bus-increments/gp-factors for one assignment."""

    def __init__(self, circuit, srcs: dict, alpha, beta):
        self.c = circuit
        self.srcs = srcs
        self.alpha = jnp.asarray(alpha)
        self.beta = jnp.asarray(beta)
        self.n = circuit.n_rows
        self.like = jnp.zeros(self.n, jnp.uint32)
        self._cache = {}

    def getter(self, kind, idx, rot):
        key = (kind, idx, rot)
        v = self._cache.get(key)
        if v is None:
            col = np.roll(self.srcs[kind][idx] % F.P, -rot)
            v = jnp.asarray(col.astype(np.uint32))
            self._cache[key] = v
        return v

    def gate_residual(self, expr) -> np.ndarray:
        v = eval_expr(expr, self.getter, BaseOps, self.like)
        return np.asarray(v, np.int64)

    def bus_inc(self, bus) -> np.ndarray:
        """Per-row logUp increment m_f/(β+αf) − m_t·t_sel/(β+αt), (n,4)."""
        f_vals = [eval_expr(e, self.getter, BaseOps, self.like)
                  for e in bus.f_tuple]
        t_vals = [eval_expr(e, self.getter, BaseOps, self.like)
                  for e in bus.t_tuple]
        m_f = eval_expr(bus.m_f, self.getter, BaseOps, self.like)
        m_t = eval_expr(bus.m_t * bus.t_sel, self.getter, BaseOps, self.like)
        bb = jnp.broadcast_to(self.beta, (self.n, 4))
        d_f = F.eadd(bb, compress_tuple(f_vals, self.alpha))
        d_t = F.eadd(bb, compress_tuple(t_vals, self.alpha))
        num = F.esub(F.fmul(d_t, m_f[:, None]), F.fmul(d_f, m_t[:, None]))
        inc = F.emul(num, F.ebatch_inv(F.emul(d_f, d_t)))
        return np.asarray(inc, np.int64)

    def gp_factors(self, gp) -> tuple:
        out = []
        bb = jnp.broadcast_to(self.beta, (self.n, 4))
        one = jnp.zeros((self.n, 4), jnp.uint32).at[:, 0].set(1)
        for tup, sel in ((gp.c1_tuple, gp.sel1), (gp.c2_tuple, gp.sel2)):
            vals = [eval_expr(e, self.getter, BaseOps, self.like) for e in tup]
            s = eval_expr(sel, self.getter, BaseOps, self.like)
            d = F.eadd(bb, compress_tuple(vals, self.alpha))
            not_s = F.fsub(jnp.full_like(s, 1), s)
            f = F.eadd(F.fmul(d, s[:, None]), F.fmul(one, not_s[:, None]))
            out.append(np.asarray(f, np.int64))
        return tuple(out)


def _constraints_of(circuit) -> list:
    """[(kind, name, obj, per-column rotation map)] for every constraint."""
    out = []
    for ckind, name, exprs in circuit.constraint_exprs():
        rotmap = {}
        for e in exprs:
            for (k, i, r) in e.rotations():
                rotmap.setdefault((k, i), set()).add(r)
        obj = None
        if ckind == "gate":
            obj = next(e for gname, e in circuit.gates if gname == name)
        elif ckind == "bus":
            obj = next(b for b in circuit.buses if b.name == name)
        else:
            obj = next(g for g in circuit.gps if g.name == name)
        out.append((ckind, name, obj, rotmap))
    return out


def _honest_violations(ev: _Evaluator, constraints, where: str) -> list:
    out = []
    for ckind, name, obj, _ in constraints:
        if ckind == "gate":
            r = ev.gate_residual(obj)
            if np.any(r):
                rows = np.nonzero(r)[0][:5].tolist()
                out.append(Finding(
                    "witness-violation", ERROR, where, name,
                    f"gate {name!r} violated by the honest witness at rows "
                    f"{rows}: the circuit rejects correct executions"))
        elif ckind == "bus":
            inc = ev.bus_inc(obj)
            if np.any(inc.sum(axis=0) % F.P):
                out.append(Finding(
                    "witness-violation", ERROR, where, name,
                    f"bus {name!r} does not balance on the honest witness"))
        else:
            f1, f2 = ev.gp_factors(obj)
            if not np.array_equal(_eprod_np(f1), _eprod_np(f2)):
                out.append(Finding(
                    "witness-violation", ERROR, where, name,
                    f"grand product {name!r} does not balance on the honest "
                    f"witness"))
    return out


# ---------------------------------------------------------------------------
# the probe
# ---------------------------------------------------------------------------
def _perturbed(srcs: dict, kind: str, col: int, delta: np.ndarray) -> dict:
    out = dict(srcs)
    arr = srcs[kind].copy()
    arr[col] = (arr[col] + delta) % F.P
    out[kind] = arr
    return out


def _probe_column(circuit, srcs, kind, col, relevant, alpha, beta, rng):
    """Return a boolean coverage mask for one column's cells."""
    n = circuit.n_rows
    rots_c = sorted({r for _, _, _, rotmap in relevant
                     for r in rotmap.get((kind, col), ())})
    stride = max(rots_c) - min(rots_c) + 1
    covered = np.zeros(n, bool)
    honest = _Evaluator(circuit, srcs, alpha, beta)
    honest_inc = {name: honest.bus_inc(obj)
                  for ckind, name, obj, _ in relevant if ckind == "bus"}
    honest_gp = {name: honest.gp_factors(obj)
                 for ckind, name, obj, _ in relevant if ckind == "gp"}
    idx = np.arange(n)
    for off in range(stride):
        mask = (idx % stride) == off
        delta = rng.integers(1, F.P, n) * mask
        ev = _Evaluator(circuit, _perturbed(srcs, kind, col, delta),
                        alpha, beta)
        for ckind, cname, obj, rotmap in relevant:
            rots = sorted(rotmap[(kind, col)])
            if ckind == "gate":
                changed = np.nonzero(ev.gate_residual(obj))[0]
                for r in rots:
                    cand = (changed + r) % n
                    covered[cand[mask[cand]]] = True
            elif ckind == "bus":
                diff = (ev.bus_inc(obj) - honest_inc[cname]) % F.P
                rows = np.nonzero(np.any(diff, axis=1))[0]
                # the bus constraint is the GLOBAL sum: a cell is bound iff
                # its total contribution-diff is nonzero, so accumulate the
                # exact ext diff per attributed cell before deciding
                acc = np.zeros((n, 4), np.int64)
                for r in rots:
                    cand = (rows + r) % n
                    hit = mask[cand]
                    np.add.at(acc, cand[hit], diff[rows[hit]])
                covered[np.any(acc % F.P, axis=1)] = True
            else:
                hf1, hf2 = honest_gp[cname]
                f1, f2 = ev.gp_factors(obj)
                ch = np.any((f1 - hf1) % F.P, axis=1) | \
                    np.any((f2 - hf2) % F.P, axis=1)
                rows = np.nonzero(ch)[0]
                for r in rots:
                    cand = (rows + r) % n
                    covered[cand[mask[cand]]] = True
    return covered


def witness_analysis(circuit, advice, instance, data, where: str,
                     seed: int = 0, extract=None):
    """Probe every advice/instance column; returns (findings, coverage).

    ``extract(instance) -> dict`` is the adapter's public-output reader,
    used to classify free instance cells as forgeable vs benign padding.
    ``coverage`` is a list of per-column stat dicts for the JSON report.
    """
    circuit.assign_ext_cols()
    n = circuit.n_rows
    advice = np.asarray(advice, np.int64).copy()
    instance = np.asarray(instance, np.int64).copy()
    data = (np.zeros((0, n), np.int64) if data is None
            else np.asarray(data, np.int64).copy())
    # fill auto-multiplicity columns ONCE on the honest witness; the probe
    # must never refill them (that would mask bus perturbations)
    adv32 = advice.astype(np.uint32).copy()
    pv.auto_multiplicities(circuit, data.astype(np.uint32),
                           adv32, instance.astype(np.uint32))
    advice = adv32.astype(np.int64)
    fixed = (np.stack(circuit.fixed_cols).astype(np.int64)
             if circuit.fixed_cols else np.zeros((0, n), np.int64))
    srcs = {FIXED: fixed, ADVICE: advice, INSTANCE: instance, DATA: data}

    rng = np.random.default_rng(seed)
    alpha = rng.integers(1, F.P, 4).astype(np.uint32)
    beta = rng.integers(1, F.P, 4).astype(np.uint32)
    constraints = _constraints_of(circuit)
    honest = _Evaluator(circuit, srcs, alpha, beta)
    findings = _honest_violations(honest, constraints, where)
    if findings:
        return findings, []       # garbage witness: probing is meaningless

    names = {ADVICE: circuit.advice_names, INSTANCE: circuit.instance_names}
    free: dict = {}
    coverage = []
    for kind in _PROBED_KINDS:
        for col, colname in enumerate(names[kind]):
            relevant = [c for c in constraints if (kind, col) in c[3]]
            if not relevant:
                # structurally orphan: every cell trivially free (the
                # structural pass already errors on the column itself)
                free[(kind, col)] = np.ones(n, bool)
            else:
                covered = _probe_column(circuit, srcs, kind, col, relevant,
                                        alpha, beta, rng)
                free[(kind, col)] = ~covered
            nfree = int(free[(kind, col)].sum())
            coverage.append(dict(kind=kind, column=colname, rows=n,
                                 free_cells=nfree))
            if kind == ADVICE and nfree == n and relevant:
                findings.append(Finding(
                    "unconstrained-advice-column", WARNING, where, colname,
                    f"advice column {colname!r} is referenced by constraints "
                    f"but no cell of it is bound: every reference is masked"))
    findings += _classify_instance_freedom(
        circuit, srcs, free, constraints, alpha, beta, rng, where, extract)
    return findings, coverage


def _outputs_equal(a: dict, b: dict) -> bool:
    if set(a) != set(b):
        return False
    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def _classify_instance_freedom(circuit, srcs, free, constraints, alpha, beta,
                               rng, where, extract):
    """Free instance cells are an ERROR iff they can change the extracted
    public outputs while the witness still satisfies every constraint."""
    if extract is None:
        return []
    out = []
    honest_outputs = extract(srcs[INSTANCE].copy())
    for (kind, col), mask in sorted(free.items()):
        if kind != INSTANCE or not mask.any():
            continue
        forged = srcs[INSTANCE].copy()
        forged[col, mask] = rng.integers(1, F.P, int(mask.sum()))
        try:
            got = extract(forged)
            changed = not _outputs_equal(honest_outputs, got)
        except Exception as exc:               # extraction crash = suspicious
            got, changed = f"extract raised {exc!r}", True
        if not changed:
            continue
        # confirm the forgery actually still satisfies the circuit before
        # reporting (kills any residual probe false positive)
        ev = _Evaluator(circuit, {**srcs, INSTANCE: forged}, alpha, beta)
        if _honest_violations(ev, constraints, where):
            continue
        colname = circuit.instance_names[col]
        out.append(Finding(
            "forgeable-output", ERROR, where, colname,
            f"instance column {colname!r} has {int(mask.sum())} free cells "
            f"whose values flow into extract_outputs: a prover can forge "
            f"query results that still verify"))
    return out


def ext_product_check(f1: np.ndarray, f2: np.ndarray) -> bool:
    """Exposed for tests: cyclic grand-product balance."""
    return bool(np.array_equal(_eprod_np(f1), _eprod_np(f2)))
