"""Networked transparency fabric: a length-prefixed framed socket transport
(`docs/protocol.md` §10) carrying gossip heads, checkpoint/consistency
fetches, and :class:`~repro.core.session.ProofBundle` delivery between
owner and verifier processes.

Blocking-IO threads, matching the `repro.serve` threading model: a
:class:`~repro.net.server.NetServer` runs one accept loop plus one thread
per connection; a :class:`~repro.net.peer.PeerClient` issues typed
request/response frames with explicit timeouts, bounded retry with
backoff + deterministic jitter, and a per-peer circuit breaker so a dead
peer fails fast (:class:`~repro.net.peer.PeerUnavailable`) instead of
wedging its caller.  Hostile bytes fail closed through
:class:`~repro.net.framing.FrameError`, a
:class:`~repro.core.wire.WireFormatError` subclass.

:mod:`repro.net.faults` is the deterministic in-process fault-injection
harness (drop/duplicate/reorder/truncate/corrupt frames, frozen-peer
stalls, connection kills) the adversarial suite drives.
"""
from .framing import (FrameError, ConnectionClosed, MAX_FRAME, NET_MAGIC,
                      NET_VERSION, encode_frame, recv_frame, send_frame)
from .peer import (CircuitOpen, NetError, PeerClient, PeerUnavailable,
                   RemoteError)
from .server import NetServer

__all__ = ["CircuitOpen", "ConnectionClosed", "FrameError", "MAX_FRAME",
           "NET_MAGIC", "NET_VERSION", "NetError", "NetServer", "PeerClient",
           "PeerUnavailable", "RemoteError", "encode_frame", "recv_frame",
           "send_frame"]
