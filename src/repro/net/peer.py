"""Typed framed RPC client with retry, backoff + jitter, and a circuit
breaker — the verifier's side of the transport.

Failure taxonomy (all subclass :class:`NetError`, itself a ``ValueError``
sibling of the wire errors, never a bare socket exception):

* :class:`RemoteError` — the peer *answered* with a typed
  :data:`~repro.net.framing.RESP_ERROR` frame.  The transport worked; the
  request was refused.  Not retried, does not count against the breaker.
* :class:`PeerUnavailable` — the transport failed after every allowed
  attempt (connect refused, timeout, truncated frame, dead socket).  The
  caller falls back — a gossip verifier keeps serving from its last
  pinned head, exactly the degradation the transparency design allows.
* :class:`CircuitOpen` — a :class:`PeerUnavailable` raised *instantly*
  because recent failures opened this peer's breaker: no socket is
  touched, so one dead peer costs its callers microseconds, not
  timeout-seconds, per request.

The breaker is the classic three-state machine: CLOSED counts consecutive
transport failures; at ``fail_threshold`` it OPENs for ``cooldown``
seconds, failing fast; the first request after cooldown is the HALF_OPEN
probe — success re-CLOSEs, failure re-OPENs.  Retry backoff is
exponential with deterministic jitter from a seeded
:class:`random.Random`, so adversarial tests replay byte-identical
schedules (no ambient randomness, same rule as the proof path).
"""
from __future__ import annotations

import contextlib
import random
import socket
import time

from . import framing

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


class NetError(ValueError):
    """Base of every typed transport failure a :class:`PeerClient` raises."""


class RemoteError(NetError):
    """The peer processed the request and refused it (RESP_ERROR frame)."""


class PeerUnavailable(NetError):
    """Every allowed transport attempt failed; the caller should degrade
    (serve from the last pinned head), not hang or crash."""


class CircuitOpen(PeerUnavailable):
    """Failing fast: the breaker is open from recent failures, no socket
    was touched.  Retry after the cooldown elapses."""


class PeerClient:
    """One peer's framed RPC endpoint: ``request(kind, payload)``.

    The connection persists across requests and reconnects transparently;
    every attempt is bounded by ``timeout`` seconds of socket inactivity,
    retries are bounded by ``retries``, and the circuit breaker bounds how
    often a dead peer is even attempted.  Not thread-safe — one client per
    calling thread, like a socket."""

    def __init__(self, addr: tuple[str, int], timeout: float = 5.0,
                 retries: int = 3, backoff: float = 0.05,
                 fail_threshold: int = 3, cooldown: float = 1.0,
                 jitter_seed: int = 0):
        self.addr = (addr[0], int(addr[1]))
        self.timeout = timeout
        self.retries = max(1, int(retries))
        self.backoff = backoff
        self.fail_threshold = max(1, int(fail_threshold))
        self.cooldown = cooldown
        self._rng = random.Random(jitter_seed)
        self._sock: socket.socket | None = None
        self._state = CLOSED
        self._consecutive_failures = 0
        self._opened_at = 0.0

    # -- breaker ------------------------------------------------------------
    @property
    def state(self) -> str:
        """Breaker state, cooldown-aware: OPEN reads as HALF_OPEN once the
        cooldown has elapsed and a probe would be allowed through."""
        if self._state == OPEN and \
                time.monotonic() - self._opened_at >= self.cooldown:
            return HALF_OPEN
        return self._state

    def _breaker_admit(self) -> None:
        if self._state != OPEN:
            return
        remaining = self.cooldown - (time.monotonic() - self._opened_at)
        if remaining > 0:
            raise CircuitOpen(
                f"peer {self.addr[0]}:{self.addr[1]} circuit open after "
                f"{self._consecutive_failures} consecutive failures; "
                f"probe allowed in {remaining:.2f}s")
        self._state = HALF_OPEN            # one probe request goes through

    def _breaker_success(self) -> None:
        self._state = CLOSED
        self._consecutive_failures = 0

    def _breaker_failure(self) -> None:
        self._consecutive_failures += 1
        if self._state == HALF_OPEN or \
                self._consecutive_failures >= self.fail_threshold:
            self._state = OPEN
            self._opened_at = time.monotonic()

    # -- transport ----------------------------------------------------------
    def _connected(self) -> socket.socket:
        if self._sock is None:
            sock = socket.create_connection(self.addr, timeout=self.timeout)
            sock.settimeout(self.timeout)
            self._sock = sock
        return self._sock

    def _drop_connection(self) -> None:
        if self._sock is not None:
            with contextlib.suppress(OSError):
                self._sock.close()
            self._sock = None

    def close(self) -> None:
        self._drop_connection()

    def __enter__(self) -> "PeerClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def request(self, kind: int, payload: bytes) -> tuple[int, bytes]:
        """One RPC: send a frame, return the response ``(kind, payload)``.

        Retries transport failures with exponential backoff + seeded
        jitter; raises :class:`RemoteError` on a typed refusal,
        :class:`PeerUnavailable` when the peer stays unreachable, and
        :class:`CircuitOpen` (without touching the network) while the
        breaker cools down."""
        self._breaker_admit()
        last: Exception | None = None
        for attempt in range(self.retries):
            if attempt:
                delay = self.backoff * (2 ** (attempt - 1)) \
                    + self._rng.uniform(0.0, self.backoff)
                time.sleep(delay)
            try:
                sock = self._connected()
                framing.send_frame(sock, kind, payload)
                resp_kind, resp_payload = framing.recv_frame(sock)
            except framing.FrameError as e:
                last = e
                self._drop_connection()
                continue
            except (TimeoutError, OSError) as e:
                last = e
                self._drop_connection()
                continue
            self._breaker_success()
            if resp_kind == framing.RESP_ERROR:
                raise RemoteError(
                    f"peer {self.addr[0]}:{self.addr[1]} refused "
                    f"{kind:#x}: {resp_payload.decode('utf-8', 'replace')}")
            return resp_kind, resp_payload
        self._breaker_failure()
        raise PeerUnavailable(
            f"peer {self.addr[0]}:{self.addr[1]} unreachable after "
            f"{self.retries} attempts: {type(last).__name__}: {last}") \
            from last
