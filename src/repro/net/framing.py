"""Length-prefixed frame codec for the socket transport (protocol.md §10).

One frame is one complete message::

    frame := NET_MAGIC(4) net_version:u8 frame_kind:u8 length:u32 payload

All integers little-endian, matching :mod:`repro.core.wire`.  The payload
of a transparency frame (``RESP_HEAD``, ``RESP_MANIFEST``, ...) is itself a
complete canonical wire message, so the byte-level trust boundary is the
existing one: the transport adds framing, never interpretation.

Fail-closed rules, mirroring the wire codec:

* a length prefix above :data:`MAX_FRAME` raises :class:`FrameError`
  *before* any allocation — a hostile peer cannot ask a verifier to
  buffer gigabytes;
* bad magic, an unknown version, or a connection closed mid-frame raise
  :class:`FrameError` (a :class:`~repro.core.wire.WireFormatError`
  subclass, so every existing except-path that fails closed on malformed
  proof bytes fails closed on malformed transport bytes too);
* a connection closed cleanly *between* frames raises
  :class:`ConnectionClosed` — the one shutdown a server loop treats as
  normal rather than hostile.

Socket timeouts are left to propagate (``TimeoutError``): the caller — a
:class:`~repro.net.peer.PeerClient` retry loop or a
:class:`~repro.net.server.NetServer` connection thread — owns the budget.
"""
from __future__ import annotations

import socket
import struct

from repro.core.wire import WireFormatError

NET_MAGIC = b"ZKGF"
NET_VERSION = 1
MAX_FRAME = 1 << 26     # 64 MiB: comfortably above any ProofBundle, far
                        # below anything that could wedge a verifier

_HEADER = struct.Struct("<4sBBI")

# frame kinds: requests (odd jobs a peer can ask) and responses
REQ_PING = 0x01         # liveness probe; empty payload
RESP_PONG = 0x02
REQ_HEAD = 0x03         # latest signed head; empty payload
RESP_HEAD = 0x04        # payload: kind-9 gossip message bytes
REQ_MANIFEST = 0x05     # empty payload
RESP_MANIFEST = 0x06    # payload: kind-4 manifest bytes
REQ_INCLUSION = 0x07    # empty payload (manifest leaf under current head)
RESP_INCLUSION = 0x08   # payload: kind-6 inclusion-proof bytes
REQ_CONSISTENCY = 0x09  # payload: old tree size, u64 LE
RESP_CONSISTENCY = 0x0A  # payload: gossip bytes carrying the linking proof
REQ_BUNDLE = 0x0B       # payload: serving-queue cursor, u64 LE
RESP_BUNDLE = 0x0C      # payload: kind-1 proof-bundle bytes
RESP_PENDING = 0x0D     # no bundle at that cursor yet; empty payload
REQ_GOSSIP = 0x0E       # push a head; payload: kind-9 gossip bytes
RESP_ACK = 0x0F
RESP_EQUIVOCATION = 0x10  # payload: utf-8 evidence text; the alarm frame
RESP_ERROR = 0x11       # payload: utf-8 error text (typed failure, not RST)

FRAME_KINDS = frozenset(range(REQ_PING, RESP_ERROR + 1))


class FrameError(WireFormatError):
    """Malformed transport bytes: bad magic, version skew, an oversized
    length prefix, an unknown frame kind, or a connection that died
    mid-frame.  Subclasses :class:`WireFormatError` so transport-level
    hostility fails closed through the same paths as payload-level."""


class ConnectionClosed(FrameError):
    """The peer closed the connection at a frame boundary — orderly EOF,
    distinct from mid-frame truncation."""


def encode_frame(kind: int, payload: bytes) -> bytes:
    """The canonical bytes of one frame; raises :class:`FrameError` on an
    unknown kind or oversized payload (the sender obeys the same caps the
    receiver enforces)."""
    if kind not in FRAME_KINDS:
        raise FrameError(f"unknown frame kind {kind:#x}")
    payload = bytes(payload)
    if len(payload) > MAX_FRAME:
        raise FrameError(
            f"frame payload {len(payload)} bytes exceeds cap {MAX_FRAME}")
    return _HEADER.pack(NET_MAGIC, NET_VERSION, kind, len(payload)) + payload


def send_frame(sock: socket.socket, kind: int, payload: bytes) -> None:
    sock.sendall(encode_frame(kind, payload))


def _recv_exact(sock: socket.socket, n: int, *, at_boundary: bool) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 16))
        if not chunk:
            if at_boundary and got == 0:
                raise ConnectionClosed("peer closed the connection")
            raise FrameError(
                f"connection closed mid-frame: wanted {n} bytes, got {got}")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> tuple[int, bytes]:
    """Read exactly one frame; ``(kind, payload)``.

    Raises :class:`ConnectionClosed` on orderly EOF, :class:`FrameError`
    on anything malformed, and lets the socket's own timeout propagate."""
    header = _recv_exact(sock, _HEADER.size, at_boundary=True)
    magic, version, kind, length = _HEADER.unpack(header)
    if magic != NET_MAGIC:
        raise FrameError(
            f"bad frame magic {magic!r}: not a zkgraph transport frame")
    if version != NET_VERSION:
        raise FrameError(
            f"unsupported transport version {version} (this peer speaks "
            f"{NET_VERSION})")
    if kind not in FRAME_KINDS:
        raise FrameError(f"unknown frame kind {kind:#x}")
    if length > MAX_FRAME:
        raise FrameError(
            f"frame length {length} exceeds cap {MAX_FRAME}")
    payload = _recv_exact(sock, length, at_boundary=False) if length else b""
    return kind, payload
