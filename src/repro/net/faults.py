"""Deterministic in-process fault injection for the framed transport.

A :class:`FaultProxy` is a tiny frame-aware TCP proxy that sits between a
:class:`~repro.net.peer.PeerClient` and a
:class:`~repro.net.server.NetServer` and misbehaves *on schedule*: each
frame it forwards (in either direction) consumes the next action from a
shared script, so an adversarial test states exactly which frame gets
dropped, duplicated, reordered, truncated, corrupted, stalled, or has its
connection killed — and replays identically every run.  Randomness (the
corrupt action's byte position) comes from a seeded :class:`random.Random`.

Actions:

========== ==============================================================
``pass``    forward the frame unchanged (also the default after the
            script is exhausted)
``drop``    swallow the frame: the other side sees silence, then timeout
``dup``     forward the frame twice (a retransmit / confused relay)
``reorder`` hold the frame; forward it *after* the next frame in the
            same direction
``truncate`` forward only the first half of the frame's bytes, then kill
            both directions — a connection dying mid-frame
``corrupt`` flip one payload byte (header left intact so the corruption
            reaches the payload codec, which must fail closed)
``stall``   sleep ``stall_seconds`` (sized beyond the client timeout)
            before forwarding — the frozen-peer scenario
``close``   kill both directions immediately, before forwarding — a
            mid-handshake death
========== ==============================================================

Every action must end, on the client side, in a typed error or a clean
fallback (`tests/test_net_faults.py` asserts this frame by frame): the
transport's contract is *no hang, no acceptance of damaged bytes*.
"""
from __future__ import annotations

import contextlib
import random
import socket
import threading
from collections import deque
from collections.abc import Iterable, Iterator

from . import framing

ACTIONS = frozenset({"pass", "drop", "dup", "reorder", "truncate",
                     "corrupt", "stall", "close"})


class FaultProxy:
    """A misbehaving hop between one client and one upstream server."""

    def __init__(self, upstream: tuple[str, int],
                 script: Iterable[str] = (), stall_seconds: float = 1.0,
                 seed: int = 0, host: str = "127.0.0.1"):
        script = list(script)
        unknown = set(script) - ACTIONS
        if unknown:
            raise ValueError(f"unknown fault actions: {sorted(unknown)}")
        self.upstream = (upstream[0], int(upstream[1]))
        self.stall_seconds = stall_seconds
        self.host = host
        self.port = 0
        self._script: deque[str] = deque(script)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._stopping = threading.Event()
        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._socks: set[socket.socket] = set()
        self.frames_seen = 0

    def extend_script(self, actions: Iterable[str]) -> None:
        """Append actions (thread-safe) — lets a test schedule the next
        fault while the transport is live."""
        actions = list(actions)
        unknown = set(actions) - ACTIONS
        if unknown:
            raise ValueError(f"unknown fault actions: {sorted(unknown)}")
        with self._lock:
            self._script.extend(actions)

    def _next_action(self) -> str:
        with self._lock:
            self.frames_seen += 1
            return self._script.popleft() if self._script else "pass"

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> tuple[str, int]:
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self.host, 0))
        listener.listen(8)
        listener.settimeout(0.2)
        self._listener = listener
        self.port = listener.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="fault-proxy", daemon=True)
        self._accept_thread.start()
        return (self.host, self.port)

    def stop(self) -> None:
        self._stopping.set()
        if self._listener is not None:
            with contextlib.suppress(OSError):
                self._listener.close()
        with self._lock:
            socks = list(self._socks)
        for s in socks:
            with contextlib.suppress(OSError):
                s.close()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        self._listener = None

    @contextlib.contextmanager
    def serving(self) -> Iterator[tuple[str, int]]:
        addr = self.start()
        try:
            yield addr
        finally:
            self.stop()

    # -- pumping ------------------------------------------------------------
    def _accept_loop(self) -> None:
        listener = self._listener
        assert listener is not None     # started before the thread spawns
        while not self._stopping.is_set():
            try:
                client, _ = listener.accept()
            except TimeoutError:
                continue
            except OSError:
                return
            try:
                server = socket.create_connection(self.upstream, timeout=5.0)
            except OSError:
                with contextlib.suppress(OSError):
                    client.close()
                continue
            for s in (client, server):
                s.settimeout(30.0)
                with self._lock:
                    self._socks.add(s)
            for src, dst in ((client, server), (server, client)):
                threading.Thread(target=self._pump, args=(src, dst),
                                 name="fault-pump", daemon=True).start()

    def _kill_pair(self, a: socket.socket, b: socket.socket) -> None:
        for s in (a, b):
            with contextlib.suppress(OSError):
                s.close()
            with self._lock:
                self._socks.discard(s)

    def _pump(self, src: socket.socket, dst: socket.socket) -> None:
        held: bytes | None = None       # a reordered frame awaiting release
        try:
            while not self._stopping.is_set():
                try:
                    kind, payload = framing.recv_frame(src)
                except (framing.FrameError, TimeoutError, OSError):
                    return self._kill_pair(src, dst)
                raw = framing.encode_frame(kind, payload)
                action = self._next_action()
                if action == "drop":
                    continue
                if action == "close":
                    return self._kill_pair(src, dst)
                if action == "stall":
                    # hold the frame beyond the client's timeout budget,
                    # checking for shutdown so stop() never waits on us
                    self._stopping.wait(self.stall_seconds)
                if action == "truncate":
                    with contextlib.suppress(OSError):
                        dst.sendall(raw[: max(1, len(raw) // 2)])
                    return self._kill_pair(src, dst)
                if action == "corrupt" and payload:
                    flip = self._rng.randrange(len(payload))
                    body = bytearray(raw)
                    body[framing._HEADER.size + flip] ^= 0x20
                    raw = bytes(body)
                out = [raw, raw] if action == "dup" else [raw]
                if action == "reorder" and held is None:
                    held = raw
                    continue
                if held is not None:
                    out.append(held)    # released *after* this frame
                    held = None
                try:
                    for frame in out:
                        dst.sendall(frame)
                except OSError:
                    return self._kill_pair(src, dst)
        finally:
            self._kill_pair(src, dst)
