"""Blocking-IO framed TCP server: one accept loop, one thread per peer.

The same threading model as :mod:`repro.serve` (plain threads + locks, no
async runtime): a :class:`NetServer` owns a listening socket, accepts
connections on a daemon thread, and runs each connection's request loop on
its own daemon thread.  Handlers are a registry from request frame kind to
``handler(payload) -> (response_kind, response_payload)`` — the server
itself never interprets payload bytes.

Failure behaviour, the part that matters:

* a handler exception becomes a typed :data:`~repro.net.framing.RESP_ERROR`
  frame (message text only — no tracebacks, no state) and the connection
  survives; a *hostile* frame (:class:`~repro.net.framing.FrameError`)
  gets one ``RESP_ERROR`` and the connection is closed — malformed bytes
  don't get a second chance to probe the parser;
* every connection socket carries an idle timeout, so a frozen peer
  occupies one thread for at most ``conn_timeout`` seconds, never forever;
* :meth:`stop` closes the listener and every live connection socket and
  joins the accept loop — shutdown cannot leak threads that outlive the
  process's useful life.
"""
from __future__ import annotations

import contextlib
import socket
import threading
from collections.abc import Callable, Iterator

from . import framing

Handler = Callable[[bytes], tuple[int, bytes]]


class NetServer:
    """A framed request/response server over TCP.

    ``register(kind, handler)`` before :meth:`start`; handlers run on the
    connection's thread and must be thread-safe across connections (the
    transparency objects they close over — log, session — already are, or
    are guarded by the caller)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 conn_timeout: float = 30.0, backlog: int = 16):
        self.host = host
        self.port = port
        self.conn_timeout = conn_timeout
        self.backlog = backlog
        self._handlers: dict[int, Handler] = {}
        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._conns: set[socket.socket] = set()
        self._lock = threading.Lock()
        self._stopping = threading.Event()

    def register(self, kind: int, handler: Handler) -> None:
        if kind not in framing.FRAME_KINDS:
            raise framing.FrameError(f"unknown frame kind {kind:#x}")
        self._handlers[kind] = handler

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> tuple[str, int]:
        """Bind, listen, and return the bound ``(host, port)``."""
        if self._listener is not None:
            raise RuntimeError("server already started")
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self.host, self.port))
        listener.listen(self.backlog)
        # a finite accept timeout keeps the loop responsive to stop()
        listener.settimeout(0.2)
        self._listener = listener
        self.port = listener.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="net-accept", daemon=True)
        self._accept_thread.start()
        return (self.host, self.port)

    def stop(self) -> None:
        self._stopping.set()
        if self._listener is not None:
            with contextlib.suppress(OSError):
                self._listener.close()
        with self._lock:
            conns = list(self._conns)
        for conn in conns:
            with contextlib.suppress(OSError):
                conn.close()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        self._listener = None

    @contextlib.contextmanager
    def serving(self) -> Iterator[tuple[str, int]]:
        addr = self.start()
        try:
            yield addr
        finally:
            self.stop()

    # -- the loops ----------------------------------------------------------
    def _accept_loop(self) -> None:
        listener = self._listener
        assert listener is not None     # started before the thread spawns
        while not self._stopping.is_set():
            try:
                conn, _ = listener.accept()
            except TimeoutError:
                continue
            except OSError:
                return                      # listener closed by stop()
            conn.settimeout(self.conn_timeout)
            with self._lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="net-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stopping.is_set():
                try:
                    kind, payload = framing.recv_frame(conn)
                except framing.ConnectionClosed:
                    return
                except framing.FrameError as e:
                    # hostile or truncated bytes: answer once, then drop the
                    # connection — never keep parsing a poisoned stream
                    with contextlib.suppress(OSError):
                        framing.send_frame(conn, framing.RESP_ERROR,
                                           str(e).encode("utf-8"))
                    return
                except (TimeoutError, OSError):
                    return                  # idle or dead peer: reclaim
                handler = self._handlers.get(kind)
                if handler is None:
                    resp = (framing.RESP_ERROR,
                            f"no handler for frame kind {kind:#x}".encode())
                else:
                    try:
                        resp = handler(payload)
                    except Exception as e:  # typed to the peer, conn survives
                        resp = (framing.RESP_ERROR,
                                f"{type(e).__name__}: {e}".encode("utf-8"))
                try:
                    framing.send_frame(conn, resp[0], resp[1])
                except (framing.FrameError, OSError):
                    return
        finally:
            with self._lock:
                self._conns.discard(conn)
            with contextlib.suppress(OSError):
                conn.close()
