"""Compile a parsed query AST to the plan IR (expansion-centric decomposition).

The compilation rules mirror the paper's operator decomposition — every
pattern hop lowers to an ``Expand``/``SetExpand`` core, WHERE predicates to
lookup + filter pairs, ORDER BY/LIMIT to the order-by circuit, aggregation
to the scalar aggregate circuit — and are chosen so a compiled plan is
*execution-identical* to the hand-written LDBC plan for the same query:
same circuits, same shapes, same public instances, same proof bytes (the
differential conformance suite asserts exactly this).

Out-of-subset constructs raise :class:`~repro.query.ast.QueryCompileError`
with an explanation; nothing compiles to a silently different plan.
"""
from __future__ import annotations

from ..core import ir
from . import catalog
from .ast import (AggCall, IntLit, LengthCall, ParamRef, PropRef, Query,
                  QueryCompileError, pretty_print)
from .parser import parse

__all__ = ["compile_query", "compile_ast"]

_CMP_MAP = {"<>": "ne", ">=": "ge", ">": "gt", "<=": "le", "<": "lt"}


def _binding(v):
    if isinstance(v, ParamRef):
        return ir.Param(v.name)
    if isinstance(v, IntLit):
        return ir.Lit(v.value)
    raise QueryCompileError(f"unsupported value term {v!r}")


class _Var:
    """Planner state for one pattern variable."""

    def __init__(self, label=None, ids=None, scalar=False):
        self.label = label
        self.ids = ids          # binding for the variable's id set
        self.scalar = scalar    # True only for the anchored source


class _Compiler:
    def __init__(self, q: Query, name: str):
        self.q = q
        self.name = name
        self.nodes = []         # plan nodes, in emission order
        self.vars = {}          # node var -> _Var
        self.edge_vals = {}     # edge var -> dict(prop=, vals=, pay=, right=)
        self.anchor_var = None
        self.hop_sources = set()   # vars later pattern hops expanded from

    def _emit(self, node) -> int:
        self.nodes.append(node)
        return len(self.nodes) - 1

    # -- variable bookkeeping ------------------------------------------------
    def _declare(self, name, var: _Var):
        if name is None:
            return
        if name in self.vars or name in self.edge_vals:
            raise QueryCompileError(f"duplicate variable {name!r}")
        self.vars[name] = var

    def _var(self, name: str) -> _Var:
        v = self.vars.get(name)
        if v is None:
            raise QueryCompileError(f"unknown variable {name!r}")
        return v

    def _label_of(self, name: str) -> str:
        label = self._var(name).label
        if label is None:
            raise QueryCompileError(
                f"cannot resolve properties of {name!r}: no label declared "
                f"or inferable for it")
        return label

    def _check_label(self, node_pat, allowed: frozenset, role: str):
        if node_pat.label is not None and node_pat.label not in allowed:
            raise QueryCompileError(
                f"label {node_pat.label!r} cannot be the {role} of this "
                f"edge (expected one of {sorted(allowed)})")

    @staticmethod
    def _inferred(node_pat, allowed: frozenset):
        if node_pat.label is not None:
            return node_pat.label
        return next(iter(allowed)) if len(allowed) == 1 else None

    # -- pattern -------------------------------------------------------------
    def _edge_props_needed(self) -> dict:
        """edge var -> the single property it must expose (from RETURN and
        ORDER BY references; WHERE never touches edge properties)."""
        edge_vars = {e.var for p in self.q.patterns for e in p.edges if e.var}
        needed = {}
        refs = [it.expr for it in self.q.returns] + \
               [o.expr for o in self.q.order]
        for x in refs:
            if isinstance(x, PropRef) and x.var in edge_vars:
                needed.setdefault(x.var, set()).add(x.key)
        for var, keys in needed.items():
            if len(keys) > 1:
                raise QueryCompileError(
                    f"edge variable {var!r} is referenced with more than one "
                    f"property ({sorted(keys)}); one is supported")
        for p in self.q.where:
            if p.lhs.var in edge_vars:
                raise QueryCompileError(
                    "WHERE predicates on edge properties are unsupported; "
                    "use ORDER BY on the edge property instead")
        return {var: next(iter(keys)) for var, keys in needed.items()}

    def _compile_pattern(self, path):
        names = [n.var for n in path.nodes if n.var] + \
                [e.var for e in path.edges if e.var]
        if len(names) != len(set(names)):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise QueryCompileError(f"duplicate pattern variables: {dup}")
        left = path.nodes[0]
        if left.prop_key != "id" or left.prop_value is None:
            raise QueryCompileError(
                "the leftmost pattern node must be anchored by an id "
                "({id: $param} or {id: <int>}) — plans expand outward from "
                "a known source")
        for other in path.nodes[1:]:
            if other.prop_key is not None:
                raise QueryCompileError(
                    "only the leftmost pattern node may carry an "
                    "{id: ...} anchor")
        if left.label is not None and left.label not in catalog.LABELS:
            raise QueryCompileError(
                f"unknown label {left.label!r}; known: "
                f"{sorted(catalog.LABELS)}")
        anchor = _binding(left.prop_value)
        self._declare(left.var, _Var(left.label, anchor, scalar=True))
        self.anchor_var = left.var
        edge_props = self._edge_props_needed()

        cur = _Var(left.label, anchor, scalar=True)
        cur_name = left.var
        for pos, (e, right) in enumerate(zip(path.edges, path.nodes[1:])):
            info = catalog.edge_info(e.etype)
            if info.undirected and e.direction != "any":
                raise QueryCompileError(
                    f"{e.etype} is undirected; use -[:{e.etype}]-")
            if not info.undirected and e.direction == "any":
                raise QueryCompileError(
                    f"{e.etype} is directed; use -[:{e.etype}]-> or "
                    f"<-[:{e.etype}]-")
            if e.direction == "in":
                self._check_label(right, info.src_labels, "source")
                right_allowed = info.src_labels
            else:
                self._check_label(right, info.dst_labels, "target")
                right_allowed = info.dst_labels
            right_label = self._inferred(right, right_allowed)
            last_edge = pos == len(path.edges) - 1

            if e.min_hops is not None:          # variable-length
                rv = self._varlength_hop(e, info, cur, last_edge)
            elif e.var in edge_props:           # edge property demanded
                rv = self._prop_edge_hop(e, info, cur,
                                         edge_props[e.var], right_label)
            elif cur.scalar:
                rv = self._scalar_hop(e, info, cur)
            else:
                rv = self._set_hop(e, info, cur)
            rv.label = right_label
            self._declare(right.var, rv)
            if cur_name is not None:
                self.hop_sources.add(cur_name)
            cur = rv
            cur_name = right.var

    def _varlength_hop(self, e, info, cur, last_edge) -> _Var:
        if not info.undirected:
            raise QueryCompileError(
                "variable-length patterns are supported on undirected "
                "edges only")
        if e.max_hops is None:
            raise QueryCompileError(
                "unbounded variable-length (*) is only supported inside "
                "shortestPath(...)")
        if e.min_hops != 1:
            raise QueryCompileError(
                "variable-length lower bound must be 1 (*1..n)")
        if not cur.scalar:
            raise QueryCompileError(
                "variable-length patterns must start at the anchored node")
        src = cur.ids
        base = len(self.nodes)
        table = ir.BaseTable(info.table)
        for j in range(e.max_hops):
            if j == 0:
                ids = ir.App(ir._singleton, (src,))
            else:
                prev = tuple(ir.Out(base + t, "dst")
                             for t in range(j - 1, -1, -1))
                ids = ir.App(ir._new_frontier, (src,) + prev)
            self._emit(ir.SetExpand(table, ids, bidirectional=True))
        dsts = tuple(ir.Out(base + t, "dst") for t in range(e.max_hops))
        if last_edge:
            # the union of every hop's targets feeds WHERE/RETURN
            return _Var(ids=ir.App(ir._uniq_concat, dsts))
        # continued patterns exclude the source itself from the frontier
        return _Var(ids=ir.App(ir._friends_minus, (src,) + dsts))

    def _prop_edge_hop(self, e, info, cur, prop, right_label) -> _Var:
        if not cur.scalar:
            raise QueryCompileError(
                "edge-property access needs a single anchored source")
        table_name = info.prop_tables.get(prop)
        if table_name is None:
            raise QueryCompileError(
                f"edge type {e.etype} has no published {prop!r} table")
        src = cur.ids
        table = ir.BaseTable(table_name)
        i = self._emit(ir.Expand(table, src, with_prop=True))
        self._emit(ir.Expand(table, src, with_prop=True, reverse=True))
        vals = ir.App(ir._concat, (ir.Out(i, "prop"), ir.Out(i + 1, "prop")))
        pay = ir.App(ir._concat, (ir.Out(i, "dst"), ir.Out(i + 1, "dst")))
        self.edge_vals[e.var] = dict(prop=prop, vals=vals, pay=pay)
        return _Var(ids=pay)

    def _scalar_hop(self, e, info, cur) -> _Var:
        if info.undirected:
            i = self._emit(ir.SetExpand(
                ir.BaseTable(info.table),
                ir.App(ir._singleton, (cur.ids,)), bidirectional=True))
            return _Var(ids=ir.App(ir._uniq_concat, (ir.Out(i, "dst"),)))
        i = self._emit(ir.Expand(ir.BaseTable(info.table), cur.ids,
                                 reverse=(e.direction == "in")))
        return _Var(ids=ir.Out(i, "dst"))

    def _set_hop(self, e, info, cur) -> _Var:
        if info.undirected:
            i = self._emit(ir.SetExpand(ir.BaseTable(info.table), cur.ids,
                                        bidirectional=True))
            return _Var(ids=ir.App(ir._uniq_concat, (ir.Out(i, "dst"),)))
        if e.direction == "out":
            table = info.table
        else:
            table = info.rev_table
            if table is None:
                raise QueryCompileError(
                    f"no reversed table published for {e.etype}; this edge "
                    f"cannot be traversed backwards from a set")
        i = self._emit(ir.SetExpand(ir.BaseTable(table), cur.ids))
        return _Var(ids=ir.Out(i, "dst"))

    # -- WHERE ---------------------------------------------------------------
    def _prop_lookup(self, var: str):
        """Emit the id -> value lookup for ``var``'s single-prop table."""
        v = self._var(var)
        if v.scalar:
            raise QueryCompileError(
                f"property access on the anchored node {var!r} is only "
                f"supported in RETURN (covering-table expansion)")
        return v

    def _single_prop_table(self, label: str, key: str):
        pt = catalog.prop_table_for(label, (key,))
        if len(pt.props) != 1:
            raise QueryCompileError(
                f"no single-property lookup table covers "
                f"{label}.{key}; filtering/ordering on it is unsupported")
        return pt

    def _compile_where(self):
        for pred in self.q.where:
            var, key = pred.lhs.var, pred.lhs.key
            v = self._prop_lookup(var)
            if var in self.hop_sources:
                raise QueryCompileError(
                    f"WHERE on intermediate pattern variable {var!r} is "
                    f"unsupported: later pattern hops already expanded from "
                    f"its unfiltered id set, so the predicate would be "
                    f"silently dropped from downstream results; filter the "
                    f"terminal variable or split the query")
            if any(v.ids is ev["pay"] for ev in self.edge_vals.values()):
                raise QueryCompileError(
                    f"WHERE on {var!r} is unsupported: its ids are bound to "
                    f"an edge-property expansion whose ORDER BY/RETURN "
                    f"payload would bypass the filter")
            pt = self._single_prop_table(self._label_of(var), key)
            i = self._emit(ir.SetExpand(ir.BaseTable(pt.table), v.ids))
            pair = ir.Chained((ir.Out(i, "src"), ir.Out(i, "dst")))
            rhs = _binding(pred.rhs)
            if pred.cmp == "=":
                j = self._emit(ir.NameFilter(pair, rhs))
                v.ids = ir.Out(j, "dst")
            else:
                j = self._emit(ir.Filter(pair, _CMP_MAP[pred.cmp], rhs))
                # Chained pads an empty lookup to one (0, 0) row; a predicate
                # the padding satisfies (e.g. >= 0) would otherwise surface a
                # phantom id 0 in the verified result
                v.ids = ir.App(ir._nonzero, (ir.Out(j, "src"),))

    # -- RETURN / ORDER BY / LIMIT ------------------------------------------
    def _anchor_returns(self) -> dict:
        """Returned properties of the anchored node, via one covering-table
        expansion (``(m {id: $message}) RETURN m.content, m.creationDate``)."""
        anchor = self.vars.get(self.anchor_var)
        props = []
        for it in self.q.returns:
            x = it.expr
            if isinstance(x, PropRef) and x.var == self.anchor_var \
                    and x.key != "id":
                props.append(x.key)
        if not props:
            return {}
        pt = catalog.prop_table_for(self._label_of(self.anchor_var),
                                    tuple(props))
        i = self._emit(ir.Expand(ir.BaseTable(pt.table), anchor.ids,
                                 with_prop=(len(pt.props) == 2)))
        slots = dict(zip(pt.props, ("dst", "prop")))
        return {(self.anchor_var, p): ir.Out(i, slots[p]) for p in props}

    def _compile_order(self):
        """Emit the order-by tail; returns the result-binding map for the
        order payload/values, or None when the query has no ORDER BY."""
        if not self.q.order:
            if self.q.limit is not None:
                raise QueryCompileError("LIMIT requires ORDER BY")
            return None
        if len(self.q.order) != 1:
            raise QueryCompileError("a single ORDER BY key is supported")
        o = self.q.order[0]
        var, key = o.expr.var, o.expr.key
        if var in self.edge_vals:
            ev = self.edge_vals[var]
            if ev["prop"] != key:
                raise QueryCompileError(
                    f"edge variable {var!r} exposes {ev['prop']!r}, "
                    f"not {key!r}")
            vals, pay = ev["vals"], ev["pay"]
            pay_keys = {(nv, "id"): "pay" for nv, info in self.vars.items()
                        if info.ids is ev["pay"]}
        else:
            v = self._prop_lookup(var)
            if key == "id":
                vals = pay = v.ids
            else:
                pt = self._single_prop_table(self._label_of(var), key)
                i = self._emit(ir.SetExpand(ir.BaseTable(pt.table), v.ids))
                vals, pay = ir.Out(i, "dst"), ir.Out(i, "src")
            pay_keys = {(var, "id"): "pay"}
        if self.q.limit is None:
            k = ir.App(ir._length_or_1, (pay,))
        else:
            k = _binding(self.q.limit)
        top = self._emit(ir.OrderBy(vals, pay, k=k,
                                    descending=o.descending))
        # ORDER BY v.id makes (v, "id") both the values and the payload;
        # the payload slot wins (the hand-written plans read "pay" there)
        out = {(var, key): ir.Out(top, "vals")}
        out.update({pk: ir.Out(top, slot) for pk, slot in pay_keys.items()})
        return out

    def _compile_aggregate(self) -> ir.Plan:
        if len(self.q.returns) != 1 or self.q.order or \
                self.q.limit is not None:
            raise QueryCompileError(
                "an aggregation must be the only RETURN item, without "
                "ORDER BY or LIMIT")
        it = self.q.returns[0]
        agg: AggCall = it.expr
        arg = agg.arg
        if isinstance(arg, str):
            arg = PropRef(arg, "id")
        if agg.fn == "count" and arg.key != "id":
            raise QueryCompileError(
                "count aggregates a variable (count(v)), not a property")
        v = self._prop_lookup(arg.var)
        if arg.key == "id":
            vals = v.ids
        else:
            pt = self._single_prop_table(self._label_of(arg.var), arg.key)
            i = self._emit(ir.SetExpand(ir.BaseTable(pt.table), v.ids))
            vals = ir.Out(i, "dst")
        j = self._emit(ir.Aggregate(ir.Chained((vals,)), agg.fn))
        return ir.Plan(self.name, tuple(self.nodes),
                       {it.alias: ir.Out(j, "value")})

    def _compile_shortest(self, path) -> ir.Plan:
        if len(path.nodes) != 2 or len(path.edges) != 1:
            raise QueryCompileError(
                "shortestPath takes exactly one edge between two nodes")
        a, b = path.nodes
        e = path.edges[0]
        info = catalog.edge_info(e.etype)
        if info.sssp_nodes is None:
            raise QueryCompileError(
                f"no shortest-path commitment published for {e.etype}")
        if e.direction != "any" or e.min_hops != 1 or e.max_hops is not None:
            raise QueryCompileError(
                "shortestPath needs an undirected unbounded edge "
                f"(-[:{e.etype}*]-)")
        for node_pat, role in ((a, "first"), (b, "second")):
            if node_pat.prop_key != "id" or node_pat.prop_value is None:
                raise QueryCompileError(
                    f"shortestPath {role} node must be anchored by "
                    "{id: ...}")
            self._check_label(node_pat, info.src_labels, role)
        if self.q.where or self.q.order or self.q.limit is not None:
            raise QueryCompileError(
                "shortestPath supports RETURN length(path) only")
        if len(self.q.returns) != 1:
            raise QueryCompileError(
                "shortestPath returns exactly one item: length(<path>)")
        it = self.q.returns[0]
        if not isinstance(it.expr, LengthCall) or path.path_var is None \
                or it.expr.path_var != path.path_var:
            raise QueryCompileError(
                "shortestPath queries must bind the path (p = shortestPath"
                "(...)) and RETURN length(p)")
        st = ir.SSSP(ir.BaseTable(info.sssp_nodes), _binding(a.prop_value),
                     target=_binding(b.prop_value))
        return ir.Plan(self.name, (st,), {it.alias: ir.Out(0, "distance")})

    # -- entry ---------------------------------------------------------------
    def compile(self) -> ir.Plan:
        if len(self.q.patterns) != 1:
            raise QueryCompileError(
                "exactly one MATCH pattern is supported")
        path = self.q.patterns[0]
        for x in (it.expr for it in self.q.returns):
            if isinstance(x, LengthCall) and not path.shortest:
                raise QueryCompileError(
                    "length(...) is only defined for shortestPath patterns")
        if path.shortest:
            return self._compile_shortest(path)
        self._compile_pattern(path)
        if any(isinstance(it.expr, AggCall) for it in self.q.returns):
            if self.q.where:
                self._compile_where()
            return self._compile_aggregate()
        self._compile_where()
        bound = self._anchor_returns()
        obound = self._compile_order()
        result = {}
        for it in self.q.returns:
            x = it.expr
            if not isinstance(x, PropRef):
                raise QueryCompileError(f"unsupported return item {x!r}")
            pk = (x.var, x.key)
            if pk in bound:
                result[it.alias] = bound[pk]
            elif obound is not None and pk in obound:
                result[it.alias] = obound[pk]
            elif obound is None and x.key == "id" and x.var in self.vars \
                    and not self._var(x.var).scalar:
                result[it.alias] = self._var(x.var).ids
            elif obound is None and x.key != "id" and x.var in self.vars:
                v = self._prop_lookup(x.var)
                pt = self._single_prop_table(self._label_of(x.var), x.key)
                i = self._emit(ir.SetExpand(ir.BaseTable(pt.table), v.ids))
                result[it.alias] = ir.Out(i, "dst")
            else:
                raise QueryCompileError(
                    f"cannot derive return item {x.var}.{x.key}: not an "
                    f"ordered payload, anchored property, or id of a "
                    f"pattern variable")
        if len(result) != len(self.q.returns):
            raise QueryCompileError("duplicate RETURN aliases")
        return ir.Plan(self.name, tuple(self.nodes), result)


def compile_ast(q: Query, name: str = None) -> ir.Plan:
    """Compile a parsed AST; ``name`` defaults to the canonical text."""
    return _Compiler(q, name if name is not None else pretty_print(q)) \
        .compile()


def compile_query(source: str, name: str = None) -> ir.Plan:
    """Parse + compile query text to an executable plan.

    Raises :class:`~repro.query.ast.QuerySyntaxError` on malformed text and
    :class:`~repro.query.ast.QueryCompileError` on out-of-subset queries."""
    return compile_ast(parse(source), name=name)
