"""Textual sources for the 8 LDBC SNB interactive queries.

Each text compiles (``repro.query.compile_query``) to a plan that proves to
the SAME wire bytes as the hand-written plan function in
:mod:`repro.core.ir` — asserted by ``tests/test_query_conformance.py``.

The datasets are integer-coded (names, content, and dates are field
elements), so every literal is an integer or a ``$parameter``.
"""
from __future__ import annotations

__all__ = ["QUERY_TEXTS"]

QUERY_TEXTS = {
    "IS3": (
        "MATCH (p:Person {id: $person})-[k:KNOWS]-(f:Person) "
        "RETURN f.id AS friends, k.creationDate AS dates "
        "ORDER BY k.creationDate DESC"
    ),
    "IS4": (
        "MATCH (m:Message {id: $message}) "
        "RETURN m.content AS content, m.creationDate AS date"
    ),
    "IS5": (
        "MATCH (m:Message {id: $message})-[:HAS_CREATOR]->(c:Person) "
        "RETURN c.id AS creator"
    ),
    "IC1": (
        "MATCH (p:Person {id: $person})-[:KNOWS*1..3]-(f:Person) "
        "WHERE f.firstName = $firstName "
        "RETURN f.id AS persons ORDER BY f.id DESC LIMIT 20"
    ),
    "IC2": (
        "MATCH (p:Person {id: $person})-[:KNOWS]-(f:Person)"
        "<-[:HAS_CREATOR]-(m:Message) "
        "RETURN m.id AS messages, m.creationDate AS dates "
        "ORDER BY m.creationDate DESC LIMIT $k"
    ),
    "IC8": (
        "MATCH (p:Person {id: $person})<-[:HAS_CREATOR]-(m)"
        "<-[:REPLY_OF]-(r:Comment) "
        "RETURN r.id AS replies, r.creationDate AS dates "
        "ORDER BY r.creationDate DESC LIMIT $k"
    ),
    "IC9": (
        "MATCH (p:Person {id: $person})-[:KNOWS*1..2]-(f:Person)"
        "<-[:HAS_CREATOR]-(m:Message) "
        "RETURN m.id AS messages, m.creationDate AS dates "
        "ORDER BY m.creationDate DESC LIMIT $k"
    ),
    "IC13": (
        "MATCH path = shortestPath((a:Person {id: $person1})"
        "-[:KNOWS*]-(b:Person {id: $person2})) "
        "RETURN length(path) AS distance"
    ),
}
