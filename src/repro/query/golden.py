"""Deterministic textual rendering of plan IR — the golden-vector format.

``render_plan`` is pure and stable: bindings render recursively (``App``
glue by function name), nodes as dataclass field lists, results sorted by
key.  Committed vectors under ``tests/vectors/plan_*.txt`` make any planner
drift a visible diff (see ``tests/test_query_vectors.py``).
"""
from __future__ import annotations

from dataclasses import fields, is_dataclass

from ..core import ir

__all__ = ["render_plan", "render_binding"]


def render_binding(b) -> str:
    if isinstance(b, ir.Param):
        if b.default is not ir._NO_DEFAULT:
            return f"Param({b.name!r}, default={b.default!r})"
        return f"Param({b.name!r})"
    if isinstance(b, ir.Lit):
        return f"Lit({b.value!r})"
    if isinstance(b, ir.Out):
        return f"Out({b.step}, {b.key!r})"
    if isinstance(b, ir.App):
        args = ", ".join(render_binding(a) for a in b.args)
        return f"App({getattr(b.fn, '__name__', str(b.fn))}, [{args}])"
    if isinstance(b, ir.BaseTable):
        return f"BaseTable({b.desc!r})"
    if isinstance(b, ir.Chained):
        cols = ", ".join(render_binding(c) for c in b.cols)
        return f"Chained([{cols}])"
    return repr(b)


def _render_node(node) -> str:
    assert is_dataclass(node)
    parts = []
    for f in fields(node):
        v = getattr(node, f.name)
        if is_dataclass(v) or isinstance(v, (ir.Param, ir.Lit, ir.Out,
                                             ir.App)):
            parts.append(f"{f.name}={render_binding(v)}")
        else:
            parts.append(f"{f.name}={v!r}")
    return f"{type(node).__name__}({', '.join(parts)})"


def render_plan(plan: ir.Plan) -> str:
    lines = [f"plan {plan.name}"]
    for i, node in enumerate(plan.nodes):
        lines.append(f"  {i}: {_render_node(node)}")
    lines.append("result")
    for key in sorted(plan.result):
        lines.append(f"  {key}: {render_binding(plan.result[key])}")
    return "\n".join(lines) + "\n"
