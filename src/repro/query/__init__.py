"""The parsed query front door: GQL/Cypher subset -> plan IR.

Public surface::

    from repro.query import compile_query, parse, pretty_print

    plan = compile_query("MATCH (m:Message {id: $message})"
                         "-[:HAS_CREATOR]->(c:Person) RETURN c.id AS creator")
    bundle = session.prove_plan(plan, dict(message=mid))

Importing this package also registers a plan resolver with
:func:`repro.core.ir.build_plan`, so a proof bundle whose ``query`` field is
a parseable query text verifies end-to-end: the verifier re-compiles the
text itself and checks the proof against its *own* plan — exactly as it
re-resolves a registered query name.  Texts that fail to parse or compile
surface as ``KeyError`` (an unknown query), keeping ``verify`` failing
closed on malformed bundles.
"""
from __future__ import annotations

from ..core import ir
from .ast import (AggCall, EdgePat, IntLit, LengthCall, NodePat, OrderItem,
                  ParamRef, PathPat, Predicate, PropRef, Query, QueryError,
                  QueryCompileError, QuerySyntaxError, ReturnItem,
                  pretty_print)
from .golden import render_plan
from .ldbc_texts import QUERY_TEXTS
from .parser import parse
from .planner import compile_ast, compile_query

__all__ = [
    "AggCall", "EdgePat", "IntLit", "LengthCall", "NodePat", "OrderItem",
    "ParamRef", "PathPat", "Predicate", "PropRef", "QUERY_TEXTS", "Query",
    "QueryError", "QueryCompileError", "QuerySyntaxError", "ReturnItem",
    "compile_ast", "compile_query", "parse", "pretty_print", "render_plan",
]


@ir.register_plan_resolver
def _resolve_query_text(qname: str):
    """Treat a bundle query field that looks like query text as one."""
    if not isinstance(qname, str) or not qname.lstrip()[:6].upper() \
            .startswith("MATCH"):
        return None
    try:
        return compile_query(qname, name=qname)
    except QueryError as exc:
        raise KeyError(f"unparseable query text: {exc}") from exc
