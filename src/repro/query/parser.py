"""Lexer + recursive-descent parser for the GQL subset.

Fails closed: every malformed input raises a positioned
:class:`~repro.query.ast.QuerySyntaxError` — never a raw exception, never a
silently wrong AST.  Hard resource caps (text length, pattern/clause/hop
counts, literal magnitude) turn depth bombs into syntax errors before any
allocation scales with attacker input.

Keywords (``MATCH``/``WHERE``/…) are case-insensitive; identifiers and the
builtin function names (``shortestPath``, ``length``, ``count``, ``sum``,
``min``) are case-sensitive.
"""
from __future__ import annotations

from dataclasses import dataclass

from .ast import (AGG_FNS, EdgePat, IntLit, LengthCall, NodePat, OrderItem,
                  ParamRef, PathPat, Predicate, PropRef, Query,
                  QuerySyntaxError, ReturnItem, AggCall)

__all__ = ["parse", "MAX_TEXT", "MAX_ITEMS", "MAX_HOPS", "MAX_INT"]

MAX_TEXT = 4096         # bytes of query text
MAX_ITEMS = 8           # patterns / edges-per-path / predicates / items
MAX_HOPS = 8            # var-length upper bound
MAX_INT = 1 << 60       # integer literals must stay well under the field

KEYWORDS = ("MATCH", "WHERE", "AND", "RETURN", "ORDER", "BY", "LIMIT",
            "AS", "ASC", "DESC")

_PUNCT = ("<>", ">=", "<=", "<-", "->", "..", "(", ")", "[", "]", "{", "}",
          ",", ":", ".", "=", ">", "<", "-", "*", "$")


@dataclass(frozen=True)
class Token:
    kind: str       # IDENT | INT | KEYWORD | a punct literal | EOF
    text: str
    line: int
    col: int


def _lex(src: str) -> list:
    if not isinstance(src, str):
        raise QuerySyntaxError("query text must be a string", 1, 1)
    if len(src) > MAX_TEXT:
        raise QuerySyntaxError(
            f"query text exceeds {MAX_TEXT} characters", 1, 1)
    toks, i, line, col = [], 0, 1, 1
    n = len(src)
    while i < n:
        ch = src[i]
        if ch == "\n":
            i, line, col = i + 1, line + 1, 1
            continue
        if ch in " \t\r":
            i, col = i + 1, col + 1
            continue
        two = src[i:i + 2]
        if two in _PUNCT:
            toks.append(Token(two, two, line, col))
            i, col = i + 2, col + 2
            continue
        if ch in _PUNCT:
            toks.append(Token(ch, ch, line, col))
            i, col = i + 1, col + 1
            continue
        if ch.isdigit():
            j = i
            while j < n and src[j].isdigit():
                j += 1
            text = src[i:j]
            if int(text) >= MAX_INT:
                raise QuerySyntaxError(
                    f"integer literal too large: {text}", line, col)
            toks.append(Token("INT", text, line, col))
            col += j - i
            i = j
            continue
        if ch.isalpha() or ch == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            text = src[i:j]
            if text.upper() in KEYWORDS:
                toks.append(Token("KEYWORD", text.upper(), line, col))
            else:
                toks.append(Token("IDENT", text, line, col))
            col += j - i
            i = j
            continue
        raise QuerySyntaxError(f"unexpected character {ch!r}", line, col)
    toks.append(Token("EOF", "", line, col))
    return toks


class _Parser:
    def __init__(self, toks: list):
        self.toks = toks
        self.i = 0

    # -- token plumbing ------------------------------------------------------
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def _fail(self, msg: str):
        t = self.cur
        got = "end of input" if t.kind == "EOF" else repr(t.text)
        raise QuerySyntaxError(f"{msg} (got {got})", t.line, t.col)

    def at(self, kind: str, text: str = None) -> bool:
        t = self.cur
        return t.kind == kind and (text is None or t.text == text)

    def eat(self, kind: str, text: str = None, what: str = None) -> Token:
        if not self.at(kind, text):
            self._fail(f"expected {what or text or kind}")
        t = self.cur
        self.i += 1
        return t

    def opt(self, kind: str, text: str = None):
        if self.at(kind, text):
            return self.eat(kind, text)
        return None

    def _list(self, parse_one, what: str) -> tuple:
        items = [parse_one()]
        while self.opt(","):
            if len(items) >= MAX_ITEMS:
                self._fail(f"too many {what} (max {MAX_ITEMS})")
            items.append(parse_one())
        return tuple(items)

    # -- terms ---------------------------------------------------------------
    def value(self):
        if self.opt("$"):
            return ParamRef(self.eat("IDENT", what="parameter name").text)
        if self.at("INT"):
            return IntLit(int(self.eat("INT").text))
        self._fail("expected an integer or $parameter")

    def prop_ref(self) -> PropRef:
        var = self.eat("IDENT", what="variable").text
        self.eat(".")
        return PropRef(var, self.eat("IDENT", what="property name").text)

    # -- patterns ------------------------------------------------------------
    def node(self) -> NodePat:
        self.eat("(", what="'('")
        var = label = prop_key = prop_value = None
        if self.at("IDENT"):
            var = self.eat("IDENT").text
        if self.opt(":"):
            label = self.eat("IDENT", what="label").text
        if self.opt("{"):
            prop_key = self.eat("IDENT", what="property name").text
            self.eat(":")
            prop_value = self.value()
            self.eat("}", what="'}'")
        self.eat(")", what="')'")
        return NodePat(var, label, prop_key, prop_value)

    def edge_body(self) -> tuple:
        """``[var:TYPE*m..n]`` — returns (var, etype, min_hops, max_hops)."""
        self.eat("[", what="'['")
        var = etype = min_hops = max_hops = None
        if self.at("IDENT"):
            var = self.eat("IDENT").text
        if self.opt(":"):
            etype = self.eat("IDENT", what="edge type").text
        if self.opt("*"):
            if self.at("INT"):
                min_hops = int(self.eat("INT").text)
                self.eat("..", what="'..'")
                max_hops = int(self.eat("INT").text)
                if not 1 <= min_hops <= max_hops <= MAX_HOPS:
                    self._fail(f"hop bounds must satisfy "
                               f"1 <= m <= n <= {MAX_HOPS}")
            else:
                min_hops, max_hops = 1, None
        self.eat("]", what="']'")
        return var, etype, min_hops, max_hops

    def edge(self) -> EdgePat:
        if self.opt("<-"):
            var, etype, lo, hi = self.edge_body()
            self.eat("-", what="'-'")
            return EdgePat(var, etype, "in", lo, hi)
        self.eat("-", what="'-'")
        var, etype, lo, hi = self.edge_body()
        if self.opt("->"):
            return EdgePat(var, etype, "out", lo, hi)
        self.eat("-", what="'-' or '->'")
        return EdgePat(var, etype, "any", lo, hi)

    def path_body(self) -> tuple:
        nodes = [self.node()]
        edges = []
        while self.at("-") or self.at("<-"):
            if len(edges) >= MAX_ITEMS:
                self._fail(f"too many edges in one path (max {MAX_ITEMS})")
            edges.append(self.edge())
            nodes.append(self.node())
        return tuple(nodes), tuple(edges)

    def pattern(self) -> PathPat:
        path_var = None
        if self.at("IDENT") and self.toks[self.i + 1].kind == "=":
            path_var = self.eat("IDENT").text
            self.eat("=")
        if self.at("IDENT", "shortestPath"):
            self.eat("IDENT")
            self.eat("(", what="'('")
            nodes, edges = self.path_body()
            self.eat(")", what="')'")
            return PathPat(nodes, edges, path_var, shortest=True)
        nodes, edges = self.path_body()
        return PathPat(nodes, edges, path_var)

    # -- clauses -------------------------------------------------------------
    def predicate(self) -> Predicate:
        lhs = self.prop_ref()
        for cmp in ("<>", ">=", "<=", "=", ">", "<"):
            if self.opt(cmp):
                return Predicate(lhs, cmp, self.value())
        self._fail("expected a comparison operator")

    def return_item(self) -> ReturnItem:
        if self.at("IDENT", "length") and self.toks[self.i + 1].kind == "(":
            self.eat("IDENT")
            self.eat("(")
            expr = LengthCall(self.eat("IDENT", what="path variable").text)
            self.eat(")", what="')'")
        elif self.cur.kind == "IDENT" and self.cur.text in AGG_FNS \
                and self.toks[self.i + 1].kind == "(":
            fn = self.eat("IDENT").text
            self.eat("(")
            var = self.eat("IDENT", what="variable").text
            arg = PropRef(var, self.eat("IDENT").text) if self.opt(".") \
                else var
            self.eat(")", what="')'")
            expr = AggCall(fn, arg)
        else:
            expr = self.prop_ref()
        self.eat("KEYWORD", "AS", what="AS")
        return ReturnItem(expr, self.eat("IDENT", what="alias").text)

    def order_item(self) -> OrderItem:
        expr = self.prop_ref()
        if self.opt("KEYWORD", "DESC"):
            return OrderItem(expr, descending=True)
        self.eat("KEYWORD", "ASC", what="ASC or DESC")
        return OrderItem(expr, descending=False)

    # -- entry ---------------------------------------------------------------
    def query(self) -> Query:
        self.eat("KEYWORD", "MATCH", what="MATCH")
        patterns = self._list(self.pattern, "patterns")
        where = ()
        if self.opt("KEYWORD", "WHERE"):
            preds = [self.predicate()]
            while self.opt("KEYWORD", "AND"):
                if len(preds) >= MAX_ITEMS:
                    self._fail(f"too many predicates (max {MAX_ITEMS})")
                preds.append(self.predicate())
            where = tuple(preds)
        self.eat("KEYWORD", "RETURN", what="RETURN")
        returns = self._list(self.return_item, "return items")
        order = ()
        if self.opt("KEYWORD", "ORDER"):
            self.eat("KEYWORD", "BY", what="BY")
            order = self._list(self.order_item, "order items")
        limit = None
        if self.opt("KEYWORD", "LIMIT"):
            limit = self.value()
        self.eat("EOF", what="end of query")
        return Query(patterns, where, returns, order, limit)


def parse(src: str) -> Query:
    """Parse query text into a :class:`~repro.query.ast.Query` AST.

    Raises :class:`~repro.query.ast.QuerySyntaxError` (positioned) on any
    malformed input."""
    return _Parser(_lex(src)).query()
