"""Typed AST for the GQL/Cypher query subset (grammar: docs/query_language.md).

Every node is a frozen dataclass so ASTs are hashable and comparable — the
parser round-trip property (``parse(pretty_print(ast)) == ast``) is plain
equality.  :func:`pretty_print` emits the *canonical* text form: uppercase
keywords, explicit ``ASC``/``DESC``, single spaces.

Error taxonomy (all subclass :class:`QueryError`):

* :class:`QuerySyntaxError` — lexing/parsing failure, always positioned
  (1-based ``line``/``col``); hostile input fails closed here.
* :class:`QueryCompileError` — well-formed text outside the supported
  subset (unknown label/edge/property, unanchored pattern, …).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

__all__ = [
    "AggCall", "EdgePat", "IntLit", "LengthCall", "NodePat", "OrderItem",
    "ParamRef", "PathPat", "Predicate", "PropRef", "Query", "QueryError",
    "QueryCompileError", "QuerySyntaxError", "ReturnItem", "pretty_print",
]

AGG_FNS = ("count", "sum", "min")
CMP_TOKENS = ("=", "<>", ">=", ">", "<=", "<")


class QueryError(Exception):
    """Base class for every query front-door failure."""


class QuerySyntaxError(QueryError):
    """Lex/parse failure with a 1-based source position."""

    def __init__(self, msg: str, line: int, col: int):
        super().__init__(f"line {line}, col {col}: {msg}")
        self.msg = msg
        self.line = line
        self.col = col


class QueryCompileError(QueryError):
    """Well-formed query outside the supported subset / schema."""


# ---------------------------------------------------------------------------
# value terms
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamRef:
    """``$name`` — a query parameter reference."""
    name: str


@dataclass(frozen=True)
class IntLit:
    """A non-negative integer literal (the datasets are integer-coded)."""
    value: int


Value = "ParamRef | IntLit"


# ---------------------------------------------------------------------------
# patterns
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class NodePat:
    """``(var:Label {prop_key: prop_value})`` — every part optional."""
    var: Optional[str] = None
    label: Optional[str] = None
    prop_key: Optional[str] = None
    prop_value: Optional[object] = None     # ParamRef | IntLit


@dataclass(frozen=True)
class EdgePat:
    """``-[var:TYPE*m..n]->`` / ``<-[...]-`` / ``-[...]-``.

    ``direction`` is ``out``/``in``/``any`` (left-to-right reading);
    ``min_hops``/``max_hops`` are both None for a single hop, ``(1, None)``
    for an unbounded ``*``, else the explicit ``*m..n`` bounds."""
    var: Optional[str] = None
    etype: Optional[str] = None
    direction: str = "any"
    min_hops: Optional[int] = None
    max_hops: Optional[int] = None


@dataclass(frozen=True)
class PathPat:
    """One linear path: n nodes joined by n-1 edges, optionally named and
    wrapped in ``shortestPath(...)``."""
    nodes: Tuple[NodePat, ...]
    edges: Tuple[EdgePat, ...] = ()
    path_var: Optional[str] = None
    shortest: bool = False


# ---------------------------------------------------------------------------
# expressions / clauses
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PropRef:
    """``var.key``."""
    var: str
    key: str


@dataclass(frozen=True)
class Predicate:
    """``var.key <cmp> value`` with cmp one of ``=``/``<>``/``>=``/``>``/
    ``<=``/``<``."""
    lhs: PropRef
    cmp: str
    rhs: object                             # ParamRef | IntLit


@dataclass(frozen=True)
class AggCall:
    """``count(var)`` or ``sum(var.key)`` / ``min(var.key)``."""
    fn: str                                 # count | sum | min
    arg: object                             # str (a var) for count, PropRef


@dataclass(frozen=True)
class LengthCall:
    """``length(path_var)``."""
    path_var: str


@dataclass(frozen=True)
class ReturnItem:
    """``expr AS alias`` — the alias names the query-result key."""
    expr: object                            # PropRef | AggCall | LengthCall
    alias: str


@dataclass(frozen=True)
class OrderItem:
    expr: PropRef
    descending: bool = True


@dataclass(frozen=True)
class Query:
    patterns: Tuple[PathPat, ...]
    where: Tuple[Predicate, ...]
    returns: Tuple[ReturnItem, ...]
    order: Tuple[OrderItem, ...] = ()
    limit: Optional[object] = None          # ParamRef | IntLit


# ---------------------------------------------------------------------------
# canonical pretty printer
# ---------------------------------------------------------------------------
def _value(v) -> str:
    if isinstance(v, ParamRef):
        return f"${v.name}"
    if isinstance(v, IntLit):
        return str(v.value)
    raise TypeError(f"not a value term: {v!r}")


def _node(n: NodePat) -> str:
    s = n.var or ""
    if n.label is not None:
        s += f":{n.label}"
    if n.prop_key is not None:
        prop = f"{{{n.prop_key}: {_value(n.prop_value)}}}"
        s = f"{s} {prop}" if s else prop
    return f"({s})"


def _edge(e: EdgePat) -> str:
    inner = e.var or ""
    if e.etype is not None:
        inner += f":{e.etype}"
    if e.min_hops is not None:
        if e.max_hops is None:
            inner += "*"
        else:
            inner += f"*{e.min_hops}..{e.max_hops}"
    body = f"[{inner}]" if inner else "[]"
    if e.direction == "out":
        return f"-{body}->"
    if e.direction == "in":
        return f"<-{body}-"
    return f"-{body}-"


def _path(p: PathPat) -> str:
    body = _node(p.nodes[0])
    for e, n in zip(p.edges, p.nodes[1:]):
        body += _edge(e) + _node(n)
    if p.shortest:
        body = f"shortestPath({body})"
    if p.path_var is not None:
        body = f"{p.path_var} = {body}"
    return body


def _expr(x) -> str:
    if isinstance(x, PropRef):
        return f"{x.var}.{x.key}"
    if isinstance(x, AggCall):
        arg = x.arg if isinstance(x.arg, str) else _expr(x.arg)
        return f"{x.fn}({arg})"
    if isinstance(x, LengthCall):
        return f"length({x.path_var})"
    raise TypeError(f"not an expression: {x!r}")


def pretty_print(q: Query) -> str:
    """Canonical single-line text for ``q`` (parses back to an equal AST)."""
    parts = ["MATCH " + ", ".join(_path(p) for p in q.patterns)]
    if q.where:
        parts.append("WHERE " + " AND ".join(
            f"{_expr(p.lhs)} {p.cmp} {_value(p.rhs)}" for p in q.where))
    parts.append("RETURN " + ", ".join(
        f"{_expr(it.expr)} AS {it.alias}" for it in q.returns))
    if q.order:
        parts.append("ORDER BY " + ", ".join(
            f"{_expr(o.expr)} {'DESC' if o.descending else 'ASC'}"
            for o in q.order))
    if q.limit is not None:
        parts.append("LIMIT " + _value(q.limit))
    return " ".join(parts)
