"""Schema catalog: how query-surface labels / edge types / properties map
onto the owner's published GraphDB tables (:mod:`repro.graphdb.tables`).

The planner consults only this module for name resolution, so growing the
query surface to a new dataset is a catalog edit, not a planner edit.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Optional, Tuple

from .ast import QueryCompileError

__all__ = ["EDGES", "LABELS", "PROP_TABLES", "EdgeInfo", "PropTable",
           "edge_info", "prop_table_for"]

LABELS = frozenset({"Person", "Message", "Comment"})


@dataclass(frozen=True)
class EdgeInfo:
    """One edge type: its committed tables and endpoint label sets."""
    table: str                              # forward (src -> dst) table
    rev_table: Optional[str] = None         # reversed table, if published
    undirected: bool = False
    # edge property -> the with-prop edge table carrying it
    prop_tables: dict = dc_field(default_factory=dict)
    # node-set table for shortest-path verification over this edge type
    sssp_nodes: Optional[str] = None
    src_labels: frozenset = frozenset()
    dst_labels: frozenset = frozenset()


EDGES = {
    "KNOWS": EdgeInfo(
        table="knows", undirected=True,
        prop_tables={"creationDate": "knows_date"},
        sssp_nodes="knows_nodes",
        src_labels=frozenset({"Person"}), dst_labels=frozenset({"Person"})),
    "HAS_CREATOR": EdgeInfo(
        table="hasCreator", rev_table="hasCreator_rev",
        src_labels=frozenset({"Message", "Comment"}),
        dst_labels=frozenset({"Person"})),
    "REPLY_OF": EdgeInfo(
        table="replyOf", rev_table="replyOf_rev",
        src_labels=frozenset({"Comment", "Message"}),
        dst_labels=frozenset({"Message", "Comment"})),
}


@dataclass(frozen=True)
class PropTable:
    """A published node-property lookup table: node id -> property value(s).

    ``props`` is ordered: for a 1-prop table the value rides the expansion's
    ``dst`` output; for a 2-prop table ``props[0]`` rides ``dst`` and
    ``props[1]`` rides ``prop`` (the with-prop expansion layout)."""
    table: str
    labels: frozenset
    props: Tuple[str, ...]


PROP_TABLES = (
    PropTable("person_firstName", frozenset({"Person"}), ("firstName",)),
    PropTable("comment_date", frozenset({"Message", "Comment"}),
              ("creationDate",)),
    PropTable("comment_content_date", frozenset({"Message", "Comment"}),
              ("content", "creationDate")),
)


def edge_info(etype: Optional[str]) -> EdgeInfo:
    if etype is None:
        raise QueryCompileError(
            "edge patterns must name an edge type (e.g. [:KNOWS])")
    info = EDGES.get(etype)
    if info is None:
        raise QueryCompileError(
            f"unknown edge type {etype!r}; known: {sorted(EDGES)}")
    return info


def prop_table_for(label: str, props: Tuple[str, ...]) -> PropTable:
    """The smallest published lookup table for ``label`` covering ``props``."""
    if label not in LABELS:
        raise QueryCompileError(
            f"unknown label {label!r}; known: {sorted(LABELS)}")
    best = None
    for pt in PROP_TABLES:
        if label in pt.labels and set(props) <= set(pt.props):
            if best is None or len(pt.props) < len(best.props):
                best = pt
    if best is None:
        raise QueryCompileError(
            f"no published property table for {label}.{{{', '.join(props)}}}")
    return best
