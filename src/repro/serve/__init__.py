"""Concurrent verifiable-query serving (`docs/serving.md`).

The serving layer turns a :class:`~repro.core.session.ZKGraphSession` into a
multi-tenant proving service: concurrent query submissions are decomposed
into plan steps, same-shaped steps from *different* queries are routed into
shared shape-keyed batch queues, and each flushed batch rides one
lane-batched prover pass (:mod:`repro.core.prover_batch`) — so commitment,
constraint, and FRI dispatches amortize across queries while every returned
bundle stays wire-byte-identical to a solo prove.
"""
from .batching import BatchReady, ShapeBatcher, StepSlot
from .metrics import Histogram, ServiceMetrics
from .pipeline import Stage
from .placement import Placement, serving_mesh
from .service import ProofService, ServiceClosed

__all__ = ["BatchReady", "Histogram", "Placement", "ProofService",
           "ServiceClosed", "ServiceMetrics", "ShapeBatcher", "Stage",
           "StepSlot", "serving_mesh"]
