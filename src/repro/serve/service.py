"""ProofService: batched, pipelined, concurrent verifiable query serving.

``submit(qname, params)`` returns a ``concurrent.futures.Future`` resolving
to the same :class:`~repro.core.session.ProofBundle` a direct
``session.prove`` call would produce — wire-byte-identical (timings aside),
which is what lets one service answer many mutually-distrustful clients:
batching is invisible in the artifact.

Dataflow (docs/serving.md has the picture)::

    submit -> [witness stage] -> ShapeBatcher -> [prove stage] -> Future
                  run_query       size/deadline     prove_steps
                                  flush (scheduler)  (lane-batched)

* The witness stage executes the query plan (host-heavy) and drops each
  step into the shape-keyed batcher; same-shaped steps from different
  queries share a queue.
* The scheduler thread flushes queues on deadline; full queues flush
  inline on size.
* The prove stage pads each batch to a power-of-two lane count (bounding
  the set of jitted shapes), runs ONE lane-batched prove, and fulfills the
  per-query slots; a query's future resolves when its last step lands.

Backpressure is the bounded stage inboxes: a slow prover backs up the
batch queue, then the witness inbox, then ``submit`` itself blocks.
Failures are per-query: a poisoned query fails its own future; the service
keeps serving.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field as dc_field

from ..core import backend as be
from ..core.session import ProofBundle, ZKGraphSession
from .batching import BatchReady, ShapeBatcher, StepSlot
from .metrics import ServiceMetrics
from .pipeline import Stage


class ServiceClosed(RuntimeError):
    """submit() after close()."""


@dataclass
class _Ticket:
    """One in-flight query submission."""
    qname: str
    params: dict
    future: Future
    submitted: float = dc_field(default_factory=time.monotonic)
    run: object = None          # ir.QueryRun once the witness stage ran
    results: list = None        # per-step StepProof slots (plan order)
    remaining: int = 0
    failed: bool = False


class ProofService:
    """Batched concurrent proving on top of one owner session.

    ``max_batch``: lane cap per shape queue (flush-on-size threshold).
    ``flush_interval``: seconds a lone step may wait for lane-mates.
    ``max_pending``: admission bound — submissions beyond it block.
    ``placement``: optional :class:`repro.serve.placement.Placement`
    sharding the lane axis across a device mesh.
    ``pad_pow2``: pad batches to power-of-two lane counts so the jit cache
    sees O(log max_batch) shapes per circuit, not O(max_batch).
    """

    def __init__(self, session: ZKGraphSession, *, max_batch: int = 8,
                 flush_interval: float = 0.025, max_pending: int = 64,
                 placement=None, pad_pow2: bool = True):
        assert session.db is not None, \
            "ProofService serves an owner session (needs the database)"
        self.session = session
        self.placement = placement
        self.pad_pow2 = pad_pow2
        # pin the compute backend NOW, in the caller's thread: worker threads
        # do not inherit be.use() scopes (thread-local), so the service must
        # carry the resolved name across and re-enter it per worker task
        self._backend = be.resolve_name(session.cfg.backend)
        with be.use(self._backend):
            # prime the manifest once so worker threads never race the lazy
            # publish; its digest is stamped into every bundle
            self._manifest_digest = session.commitments.digest()
        self.metrics = ServiceMetrics()
        self.batcher = ShapeBatcher(max_batch, flush_interval)
        self._lock = threading.Lock()
        self._closed = False
        self._prove = Stage("prove", self._handle_batch, maxsize=4,
                            on_error=self._batch_error).start()
        self._witness = Stage("witness", self._handle_ticket,
                              maxsize=max_pending,
                              on_error=self._ticket_error).start()
        self._stop_evt = threading.Event()
        self._scheduler = threading.Thread(target=self._run_scheduler,
                                           name="zkserve-scheduler",
                                           daemon=True)
        self._scheduler.start()

    # -- client surface ------------------------------------------------------
    def submit(self, qname: str, params: dict,
               timeout: float = None) -> Future:
        """Queue one query; blocks when ``max_pending`` submissions are in
        flight (backpressure).  The future resolves to the ProofBundle, or
        raises the query's failure."""
        with self._lock:
            if self._closed:
                raise ServiceClosed("ProofService is closed")
        ticket = _Ticket(qname, dict(params), Future())
        self.metrics.inc("submitted")
        self._witness.put(ticket, timeout=timeout)
        return ticket.future

    def stats(self) -> dict:
        """The full metrics snapshot (docs/serving.md schema) plus live
        queue depths."""
        out = self.metrics.snapshot(cache_stats=self.session.cache.stats())
        out["depths"] = dict(witness=self._witness.depth(),
                             batcher=self.batcher.depth(),
                             prove=self._prove.depth())
        return out

    def close(self):
        """Drain everything in flight, then stop the workers.  Every
        already-submitted future resolves before close returns."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._witness.stop(wait=True)           # all tickets reach batcher
        self._stop_evt.set()
        self._scheduler.join()
        for ready in self.batcher.drain():      # flush partial batches
            self._prove.put(ready)
        self._prove.stop(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- witness stage -------------------------------------------------------
    def _handle_ticket(self, ticket: _Ticket):
        with be.use(self._backend):
            run = self.session.run_query(ticket.qname, ticket.params)
            ticket.run = run
            ticket.results = [None] * len(run.steps)
            ticket.remaining = len(run.steps)
            if not run.steps:
                self._complete(ticket)
                return
            for pos, st in enumerate(run.steps):
                key = self.session.step_shape_key(st)
                ready = self.batcher.add(key, StepSlot(ticket, pos, st))
                if ready is not None:
                    self._prove.put(ready)      # blocks = backpressure

    def _ticket_error(self, ticket: _Ticket, exc: BaseException):
        self._fail(ticket, exc)

    # -- scheduler (deadline flush) ------------------------------------------
    def _run_scheduler(self):
        while not self._stop_evt.wait(
                timeout=max(0.001, self.batcher.next_deadline())):
            for ready in self.batcher.take_expired():
                self._prove.put(ready)

    # -- prove stage ---------------------------------------------------------
    def _lane_count(self, n: int) -> int:
        if not self.pad_pow2:
            return n
        lanes = 1
        while lanes < n:
            lanes *= 2
        return lanes

    def _handle_batch(self, ready: BatchReady):
        live = [s for s in ready.slots if not s.ticket.failed]
        if not live:
            return
        now = time.monotonic()
        for s in live:
            self.metrics.queue_wait_us.observe((now - s.enqueued) * 1e6)
        steps = [s.step for s in live]
        pad = self._lane_count(len(steps)) - len(steps)
        t0 = time.perf_counter()
        with be.use(self._backend):
            # pad lanes replicate the last witness; their proofs are
            # discarded (bit-identity makes them redundant, not wrong)
            step_proofs = self.session.prove_steps(steps + [steps[-1]] * pad)
        self.metrics.prove_us.observe((time.perf_counter() - t0) * 1e6)
        self.metrics.inc("batches")
        self.metrics.inc("lanes", len(steps))
        self.metrics.inc("pad_lanes", pad)
        self.metrics.batch_occupancy.observe(len(steps))
        self.metrics.observe_phases(step_proofs[0].proof.timings)
        for slot, sp in zip(live, step_proofs):
            self._fulfill(slot, sp)

    def _batch_error(self, ready: BatchReady, exc: BaseException):
        for slot in ready.slots:
            self._fail(slot.ticket, exc)

    # -- completion bookkeeping ----------------------------------------------
    def _fulfill(self, slot: StepSlot, step_proof):
        ticket = slot.ticket
        with self._lock:
            if ticket.failed:
                return
            ticket.results[slot.pos] = step_proof
            ticket.remaining -= 1
            done = ticket.remaining == 0
        if done:
            self._complete(ticket)

    def _complete(self, ticket: _Ticket):
        bundle = ProofBundle(ticket.qname, dict(ticket.params),
                             list(ticket.results or []), ticket.run.result,
                             self.session.cfg, self._manifest_digest)
        self.metrics.inc("completed")
        ticket.future.set_result(bundle)

    def _fail(self, ticket: _Ticket, exc: BaseException):
        with self._lock:
            if ticket.failed:
                return
            ticket.failed = True
        self.metrics.inc("failed")
        ticket.future.set_exception(exc)
