"""Device-mesh placement for lane-batched proving.

Wires the training substrate's mesh/sharding helpers (`repro.launch.mesh`,
`repro.launch.sharding`) into the serving path: the lane axis of a batched
prove is data-parallel by construction (lanes never interact), so a batch of
L witnesses shards its leading axis across the mesh's ``data`` axis and each
device proves its lane slice under the same jitted computation.

On this container there is a single device, so the mesh degrades to
``(1, 1)`` and placement is an explicit no-op-shaped ``device_put`` — but
the same code path scales the lane axis out on a real pod
(:func:`repro.launch.mesh.make_production_mesh`), and
``sanitize_spec`` already handles non-divisible lane counts by de-sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch import mesh as mesh_lib
from ..launch import sharding as sharding_lib


def serving_mesh(*, production: bool = False, multi_pod: bool = False):
    """The serving mesh: all local devices on the ``data`` (lane) axis.

    ``production=True`` returns the 256/512-chip training-substrate mesh
    (`repro.launch.mesh.make_production_mesh`) instead — same axis names, so
    :class:`Placement` is oblivious to which one it got.
    """
    if production:
        return mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


@dataclass
class Placement:
    """Shards the lane axis of batched-prove inputs across a mesh."""
    mesh: object

    def lane_sharding(self, shape) -> NamedSharding:
        """NamedSharding for one (L, ...) witness stack: lanes over the
        data-parallel axes, everything else replicated; non-divisible lane
        counts fall back to replication (sanitize_spec)."""
        spec = P(mesh_lib.dp_axes(self.mesh), *([None] * (len(shape) - 1)))
        spec = sharding_lib.sanitize_spec(spec, shape, self.mesh)
        return NamedSharding(self.mesh, spec)

    def shard_lanes(self, *arrays):
        """device_put each (L, ...) array with its lane sharding (the
        prover's entry hook — see prover_batch.prove_batch)."""
        return tuple(jax.device_put(a, self.lane_sharding(a.shape))
                     for a in arrays)

    @property
    def lane_parallelism(self) -> int:
        return mesh_lib.data_size(self.mesh)
