"""Shape-keyed batch queues: route same-shaped steps into shared lanes.

The routing key is :meth:`ZKGraphSession.step_shape_key` — the keygen-cache
key — so two steps land in the same queue exactly when they share circuit
structure, prover config, and compute backend, i.e. exactly when their
witnesses can ride one :func:`repro.core.prover_batch.prove_batch` pass.

A queue flushes on **size or deadline**: the moment it holds ``max_batch``
slots it emits a full batch; otherwise the scheduler flushes any queue whose
oldest slot has waited ``flush_interval`` seconds.  Deadline flushing bounds
the latency a lone query pays for batching; size flushing bounds memory.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field as dc_field


@dataclass
class StepSlot:
    """One plan step of one in-flight query, waiting for lane-mates."""
    ticket: object          # serve.service._Ticket owning this step
    pos: int                # index into the query's plan-step order
    step: object            # ir.Step (witness already built)
    enqueued: float = dc_field(default_factory=time.monotonic)


@dataclass
class BatchReady:
    """A flushed batch: same-shaped slots ready for one lane-batched prove."""
    key: tuple              # the shared step_shape_key
    slots: list             # [StepSlot], 1 <= len <= max_batch


class ShapeBatcher:
    """The shared batch queues; thread-safe, no threads of its own."""

    def __init__(self, max_batch: int = 8, flush_interval: float = 0.025):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.flush_interval = flush_interval
        self._lock = threading.Lock()
        # key -> [StepSlot]; OrderedDict so expiry scans oldest-first
        self._queues: "OrderedDict[tuple, list]" = OrderedDict()

    def add(self, key: tuple, slot: StepSlot):
        """Queue one slot; returns a BatchReady when this fill hits
        ``max_batch``, else None (the scheduler's deadline will flush it)."""
        with self._lock:
            q = self._queues.setdefault(key, [])
            q.append(slot)
            if len(q) >= self.max_batch:
                del self._queues[key]
                return BatchReady(key, q)
        return None

    def take_expired(self, now: float = None):
        """Flush every queue whose oldest slot exceeded the deadline."""
        if now is None:
            now = time.monotonic()
        ready = []
        with self._lock:
            for key in list(self._queues):
                q = self._queues[key]
                if q and now - q[0].enqueued >= self.flush_interval:
                    del self._queues[key]
                    ready.append(BatchReady(key, q))
        return ready

    def drain(self):
        """Flush everything (service shutdown)."""
        with self._lock:
            ready = [BatchReady(k, q) for k, q in self._queues.items() if q]
            self._queues.clear()
        return ready

    def depth(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def next_deadline(self, now: float = None) -> float:
        """Seconds until the oldest queued slot expires (scheduler sleep
        bound); ``flush_interval`` when nothing is queued."""
        if now is None:
            now = time.monotonic()
        with self._lock:
            oldest = min((q[0].enqueued for q in self._queues.values() if q),
                         default=None)
        if oldest is None:
            return self.flush_interval
        return max(0.0, oldest + self.flush_interval - now)
