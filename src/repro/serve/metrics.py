"""Observability surface of the proving service (`docs/serving.md` schema).

Everything here is plain host-side bookkeeping — thread-safe, allocation-
bounded, and cheap enough to leave on in production.  The service exposes one
:meth:`ServiceMetrics.snapshot` dict; ``benchmarks/paper_tables.py:serving``
and the regression gate consume the same schema.
"""
from __future__ import annotations

import threading


class Histogram:
    """Bounded-reservoir latency/occupancy histogram.

    Keeps the most recent ``max_samples`` observations (a ring buffer — a
    long-lived service must not grow without limit) plus exact running
    count/sum, and reports order statistics over the reservoir.
    """

    def __init__(self, max_samples: int = 4096):
        self._max = max_samples
        self._ring = [0.0] * max_samples
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0

    def observe(self, value: float):
        with self._lock:
            self._ring[self.count % self._max] = float(value)
            self.count += 1
            self.total += float(value)

    def _samples(self):
        n = min(self.count, self._max)
        return sorted(self._ring[:n])

    def percentile(self, p: float) -> float:
        """p in [0, 100]; nearest-rank over the reservoir (0.0 when empty)."""
        with self._lock:
            s = self._samples()
        if not s:
            return 0.0
        rank = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[rank]

    def snapshot(self) -> dict:
        with self._lock:
            s = self._samples()
            count, total = self.count, self.total
        if not s:
            return dict(count=0, mean=0.0, p50=0.0, p95=0.0, max=0.0)

        def pct(p):
            return s[min(len(s) - 1,
                         max(0, int(round(p / 100.0 * (len(s) - 1)))))]

        return dict(count=count, mean=total / count, p50=pct(50),
                    p95=pct(95), max=s[-1])


# the prover's per-phase timing keys, in pipeline order (prover.py timings)
PHASES = ("commit_advice", "phase2_ext", "quotient", "ood_openings", "deep",
          "fri", "total")


class ServiceMetrics:
    """All service counters + histograms; one :meth:`snapshot` dict.

    Schema (documented in docs/serving.md and consumed by the serving
    benchmark)::

        counters:        submitted / completed / failed / batches /
                         lanes / pad_lanes
        phase_us:        per prover phase -> {count, mean, p50, p95, max}
        queue_wait_us:   submit -> batch-flush wait     (same stats dict)
        prove_us:        per-batch prove wall time      (same stats dict)
        batch_occupancy: real lanes per flushed batch   (same stats dict)
        keygen_cache:    {hits, misses, waits, entries} (KeygenCache.stats)
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters = dict(submitted=0, completed=0, failed=0, batches=0,
                              lanes=0, pad_lanes=0)
        self.phase_us = {p: Histogram() for p in PHASES}
        self.queue_wait_us = Histogram()
        self.prove_us = Histogram()
        self.batch_occupancy = Histogram()

    def inc(self, name: str, by: int = 1):
        with self._lock:
            self._counters[name] += by

    def observe_phases(self, timings: dict):
        """Record one prove's per-phase seconds (stored as microseconds)."""
        for phase in PHASES:
            if phase in timings:
                self.phase_us[phase].observe(timings[phase] * 1e6)

    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def snapshot(self, cache_stats: dict = None) -> dict:
        out = dict(
            counters=self.counters(),
            phase_us={p: h.snapshot() for p, h in self.phase_us.items()},
            queue_wait_us=self.queue_wait_us.snapshot(),
            prove_us=self.prove_us.snapshot(),
            batch_occupancy=self.batch_occupancy.snapshot(),
        )
        if cache_stats is not None:
            out["keygen_cache"] = dict(cache_stats)
        return out
