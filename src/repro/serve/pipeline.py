"""Bounded-queue pipeline stages for the proving service.

A :class:`Stage` is one worker thread draining one bounded queue.  The
bounded queues ARE the backpressure: when a downstream stage falls behind,
upstream ``put`` calls block, and ultimately :meth:`ProofService.submit`
itself blocks — admission control without any explicit token scheme.

On this container the prover is effectively single-core, so the win from
pipelining is *overlap of host-side phases* (witness building, transcript
bookkeeping, result assembly) with device dispatch of the previous batch —
plus the batching itself, which is where the throughput lives
(`docs/serving.md`).
"""
from __future__ import annotations

import queue
import threading

_STOP = object()


class Stage:
    """One pipeline stage: ``handler(item)`` on a dedicated worker thread.

    ``on_error(item, exc)`` is invoked (on the worker) when the handler
    raises; the stage keeps running — one poisoned query must not take the
    service down.  ``maxsize`` bounds the inbox; full inboxes block
    producers (backpressure).
    """

    def __init__(self, name: str, handler, maxsize: int = 8, on_error=None):
        self.name = name
        self.inbox: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self._handler = handler
        self._on_error = on_error
        self._thread = threading.Thread(target=self._run,
                                        name=f"zkserve-{name}", daemon=True)

    def start(self):
        self._thread.start()
        return self

    def put(self, item, timeout: float = None):
        self.inbox.put(item, timeout=timeout)

    def _run(self):
        while True:
            item = self.inbox.get()
            if item is _STOP:
                self.inbox.task_done()
                return
            try:
                self._handler(item)
            except BaseException as exc:      # noqa: BLE001 — stage survives
                if self._on_error is not None:
                    self._on_error(item, exc)
            finally:
                self.inbox.task_done()

    def stop(self, wait: bool = True):
        """Send the stop sentinel; with ``wait`` join the worker after it
        drains everything already queued ahead of the sentinel."""
        self.inbox.put(_STOP)
        if wait:
            self._thread.join()

    def depth(self) -> int:
        return self.inbox.qsize()
