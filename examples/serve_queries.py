"""End-to-end proving service (the paper-kind e2e driver): a batched queue of
graph queries is executed + proven with fault-tolerant checkpointing — kill it
mid-run and restart: it resumes at the first unproven query.

    PYTHONPATH=src python examples/serve_queries.py [--queries 8] [--restart-demo]

One ZKGraphSession serves the whole queue, so its keygen cache turns repeated
query shapes into cache hits — the steady-state cost a proving service pays.
At production scale each query's proof is independent, so the batch fans out
across the ('pod','data') mesh axes — this driver is the single-host cell of
that fleet (see launch/dryrun.py for the multi-pod lowering of the LM cells).
"""
import sys
sys.path.insert(0, "src")

import argparse
import json
import os
import time

import numpy as np

from repro.core import prover as pv
from repro.core.session import ZKGraphSession
from repro.core.transparency import TransparencyLog, verify_consistency
from repro.graphdb import ldbc
from repro.train.fault import FaultController, FaultConfig

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)
STATE = "/tmp/zkgraph_serve_state.json"


def query_queue(db, n):
    rng = np.random.default_rng(41)
    qs = []
    for i in range(n):
        kind = ["IS3", "IS5", "IC13"][i % 3]
        if kind == "IS3":
            qs.append((kind, dict(person=int(rng.integers(1, db.n_nodes)))))
        elif kind == "IS5":
            qs.append((kind, dict(message=(1 << 20) + int(
                rng.integers(0, 32)))))
        else:
            qs.append((kind, dict(person1=int(rng.integers(1, 8)),
                                  person2=int(rng.integers(9, 24)))))
    return qs


def main(argv=None, n_knows=128, n_persons=24, cfg=CFG):
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--reset", action="store_true")
    ap.add_argument("--restart-demo", action="store_true",
                    help="simulate a crash after 2 queries, then resume")
    args = ap.parse_args(argv)
    if args.reset and os.path.exists(STATE):
        os.remove(STATE)

    db = ldbc.generate(n_knows=n_knows, n_persons=n_persons, seed=3)
    session = ZKGraphSession(db, cfg)
    # the owner publishes the manifest on an append-only transparency log;
    # the verifier bootstraps its ENTIRE trust root from the checkpoint +
    # inclusion proof + manifest bytes — no in-process object is trusted
    log = TransparencyLog("zkgraph-serve-log")
    checkpoint, inclusion, manifest_bytes = session.publish_to(log)
    print(f"manifest published: {len(manifest_bytes)} bytes -> "
          f"log {checkpoint.origin!r} size {checkpoint.tree_size}")
    verifier = ZKGraphSession.verifier(
        cfg=cfg, checkpoint=checkpoint, inclusion=inclusion,
        manifest_bytes=manifest_bytes)
    queue = query_queue(db, args.queries)
    done = {}
    if os.path.exists(STATE):
        done = json.load(open(STATE))
        print(f"resuming: {len(done)} queries already proven")

    ctrl = FaultController(["prover0"], FaultConfig())
    t0 = time.time()
    for i, (kind, params) in enumerate(queue):
        key = f"q{i}"
        if key in done:
            continue
        ts = time.time()
        bundle = session.prove(kind, params)
        ok = verifier.verify(bundle)
        assert ok, f"{key} failed verification"
        dt = time.time() - ts
        ctrl.heartbeat("prover0", dt)
        ctrl.sweep()
        done[key] = dict(kind=kind, params=params, steps=len(bundle.steps),
                         prove_s=round(dt, 2),
                         proof_fields=bundle.size_fields())
        json.dump(done, open(STATE, "w"))   # checkpoint after each query
        print(f"{key} {kind:5s} {len(bundle.steps)} ops proven+verified "
              f"in {dt:.1f}s")
        if args.restart_demo and i == 1:
            print("-- simulated crash (state checkpointed); rerun to resume --")
            return
    wall = time.time() - t0
    stats = session.cache.stats()
    print(f"served {len(done)} verified queries, batch wall {wall:.1f}s; "
          f"keygen cache: {stats['misses']} keygens, {stats['hits']} reuses")
    # a manifest revision appends a NEW leaf; clients holding the old
    # checkpoint verify the log only grew (equivocation would fail this)
    new_cp, _, _ = session.publish_to(log)
    ok = verify_consistency(checkpoint, new_cp,
                            log.consistency_proof(checkpoint.tree_size,
                                                  new_cp.tree_size))
    print(f"log grew {checkpoint.tree_size} -> {new_cp.tree_size}, "
          f"append-only consistency verified: {ok}")
    assert ok
    if os.path.exists(STATE):
        os.remove(STATE)


if __name__ == "__main__":
    main()
