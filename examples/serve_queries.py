"""Multi-process transparency deployment demo: one owner, two verifiers.

The full deployment story of the durable transparency layer, end to end::

    PYTHONPATH=src python examples/serve_queries.py [--queries 4] [--dir D]

The driver (this process) orchestrates three child processes over a shared
work directory — no in-process object crosses a trust boundary, only bytes:

* an **owner** that opens a *durable* transparency log
  (``TransparencyLog.open``), publishes the commitment manifest as leaf 0,
  emits a signed gossip head, proves a queue of LDBC queries to spool
  files, then appends a manifest revision and gossips the new head with a
  consistency proof;
* **two verifiers** that each pin the head with a ``GossipPeer``, bootstrap
  their entire trust root from ``(gossip-pinned checkpoint, inclusion
  proof, manifest bytes)``, verify every spooled bundle from bytes alone,
  advance their head across the revision only on a valid consistency
  proof, and cross-gossip their heads with each other.

Mid-stream the driver **kills the owner with SIGKILL**, appends a torn
half-record to the log file (what a crash during an unsynced write leaves
behind), and restarts the owner: the reopened log truncates the torn tail,
re-derives every Merkle root against the stored checkpoints, and the owner
resumes at the first unproven query.  Finally the driver plays a malicious
owner: it forks the log history and gossips a conflicting signed head —
both verifiers must raise ``EquivocationError`` with the two conflicting
checkpoints as evidence.

The driver asserts all of it: recovery happened, every bundle verified in
both verifier processes, heads advanced exactly once, and equivocation was
detected twice.
"""
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
sys.path.insert(0, str(SRC))

import argparse
import json
import os
import signal
import subprocess
import tempfile
import time

from repro.core import gossip
from repro.core import prover as pv
from repro.core.session import ZKGraphSession
from repro.core.transparency import InclusionProof, TransparencyLog
from repro.graphdb import ldbc
from repro.serve import ProofService

CFG = pv.ProverConfig(blowup=4, n_queries=16, fri_final_size=16)
ORIGIN = "zkgraph-serve-log"
# the log operator's gossip key.  The demo driver knowingly holds it so it
# can play a MALICIOUS owner in the final act — which is exactly the threat
# gossip exists to catch: a correctly-signed but equivocating head.
AUTH_KEY = b"zkgraph-demo-origin-key"
TIMEOUT = float(os.environ.get("ZKGRAPH_DEMO_TIMEOUT", "900"))


def query_queue(db, n):
    import numpy as np
    rng = np.random.default_rng(41)
    qs = []
    for i in range(n):
        kind = ["IS3", "IS5", "IC13"][i % 3]
        if kind == "IS3":
            qs.append((kind, dict(person=int(rng.integers(1, db.n_nodes)))))
        elif kind == "IS5":
            qs.append((kind, dict(message=(1 << 20) + int(
                rng.integers(0, 32)))))
        else:
            qs.append((kind, dict(person1=int(rng.integers(1, 8)),
                                  person2=int(rng.integers(9, 24)))))
    return qs


# ---------------------------------------------------------------------------
# shared helpers: atomic byte exchange through the work dir
# ---------------------------------------------------------------------------
def _strip_timings(raw: bytes) -> bytes:
    """Re-encode bundle bytes with per-step prover timings zeroed: timings
    are host-side telemetry carried in the wire format, and the only field
    where a batched and a solo prove may legitimately differ."""
    from repro.core.session import ProofBundle
    bundle = ProofBundle.from_bytes(raw)
    for sp in bundle.steps:
        sp.proof.timings = {}
    return bundle.to_bytes()


def atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)       # readers only ever see complete files


def wait_for(path: Path, deadline: float) -> bytes:
    while time.time() < deadline:
        if path.exists():
            return path.read_bytes()
        time.sleep(0.1)
    raise TimeoutError(f"timed out waiting for {path}")


def _cfg_args(cfg: pv.ProverConfig, n_knows: int, n_persons: int) -> list:
    return ["--blowup", str(cfg.blowup), "--n-queries", str(cfg.n_queries),
            "--fri-final-size", str(cfg.fri_final_size),
            "--n-knows", str(n_knows), "--n-persons", str(n_persons)]


def _build(args):
    cfg = pv.ProverConfig(blowup=args.blowup, n_queries=args.n_queries,
                          fri_final_size=args.fri_final_size)
    db = ldbc.generate(n_knows=args.n_knows, n_persons=args.n_persons,
                       seed=3)
    return db, cfg


# ---------------------------------------------------------------------------
# the owner process
# ---------------------------------------------------------------------------
def run_owner(args) -> None:
    d = Path(args.dir)
    db, cfg = _build(args)
    session = ZKGraphSession(db, cfg)
    log = TransparencyLog.open(d / "transparency.log", ORIGIN)
    if log.recovered_bytes:
        print(f"[owner] crash recovery: truncated {log.recovered_bytes} "
              f"torn-tail bytes, {log.size} intact leaves", flush=True)
    raw = session.commitments.to_bytes()
    if log.size == 0:
        checkpoint, inclusion, raw = session.publish_to(log)
        print(f"[owner] manifest published: {len(raw)} bytes -> "
              f"log {checkpoint.origin!r} size {checkpoint.tree_size}",
              flush=True)
    else:
        assert log.entry(0) == raw, "restart re-derived a different manifest"
        inclusion = log.inclusion_proof(0, 1)
        print(f"[owner] resumed with {log.size} published leaves", flush=True)
    # the bootstrap artifacts are (re)written on EVERY start — a crash
    # between the log append and these writes must not strand verifiers;
    # everything is deterministic from the persisted log, so a rewrite is
    # byte-identical to what a verifier may already have read
    cp1 = log.checkpoint(1)
    atomic_write(d / "manifest.bin", raw)
    atomic_write(d / "inclusion.bin", inclusion.to_bytes())
    atomic_write(d / "head0.bin", gossip.GossipMessage(
        cp1, None, gossip.sign_checkpoint(AUTH_KEY, cp1)).to_bytes())
    log.sync()                  # audit disk against memory before serving

    spool = d / "bundles"
    spool.mkdir(exist_ok=True)
    pending = [(i, kind, params)
               for i, (kind, params) in enumerate(query_queue(db,
                                                              args.queries))
               if not (spool / f"q{i}.bin").exists()]
    # all unproven queries ride ONE ProofService: same-shaped steps from
    # different queries share lane-batched proves, and each returned bundle
    # is wire-byte-identical to a solo session.prove (spot-checked below)
    if pending:
        with ProofService(session, max_batch=4, flush_interval=0.25) as svc:
            t0 = time.time()
            futs = [(i, kind, svc.submit(kind, params))
                    for i, kind, params in pending]
            for i, kind, fut in futs:
                bundle = fut.result()
                atomic_write(spool / f"q{i}.bin", bundle.to_bytes())
                print(f"[owner] q{i} {kind:5s} spooled at "
                      f"{time.time() - t0:.1f}s ({len(bundle.steps)} ops)",
                      flush=True)
            occupancy = svc.stats()["batch_occupancy"]
        print(f"[owner] served {len(pending)} queries, mean batch "
              f"occupancy {occupancy['mean']:.2f}", flush=True)
        # byte-for-byte spot check: re-prove one serviced query solo and
        # compare wire bytes (timings are telemetry, not proof material)
        i0, kind0, params0 = pending[0]
        serviced = (spool / f"q{i0}.bin").read_bytes()
        solo = session.prove(kind0, params0)
        assert _strip_timings(serviced) == _strip_timings(solo.to_bytes()), \
            "serviced bundle bytes diverged from the solo prover"
        print(f"[owner] q{i0} re-proven solo: bytes identical", flush=True)

    if log.size < 2:            # manifest revision: the log must only GROW
        session.publish_to(log)
    atomic_write(d / "head1.bin",
                 gossip.emit(log, AUTH_KEY, since=1).to_bytes())
    head = log.sync()
    log.close()
    stats = session.cache.stats()
    atomic_write(d / "owner.done", json.dumps(dict(
        queries=args.queries, tree_size=head.tree_size,
        keygen_misses=stats["misses"], keygen_hits=stats["hits"]),
        sort_keys=True).encode())
    print(f"[owner] done: log size {head.tree_size}, keygen cache "
          f"{stats['misses']} misses / {stats['hits']} hits", flush=True)


# ---------------------------------------------------------------------------
# a verifier process
# ---------------------------------------------------------------------------
def run_verifier(args) -> None:
    d = Path(args.dir)
    name = args.name
    deadline = time.time() + TIMEOUT
    _, cfg = _build(args)       # policy only — a verifier has NO database

    raw = wait_for(d / "manifest.bin", deadline)
    inclusion = InclusionProof.from_bytes(
        wait_for(d / "inclusion.bin", deadline))
    peer = gossip.GossipPeer(ORIGIN, AUTH_KEY)
    peer.offer(gossip.GossipMessage.from_bytes(
        wait_for(d / "head0.bin", deadline)))
    verifier = ZKGraphSession.verifier(
        cfg=cfg, gossip=peer, inclusion=inclusion, manifest_bytes=raw)
    print(f"[{name}] trust root bootstrapped from gossip-pinned head "
          f"@{peer.pinned.tree_size}", flush=True)

    results = {}
    for i in range(args.queries):
        data = wait_for(d / "bundles" / f"q{i}.bin", deadline)
        results[f"q{i}"] = bool(verifier.verify_bytes(data))
        print(f"[{name}] q{i} verified from {len(data)} bytes: "
              f"{results[f'q{i}']}", flush=True)

    # the owner revised the manifest: advance ONLY on a consistency proof
    advanced = peer.offer(gossip.GossipMessage.from_bytes(
        wait_for(d / "head1.bin", deadline)))
    print(f"[{name}] head advanced to @{peer.pinned.tree_size} "
          f"(append-only growth proven)", flush=True)

    # verifier <-> verifier gossip: exchange heads, expect agreement
    atomic_write(d / f"{name}.head.bin", peer.head_message().to_bytes())
    other = "v2" if name == "v1" else "v1"
    other_msg = gossip.GossipMessage.from_bytes(
        wait_for(d / f"{other}.head.bin", deadline))
    cross = peer.offer(other_msg)       # same honest head: no advance
    print(f"[{name}] cross-gossip with {other}: heads agree", flush=True)

    detected = None
    try:
        peer.offer(gossip.GossipMessage.from_bytes(
            wait_for(d / "equivocation.bin", deadline)))
        detected = False
    except gossip.EquivocationError as e:
        detected = True
        print(f"[{name}] ALARM: {e}", flush=True)

    atomic_write(d / f"{name}.done", json.dumps(dict(
        results=results, advanced=bool(advanced), cross_advance=bool(cross),
        equivocation_detected=detected, head=peer.pinned.tree_size),
        sort_keys=True).encode())


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------
def _spawn(role: str, d: str, args, extra=()) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, str(Path(__file__).resolve()), "--role", role,
           "--dir", d, "--queries", str(args.queries),
           *_cfg_args(pv.ProverConfig(args.blowup, args.n_queries,
                                      args.fri_final_size), args.n_knows,
                      args.n_persons), *extra]
    return subprocess.Popen(cmd, env=env)


def _wait_done(path: Path, procs, deadline: float) -> dict:
    while time.time() < deadline:
        if path.exists():
            return json.loads(path.read_bytes())
        for p in procs:
            if p.poll() not in (None, 0):
                raise RuntimeError(
                    f"child {p.args[-1]} exited with {p.returncode} "
                    f"before producing {path.name}")
        time.sleep(0.2)
    raise TimeoutError(f"timed out waiting for {path}")


def run_driver(args) -> dict:
    d = Path(args.dir or tempfile.mkdtemp(prefix="zkgraph_demo_"))
    d.mkdir(parents=True, exist_ok=True)
    stale = [p.name for p in (d / "owner.done", d / "v1.done",
                              d / "v2.done", d / "equivocation.bin",
                              d / "transparency.log") if p.exists()]
    if stale:
        raise SystemExit(
            f"[driver] {d} holds artifacts from a previous run ({stale}); "
            f"the demo's waits would satisfy themselves from them without "
            f"exercising anything — use a fresh --dir")
    (d / "bundles").mkdir(exist_ok=True)
    print(f"[driver] work dir: {d}", flush=True)
    deadline = time.time() + TIMEOUT
    children = []
    try:
        for name in ("v1", "v2"):
            children.append(_spawn("verifier", str(d), args,
                                   ("--name", name)))
        owner = _spawn("owner", str(d), args)
        children.append(owner)

        # let the owner prove `kill_after` queries, then pull the plug
        kill_mark = d / "bundles" / f"q{args.kill_after - 1}.bin"
        wait_for(kill_mark, deadline)
        try:
            owner.send_signal(signal.SIGKILL)
        except ProcessLookupError:
            pass                # already exited: restart is a plain resume
        owner.wait()
        print(f"[driver] owner SIGKILLed after {args.kill_after} queries",
              flush=True)
        # what a crash mid-write leaves: a torn half-record on the log tail
        with open(d / "transparency.log", "ab") as fh:
            fh.write(b"\x01\x40\x00\x00\x00partial")
        print("[driver] torn half-record appended to the log tail",
              flush=True)

        owner = _spawn("owner", str(d), args)
        children.append(owner)
        owner_summary = _wait_done(d / "owner.done", [owner], deadline)

        # the malicious-owner act: fork the history (different leaf 0),
        # sign the forked head with the REAL origin key, and gossip it
        raw = (d / "manifest.bin").read_bytes()
        fork = TransparencyLog(ORIGIN)
        fork.append(raw + b"\xff")
        fork.append(raw)
        forged = gossip.emit(fork, AUTH_KEY)
        atomic_write(d / "equivocation.bin", forged.to_bytes())
        print("[driver] forged (signed!) fork head gossiped to verifiers",
              flush=True)

        summaries = {
            name: _wait_done(d / f"{name}.done", children[:2], deadline)
            for name in ("v1", "v2")}
    finally:
        for p in children:
            if p.poll() is None:
                p.kill()

    for name, s in summaries.items():
        assert all(s["results"].values()), f"{name} rejected a bundle: {s}"
        assert s["advanced"] and not s["cross_advance"], s
        assert s["equivocation_detected"] is True, \
            f"{name} missed the equivocation"
    assert owner_summary["tree_size"] == 2
    n_ok = sum(len(s["results"]) for s in summaries.values())
    print(f"[driver] OK: crash-recovered owner served {args.queries} "
          f"queries; {n_ok} bundle verifications across 2 verifier "
          f"processes; revision advanced by consistency proof; "
          f"equivocation detected by both peers", flush=True)
    return dict(owner=owner_summary, **summaries)


def main(argv=None, n_knows=128, n_persons=24, cfg=CFG):
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=["driver", "owner", "verifier"],
                    default="driver")
    ap.add_argument("--dir", default=None)
    ap.add_argument("--name", default="v1")
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--kill-after", type=int, default=2,
                    help="SIGKILL the owner after this many proven queries")
    ap.add_argument("--blowup", type=int, default=cfg.blowup)
    ap.add_argument("--n-queries", type=int, default=cfg.n_queries)
    ap.add_argument("--fri-final-size", type=int, default=cfg.fri_final_size)
    ap.add_argument("--n-knows", type=int, default=n_knows)
    ap.add_argument("--n-persons", type=int, default=n_persons)
    args = ap.parse_args(argv)
    # the kill mark must be a bundle the owner actually produces, or the
    # driver would wait out the whole demo timeout on a short queue
    args.kill_after = max(1, min(args.kill_after, args.queries))
    if args.role == "owner":
        return run_owner(args)
    if args.role == "verifier":
        return run_verifier(args)
    return run_driver(args)


if __name__ == "__main__":
    main()
